//! Self-contained testing/benchmarking support: a deterministic PRNG and a
//! tiny property-testing driver, used because this workspace builds fully
//! offline (no `rand`/`proptest`/`criterion` available).

/// xorshift64* — deterministic, seedable, good enough for test-case
/// generation and synthetic workloads (NOT cryptographic).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng { state: seed.max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + (self.below((hi - lo + 1) as u64) as i64)
    }

    pub fn i16(&mut self) -> i16 {
        self.next_u64() as i16
    }

    /// A "small" i16 that never overflows 16-bit adds/muls in pairs —
    /// useful when testing exact agreement between backends.
    pub fn small_i16(&mut self) -> i16 {
        self.range_i64(-100, 100) as i16
    }

    pub fn f32(&mut self) -> f32 {
        // Uniform in [0, 1).
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Vector of small i16 values.
    pub fn small_vec(&mut self, len: usize) -> Vec<i16> {
        (0..len).map(|_| self.small_i16()).collect()
    }
}

/// Run a randomized property `cases` times with distinct deterministic
/// seeds; panics (with the failing seed) on the first violation.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: u64, mut prop: F) {
    for case in 0..cases {
        let seed = 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(case + 1) ^ 0xDEAD_BEEF;
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            eprintln!("property `{name}` failed on case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut rng = Rng::new(7);
        for _ in 0..1000 {
            assert!(rng.below(13) < 13);
        }
    }

    #[test]
    fn range_is_inclusive_and_covers_endpoints() {
        let mut rng = Rng::new(5);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..10_000 {
            let v = rng.range_i64(-2, 2);
            assert!((-2..=2).contains(&v));
            saw_lo |= v == -2;
            saw_hi |= v == 2;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut rng = Rng::new(11);
        for _ in 0..1000 {
            let v = rng.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("counts", 25, |_| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic]
    fn check_propagates_failures() {
        check("fails", 10, |rng| assert!(rng.below(10) > 100));
    }
}
