//! Transform pipelines: compose a transform sequence once, then apply it
//! to batched point sets — the unit of work the coordinator schedules onto
//! a backend. Also the float ↔ fixed-point bridge to the M1's 16-bit
//! integer datapath.

use super::geometry::Mat3;
use super::transform::Transform;

/// A composed sequence of transforms applied to batches of points.
#[derive(Debug, Clone, Default)]
pub struct TransformPipeline {
    pub transforms: Vec<Transform>,
}

impl TransformPipeline {
    pub fn new(transforms: Vec<Transform>) -> TransformPipeline {
        TransformPipeline { transforms }
    }

    /// The single composed homogeneous matrix.
    pub fn matrix(&self) -> Mat3 {
        Transform::compose(&self.transforms)
    }

    /// Apply natively (f32 reference path) to parallel coordinate arrays,
    /// in place.
    pub fn apply_native(&self, xs: &mut [f32], ys: &mut [f32]) {
        assert_eq!(xs.len(), ys.len());
        let m = self.matrix();
        let [a, b, c, d] = m.linear();
        let (tx, ty) = m.translation();
        for i in 0..xs.len() {
            let (x, y) = (xs[i], ys[i]);
            xs[i] = a * x + b * y + tx;
            ys[i] = c * x + d * y + ty;
        }
    }
}

/// Fixed-point quantization of an affine transform for the M1's integer
/// datapath: the 2×2 linear part in `Q(shift)` (scaled by `2^shift`,
/// clamped to the 8-bit context-immediate range), translation as plain
/// integers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FixedPointParams {
    /// Row-major quantized 2×2 matrix.
    pub m: [i16; 4],
    /// Integer translation.
    pub t: [i16; 2],
    /// The Q shift.
    pub shift: u8,
}

impl FixedPointParams {
    /// Quantize `mat`'s linear part with `shift` fractional bits. Returns
    /// `None` if any scaled entry exceeds the i8 context-immediate range
    /// or the translation exceeds it (the caller then falls back to a
    /// float backend or a smaller shift).
    pub fn quantize(mat: &Mat3, shift: u8) -> Option<FixedPointParams> {
        let scale = (1i32 << shift) as f32;
        let lin = mat.linear();
        let mut m = [0i16; 4];
        for (q, &v) in m.iter_mut().zip(lin.iter()) {
            let s = (v * scale).round();
            if !(-128.0..=127.0).contains(&s) {
                return None;
            }
            *q = s as i16;
        }
        let (tx, ty) = mat.translation();
        let (tx, ty) = (tx.round(), ty.round());
        if !(-128.0..=127.0).contains(&tx) || !(-128.0..=127.0).contains(&ty) {
            return None;
        }
        Some(FixedPointParams { m, t: [tx as i16, ty as i16], shift })
    }

    /// Native fixed-point reference: exactly what the M1 point-transform
    /// mapping computes (`q = ((M·p) >> shift) + t` with 16-bit wrap).
    pub fn apply(&self, xs: &[i16], ys: &[i16]) -> (Vec<i16>, Vec<i16>) {
        assert_eq!(xs.len(), ys.len());
        let mut ox = Vec::with_capacity(xs.len());
        let mut oy = Vec::with_capacity(xs.len());
        for i in 0..xs.len() {
            let (x, y) = (xs[i] as i32, ys[i] as i32);
            let xp = ((self.m[0] as i32 * x + self.m[1] as i32 * y) >> self.shift)
                .wrapping_add(self.t[0] as i32);
            let yp = ((self.m[2] as i32 * x + self.m[3] as i32 * y) >> self.shift)
                .wrapping_add(self.t[1] as i32);
            ox.push(xp as i16);
            oy.push(yp as i16);
        }
        (ox, oy)
    }

    /// Worst-case coordinate error (vs the float transform) for inputs
    /// bounded by `max_coord`: quantization error of the matrix entries
    /// (≤ 2⁻ˢʰⁱᶠᵗ⁻¹ each) times 2·|coord|, plus 1 for the truncating
    /// shift, plus 0.5 for translation rounding.
    pub fn error_bound(&self, max_coord: f32) -> f32 {
        let q = 0.5 / (1i32 << self.shift) as f32;
        2.0 * q * max_coord + 1.0 + 0.5
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphics::geometry::Point2;
    use crate::testkit::{check, Rng};

    #[test]
    fn pipeline_matches_pointwise_application() {
        let pipe = TransformPipeline::new(vec![
            Transform::Rotate { theta: 0.3 },
            Transform::Scale { sx: 2.0, sy: 0.5 },
            Transform::Translate { tx: 10.0, ty: -5.0 },
        ]);
        let pts = [Point2::new(1.0, 2.0), Point2::new(-3.0, 0.5)];
        let mut xs: Vec<f32> = pts.iter().map(|p| p.x).collect();
        let mut ys: Vec<f32> = pts.iter().map(|p| p.y).collect();
        pipe.apply_native(&mut xs, &mut ys);
        for (i, p) in pts.iter().enumerate() {
            let q = pts[i];
            let expected = pipe.matrix().apply(q);
            assert!(Point2::new(xs[i], ys[i]).dist(expected) < 1e-4, "{p:?}");
        }
    }

    #[test]
    fn quantize_identity_is_exact() {
        let fp = FixedPointParams::quantize(&Mat3::IDENTITY, 6).unwrap();
        assert_eq!(fp.m, [64, 0, 0, 64]);
        assert_eq!(fp.t, [0, 0]);
        let (xs, ys) = fp.apply(&[5, -7], &[9, 11]);
        assert_eq!(xs, vec![5, -7]);
        assert_eq!(ys, vec![9, 11]);
    }

    #[test]
    fn quantize_rejects_out_of_range() {
        // 3.0 in Q6 = 192 > 127.
        assert!(FixedPointParams::quantize(&Mat3::scale(3.0, 1.0), 6).is_none());
        // Fits at a smaller shift.
        assert!(FixedPointParams::quantize(&Mat3::scale(3.0, 1.0), 5).is_some());
        // Oversized translation.
        assert!(FixedPointParams::quantize(&Mat3::translate(1000.0, 0.0), 6).is_none());
    }

    #[test]
    fn fixed_point_rotation_stays_within_error_bound() {
        check("fixed-point error bound", 30, |rng: &mut Rng| {
            let theta = rng.f32_range(-3.1, 3.1);
            let mat = Mat3::rotate(theta);
            let fp = FixedPointParams::quantize(&mat, 6).unwrap();
            let xs: Vec<i16> = (0..32).map(|_| rng.range_i64(-100, 100) as i16).collect();
            let ys: Vec<i16> = (0..32).map(|_| rng.range_i64(-100, 100) as i16).collect();
            let (ox, oy) = fp.apply(&xs, &ys);
            let bound = fp.error_bound(100.0);
            for i in 0..xs.len() {
                let exact = mat.apply(Point2::new(xs[i] as f32, ys[i] as f32));
                assert!(
                    (ox[i] as f32 - exact.x).abs() <= bound,
                    "x: {} vs {} (bound {bound})",
                    ox[i],
                    exact.x
                );
                assert!((oy[i] as f32 - exact.y).abs() <= bound);
            }
        });
    }

    #[test]
    fn fixed_point_agrees_with_m1_point_transform_mapping() {
        // The native fixed-point reference and the simulated M1 routine
        // must agree bit-for-bit.
        use crate::mapping::{runner::run_routine, PointTransformMapping};
        check("fp == M1 mapping", 20, |rng: &mut Rng| {
            let theta = rng.f32_range(-3.1, 3.1);
            let fp = FixedPointParams::quantize(&Mat3::rotate(theta), 6).unwrap();
            let xs: Vec<i16> = (0..8).map(|_| rng.range_i64(-100, 100) as i16).collect();
            let ys: Vec<i16> = (0..8).map(|_| rng.range_i64(-100, 100) as i16).collect();
            let mapping = PointTransformMapping { n: 8, m: fp.m, t: fp.t, shift: fp.shift };
            let out = run_routine(&mapping.compile(), &xs, Some(&ys));
            let (ex, ey) = fp.apply(&xs, &ys);
            let (mx, my) = out.result.split_at(8);
            assert_eq!(mx, &ex[..]);
            assert_eq!(my, &ey[..]);
        });
    }

    #[test]
    fn empty_pipeline_is_identity() {
        let pipe = TransformPipeline::default();
        let mut xs = vec![1.0, 2.0];
        let mut ys = vec![3.0, 4.0];
        pipe.apply_native(&mut xs, &mut ys);
        assert_eq!(xs, vec![1.0, 2.0]);
        assert_eq!(ys, vec![3.0, 4.0]);
    }
}
