//! # 2-D graphics: geometry, transformations, scenes
//!
//! The application layer the paper motivates (§4): points, homogeneous
//! transforms (translation, scaling, rotation, composition) and simple
//! scenes. This is the "complete graphics acceleration library" of §7 —
//! the [`crate::coordinator`] accelerates [`pipeline::TransformPipeline`]
//! applications over the M1 simulator, the XLA runtime, or native code.

pub mod geometry;
pub mod pipeline;
pub mod scene;
pub mod three_d;
pub mod transform;

pub use geometry::{Mat3, Point2};
pub use pipeline::{FixedPointParams, TransformPipeline};
pub use scene::Scene;
pub use three_d::{Mat4, Pipeline3D, Point3};
pub use transform::Transform;
