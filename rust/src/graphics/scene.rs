//! Scenes: batched point sets with polygon connectivity, plus synthetic
//! generators for the examples/benches (the paper's Figure 4 image-
//! tracking workload, in spirit).

use super::geometry::Point2;
use crate::testkit::Rng;

/// A 2-D scene: a flat point (vertex) pool plus polygons indexing into it.
#[derive(Debug, Clone, Default)]
pub struct Scene {
    pub points: Vec<Point2>,
    /// Each polygon is a list of vertex indices (closed implicitly).
    pub polygons: Vec<Vec<u32>>,
}

impl Scene {
    pub fn new() -> Scene {
        Scene::default()
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Add a regular `sides`-gon centred at `c` with circumradius `r`.
    pub fn add_regular_polygon(&mut self, c: Point2, r: f32, sides: usize) {
        assert!(sides >= 3);
        let base = self.points.len() as u32;
        for k in 0..sides {
            let a = 2.0 * std::f32::consts::PI * k as f32 / sides as f32;
            self.points.push(Point2::new(c.x + r * a.cos(), c.y + r * a.sin()));
        }
        self.polygons.push((base..base + sides as u32).collect());
    }

    /// Synthetic scene: `polygons` regular polygons with 3–10 sides
    /// scattered over `[-extent, extent]²`. Deterministic for a given
    /// seed.
    pub fn synthetic(polygons: usize, extent: f32, seed: u64) -> Scene {
        let mut rng = Rng::new(seed);
        let mut scene = Scene::new();
        for _ in 0..polygons {
            let c = Point2::new(
                rng.f32_range(-extent, extent),
                rng.f32_range(-extent, extent),
            );
            let r = rng.f32_range(extent * 0.01, extent * 0.1);
            let sides = rng.range_i64(3, 10) as usize;
            scene.add_regular_polygon(c, r, sides);
        }
        scene
    }

    /// Flatten to parallel x / y coordinate vectors (the layout the
    /// accelerator backends consume).
    pub fn coords(&self) -> (Vec<f32>, Vec<f32>) {
        (
            self.points.iter().map(|p| p.x).collect(),
            self.points.iter().map(|p| p.y).collect(),
        )
    }

    /// Axis-aligned bounding box `(min, max)`.
    pub fn bounds(&self) -> (Point2, Point2) {
        let mut lo = Point2::new(f32::INFINITY, f32::INFINITY);
        let mut hi = Point2::new(f32::NEG_INFINITY, f32::NEG_INFINITY);
        for p in &self.points {
            lo.x = lo.x.min(p.x);
            lo.y = lo.y.min(p.y);
            hi.x = hi.x.max(p.x);
            hi.y = hi.y.max(p.y);
        }
        (lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regular_polygon_vertices_on_circle() {
        let mut s = Scene::new();
        s.add_regular_polygon(Point2::new(1.0, 2.0), 3.0, 6);
        assert_eq!(s.len(), 6);
        assert_eq!(s.polygons.len(), 1);
        for &i in &s.polygons[0] {
            let d = s.points[i as usize].dist(Point2::new(1.0, 2.0));
            assert!((d - 3.0).abs() < 1e-4);
        }
    }

    #[test]
    fn synthetic_is_deterministic() {
        let a = Scene::synthetic(10, 100.0, 7);
        let b = Scene::synthetic(10, 100.0, 7);
        assert_eq!(a.points.len(), b.points.len());
        for (p, q) in a.points.iter().zip(&b.points) {
            assert_eq!(p, q);
        }
        let c = Scene::synthetic(10, 100.0, 8);
        assert_ne!(
            a.points.iter().map(|p| p.x).sum::<f32>(),
            c.points.iter().map(|p| p.x).sum::<f32>()
        );
    }

    #[test]
    fn coords_are_parallel_arrays() {
        let s = Scene::synthetic(5, 10.0, 1);
        let (xs, ys) = s.coords();
        assert_eq!(xs.len(), s.len());
        assert_eq!(ys.len(), s.len());
        assert_eq!(xs[3], s.points[3].x);
        assert_eq!(ys[3], s.points[3].y);
    }

    #[test]
    fn bounds_contain_all_points() {
        let s = Scene::synthetic(20, 50.0, 3);
        let (lo, hi) = s.bounds();
        for p in &s.points {
            assert!(p.x >= lo.x && p.x <= hi.x);
            assert!(p.y >= lo.y && p.y <= hi.y);
        }
    }

    #[test]
    fn polygon_count_matches_request() {
        let s = Scene::synthetic(13, 10.0, 42);
        assert_eq!(s.polygons.len(), 13);
        assert!(s.len() >= 13 * 3);
    }
}
