//! 3-D geometry and transforms — the extension the authors pursued in
//! "2D and 3D Computer Graphics Algorithms under MorphoSys" (paper
//! reference [8]): homogeneous 4×4 matrices over 3-D points, with the
//! same translate/scale/rotate vocabulary.

use crate::testkit::Rng;

/// A 3-D point.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Point3 {
    pub x: f32,
    pub y: f32,
    pub z: f32,
}

impl Point3 {
    pub fn new(x: f32, y: f32, z: f32) -> Point3 {
        Point3 { x, y, z }
    }

    pub fn dist(self, o: Point3) -> f32 {
        ((self.x - o.x).powi(2) + (self.y - o.y).powi(2) + (self.z - o.z).powi(2)).sqrt()
    }
}

/// Row-major homogeneous 4×4 matrix.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Mat4 {
    pub m: [[f32; 4]; 4],
}

impl Mat4 {
    pub const IDENTITY: Mat4 = Mat4 {
        m: [
            [1.0, 0.0, 0.0, 0.0],
            [0.0, 1.0, 0.0, 0.0],
            [0.0, 0.0, 1.0, 0.0],
            [0.0, 0.0, 0.0, 1.0],
        ],
    };

    pub fn translate(tx: f32, ty: f32, tz: f32) -> Mat4 {
        let mut m = Mat4::IDENTITY;
        m.m[0][3] = tx;
        m.m[1][3] = ty;
        m.m[2][3] = tz;
        m
    }

    pub fn scale(sx: f32, sy: f32, sz: f32) -> Mat4 {
        let mut m = Mat4::IDENTITY;
        m.m[0][0] = sx;
        m.m[1][1] = sy;
        m.m[2][2] = sz;
        m
    }

    /// Rotation about the X axis.
    pub fn rotate_x(theta: f32) -> Mat4 {
        let (s, c) = theta.sin_cos();
        let mut m = Mat4::IDENTITY;
        m.m[1][1] = c;
        m.m[1][2] = -s;
        m.m[2][1] = s;
        m.m[2][2] = c;
        m
    }

    /// Rotation about the Y axis.
    pub fn rotate_y(theta: f32) -> Mat4 {
        let (s, c) = theta.sin_cos();
        let mut m = Mat4::IDENTITY;
        m.m[0][0] = c;
        m.m[0][2] = s;
        m.m[2][0] = -s;
        m.m[2][2] = c;
        m
    }

    /// Rotation about the Z axis.
    pub fn rotate_z(theta: f32) -> Mat4 {
        let (s, c) = theta.sin_cos();
        let mut m = Mat4::IDENTITY;
        m.m[0][0] = c;
        m.m[0][1] = -s;
        m.m[1][0] = s;
        m.m[1][1] = c;
        m
    }

    pub fn mul(&self, o: &Mat4) -> Mat4 {
        let mut r = [[0.0f32; 4]; 4];
        for (i, row) in r.iter_mut().enumerate() {
            for (j, cell) in row.iter_mut().enumerate() {
                *cell = (0..4).map(|k| self.m[i][k] * o.m[k][j]).sum();
            }
        }
        Mat4 { m: r }
    }

    pub fn apply(&self, p: Point3) -> Point3 {
        Point3::new(
            self.m[0][0] * p.x + self.m[0][1] * p.y + self.m[0][2] * p.z + self.m[0][3],
            self.m[1][0] * p.x + self.m[1][1] * p.y + self.m[1][2] * p.z + self.m[1][3],
            self.m[2][0] * p.x + self.m[2][1] * p.y + self.m[2][2] * p.z + self.m[2][3],
        )
    }

    /// The linear 3×3 part, row-major.
    pub fn linear(&self) -> [f32; 9] {
        [
            self.m[0][0], self.m[0][1], self.m[0][2],
            self.m[1][0], self.m[1][1], self.m[1][2],
            self.m[2][0], self.m[2][1], self.m[2][2],
        ]
    }

    pub fn translation(&self) -> (f32, f32, f32) {
        (self.m[0][3], self.m[1][3], self.m[2][3])
    }

    /// The 12 affine parameters the `affine3d` artifact consumes:
    /// `[m00..m22 row-major, tx, ty, tz]`.
    pub fn affine_params(&self) -> [f32; 12] {
        let l = self.linear();
        let (tx, ty, tz) = self.translation();
        [l[0], l[1], l[2], l[3], l[4], l[5], l[6], l[7], l[8], tx, ty, tz]
    }
}

/// A 3-D transform sequence, composed left-to-right.
#[derive(Debug, Clone, Default)]
pub struct Pipeline3D {
    pub matrices: Vec<Mat4>,
}

impl Pipeline3D {
    pub fn new(matrices: Vec<Mat4>) -> Pipeline3D {
        Pipeline3D { matrices }
    }

    pub fn matrix(&self) -> Mat4 {
        self.matrices.iter().fold(Mat4::IDENTITY, |acc, m| m.mul(&acc))
    }

    /// Apply natively to parallel coordinate arrays, in place.
    pub fn apply_native(&self, xs: &mut [f32], ys: &mut [f32], zs: &mut [f32]) {
        assert!(xs.len() == ys.len() && ys.len() == zs.len());
        let m = self.matrix();
        for i in 0..xs.len() {
            let p = m.apply(Point3::new(xs[i], ys[i], zs[i]));
            xs[i] = p.x;
            ys[i] = p.y;
            zs[i] = p.z;
        }
    }
}

/// A random rigid-ish 3-D transform for tests/benches.
pub fn random_transform(rng: &mut Rng) -> Mat4 {
    Mat4::translate(
        rng.f32_range(-10.0, 10.0),
        rng.f32_range(-10.0, 10.0),
        rng.f32_range(-10.0, 10.0),
    )
    .mul(&Mat4::rotate_z(rng.f32_range(-3.0, 3.0)))
    .mul(&Mat4::rotate_x(rng.f32_range(-3.0, 3.0)))
    .mul(&Mat4::scale(rng.f32_range(0.5, 1.5), rng.f32_range(0.5, 1.5), rng.f32_range(0.5, 1.5)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::check;

    const EPS: f32 = 1e-4;

    #[test]
    fn translate_and_scale() {
        let p = Point3::new(1.0, 2.0, 3.0);
        assert_eq!(Mat4::translate(1.0, -1.0, 0.5).apply(p), Point3::new(2.0, 1.0, 3.5));
        assert_eq!(Mat4::scale(2.0, 3.0, -1.0).apply(p), Point3::new(2.0, 6.0, -3.0));
    }

    #[test]
    fn axis_rotations_quarter_turn() {
        let p = Point3::new(1.0, 0.0, 0.0);
        let q = Mat4::rotate_z(std::f32::consts::FRAC_PI_2).apply(p);
        assert!(q.dist(Point3::new(0.0, 1.0, 0.0)) < EPS);
        let q = Mat4::rotate_y(std::f32::consts::FRAC_PI_2).apply(p);
        assert!(q.dist(Point3::new(0.0, 0.0, -1.0)) < EPS);
        let p = Point3::new(0.0, 1.0, 0.0);
        let q = Mat4::rotate_x(std::f32::consts::FRAC_PI_2).apply(p);
        assert!(q.dist(Point3::new(0.0, 0.0, 1.0)) < EPS);
    }

    #[test]
    fn rotations_preserve_norm() {
        check("rot3 preserves norm", 20, |rng| {
            let m = Mat4::rotate_x(rng.f32_range(-3.0, 3.0))
                .mul(&Mat4::rotate_y(rng.f32_range(-3.0, 3.0)))
                .mul(&Mat4::rotate_z(rng.f32_range(-3.0, 3.0)));
            let p = Point3::new(
                rng.f32_range(-5.0, 5.0),
                rng.f32_range(-5.0, 5.0),
                rng.f32_range(-5.0, 5.0),
            );
            let q = m.apply(p);
            let n0 = p.dist(Point3::default());
            let n1 = q.dist(Point3::default());
            assert!((n0 - n1).abs() < 1e-3 * (1.0 + n0));
        });
    }

    #[test]
    fn pipeline_matches_pointwise() {
        let pipe = Pipeline3D::new(vec![
            Mat4::scale(2.0, 2.0, 2.0),
            Mat4::rotate_z(0.5),
            Mat4::translate(1.0, 2.0, 3.0),
        ]);
        let mut xs = vec![1.0f32, -2.0];
        let mut ys = vec![0.5f32, 1.5];
        let mut zs = vec![3.0f32, -1.0];
        let (oxs, oys, ozs) = (xs.clone(), ys.clone(), zs.clone());
        pipe.apply_native(&mut xs, &mut ys, &mut zs);
        for i in 0..2 {
            let q = pipe.matrix().apply(Point3::new(oxs[i], oys[i], ozs[i]));
            assert!(Point3::new(xs[i], ys[i], zs[i]).dist(q) < EPS);
        }
    }

    #[test]
    fn affine_params_roundtrip() {
        let m = Mat4::translate(1.0, 2.0, 3.0).mul(&Mat4::rotate_y(0.7));
        let p = m.affine_params();
        let point = Point3::new(4.0, -5.0, 6.0);
        let q = m.apply(point);
        let manual = Point3::new(
            p[0] * point.x + p[1] * point.y + p[2] * point.z + p[9],
            p[3] * point.x + p[4] * point.y + p[5] * point.z + p[10],
            p[6] * point.x + p[7] * point.y + p[8] * point.z + p[11],
        );
        assert!(q.dist(manual) < EPS);
    }

    #[test]
    fn composition_is_left_to_right() {
        let pipe = Pipeline3D::new(vec![
            Mat4::translate(1.0, 0.0, 0.0),
            Mat4::scale(2.0, 2.0, 2.0),
        ]);
        // (0,0,0) → translate → (1,0,0) → scale → (2,0,0).
        let q = pipe.matrix().apply(Point3::default());
        assert!(q.dist(Point3::new(2.0, 0.0, 0.0)) < EPS);
    }
}
