//! The transformation vocabulary of the paper's §4: translation, scaling,
//! rotation, and their compositions.

use super::geometry::{Mat3, Point2};

/// One 2-D geometric transformation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Transform {
    /// `q = p + (tx, ty)` — the paper's vector-vector mapping.
    Translate { tx: f32, ty: f32 },
    /// `q = (sx·x, sy·y)` — the paper's vector-scalar mapping.
    Scale { sx: f32, sy: f32 },
    /// Counter-clockwise rotation about the origin, radians — the
    /// paper's matrix-multiplication mapping.
    Rotate { theta: f32 },
    /// Rotation about an arbitrary pivot (a composite: T · R · T⁻¹).
    RotateAbout { theta: f32, cx: f32, cy: f32 },
}

impl Transform {
    /// Homogeneous matrix of this transform.
    pub fn matrix(&self) -> Mat3 {
        match *self {
            Transform::Translate { tx, ty } => Mat3::translate(tx, ty),
            Transform::Scale { sx, sy } => Mat3::scale(sx, sy),
            Transform::Rotate { theta } => Mat3::rotate(theta),
            Transform::RotateAbout { theta, cx, cy } => Mat3::translate(cx, cy)
                .mul(&Mat3::rotate(theta))
                .mul(&Mat3::translate(-cx, -cy)),
        }
    }

    /// Apply to a single point.
    pub fn apply(&self, p: Point2) -> Point2 {
        self.matrix().apply(p)
    }

    /// Compose a sequence (applied left to right) into one matrix.
    pub fn compose(seq: &[Transform]) -> Mat3 {
        seq.iter().fold(Mat3::IDENTITY, |acc, t| t.matrix().mul(&acc))
    }

    /// Is this a pure translation (maps to the vector-vector routine)?
    pub fn is_translation(&self) -> bool {
        matches!(self, Transform::Translate { .. })
    }

    /// Is this a pure scaling (maps to the vector-scalar routine)?
    pub fn is_scaling(&self) -> bool {
        matches!(self, Transform::Scale { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const EPS: f32 = 1e-5;

    #[test]
    fn each_variant_matches_its_matrix() {
        let p = Point2::new(2.0, -1.0);
        for t in [
            Transform::Translate { tx: 3.0, ty: 4.0 },
            Transform::Scale { sx: -2.0, sy: 0.5 },
            Transform::Rotate { theta: 0.9 },
            Transform::RotateAbout { theta: 0.9, cx: 1.0, cy: 1.0 },
        ] {
            assert!(t.apply(p).dist(t.matrix().apply(p)) < EPS);
        }
    }

    #[test]
    fn rotate_about_pivot_fixes_the_pivot() {
        let t = Transform::RotateAbout { theta: 2.1, cx: 5.0, cy: -3.0 };
        let pivot = Point2::new(5.0, -3.0);
        assert!(t.apply(pivot).dist(pivot) < 1e-4);
    }

    #[test]
    fn compose_applies_left_to_right() {
        let seq = [
            Transform::Scale { sx: 2.0, sy: 2.0 },
            Transform::Translate { tx: 1.0, ty: 0.0 },
        ];
        let m = Transform::compose(&seq);
        // (1,1) → scaled (2,2) → translated (3,2).
        assert!(m.apply(Point2::new(1.0, 1.0)).dist(Point2::new(3.0, 2.0)) < EPS);
    }

    #[test]
    fn compose_empty_is_identity() {
        assert_eq!(Transform::compose(&[]), Mat3::IDENTITY);
    }

    #[test]
    fn classification_predicates() {
        assert!(Transform::Translate { tx: 1.0, ty: 2.0 }.is_translation());
        assert!(!Transform::Translate { tx: 1.0, ty: 2.0 }.is_scaling());
        assert!(Transform::Scale { sx: 1.0, sy: 2.0 }.is_scaling());
        assert!(!Transform::Rotate { theta: 1.0 }.is_translation());
    }
}
