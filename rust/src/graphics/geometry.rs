//! Points and homogeneous 3×3 matrices (the standard computer-graphics
//! formulation of §4's transformations).

/// A 2-D point (also used as a vector).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Point2 {
    pub x: f32,
    pub y: f32,
}

impl Point2 {
    pub fn new(x: f32, y: f32) -> Point2 {
        Point2 { x, y }
    }

    pub fn dist(self, other: Point2) -> f32 {
        ((self.x - other.x).powi(2) + (self.y - other.y).powi(2)).sqrt()
    }
}

impl std::ops::Add for Point2 {
    type Output = Point2;
    fn add(self, o: Point2) -> Point2 {
        Point2::new(self.x + o.x, self.y + o.y)
    }
}

impl std::ops::Sub for Point2 {
    type Output = Point2;
    fn sub(self, o: Point2) -> Point2 {
        Point2::new(self.x - o.x, self.y - o.y)
    }
}

/// Row-major homogeneous 3×3 matrix.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Mat3 {
    pub m: [[f32; 3]; 3],
}

impl Mat3 {
    pub const IDENTITY: Mat3 =
        Mat3 { m: [[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]] };

    /// Translation by `(tx, ty)` (paper §4, "Translations").
    pub fn translate(tx: f32, ty: f32) -> Mat3 {
        Mat3 { m: [[1.0, 0.0, tx], [0.0, 1.0, ty], [0.0, 0.0, 1.0]] }
    }

    /// Scaling about the origin (paper §4, "Scaling").
    pub fn scale(sx: f32, sy: f32) -> Mat3 {
        Mat3 { m: [[sx, 0.0, 0.0], [0.0, sy, 0.0], [0.0, 0.0, 1.0]] }
    }

    /// Counter-clockwise rotation about the origin by `theta` radians.
    pub fn rotate(theta: f32) -> Mat3 {
        let (s, c) = theta.sin_cos();
        Mat3 { m: [[c, -s, 0.0], [s, c, 0.0], [0.0, 0.0, 1.0]] }
    }

    /// Matrix product `self × other` (apply `other` first).
    pub fn mul(&self, other: &Mat3) -> Mat3 {
        let mut r = [[0.0f32; 3]; 3];
        for (i, row) in r.iter_mut().enumerate() {
            for (j, cell) in row.iter_mut().enumerate() {
                *cell = (0..3).map(|k| self.m[i][k] * other.m[k][j]).sum();
            }
        }
        Mat3 { m: r }
    }

    /// Transform one point.
    pub fn apply(&self, p: Point2) -> Point2 {
        Point2::new(
            self.m[0][0] * p.x + self.m[0][1] * p.y + self.m[0][2],
            self.m[1][0] * p.x + self.m[1][1] * p.y + self.m[1][2],
        )
    }

    /// The linear 2×2 part, row-major.
    pub fn linear(&self) -> [f32; 4] {
        [self.m[0][0], self.m[0][1], self.m[1][0], self.m[1][1]]
    }

    /// The translation column.
    pub fn translation(&self) -> (f32, f32) {
        (self.m[0][2], self.m[1][2])
    }

    /// Largest absolute difference to another matrix.
    pub fn max_abs_diff(&self, other: &Mat3) -> f32 {
        let mut d = 0.0f32;
        for i in 0..3 {
            for j in 0..3 {
                d = d.max((self.m[i][j] - other.m[i][j]).abs());
            }
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const EPS: f32 = 1e-5;

    #[test]
    fn translate_matches_paper_definition() {
        // q = p + t (paper: q = [x + tx, y + ty]).
        let q = Mat3::translate(3.0, -2.0).apply(Point2::new(1.0, 1.0));
        assert_eq!(q, Point2::new(4.0, -1.0));
    }

    #[test]
    fn scale_matches_paper_definition() {
        // q = S × p = [sx·x, sy·y].
        let q = Mat3::scale(2.0, 0.5).apply(Point2::new(3.0, 8.0));
        assert_eq!(q, Point2::new(6.0, 4.0));
    }

    #[test]
    fn rotation_quarter_turn() {
        let q = Mat3::rotate(std::f32::consts::FRAC_PI_2).apply(Point2::new(1.0, 0.0));
        assert!(q.dist(Point2::new(0.0, 1.0)) < EPS);
    }

    #[test]
    fn composition_applies_right_to_left() {
        // Scale then translate ≠ translate then scale.
        let p = Point2::new(1.0, 1.0);
        let scale_then_translate = Mat3::translate(10.0, 0.0).mul(&Mat3::scale(2.0, 2.0));
        assert!(scale_then_translate.apply(p).dist(Point2::new(12.0, 2.0)) < EPS);
        let translate_then_scale = Mat3::scale(2.0, 2.0).mul(&Mat3::translate(10.0, 0.0));
        assert!(translate_then_scale.apply(p).dist(Point2::new(22.0, 2.0)) < EPS);
    }

    #[test]
    fn identity_is_neutral() {
        let m = Mat3::rotate(0.7).mul(&Mat3::translate(1.0, 2.0));
        assert!(m.mul(&Mat3::IDENTITY).max_abs_diff(&m) < EPS);
        assert!(Mat3::IDENTITY.mul(&m).max_abs_diff(&m) < EPS);
    }

    #[test]
    fn rotation_preserves_distance() {
        let p = Point2::new(3.0, 4.0);
        let q = Mat3::rotate(1.234).apply(p);
        assert!((q.dist(Point2::default()) - 5.0).abs() < EPS);
    }

    #[test]
    fn scaling_shows_inherent_translation_of_figure6() {
        // Paper Figure 6: scaling is about the origin, so an off-origin
        // object also moves.
        let p = Point2::new(2.0, 2.0);
        let q = Mat3::scale(2.0, 2.0).apply(p);
        assert!(q.dist(p) > 0.0);
    }
}
