//! `repro` — the reproduction CLI. Run `repro help` (or any unknown
//! verb) for the authoritative verb listing in [`USAGE`].

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use morpho::coordinator::{
    BackendChoice, Coordinator, CoordinatorConfig, Router, RouterConfig, WireServer, WIRE_VERSION,
};
use morpho::graphics::Transform;
use morpho::loadgen;
use morpho::loadgen::TransportKind;
use morpho::mapping::{VecScalarMapping, VecVecMapping};
use morpho::morphosys::{AluOp, M1System};
use morpho::perf::{
    figure, render_figure, render_table, table1_listing, table2_listing, table3, table4, table5,
    to_csv,
};

/// The single authoritative verb listing: printed by `repro help` (exit
/// 0) and, to stderr, on any malformed or unknown invocation (exit 2).
const USAGE: &str = "\
repro — Performance Analysis of Linear Algebraic Functions, reproduction CLI

usage: repro <verb> [args]

verbs:
  all                       regenerate every table and figure
  table <1..5>              one table (1-2: TinyRISC listings)
  figure <9..16>            one figure (ASCII chart)
  csv <dir>                 write tables 3-5 and figures 9-16 as CSV
  trace <translation|scaling> [n]
                            mULATE-style execution trace (default n=64)
  artifacts                 list AOT artifacts and the PJRT platform
  serve [requests] [native|xla|m1sim] [shards] [sync|async]
                            quick coordinator smoke run; backend defaults
                            to xla; `shards` sizes the m1sim worker's tile
                            pool (default 1); `async` runs the m1sim
                            shards in overlapped async-DMA mode
  serve --listen <addr> [native|xla|m1sim] [shards] [sync|async]
                            bind the wire-protocol TCP listener on <addr>
                            (e.g. 127.0.0.1:7070) and serve until stdin
                            closes or Ctrl-C/SIGTERM, then drain
                            gracefully (every admitted request is
                            answered before exit)
  route --listen <addr> <backend-addr>...
                            fault-tolerant front-end: accept wire-protocol
                            clients on <addr> and balance them across the
                            given backend coordinators by least reported
                            queue depth; per-backend health-checked
                            breaker, mid-run failover with exactly-once
                            replies, immediate Unavailable when every
                            backend is dead; stdin-EOF/Ctrl-C drains
  loadtest [scenario|list] [--transport tcp|in-process] [shards] [seconds]
                            run a named load-generation scenario against
                            the coordinator (M1Sim backend) and write
                            BENCH_coordinator.json; `list` (or no
                            argument) names them on stdout, exit 0;
                            `--transport tcp` drives it over a loopback
                            wire-protocol listener instead of in-process
  sweep [--cell-seconds n] [--workers a,b] [--shards a,b]
        [--windows-us a,b] [--seed n]
                            measure the saturation surface: the ramp
                            scenario across the workers x shards x
                            batch-window grid (default 2x2x2, 2s cells),
                            written to BENCH_saturation.json
  replay <file.m1ra>        re-execute a failure-repro artifact (dumped on
                            shard crashes when MORPHO_REPRO_DIR is set)
                            step by step and report the exact first
                            divergent instruction; exit 0 iff it matches
  help                      print this listing";

fn usage() -> ! {
    eprintln!("{USAGE}");
    std::process::exit(2)
}

fn loadtest(name: &str, transport: Option<TransportKind>, shards: Option<usize>, seconds: Option<u64>) {
    if name == "list" {
        // The listing is data, not diagnostics: stdout, exit 0 — unlike
        // unknown scenarios/verbs, which go to stderr with exit 2.
        for sc in loadgen::scenario::all() {
            println!("{:<16} {}", sc.name, sc.summary);
        }
        return;
    }
    let mut sc = loadgen::scenario::by_name(name).unwrap_or_else(|| {
        eprintln!("unknown scenario `{name}` — try `repro loadtest list`");
        std::process::exit(2)
    });
    if let Some(t) = transport {
        sc = sc.with_transport(t);
    }
    if let Some(s) = shards {
        sc.shards = s.max(1);
    }
    if let Some(s) = seconds {
        sc.duration = std::time::Duration::from_secs(s.max(1));
    }
    println!(
        "loadtest `{}` via {}: {} [{}]…",
        sc.name,
        sc.transport.label(),
        sc.summary,
        sc.profile.label()
    );
    let report = loadgen::run_scenario(&sc).expect("run loadtest scenario");
    println!("\n{}", report.render());
    let path = loadgen::report::default_path();
    match loadgen::report::write_reports(&[report], &path) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => {
            eprintln!("\nfailed to write {path}: {e}");
            std::process::exit(1);
        }
    }
}

/// Parse a comma-separated numeric list, e.g. `--workers 1,2,4`.
fn parse_list(flag: &str, value: &str) -> Vec<u64> {
    let parsed: Option<Vec<u64>> = value.split(',').map(|s| s.trim().parse().ok()).collect();
    match parsed {
        Some(v) if !v.is_empty() => v,
        _ => {
            eprintln!("{flag}: expected a comma-separated number list, got `{value}`");
            std::process::exit(2)
        }
    }
}

fn sweep(args: &[&str]) {
    let mut config = loadgen::SweepConfig::default();
    let mut it = args.iter();
    while let Some(&flag) = it.next() {
        let value = *it.next().unwrap_or_else(|| usage());
        match flag {
            "--cell-seconds" => {
                let secs: f64 = value.parse().unwrap_or_else(|_| usage());
                if !(secs > 0.0 && secs.is_finite()) {
                    usage();
                }
                config.cell_duration = std::time::Duration::from_secs_f64(secs);
            }
            "--workers" => {
                config.workers = parse_list(flag, value).into_iter().map(|v| v as usize).collect();
            }
            "--shards" => {
                config.shards =
                    parse_list(flag, value).into_iter().map(|v| (v as usize).max(2)).collect();
            }
            "--windows-us" => {
                config.windows = parse_list(flag, value)
                    .into_iter()
                    .map(|v| std::time::Duration::from_micros(v.max(1)))
                    .collect();
            }
            "--seed" => config.seed = value.parse().unwrap_or_else(|_| usage()),
            _ => usage(),
        }
    }
    let cells = config.workers.len() * config.shards.len() * config.windows.len();
    println!(
        "saturation sweep: {} cells ({} workers x {} shards x {} windows), {:.1}s each, seed {}",
        cells,
        config.workers.len(),
        config.shards.len(),
        config.windows.len(),
        config.cell_duration.as_secs_f64(),
        config.seed,
    );
    let cells = match loadgen::run_sweep(&config, |line| println!("{line}")) {
        Ok(cells) => cells,
        Err(e) => {
            eprintln!("sweep failed: {e:#}");
            std::process::exit(1);
        }
    };
    let path = loadgen::saturation::default_path();
    match loadgen::saturation::write_cells(&config, &cells, &path) {
        Ok(()) => println!("\nwrote {path} ({} cells)", cells.len()),
        Err(e) => {
            eprintln!("\nfailed to write {path}: {e}");
            std::process::exit(1);
        }
    }
}

fn replay(path: &str) {
    let art = match morpho::replay::ReproArtifact::read_from(std::path::Path::new(path)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("failed to read repro artifact {path}: {e:#}");
            std::process::exit(1);
        }
    };
    println!("repro artifact {path}");
    println!("  summary: {}", art.summary);
    println!(
        "  fault seed {} · {} instructions · {} recorded step digests",
        art.seed,
        art.program.instructions.len(),
        art.step_digests.len()
    );
    match art.replay() {
        Ok(outcome) => {
            println!("{}", outcome.render());
            if !outcome.is_match() {
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("replay failed: {e:#}");
            std::process::exit(1);
        }
    }
}

fn print_table(n: u32) {
    match n {
        1 => println!("{}", table1_listing()),
        2 => println!("{}", table2_listing()),
        3 => println!(
            "{}",
            render_table(
                "Table 3 — vector-vector (translation) on the Intel baselines",
                &[table3()]
            )
        ),
        4 => println!(
            "{}",
            render_table(
                "Table 4 — vector-scalar (scaling) on the Intel baselines",
                &[table4()]
            )
        ),
        5 => println!(
            "{}",
            render_table("Table 5 — comparisons between algorithms and systems", &table5())
        ),
        _ => usage(),
    }
}

fn print_figure(n: u32) {
    if !(9..=16).contains(&n) {
        usage();
    }
    let (title, rows, per_elem) = figure(n);
    println!("{}", render_figure(&title, &rows, per_elem));
}

fn trace(alg: &str, n: usize) {
    let routine = match alg {
        "translation" => VecVecMapping { n, op: AluOp::Add }.compile(),
        "scaling" => VecScalarMapping { n, op: AluOp::Cmul, scalar: 5 }.compile(),
        _ => usage(),
    };
    let mut sys = M1System::new().with_trace();
    let u: Vec<i16> = (0..n as i16).collect();
    let v = vec![5i16; n];
    let out = morpho::mapping::runner::run_routine_on(
        &mut sys,
        &routine,
        &u,
        routine.v_elems.map(|_| &v[..]),
    );
    if let Some(t) = sys.take_trace() {
        println!("{}", t.render());
    }
    println!(
        "cycles={} ({}µs @100MHz)   result[..8]={:?}",
        out.report.cycles,
        out.report.micros(),
        &out.result[..8.min(out.result.len())]
    );
}

fn artifacts() {
    match morpho::runtime::Executor::discover() {
        Ok(exec) => {
            println!("PJRT platform: {}", exec.platform());
            println!("artifacts in {}:", exec.registry().dir().display());
            for name in exec.registry().names() {
                println!("  {name}");
            }
        }
        Err(e) => {
            eprintln!("no artifacts: {e:#}");
            std::process::exit(1);
        }
    }
}

fn serve(requests: usize, backend: BackendChoice, m1_shards: usize, m1_async_dma: bool) {
    let c = Coordinator::start(CoordinatorConfig {
        backend,
        workers: 1,
        m1_shards,
        m1_async_dma,
        ..Default::default()
    })
    .expect("start coordinator");
    let receivers: Vec<_> = (0..requests)
        .map(|i| {
            let n = 64 + (i * 191) % 2048;
            let xs: Vec<f32> = (0..n).map(|k| k as f32).collect();
            let ys = vec![0.5f32; n];
            c.submit(
                xs,
                ys,
                vec![
                    Transform::Rotate { theta: 0.1 * (i % 7) as f32 },
                    Transform::Translate { tx: 3.0, ty: -1.0 },
                ],
            )
            .unwrap()
        })
        .collect();
    for rx in receivers {
        rx.recv().unwrap().expect("serve demo requests carry no TTL, so none are shed");
    }
    println!("{}", c.metrics().render());
    c.shutdown();
}

/// Flipped by the SIGINT/SIGTERM handler and the stdin-EOF watcher:
/// tells `serve --listen` and `route` to drain and exit instead of dying
/// mid-request.
static DRAIN: AtomicBool = AtomicBool::new(false);

/// Turn Ctrl-C (and SIGTERM) into a graceful drain by flipping [`DRAIN`].
/// Dependency-free: the raw `signal(2)` the binary already links. The
/// handler does only async-signal-safe work — a single atomic store.
fn install_ctrl_c_drain() {
    extern "C" fn on_signal(_sig: i32) {
        DRAIN.store(true, Ordering::SeqCst);
    }
    #[cfg(unix)]
    unsafe {
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        let _ = signal(2, on_signal as usize); // SIGINT
        let _ = signal(15, on_signal as usize); // SIGTERM
    }
    #[cfg(not(unix))]
    let _ = on_signal; // stdin-EOF still drains
}

/// Watch stdin on a helper thread and flip [`DRAIN`] when the operator
/// closes it (Ctrl-D / pipe end).
fn drain_on_stdin_eof() {
    std::thread::spawn(|| {
        let mut line = String::new();
        while matches!(std::io::stdin().read_line(&mut line), Ok(n) if n > 0) {
            line.clear();
        }
        DRAIN.store(true, Ordering::SeqCst);
    });
}

/// `repro serve --listen <addr>`: put the coordinator on the wire and
/// serve remote clients until the operator closes stdin or sends
/// SIGINT/SIGTERM, then drain gracefully — stop accepting, answer
/// everything admitted, report, exit.
fn serve_listen(addr: &str, backend: BackendChoice, m1_shards: usize, m1_async_dma: bool) {
    let c = Arc::new(
        Coordinator::start(CoordinatorConfig {
            backend,
            workers: 2,
            m1_shards,
            m1_async_dma,
            ..Default::default()
        })
        .expect("start coordinator"),
    );
    let server = WireServer::bind(addr, c.clone()).unwrap_or_else(|e| {
        eprintln!("failed to bind {addr}: {e:#}");
        std::process::exit(1)
    });
    println!(
        "serving wire protocol v{WIRE_VERSION} on {} ({:?} backend, shards={})",
        server.local_addr(),
        backend,
        m1_shards
    );
    println!("close stdin (Ctrl-D) or Ctrl-C to drain and stop");
    install_ctrl_c_drain();
    drain_on_stdin_eof();
    server.serve_until(&DRAIN);
    println!("{}", c.metrics().render());
    if let Ok(c) = Arc::try_unwrap(c) {
        c.shutdown();
    }
}

/// `repro route --listen <addr> <backend-addr>...`: the fault-tolerant
/// front-end as its own process — clients speak wire protocol v1 to the
/// router exactly as they would to a single coordinator; the backends
/// are `repro serve --listen` processes (or anything serving the same
/// protocol). Drains on stdin-EOF or SIGINT/SIGTERM.
fn route(listen: &str, backend_addrs: &[&str]) {
    let mut backends = Vec::new();
    for a in backend_addrs {
        match a.parse::<std::net::SocketAddr>() {
            Ok(sa) => backends.push(sa),
            Err(e) => {
                eprintln!("bad backend address `{a}`: {e}");
                std::process::exit(2)
            }
        }
    }
    let n = backends.len();
    let router = Router::bind(listen, RouterConfig::new(backends)).unwrap_or_else(|e| {
        eprintln!("failed to bind router on {listen}: {e:#}");
        std::process::exit(1)
    });
    println!(
        "routing wire protocol v{WIRE_VERSION} on {} across {n} backends",
        router.local_addr()
    );
    println!("close stdin (Ctrl-D) or Ctrl-C to drain and stop");
    install_ctrl_c_drain();
    drain_on_stdin_eof();
    while !DRAIN.load(Ordering::SeqCst) {
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    println!("draining…");
    println!("{}", router.metrics().render());
    router.shutdown();
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut it = args.iter().map(String::as_str);
    match it.next() {
        Some("all") => {
            print_table(1);
            print_table(2);
            print_table(3);
            print_table(4);
            print_table(5);
            for f in 9..=16 {
                print_figure(f);
                println!();
            }
        }
        Some("table") => {
            let n = it.next().and_then(|s| s.parse().ok()).unwrap_or_else(|| usage());
            print_table(n);
        }
        Some("figure") => {
            let n = it.next().and_then(|s| s.parse().ok()).unwrap_or_else(|| usage());
            print_figure(n);
        }
        Some("csv") => {
            let dir = it.next().unwrap_or_else(|| usage());
            std::fs::create_dir_all(dir).expect("create csv dir");
            std::fs::write(format!("{dir}/table3.csv"), to_csv(&[table3()])).unwrap();
            std::fs::write(format!("{dir}/table4.csv"), to_csv(&[table4()])).unwrap();
            std::fs::write(format!("{dir}/table5.csv"), to_csv(&table5())).unwrap();
            for f in 9..=16 {
                let (_, rows, _) = figure(f);
                std::fs::write(format!("{dir}/figure{f}.csv"), to_csv(&[rows])).unwrap();
            }
            println!("wrote table3/4/5.csv and figure9..16.csv to {dir}");
        }
        Some("trace") => {
            let alg = it.next().unwrap_or_else(|| usage());
            let n = it.next().and_then(|s| s.parse().ok()).unwrap_or(64);
            trace(alg, n);
        }
        Some("artifacts") => artifacts(),
        Some("serve") => {
            // Strictly positional: a malformed count/shards errors out
            // instead of silently shifting the arguments. `--listen`
            // replaces the request count (a listener serves until told
            // to stop, not for N requests).
            let mut first = it.next();
            let listen = if first == Some("--listen") {
                let addr = it.next().unwrap_or_else(|| usage());
                first = it.next();
                Some(addr)
            } else {
                None
            };
            let n = match (listen, first) {
                (_, None) => 100,
                // With --listen the next positional is the backend, not
                // a request count — `first` already holds it.
                (Some(_), Some(_)) => 100,
                (None, Some(s)) => {
                    first = it.next();
                    s.parse().unwrap_or_else(|_| usage())
                }
            };
            let backend = match first {
                None => BackendChoice::Xla,
                Some("native") => BackendChoice::Native,
                Some("xla") => BackendChoice::Xla,
                Some("m1sim") => BackendChoice::M1Sim,
                Some(_) => usage(),
            };
            let shards = match it.next() {
                None => 1,
                Some(s) => s.parse().unwrap_or_else(|_| usage()),
            };
            let async_dma = match it.next() {
                None | Some("sync") => false,
                Some("async") => true,
                Some(_) => usage(),
            };
            match listen {
                Some(addr) => serve_listen(addr, backend, shards, async_dma),
                None => serve(n, backend, shards, async_dma),
            }
        }
        Some("route") => {
            if it.next() != Some("--listen") {
                usage();
            }
            let listen = it.next().unwrap_or_else(|| usage());
            let backends: Vec<&str> = it.collect();
            if backends.is_empty() {
                usage();
            }
            route(listen, &backends);
        }
        Some("loadtest") => {
            // Bare `repro loadtest` means `list`: a discovery query, not
            // a malformed invocation.
            let name = it.next().unwrap_or("list");
            let mut rest: Vec<&str> = it.collect();
            let transport = if rest.first() == Some(&"--transport") {
                rest.remove(0);
                if rest.is_empty() {
                    usage();
                }
                Some(TransportKind::parse(rest.remove(0)).unwrap_or_else(|| usage()))
            } else {
                None
            };
            if rest.len() > 2 {
                usage();
            }
            let shards = rest.first().map(|s| s.parse().unwrap_or_else(|_| usage()));
            let seconds = rest.get(1).map(|s| s.parse().unwrap_or_else(|_| usage()));
            loadtest(name, transport, shards, seconds);
        }
        Some("sweep") => {
            let rest: Vec<&str> = it.collect();
            sweep(&rest);
        }
        Some("replay") => {
            let path = it.next().unwrap_or_else(|| usage());
            replay(path);
        }
        Some("help") | Some("-h") | Some("--help") => println!("{USAGE}"),
        // Unknown (or missing) verb: the authoritative listing, non-zero.
        _ => usage(),
    }
}
