//! # Intel single-processor baselines (80386 / 80486 / Pentium)
//!
//! The paper compares the M1 against hand cycle-counted x86 assembly
//! (Tables 3–4 for translation/scaling, and matrix-multiplication
//! routines for rotation, with clock speeds 40 / 100 / 133 MHz per
//! Table 5). We go one step further than the paper: rather than summing a
//! static table by hand, we **execute** the same listings in an x86-subset
//! interpreter ([`x86`]) against per-model timing tables ([`timing`],
//! values from the Intel programmer's reference manuals), which yields
//! both the cycle count *and* a functional-correctness check of the
//! baseline.
//!
//! Where the paper's own arithmetic disagrees with its per-instruction
//! tables (e.g. 769T for the 64-element translation on the 80486, where
//! its own 11-cycle iteration implies 706T), we report the model's number
//! and flag the delta in `EXPERIMENTS.md §Deviations` — the comparisons'
//! *shape* is unaffected.

pub mod routines;
pub mod timing;
pub mod x86;

pub use timing::Cpu;
pub use x86::{Interp, Op, Reg16, RunReport};
