//! Per-model cycle timing tables for the 80386, 80486 and Pentium.
//!
//! Values follow the Intel programmer's reference manuals as the paper's
//! Tables 3–4 use them:
//!
//! | op                | 386 | 486 | Pentium |
//! |-------------------|-----|-----|---------|
//! | MOV reg, imm      | 2   | 1   | 1       |
//! | MOV reg, reg      | 2   | 1   | 1       |
//! | MOV reg, mem      | 4   | 1   | 1       |
//! | MOV mem, reg      | 2   | 1   | 1       |
//! | ALU reg, reg/imm  | 2   | 1   | 1       |
//! | ALU reg, mem      | 6   | 2   | 2       |
//! | INC/DEC           | 2   | 1   | 1       |
//! | IMUL (16-bit)     | 22  | 18  | 10      |
//! | Jcc taken / not   | 7/3 | 3/1 | 3/1     |
//!
//! The Pentium additionally dual-issues: two adjacent *simple* 1-cycle
//! instructions with no register dependence issue together (U+V pipes) —
//! implemented in [`crate::baselines::x86::Interp`] via
//! [`Cpu::pairable`]. Clock speeds per the paper's Table 5: 40, 100 and
//! 133 MHz.

use super::x86::ast::{Op, Operand};

/// Baseline CPU model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Cpu {
    I386,
    I486,
    Pentium,
}

impl Cpu {
    pub const ALL: [Cpu; 3] = [Cpu::I386, Cpu::I486, Cpu::Pentium];

    pub fn name(self) -> &'static str {
        match self {
            Cpu::I386 => "80386",
            Cpu::I486 => "80486",
            Cpu::Pentium => "Pentium",
        }
    }

    /// Clock in MHz (paper Table 5: "Clock speeds for the 80386, 80486,
    /// and Pentium are: 40, 100, and 133MHz").
    pub fn clock_mhz(self) -> f64 {
        match self {
            Cpu::I386 => 40.0,
            Cpu::I486 => 100.0,
            Cpu::Pentium => 133.0,
        }
    }

    pub fn dual_issue(self) -> bool {
        self == Cpu::Pentium
    }

    /// Cycle cost of one retired instruction (`taken` applies to
    /// branches).
    pub fn cost(self, op: &Op, taken: bool) -> u64 {
        let mem_src = |o: &Operand| matches!(o, Operand::Mem(_) | Operand::Abs(_));
        match self {
            Cpu::I386 => match op {
                Op::Mov(Operand::Reg(_), s) if mem_src(s) => 4,
                Op::Mov(_, _) => 2,
                Op::Add(_, s) | Op::Sub(_, s) | Op::Cmp(_, s) if mem_src(s) => 6,
                Op::Add(_, _) | Op::Sub(_, _) | Op::Cmp(_, _) => 2,
                Op::Imul(_) => 22,
                Op::Inc(_) | Op::Dec(_) => 2,
                Op::Jnz(_) => {
                    if taken {
                        7
                    } else {
                        3
                    }
                }
                Op::Jmp(_) => 7,
                Op::Halt => 0,
            },
            Cpu::I486 | Cpu::Pentium => match op {
                Op::Add(_, s) | Op::Sub(_, s) | Op::Cmp(_, s) if mem_src(s) => 2,
                Op::Mov(_, _) | Op::Add(_, _) | Op::Sub(_, _) | Op::Cmp(_, _) => 1,
                Op::Imul(_) => {
                    if self == Cpu::Pentium {
                        10
                    } else {
                        18
                    }
                }
                Op::Inc(_) | Op::Dec(_) => 1,
                Op::Jnz(_) => {
                    if taken {
                        3
                    } else {
                        1
                    }
                }
                Op::Jmp(_) => 3,
                Op::Halt => 0,
            },
        }
    }

    /// Can this instruction occupy the Pentium U pipe and accept a V-pipe
    /// partner? (simple 1-cycle register/memory ops only).
    pub fn u_pipe_candidate(op: &Op) -> bool {
        matches!(
            op,
            Op::Mov(_, _) | Op::Add(_, _) | Op::Sub(_, _) | Op::Inc(_) | Op::Dec(_) | Op::Cmp(_, _)
        )
    }

    /// Pentium U/V pairing rule: both simple, and the V instruction
    /// neither reads nor writes the U instruction's destination.
    pub fn pairable(u: &Op, v: &Op) -> bool {
        if !Cpu::u_pipe_candidate(u) || !Cpu::u_pipe_candidate(v) {
            return false;
        }
        match u.writes() {
            Some(w) => !v.reads().contains(&w) && v.writes() != Some(w),
            None => true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::x86::ast::Operand::{Imm, Mem, Reg};
    use crate::baselines::x86::ast::Reg16;

    #[test]
    fn table3_iteration_costs_match_paper() {
        // The paper's Table 3 (translation loop) per-instruction clocks.
        let body = [
            (Op::Mov(Reg(Reg16::AX), Mem(Reg16::SP)), 1, 4),
            (Op::Mov(Reg(Reg16::BX), Mem(Reg16::BP)), 1, 4),
            (Op::Add(Reg16::AX, Reg(Reg16::BX)), 1, 2),
            (Op::Mov(Mem(Reg16::DI), Reg(Reg16::AX)), 1, 2),
            (Op::Inc(Reg16::SP), 1, 2),
            (Op::Inc(Reg16::BP), 1, 2),
            (Op::Inc(Reg16::DI), 1, 2),
            (Op::Dec(Reg16::SI), 1, 2),
        ];
        for (op, c486, c386) in body {
            assert_eq!(Cpu::I486.cost(&op, false), c486, "{op:?} on 486");
            assert_eq!(Cpu::I386.cost(&op, false), c386, "{op:?} on 386");
        }
        // JNZ 3/1 on 486, 7/3 on 386 (paper: "3/1T", "7/3T").
        assert_eq!(Cpu::I486.cost(&Op::Jnz(0), true), 3);
        assert_eq!(Cpu::I486.cost(&Op::Jnz(0), false), 1);
        assert_eq!(Cpu::I386.cost(&Op::Jnz(0), true), 7);
        assert_eq!(Cpu::I386.cost(&Op::Jnz(0), false), 3);
    }

    #[test]
    fn setup_costs_match_paper() {
        // MOV reg, imm = 1T (486) / 2T (386) — Table 3 header block.
        let op = Op::Mov(Reg(Reg16::SP), Imm(0));
        assert_eq!(Cpu::I486.cost(&op, false), 1);
        assert_eq!(Cpu::I386.cost(&op, false), 2);
    }

    #[test]
    fn pairing_rules() {
        let inc_si = Op::Inc(Reg16::SI);
        let inc_di = Op::Inc(Reg16::DI);
        let use_si = Op::Mov(Reg(Reg16::AX), Mem(Reg16::SI));
        let imul = Op::Imul(Reg(Reg16::DX));
        assert!(Cpu::pairable(&inc_si, &inc_di));
        assert!(!Cpu::pairable(&inc_si, &use_si)); // RAW dependence
        assert!(!Cpu::pairable(&inc_si, &imul)); // IMUL is not simple
        assert!(!Cpu::pairable(&imul, &inc_si));
    }

    #[test]
    fn clocks_match_table5_note() {
        assert_eq!(Cpu::I386.clock_mhz(), 40.0);
        assert_eq!(Cpu::I486.clock_mhz(), 100.0);
        assert_eq!(Cpu::Pentium.clock_mhz(), 133.0);
    }
}
