//! The paper's baseline assembly listings, transcribed verbatim
//! (Tables 3–4) plus the dense matrix-multiplication routine its rotation
//! comparison implies, with staging/running helpers.
//!
//! Memory map (element-addressed): V1 at `0x1000`, V2 at `0x2000`, result
//! at `0x3000`, matrices A/B/C at `0x1000/0x2000/0x3000`, scratch loop
//! counters below `0x100`.

use super::timing::Cpu;
use super::x86::ast::Operand::{Abs, Imm, Mem, Reg};
use super::x86::ast::{Op, Reg16};
use super::x86::interp::{Interp, RunReport};

/// Element address of vector/matrix operand 1.
pub const V1_LOC: u16 = 0x1000;
/// Element address of vector/matrix operand 2.
pub const V2_LOC: u16 = 0x2000;
/// Element address of the result.
pub const RESULT_LOC: u16 = 0x3000;
const I_CNT: u16 = 0x10;
const ROW_SAVE: u16 = 0x12;

/// Table 3 — the vector-vector (translation) loop:
/// `result[i] = V1[i] + V2[i]`, `n` iterations.
pub fn translation_routine(n: i16) -> Vec<Op> {
    vec![
        Op::Mov(Reg(Reg16::SP), Imm(V1_LOC as i16)),
        Op::Mov(Reg(Reg16::BP), Imm(V2_LOC as i16)),
        Op::Mov(Reg(Reg16::DI), Imm(RESULT_LOC as i16)),
        Op::Mov(Reg(Reg16::SI), Imm(n)),
        // AA:
        Op::Mov(Reg(Reg16::AX), Mem(Reg16::SP)),
        Op::Mov(Reg(Reg16::BX), Mem(Reg16::BP)),
        Op::Add(Reg16::AX, Reg(Reg16::BX)),
        Op::Mov(Mem(Reg16::DI), Reg(Reg16::AX)),
        Op::Inc(Reg16::SP),
        Op::Inc(Reg16::BP),
        Op::Inc(Reg16::DI),
        Op::Dec(Reg16::SI),
        Op::Jnz(4),
        Op::Halt,
    ]
}

/// Table 4 — the vector-scalar (scaling) loop **as published**: the
/// paper's listing reads "AX ← AX + Constant", i.e. it *adds* the scalar
/// (its cycle counts are built on ADD). Kept verbatim for the cycle
/// reproduction; see [`scaling_routine_imul`] for the functionally
/// multiplicative variant.
pub fn scaling_routine(n: i16, constant: i16) -> Vec<Op> {
    vec![
        Op::Mov(Reg(Reg16::SP), Imm(V1_LOC as i16)),
        Op::Mov(Reg(Reg16::BP), Imm(constant)),
        Op::Mov(Reg(Reg16::DI), Imm(RESULT_LOC as i16)),
        Op::Mov(Reg(Reg16::SI), Imm(n)),
        // AA:
        Op::Mov(Reg(Reg16::AX), Mem(Reg16::SP)),
        Op::Add(Reg16::AX, Reg(Reg16::BP)),
        Op::Mov(Mem(Reg16::DI), Reg(Reg16::AX)),
        Op::Inc(Reg16::SP),
        Op::Inc(Reg16::DI),
        Op::Dec(Reg16::SI),
        Op::Jnz(4),
        Op::Halt,
    ]
}

/// The corrected scaling loop that actually multiplies (`IMUL`), used by
/// the deviation analysis in EXPERIMENTS.md — strictly slower than the
/// published ADD loop on every model, so the paper's speedup claims only
/// improve under the correction.
pub fn scaling_routine_imul(n: i16, constant: i16) -> Vec<Op> {
    vec![
        Op::Mov(Reg(Reg16::SP), Imm(V1_LOC as i16)),
        Op::Mov(Reg(Reg16::BP), Imm(constant)),
        Op::Mov(Reg(Reg16::DI), Imm(RESULT_LOC as i16)),
        Op::Mov(Reg(Reg16::SI), Imm(n)),
        // AA:
        Op::Mov(Reg(Reg16::AX), Mem(Reg16::SP)),
        Op::Imul(Reg(Reg16::BP)),
        Op::Mov(Mem(Reg16::DI), Reg(Reg16::AX)),
        Op::Inc(Reg16::SP),
        Op::Inc(Reg16::DI),
        Op::Dec(Reg16::SI),
        Op::Jnz(4),
        Op::Halt,
    ]
}

/// Fully unrolled vector-vector loop — the obvious hand-optimization of
/// Table 3 (no INC/DEC/JNZ overhead, absolute addressing). Used by the
/// ablation bench to show the baselines' headroom: the 486 gains ~45%,
/// yet the M1 still wins by ~4× on 64 elements.
pub fn translation_unrolled(n: i16) -> Vec<Op> {
    let mut p = Vec::new();
    for i in 0..n as u16 {
        p.push(Op::Mov(Reg(Reg16::AX), Abs(V1_LOC + i)));
        p.push(Op::Mov(Reg(Reg16::BX), Abs(V2_LOC + i)));
        p.push(Op::Add(Reg16::AX, Reg(Reg16::BX)));
        p.push(Op::Mov(Abs(RESULT_LOC + i), Reg(Reg16::AX)));
    }
    p.push(Op::Halt);
    p
}

/// Run the unrolled translation loop.
pub fn run_translation_unrolled(cpu: Cpu, u: &[i16], v: &[i16]) -> (Vec<i16>, RunReport) {
    assert_eq!(u.len(), v.len());
    let mut m = Interp::new(0x10000);
    m.mem[V1_LOC as usize..V1_LOC as usize + u.len()].copy_from_slice(u);
    m.mem[V2_LOC as usize..V2_LOC as usize + v.len()].copy_from_slice(v);
    let report = m.run(&translation_unrolled(u.len() as i16), cpu);
    let out = m.mem[RESULT_LOC as usize..RESULT_LOC as usize + u.len()].to_vec();
    (out, report)
}

/// Pentium-scheduled translation loop: the Table 3 body reordered so
/// independent simple ops are adjacent and pair in the U/V pipes — the
/// hand-tuning a 1995-era compiler would do. Note the constraint that
/// costs the schedule its last pairing opportunity: INC sets ZF, so
/// `DEC SI` must stay immediately before `JNZ` (reordering it earlier is
/// a real x86 bug).
pub fn translation_pentium_scheduled(n: i16) -> Vec<Op> {
    vec![
        Op::Mov(Reg(Reg16::SP), Imm(V1_LOC as i16)),
        Op::Mov(Reg(Reg16::BP), Imm(V2_LOC as i16)),
        Op::Mov(Reg(Reg16::DI), Imm(RESULT_LOC as i16)),
        Op::Mov(Reg(Reg16::SI), Imm(n)),
        // AA: loads pair; pointer increments pair; store pairs with the
        // destination increment.
        Op::Mov(Reg(Reg16::AX), Mem(Reg16::SP)),
        Op::Mov(Reg(Reg16::BX), Mem(Reg16::BP)),
        Op::Inc(Reg16::SP),
        Op::Inc(Reg16::BP),
        Op::Add(Reg16::AX, Reg(Reg16::BX)),
        Op::Mov(Mem(Reg16::DI), Reg(Reg16::AX)),
        Op::Inc(Reg16::DI),
        Op::Dec(Reg16::SI),
        Op::Jnz(4),
        Op::Halt,
    ]
}

/// Run the Pentium-scheduled loop.
pub fn run_translation_scheduled(cpu: Cpu, u: &[i16], v: &[i16]) -> (Vec<i16>, RunReport) {
    assert_eq!(u.len(), v.len());
    let mut m = Interp::new(0x10000);
    m.mem[V1_LOC as usize..V1_LOC as usize + u.len()].copy_from_slice(u);
    m.mem[V2_LOC as usize..V2_LOC as usize + v.len()].copy_from_slice(v);
    let report = m.run(&translation_pentium_scheduled(u.len() as i16), cpu);
    let out = m.mem[RESULT_LOC as usize..RESULT_LOC as usize + u.len()].to_vec();
    (out, report)
}

/// Dense `dim × dim` matrix multiplication `C = A × B` — the baseline for
/// the paper's rotation/composite comparison. A is row-major at
/// [`V1_LOC`], **B column-major** at [`V2_LOC`] (the natural layout for a
/// hand-tuned inner loop: both pointers just increment), C row-major at
/// [`RESULT_LOC`].
pub fn matmul_routine(dim: i16) -> Vec<Op> {
    let mut p = Vec::new();
    // setup
    p.push(Op::Mov(Reg(Reg16::SP), Imm(V1_LOC as i16))); // A row ptr
    p.push(Op::Mov(Reg(Reg16::DI), Imm(RESULT_LOC as i16))); // C ptr
    p.push(Op::Mov(Reg(Reg16::AX), Imm(dim)));
    p.push(Op::Mov(Abs(I_CNT), Reg(Reg16::AX)));
    let i_loop = p.len(); // 4
    p.push(Op::Mov(Reg(Reg16::BP), Imm(V2_LOC as i16))); // B base (col-major)
    p.push(Op::Mov(Reg(Reg16::CX), Imm(dim))); // j counter
    let j_loop = p.len(); // 6
    p.push(Op::Mov(Abs(ROW_SAVE), Reg(Reg16::SP)));
    p.push(Op::Mov(Reg(Reg16::BX), Imm(0))); // acc
    p.push(Op::Mov(Reg(Reg16::SI), Imm(dim))); // k counter
    let k_loop = p.len(); // 9
    p.push(Op::Mov(Reg(Reg16::AX), Mem(Reg16::SP))); // A[i][k]
    p.push(Op::Mov(Reg(Reg16::DX), Mem(Reg16::BP))); // B[k][j]
    p.push(Op::Imul(Reg(Reg16::DX)));
    p.push(Op::Add(Reg16::BX, Reg(Reg16::AX)));
    p.push(Op::Inc(Reg16::SP));
    p.push(Op::Inc(Reg16::BP));
    p.push(Op::Dec(Reg16::SI));
    p.push(Op::Jnz(k_loop));
    p.push(Op::Mov(Mem(Reg16::DI), Reg(Reg16::BX))); // C[i][j]
    p.push(Op::Inc(Reg16::DI));
    p.push(Op::Mov(Reg(Reg16::SP), Abs(ROW_SAVE))); // rewind row
    p.push(Op::Dec(Reg16::CX));
    p.push(Op::Jnz(j_loop));
    p.push(Op::Add(Reg16::SP, Imm(dim))); // next row of A
    p.push(Op::Mov(Reg(Reg16::AX), Abs(I_CNT)));
    p.push(Op::Dec(Reg16::AX));
    p.push(Op::Mov(Abs(I_CNT), Reg(Reg16::AX)));
    p.push(Op::Jnz(i_loop));
    p.push(Op::Halt);
    p
}

/// Stage two vectors, run the translation loop, return result + report.
pub fn run_translation(cpu: Cpu, u: &[i16], v: &[i16]) -> (Vec<i16>, RunReport) {
    assert_eq!(u.len(), v.len());
    let mut m = Interp::new(0x10000);
    m.mem[V1_LOC as usize..V1_LOC as usize + u.len()].copy_from_slice(u);
    m.mem[V2_LOC as usize..V2_LOC as usize + v.len()].copy_from_slice(v);
    let report = m.run(&translation_routine(u.len() as i16), cpu);
    let out = m.mem[RESULT_LOC as usize..RESULT_LOC as usize + u.len()].to_vec();
    (out, report)
}

/// Stage a vector, run the (published, additive) scaling loop.
pub fn run_scaling(cpu: Cpu, u: &[i16], constant: i16) -> (Vec<i16>, RunReport) {
    let mut m = Interp::new(0x10000);
    m.mem[V1_LOC as usize..V1_LOC as usize + u.len()].copy_from_slice(u);
    let report = m.run(&scaling_routine(u.len() as i16, constant), cpu);
    let out = m.mem[RESULT_LOC as usize..RESULT_LOC as usize + u.len()].to_vec();
    (out, report)
}

/// Run the corrected multiplicative scaling loop.
pub fn run_scaling_imul(cpu: Cpu, u: &[i16], constant: i16) -> (Vec<i16>, RunReport) {
    let mut m = Interp::new(0x10000);
    m.mem[V1_LOC as usize..V1_LOC as usize + u.len()].copy_from_slice(u);
    let report = m.run(&scaling_routine_imul(u.len() as i16, constant), cpu);
    let out = m.mem[RESULT_LOC as usize..RESULT_LOC as usize + u.len()].to_vec();
    (out, report)
}

/// Stage A (row-major) and B (row-major — transposed internally to the
/// routine's column-major layout), run the matmul, return row-major C.
pub fn run_matmul(cpu: Cpu, dim: usize, a: &[i16], b: &[i16]) -> (Vec<i16>, RunReport) {
    assert_eq!(a.len(), dim * dim);
    assert_eq!(b.len(), dim * dim);
    let mut m = Interp::new(0x10000);
    m.mem[V1_LOC as usize..V1_LOC as usize + a.len()].copy_from_slice(a);
    for k in 0..dim {
        for j in 0..dim {
            // column-major: B[k][j] at V2 + j*dim + k
            m.mem[V2_LOC as usize + j * dim + k] = b[k * dim + j];
        }
    }
    let report = m.run(&matmul_routine(dim as i16), cpu);
    let out = m.mem[RESULT_LOC as usize..RESULT_LOC as usize + dim * dim].to_vec();
    (out, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, Rng};

    #[test]
    fn translation_cycles_match_table3_8_elements() {
        let u = vec![1i16; 8];
        let v = vec![2i16; 8];
        // Paper Table 3: 90T on the 486, 220T on the 386.
        assert_eq!(run_translation(Cpu::I486, &u, &v).1.cycles, 90);
        assert_eq!(run_translation(Cpu::I386, &u, &v).1.cycles, 220);
    }

    #[test]
    fn translation_cycles_64_elements_model_vs_paper() {
        let u = vec![1i16; 64];
        let v = vec![2i16; 64];
        // The paper reports 769T (486) / 1723T (386); its own
        // per-instruction table implies 706T / 1732T. We assert the
        // table-derived model values; the delta is recorded in
        // EXPERIMENTS.md §Deviations.
        assert_eq!(run_translation(Cpu::I486, &u, &v).1.cycles, 706);
        assert_eq!(run_translation(Cpu::I386, &u, &v).1.cycles, 1732);
    }

    #[test]
    fn scaling_cycles_match_table4_exactly() {
        let u = vec![3i16; 8];
        assert_eq!(run_scaling(Cpu::I486, &u, 5).1.cycles, 74);
        assert_eq!(run_scaling(Cpu::I386, &u, 5).1.cycles, 172);
        let u64v = vec![3i16; 64];
        assert_eq!(run_scaling(Cpu::I486, &u64v, 5).1.cycles, 578);
        assert_eq!(run_scaling(Cpu::I386, &u64v, 5).1.cycles, 1348);
    }

    #[test]
    fn translation_is_functionally_correct() {
        let u: Vec<i16> = (0..64).collect();
        let v: Vec<i16> = (0..64).map(|i| 100 - i).collect();
        let (out, _) = run_translation(Cpu::I486, &u, &v);
        assert_eq!(out, vec![100i16; 64]);
    }

    #[test]
    fn published_scaling_listing_adds_not_multiplies() {
        // Faithful to Table 4: the "scaling" listing adds the constant.
        let u: Vec<i16> = (0..8).collect();
        let (out, _) = run_scaling(Cpu::I486, &u, 5);
        let expected: Vec<i16> = u.iter().map(|x| x + 5).collect();
        assert_eq!(out, expected);
    }

    #[test]
    fn corrected_scaling_multiplies_and_costs_more() {
        let u: Vec<i16> = (0..8).collect();
        let (out, rep_mul) = run_scaling_imul(Cpu::I486, &u, 5);
        let expected: Vec<i16> = u.iter().map(|x| x * 5).collect();
        assert_eq!(out, expected);
        let (_, rep_add) = run_scaling(Cpu::I486, &u, 5);
        assert!(rep_mul.cycles > rep_add.cycles);
    }

    #[test]
    fn matmul_is_functionally_correct() {
        let mut rng = Rng::new(3);
        for dim in [2usize, 4, 8] {
            let a: Vec<i16> = (0..dim * dim).map(|_| rng.range_i64(-9, 9) as i16).collect();
            let b: Vec<i16> = (0..dim * dim).map(|_| rng.range_i64(-9, 9) as i16).collect();
            let (c, _) = run_matmul(Cpu::I486, dim, &a, &b);
            for i in 0..dim {
                for j in 0..dim {
                    let e: i32 =
                        (0..dim).map(|k| a[i * dim + k] as i32 * b[k * dim + j] as i32).sum();
                    assert_eq!(c[i * dim + j], e as i16, "dim={dim} C[{i}][{j}]");
                }
            }
        }
    }

    #[test]
    fn matmul_cycle_scale_matches_paper_order() {
        let a = vec![1i16; 64];
        let b = vec![1i16; 64];
        // Paper Table 5: 8×8 rotation = 27038T (486) / 10151T (Pentium).
        // Our executable model lands in the same order of magnitude, with
        // the Pentium ~2-3× faster thanks to its cheaper IMUL + pairing.
        let r486 = run_matmul(Cpu::I486, 8, &a, &b).1;
        let rp = run_matmul(Cpu::Pentium, 8, &a, &b).1;
        assert!(r486.cycles > 10_000 && r486.cycles < 40_000, "486: {}", r486.cycles);
        assert!(rp.cycles > 4_000 && rp.cycles < 15_000, "P5: {}", rp.cycles);
        assert!(rp.cycles < r486.cycles);
        assert!(rp.paired > 0);
    }

    #[test]
    fn pentium_beats_486_on_every_routine() {
        let u = vec![7i16; 64];
        let v = vec![9i16; 64];
        assert!(
            run_translation(Cpu::Pentium, &u, &v).1.cycles
                < run_translation(Cpu::I486, &u, &v).1.cycles
        );
        assert!(run_scaling(Cpu::Pentium, &u, 5).1.cycles < run_scaling(Cpu::I486, &u, 5).1.cycles);
    }

    #[test]
    fn unrolled_translation_is_faster_but_m1_still_wins() {
        let u: Vec<i16> = (0..64).collect();
        let v = vec![9i16; 64];
        let (out, unrolled) = run_translation_unrolled(Cpu::I486, &u, &v);
        let expected: Vec<i16> = u.iter().zip(&v).map(|(a, b)| a + b).collect();
        assert_eq!(out, expected);
        let (_, looped) = run_translation(Cpu::I486, &u, &v);
        assert!(unrolled.cycles < looped.cycles);
        // The M1's 96 cycles still beat the best unrolled baseline.
        assert!(unrolled.cycles > 96 * 2, "unrolled {} cycles", unrolled.cycles);
    }

    #[test]
    fn pentium_scheduling_cannot_beat_the_already_saturated_loop() {
        // Finding (recorded in EXPERIMENTS.md): the paper's Table 3 loop
        // already pairs optimally under the U/V rules — the ZF hazard
        // (INC sets ZF, so DEC must stay adjacent to JNZ) blocks the only
        // remaining pairing. Hand-scheduling neither helps nor hurts.
        let u: Vec<i16> = (0..64).collect();
        let v = vec![1i16; 64];
        let (out, sched) = run_translation_scheduled(Cpu::Pentium, &u, &v);
        let expected: Vec<i16> = u.iter().zip(&v).map(|(a, b)| a + b).collect();
        assert_eq!(out, expected);
        let (_, plain) = run_translation(Cpu::Pentium, &u, &v);
        assert!(sched.paired >= plain.paired);
        assert_eq!(sched.cycles, plain.cycles, "pairing already saturated");
        // Scheduling must not change results on in-order models either.
        let (out386, _) = run_translation_scheduled(Cpu::I386, &u, &v);
        assert_eq!(out386, expected);
    }

    #[test]
    fn property_baseline_translation_agrees_with_native() {
        check("x86 translation == native", 30, |rng: &mut Rng| {
            let n = rng.range_i64(1, 64) as usize;
            let u = rng.small_vec(n);
            let v = rng.small_vec(n);
            for cpu in Cpu::ALL {
                let (out, _) = run_translation(cpu, &u, &v);
                let expected: Vec<i16> =
                    u.iter().zip(&v).map(|(a, b)| a.wrapping_add(*b)).collect();
                assert_eq!(out, expected, "{cpu:?}");
            }
        });
    }

    #[test]
    fn property_m1_beats_all_baselines_on_cycles() {
        // The paper's headline, as a property over sizes: for every
        // supported size the M1 mapping needs fewer cycles than any
        // baseline model.
        use crate::mapping::{runner::run_routine, VecVecMapping};
        use crate::morphosys::AluOp;
        check("m1 < baselines", 12, |rng: &mut Rng| {
            let n = [8usize, 16, 24, 32, 40, 48, 56, 64][rng.below(8) as usize];
            let u = rng.small_vec(n);
            let v = rng.small_vec(n);
            let m1 = run_routine(&VecVecMapping { n, op: AluOp::Add }.compile(), &u, Some(&v));
            for cpu in Cpu::ALL {
                let (_, rep) = run_translation(cpu, &u, &v);
                assert!(
                    m1.report.cycles < rep.cycles,
                    "n={n}: M1 {} !< {} {}",
                    m1.report.cycles,
                    cpu.name(),
                    rep.cycles
                );
            }
        });
    }
}
