//! Functional interpreter for the x86-16 subset, with pluggable cycle
//! accounting (the timing model lives in [`crate::baselines::timing`] and
//! is queried per retired instruction, so one interpreter serves all three
//! CPU models).

use super::ast::{Op, Operand, Reg16};
use crate::baselines::timing::Cpu;

/// Cap on retired instructions — a runaway loop is a bug, not a workload.
pub const MAX_RETIRED: u64 = 200_000_000;

/// Execution result.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub cycles: u64,
    pub retired: u64,
    /// Instructions that issued in the V pipe alongside a U-pipe partner
    /// (Pentium only; 0 for the single-issue models).
    pub paired: u64,
}

impl RunReport {
    /// Wall-clock microseconds at the model's documented clock.
    pub fn micros(&self, cpu: Cpu) -> f64 {
        self.cycles as f64 / cpu.clock_mhz()
    }
}

/// Machine state: 8 registers, flags, element-addressed data memory.
pub struct Interp {
    pub regs: [i16; 8],
    pub zf: bool,
    pub sf: bool,
    pub mem: Vec<i16>,
}

impl Interp {
    pub fn new(mem_elems: usize) -> Interp {
        Interp { regs: [0; 8], zf: false, sf: false, mem: vec![0; mem_elems] }
    }

    pub fn reg(&self, r: Reg16) -> i16 {
        self.regs[r.index()]
    }

    pub fn set_reg(&mut self, r: Reg16, v: i16) {
        self.regs[r.index()] = v;
    }

    fn load(&self, o: Operand) -> i16 {
        match o {
            Operand::Reg(r) => self.reg(r),
            Operand::Imm(i) => i,
            Operand::Mem(r) => self.mem[self.reg(r) as u16 as usize],
            Operand::Abs(a) => self.mem[a as usize],
        }
    }

    fn store(&mut self, o: Operand, v: i16) {
        match o {
            Operand::Reg(r) => self.set_reg(r, v),
            Operand::Mem(r) => {
                let a = self.reg(r) as u16 as usize;
                self.mem[a] = v;
            }
            Operand::Abs(a) => self.mem[a as usize] = v,
            Operand::Imm(_) => panic!("store to immediate"),
        }
    }

    fn flags_from(&mut self, v: i16) {
        self.zf = v == 0;
        self.sf = v < 0;
    }

    /// Run `program` to `Halt` (or falling off the end), accumulating
    /// cycles per `cpu`'s timing model, including its dual-issue pairing
    /// rule when applicable.
    pub fn run(&mut self, program: &[Op], cpu: Cpu) -> RunReport {
        let mut pc = 0usize;
        let mut cycles = 0u64;
        let mut retired = 0u64;
        let mut paired = 0u64;
        // Pentium pairing: remembers whether the previous instruction
        // occupies the U pipe and can still take a V-pipe partner.
        let mut u_slot: Option<Op> = None;

        while pc < program.len() {
            let op = program[pc];
            retired += 1;
            assert!(retired <= MAX_RETIRED, "x86 instruction budget exhausted at pc={pc}");
            let mut next = pc + 1;
            let mut taken = false;

            match op {
                Op::Mov(dst, src) => {
                    let v = self.load(src);
                    self.store(dst, v);
                }
                Op::Add(d, s) => {
                    let v = self.reg(d).wrapping_add(self.load(s));
                    self.set_reg(d, v);
                    self.flags_from(v);
                }
                Op::Sub(d, s) => {
                    let v = self.reg(d).wrapping_sub(self.load(s));
                    self.set_reg(d, v);
                    self.flags_from(v);
                }
                Op::Imul(s) => {
                    let v = (self.reg(Reg16::AX) as i32).wrapping_mul(self.load(s) as i32) as i16;
                    self.set_reg(Reg16::AX, v);
                    self.flags_from(v);
                }
                Op::Inc(r) => {
                    let v = self.reg(r).wrapping_add(1);
                    self.set_reg(r, v);
                    self.flags_from(v);
                }
                Op::Dec(r) => {
                    let v = self.reg(r).wrapping_sub(1);
                    self.set_reg(r, v);
                    self.flags_from(v);
                }
                Op::Cmp(a, b) => {
                    let v = self.reg(a).wrapping_sub(self.load(b));
                    self.flags_from(v);
                }
                Op::Jnz(t) => {
                    if !self.zf {
                        next = t;
                        taken = true;
                    }
                }
                Op::Jmp(t) => {
                    next = t;
                    taken = true;
                }
                Op::Halt => break,
            }

            // Cycle accounting.
            let cost = cpu.cost(&op, taken);
            if cpu.dual_issue() {
                if let Some(prev) = u_slot.take() {
                    if Cpu::pairable(&prev, &op) {
                        // Issues in the V pipe for free alongside `prev`
                        // (both are 1-cycle simple ops).
                        paired += 1;
                    } else {
                        cycles += cost;
                        u_slot = if Cpu::u_pipe_candidate(&op) { Some(op) } else { None };
                    }
                } else {
                    cycles += cost;
                    u_slot = if Cpu::u_pipe_candidate(&op) { Some(op) } else { None };
                }
                // A taken branch breaks the issue window.
                if taken {
                    u_slot = None;
                }
            } else {
                cycles += cost;
            }
            pc = next;
        }

        RunReport { cycles, retired, paired }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::x86::ast::Operand::{Abs, Imm, Mem, Reg};

    #[test]
    fn mov_add_store_roundtrip() {
        let mut m = Interp::new(64);
        m.mem[10] = 7;
        let prog = [
            Op::Mov(Reg(Reg16::SP), Imm(10)),
            Op::Mov(Reg(Reg16::AX), Mem(Reg16::SP)),
            Op::Add(Reg16::AX, Imm(5)),
            Op::Mov(Abs(20), Reg(Reg16::AX)),
            Op::Halt,
        ];
        m.run(&prog, Cpu::I486);
        assert_eq!(m.mem[20], 12);
    }

    #[test]
    fn dec_jnz_loops_count_times() {
        let mut m = Interp::new(8);
        let prog = [
            Op::Mov(Reg(Reg16::SI), Imm(5)),
            Op::Mov(Reg(Reg16::AX), Imm(0)),
            // loop:
            Op::Add(Reg16::AX, Imm(2)),
            Op::Dec(Reg16::SI),
            Op::Jnz(2),
            Op::Halt,
        ];
        m.run(&prog, Cpu::I486);
        assert_eq!(m.reg(Reg16::AX), 10);
    }

    #[test]
    fn imul_multiplies_into_ax() {
        let mut m = Interp::new(1);
        let prog = [
            Op::Mov(Reg(Reg16::AX), Imm(-7)),
            Op::Mov(Reg(Reg16::DX), Imm(6)),
            Op::Imul(Reg(Reg16::DX)),
            Op::Halt,
        ];
        m.run(&prog, Cpu::Pentium);
        assert_eq!(m.reg(Reg16::AX), -42);
    }

    #[test]
    fn flags_drive_conditional_branches() {
        let mut m = Interp::new(1);
        let prog = [
            Op::Mov(Reg(Reg16::AX), Imm(3)),
            Op::Cmp(Reg16::AX, Imm(3)),
            Op::Jnz(5), // not taken: ZF set
            Op::Mov(Reg(Reg16::BX), Imm(1)),
            Op::Halt,
            Op::Mov(Reg(Reg16::BX), Imm(2)),
        ];
        m.run(&prog, Cpu::I386);
        assert_eq!(m.reg(Reg16::BX), 1);
    }

    #[test]
    fn cycle_costs_differ_by_model() {
        let prog = [
            Op::Mov(Reg(Reg16::SP), Imm(0)),
            Op::Mov(Reg(Reg16::AX), Mem(Reg16::SP)),
            Op::Halt,
        ];
        let c386 = Interp::new(8).run(&prog, Cpu::I386).cycles;
        let c486 = Interp::new(8).run(&prog, Cpu::I486).cycles;
        // 386: 2 + 4 = 6; 486: 1 + 1 = 2 (+0 for HLT boundary marker).
        assert!(c386 > c486);
        assert_eq!(c486, 2);
        assert_eq!(c386, 6);
    }

    #[test]
    fn pentium_pairs_independent_simple_ops() {
        // INC SI / INC DI are independent → pair on Pentium.
        let prog = [Op::Inc(Reg16::SI), Op::Inc(Reg16::DI), Op::Halt];
        let r = Interp::new(1).run(&prog, Cpu::Pentium);
        assert_eq!(r.paired, 1);
        assert_eq!(r.cycles, 1);
        // Dependent ops do not pair.
        let prog2 = [Op::Inc(Reg16::SI), Op::Mov(Reg(Reg16::AX), Mem(Reg16::SI)), Op::Halt];
        let r2 = Interp::new(64).run(&prog2, Cpu::Pentium);
        assert_eq!(r2.paired, 0);
        assert_eq!(r2.cycles, 2);
    }

    #[test]
    fn memory_wraps_at_16bit_pointer() {
        let mut m = Interp::new(0x10000);
        let prog = [
            Op::Mov(Reg(Reg16::SP), Imm(-1)), // 0xFFFF
            Op::Mov(Reg(Reg16::AX), Mem(Reg16::SP)),
            Op::Halt,
        ];
        m.mem[0xFFFF] = 321;
        m.run(&prog, Cpu::I486);
        assert_eq!(m.reg(Reg16::AX), 321);
    }
}
