//! Instruction AST for the x86-16 subset used by the paper's baselines.

/// The eight 16-bit general registers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Reg16 {
    AX,
    BX,
    CX,
    DX,
    SI,
    DI,
    BP,
    /// The paper's listings use SP as a plain pointer register.
    SP,
}

impl Reg16 {
    pub const ALL: [Reg16; 8] = [
        Reg16::AX,
        Reg16::BX,
        Reg16::CX,
        Reg16::DX,
        Reg16::SI,
        Reg16::DI,
        Reg16::BP,
        Reg16::SP,
    ];

    pub fn index(self) -> usize {
        match self {
            Reg16::AX => 0,
            Reg16::BX => 1,
            Reg16::CX => 2,
            Reg16::DX => 3,
            Reg16::SI => 4,
            Reg16::DI => 5,
            Reg16::BP => 6,
            Reg16::SP => 7,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Reg16::AX => "AX",
            Reg16::BX => "BX",
            Reg16::CX => "CX",
            Reg16::DX => "DX",
            Reg16::SI => "SI",
            Reg16::DI => "DI",
            Reg16::BP => "BP",
            Reg16::SP => "SP",
        }
    }
}

/// A data operand: register, immediate, register-indirect memory, or
/// absolute memory. Data memory is element (16-bit word) addressed, which
/// matches the paper's listings incrementing pointers by one per element.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Operand {
    Reg(Reg16),
    Imm(i16),
    /// `[reg]` — register-indirect.
    Mem(Reg16),
    /// `[addr]` — absolute (used for loop-counter spills in the matmul
    /// routine).
    Abs(u16),
}

/// One instruction of the subset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// `MOV dst, src` (dst: Reg/Mem/Abs; src: any).
    Mov(Operand, Operand),
    /// `ADD dst, src` — dst must be a register.
    Add(Reg16, Operand),
    /// `SUB dst, src`.
    Sub(Reg16, Operand),
    /// `IMUL src` — `AX ← AX × src` (low 16 bits; we ignore DX:AX).
    Imul(Operand),
    /// `INC reg`.
    Inc(Reg16),
    /// `DEC reg`.
    Dec(Reg16),
    /// `CMP a, b` — sets flags from `a - b`.
    Cmp(Reg16, Operand),
    /// `JNZ target` (instruction index).
    Jnz(usize),
    /// `JMP target`.
    Jmp(usize),
    /// End of routine.
    Halt,
}

impl Op {
    /// Registers read by this instruction (for the Pentium pairing model).
    pub fn reads(&self) -> Vec<Reg16> {
        fn operand(r: &mut Vec<Reg16>, o: &Operand) {
            if let Operand::Reg(x) | Operand::Mem(x) = o {
                r.push(*x);
            }
        }
        let mut r = Vec::new();
        match self {
            Op::Mov(dst, src) => {
                operand(&mut r, src);
                if let Operand::Mem(x) = dst {
                    r.push(*x);
                }
            }
            Op::Add(d, s) | Op::Sub(d, s) => {
                r.push(*d);
                operand(&mut r, s);
            }
            Op::Imul(s) => {
                r.push(Reg16::AX);
                operand(&mut r, s);
            }
            Op::Inc(x) | Op::Dec(x) => r.push(*x),
            Op::Cmp(a, b) => {
                r.push(*a);
                operand(&mut r, b);
            }
            Op::Jnz(_) | Op::Jmp(_) | Op::Halt => {}
        }
        r
    }

    /// Register written by this instruction, if any.
    pub fn writes(&self) -> Option<Reg16> {
        match self {
            Op::Mov(Operand::Reg(d), _) => Some(*d),
            Op::Add(d, _) | Op::Sub(d, _) => Some(*d),
            Op::Imul(_) => Some(Reg16::AX),
            Op::Inc(d) | Op::Dec(d) => Some(*d),
            _ => None,
        }
    }

    pub fn render(&self) -> String {
        fn o(op: &Operand) -> String {
            match op {
                Operand::Reg(r) => r.name().to_string(),
                Operand::Imm(i) => format!("{i}"),
                Operand::Mem(r) => format!("[{}]", r.name()),
                Operand::Abs(a) => format!("[{a:#x}]"),
            }
        }
        match self {
            Op::Mov(d, s) => format!("MOV  {}, {}", o(d), o(s)),
            Op::Add(d, s) => format!("ADD  {}, {}", d.name(), o(s)),
            Op::Sub(d, s) => format!("SUB  {}, {}", d.name(), o(s)),
            Op::Imul(s) => format!("IMUL {}", o(s)),
            Op::Inc(r) => format!("INC  {}", r.name()),
            Op::Dec(r) => format!("DEC  {}", r.name()),
            Op::Cmp(a, b) => format!("CMP  {}, {}", a.name(), o(b)),
            Op::Jnz(t) => format!("JNZ  {t}"),
            Op::Jmp(t) => format!("JMP  {t}"),
            Op::Halt => "HLT".to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reads_and_writes_for_pairing() {
        let add = Op::Add(Reg16::AX, Operand::Reg(Reg16::BX));
        assert_eq!(add.reads(), vec![Reg16::AX, Reg16::BX]);
        assert_eq!(add.writes(), Some(Reg16::AX));

        let store = Op::Mov(Operand::Mem(Reg16::DI), Operand::Reg(Reg16::AX));
        assert!(store.reads().contains(&Reg16::DI));
        assert!(store.reads().contains(&Reg16::AX));
        assert_eq!(store.writes(), None);

        let imul = Op::Imul(Operand::Reg(Reg16::DX));
        assert!(imul.reads().contains(&Reg16::AX));
        assert_eq!(imul.writes(), Some(Reg16::AX));
    }

    #[test]
    fn render_is_readable() {
        assert_eq!(Op::Mov(Operand::Reg(Reg16::AX), Operand::Mem(Reg16::SP)).render(), "MOV  AX, [SP]");
        assert_eq!(Op::Jnz(4).render(), "JNZ  4");
    }
}
