//! A 16-bit x86 subset: just enough of the 8086/80386 programmer's model
//! to execute the paper's baseline listings — eight 16-bit registers,
//! element-addressed data memory, ZF/SF flags, register-indirect and
//! absolute addressing.

pub mod ast;
pub mod interp;

pub use ast::{Op, Operand, Reg16};
pub use interp::{Interp, RunReport};
