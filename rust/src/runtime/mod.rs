//! # PJRT runtime — the request-path bridge to the AOT artifacts
//!
//! Loads the HLO-text artifacts `python/compile/aot.py` produced, compiles
//! them once on the PJRT CPU client (`xla` crate), and executes them from
//! the coordinator's hot path. Python never runs here — the artifacts are
//! plain HLO, and after `make artifacts` the binary is self-contained.

pub mod artifacts;
pub mod executor;
pub mod pjrt;

pub use artifacts::ArtifactRegistry;
pub use executor::Executor;
