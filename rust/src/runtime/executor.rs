//! Compile-and-execute of HLO-text artifacts on the PJRT CPU client, with
//! an executable cache (each artifact compiles once per process).

use std::cell::RefCell;
use std::collections::HashMap;

use anyhow::{Context, Result};

use super::artifacts::ArtifactRegistry;
use super::pjrt as xla;

/// The PJRT executor with a per-name executable cache.
///
/// `PjRtClient` is `Rc`-based (not `Send`), so an `Executor` lives on one
/// thread; the coordinator gives its XLA backend a dedicated worker
/// thread that owns the executor and feeds it through channels.
pub struct Executor {
    client: xla::PjRtClient,
    registry: ArtifactRegistry,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl Executor {
    /// Create a CPU-PJRT executor over a registry.
    pub fn new(registry: ArtifactRegistry) -> Result<Executor> {
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Executor { client, registry, cache: RefCell::new(HashMap::new()) })
    }

    /// Create with the default artifact discovery.
    pub fn discover() -> Result<Executor> {
        Executor::new(ArtifactRegistry::discover()?)
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Ensure an artifact is compiled, then run `f` on the cached
    /// executable (executables are neither `Clone` nor `Send`, so access
    /// stays inside the cache borrow).
    fn with_executable<R>(
        &self,
        name: &str,
        f: impl FnOnce(&xla::PjRtLoadedExecutable) -> Result<R>,
    ) -> Result<R> {
        if !self.cache.borrow().contains_key(name) {
            let path = self.registry.path(name)?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe =
                self.client.compile(&comp).with_context(|| format!("compile `{name}`"))?;
            self.cache.borrow_mut().insert(name.to_string(), exe);
        }
        let cache = self.cache.borrow();
        f(cache.get(name).expect("just inserted"))
    }

    /// Pre-compile a set of artifacts (warm-up before serving).
    pub fn warm_up<'a>(&self, names: impl IntoIterator<Item = &'a str>) -> Result<()> {
        for name in names {
            self.with_executable(name, |_| Ok(()))?;
        }
        Ok(())
    }

    /// Execute an artifact on f32 vector inputs; returns the flattened
    /// f32 outputs (the artifacts are lowered with `return_tuple=True`,
    /// so the single result literal is a tuple which we unpack).
    pub fn run_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|v| xla::Literal::vec1(v)).collect();
        self.with_executable(name, |exe| {
            let result = exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("execute `{name}`"))?[0][0]
                .to_literal_sync()?;
            let parts = result.to_tuple()?;
            parts
                .into_iter()
                .map(|lit| lit.to_vec::<f32>().map_err(Into::into))
                .collect()
        })
    }

    /// Execute with explicitly shaped inputs (`(data, dims)` pairs), for
    /// matrix artifacts.
    pub fn run_f32_shaped(
        &self,
        name: &str,
        inputs: &[(&[f32], &[i64])],
    ) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(v, dims)| xla::Literal::vec1(v).reshape(dims))
            .collect::<std::result::Result<_, _>>()?;
        self.with_executable(name, |exe| {
            let result = exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("execute `{name}`"))?[0][0]
                .to_literal_sync()?;
            let parts = result.to_tuple()?;
            parts
                .into_iter()
                .map(|lit| lit.to_vec::<f32>().map_err(Into::into))
                .collect()
        })
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.borrow().len()
    }
}
