//! Artifact discovery: map artifact names to `.hlo.txt` paths.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Registry of AOT artifacts on disk.
#[derive(Debug, Clone)]
pub struct ArtifactRegistry {
    dir: PathBuf,
    names: BTreeMap<String, PathBuf>,
}

impl ArtifactRegistry {
    /// Scan a directory for `<name>.hlo.txt` files.
    pub fn open(dir: impl AsRef<Path>) -> Result<ArtifactRegistry> {
        let dir = dir.as_ref().to_path_buf();
        let mut names = BTreeMap::new();
        let entries = std::fs::read_dir(&dir)
            .with_context(|| format!("artifact dir {} (run `make artifacts`)", dir.display()))?;
        for entry in entries {
            let path = entry?.path();
            if let Some(fname) = path.file_name().and_then(|s| s.to_str()) {
                if let Some(name) = fname.strip_suffix(".hlo.txt") {
                    names.insert(name.to_string(), path.clone());
                }
            }
        }
        if names.is_empty() {
            bail!("no .hlo.txt artifacts in {} (run `make artifacts`)", dir.display());
        }
        Ok(ArtifactRegistry { dir, names })
    }

    /// Default location: `$MORPHO_ARTIFACTS`, else `./artifacts`, else
    /// `<crate root>/artifacts` (so tests/examples work from any cwd).
    pub fn discover() -> Result<ArtifactRegistry> {
        if let Ok(dir) = std::env::var("MORPHO_ARTIFACTS") {
            return ArtifactRegistry::open(dir);
        }
        let candidates =
            [PathBuf::from("artifacts"), Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")];
        for c in &candidates {
            if c.is_dir() {
                return ArtifactRegistry::open(c);
            }
        }
        bail!("no artifacts directory found (run `make artifacts`)")
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.names.keys().map(String::as_str)
    }

    pub fn path(&self, name: &str) -> Result<&Path> {
        self.names
            .get(name)
            .map(PathBuf::as_path)
            .with_context(|| format!("unknown artifact `{name}` in {}", self.dir.display()))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.names.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_rejects_missing_dir() {
        assert!(ArtifactRegistry::open("/nonexistent/morpho").is_err());
    }

    #[test]
    fn scans_hlo_files() {
        let tmp = std::env::temp_dir().join(format!("morpho-art-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("foo.hlo.txt"), "HloModule foo").unwrap();
        std::fs::write(tmp.join("bar.hlo.txt"), "HloModule bar").unwrap();
        std::fs::write(tmp.join("ignored.txt"), "").unwrap();
        let reg = ArtifactRegistry::open(&tmp).unwrap();
        let names: Vec<&str> = reg.names().collect();
        assert_eq!(names, vec!["bar", "foo"]);
        assert!(reg.contains("foo"));
        assert!(!reg.contains("ignored"));
        assert!(reg.path("foo").unwrap().ends_with("foo.hlo.txt"));
        assert!(reg.path("baz").is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }
}
