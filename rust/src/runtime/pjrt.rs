//! PJRT bindings facade.
//!
//! With the `pjrt` cargo feature the executor compiles against the real
//! external `xla` bindings crate (vendor it next to this workspace and add
//! the dependency before enabling the feature). Without it — the offline
//! default — this module supplies a type-compatible stub whose client
//! constructor fails with a descriptive error, so `Executor::discover()`
//! returns `Err(..)` and the coordinator's XLA backend falls back to the
//! native path at worker startup instead of breaking the build.

#[cfg(feature = "pjrt")]
pub use xla::*;

#[cfg(not(feature = "pjrt"))]
pub use stub::*;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::fmt;
    use std::path::Path;

    /// Error produced by every stub entry point.
    #[derive(Debug)]
    pub struct Error(&'static str);

    impl fmt::Display for Error {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str(self.0)
        }
    }

    impl std::error::Error for Error {}

    fn unavailable() -> Error {
        Error("PJRT runtime unavailable: morpho was built without the `pjrt` feature")
    }

    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient, Error> {
            Err(unavailable())
        }

        pub fn platform_name(&self) -> String {
            "unavailable".to_string()
        }

        pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
            Err(unavailable())
        }
    }

    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
            Err(unavailable())
        }
    }

    pub struct PjRtBuffer;

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal, Error> {
            Err(unavailable())
        }
    }

    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
            Err(unavailable())
        }
    }

    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    pub struct Literal;

    impl Literal {
        pub fn vec1(_values: &[f32]) -> Literal {
            Literal
        }

        pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
            Err(unavailable())
        }

        pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
            Err(unavailable())
        }

        pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
            Err(unavailable())
        }
    }
}
