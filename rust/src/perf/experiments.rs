//! Experiment runners: execute the mappings on the M1 simulator and the
//! listings on the baseline models, and assemble paper-vs-measured rows.

use crate::baselines::routines as x86;
use crate::baselines::Cpu;
use crate::mapping::{runner::run_routine, MatMulMapping, VecScalarMapping, VecVecMapping};
use crate::morphosys::tinyrisc::asm::disassemble_program;
use crate::morphosys::{timing, AluOp};

use super::paper;

/// One measured cell of a comparison.
#[derive(Debug, Clone)]
pub struct Row {
    pub algorithm: String,
    pub system: String,
    pub n: usize,
    pub cycles: u64,
    pub clock_mhz: f64,
    /// The paper's published cycle count for this cell, if any.
    pub paper_cycles: Option<u64>,
}

impl Row {
    pub fn total_us(&self) -> f64 {
        self.cycles as f64 / self.clock_mhz
    }

    pub fn elems_per_cycle(&self) -> f64 {
        self.n as f64 / self.cycles as f64
    }

    pub fn cycles_per_elem(&self) -> f64 {
        self.cycles as f64 / self.n as f64
    }
}

/// Measured M1 cycles for one of the paper's six algorithm×size points.
fn m1_row(algorithm: &str, n: usize) -> Row {
    let u: Vec<i16> = (0..n as i16).collect();
    let cycles = match algorithm {
        "translation" => {
            let v = vec![1i16; n];
            run_routine(&VecVecMapping { n, op: AluOp::Add }.compile(), &u, Some(&v))
                .report
                .cycles
        }
        "scaling" => {
            run_routine(&VecScalarMapping { n, op: AluOp::Cmul, scalar: 5 }.compile(), &u, None)
                .report
                .cycles
        }
        "rotation-I" | "rotation-II" => {
            let dim = (n as f64).sqrt() as usize;
            let mapping = MatMulMapping { dim, a: vec![1i16; dim * dim], shift: 0 };
            let b: Vec<i16> = (0..(dim * dim) as i16).collect();
            run_routine(&mapping.compile(), &b, None).report.cycles
        }
        other => panic!("unknown algorithm {other}"),
    };
    Row {
        algorithm: algorithm.into(),
        system: "M1".into(),
        n,
        cycles,
        clock_mhz: timing::M1_CLOCK_HZ as f64 / 1e6,
        paper_cycles: paper::cycles(algorithm, "M1", n),
    }
}

/// Measured baseline cycles for one cell.
fn baseline_row(algorithm: &str, cpu: Cpu, n: usize) -> Row {
    let u: Vec<i16> = (0..n as i16).collect();
    let cycles = match algorithm {
        "translation" => {
            let v = vec![1i16; n];
            x86::run_translation(cpu, &u, &v).1.cycles
        }
        "scaling" => x86::run_scaling(cpu, &u, 5).1.cycles,
        "rotation-I" | "rotation-II" => {
            let dim = (n as f64).sqrt() as usize;
            let a = vec![1i16; dim * dim];
            let b: Vec<i16> = (0..(dim * dim) as i16).collect();
            x86::run_matmul(cpu, dim, &a, &b).1.cycles
        }
        other => panic!("unknown algorithm {other}"),
    };
    Row {
        algorithm: algorithm.into(),
        system: cpu.name().into(),
        n,
        cycles,
        clock_mhz: cpu.clock_mhz(),
        paper_cycles: paper::cycles(algorithm, cpu.name(), n),
    }
}

/// Table 1: the emitted TinyRISC translation routine (the paper's 64-
/// element uniform-translation listing).
pub fn table1_listing() -> String {
    let r = VecVecMapping { n: 64, op: AluOp::Add }.compile();
    format!(
        "Table 1 — TinyRISC uniform translation routine, 64 elements\n\
         context word: {:#010x} (OUT = A + B)   predicted cycles: {}\n\n{}",
        r.ctx_words[0].1,
        r.predicted_cycles,
        disassemble_program(&r.program)
    )
}

/// Table 2: the emitted TinyRISC scaling routine.
pub fn table2_listing() -> String {
    let r = VecScalarMapping { n: 64, op: AluOp::Cmul, scalar: 5 }.compile();
    format!(
        "Table 2 — TinyRISC uniform scaling routine, 64 elements (c = 5)\n\
         context word: {:#010x} (OUT = c × A)   predicted cycles: {}\n\n{}",
        r.ctx_words[0].1,
        r.predicted_cycles,
        disassemble_program(&r.program)
    )
}

/// Table 3: the 386/486 vector-vector (translation) analysis, n ∈ {8, 64}.
pub fn table3() -> Vec<Row> {
    let mut rows = Vec::new();
    for n in [8, 64] {
        for cpu in [Cpu::I486, Cpu::I386] {
            rows.push(baseline_row("translation", cpu, n));
        }
    }
    rows
}

/// Table 4: the 386/486 vector-scalar (scaling) analysis, n ∈ {8, 64}.
pub fn table4() -> Vec<Row> {
    let mut rows = Vec::new();
    for n in [8, 64] {
        for cpu in [Cpu::I486, Cpu::I386] {
            rows.push(baseline_row("scaling", cpu, n));
        }
    }
    rows
}

/// Table 5: the headline comparison — all six algorithm×size blocks.
pub fn table5() -> Vec<Vec<Row>> {
    let blocks: [(&str, usize, &[Cpu]); 6] = [
        ("translation", 64, &[Cpu::I486, Cpu::I386]),
        ("scaling", 64, &[Cpu::I486, Cpu::I386]),
        ("rotation-I", 64, &[Cpu::Pentium, Cpu::I486]),
        ("rotation-II", 16, &[Cpu::Pentium, Cpu::I486]),
        ("translation", 8, &[Cpu::I486, Cpu::I386]),
        ("scaling", 8, &[Cpu::I486, Cpu::I386]),
    ];
    blocks
        .iter()
        .map(|(alg, n, cpus)| {
            let mut rows = vec![m1_row(alg, *n)];
            rows.extend(cpus.iter().map(|c| baseline_row(alg, *c, *n)));
            rows
        })
        .collect()
}

/// Figure data: `(title, rows, per_element)`.
pub fn figure(num: u32) -> (String, Vec<Row>, bool) {
    let (alg, n, per_elem) = match num {
        9 => ("translation", 8, false),
        10 => ("translation", 64, false),
        11 => ("translation", 8, true),
        12 => ("translation", 64, true),
        13 => ("scaling", 8, false),
        14 => ("scaling", 64, false),
        15 => ("scaling", 8, true),
        16 => ("scaling", 64, true),
        other => panic!("figure {other} is not in the paper's evaluation (9–16)"),
    };
    let rows = vec![
        m1_row(alg, n),
        baseline_row(alg, Cpu::I486, n),
        baseline_row(alg, Cpu::I386, n),
    ];
    let metric = if per_elem { "cycles/element" } else { "cycles" };
    let title = format!(
        "Figure {num} — {metric} for the {n}-element {alg} algorithm (M1 vs 80486 vs 80386)"
    );
    (title, rows, per_elem)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn m1_vector_rows_match_paper_exactly() {
        // The four calibrated cells reproduce the paper bit-for-bit.
        for (alg, n) in [("translation", 64), ("scaling", 64), ("translation", 8), ("scaling", 8)]
        {
            let row = m1_row(alg, n);
            assert_eq!(Some(row.cycles), row.paper_cycles, "{alg} n={n}");
        }
    }

    #[test]
    fn m1_rotation_rows_same_order_as_paper() {
        // Rotation routines are unpublished; measured must land within 2×
        // of the paper's count with the same verdict (M1 wins big).
        for (alg, n) in [("rotation-I", 64), ("rotation-II", 16)] {
            let row = m1_row(alg, n);
            let paper = row.paper_cycles.unwrap() as f64;
            let ratio = row.cycles as f64 / paper;
            assert!((0.4..=2.0).contains(&ratio), "{alg}: measured {} paper {}", row.cycles, paper);
        }
    }

    #[test]
    fn table5_speedups_preserve_paper_shape() {
        for block in table5() {
            let m1 = &block[0];
            assert_eq!(m1.system, "M1");
            for other in &block[1..] {
                let speedup = other.cycles as f64 / m1.cycles as f64;
                assert!(
                    speedup > 3.0,
                    "{} n={} vs {}: speedup {speedup:.2} too small",
                    other.system,
                    other.n,
                    m1.algorithm
                );
            }
        }
    }

    #[test]
    fn table3_and_4_match_published_cells_where_consistent() {
        // Table 4 is internally consistent in the paper → all 4 cells
        // must match exactly.
        for row in table4() {
            assert_eq!(Some(row.cycles), row.paper_cycles, "{} n={}", row.system, row.n);
        }
        // Table 3: the 8-element cells match; the 64-element cells carry
        // the paper's arithmetic slips (769 vs 706, 1723 vs 1732).
        for row in table3() {
            if row.n == 8 {
                assert_eq!(Some(row.cycles), row.paper_cycles);
            } else {
                let paper = row.paper_cycles.unwrap() as f64;
                assert!((row.cycles as f64 - paper).abs() / paper < 0.1);
            }
        }
    }

    #[test]
    fn figures_cover_9_to_16() {
        for num in 9..=16 {
            let (title, rows, per_elem) = figure(num);
            assert!(title.contains(&format!("Figure {num}")));
            assert_eq!(rows.len(), 3);
            assert_eq!(per_elem, num == 11 || num == 12 || num == 15 || num == 16);
            // M1 always wins.
            assert!(rows[0].cycles < rows[1].cycles);
            assert!(rows[0].cycles < rows[2].cycles);
        }
    }

    #[test]
    #[should_panic(expected = "not in the paper")]
    fn unknown_figure_panics() {
        figure(8);
    }

    #[test]
    fn listings_render() {
        let t1 = table1_listing();
        assert!(t1.contains("0x0000f400"));
        assert!(t1.contains("dbcdc"));
        assert!(t1.contains("predicted cycles: 96"));
        let t2 = table2_listing();
        assert!(t2.contains("0x00009005"));
        assert!(t2.contains("sbcb"));
        assert!(t2.contains("predicted cycles: 55"));
    }

    #[test]
    fn row_derived_metrics() {
        let row = Row {
            algorithm: "translation".into(),
            system: "M1".into(),
            n: 64,
            cycles: 96,
            clock_mhz: 100.0,
            paper_cycles: Some(96),
        };
        assert!((row.total_us() - 0.96).abs() < 1e-9);
        assert!((row.elems_per_cycle() - 0.667).abs() < 1e-3);
        assert!((row.cycles_per_elem() - 1.5).abs() < 1e-9);
    }
}
