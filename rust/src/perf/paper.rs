//! The paper's published numbers (Tables 3–5), kept verbatim as the
//! comparison column of every reproduction.

/// One published Table 5 entry: `(algorithm, system, n, cycles, speedup,
/// total_us, elems_per_cycle, cycles_per_elem)`. `speedup` is vs the M1
/// row of the same block (`None` for the M1 itself).
pub struct PaperRow {
    pub algorithm: &'static str,
    pub system: &'static str,
    pub n: usize,
    pub cycles: u64,
    pub speedup: Option<f64>,
    pub total_us: f64,
    pub elems_per_cycle: f64,
    pub cycles_per_elem: f64,
}

/// Table 5, verbatim.
pub const TABLE5: &[PaperRow] = &[
    PaperRow { algorithm: "translation", system: "M1", n: 64, cycles: 96, speedup: None, total_us: 0.96, elems_per_cycle: 0.667, cycles_per_elem: 1.5 },
    PaperRow { algorithm: "translation", system: "80486", n: 64, cycles: 769, speedup: Some(8.01), total_us: 7.69, elems_per_cycle: 0.083, cycles_per_elem: 12.0 },
    PaperRow { algorithm: "translation", system: "80386", n: 64, cycles: 1723, speedup: Some(17.94), total_us: 43.075, elems_per_cycle: 0.037, cycles_per_elem: 26.9 },
    PaperRow { algorithm: "scaling", system: "M1", n: 64, cycles: 55, speedup: None, total_us: 0.55, elems_per_cycle: 1.16, cycles_per_elem: 0.859 },
    PaperRow { algorithm: "scaling", system: "80486", n: 64, cycles: 578, speedup: Some(10.51), total_us: 5.78, elems_per_cycle: 0.047, cycles_per_elem: 9.03 },
    PaperRow { algorithm: "scaling", system: "80386", n: 64, cycles: 1348, speedup: Some(24.51), total_us: 33.7, elems_per_cycle: 0.11, cycles_per_elem: 21.2 },
    PaperRow { algorithm: "rotation-I", system: "M1", n: 64, cycles: 256, speedup: None, total_us: 2.56, elems_per_cycle: 0.25, cycles_per_elem: 4.0 },
    PaperRow { algorithm: "rotation-I", system: "Pentium", n: 64, cycles: 10151, speedup: Some(39.65), total_us: 76.32, elems_per_cycle: 0.006, cycles_per_elem: 158.6 },
    PaperRow { algorithm: "rotation-I", system: "80486", n: 64, cycles: 27038, speedup: Some(105.62), total_us: 270.38, elems_per_cycle: 0.002, cycles_per_elem: 422.4 },
    PaperRow { algorithm: "rotation-II", system: "M1", n: 16, cycles: 70, speedup: None, total_us: 0.7, elems_per_cycle: 0.228, cycles_per_elem: 4.375 },
    PaperRow { algorithm: "rotation-II", system: "Pentium", n: 16, cycles: 1328, speedup: Some(18.97), total_us: 9.98, elems_per_cycle: 0.012, cycles_per_elem: 83.0 },
    PaperRow { algorithm: "rotation-II", system: "80486", n: 16, cycles: 3354, speedup: Some(47.91), total_us: 33.54, elems_per_cycle: 0.0047, cycles_per_elem: 209.6 },
    PaperRow { algorithm: "translation", system: "M1", n: 8, cycles: 21, speedup: None, total_us: 0.21, elems_per_cycle: 0.38, cycles_per_elem: 2.625 },
    PaperRow { algorithm: "translation", system: "80486", n: 8, cycles: 90, speedup: Some(4.29), total_us: 0.9, elems_per_cycle: 0.088, cycles_per_elem: 11.36 },
    PaperRow { algorithm: "translation", system: "80386", n: 8, cycles: 220, speedup: Some(10.48), total_us: 5.5, elems_per_cycle: 0.036, cycles_per_elem: 27.5 },
    PaperRow { algorithm: "scaling", system: "M1", n: 8, cycles: 14, speedup: None, total_us: 0.14, elems_per_cycle: 0.57, cycles_per_elem: 1.75 },
    PaperRow { algorithm: "scaling", system: "80486", n: 8, cycles: 74, speedup: Some(5.28), total_us: 0.74, elems_per_cycle: 0.108, cycles_per_elem: 9.25 },
    PaperRow { algorithm: "scaling", system: "80386", n: 8, cycles: 172, speedup: Some(12.29), total_us: 4.3, elems_per_cycle: 0.46, cycles_per_elem: 21.7 },
];

/// Published cycle count, if the paper reports one for this cell.
pub fn cycles(algorithm: &str, system: &str, n: usize) -> Option<u64> {
    TABLE5
        .iter()
        .find(|r| r.algorithm == algorithm && r.system == system && r.n == n)
        .map(|r| r.cycles)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_finds_published_cells() {
        assert_eq!(cycles("translation", "M1", 64), Some(96));
        assert_eq!(cycles("scaling", "80386", 8), Some(172));
        assert_eq!(cycles("rotation-I", "Pentium", 64), Some(10151));
        assert_eq!(cycles("translation", "Pentium", 64), None);
    }

    #[test]
    fn table5_has_all_six_blocks() {
        let m1_rows = TABLE5.iter().filter(|r| r.system == "M1").count();
        assert_eq!(m1_rows, 6);
        assert_eq!(TABLE5.len(), 18);
    }
}
