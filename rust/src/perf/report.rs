//! Rendering: ASCII tables, ASCII bar charts (the figures), and CSV.

use super::experiments::Row;

/// Render comparison rows as an ASCII table with measured + paper columns.
pub fn render_table(title: &str, blocks: &[Vec<Row>]) -> String {
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    out.push_str(&format!(
        "{:<14} {:<8} {:>4} {:>9} {:>9} {:>8} {:>10} {:>9} {:>10} {:>9}\n",
        "algorithm", "system", "n", "cycles", "paper", "speedup", "total µs", "el/cyc", "cyc/el", "Δpaper%"
    ));
    out.push_str(&"-".repeat(100));
    out.push('\n');
    for block in blocks {
        let m1_cycles = block.first().map(|r| r.cycles).unwrap_or(1);
        for (i, row) in block.iter().enumerate() {
            let speedup = if i == 0 {
                "—".to_string()
            } else {
                format!("{:.2}", row.cycles as f64 / m1_cycles as f64)
            };
            let paper = row
                .paper_cycles
                .map(|c| c.to_string())
                .unwrap_or_else(|| "—".to_string());
            let delta = row
                .paper_cycles
                .map(|c| {
                    format!("{:+.1}", 100.0 * (row.cycles as f64 - c as f64) / c as f64)
                })
                .unwrap_or_else(|| "—".to_string());
            out.push_str(&format!(
                "{:<14} {:<8} {:>4} {:>9} {:>9} {:>8} {:>10.3} {:>9.3} {:>10.3} {:>9}\n",
                row.algorithm,
                row.system,
                row.n,
                row.cycles,
                paper,
                speedup,
                row.total_us(),
                row.elems_per_cycle(),
                row.cycles_per_elem(),
                delta,
            ));
        }
        out.push('\n');
    }
    out
}

/// Render one figure as an ASCII bar chart.
pub fn render_figure(title: &str, rows: &[Row], per_element: bool) -> String {
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    let values: Vec<f64> = rows
        .iter()
        .map(|r| if per_element { r.cycles_per_elem() } else { r.cycles as f64 })
        .collect();
    let max = values.iter().cloned().fold(f64::MIN, f64::max).max(1e-9);
    for (row, v) in rows.iter().zip(&values) {
        let width = ((v / max) * 50.0).round() as usize;
        let bar: String = "█".repeat(width.max(1));
        out.push_str(&format!("{:<8} {:>10.3} |{}\n", row.system, v, bar));
    }
    out
}

/// CSV serialization of comparison rows (one line per system).
pub fn to_csv(blocks: &[Vec<Row>]) -> String {
    let mut out = String::from(
        "algorithm,system,n,cycles_measured,cycles_paper,total_us,elems_per_cycle,cycles_per_elem\n",
    );
    for row in blocks.iter().flatten() {
        out.push_str(&format!(
            "{},{},{},{},{},{:.4},{:.4},{:.4}\n",
            row.algorithm,
            row.system,
            row.n,
            row.cycles,
            row.paper_cycles.map(|c| c.to_string()).unwrap_or_default(),
            row.total_us(),
            row.elems_per_cycle(),
            row.cycles_per_elem(),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf::experiments::{figure, table5};

    #[test]
    fn table_render_includes_all_systems() {
        let s = render_table("Table 5", &table5());
        assert!(s.contains("M1"));
        assert!(s.contains("80486"));
        assert!(s.contains("80386"));
        assert!(s.contains("Pentium"));
        assert!(s.contains("rotation-I"));
        // The calibrated cells show zero deviation.
        assert!(s.contains("+0.0"));
    }

    #[test]
    fn figure_render_has_bars() {
        let (title, rows, per_elem) = figure(10);
        let s = render_figure(&title, &rows, per_elem);
        assert!(s.contains("Figure 10"));
        assert!(s.contains('█'));
        // Three systems, three bars.
        assert_eq!(s.matches('|').count(), 3);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let csv = to_csv(&table5());
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert!(lines[0].starts_with("algorithm,system"));
        assert_eq!(lines.len(), 1 + 6 * 3);
        assert!(lines[1].starts_with("translation,M1,64,96,96"));
    }
}
