//! # Reproduction harness
//!
//! Regenerates every table and figure of the paper's evaluation:
//!
//! * Tables 1–2 — the emitted TinyRISC routines (with mULATE traces).
//! * Tables 3–4 — the x86 baseline cycle analyses.
//! * Table 5 — the headline comparison (cycles, speedup, µs,
//!   elements/cycle, cycles/element across M1 / 80486 / 80386 / Pentium).
//! * Figures 9–16 — the per-size cycle and cycles-per-element charts.
//!
//! Every row carries both the **measured** value (this crate's simulator
//! and baseline models, actually executed) and the **paper** value, so
//! deviations are visible rather than hidden (EXPERIMENTS.md §Deviations).

pub mod experiments;
pub mod paper;
pub mod report;

pub use experiments::{figure, table1_listing, table2_listing, table3, table4, table5, Row};
pub use report::{render_figure, render_table, to_csv};
