//! Context memory: storage for the RC array's configuration program.
//!
//! Two **blocks** (column-broadcast words and row-broadcast words), each
//! with two **planes** of 16 context words. Like the frame buffer's two
//! sets, the two planes allow the DMA to load the next configuration while
//! the RC array executes from the current one ("configuration data is also
//! loaded into context memory without interrupting RC array operation").

use super::rc_array::ContextWord;

/// Context words per plane.
pub const PLANE_WORDS: usize = 16;

/// Number of planes per block.
pub const PLANES: usize = 2;

/// Context block: which broadcast direction the words configure.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Block {
    Column,
    Row,
}

impl Block {
    pub fn index(self) -> usize {
        match self {
            Block::Column => 0,
            Block::Row => 1,
        }
    }

    pub fn from_index(i: usize) -> Block {
        if i == 0 {
            Block::Column
        } else {
            Block::Row
        }
    }
}

/// The context memory.
///
/// Words are decoded into [`ContextWord`]s **at write time** (DMA fills
/// happen once per configuration load), so the broadcast hot path reads a
/// pre-decoded word instead of re-decoding the raw 32 bits on every
/// 8-cell step (§Perf).
#[derive(Debug, Clone)]
pub struct ContextMemory {
    // [block][plane][word]
    words: Vec<u32>,
    /// Decode of `words`, kept in lockstep by every write path.
    decoded: Vec<ContextWord>,
}

impl Default for ContextMemory {
    fn default() -> Self {
        Self::new()
    }
}

impl ContextMemory {
    pub fn new() -> ContextMemory {
        ContextMemory {
            words: vec![0; 2 * PLANES * PLANE_WORDS],
            decoded: vec![ContextWord::decode(0); 2 * PLANES * PLANE_WORDS],
        }
    }

    /// Zero all contents in place (no reallocation).
    pub fn clear(&mut self) {
        self.words.fill(0);
        self.decoded.fill(ContextWord::decode(0));
    }

    fn idx(block: Block, plane: usize, word: usize) -> usize {
        assert!(plane < PLANES, "context plane {plane} out of range");
        assert!(word < PLANE_WORDS, "context word {word} out of range");
        (block.index() * PLANES + plane) * PLANE_WORDS + word
    }

    pub fn read(&self, block: Block, plane: usize, word: usize) -> u32 {
        self.words[Self::idx(block, plane, word)]
    }

    /// Read the pre-decoded form of a context word (the broadcast path).
    pub fn read_decoded(&self, block: Block, plane: usize, word: usize) -> ContextWord {
        self.decoded[Self::idx(block, plane, word)]
    }

    pub fn write(&mut self, block: Block, plane: usize, word: usize, value: u32) {
        let i = Self::idx(block, plane, word);
        self.words[i] = value;
        self.decoded[i] = ContextWord::decode(value);
    }

    /// All raw words in storage order (`[block][plane][word]`), for
    /// [`crate::morphosys::snapshot`].
    pub(crate) fn snapshot_words(&self) -> &[u32] {
        &self.words
    }

    /// Restore from a [`ContextMemory::snapshot_words`] image, re-decoding
    /// every word so the lockstep decode cache stays consistent.
    pub(crate) fn restore_words(&mut self, words: &[u32]) {
        assert_eq!(words.len(), self.words.len(), "context snapshot size mismatch");
        self.words.copy_from_slice(words);
        for (d, &w) in self.decoded.iter_mut().zip(words) {
            *d = ContextWord::decode(w);
        }
    }

    /// DMA fill of consecutive words within one plane.
    pub fn write_slice(&mut self, block: Block, plane: usize, word: usize, values: &[u32]) {
        assert!(word + values.len() <= PLANE_WORDS, "context fill out of range");
        let base = Self::idx(block, plane, word);
        self.words[base..base + values.len()].copy_from_slice(values);
        for (i, &v) in values.iter().enumerate() {
            self.decoded[base + i] = ContextWord::decode(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocks_and_planes_are_disjoint() {
        let mut cm = ContextMemory::new();
        cm.write(Block::Column, 0, 3, 0xF400);
        cm.write(Block::Column, 1, 3, 0x9005);
        cm.write(Block::Row, 0, 3, 0x1234);
        assert_eq!(cm.read(Block::Column, 0, 3), 0xF400);
        assert_eq!(cm.read(Block::Column, 1, 3), 0x9005);
        assert_eq!(cm.read(Block::Row, 0, 3), 0x1234);
        assert_eq!(cm.read(Block::Row, 1, 3), 0);
    }

    #[test]
    fn slice_fill() {
        let mut cm = ContextMemory::new();
        let words: Vec<u32> = (0..8).map(|i| 0xC000 + i).collect();
        cm.write_slice(Block::Row, 1, 4, &words);
        for (i, w) in words.iter().enumerate() {
            assert_eq!(cm.read(Block::Row, 1, 4 + i), *w);
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn overflowing_fill_panics() {
        let mut cm = ContextMemory::new();
        cm.write_slice(Block::Column, 0, 10, &[0; 8]);
    }

    #[test]
    fn decoded_cache_tracks_every_write_path() {
        let mut cm = ContextMemory::new();
        cm.write(Block::Column, 0, 2, 0x0000_F400);
        assert_eq!(cm.read_decoded(Block::Column, 0, 2), ContextWord::decode(0x0000_F400));
        cm.write_slice(Block::Row, 1, 0, &[0x0000_9005, 0x0000_F400]);
        assert_eq!(cm.read_decoded(Block::Row, 1, 0), ContextWord::decode(0x0000_9005));
        assert_eq!(cm.read_decoded(Block::Row, 1, 1), ContextWord::decode(0x0000_F400));
        cm.clear();
        assert_eq!(cm.read_decoded(Block::Row, 1, 0), ContextWord::decode(0));
    }

    #[test]
    fn block_index_roundtrip() {
        assert_eq!(Block::from_index(Block::Column.index()), Block::Column);
        assert_eq!(Block::from_index(Block::Row.index()), Block::Row);
    }
}
