//! mULATE-style execution traces.
//!
//! The paper validated its mappings "using the MorphoSys mULATE program,
//! which emulates M1 operations". This module is our equivalent: a
//! cycle-annotated, per-instruction trace with the architectural effect of
//! each step, rendered in a format close to the paper's Tables 1–2
//! (instruction index, mnemonic, effect commentary).

use super::tinyrisc::asm::disassemble;
use super::tinyrisc::Instruction;

/// One traced instruction issue.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Cycle at which the instruction issued.
    pub cycle: u64,
    /// Program counter.
    pub pc: usize,
    pub instr: Instruction,
    /// Human-readable architectural effect.
    pub effect: String,
}

/// A full execution trace.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
}

impl Trace {
    pub fn new() -> Trace {
        Trace::default()
    }

    pub fn push(&mut self, e: TraceEvent) {
        self.events.push(e);
    }

    /// Render the trace as a mULATE-style table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("cycle   pc  instruction                              effect\n");
        out.push_str("-----  ---  ---------------------------------------  ------------------------------\n");
        for e in &self.events {
            out.push_str(&format!(
                "{:5}  {:3}  {:<39}  {}\n",
                e.cycle,
                e.pc,
                disassemble(&e.instr),
                e.effect
            ));
        }
        out
    }

    /// Cycle of the final event (the paper's cycle-count convention).
    pub fn final_cycle(&self) -> u64 {
        self.events.last().map(|e| e.cycle).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::morphosys::tinyrisc::Reg;

    #[test]
    fn render_contains_cycles_and_mnemonics() {
        let mut t = Trace::new();
        t.push(TraceEvent {
            cycle: 0,
            pc: 0,
            instr: Instruction::Ldui { rd: Reg(1), imm: 1 },
            effect: "r1 <- 0x10000".into(),
        });
        t.push(TraceEvent { cycle: 1, pc: 1, instr: Instruction::Halt, effect: "halt".into() });
        let s = t.render();
        assert!(s.contains("ldui"));
        assert!(s.contains("r1 <- 0x10000"));
        assert_eq!(t.final_cycle(), 1);
    }

    #[test]
    fn empty_trace_final_cycle_is_zero() {
        assert_eq!(Trace::new().final_cycle(), 0);
    }
}
