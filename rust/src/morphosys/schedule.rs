//! Pre-decoded broadcast schedules (§Perf).
//!
//! Every mapping the compiler emits is a straight-line TinyRISC program:
//! stage data, load context, fire a run of `dbcdc`/`sbcb`/`wfbi`
//! instructions, store. Interpreting such a program per instruction pays
//! fetch + dispatch + cycle-accounting on every step even though nothing
//! about the control flow or the timing depends on runtime values.
//!
//! [`BroadcastSchedule::compile`] flattens a straight-line program once
//! into a vector of pre-classified steps and **precomputes the entire
//! cycle accounting** (issue slots, final-issue cycle, executed count,
//! broadcast count) at compile time, using exactly the blocking-DMA issue
//! model of [`M1System::run`]. Executing a schedule is then pure data
//! movement and RC-array compute — no per-instruction dispatch, no
//! accounting arithmetic, no trace plumbing.
//!
//! Schedules are compiled once per distinct program and reused across
//! `run_routine` calls (see the thread-local cache in
//! [`crate::mapping::runner`]). Programs with branches (`jmp`/`bnez`)
//! don't compile — callers fall back to the interpreter — and the
//! schedule path is only taken in blocking-DMA, non-tracing mode, where
//! its accounting is bit-for-bit identical to the interpreter's.
//!
//! [`M1System::run`]: crate::morphosys::M1System::run

use super::context_memory::{PLANES, PLANE_WORDS};
use super::frame_buffer::{Bank, Set, BANK_ELEMS};
use super::rc_array::{BroadcastMode, ARRAY_DIM};
use super::system::ExecutionReport;
use super::tinyrisc::{Instruction, Program};

/// One pre-decoded step of a schedule.
#[derive(Debug, Clone, Copy)]
pub(crate) enum Step {
    /// A scalar / DMA / context-load instruction, executed through the
    /// ordinary effect path (these are rare and cheap; the hot steps are
    /// the two below).
    Plain(Instruction),
    /// A broadcast trigger with its context-memory coordinates and
    /// operand-bus sources fully resolved (the context block follows from
    /// `mode`, exactly as in the interpreter).
    Broadcast {
        mode: BroadcastMode,
        plane: usize,
        cw: usize,
        line: usize,
        set: Set,
        bus_a: Option<(Bank, usize)>,
        bus_b: Option<(Bank, usize)>,
    },
    /// A `wfbi`/`wfbir` write-back of one line's output registers.
    WriteBack { mode: BroadcastMode, line: usize, set: Set, bank: Bank, addr: usize },
}

/// A straight-line TinyRISC program compiled to a flat step vector with
/// precomputed cycle accounting.
#[derive(Debug, Clone)]
pub struct BroadcastSchedule {
    /// Private (even crate-wide): `steps` together with `validated` carry
    /// the safety proof for the executor's unchecked plane reads, so only
    /// `compile` may establish them.
    steps: Vec<Step>,
    /// Every broadcast step's static coordinates were proven in range at
    /// compile time (context plane/word, broadcast line, and — the hot
    /// part — `bus addr + ARRAY_DIM <= BANK_ELEMS` for both operand
    /// buses), so the executor may use unchecked frame-buffer plane reads
    /// (§Perf). An out-of-range program compiles unvalidated and runs
    /// through the checked path, panicking exactly like the interpreter.
    validated: bool,
    cycles: u64,
    slots: u64,
    executed: u64,
    broadcasts: u64,
}

impl BroadcastSchedule {
    /// Compile a program. Returns `None` when the program branches
    /// (`jmp`/`bnez`) — those run through the interpreter. A trailing
    /// `halt` (and anything after it) ends the schedule, mirroring the
    /// interpreter.
    pub fn compile(program: &Program) -> Option<BroadcastSchedule> {
        let mut steps = Vec::with_capacity(program.len());
        let mut slots = 0u64;
        let mut executed = 0u64;
        let mut broadcasts = 0u64;
        let mut last_issue = 0u64;
        let mut validated = true;
        let bus_ok = |bus: Option<(Bank, usize)>| match bus {
            Some((_, addr)) => addr + ARRAY_DIM <= BANK_ELEMS,
            None => true,
        };
        let coords_ok = |plane: usize, cw: usize, line: usize| {
            plane < PLANES && cw < PLANE_WORDS && line < ARRAY_DIM
        };
        for instr in &program.instructions {
            // Blocking-DMA issue model: the instruction issues at the
            // current slot count and occupies `issue_slots()` slots.
            last_issue = slots;
            slots += instr.issue_slots();
            executed += 1;
            match *instr {
                Instruction::Jmp { .. } | Instruction::Bnez { .. } => return None,
                Instruction::Halt => break,
                Instruction::Dbcdc { plane, cw, col, set, addr_a, addr_b } => {
                    broadcasts += 1;
                    steps.push(Step::Broadcast {
                        mode: BroadcastMode::Column,
                        plane,
                        cw,
                        line: col,
                        set,
                        bus_a: Some((Bank::A, addr_a)),
                        bus_b: Some((Bank::B, addr_b)),
                    });
                }
                Instruction::Dbcdr { plane, cw, row, set, addr_a, addr_b } => {
                    broadcasts += 1;
                    steps.push(Step::Broadcast {
                        mode: BroadcastMode::Row,
                        plane,
                        cw,
                        line: row,
                        set,
                        bus_a: Some((Bank::A, addr_a)),
                        bus_b: Some((Bank::B, addr_b)),
                    });
                }
                Instruction::Sbcb { plane, cw, col, set, bank, addr } => {
                    broadcasts += 1;
                    steps.push(Step::Broadcast {
                        mode: BroadcastMode::Column,
                        plane,
                        cw,
                        line: col,
                        set,
                        bus_a: Some((bank, addr)),
                        bus_b: None,
                    });
                }
                Instruction::Sbcbr { plane, cw, row, set, bank, addr } => {
                    broadcasts += 1;
                    steps.push(Step::Broadcast {
                        mode: BroadcastMode::Row,
                        plane,
                        cw,
                        line: row,
                        set,
                        bus_a: Some((bank, addr)),
                        bus_b: None,
                    });
                }
                Instruction::Wfbi { col, set, bank, addr } => {
                    steps.push(Step::WriteBack {
                        mode: BroadcastMode::Column,
                        line: col,
                        set,
                        bank,
                        addr,
                    });
                }
                Instruction::Wfbir { row, set, bank, addr } => {
                    steps.push(Step::WriteBack { mode: BroadcastMode::Row, line: row, set, bank, addr });
                }
                plain => steps.push(Step::Plain(plain)),
            }
            // Validate the step just pushed: every broadcast whose static
            // coordinates are provably in range may take the unchecked
            // plane-read path at execution time.
            if let Some(Step::Broadcast { plane, cw, line, bus_a, bus_b, .. }) = steps.last() {
                validated &=
                    coords_ok(*plane, *cw, *line) && bus_ok(*bus_a) && bus_ok(*bus_b);
            }
        }
        Some(BroadcastSchedule {
            steps,
            validated,
            cycles: last_issue,
            slots,
            executed,
            broadcasts,
        })
    }

    /// Whether every broadcast step passed compile-time bounds validation
    /// (the precondition for the executor's unchecked plane reads).
    pub fn is_validated(&self) -> bool {
        self.validated
    }

    /// The pre-decoded steps, read-only (the executor's iteration path).
    pub(crate) fn steps(&self) -> &[Step] {
        &self.steps
    }

    /// The precomputed execution report (identical to what the
    /// interpreter would account for this program in blocking-DMA mode).
    pub fn report(&self) -> ExecutionReport {
        ExecutionReport {
            cycles: self.cycles,
            slots: self.slots,
            executed: self.executed,
            broadcasts: self.broadcasts,
        }
    }

    /// Number of pre-decoded steps.
    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::morphosys::tinyrisc::Reg;

    #[test]
    fn branchy_programs_do_not_compile() {
        let p = Program::new(vec![
            Instruction::Ldli { rd: Reg(1), imm: 1 },
            Instruction::Bnez { rs: Reg(1), target: 0 },
        ]);
        assert!(BroadcastSchedule::compile(&p).is_none());
        let p = Program::new(vec![Instruction::Jmp { target: 0 }]);
        assert!(BroadcastSchedule::compile(&p).is_none());
    }

    #[test]
    fn accounting_matches_the_paper_convention() {
        let p = Program::new(vec![
            Instruction::Ldui { rd: Reg(1), imm: 1 },
            Instruction::Ldfb { rs: Reg(1), set: Set::Zero, bank: Bank::A, words: 32, fb_addr: 0 },
            Instruction::Dbcdc { plane: 0, cw: 0, col: 0, set: Set::Zero, addr_a: 0, addr_b: 0 },
            Instruction::Stfb { rs: Reg(1), set: Set::Zero, bank: Bank::A, words: 32, fb_addr: 0 },
        ]);
        let s = BroadcastSchedule::compile(&p).unwrap();
        let r = s.report();
        // Issue slots: 1 + 32 + 1 + 32; the final stfb issues at cycle 34.
        assert_eq!(r.slots, 66);
        assert_eq!(r.cycles, 34);
        assert_eq!(r.executed, 4);
        assert_eq!(r.broadcasts, 1);
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn halt_truncates_the_schedule() {
        let p = Program::new(vec![
            Instruction::Ldli { rd: Reg(1), imm: 1 },
            Instruction::Halt,
            Instruction::Ldli { rd: Reg(1), imm: 9 }, // dead
        ]);
        let s = BroadcastSchedule::compile(&p).unwrap();
        assert_eq!(s.len(), 1);
        let r = s.report();
        assert_eq!(r.executed, 2);
        assert_eq!(r.cycles, 1);
        assert_eq!(r.slots, 2);
    }

    #[test]
    fn in_range_broadcasts_validate_for_unchecked_reads() {
        let p = Program::new(vec![
            Instruction::Dbcdc { plane: 1, cw: 15, col: 7, set: Set::One, addr_a: BANK_ELEMS - ARRAY_DIM, addr_b: 0 },
            Instruction::Sbcbr { plane: 0, cw: 0, row: 0, set: Set::Zero, bank: Bank::B, addr: 64 },
            Instruction::Wfbi { col: 3, set: Set::One, bank: Bank::A, addr: 0 },
        ]);
        assert!(BroadcastSchedule::compile(&p).unwrap().is_validated());
    }

    #[test]
    fn out_of_range_bus_addresses_fall_back_to_checked_execution() {
        // One element past the last whole operand-bus window: the
        // schedule still compiles (and must panic at run time exactly
        // like the interpreter), but the unchecked path is off.
        let p = Program::new(vec![Instruction::Dbcdc {
            plane: 0,
            cw: 0,
            col: 0,
            set: Set::Zero,
            addr_a: BANK_ELEMS - ARRAY_DIM + 1,
            addr_b: 0,
        }]);
        assert!(!BroadcastSchedule::compile(&p).unwrap().is_validated());
        let p = Program::new(vec![Instruction::Sbcb {
            plane: 2, // out-of-range context plane
            cw: 0,
            col: 0,
            set: Set::Zero,
            bank: Bank::A,
            addr: 0,
        }]);
        assert!(!BroadcastSchedule::compile(&p).unwrap().is_validated());
    }

    #[test]
    fn empty_program_compiles_to_empty_schedule() {
        let s = BroadcastSchedule::compile(&Program::default()).unwrap();
        assert!(s.is_empty());
        let r = s.report();
        assert_eq!((r.cycles, r.slots, r.executed, r.broadcasts), (0, 0, 0, 0));
    }
}
