//! Pre-decoded broadcast schedules (§Perf).
//!
//! Every mapping the compiler emits is a straight-line TinyRISC program:
//! stage data, load context, fire a run of `dbcdc`/`sbcb`/`wfbi`
//! instructions, store. Interpreting such a program per instruction pays
//! fetch + dispatch + cycle-accounting on every step even though nothing
//! about the control flow or the timing depends on runtime values.
//!
//! [`BroadcastSchedule::compile`] flattens a straight-line program once
//! into a vector of pre-classified steps and **precomputes the entire
//! cycle accounting** (issue slots, final-issue cycle, executed count,
//! broadcast count) at compile time — for **both DMA modes** (§Perf
//! PR 5): the blocking issue model of [`M1System::run`] and the
//! non-blocking `AsyncDma` issue/readiness model of
//! `M1System::with_async_dma` (see [`super::timing`]). Executing a
//! schedule is then pure data
//! movement and RC-array compute — no per-instruction dispatch, no
//! accounting arithmetic, no trace plumbing — and the report comes from
//! whichever precomputed accounting matches the executing system's mode.
//!
//! The async accounting is computable at compile time because every
//! latency input of the issue model is a static instruction field
//! (transfer word counts, set/bank selects): each DMA step's issue cycle
//! and readiness edge, and each broadcast/write-back's stall-or-proceed
//! decision, are replayed over the **same** `AsyncDma` state machine
//! the interpreter steps at run time — identical by construction, and
//! pinned bit-for-bit by the conformance suite in both modes. The only
//! dynamic hazard in the ISA is control flow: programs with branches
//! (`jmp`/`bnez`) refuse to compile and callers fall back to the
//! interpreter, as do tracing systems (which need per-instruction event
//! plumbing).
//!
//! Schedules are compiled once per distinct program and reused across
//! `run_routine` calls (see the thread-local cache in
//! [`crate::mapping::runner`]).
//!
//! [`M1System::run`]: crate::morphosys::M1System::run

use super::context_memory::{PLANES, PLANE_WORDS};
use super::frame_buffer::{Bank, Set, BANK_ELEMS};
use super::rc_array::{BroadcastMode, ARRAY_DIM};
use super::system::ExecutionReport;
use super::timing::AsyncDma;
use super::tinyrisc::{Instruction, Program};

/// One pre-decoded step of a schedule.
#[derive(Debug, Clone, Copy)]
pub(crate) enum Step {
    /// A scalar / DMA / context-load instruction, executed through the
    /// ordinary effect path (these are rare and cheap; the hot steps are
    /// the two below).
    Plain(Instruction),
    /// A broadcast trigger with its context-memory coordinates and
    /// operand-bus sources fully resolved (the context block follows from
    /// `mode`, exactly as in the interpreter).
    Broadcast {
        mode: BroadcastMode,
        plane: usize,
        cw: usize,
        line: usize,
        set: Set,
        bus_a: Option<(Bank, usize)>,
        bus_b: Option<(Bank, usize)>,
    },
    /// A `wfbi`/`wfbir` write-back of one line's output registers.
    WriteBack { mode: BroadcastMode, line: usize, set: Set, bank: Bank, addr: usize },
    /// A fused run of broadcasts or write-backs (§Perf, fused tile-kernel
    /// tier) — see [`FusedRun`] and the compile-time fusion pass.
    FusedRun(FusedRun),
}

/// A compile-time-fused run of hot steps, executed as one tight loop with
/// no per-step dispatch and no per-broadcast context-word/operand-plan
/// re-resolution (§Perf).
///
/// Fusion criteria (checked statically by [`fuse_steps`]):
///
/// * **Broadcasts** — ≥ 2 consecutive broadcast steps sharing one context
///   word (same `mode`/`plane`/`cw`/`set`), lines ascending by one and
///   every operand-bus address advancing by exactly [`ARRAY_DIM`] on the
///   same bank — the shape every `VecVecMapping`, `VecScalarMapping` and
///   `TiledVecVecMapping` tile emits. Register-only scalar steps
///   interleaved with the run (the paper's `ldli r4` bank-address
///   formation) are hoisted ahead of it: they touch only the TinyRISC
///   register file, which no broadcast or write-back reads or writes, so
///   the reordering is architecturally exact.
/// * **Write-backs** — ≥ 2 consecutive write-backs of ascending lines to
///   one contiguous frame-buffer span (same `mode`/`set`/`bank`, address
///   advancing by [`ARRAY_DIM`]), committed as a single slice write.
///
/// Every fused step is additionally proven in range at compile time
/// (context coordinates, lines, and full bus/write-back windows), so a
/// fused run can never panic mid-run; programs that fail any criterion
/// keep their steps unfused and execute exactly as before.
#[derive(Debug, Clone, Copy)]
pub(crate) enum FusedRun {
    /// `count` broadcasts driving lines `line0 ..`, operand buses walking
    /// `base + i·ARRAY_DIM` from the given base addresses.
    Broadcasts {
        mode: BroadcastMode,
        plane: usize,
        cw: usize,
        line0: usize,
        set: Set,
        bus_a: Option<(Bank, usize)>,
        bus_b: Option<(Bank, usize)>,
        count: usize,
    },
    /// `count` write-backs of lines `line0 ..` to the contiguous span
    /// `addr0 .. addr0 + count·ARRAY_DIM`.
    WriteBacks {
        mode: BroadcastMode,
        line0: usize,
        set: Set,
        bank: Bank,
        addr0: usize,
        count: usize,
    },
}

/// A straight-line TinyRISC program compiled to a flat step vector with
/// precomputed cycle accounting.
#[derive(Debug, Clone)]
pub struct BroadcastSchedule {
    /// Private (even crate-wide): `steps` together with `validated` carry
    /// the safety proof for the executor's unchecked plane reads, so only
    /// `compile` may establish them.
    steps: Vec<Step>,
    /// Every broadcast step's static coordinates were proven in range at
    /// compile time (context plane/word, broadcast line, and — the hot
    /// part — `bus addr + ARRAY_DIM <= BANK_ELEMS` for both operand
    /// buses), so the executor may use unchecked frame-buffer plane reads
    /// (§Perf). An out-of-range program compiles unvalidated and runs
    /// through the checked path, panicking exactly like the interpreter.
    validated: bool,
    cycles: u64,
    slots: u64,
    /// Final-issue cycle under the `AsyncDma` issue model (§Perf PR 5)
    /// — the same program's accounting on an async-DMA system.
    async_cycles: u64,
    /// Issue-slot total under the async model (`last issue + 1`, the
    /// interpreter's convention).
    async_slots: u64,
    /// Final state of the async issue model after the whole program — what
    /// the interpreter's `AsyncDma` ends at, captured at compile time so
    /// the scheduled tier can expose identical in-flight DMA state to
    /// [`crate::morphosys::snapshot`].
    final_async: AsyncDma,
    executed: u64,
    broadcasts: u64,
}

impl BroadcastSchedule {
    /// Compile a program. Returns `None` when the program branches
    /// (`jmp`/`bnez`) — those run through the interpreter. A trailing
    /// `halt` (and anything after it) ends the schedule, mirroring the
    /// interpreter. Eligible broadcast/write-back runs are collapsed into
    /// [`FusedRun`] steps (§Perf — see the fusion criteria there).
    pub fn compile(program: &Program) -> Option<BroadcastSchedule> {
        Self::compile_with(program, true)
    }

    /// As [`BroadcastSchedule::compile`] but with the fusion pass
    /// disabled: one step per instruction, exactly the pre-fusion
    /// scheduled path. The bench baseline and the fusion-refusal
    /// conformance tests use this to pin the two tiers against each
    /// other.
    pub fn compile_unfused(program: &Program) -> Option<BroadcastSchedule> {
        Self::compile_with(program, false)
    }

    fn compile_with(program: &Program, fuse: bool) -> Option<BroadcastSchedule> {
        let mut steps = Vec::with_capacity(program.len());
        let mut slots = 0u64;
        let mut executed = 0u64;
        let mut broadcasts = 0u64;
        let mut last_issue = 0u64;
        // Async-DMA accounting, replayed over the interpreter's own issue
        // model (§Perf PR 5): every latency input is a static instruction
        // field, so the whole stall-or-proceed resolution happens here at
        // compile time.
        let mut dma = AsyncDma::default();
        let mut async_slots = 0u64;
        let mut async_last = 0u64;
        let mut validated = true;
        let bus_ok = |bus: Option<(Bank, usize)>| match bus {
            Some((_, addr)) => addr + ARRAY_DIM <= BANK_ELEMS,
            None => true,
        };
        let coords_ok = |plane: usize, cw: usize, line: usize| {
            plane < PLANES && cw < PLANE_WORDS && line < ARRAY_DIM
        };
        for instr in &program.instructions {
            // Blocking-DMA issue model: the instruction issues at the
            // current slot count and occupies `issue_slots()` slots.
            last_issue = slots;
            slots += instr.issue_slots();
            // Async model: issue when the engine/resources allow, then
            // the next instruction is offered one cycle later.
            async_last = dma.issue(instr, async_slots);
            async_slots = async_last + 1;
            executed += 1;
            match *instr {
                Instruction::Jmp { .. } | Instruction::Bnez { .. } => return None,
                Instruction::Halt => break,
                Instruction::Dbcdc { plane, cw, col, set, addr_a, addr_b } => {
                    broadcasts += 1;
                    steps.push(Step::Broadcast {
                        mode: BroadcastMode::Column,
                        plane,
                        cw,
                        line: col,
                        set,
                        bus_a: Some((Bank::A, addr_a)),
                        bus_b: Some((Bank::B, addr_b)),
                    });
                }
                Instruction::Dbcdr { plane, cw, row, set, addr_a, addr_b } => {
                    broadcasts += 1;
                    steps.push(Step::Broadcast {
                        mode: BroadcastMode::Row,
                        plane,
                        cw,
                        line: row,
                        set,
                        bus_a: Some((Bank::A, addr_a)),
                        bus_b: Some((Bank::B, addr_b)),
                    });
                }
                Instruction::Sbcb { plane, cw, col, set, bank, addr } => {
                    broadcasts += 1;
                    steps.push(Step::Broadcast {
                        mode: BroadcastMode::Column,
                        plane,
                        cw,
                        line: col,
                        set,
                        bus_a: Some((bank, addr)),
                        bus_b: None,
                    });
                }
                Instruction::Sbcbr { plane, cw, row, set, bank, addr } => {
                    broadcasts += 1;
                    steps.push(Step::Broadcast {
                        mode: BroadcastMode::Row,
                        plane,
                        cw,
                        line: row,
                        set,
                        bus_a: Some((bank, addr)),
                        bus_b: None,
                    });
                }
                Instruction::Wfbi { col, set, bank, addr } => {
                    steps.push(Step::WriteBack {
                        mode: BroadcastMode::Column,
                        line: col,
                        set,
                        bank,
                        addr,
                    });
                }
                Instruction::Wfbir { row, set, bank, addr } => {
                    steps.push(Step::WriteBack { mode: BroadcastMode::Row, line: row, set, bank, addr });
                }
                plain => steps.push(Step::Plain(plain)),
            }
            // Validate the step just pushed: every broadcast whose static
            // coordinates are provably in range may take the unchecked
            // plane-read path at execution time.
            if let Some(Step::Broadcast { plane, cw, line, bus_a, bus_b, .. }) = steps.last() {
                validated &=
                    coords_ok(*plane, *cw, *line) && bus_ok(*bus_a) && bus_ok(*bus_b);
            }
        }
        let steps = if fuse { fuse_steps(steps) } else { steps };
        Some(BroadcastSchedule {
            steps,
            validated,
            cycles: last_issue,
            slots,
            async_cycles: async_last,
            async_slots,
            final_async: dma,
            executed,
            broadcasts,
        })
    }

    /// Final async-DMA engine state after the program (see the field docs).
    pub(crate) fn final_async(&self) -> AsyncDma {
        self.final_async
    }

    /// Whether every broadcast step passed compile-time bounds validation
    /// (the precondition for the executor's unchecked plane reads).
    pub fn is_validated(&self) -> bool {
        self.validated
    }

    /// Number of [`FusedRun`] steps the fusion pass produced (0 for
    /// unfusable programs and [`BroadcastSchedule::compile_unfused`]).
    pub fn fused_runs(&self) -> usize {
        self.steps.iter().filter(|s| matches!(s, Step::FusedRun(_))).count()
    }

    /// The pre-decoded steps, read-only (the executor's iteration path).
    pub(crate) fn steps(&self) -> &[Step] {
        &self.steps
    }

    /// The precomputed execution report (identical to what the
    /// interpreter would account for this program in blocking-DMA mode).
    pub fn report(&self) -> ExecutionReport {
        ExecutionReport {
            cycles: self.cycles,
            slots: self.slots,
            executed: self.executed,
            broadcasts: self.broadcasts,
        }
    }

    /// The precomputed **async-DMA** execution report (identical to what
    /// the interpreter would account for this program on an
    /// `M1System::with_async_dma` system — §Perf PR 5).
    pub fn async_report(&self) -> ExecutionReport {
        ExecutionReport {
            cycles: self.async_cycles,
            slots: self.async_slots,
            executed: self.executed,
            broadcasts: self.broadcasts,
        }
    }

    /// Report for the executing system's DMA mode.
    pub(crate) fn report_for(&self, async_dma: bool) -> ExecutionReport {
        if async_dma {
            self.async_report()
        } else {
            self.report()
        }
    }

    /// Number of pre-decoded steps.
    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }
}

/// Is this plain step a pure TinyRISC-register operation (reads and
/// writes the scalar register file only)? Broadcasts and write-backs
/// never touch the register file, so these commute with them exactly —
/// which is what lets [`fuse_steps`] hoist interleaved address-formation
/// steps (the paper's `ldli r4`) ahead of a fused run.
fn register_only(instr: &Instruction) -> bool {
    matches!(
        instr,
        Instruction::Ldui { .. }
            | Instruction::Ldli { .. }
            | Instruction::Add { .. }
            | Instruction::Sub { .. }
            | Instruction::Addi { .. }
    )
}

/// Does `cand` continue the operand-bus pattern anchored at `base`, `count`
/// windows in: same bank (or both absent), address advanced by exactly
/// `count · ARRAY_DIM`?
fn bus_advances(
    base: Option<(Bank, usize)>,
    cand: Option<(Bank, usize)>,
    count: usize,
) -> bool {
    match (base, cand) {
        (None, None) => true,
        (Some((bank0, a0)), Some((bank, a))) => bank == bank0 && a == a0 + count * ARRAY_DIM,
        _ => false,
    }
}

/// The compile-time fusion pass: collapse eligible broadcast and
/// write-back runs into [`FusedRun`] steps (see the criteria on
/// [`FusedRun`]). Pure step-vector rewrite — the precomputed cycle
/// accounting is untouched (it was derived from the instruction stream
/// before fusion), and programs with no eligible run come back unchanged.
fn fuse_steps(steps: Vec<Step>) -> Vec<Step> {
    let bus_in_range = |bus: Option<(Bank, usize)>| match bus {
        Some((_, addr)) => addr + ARRAY_DIM <= BANK_ELEMS,
        None => true,
    };
    let mut out = Vec::with_capacity(steps.len());
    let mut i = 0;
    while i < steps.len() {
        if let Step::Broadcast { mode, plane, cw, line: line0, set, bus_a, bus_b } = steps[i] {
            // Anchor must be fully in range so a fused run can never
            // panic mid-loop; out-of-range steps stay unfused and keep
            // the interpreter's checked reads (and panics).
            if plane < PLANES
                && cw < PLANE_WORDS
                && line0 < ARRAY_DIM
                && bus_in_range(bus_a)
                && bus_in_range(bus_b)
            {
                let mut hoisted: Vec<Step> = Vec::new();
                let mut pending: Vec<Step> = Vec::new();
                let mut count = 1usize;
                let mut next_i = i + 1;
                for j in i + 1..steps.len() {
                    match steps[j] {
                        Step::Plain(instr) if register_only(&instr) => pending.push(steps[j]),
                        Step::Broadcast {
                            mode: m2,
                            plane: p2,
                            cw: c2,
                            line: l2,
                            set: s2,
                            bus_a: a2,
                            bus_b: b2,
                        } if m2 == mode
                            && p2 == plane
                            && c2 == cw
                            && s2 == set
                            && l2 == line0 + count
                            && l2 < ARRAY_DIM
                            && bus_advances(bus_a, a2, count)
                            && bus_advances(bus_b, b2, count)
                            && bus_in_range(a2)
                            && bus_in_range(b2) =>
                        {
                            hoisted.append(&mut pending);
                            count += 1;
                            next_i = j + 1;
                        }
                        _ => break,
                    }
                }
                if count >= 2 {
                    out.extend(hoisted);
                    out.push(Step::FusedRun(FusedRun::Broadcasts {
                        mode,
                        plane,
                        cw,
                        line0,
                        set,
                        bus_a,
                        bus_b,
                        count,
                    }));
                    i = next_i;
                    continue;
                }
            }
        }
        if let Step::WriteBack { mode, line: line0, set, bank, addr: addr0 } = steps[i] {
            if line0 < ARRAY_DIM && addr0 + ARRAY_DIM <= BANK_ELEMS {
                let mut hoisted: Vec<Step> = Vec::new();
                let mut pending: Vec<Step> = Vec::new();
                let mut count = 1usize;
                let mut next_i = i + 1;
                for j in i + 1..steps.len() {
                    match steps[j] {
                        Step::Plain(instr) if register_only(&instr) => pending.push(steps[j]),
                        Step::WriteBack { mode: m2, line: l2, set: s2, bank: bk2, addr: a2 }
                            if m2 == mode
                                && s2 == set
                                && bk2 == bank
                                && l2 == line0 + count
                                && l2 < ARRAY_DIM
                                && a2 == addr0 + count * ARRAY_DIM
                                && a2 + ARRAY_DIM <= BANK_ELEMS =>
                        {
                            hoisted.append(&mut pending);
                            count += 1;
                            next_i = j + 1;
                        }
                        _ => break,
                    }
                }
                if count >= 2 {
                    out.extend(hoisted);
                    out.push(Step::FusedRun(FusedRun::WriteBacks {
                        mode,
                        line0,
                        set,
                        bank,
                        addr0,
                        count,
                    }));
                    i = next_i;
                    continue;
                }
            }
        }
        out.push(steps[i]);
        i += 1;
    }
    out
}

/// One step of a [`Megakernel`] — a further-lowered [`Step`] stream for
/// whole-plan programs (§Perf, megakernel tier).
#[derive(Debug, Clone, Copy)]
pub(crate) enum MegaStep {
    /// An ordinary pre-decoded step, executed exactly as the scheduled
    /// tier would (register ops stay in the stream so the TinyRISC
    /// register file ends bit-identical to every other tier).
    Step(Step),
    /// An `ldfb` whose source address was proven constant at compile
    /// time: the main-memory→frame-buffer transfer runs without reading
    /// the register file or allocating an element buffer. Word reads and
    /// the frame-buffer commit happen in the interpreter's order.
    Load { mem_addr: usize, set: Set, bank: Bank, fb_addr: usize, words: usize },
    /// One whole 64-point tile: a full-array column broadcast run plus
    /// its write-back run, committed as a single frame-buffer
    /// read → 64-lane ALU evaluation → single slice write. All windows
    /// were proven in range by the fusion pass, so the executor's
    /// whole-tile fast path can never panic mid-tile.
    Tile {
        plane: usize,
        cw: usize,
        set: Set,
        bus_a: (Bank, usize),
        bus_b: (Bank, usize),
        wb_set: Set,
        wb_bank: Bank,
        wb_addr: usize,
    },
}

/// A whole tile plan compiled to one megakernel (§Perf, megakernel
/// tier): the program's [`BroadcastSchedule`] lowered one level further
/// by constant-propagating the TinyRISC register file over the
/// straight-line step stream, so that
///
/// * every `ldfb` with a statically-known source address becomes a
///   [`MegaStep::Load`] (no register read, no per-transfer element
///   buffer), and
/// * every full-array fused broadcast run followed immediately by its
///   full-array fused write-back run — the shape every vecvec /
///   point-transform tile emits — becomes one [`MegaStep::Tile`],
///   executed as a single 64-lane kernel call per context word.
///
/// The cycle accounting is the wrapped schedule's, untouched: lowering
/// is a pure step-stream rewrite, so the megakernel reports exactly what
/// the interpreter, scheduled and fused tiers report, in both DMA modes.
/// Register-writing steps are kept in the stream (only their *reads* are
/// folded away), so the architectural register file, frame buffer,
/// context memory, RC-array planes and main memory all end bit-identical
/// to the other tiers — pinned by the conformance suite.
#[derive(Debug, Clone)]
pub struct Megakernel {
    schedule: BroadcastSchedule,
    steps: Vec<MegaStep>,
    tiles: usize,
    loads: usize,
}

impl Megakernel {
    /// Compile a program all the way to a megakernel. Returns `None`
    /// exactly when [`BroadcastSchedule::compile`] does (branchy
    /// programs); a program with no liftable loads or tiles still
    /// compiles — its megakernel just degenerates to the fused schedule.
    pub fn compile(program: &Program) -> Option<Megakernel> {
        let schedule = BroadcastSchedule::compile(program)?;
        // Constant propagation over the TinyRISC register file. `None`
        // means "not statically known"; r0 is architecturally zero. The
        // stream is straight-line (branches refused above), so a single
        // forward pass is exact.
        let mut regs: [Option<u32>; 16] = [None; 16];
        regs[0] = Some(0);
        let set_reg = |regs: &mut [Option<u32>; 16], rd: usize, v: Option<u32>| {
            if rd != 0 {
                regs[rd] = v;
            }
        };
        let sched_steps = schedule.steps();
        let mut steps = Vec::with_capacity(sched_steps.len());
        let mut tiles = 0usize;
        let mut loads = 0usize;
        let mut i = 0;
        while i < sched_steps.len() {
            // A full-array column broadcast run immediately followed by a
            // full-array column write-back run is one tile. The fusion
            // pass already proved every window in range (bus and
            // write-back spans walk `base + i·ARRAY_DIM`), so with
            // count == ARRAY_DIM the whole 64-element windows are valid.
            if i + 1 < sched_steps.len() {
                if let (
                    Step::FusedRun(FusedRun::Broadcasts {
                        mode,
                        plane,
                        cw,
                        line0,
                        set,
                        bus_a: Some(bus_a),
                        bus_b: Some(bus_b),
                        count,
                    }),
                    Step::FusedRun(FusedRun::WriteBacks {
                        mode: wb_mode,
                        line0: wb_line0,
                        set: wb_set,
                        bank: wb_bank,
                        addr0: wb_addr,
                        count: wb_count,
                    }),
                ) = (sched_steps[i], sched_steps[i + 1])
                {
                    if mode == BroadcastMode::Column
                        && wb_mode == BroadcastMode::Column
                        && line0 == 0
                        && wb_line0 == 0
                        && count == ARRAY_DIM
                        && wb_count == ARRAY_DIM
                    {
                        steps.push(MegaStep::Tile {
                            plane,
                            cw,
                            set,
                            bus_a,
                            bus_b,
                            wb_set,
                            wb_bank,
                            wb_addr,
                        });
                        tiles += 1;
                        i += 2;
                        continue;
                    }
                }
            }
            let step = sched_steps[i];
            i += 1;
            if let Step::Plain(instr) = step {
                match instr {
                    Instruction::Ldui { rd, imm } => {
                        set_reg(&mut regs, rd.index(), Some((imm as u32) << 16));
                    }
                    Instruction::Ldli { rd, imm } => {
                        let v = regs[rd.index()].map(|v| (v & 0xFFFF_0000) | imm as u32);
                        set_reg(&mut regs, rd.index(), v);
                    }
                    Instruction::Add { rd, rs, rt } => {
                        let v = match (regs[rs.index()], regs[rt.index()]) {
                            (Some(a), Some(b)) => Some(a.wrapping_add(b)),
                            _ => None,
                        };
                        set_reg(&mut regs, rd.index(), v);
                    }
                    Instruction::Sub { rd, rs, rt } => {
                        let v = match (regs[rs.index()], regs[rt.index()]) {
                            (Some(a), Some(b)) => Some(a.wrapping_sub(b)),
                            _ => None,
                        };
                        set_reg(&mut regs, rd.index(), v);
                    }
                    Instruction::Addi { rd, rs, imm } => {
                        let v = regs[rs.index()].map(|v| v.wrapping_add(imm as i32 as u32));
                        set_reg(&mut regs, rd.index(), v);
                    }
                    Instruction::Ldfb { rs, set, bank, words, fb_addr } => {
                        // Lift only when the executor's stack staging
                        // buffer covers the transfer (every mapping tile
                        // load is ≤ 32 words); larger or unknown-address
                        // transfers keep the ordinary path.
                        if let Some(v) = regs[rs.index()] {
                            if words <= 32 {
                                steps.push(MegaStep::Load {
                                    mem_addr: v as usize,
                                    set,
                                    bank,
                                    fb_addr,
                                    words,
                                });
                                loads += 1;
                                continue;
                            }
                        }
                    }
                    _ => {}
                }
            }
            steps.push(MegaStep::Step(step));
        }
        Some(Megakernel { schedule, steps, tiles, loads })
    }

    /// The lowered step stream (the megakernel executor's iteration path).
    pub(crate) fn steps(&self) -> &[MegaStep] {
        &self.steps
    }

    /// The wrapped schedule — the lowering's accounting and validation
    /// source of truth.
    pub(crate) fn schedule(&self) -> &BroadcastSchedule {
        &self.schedule
    }

    /// Number of whole-tile steps the lowering produced.
    pub fn fused_tiles(&self) -> usize {
        self.tiles
    }

    /// Number of `ldfb` transfers lifted to register-free [`MegaStep::Load`]s.
    pub fn lowered_loads(&self) -> usize {
        self.loads
    }

    /// See [`BroadcastSchedule::is_validated`].
    pub fn is_validated(&self) -> bool {
        self.schedule.is_validated()
    }

    /// The precomputed blocking-DMA execution report (the schedule's).
    pub fn report(&self) -> ExecutionReport {
        self.schedule.report()
    }

    /// The precomputed async-DMA execution report (the schedule's).
    pub fn async_report(&self) -> ExecutionReport {
        self.schedule.async_report()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::morphosys::tinyrisc::Reg;

    #[test]
    fn branchy_programs_do_not_compile() {
        let p = Program::new(vec![
            Instruction::Ldli { rd: Reg(1), imm: 1 },
            Instruction::Bnez { rs: Reg(1), target: 0 },
        ]);
        assert!(BroadcastSchedule::compile(&p).is_none());
        let p = Program::new(vec![Instruction::Jmp { target: 0 }]);
        assert!(BroadcastSchedule::compile(&p).is_none());
    }

    #[test]
    fn accounting_matches_the_paper_convention() {
        let p = Program::new(vec![
            Instruction::Ldui { rd: Reg(1), imm: 1 },
            Instruction::Ldfb { rs: Reg(1), set: Set::Zero, bank: Bank::A, words: 32, fb_addr: 0 },
            Instruction::Dbcdc { plane: 0, cw: 0, col: 0, set: Set::Zero, addr_a: 0, addr_b: 0 },
            Instruction::Stfb { rs: Reg(1), set: Set::Zero, bank: Bank::A, words: 32, fb_addr: 0 },
        ]);
        let s = BroadcastSchedule::compile(&p).unwrap();
        let r = s.report();
        // Issue slots: 1 + 32 + 1 + 32; the final stfb issues at cycle 34.
        assert_eq!(r.slots, 66);
        assert_eq!(r.cycles, 34);
        assert_eq!(r.executed, 4);
        assert_eq!(r.broadcasts, 1);
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn halt_truncates_the_schedule() {
        let p = Program::new(vec![
            Instruction::Ldli { rd: Reg(1), imm: 1 },
            Instruction::Halt,
            Instruction::Ldli { rd: Reg(1), imm: 9 }, // dead
        ]);
        let s = BroadcastSchedule::compile(&p).unwrap();
        assert_eq!(s.len(), 1);
        let r = s.report();
        assert_eq!(r.executed, 2);
        assert_eq!(r.cycles, 1);
        assert_eq!(r.slots, 2);
    }

    #[test]
    fn in_range_broadcasts_validate_for_unchecked_reads() {
        let p = Program::new(vec![
            Instruction::Dbcdc { plane: 1, cw: 15, col: 7, set: Set::One, addr_a: BANK_ELEMS - ARRAY_DIM, addr_b: 0 },
            Instruction::Sbcbr { plane: 0, cw: 0, row: 0, set: Set::Zero, bank: Bank::B, addr: 64 },
            Instruction::Wfbi { col: 3, set: Set::One, bank: Bank::A, addr: 0 },
        ]);
        assert!(BroadcastSchedule::compile(&p).unwrap().is_validated());
    }

    #[test]
    fn out_of_range_bus_addresses_fall_back_to_checked_execution() {
        // One element past the last whole operand-bus window: the
        // schedule still compiles (and must panic at run time exactly
        // like the interpreter), but the unchecked path is off.
        let p = Program::new(vec![Instruction::Dbcdc {
            plane: 0,
            cw: 0,
            col: 0,
            set: Set::Zero,
            addr_a: BANK_ELEMS - ARRAY_DIM + 1,
            addr_b: 0,
        }]);
        assert!(!BroadcastSchedule::compile(&p).unwrap().is_validated());
        let p = Program::new(vec![Instruction::Sbcb {
            plane: 2, // out-of-range context plane
            cw: 0,
            col: 0,
            set: Set::Zero,
            bank: Bank::A,
            addr: 0,
        }]);
        assert!(!BroadcastSchedule::compile(&p).unwrap().is_validated());
    }

    #[test]
    fn translation_and_scaling_shapes_fuse_their_runs() {
        use crate::mapping::{VecScalarMapping, VecVecMapping};
        use crate::morphosys::AluOp;
        // Translation: the 8 `ldli r4` + `dbcdc` pairs collapse into 8
        // hoisted register steps plus one fused broadcast run; the 8
        // `wfbi`s into one fused write-back run.
        let translation = VecVecMapping { n: 64, op: AluOp::Add }.compile();
        let fused = BroadcastSchedule::compile(&translation.program).unwrap();
        let unfused = BroadcastSchedule::compile_unfused(&translation.program).unwrap();
        assert_eq!(fused.fused_runs(), 2);
        assert_eq!(unfused.fused_runs(), 0);
        assert!(fused.len() < unfused.len(), "{} !< {}", fused.len(), unfused.len());
        // Fusion is a pure step rewrite: the precomputed accounting is
        // identical between the tiers.
        let (rf, ru) = (fused.report(), unfused.report());
        assert_eq!(
            (rf.cycles, rf.slots, rf.executed, rf.broadcasts),
            (ru.cycles, ru.slots, ru.executed, ru.broadcasts)
        );
        // Scaling: one fused sbcb run + one fused write-back run.
        let scaling = VecScalarMapping { n: 64, op: AluOp::Cmul, scalar: 5 }.compile();
        assert_eq!(BroadcastSchedule::compile(&scaling.program).unwrap().fused_runs(), 2);
    }

    #[test]
    fn tiled_vecvec_fuses_every_tile() {
        use crate::mapping::TiledVecVecMapping;
        use crate::morphosys::AluOp;
        for streamed in [false, true] {
            let m = TiledVecVecMapping { n: 256, op: AluOp::Add, streamed }.compile();
            let s = BroadcastSchedule::compile(&m.program).unwrap();
            // One broadcast run and one write-back run per 64-point tile.
            assert_eq!(s.fused_runs(), 2 * 4, "streamed={streamed}");
        }
    }

    #[test]
    fn non_contiguous_or_mixed_runs_refuse_fusion() {
        let dbcdc = |cw: usize, col: usize, addr: usize| Instruction::Dbcdc {
            plane: 0,
            cw,
            col,
            set: Set::Zero,
            addr_a: addr,
            addr_b: addr,
        };
        let fused_runs = |instrs: Vec<Instruction>| {
            BroadcastSchedule::compile(&Program::new(instrs)).unwrap().fused_runs()
        };
        // Bus addresses striding 16 instead of 8: not one contiguous span.
        assert_eq!(fused_runs(vec![dbcdc(0, 0, 0), dbcdc(0, 1, 16)]), 0);
        // Mixed context words.
        assert_eq!(fused_runs(vec![dbcdc(0, 0, 0), dbcdc(1, 1, 8)]), 0);
        // Non-ascending lines.
        assert_eq!(fused_runs(vec![dbcdc(0, 1, 0), dbcdc(0, 0, 8)]), 0);
        // A DMA step (not register-only) between the broadcasts pins them
        // apart — it reads the frame buffer the run writes through.
        assert_eq!(
            fused_runs(vec![
                dbcdc(0, 0, 0),
                Instruction::Stfb { rs: Reg(1), set: Set::Zero, bank: Bank::A, words: 4, fb_addr: 0 },
                dbcdc(0, 1, 8),
            ]),
            0
        );
        // Write-backs with an address gap.
        assert_eq!(
            fused_runs(vec![
                Instruction::Wfbi { col: 0, set: Set::One, bank: Bank::A, addr: 0 },
                Instruction::Wfbi { col: 1, set: Set::One, bank: Bank::A, addr: 24 },
            ]),
            0
        );
        // An out-of-range continuation closes the run at the boundary.
        assert_eq!(
            fused_runs(vec![
                dbcdc(0, 0, BANK_ELEMS - ARRAY_DIM),
                dbcdc(0, 1, BANK_ELEMS),
            ]),
            0
        );
        // Positive control: the same shapes with contiguous addresses fuse.
        assert_eq!(fused_runs(vec![dbcdc(0, 0, 0), dbcdc(0, 1, 8)]), 1);
        assert_eq!(
            fused_runs(vec![
                Instruction::Wfbi { col: 0, set: Set::One, bank: Bank::A, addr: 0 },
                Instruction::Wfbi { col: 1, set: Set::One, bank: Bank::A, addr: 8 },
            ]),
            1
        );
    }

    #[test]
    fn interleaved_register_steps_hoist_ahead_of_a_fused_run() {
        // The paper's Table 1 pattern: `ldli r4` between every `dbcdc`.
        let mut instrs = Vec::new();
        for c in 0..4usize {
            instrs.push(Instruction::Ldli { rd: Reg(4), imm: (8 * c) as u16 });
            instrs.push(Instruction::Dbcdc {
                plane: 0,
                cw: 0,
                col: c,
                set: Set::Zero,
                addr_a: 8 * c,
                addr_b: 8 * c,
            });
        }
        let s = BroadcastSchedule::compile(&Program::new(instrs)).unwrap();
        assert_eq!(s.fused_runs(), 1);
        // 4 hoisted ldli steps + 1 fused run.
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn empty_program_compiles_to_empty_schedule() {
        let s = BroadcastSchedule::compile(&Program::default()).unwrap();
        assert!(s.is_empty());
        let r = s.report();
        assert_eq!((r.cycles, r.slots, r.executed, r.broadcasts), (0, 0, 0, 0));
        let ra = s.async_report();
        assert_eq!((ra.cycles, ra.slots, ra.executed, ra.broadcasts), (0, 0, 0, 0));
    }

    #[test]
    fn megakernel_lowers_streamed_plans_to_tiles_and_loads() {
        use crate::mapping::StreamedTiledMapping;
        use crate::morphosys::AluOp;
        let m = StreamedTiledMapping { n: 256, op: AluOp::Add }.compile();
        let k = Megakernel::compile(&m.program).unwrap();
        // One whole-tile step per 64-point tile; two lifted DMA loads
        // (U and V) per tile — every address is formed by ldui/ldli, so
        // constant propagation resolves all of them.
        assert_eq!(k.fused_tiles(), 4);
        assert_eq!(k.lowered_loads(), 8);
        assert!(k.is_validated());
        // Lowering is a pure step rewrite: the accounting is the wrapped
        // schedule's, bit-identical in both DMA modes.
        let s = BroadcastSchedule::compile(&m.program).unwrap();
        let (rk, rs) = (k.report(), s.report());
        assert_eq!(
            (rk.cycles, rk.slots, rk.executed, rk.broadcasts),
            (rs.cycles, rs.slots, rs.executed, rs.broadcasts)
        );
        let (ak, asch) = (k.async_report(), s.async_report());
        assert_eq!((ak.cycles, ak.slots), (asch.cycles, asch.slots));
    }

    #[test]
    fn megakernel_refuses_branches_and_keeps_unknown_loads_plain() {
        // Branchy programs refuse to compile, same as the schedule tier.
        let p = Program::new(vec![Instruction::Jmp { target: 0 }]);
        assert!(Megakernel::compile(&p).is_none());
        // An ldfb whose address register was never statically formed
        // stays a plain step (executed through the register file).
        let p = Program::new(vec![Instruction::Ldfb {
            rs: Reg(1),
            set: Set::Zero,
            bank: Bank::A,
            words: 4,
            fb_addr: 0,
        }]);
        let k = Megakernel::compile(&p).unwrap();
        assert_eq!(k.lowered_loads(), 0);
        assert!(matches!(k.steps(), [MegaStep::Step(Step::Plain(_))]));
        // r0 is statically zero, so an r0-addressed load lifts.
        let p = Program::new(vec![Instruction::Ldfb {
            rs: Reg(0),
            set: Set::Zero,
            bank: Bank::A,
            words: 4,
            fb_addr: 0,
        }]);
        assert_eq!(Megakernel::compile(&p).unwrap().lowered_loads(), 1);
    }

    #[test]
    fn megakernel_lowers_point_transform_plans() {
        use crate::mapping::StreamedPointTransformMapping;
        for shift in [0u8, 2] {
            let m = StreamedPointTransformMapping {
                n: 128,
                m: [3, -1, 2, 4],
                t: [7, -9],
                shift,
            }
            .compile();
            let k = Megakernel::compile(&m.program).unwrap();
            // Two output banks per tile, each its own broadcast+write-back
            // pair — but only runs whose context word drives the full
            // bus/bus fast shape lower to tiles; at minimum the loads (U
            // and V per tile) always lift.
            assert_eq!(k.lowered_loads(), 4);
            assert!(k.is_validated(), "shift={shift}");
            let s = BroadcastSchedule::compile(&m.program).unwrap();
            assert_eq!(k.report().cycles, s.report().cycles);
        }
    }

    #[test]
    fn async_accounting_matches_the_interpreter_in_both_dma_modes() {
        // Compile once, compare against a fresh interpreter run in each
        // DMA mode — the precomputed reports must be bit-identical to
        // what `M1System::run` accounts, across representative mapping
        // shapes (single-tile, multi-broadcast, and the ping-ponged
        // streamed schedule whose overlap is the whole point).
        use crate::mapping::{StreamedTiledMapping, TiledVecVecMapping, VecScalarMapping, VecVecMapping};
        use crate::morphosys::{AluOp, M1System};
        let programs = [
            VecVecMapping { n: 64, op: AluOp::Add }.compile().program,
            VecScalarMapping { n: 64, op: AluOp::Cmul, scalar: 5 }.compile().program,
            TiledVecVecMapping { n: 256, op: AluOp::Add, streamed: false }.compile().program,
            StreamedTiledMapping { n: 256, op: AluOp::Add }.compile().program,
        ];
        for (i, program) in programs.iter().enumerate() {
            let s = BroadcastSchedule::compile(program).unwrap();
            for async_dma in [false, true] {
                let mut sys = M1System::with_dma_mode(async_dma);
                let ri = sys.run(program);
                let rs = s.report_for(async_dma);
                assert_eq!(ri.cycles, rs.cycles, "program {i} async={async_dma} cycles");
                assert_eq!(ri.slots, rs.slots, "program {i} async={async_dma} slots");
                assert_eq!(ri.executed, rs.executed, "program {i} async={async_dma} executed");
                assert_eq!(ri.broadcasts, rs.broadcasts, "program {i} async={async_dma} broadcasts");
            }
            // Overlap really is modelled: the multi-tile shapes finish
            // earlier under async DMA.
            if i >= 2 {
                assert!(
                    s.async_report().cycles < s.report().cycles,
                    "program {i}: async {} !< blocking {}",
                    s.async_report().cycles,
                    s.report().cycles
                );
            }
        }
    }
}
