//! The 8×8 RC array: context broadcast execution.
//!
//! MorphoSys executes SIMD-style: in **column broadcast** mode one context
//! word drives all eight cells of one column, each cell reading its own
//! element of the operand buses (bank A / bank B of the frame buffer). Row
//! broadcast is symmetric. Cells latch simultaneously; interconnect ports
//! observe the *previous* step's output registers.
//!
//! # Data layout (§Perf)
//!
//! Cell state is stored as **struct-of-arrays planes** (`out`, `regs`,
//! `acc`, `express`) rather than a `Vec` of cell structs, so the
//! broadcast hot loop touches only the planes it needs and the
//! interconnect borrows the `out`/`express` planes in place. A broadcast
//! is executed in two phases — *gather* (resolve all eight lanes' operands
//! against the current planes) then *commit* (latch all eight lanes) — so
//! neighbour reads observe previous-step values without materializing the
//! 64-cell `outputs()`/express snapshots the old engine copied on every
//! step. Operand sources are classified once per context word
//! ([`OperandPlan`]), with a branch-free fast path for the dominant
//! bus/bus and bus/immediate words.

use super::cell::{self, CellInputs, RcCell};
use super::context::ContextWord;
use super::interconnect::{Interconnect, OperandSource};

/// Edge length of the RC array (64 cells as an 8×8 matrix).
pub const ARRAY_DIM: usize = 8;

/// Context broadcast direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BroadcastMode {
    /// One context word drives a whole column; operand buses deliver one
    /// element per row.
    Column,
    /// One context word drives a whole row; operand buses deliver one
    /// element per column.
    Row,
}

/// Map a broadcast lane to its cell coordinates.
#[inline]
fn line_cell(mode: BroadcastMode, index: usize, lane: usize) -> (usize, usize) {
    match mode {
        BroadcastMode::Column => (lane, index),
        BroadcastMode::Row => (index, lane),
    }
}

/// The RC array, stored as parallel state planes (row-major 8×8).
#[derive(Debug, Clone)]
pub struct RcArray {
    /// Output registers — what the interconnect and `wfbi` observe.
    out: [[i16; ARRAY_DIM]; ARRAY_DIM],
    /// Per-cell register files (four 16-bit registers each).
    regs: [[[i16; 4]; ARRAY_DIM]; ARRAY_DIM],
    /// 32-bit multiply-accumulate registers.
    acc: [[i32; ARRAY_DIM]; ARRAY_DIM],
    /// Express-lane latches (driven when a context word has
    /// `express_write`).
    express: [[Option<i16>; ARRAY_DIM]; ARRAY_DIM],
}

impl Default for RcArray {
    fn default() -> Self {
        Self::new()
    }
}

impl RcArray {
    pub fn new() -> RcArray {
        RcArray {
            out: [[0; ARRAY_DIM]; ARRAY_DIM],
            regs: [[[0; 4]; ARRAY_DIM]; ARRAY_DIM],
            acc: [[0; ARRAY_DIM]; ARRAY_DIM],
            express: [[None; ARRAY_DIM]; ARRAY_DIM],
        }
    }

    /// Output register of one cell.
    pub fn out(&self, row: usize, col: usize) -> i16 {
        self.out[row][col]
    }

    /// Set one cell's output register (tests / state injection).
    pub fn set_out(&mut self, row: usize, col: usize, value: i16) {
        self.out[row][col] = value;
    }

    /// One register of one cell's register file.
    pub fn reg(&self, row: usize, col: usize, r: usize) -> i16 {
        self.regs[row][col][r & 3]
    }

    /// Set one register of one cell's register file.
    pub fn set_reg(&mut self, row: usize, col: usize, r: usize, value: i16) {
        self.regs[row][col][r & 3] = value;
    }

    /// One cell's accumulator.
    pub fn acc(&self, row: usize, col: usize) -> i32 {
        self.acc[row][col]
    }

    /// Set one cell's accumulator (tests / state injection — the restore
    /// counterpart of [`RcArray::acc`]).
    pub fn set_acc(&mut self, row: usize, col: usize, value: i32) {
        self.acc[row][col] = value;
    }

    /// One cell's express latch.
    pub fn express(&self, row: usize, col: usize) -> Option<i16> {
        self.express[row][col]
    }

    /// Set one cell's express latch (tests / state injection).
    pub fn set_express(&mut self, row: usize, col: usize, value: Option<i16>) {
        self.express[row][col] = value;
    }

    /// Assemble the AoS view of one cell (debug/inspection; the planes are
    /// the source of truth).
    pub fn cell(&self, row: usize, col: usize) -> RcCell {
        RcCell {
            regs: self.regs[row][col],
            out: self.out[row][col],
            acc: self.acc[row][col],
            express: self.express[row][col],
        }
    }

    /// Snapshot all output registers.
    pub fn outputs(&self) -> [[i16; ARRAY_DIM]; ARRAY_DIM] {
        self.out
    }

    /// Execute one broadcast step: the context word drives line `index`
    /// (a column in `Column` mode, a row in `Row` mode); `bus_a`/`bus_b`
    /// carry the eight operand-bus elements for that line.
    ///
    /// Two-phase (gather, then commit): every lane's operands resolve
    /// against the pre-step planes before any lane latches, preserving the
    /// previous-step neighbour visibility of the hardware without copying
    /// the planes.
    pub fn broadcast(
        &mut self,
        mode: BroadcastMode,
        index: usize,
        cw: &ContextWord,
        bus_a: &[i16; ARRAY_DIM],
        bus_b: &[i16; ARRAY_DIM],
    ) {
        assert!(index < ARRAY_DIM, "broadcast line {index} out of range");
        let mut ins = [CellInputs::default(); ARRAY_DIM];
        let plan = cw.operand_plan();
        if plan.is_bus_bus() {
            // Fast path: both operands stream straight off the buses.
            for ((slot, &a), &b) in ins.iter_mut().zip(bus_a).zip(bus_b) {
                *slot = CellInputs { a, b };
            }
        } else {
            let ic = Interconnect { outs: &self.out, express: &self.express };
            for (lane, slot) in ins.iter_mut().enumerate() {
                let (row, col) = line_cell(mode, index, lane);
                let a = match plan.a {
                    OperandSource::Bus => bus_a[lane],
                    OperandSource::Reg(r) => self.regs[row][col][r as usize],
                    OperandSource::Port(p) => ic.port(row, col, p),
                };
                let b = match plan.b {
                    OperandSource::Bus => bus_b[lane],
                    OperandSource::Reg(r) => self.regs[row][col][r as usize],
                    OperandSource::Port(p) => ic.port(row, col, p),
                };
                *slot = CellInputs { a, b };
            }
        }
        for (lane, &inputs) in ins.iter().enumerate() {
            let (row, col) = line_cell(mode, index, lane);
            cell::execute_step(
                cw,
                inputs,
                &mut self.out[row][col],
                &mut self.regs[row][col],
                &mut self.acc[row][col],
                &mut self.express[row][col],
            );
        }
    }

    /// Commit all eight lanes of a **bus/bus** broadcast with straight-line
    /// 8-wide lane code (§Perf, fused tile-kernel tier): gather the line's
    /// `out`/`acc` lanes, run the [`alu::eval8`] kernel once, apply the
    /// accumulate/NOP/reg-write/express commit rules across whole lanes,
    /// and scatter back. Bit-for-bit identical to [`RcArray::broadcast`]
    /// for any context word whose operand plan is bus/bus (the only plans
    /// the fused executor routes here — no interconnect or register
    /// sources, so lanes are fully independent); pinned by the
    /// `broadcast_lanes_is_bit_identical_to_broadcast` test and the fused
    /// conformance sweep.
    pub(crate) fn broadcast_lanes(
        &mut self,
        mode: BroadcastMode,
        index: usize,
        cw: &ContextWord,
        bus_a: &[i16; ARRAY_DIM],
        bus_b: &[i16; ARRAY_DIM],
    ) {
        assert!(index < ARRAY_DIM, "broadcast line {index} out of range");
        debug_assert!(
            cw.operand_plan().is_bus_bus(),
            "broadcast_lanes requires a bus/bus operand plan"
        );
        use super::alu::{self, AluOp};
        // Gather the only planes the ALU reads: previous outputs (kept for
        // the NOP rule) and accumulators.
        let (prev_out, mut acc): ([i16; ARRAY_DIM], [i32; ARRAY_DIM]) = match mode {
            BroadcastMode::Row => (self.out[index], self.acc[index]),
            BroadcastMode::Column => {
                let mut o = [0i16; ARRAY_DIM];
                let mut c = [0i32; ARRAY_DIM];
                for l in 0..ARRAY_DIM {
                    o[l] = self.out[l][index];
                    c[l] = self.acc[l][index];
                }
                (o, c)
            }
        };
        if cw.acc_reset {
            acc = [0; ARRAY_DIM];
        }
        let (mut res, mut new_acc) = alu::eval8(cw.op, bus_a, bus_b, cw.imm, &acc);
        if cw.acc_accumulate {
            // Fused accumulate, exactly as in `cell::execute_step`: the
            // pre-eval (post-reset) accumulator plus the ALU result drives
            // both the accumulator and the output.
            for l in 0..ARRAY_DIM {
                new_acc[l] = acc[l].wrapping_add(res[l] as i32);
                res[l] = new_acc[l] as i16;
            }
        }
        // NOP leaves the output register unchanged; the register-write
        // mask and express latch still observe the ALU result.
        let out = if cw.op == AluOp::Nop { prev_out } else { res };
        match mode {
            BroadcastMode::Row => {
                self.out[index] = out;
                self.acc[index] = new_acc;
            }
            BroadcastMode::Column => {
                for l in 0..ARRAY_DIM {
                    self.out[l][index] = out[l];
                    self.acc[l][index] = new_acc[l];
                }
            }
        }
        if cw.reg_write != 0 {
            for r in 0..4 {
                if cw.reg_write & (1 << r) != 0 {
                    match mode {
                        BroadcastMode::Row => {
                            for l in 0..ARRAY_DIM {
                                self.regs[index][l][r] = res[l];
                            }
                        }
                        BroadcastMode::Column => {
                            for l in 0..ARRAY_DIM {
                                self.regs[l][index][r] = res[l];
                            }
                        }
                    }
                }
            }
        }
        // The express latch is re-driven (or released) on every step.
        let xp: [Option<i16>; ARRAY_DIM] =
            if cw.express_write { res.map(Some) } else { [None; ARRAY_DIM] };
        match mode {
            BroadcastMode::Row => self.express[index] = xp,
            BroadcastMode::Column => {
                for l in 0..ARRAY_DIM {
                    self.express[l][index] = xp[l];
                }
            }
        }
    }

    /// Commit a whole tile's ALU results column-by-column (§Perf,
    /// megakernel tier): lane `l` of column `c` latches `res[c·8 + l]`,
    /// the express latch releases, and the accumulator resets or is left
    /// alone. Bit-for-bit what eight [`RcArray::broadcast_lanes`] column
    /// calls commit for a context word on the megakernel's fast-tile
    /// shape — bus/bus operands, `reg_write == 0`, no `express_write`, no
    /// `acc_accumulate`, an op that is neither `Nop` nor `Mula` (such ops
    /// overwrite the outputs and pass the accumulator through `eval8`
    /// unchanged) — pinned by `commit_tile_columns_matches_lane_broadcasts`.
    pub(crate) fn commit_tile_columns(
        &mut self,
        res: &[i16; ARRAY_DIM * ARRAY_DIM],
        acc_reset: bool,
    ) {
        for l in 0..ARRAY_DIM {
            for c in 0..ARRAY_DIM {
                self.out[l][c] = res[c * ARRAY_DIM + l];
                self.express[l][c] = None;
            }
        }
        if acc_reset {
            self.acc = [[0; ARRAY_DIM]; ARRAY_DIM];
        }
    }

    /// Read the eight output registers of a column (what `wfbi` writes
    /// back to the frame buffer).
    pub fn column_outputs(&self, col: usize) -> [i16; ARRAY_DIM] {
        let mut o = [0i16; ARRAY_DIM];
        for (r, v) in o.iter_mut().enumerate() {
            *v = self.out[r][col];
        }
        o
    }

    /// Read the eight output registers of a row.
    pub fn row_outputs(&self, row: usize) -> [i16; ARRAY_DIM] {
        self.out[row]
    }

    /// Reset every cell.
    pub fn reset(&mut self) {
        self.out = [[0; ARRAY_DIM]; ARRAY_DIM];
        self.regs = [[[0; 4]; ARRAY_DIM]; ARRAY_DIM];
        self.acc = [[0; ARRAY_DIM]; ARRAY_DIM];
        self.express = [[None; ARRAY_DIM]; ARRAY_DIM];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::morphosys::rc_array::alu::AluOp;
    use crate::morphosys::rc_array::context::{MuxASel, MuxBSel};

    #[test]
    fn column_broadcast_adds_buses_elementwise() {
        let mut arr = RcArray::new();
        let cw = ContextWord::two_port(AluOp::Add);
        let a = [1, 2, 3, 4, 5, 6, 7, 8];
        let b = [10, 20, 30, 40, 50, 60, 70, 80];
        arr.broadcast(BroadcastMode::Column, 3, &cw, &a, &b);
        assert_eq!(arr.column_outputs(3), [11, 22, 33, 44, 55, 66, 77, 88]);
        // Other columns untouched.
        assert_eq!(arr.column_outputs(0), [0; 8]);
    }

    #[test]
    fn row_broadcast_scales_by_immediate() {
        let mut arr = RcArray::new();
        let cw = ContextWord::immediate(AluOp::Cmul, 5);
        let a = [1, 2, 3, 4, 5, 6, 7, 8];
        arr.broadcast(BroadcastMode::Row, 6, &cw, &a, &[0; 8]);
        assert_eq!(arr.row_outputs(6), [5, 10, 15, 20, 25, 30, 35, 40]);
    }

    #[test]
    fn paper_figure7_layout_64_element_add() {
        // Figure 7: after 8 column broadcasts, cell (r, c) holds
        // U[c*8 + r] + V[c*8 + r].
        let u: Vec<i16> = (0..64).collect();
        let v: Vec<i16> = (0..64).map(|i| 100 + i).collect();
        let mut arr = RcArray::new();
        let cw = ContextWord::two_port(AluOp::Add);
        for col in 0..ARRAY_DIM {
            let mut a = [0i16; 8];
            let mut b = [0i16; 8];
            for r in 0..8 {
                a[r] = u[col * 8 + r];
                b[r] = v[col * 8 + r];
            }
            arr.broadcast(BroadcastMode::Column, col, &cw, &a, &b);
        }
        for r in 0..8 {
            for c in 0..8 {
                let i = c * 8 + r;
                assert_eq!(arr.out(r, c), u[i] + v[i], "cell ({r},{c})");
            }
        }
    }

    #[test]
    fn neighbour_ports_read_previous_step_snapshot() {
        let mut arr = RcArray::new();
        // Preload column 0 outputs with known values.
        for r in 0..ARRAY_DIM {
            arr.set_out(r, 0, (r as i16 + 1) * 10);
        }
        // Column 1 reads its West neighbour (column 0) through mux A.
        let mut cw = ContextWord::two_port(AluOp::PassA);
        cw.mux_a = MuxASel::West;
        arr.broadcast(BroadcastMode::Column, 1, &cw, &[0; 8], &[0; 8]);
        assert_eq!(arr.column_outputs(1), [10, 20, 30, 40, 50, 60, 70, 80]);
    }

    #[test]
    fn in_line_neighbour_reads_are_pre_step_not_in_step() {
        // All eight cells of a column shift from their North neighbour in
        // the same step: every lane must observe the *previous* outputs,
        // not a partially-updated plane (the gather/commit invariant).
        let mut arr = RcArray::new();
        for r in 0..ARRAY_DIM {
            arr.set_out(r, 5, r as i16 + 1);
        }
        let mut cw = ContextWord::two_port(AluOp::PassA);
        cw.mux_a = MuxASel::North;
        arr.broadcast(BroadcastMode::Column, 5, &cw, &[0; 8], &[0; 8]);
        // Toroidal shift down by one: row r now holds old row (r-1).
        assert_eq!(arr.column_outputs(5), [8, 1, 2, 3, 4, 5, 6, 7]);
    }

    #[test]
    fn register_file_sources_feed_mux() {
        let mut arr = RcArray::new();
        for r in 0..ARRAY_DIM {
            arr.set_reg(r, 2, 1, 7);
        }
        let mut cw = ContextWord::two_port(AluOp::Add);
        cw.mux_a = MuxASel::Reg(1);
        cw.mux_b = MuxBSel::Reg(1);
        arr.broadcast(BroadcastMode::Column, 2, &cw, &[0; 8], &[0; 8]);
        assert_eq!(arr.column_outputs(2), [14; 8]);
    }

    #[test]
    fn cell_view_assembles_all_planes() {
        let mut arr = RcArray::new();
        let mut cw = ContextWord::cmula(3, true);
        cw.reg_write = 0b0001;
        cw.express_write = true;
        arr.broadcast(BroadcastMode::Column, 4, &cw, &[2, 0, 0, 0, 0, 0, 0, 0], &[0; 8]);
        let cell = arr.cell(0, 4);
        assert_eq!(cell.out, 6);
        assert_eq!(cell.acc, 6);
        assert_eq!(cell.regs[0], 6);
        assert_eq!(cell.express, Some(6));
    }

    #[test]
    fn broadcast_lanes_is_bit_identical_to_broadcast() {
        // The fused 8-wide commit vs the reference per-lane path, across
        // every ALU op, both broadcast modes, random flags (acc reset /
        // accumulate, reg-write masks, express) and live pre-existing
        // array state.
        use crate::testkit::Rng;
        let mut rng = Rng::new(0xFA57);
        for case in 0..300 {
            let op = AluOp::from_bits(rng.below(16) as u8);
            let mut cw = if op.uses_immediate() {
                ContextWord::immediate(op, rng.range_i64(-128, 127) as i16)
            } else {
                ContextWord::two_port(op)
            };
            cw.reg_write = rng.below(16) as u8;
            cw.express_write = rng.below(2) == 0;
            cw.acc_reset = rng.below(2) == 0;
            cw.acc_accumulate = rng.below(4) == 0;
            let mode = if rng.below(2) == 0 { BroadcastMode::Column } else { BroadcastMode::Row };
            let index = rng.below(8) as usize;
            let mut a = [0i16; ARRAY_DIM];
            let mut b = [0i16; ARRAY_DIM];
            for l in 0..ARRAY_DIM {
                a[l] = rng.i16();
                b[l] = rng.i16();
            }
            // Random pre-existing state in both arrays.
            let mut reference = RcArray::new();
            for r in 0..ARRAY_DIM {
                for c in 0..ARRAY_DIM {
                    reference.set_out(r, c, rng.i16());
                    reference.acc[r][c] = rng.i16() as i32 * 17;
                    reference.set_reg(r, c, (r + c) & 3, rng.i16());
                    if rng.below(3) == 0 {
                        reference.express[r][c] = Some(rng.i16());
                    }
                }
            }
            let mut fused = reference.clone();
            reference.broadcast(mode, index, &cw, &a, &b);
            fused.broadcast_lanes(mode, index, &cw, &a, &b);
            for r in 0..ARRAY_DIM {
                for c in 0..ARRAY_DIM {
                    assert_eq!(
                        reference.cell(r, c),
                        fused.cell(r, c),
                        "case {case}: {op:?} {mode:?} line {index}, cell ({r},{c})"
                    );
                }
            }
        }
    }

    #[test]
    fn commit_tile_columns_matches_lane_broadcasts() {
        // The megakernel's whole-tile commit vs eight reference
        // `broadcast_lanes` column calls, for guard-shape context words
        // (bus/bus, no reg writes / express / accumulate, op ≠ Nop/Mula),
        // across random live pre-state and both acc_reset polarities.
        use crate::morphosys::rc_array::alu;
        use crate::testkit::Rng;
        let mut rng = Rng::new(0x7173);
        for case in 0..200 {
            let op = AluOp::from_bits(rng.below(16) as u8);
            if matches!(op, AluOp::Mula | AluOp::Nop) {
                continue;
            }
            let mut cw = if op.uses_immediate() {
                ContextWord::immediate(op, rng.range_i64(-128, 127) as i16)
            } else {
                ContextWord::two_port(op)
            };
            cw.acc_reset = rng.below(2) == 0;
            let mut a = [0i16; ARRAY_DIM * ARRAY_DIM];
            let mut b = [0i16; ARRAY_DIM * ARRAY_DIM];
            for l in 0..ARRAY_DIM * ARRAY_DIM {
                a[l] = rng.i16();
                b[l] = rng.i16();
            }
            let mut reference = RcArray::new();
            for r in 0..ARRAY_DIM {
                for c in 0..ARRAY_DIM {
                    reference.set_out(r, c, rng.i16());
                    reference.acc[r][c] = rng.i16() as i32 * 23;
                    reference.set_reg(r, c, (r + c) & 3, rng.i16());
                    if rng.below(3) == 0 {
                        reference.express[r][c] = Some(rng.i16());
                    }
                }
            }
            let mut tile = reference.clone();
            for c in 0..ARRAY_DIM {
                let ba: [i16; ARRAY_DIM] =
                    a[c * ARRAY_DIM..(c + 1) * ARRAY_DIM].try_into().unwrap();
                let bb: [i16; ARRAY_DIM] =
                    b[c * ARRAY_DIM..(c + 1) * ARRAY_DIM].try_into().unwrap();
                reference.broadcast_lanes(BroadcastMode::Column, c, &cw, &ba, &bb);
            }
            let res = alu::eval_tile(cw.op, &a, &b, cw.imm);
            tile.commit_tile_columns(&res, cw.acc_reset);
            for r in 0..ARRAY_DIM {
                for c in 0..ARRAY_DIM {
                    assert_eq!(
                        reference.cell(r, c),
                        tile.cell(r, c),
                        "case {case}: {op:?} acc_reset={} cell ({r},{c})",
                        cw.acc_reset
                    );
                }
            }
        }
    }

    #[test]
    fn reset_clears_all_state() {
        let mut arr = RcArray::new();
        arr.broadcast(
            BroadcastMode::Column,
            0,
            &ContextWord::two_port(AluOp::Add),
            &[1; 8],
            &[1; 8],
        );
        arr.reset();
        assert_eq!(arr.outputs(), [[0; 8]; 8]);
    }
}
