//! The 8×8 RC array: context broadcast execution.
//!
//! MorphoSys executes SIMD-style: in **column broadcast** mode one context
//! word drives all eight cells of one column, each cell reading its own
//! element of the operand buses (bank A / bank B of the frame buffer). Row
//! broadcast is symmetric. Cells latch simultaneously; interconnect ports
//! observe the *previous* step's output registers.

use super::cell::{CellInputs, RcCell};
use super::context::{ContextWord, MuxASel, MuxBSel};
use super::interconnect::Interconnect;

/// Edge length of the RC array (64 cells as an 8×8 matrix).
pub const ARRAY_DIM: usize = 8;

/// Context broadcast direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BroadcastMode {
    /// One context word drives a whole column; operand buses deliver one
    /// element per row.
    Column,
    /// One context word drives a whole row; operand buses deliver one
    /// element per column.
    Row,
}

/// The RC array.
#[derive(Debug, Clone)]
pub struct RcArray {
    cells: Vec<RcCell>, // row-major 8×8
}

impl Default for RcArray {
    fn default() -> Self {
        Self::new()
    }
}

impl RcArray {
    pub fn new() -> RcArray {
        RcArray { cells: vec![RcCell::new(); ARRAY_DIM * ARRAY_DIM] }
    }

    pub fn cell(&self, row: usize, col: usize) -> &RcCell {
        &self.cells[row * ARRAY_DIM + col]
    }

    pub fn cell_mut(&mut self, row: usize, col: usize) -> &mut RcCell {
        &mut self.cells[row * ARRAY_DIM + col]
    }

    /// Snapshot all output registers.
    pub fn outputs(&self) -> [[i16; ARRAY_DIM]; ARRAY_DIM] {
        let mut o = [[0i16; ARRAY_DIM]; ARRAY_DIM];
        for r in 0..ARRAY_DIM {
            for c in 0..ARRAY_DIM {
                o[r][c] = self.cell(r, c).out;
            }
        }
        o
    }

    fn express_latches(&self) -> [[Option<i16>; ARRAY_DIM]; ARRAY_DIM] {
        let mut x = [[None; ARRAY_DIM]; ARRAY_DIM];
        for r in 0..ARRAY_DIM {
            for c in 0..ARRAY_DIM {
                x[r][c] = self.cell(r, c).express;
            }
        }
        x
    }

    /// Execute one broadcast step: the context word drives line `index`
    /// (a column in `Column` mode, a row in `Row` mode); `bus_a`/`bus_b`
    /// carry the eight operand-bus elements for that line.
    pub fn broadcast(
        &mut self,
        mode: BroadcastMode,
        index: usize,
        cw: &ContextWord,
        bus_a: &[i16; ARRAY_DIM],
        bus_b: &[i16; ARRAY_DIM],
    ) {
        assert!(index < ARRAY_DIM, "broadcast line {index} out of range");
        let outs = self.outputs();
        let express = self.express_latches();
        for lane in 0..ARRAY_DIM {
            let (row, col) = match mode {
                BroadcastMode::Column => (lane, index),
                BroadcastMode::Row => (index, lane),
            };
            let ic = Interconnect { outs: &outs, express: &express };
            let cell = self.cell(row, col);
            let a = match cw.mux_a {
                MuxASel::OperandBusA => bus_a[lane],
                MuxASel::Reg(r) => cell.regs[r as usize & 3],
                sel => ic.mux_a(row, col, sel).expect("interconnect source"),
            };
            let b = match cw.mux_b {
                MuxBSel::OperandBusB => bus_b[lane],
                MuxBSel::Reg(r) => cell.regs[r as usize & 3],
                sel => ic.mux_b(row, col, sel).expect("interconnect source"),
            };
            self.cell_mut(row, col).execute(cw, CellInputs { a, b });
        }
    }

    /// Read the eight output registers of a column (what `wfbi` writes
    /// back to the frame buffer).
    pub fn column_outputs(&self, col: usize) -> [i16; ARRAY_DIM] {
        let mut o = [0i16; ARRAY_DIM];
        for (r, v) in o.iter_mut().enumerate() {
            *v = self.cell(r, col).out;
        }
        o
    }

    /// Read the eight output registers of a row.
    pub fn row_outputs(&self, row: usize) -> [i16; ARRAY_DIM] {
        let mut o = [0i16; ARRAY_DIM];
        for (c, v) in o.iter_mut().enumerate() {
            *v = self.cell(row, c).out;
        }
        o
    }

    /// Reset every cell.
    pub fn reset(&mut self) {
        for cell in &mut self.cells {
            cell.reset();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::morphosys::rc_array::alu::AluOp;

    #[test]
    fn column_broadcast_adds_buses_elementwise() {
        let mut arr = RcArray::new();
        let cw = ContextWord::two_port(AluOp::Add);
        let a = [1, 2, 3, 4, 5, 6, 7, 8];
        let b = [10, 20, 30, 40, 50, 60, 70, 80];
        arr.broadcast(BroadcastMode::Column, 3, &cw, &a, &b);
        assert_eq!(arr.column_outputs(3), [11, 22, 33, 44, 55, 66, 77, 88]);
        // Other columns untouched.
        assert_eq!(arr.column_outputs(0), [0; 8]);
    }

    #[test]
    fn row_broadcast_scales_by_immediate() {
        let mut arr = RcArray::new();
        let cw = ContextWord::immediate(AluOp::Cmul, 5);
        let a = [1, 2, 3, 4, 5, 6, 7, 8];
        arr.broadcast(BroadcastMode::Row, 6, &cw, &a, &[0; 8]);
        assert_eq!(arr.row_outputs(6), [5, 10, 15, 20, 25, 30, 35, 40]);
    }

    #[test]
    fn paper_figure7_layout_64_element_add() {
        // Figure 7: after 8 column broadcasts, cell (r, c) holds
        // U[c*8 + r] + V[c*8 + r].
        let u: Vec<i16> = (0..64).collect();
        let v: Vec<i16> = (0..64).map(|i| 100 + i).collect();
        let mut arr = RcArray::new();
        let cw = ContextWord::two_port(AluOp::Add);
        for col in 0..ARRAY_DIM {
            let mut a = [0i16; 8];
            let mut b = [0i16; 8];
            for r in 0..8 {
                a[r] = u[col * 8 + r];
                b[r] = v[col * 8 + r];
            }
            arr.broadcast(BroadcastMode::Column, col, &cw, &a, &b);
        }
        for r in 0..8 {
            for c in 0..8 {
                let i = c * 8 + r;
                assert_eq!(arr.cell(r, c).out, u[i] + v[i], "cell ({r},{c})");
            }
        }
    }

    #[test]
    fn neighbour_ports_read_previous_step_snapshot() {
        let mut arr = RcArray::new();
        // Preload column 0 outputs with known values.
        for r in 0..ARRAY_DIM {
            arr.cell_mut(r, 0).out = (r as i16 + 1) * 10;
        }
        // Column 1 reads its West neighbour (column 0) through mux A.
        let mut cw = ContextWord::two_port(AluOp::PassA);
        cw.mux_a = MuxASel::West;
        arr.broadcast(BroadcastMode::Column, 1, &cw, &[0; 8], &[0; 8]);
        assert_eq!(arr.column_outputs(1), [10, 20, 30, 40, 50, 60, 70, 80]);
    }

    #[test]
    fn register_file_sources_feed_mux() {
        let mut arr = RcArray::new();
        for r in 0..ARRAY_DIM {
            arr.cell_mut(r, 2).regs[1] = 7;
        }
        let mut cw = ContextWord::two_port(AluOp::Add);
        cw.mux_a = MuxASel::Reg(1);
        cw.mux_b = MuxBSel::Reg(1);
        arr.broadcast(BroadcastMode::Column, 2, &cw, &[0; 8], &[0; 8]);
        assert_eq!(arr.column_outputs(2), [14; 8]);
    }

    #[test]
    fn reset_clears_all_state() {
        let mut arr = RcArray::new();
        arr.broadcast(
            BroadcastMode::Column,
            0,
            &ContextWord::two_port(AluOp::Add),
            &[1; 8],
            &[1; 8],
        );
        arr.reset();
        assert_eq!(arr.outputs(), [[0; 8]; 8]);
    }
}
