//! The 32-bit context word — the configuration unit of the RC array.
//!
//! A context word configures every cell of one row/column: ALU opcode,
//! input-multiplexer selects, an immediate operand field, result
//! destination, and accumulator control. The layout is reverse-engineered
//! to be consistent with the two words published in the paper:
//!
//! * `0000F400` — "Out = A + B" for the translation routine (Table 1):
//!   opcode `F` = ADD, mux A = operand bus A, mux B = operand bus B.
//! * `00009005` — "Out = c × A" with `c = 5` for the scaling routine
//!   (Table 2): opcode `9` = CMUL, mux A = operand bus A, `imm = 5`.
//!
//! ```text
//!  31         22  21  20   19..16   15..12   11..8    7..4     3..0
//! ┌─────────────┬───┬───┬────────┬────────┬───────┬────────┬────────┐
//! │  reserved   │ACC│XPR│ regwr  │ opcode │ mux A │ mux B  │ dest   │  two-port ops
//! │  reserved   │ACC│XPR│ regwr  │ opcode │ mux A │    immediate    │  immediate ops
//! └─────────────┴───┴───┴────────┴────────┴───────┴────────┴────────┘
//! ```
//!
//! Immediate-class opcodes (CMUL/CADD/CSUB/SHL/SHR) repurpose bits `[7:0]`
//! as an 8-bit immediate and use a compact mux-A encoding in which `0`
//! selects the operand bus (hence `00009005` reads the operand bus).

use super::alu::AluOp;

/// Mux A source select for two-port operations (bits `[11:8]`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MuxASel {
    /// Nearest-neighbour inputs in the 2-D mesh.
    North,
    East,
    South,
    West,
    /// The operand data bus, bank A (frame-buffer broadcast).
    OperandBusA,
    /// Intra-quadrant row lane.
    RowQuad,
    /// Intra-quadrant column lane.
    ColQuad,
    /// Inter-quadrant express lane.
    Express,
    /// Internal register file, r0–r3.
    Reg(u8),
}

impl MuxASel {
    pub fn bits(self) -> u8 {
        match self {
            MuxASel::North => 0,
            MuxASel::East => 1,
            MuxASel::South => 2,
            MuxASel::West => 3,
            MuxASel::OperandBusA => 4,
            MuxASel::RowQuad => 5,
            MuxASel::ColQuad => 6,
            MuxASel::Express => 7,
            MuxASel::Reg(r) => 8 + (r & 3),
        }
    }

    pub fn from_bits(bits: u8) -> MuxASel {
        match bits & 0xF {
            0 => MuxASel::North,
            1 => MuxASel::East,
            2 => MuxASel::South,
            3 => MuxASel::West,
            4 => MuxASel::OperandBusA,
            5 => MuxASel::RowQuad,
            6 => MuxASel::ColQuad,
            7 => MuxASel::Express,
            b => MuxASel::Reg((b - 8) & 3),
        }
    }

    /// Compact encoding used by immediate-class context words, where `0`
    /// selects the operand bus (the common case).
    pub fn bits_compact(self) -> u8 {
        match self {
            MuxASel::OperandBusA => 0,
            MuxASel::North => 1,
            MuxASel::East => 2,
            MuxASel::South => 3,
            MuxASel::West => 4,
            MuxASel::RowQuad => 5,
            MuxASel::ColQuad => 6,
            MuxASel::Express => 7,
            MuxASel::Reg(r) => 8 + (r & 3),
        }
    }

    pub fn from_bits_compact(bits: u8) -> MuxASel {
        match bits & 0xF {
            0 => MuxASel::OperandBusA,
            1 => MuxASel::North,
            2 => MuxASel::East,
            3 => MuxASel::South,
            4 => MuxASel::West,
            5 => MuxASel::RowQuad,
            6 => MuxASel::ColQuad,
            7 => MuxASel::Express,
            b => MuxASel::Reg((b - 8) & 3),
        }
    }
}

/// Mux B source select (bits `[7:4]` of two-port context words). Mux B has
/// fewer sources than mux A (paper Figure 3: three nearest neighbours, the
/// operand bus, the register file).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MuxBSel {
    /// The operand data bus, bank B.
    OperandBusB,
    North,
    East,
    West,
    /// Internal register file, r0–r3.
    Reg(u8),
}

impl MuxBSel {
    pub fn bits(self) -> u8 {
        match self {
            MuxBSel::OperandBusB => 0,
            MuxBSel::North => 1,
            MuxBSel::East => 2,
            MuxBSel::West => 3,
            MuxBSel::Reg(r) => 4 + (r & 3),
        }
    }

    pub fn from_bits(bits: u8) -> MuxBSel {
        match bits & 0x7 {
            0 => MuxBSel::OperandBusB,
            1 => MuxBSel::North,
            2 => MuxBSel::East,
            3 => MuxBSel::West,
            b => MuxBSel::Reg((b - 4) & 3),
        }
    }
}

/// A decoded context word.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ContextWord {
    pub op: AluOp,
    pub mux_a: MuxASel,
    /// Ignored (forced to `OperandBusB`) for immediate-class ops.
    pub mux_b: MuxBSel,
    /// Sign-extended 8-bit immediate; meaningful for immediate-class ops.
    pub imm: i16,
    /// Register-file write mask, one bit per r0–r3 (bits `[19:16]`).
    pub reg_write: u8,
    /// Drive the result onto the express lane (bit 20).
    pub express_write: bool,
    /// Clear the accumulator before executing (bit 21); used by the first
    /// MULA step of a dot product.
    pub acc_reset: bool,
    /// Fused accumulate (bit 22): after the ALU op, `ACC += result` and
    /// the accumulator value is latched to the output register. Combined
    /// with CMUL this gives the constant-multiply-accumulate step the
    /// §5.3 matrix-multiplication mapping is built on.
    pub acc_accumulate: bool,
    /// Destination select, bits `[3:0]` of two-port words (0 = output
    /// register only).
    pub dest: u8,
}

impl ContextWord {
    /// The paper's Table 1 word: `OUT = A + B` from the two operand buses.
    pub const ADD_AB: u32 = 0x0000_F400;

    /// Decode a raw 32-bit context word.
    pub fn decode(raw: u32) -> ContextWord {
        let op = AluOp::from_bits(((raw >> 12) & 0xF) as u8);
        let reg_write = ((raw >> 16) & 0xF) as u8;
        let express_write = raw & (1 << 20) != 0;
        let acc_reset = raw & (1 << 21) != 0;
        let acc_accumulate = raw & (1 << 22) != 0;
        if op.uses_immediate() {
            ContextWord {
                op,
                mux_a: MuxASel::from_bits_compact(((raw >> 8) & 0xF) as u8),
                mux_b: MuxBSel::OperandBusB,
                imm: (raw & 0xFF) as u8 as i8 as i16,
                reg_write,
                express_write,
                acc_reset,
                acc_accumulate,
                dest: 0,
            }
        } else {
            ContextWord {
                op,
                mux_a: MuxASel::from_bits(((raw >> 8) & 0xF) as u8),
                mux_b: MuxBSel::from_bits(((raw >> 4) & 0xF) as u8),
                imm: 0,
                reg_write,
                express_write,
                acc_reset,
                acc_accumulate,
                dest: (raw & 0xF) as u8,
            }
        }
    }

    /// Encode back to the raw 32-bit form.
    pub fn encode(&self) -> u32 {
        let mut raw = (self.op.bits() as u32) << 12;
        raw |= (self.reg_write as u32 & 0xF) << 16;
        if self.express_write {
            raw |= 1 << 20;
        }
        if self.acc_reset {
            raw |= 1 << 21;
        }
        if self.acc_accumulate {
            raw |= 1 << 22;
        }
        if self.op.uses_immediate() {
            raw |= (self.mux_a.bits_compact() as u32) << 8;
            raw |= self.imm as u8 as u32;
        } else {
            raw |= (self.mux_a.bits() as u32) << 8;
            raw |= (self.mux_b.bits() as u32) << 4;
            raw |= self.dest as u32 & 0xF;
        }
        raw
    }

    /// Classify this word's operand sources once (hoisted out of the
    /// per-lane broadcast loop — see
    /// [`super::interconnect::OperandPlan`]).
    pub fn operand_plan(&self) -> super::interconnect::OperandPlan {
        super::interconnect::OperandPlan::of(self)
    }

    /// Two-port op reading both operand buses (the vector-vector pattern).
    pub fn two_port(op: AluOp) -> ContextWord {
        ContextWord {
            op,
            mux_a: MuxASel::OperandBusA,
            mux_b: MuxBSel::OperandBusB,
            imm: 0,
            reg_write: 0,
            express_write: false,
            acc_reset: false,
            acc_accumulate: false,
            dest: 0,
        }
    }

    /// Immediate op on the operand bus (the vector-scalar pattern).
    pub fn immediate(op: AluOp, imm: i16) -> ContextWord {
        debug_assert!(op.uses_immediate(), "{op:?} takes no immediate");
        debug_assert!(
            (-128..=127).contains(&imm),
            "context immediate field is 8 bits, got {imm}"
        );
        ContextWord {
            op,
            mux_a: MuxASel::OperandBusA,
            mux_b: MuxBSel::OperandBusB,
            imm,
            reg_write: 0,
            express_write: false,
            acc_reset: false,
            acc_accumulate: false,
            dest: 0,
        }
    }

    /// Constant-multiply-accumulate (CMUL + fused accumulate): the
    /// building block of the §5.3 matrix-multiplication mapping.
    /// `first` resets the accumulator.
    pub fn cmula(imm: i16, first: bool) -> ContextWord {
        let mut cw = ContextWord::immediate(AluOp::Cmul, imm);
        cw.acc_accumulate = true;
        cw.acc_reset = first;
        cw
    }

    /// Multiply-accumulate step of a dot product; `first` resets the
    /// accumulator.
    pub fn mula(first: bool) -> ContextWord {
        let mut cw = ContextWord::two_port(AluOp::Mula);
        cw.acc_reset = first;
        cw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_translation_word_decodes_to_add_ab() {
        let cw = ContextWord::decode(0x0000_F400);
        assert_eq!(cw.op, AluOp::Add);
        assert_eq!(cw.mux_a, MuxASel::OperandBusA);
        assert_eq!(cw.mux_b, MuxBSel::OperandBusB);
        assert_eq!(cw.dest, 0);
    }

    #[test]
    fn paper_scaling_word_decodes_to_cmul_5() {
        let cw = ContextWord::decode(0x0000_9005);
        assert_eq!(cw.op, AluOp::Cmul);
        assert_eq!(cw.mux_a, MuxASel::OperandBusA);
        assert_eq!(cw.imm, 5);
    }

    #[test]
    fn encode_reproduces_paper_words() {
        assert_eq!(ContextWord::two_port(AluOp::Add).encode(), 0x0000_F400);
        assert_eq!(
            ContextWord::immediate(AluOp::Cmul, 5).encode(),
            0x0000_9005
        );
    }

    #[test]
    fn roundtrip_two_port_words() {
        for op in [AluOp::Add, AluOp::Sub, AluOp::Mul, AluOp::And, AluOp::Mula] {
            let mut cw = ContextWord::two_port(op);
            cw.reg_write = 0b0101;
            cw.express_write = true;
            cw.dest = 3;
            assert_eq!(ContextWord::decode(cw.encode()), cw);
        }
    }

    #[test]
    fn roundtrip_immediate_words() {
        for imm in [-128i16, -1, 0, 1, 5, 127] {
            let mut cw = ContextWord::immediate(AluOp::Cadd, imm);
            cw.acc_reset = true;
            assert_eq!(ContextWord::decode(cw.encode()), cw);
        }
    }

    #[test]
    fn negative_immediates_sign_extend() {
        let raw = ContextWord::immediate(AluOp::Csub, -3).encode();
        assert_eq!(ContextWord::decode(raw).imm, -3);
    }

    #[test]
    fn mux_selects_roundtrip() {
        for b in 0..12u8 {
            assert_eq!(MuxASel::from_bits(b).bits(), b);
            assert_eq!(MuxASel::from_bits_compact(b).bits_compact(), b);
        }
        for b in 0..8u8 {
            assert_eq!(MuxBSel::from_bits(b).bits(), b);
        }
    }

    #[test]
    fn mula_helper_sets_acc_reset_on_first_step() {
        assert!(ContextWord::mula(true).acc_reset);
        assert!(!ContextWord::mula(false).acc_reset);
    }
}
