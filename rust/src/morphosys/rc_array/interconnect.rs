//! The three-level RC-array interconnect (paper Figure 2).
//!
//! 1. **Mesh** — nearest-neighbour connectivity in the 8×8 grid. Like the
//!    real M1 the mesh wraps toroidally at the array edge.
//! 2. **Intra-quadrant** — a cell can read any other cell in its row or
//!    column *within its 4×4 quadrant*. Context words carry no source
//!    index in our compact encoding, so the lane carries the quadrant
//!    row/column *leader* (the cell at the quadrant-base row/column) — the
//!    pattern all our mappings use.
//! 3. **Express lanes** — inter-quadrant buses carrying one cell's output
//!    per quadrant row/column to the adjacent quadrant. The value is the
//!    express latch of the same row in the horizontally adjacent quadrant
//!    (falling back to its output register when no cell latched the lane).
//!
//! The interconnect is purely combinational over the previous-step output
//! registers, which models the real array: all cells read their
//! neighbours' registered outputs, then latch simultaneously. The
//! executing array guarantees this by resolving every lane's operands
//! before committing any lane (gather-then-commit), so the planes can be
//! borrowed in place instead of copied per step.

use super::array::ARRAY_DIM;
use super::context::{ContextWord, MuxASel, MuxBSel};

/// Quadrant edge length (the RC array is 2×2 quadrants of 4×4 cells).
pub const QUAD_DIM: usize = 4;

/// A named interconnect source, unifying mux A and mux B selects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Port {
    North,
    East,
    South,
    West,
    RowQuad,
    ColQuad,
    Express,
}

/// One operand's resolved source class — the per-context-word
/// classification hoisted out of the per-lane broadcast loop (§Perf).
/// `Bus` is the operand data bus (bank A for mux A, bank B for mux B),
/// `Reg` the cell-local register file, `Port` an interconnect source.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OperandSource {
    Bus,
    Reg(u8),
    Port(Port),
}

/// The operand-source plan of one context word: where each of the eight
/// lanes of a broadcast reads its A and B inputs from. Classified once
/// per broadcast step so the lane loop never re-matches the mux selects,
/// with a branch-free fast path for the dominant bus/bus and
/// bus/immediate words (both classify as `(Bus, Bus)`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OperandPlan {
    pub a: OperandSource,
    pub b: OperandSource,
}

impl OperandPlan {
    /// Classify a context word's mux selects.
    pub fn of(cw: &ContextWord) -> OperandPlan {
        let a = match cw.mux_a {
            MuxASel::OperandBusA => OperandSource::Bus,
            MuxASel::Reg(r) => OperandSource::Reg(r & 3),
            MuxASel::North => OperandSource::Port(Port::North),
            MuxASel::East => OperandSource::Port(Port::East),
            MuxASel::South => OperandSource::Port(Port::South),
            MuxASel::West => OperandSource::Port(Port::West),
            MuxASel::RowQuad => OperandSource::Port(Port::RowQuad),
            MuxASel::ColQuad => OperandSource::Port(Port::ColQuad),
            MuxASel::Express => OperandSource::Port(Port::Express),
        };
        let b = match cw.mux_b {
            MuxBSel::OperandBusB => OperandSource::Bus,
            MuxBSel::Reg(r) => OperandSource::Reg(r & 3),
            MuxBSel::North => OperandSource::Port(Port::North),
            MuxBSel::East => OperandSource::Port(Port::East),
            MuxBSel::West => OperandSource::Port(Port::West),
        };
        OperandPlan { a, b }
    }

    /// The fast path: both operands read straight off the operand buses
    /// (every two-port bus/bus word and every immediate-class word).
    pub fn is_bus_bus(&self) -> bool {
        self.a == OperandSource::Bus && self.b == OperandSource::Bus
    }
}

/// View of the array's output/express planes for one execution step. All
/// reads of a broadcast resolve against these planes *before* any lane
/// commits, which models the real array: cells read their neighbours'
/// registered (previous-step) outputs, then latch simultaneously. Since
/// the planes live directly in `RcArray` this borrows them in place — no
/// per-step snapshot copies.
pub struct Interconnect<'a> {
    pub outs: &'a [[i16; ARRAY_DIM]; ARRAY_DIM],
    pub express: &'a [[Option<i16>; ARRAY_DIM]; ARRAY_DIM],
}

impl<'a> Interconnect<'a> {
    /// Resolve a mesh/lane port for the cell at `(row, col)`.
    pub fn port(&self, row: usize, col: usize, port: Port) -> i16 {
        let d = ARRAY_DIM;
        match port {
            Port::North => self.outs[(row + d - 1) % d][col],
            Port::South => self.outs[(row + 1) % d][col],
            Port::West => self.outs[row][(col + d - 1) % d],
            Port::East => self.outs[row][(col + 1) % d],
            // Quadrant row/column leader (quadrant-base index).
            Port::RowQuad => self.outs[row][col / QUAD_DIM * QUAD_DIM],
            Port::ColQuad => self.outs[row / QUAD_DIM * QUAD_DIM][col],
            Port::Express => {
                // Same row, horizontally adjacent quadrant; the lane
                // carries that quadrant's row leader (express latch if
                // driven, output register otherwise).
                let adj_base = (col / QUAD_DIM ^ 1) * QUAD_DIM;
                self.express[row][adj_base].unwrap_or(self.outs[row][adj_base])
            }
        }
    }

    /// Resolve a mux A select. Operand-bus and register selects are
    /// resolved by the caller (they are not interconnect sources).
    pub fn mux_a(&self, row: usize, col: usize, sel: MuxASel) -> Option<i16> {
        let port = match sel {
            MuxASel::North => Port::North,
            MuxASel::East => Port::East,
            MuxASel::South => Port::South,
            MuxASel::West => Port::West,
            MuxASel::RowQuad => Port::RowQuad,
            MuxASel::ColQuad => Port::ColQuad,
            MuxASel::Express => Port::Express,
            MuxASel::OperandBusA | MuxASel::Reg(_) => return None,
        };
        Some(self.port(row, col, port))
    }

    /// Resolve a mux B select (mux B reaches three neighbours only).
    pub fn mux_b(&self, row: usize, col: usize, sel: MuxBSel) -> Option<i16> {
        let port = match sel {
            MuxBSel::North => Port::North,
            MuxBSel::East => Port::East,
            MuxBSel::West => Port::West,
            MuxBSel::OperandBusB | MuxBSel::Reg(_) => return None,
        };
        Some(self.port(row, col, port))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid() -> [[i16; ARRAY_DIM]; ARRAY_DIM] {
        let mut g = [[0i16; ARRAY_DIM]; ARRAY_DIM];
        for (r, row) in g.iter_mut().enumerate() {
            for (c, v) in row.iter_mut().enumerate() {
                *v = (r * ARRAY_DIM + c) as i16;
            }
        }
        g
    }

    fn no_express() -> [[Option<i16>; ARRAY_DIM]; ARRAY_DIM] {
        [[None; ARRAY_DIM]; ARRAY_DIM]
    }

    #[test]
    fn mesh_neighbours() {
        let outs = grid();
        let xp = no_express();
        let ic = Interconnect { outs: &outs, express: &xp };
        assert_eq!(ic.port(1, 1, Port::North), outs[0][1]);
        assert_eq!(ic.port(1, 1, Port::South), outs[2][1]);
        assert_eq!(ic.port(1, 1, Port::West), outs[1][0]);
        assert_eq!(ic.port(1, 1, Port::East), outs[1][2]);
    }

    #[test]
    fn mesh_wraps_toroidally() {
        let outs = grid();
        let xp = no_express();
        let ic = Interconnect { outs: &outs, express: &xp };
        assert_eq!(ic.port(0, 0, Port::North), outs[7][0]);
        assert_eq!(ic.port(0, 0, Port::West), outs[0][7]);
        assert_eq!(ic.port(7, 7, Port::South), outs[0][7]);
        assert_eq!(ic.port(7, 7, Port::East), outs[7][0]);
    }

    #[test]
    fn quadrant_lanes_carry_leaders() {
        let outs = grid();
        let xp = no_express();
        let ic = Interconnect { outs: &outs, express: &xp };
        // Cell (2, 6) is in the right quadrant: row leader is column 4.
        assert_eq!(ic.port(2, 6, Port::RowQuad), outs[2][4]);
        // Cell (6, 2) is in the bottom quadrant: column leader is row 4.
        assert_eq!(ic.port(6, 2, Port::ColQuad), outs[4][2]);
    }

    #[test]
    fn express_lane_reads_adjacent_quadrant() {
        let outs = grid();
        let mut xp = no_express();
        xp[3][4] = Some(-77); // right-quadrant row-3 leader drives the lane
        let ic = Interconnect { outs: &outs, express: &xp };
        // A left-quadrant cell in row 3 sees the latched value.
        assert_eq!(ic.port(3, 1, Port::Express), -77);
        // Without a latch it falls back to the leader's output register.
        let xp2 = no_express();
        let ic2 = Interconnect { outs: &outs, express: &xp2 };
        assert_eq!(ic2.port(3, 1, Port::Express), outs[3][4]);
    }

    #[test]
    fn operand_plan_classifies_bus_reg_and_port_words() {
        use crate::morphosys::rc_array::alu::AluOp;
        let add = ContextWord::two_port(AluOp::Add);
        assert!(OperandPlan::of(&add).is_bus_bus());
        // Immediate-class words force mux B to the bus encoding → fast path.
        let imm = ContextWord::immediate(AluOp::Cmul, 5);
        assert!(OperandPlan::of(&imm).is_bus_bus());
        let mut mixed = ContextWord::two_port(AluOp::Add);
        mixed.mux_a = MuxASel::West;
        mixed.mux_b = MuxBSel::Reg(2);
        let plan = OperandPlan::of(&mixed);
        assert_eq!(plan.a, OperandSource::Port(Port::West));
        assert_eq!(plan.b, OperandSource::Reg(2));
        assert!(!plan.is_bus_bus());
    }

    #[test]
    fn operand_bus_selects_are_not_interconnect_sources() {
        let outs = grid();
        let xp = no_express();
        let ic = Interconnect { outs: &outs, express: &xp };
        assert_eq!(ic.mux_a(0, 0, MuxASel::OperandBusA), None);
        assert_eq!(ic.mux_a(0, 0, MuxASel::Reg(2)), None);
        assert_eq!(ic.mux_b(0, 0, MuxBSel::OperandBusB), None);
        assert_eq!(ic.mux_a(1, 1, MuxASel::North), Some(outs[0][1]));
        assert_eq!(ic.mux_b(1, 1, MuxBSel::East), Some(outs[1][2]));
    }
}
