//! A single reconfigurable cell (paper Figure 3): ALU/multiplier + shift
//! unit, input muxes, a four-register file, an output register and the
//! context register.
//!
//! Since the §Perf data-layout rework the array stores cell state as
//! struct-of-arrays planes (see [`super::array::RcArray`]); the cell-step
//! semantics live in [`execute_step`], which operates on one lane of each
//! plane. [`RcCell`] remains as the single-cell view the unit tests pin
//! the semantics with.

use super::alu::{self, AluOp};
use super::context::ContextWord;

/// Resolved input operands for one cell execution, produced by the
/// interconnect from the mux selects of the context word.
#[derive(Debug, Clone, Copy, Default)]
pub struct CellInputs {
    pub a: i16,
    pub b: i16,
}

/// Execute one context word against one cell's architectural state,
/// passed as one lane of the array's state planes. Returns the value of
/// the output register after the step.
///
/// Semantics preserved bit-for-bit from the original cell model:
/// * `acc_reset` clears the accumulator before the ALU op;
/// * `acc_accumulate` fuses `ACC += result` and latches the accumulator
///   (the CMUL-accumulate of the §5.3 matmul);
/// * NOP leaves the output register unchanged (the cell is idle), but the
///   register-write mask and express latch still observe the ALU result;
/// * the express latch is re-driven (or released) on every step.
#[inline]
pub fn execute_step(
    cw: &ContextWord,
    inputs: CellInputs,
    out: &mut i16,
    regs: &mut [i16; 4],
    acc: &mut i32,
    express: &mut Option<i16>,
) -> i16 {
    if cw.acc_reset {
        *acc = 0;
    }
    let mut r = alu::eval(cw.op, inputs.a, inputs.b, cw.imm, *acc);
    if cw.acc_accumulate {
        // Fused accumulate: ACC += result, accumulator drives the
        // output register (the CMUL-accumulate of the §5.3 matmul).
        r.acc = acc.wrapping_add(r.out as i32);
        r.out = r.acc as i16;
    }
    *acc = r.acc;
    // NOP leaves the output register unchanged (the cell is idle).
    if cw.op != AluOp::Nop {
        *out = r.out;
    }
    for i in 0..4 {
        if cw.reg_write & (1 << i) != 0 {
            regs[i] = r.out;
        }
    }
    *express = if cw.express_write { Some(r.out) } else { None };
    *out
}

/// One reconfigurable cell (the AoS view; see [`execute_step`]).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RcCell {
    /// Register file: four 16-bit registers.
    pub regs: [i16; 4],
    /// Output register, visible to neighbours via the interconnect.
    pub out: i16,
    /// 32-bit multiply-accumulate register.
    pub acc: i32,
    /// Express-lane latch (set when the context word has `express_write`).
    pub express: Option<i16>,
}

impl RcCell {
    pub fn new() -> RcCell {
        RcCell::default()
    }

    /// Execute one context word with resolved inputs. Returns the value
    /// latched into the output register.
    pub fn execute(&mut self, cw: &ContextWord, inputs: CellInputs) -> i16 {
        execute_step(cw, inputs, &mut self.out, &mut self.regs, &mut self.acc, &mut self.express)
    }

    /// Reset all architectural state.
    pub fn reset(&mut self) {
        *self = RcCell::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::morphosys::rc_array::context::ContextWord;

    #[test]
    fn execute_latches_output_register() {
        let mut cell = RcCell::new();
        let cw = ContextWord::two_port(AluOp::Add);
        let out = cell.execute(&cw, CellInputs { a: 2, b: 5 });
        assert_eq!(out, 7);
        assert_eq!(cell.out, 7);
    }

    #[test]
    fn nop_preserves_output_register() {
        let mut cell = RcCell::new();
        cell.out = 42;
        cell.execute(&ContextWord::two_port(AluOp::Nop), CellInputs::default());
        assert_eq!(cell.out, 42);
    }

    #[test]
    fn reg_write_mask_updates_register_file() {
        let mut cell = RcCell::new();
        let mut cw = ContextWord::two_port(AluOp::Add);
        cw.reg_write = 0b1010; // r1 and r3
        cell.execute(&cw, CellInputs { a: 1, b: 2 });
        assert_eq!(cell.regs, [0, 3, 0, 3]);
    }

    #[test]
    fn acc_reset_then_mula_chain() {
        let mut cell = RcCell::new();
        cell.execute(&ContextWord::mula(true), CellInputs { a: 2, b: 3 });
        cell.execute(&ContextWord::mula(false), CellInputs { a: 4, b: 5 });
        assert_eq!(cell.acc, 26);
        // Restarting with acc_reset discards the old accumulation.
        cell.execute(&ContextWord::mula(true), CellInputs { a: 1, b: 1 });
        assert_eq!(cell.acc, 1);
    }

    #[test]
    fn cmula_accumulates_constant_products() {
        // The §5.3 building block: acc = Σ_k (imm_k × a_k).
        let mut cell = RcCell::new();
        cell.execute(&ContextWord::cmula(3, true), CellInputs { a: 10, b: 0 });
        assert_eq!(cell.out, 30);
        cell.execute(&ContextWord::cmula(-2, false), CellInputs { a: 4, b: 0 });
        assert_eq!(cell.out, 22);
        assert_eq!(cell.acc, 22);
    }

    #[test]
    fn express_latch_follows_express_write_flag() {
        let mut cell = RcCell::new();
        let mut cw = ContextWord::two_port(AluOp::Add);
        cw.express_write = true;
        cell.execute(&cw, CellInputs { a: 1, b: 1 });
        assert_eq!(cell.express, Some(2));
        cw.express_write = false;
        cell.execute(&cw, CellInputs { a: 1, b: 1 });
        assert_eq!(cell.express, None);
    }
}
