//! The reconfigurable device: the 8×8 RC array.
//!
//! Each reconfigurable cell (paper Figure 3) has an ALU/multiplier, a
//! 32-bit shift unit, two input multiplexers, a register file of four
//! 16-bit registers, an output register, and a context register. All cells
//! in a column (column-broadcast mode) or row (row-broadcast mode) share
//! one context word, giving the array its SIMD character.

pub mod alu;
pub mod array;
pub mod cell;
pub mod context;
pub mod interconnect;

pub use alu::AluOp;
pub use array::{BroadcastMode, RcArray, ARRAY_DIM};
pub use cell::RcCell;
pub use context::{ContextWord, MuxASel, MuxBSel};
pub use interconnect::{Interconnect, OperandPlan, OperandSource, Port};
