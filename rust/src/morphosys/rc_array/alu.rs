//! ALU/multiplier of a reconfigurable cell.
//!
//! The datapath is 16-bit signed (the paper: "the ALU-Multiplier operates
//! only on signed numbers" in the M1 prototype) with a 32-bit
//! accumulator for multiply-accumulate, which executes in a single cycle.
//!
//! Opcode assignments are chosen so that the two context words published
//! in the paper decode to their published semantics:
//! `0000F400` → `OUT = A + B` (opcode `0xF` = ADD) and
//! `00009005` → `OUT = c × A` with `c = 5` (opcode `0x9` = CMUL).

/// ALU operation, encoded in bits `[15:12]` of a context word.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum AluOp {
    /// No operation; output register unchanged.
    Nop = 0x0,
    /// `OUT = A`.
    PassA = 0x1,
    /// `OUT = B`.
    PassB = 0x2,
    /// `OUT = A - B`.
    Sub = 0x3,
    /// `OUT = A × B` (low 16 bits of the signed product).
    Mul = 0x4,
    /// `OUT = A & B`.
    And = 0x5,
    /// `OUT = A | B`.
    Or = 0x6,
    /// `OUT = A ^ B`.
    Xor = 0x7,
    /// `OUT = !A`.
    NotA = 0x8,
    /// Constant multiply: `OUT = imm × A` (the §5.2 / §5.3 CMUL op).
    Cmul = 0x9,
    /// Constant add: `OUT = A + imm`.
    Cadd = 0xA,
    /// Constant subtract: `OUT = A - imm`.
    Csub = 0xB,
    /// Multiply-accumulate: `ACC += A × B; OUT = ACC` (single cycle).
    Mula = 0xC,
    /// Shift left by `imm & 0x1F` (32-bit shift unit).
    Shl = 0xD,
    /// Arithmetic shift right by `imm & 0x1F`.
    Shr = 0xE,
    /// `OUT = A + B`.
    Add = 0xF,
}

impl AluOp {
    /// Decode from a 4-bit opcode field. Total over all 16 encodings.
    pub fn from_bits(bits: u8) -> AluOp {
        match bits & 0xF {
            0x0 => AluOp::Nop,
            0x1 => AluOp::PassA,
            0x2 => AluOp::PassB,
            0x3 => AluOp::Sub,
            0x4 => AluOp::Mul,
            0x5 => AluOp::And,
            0x6 => AluOp::Or,
            0x7 => AluOp::Xor,
            0x8 => AluOp::NotA,
            0x9 => AluOp::Cmul,
            0xA => AluOp::Cadd,
            0xB => AluOp::Csub,
            0xC => AluOp::Mula,
            0xD => AluOp::Shl,
            0xE => AluOp::Shr,
            _ => AluOp::Add,
        }
    }

    /// Encode to the 4-bit opcode field.
    pub fn bits(self) -> u8 {
        self as u8
    }

    /// Does this op consume the context-word immediate instead of port B?
    pub fn uses_immediate(self) -> bool {
        matches!(
            self,
            AluOp::Cmul | AluOp::Cadd | AluOp::Csub | AluOp::Shl | AluOp::Shr
        )
    }
}

/// Result of one ALU evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AluResult {
    /// Value latched into the output register (16-bit datapath).
    pub out: i16,
    /// New accumulator value (32-bit, only changed by MULA).
    pub acc: i32,
}

/// Evaluate one ALU operation. `a`/`b` are the mux outputs, `imm` the
/// context-word immediate, `acc` the current accumulator.
pub fn eval(op: AluOp, a: i16, b: i16, imm: i16, acc: i32) -> AluResult {
    let (out, acc) = match op {
        AluOp::Nop => (0, acc),
        AluOp::PassA => (a, acc),
        AluOp::PassB => (b, acc),
        AluOp::Sub => (a.wrapping_sub(b), acc),
        AluOp::Mul => ((a as i32).wrapping_mul(b as i32) as i16, acc),
        AluOp::And => (a & b, acc),
        AluOp::Or => (a | b, acc),
        AluOp::Xor => (a ^ b, acc),
        AluOp::NotA => (!a, acc),
        AluOp::Cmul => ((imm as i32).wrapping_mul(a as i32) as i16, acc),
        AluOp::Cadd => (a.wrapping_add(imm), acc),
        AluOp::Csub => (a.wrapping_sub(imm), acc),
        AluOp::Mula => {
            let acc = acc.wrapping_add((a as i32).wrapping_mul(b as i32));
            (acc as i16, acc)
        }
        AluOp::Shl => (((a as i32) << (imm as u32 & 0x1F)) as i16, acc),
        AluOp::Shr => (((a as i32) >> (imm as u32 & 0x1F)) as i16, acc),
        AluOp::Add => (a.wrapping_add(b), acc),
    };
    AluResult { out, acc }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(op: AluOp, a: i16, b: i16, imm: i16) -> i16 {
        eval(op, a, b, imm, 0).out
    }

    #[test]
    fn opcode_roundtrip_is_total() {
        for bits in 0..16u8 {
            assert_eq!(AluOp::from_bits(bits).bits(), bits);
        }
    }

    #[test]
    fn paper_ops_have_paper_encodings() {
        // 0000F400 decodes to OUT = A + B; 00009005 to OUT = 5 × A.
        assert_eq!(AluOp::from_bits(0xF), AluOp::Add);
        assert_eq!(AluOp::from_bits(0x9), AluOp::Cmul);
    }

    #[test]
    fn arithmetic_ops() {
        assert_eq!(run(AluOp::Add, 3, 4, 0), 7);
        assert_eq!(run(AluOp::Sub, 3, 4, 0), -1);
        assert_eq!(run(AluOp::Mul, -3, 4, 0), -12);
        assert_eq!(run(AluOp::Cmul, 7, 0, 5), 35);
        assert_eq!(run(AluOp::Cadd, 7, 0, 5), 12);
        assert_eq!(run(AluOp::Csub, 7, 0, 5), 2);
    }

    #[test]
    fn logic_ops() {
        assert_eq!(run(AluOp::And, 0b1100, 0b1010, 0), 0b1000);
        assert_eq!(run(AluOp::Or, 0b1100, 0b1010, 0), 0b1110);
        assert_eq!(run(AluOp::Xor, 0b1100, 0b1010, 0), 0b0110);
        assert_eq!(run(AluOp::NotA, 0, 0, 0), -1);
    }

    #[test]
    fn passthrough_ops() {
        assert_eq!(run(AluOp::PassA, 11, 22, 0), 11);
        assert_eq!(run(AluOp::PassB, 11, 22, 0), 22);
        assert_eq!(run(AluOp::Nop, 11, 22, 0), 0);
    }

    #[test]
    fn shifts_use_immediate() {
        assert_eq!(run(AluOp::Shl, 1, 0, 4), 16);
        assert_eq!(run(AluOp::Shr, -16, 0, 2), -4);
        assert!(AluOp::Shl.uses_immediate());
    }

    #[test]
    fn mula_accumulates_across_steps() {
        // Single-cycle multiply-accumulate, as the paper highlights.
        let r1 = eval(AluOp::Mula, 2, 3, 0, 0);
        assert_eq!(r1.acc, 6);
        let r2 = eval(AluOp::Mula, 4, 5, 0, r1.acc);
        assert_eq!(r2.acc, 26);
        assert_eq!(r2.out, 26);
    }

    #[test]
    fn signed_wraparound_matches_16bit_datapath() {
        assert_eq!(run(AluOp::Add, i16::MAX, 1, 0), i16::MIN);
        assert_eq!(run(AluOp::Mul, 300, 300, 0), (300i32 * 300) as i16);
    }

    #[test]
    fn mula_accumulator_is_32bit() {
        // 200 * 200 = 40_000 overflows i16 but not the 32-bit accumulator.
        let r = eval(AluOp::Mula, 200, 200, 0, 0);
        assert_eq!(r.acc, 40_000);
    }
}
