//! ALU/multiplier of a reconfigurable cell.
//!
//! The datapath is 16-bit signed (the paper: "the ALU-Multiplier operates
//! only on signed numbers" in the M1 prototype) with a 32-bit
//! accumulator for multiply-accumulate, which executes in a single cycle.
//!
//! Opcode assignments are chosen so that the two context words published
//! in the paper decode to their published semantics:
//! `0000F400` → `OUT = A + B` (opcode `0xF` = ADD) and
//! `00009005` → `OUT = c × A` with `c = 5` (opcode `0x9` = CMUL).

/// ALU operation, encoded in bits `[15:12]` of a context word.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum AluOp {
    /// No operation; output register unchanged.
    Nop = 0x0,
    /// `OUT = A`.
    PassA = 0x1,
    /// `OUT = B`.
    PassB = 0x2,
    /// `OUT = A - B`.
    Sub = 0x3,
    /// `OUT = A × B` (low 16 bits of the signed product).
    Mul = 0x4,
    /// `OUT = A & B`.
    And = 0x5,
    /// `OUT = A | B`.
    Or = 0x6,
    /// `OUT = A ^ B`.
    Xor = 0x7,
    /// `OUT = !A`.
    NotA = 0x8,
    /// Constant multiply: `OUT = imm × A` (the §5.2 / §5.3 CMUL op).
    Cmul = 0x9,
    /// Constant add: `OUT = A + imm`.
    Cadd = 0xA,
    /// Constant subtract: `OUT = A - imm`.
    Csub = 0xB,
    /// Multiply-accumulate: `ACC += A × B; OUT = ACC` (single cycle).
    Mula = 0xC,
    /// Shift left by `imm & 0x1F` (32-bit shift unit).
    Shl = 0xD,
    /// Arithmetic shift right by `imm & 0x1F`.
    Shr = 0xE,
    /// `OUT = A + B`.
    Add = 0xF,
}

impl AluOp {
    /// Decode from a 4-bit opcode field. Total over all 16 encodings.
    pub fn from_bits(bits: u8) -> AluOp {
        match bits & 0xF {
            0x0 => AluOp::Nop,
            0x1 => AluOp::PassA,
            0x2 => AluOp::PassB,
            0x3 => AluOp::Sub,
            0x4 => AluOp::Mul,
            0x5 => AluOp::And,
            0x6 => AluOp::Or,
            0x7 => AluOp::Xor,
            0x8 => AluOp::NotA,
            0x9 => AluOp::Cmul,
            0xA => AluOp::Cadd,
            0xB => AluOp::Csub,
            0xC => AluOp::Mula,
            0xD => AluOp::Shl,
            0xE => AluOp::Shr,
            _ => AluOp::Add,
        }
    }

    /// Encode to the 4-bit opcode field.
    pub fn bits(self) -> u8 {
        self as u8
    }

    /// Does this op consume the context-word immediate instead of port B?
    pub fn uses_immediate(self) -> bool {
        matches!(
            self,
            AluOp::Cmul | AluOp::Cadd | AluOp::Csub | AluOp::Shl | AluOp::Shr
        )
    }
}

/// Result of one ALU evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AluResult {
    /// Value latched into the output register (16-bit datapath).
    pub out: i16,
    /// New accumulator value (32-bit, only changed by MULA).
    pub acc: i32,
}

/// Number of lanes an 8-wide kernel commits at once (one broadcast line).
pub const LANES: usize = 8;

/// Eight-lane form of [`eval`]: evaluate one ALU operation across all
/// eight lanes of a broadcast at once (§Perf, fused tile-kernel tier).
///
/// Each op is written as a fixed-trip-count loop over `[i16; 8]` /
/// `[i32; 8]` lanes with no cross-lane dependencies, the shape LLVM
/// autovectorizes; the dominant `Add`/`Mul`/`Cmul` ops additionally take
/// an explicit SSE2 path on x86_64 (behind the `sse2-kernels` feature).
/// Results are bit-for-bit identical to eight scalar [`eval`] calls,
/// pinned by the `eval8_matches_scalar_eval_for_every_op` test below and
/// by the fused differential conformance suite.
pub fn eval8(
    op: AluOp,
    a: &[i16; LANES],
    b: &[i16; LANES],
    imm: i16,
    acc: &[i32; LANES],
) -> ([i16; LANES], [i32; LANES]) {
    let mut out = [0i16; LANES];
    let mut acc_out = *acc;
    match op {
        AluOp::Nop => {}
        AluOp::PassA => out = *a,
        AluOp::PassB => out = *b,
        AluOp::Sub => {
            for i in 0..LANES {
                out[i] = a[i].wrapping_sub(b[i]);
            }
        }
        AluOp::Mul => out = mul8(a, b),
        AluOp::And => {
            for i in 0..LANES {
                out[i] = a[i] & b[i];
            }
        }
        AluOp::Or => {
            for i in 0..LANES {
                out[i] = a[i] | b[i];
            }
        }
        AluOp::Xor => {
            for i in 0..LANES {
                out[i] = a[i] ^ b[i];
            }
        }
        AluOp::NotA => {
            for i in 0..LANES {
                out[i] = !a[i];
            }
        }
        AluOp::Cmul => out = mul8(a, &[imm; LANES]),
        AluOp::Cadd => {
            for i in 0..LANES {
                out[i] = a[i].wrapping_add(imm);
            }
        }
        AluOp::Csub => {
            for i in 0..LANES {
                out[i] = a[i].wrapping_sub(imm);
            }
        }
        AluOp::Mula => {
            for i in 0..LANES {
                acc_out[i] = acc[i].wrapping_add((a[i] as i32).wrapping_mul(b[i] as i32));
                out[i] = acc_out[i] as i16;
            }
        }
        AluOp::Shl => {
            let sh = imm as u32 & 0x1F;
            for i in 0..LANES {
                out[i] = ((a[i] as i32) << sh) as i16;
            }
        }
        AluOp::Shr => {
            let sh = imm as u32 & 0x1F;
            for i in 0..LANES {
                out[i] = ((a[i] as i32) >> sh) as i16;
            }
        }
        AluOp::Add => out = add8(a, b),
    }
    (out, acc_out)
}

/// Lane-wise wrapping 16-bit add (SSE2 `paddw` on x86_64).
#[inline]
fn add8(a: &[i16; LANES], b: &[i16; LANES]) -> [i16; LANES] {
    #[cfg(all(target_arch = "x86_64", feature = "sse2-kernels"))]
    {
        sse2::add8(a, b)
    }
    #[cfg(not(all(target_arch = "x86_64", feature = "sse2-kernels")))]
    {
        let mut out = [0i16; LANES];
        for i in 0..LANES {
            out[i] = a[i].wrapping_add(b[i]);
        }
        out
    }
}

/// Lane-wise low-16-bit signed multiply (SSE2 `pmullw` on x86_64) — the
/// shared kernel of `Mul` (lane × lane) and `Cmul` (lane × splat imm):
/// both keep the low 16 bits of the 32-bit signed product.
#[inline]
fn mul8(a: &[i16; LANES], b: &[i16; LANES]) -> [i16; LANES] {
    #[cfg(all(target_arch = "x86_64", feature = "sse2-kernels"))]
    {
        sse2::mul8(a, b)
    }
    #[cfg(not(all(target_arch = "x86_64", feature = "sse2-kernels")))]
    {
        let mut out = [0i16; LANES];
        for i in 0..LANES {
            out[i] = ((a[i] as i32).wrapping_mul(b[i] as i32)) as i16;
        }
        out
    }
}

/// Whole-tile form of [`eval8`]: evaluate one accumulator-free ALU
/// operation across all 64 lanes of an 8×8 tile at once (§Perf,
/// megakernel tier). `a`/`b` hold the tile's two operand-bus spans in
/// frame-buffer order (column-major: lane `c·8 + l` is column `c`,
/// row `l`).
///
/// Only accumulator-free ops are eligible (the megakernel executor's
/// tile fast path excludes `Mula` and `Nop` before calling here), so no
/// accumulator state flows in or out. With the `avx2-kernels` feature
/// the dominant `Add`/`Sub`/`Mul`/`Cmul` ops take a runtime-detected
/// 16-lane AVX2 path committing two 8-lane rows per step; every other
/// op — and every non-AVX2 host — goes through eight [`eval8`] row
/// calls. Bit-for-bit identical to 64 scalar [`eval`] calls on every
/// path, pinned by `eval_tile_matches_eval8_rows` below and the
/// megakernel conformance sweep.
pub fn eval_tile(
    op: AluOp,
    a: &[i16; LANES * LANES],
    b: &[i16; LANES * LANES],
    imm: i16,
) -> [i16; LANES * LANES] {
    debug_assert!(
        !matches!(op, AluOp::Mula | AluOp::Nop),
        "eval_tile requires an accumulator-free, output-writing op"
    );
    #[cfg(all(target_arch = "x86_64", feature = "avx2-kernels"))]
    {
        if matches!(op, AluOp::Add | AluOp::Sub | AluOp::Mul | AluOp::Cmul)
            && std::is_x86_feature_detected!("avx2")
        {
            // SAFETY: AVX2 support was just verified at run time.
            return unsafe { avx2::eval_tile(op, a, b, imm) };
        }
    }
    eval_tile_rows(op, a, b, imm)
}

/// Portable reference tile kernel: eight [`eval8`] row evaluations with a
/// zero accumulator (sound for every accumulator-free op — the
/// accumulator never feeds their outputs and passes through unchanged).
fn eval_tile_rows(
    op: AluOp,
    a: &[i16; LANES * LANES],
    b: &[i16; LANES * LANES],
    imm: i16,
) -> [i16; LANES * LANES] {
    let zero_acc = [0i32; LANES];
    let mut out = [0i16; LANES * LANES];
    for r in 0..LANES {
        let span = r * LANES..(r + 1) * LANES;
        let ra: &[i16; LANES] = a[span.clone()].try_into().unwrap();
        let rb: &[i16; LANES] = b[span.clone()].try_into().unwrap();
        let (row, _) = eval8(op, ra, rb, imm, &zero_acc);
        out[span].copy_from_slice(&row);
    }
    out
}

/// Runtime-detected AVX2 tile kernels (§Perf, megakernel tier): four
/// 256-bit vector operations cover the whole 64-lane tile, two 8-lane
/// rows per step. The wrapping 16-bit semantics of `vpaddw`/`vpsubw`/
/// `vpmullw` match the scalar [`eval`] reference exactly.
#[cfg(all(target_arch = "x86_64", feature = "avx2-kernels"))]
mod avx2 {
    use super::{AluOp, LANES};
    use core::arch::x86_64::*;

    /// # Safety
    /// Callers must verify AVX2 availability first
    /// (`is_x86_feature_detected!("avx2")`).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn eval_tile(
        op: AluOp,
        a: &[i16; LANES * LANES],
        b: &[i16; LANES * LANES],
        imm: i16,
    ) -> [i16; LANES * LANES] {
        let mut out = [0i16; LANES * LANES];
        let splat = _mm256_set1_epi16(imm);
        for step in 0..4 {
            // Two 8-lane rows per 256-bit vector; the unaligned
            // load/store intrinsics accept any address.
            let va = _mm256_loadu_si256(a.as_ptr().add(16 * step).cast());
            let vb = _mm256_loadu_si256(b.as_ptr().add(16 * step).cast());
            let v = match op {
                AluOp::Add => _mm256_add_epi16(va, vb),
                AluOp::Sub => _mm256_sub_epi16(va, vb),
                AluOp::Mul => _mm256_mullo_epi16(va, vb),
                // Cmul keeps the low 16 bits of imm × A, same as Mul
                // against a splatted immediate.
                _ => _mm256_mullo_epi16(va, splat),
            };
            _mm256_storeu_si256(out.as_mut_ptr().add(16 * step).cast(), v);
        }
        out
    }
}

/// Explicit SSE2 kernels for the dominant fused ops. SSE2 is part of the
/// x86_64 baseline, so no runtime feature detection is needed; the
/// intrinsics' wrapping 16-bit semantics (`paddw`, `pmullw`) match the
/// scalar [`eval`] reference exactly.
#[cfg(all(target_arch = "x86_64", feature = "sse2-kernels"))]
mod sse2 {
    use super::LANES;
    use core::arch::x86_64::*;

    #[inline]
    pub(super) fn add8(a: &[i16; LANES], b: &[i16; LANES]) -> [i16; LANES] {
        // SAFETY: SSE2 is unconditionally available on x86_64 and the
        // unaligned load/store intrinsics accept any address; the arrays
        // are exactly one 128-bit vector wide.
        unsafe {
            let va = _mm_loadu_si128(a.as_ptr().cast());
            let vb = _mm_loadu_si128(b.as_ptr().cast());
            let mut out = [0i16; LANES];
            _mm_storeu_si128(out.as_mut_ptr().cast(), _mm_add_epi16(va, vb));
            out
        }
    }

    #[inline]
    pub(super) fn mul8(a: &[i16; LANES], b: &[i16; LANES]) -> [i16; LANES] {
        // SAFETY: as in `add8`.
        unsafe {
            let va = _mm_loadu_si128(a.as_ptr().cast());
            let vb = _mm_loadu_si128(b.as_ptr().cast());
            let mut out = [0i16; LANES];
            _mm_storeu_si128(out.as_mut_ptr().cast(), _mm_mullo_epi16(va, vb));
            out
        }
    }
}

/// Evaluate one ALU operation. `a`/`b` are the mux outputs, `imm` the
/// context-word immediate, `acc` the current accumulator.
pub fn eval(op: AluOp, a: i16, b: i16, imm: i16, acc: i32) -> AluResult {
    let (out, acc) = match op {
        AluOp::Nop => (0, acc),
        AluOp::PassA => (a, acc),
        AluOp::PassB => (b, acc),
        AluOp::Sub => (a.wrapping_sub(b), acc),
        AluOp::Mul => ((a as i32).wrapping_mul(b as i32) as i16, acc),
        AluOp::And => (a & b, acc),
        AluOp::Or => (a | b, acc),
        AluOp::Xor => (a ^ b, acc),
        AluOp::NotA => (!a, acc),
        AluOp::Cmul => ((imm as i32).wrapping_mul(a as i32) as i16, acc),
        AluOp::Cadd => (a.wrapping_add(imm), acc),
        AluOp::Csub => (a.wrapping_sub(imm), acc),
        AluOp::Mula => {
            let acc = acc.wrapping_add((a as i32).wrapping_mul(b as i32));
            (acc as i16, acc)
        }
        AluOp::Shl => (((a as i32) << (imm as u32 & 0x1F)) as i16, acc),
        AluOp::Shr => (((a as i32) >> (imm as u32 & 0x1F)) as i16, acc),
        AluOp::Add => (a.wrapping_add(b), acc),
    };
    AluResult { out, acc }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(op: AluOp, a: i16, b: i16, imm: i16) -> i16 {
        eval(op, a, b, imm, 0).out
    }

    #[test]
    fn opcode_roundtrip_is_total() {
        for bits in 0..16u8 {
            assert_eq!(AluOp::from_bits(bits).bits(), bits);
        }
    }

    #[test]
    fn paper_ops_have_paper_encodings() {
        // 0000F400 decodes to OUT = A + B; 00009005 to OUT = 5 × A.
        assert_eq!(AluOp::from_bits(0xF), AluOp::Add);
        assert_eq!(AluOp::from_bits(0x9), AluOp::Cmul);
    }

    #[test]
    fn arithmetic_ops() {
        assert_eq!(run(AluOp::Add, 3, 4, 0), 7);
        assert_eq!(run(AluOp::Sub, 3, 4, 0), -1);
        assert_eq!(run(AluOp::Mul, -3, 4, 0), -12);
        assert_eq!(run(AluOp::Cmul, 7, 0, 5), 35);
        assert_eq!(run(AluOp::Cadd, 7, 0, 5), 12);
        assert_eq!(run(AluOp::Csub, 7, 0, 5), 2);
    }

    #[test]
    fn logic_ops() {
        assert_eq!(run(AluOp::And, 0b1100, 0b1010, 0), 0b1000);
        assert_eq!(run(AluOp::Or, 0b1100, 0b1010, 0), 0b1110);
        assert_eq!(run(AluOp::Xor, 0b1100, 0b1010, 0), 0b0110);
        assert_eq!(run(AluOp::NotA, 0, 0, 0), -1);
    }

    #[test]
    fn passthrough_ops() {
        assert_eq!(run(AluOp::PassA, 11, 22, 0), 11);
        assert_eq!(run(AluOp::PassB, 11, 22, 0), 22);
        assert_eq!(run(AluOp::Nop, 11, 22, 0), 0);
    }

    #[test]
    fn shifts_use_immediate() {
        assert_eq!(run(AluOp::Shl, 1, 0, 4), 16);
        assert_eq!(run(AluOp::Shr, -16, 0, 2), -4);
        assert!(AluOp::Shl.uses_immediate());
    }

    #[test]
    fn mula_accumulates_across_steps() {
        // Single-cycle multiply-accumulate, as the paper highlights.
        let r1 = eval(AluOp::Mula, 2, 3, 0, 0);
        assert_eq!(r1.acc, 6);
        let r2 = eval(AluOp::Mula, 4, 5, 0, r1.acc);
        assert_eq!(r2.acc, 26);
        assert_eq!(r2.out, 26);
    }

    #[test]
    fn signed_wraparound_matches_16bit_datapath() {
        assert_eq!(run(AluOp::Add, i16::MAX, 1, 0), i16::MIN);
        assert_eq!(run(AluOp::Mul, 300, 300, 0), (300i32 * 300) as i16);
    }

    #[test]
    fn mula_accumulator_is_32bit() {
        // 200 * 200 = 40_000 overflows i16 but not the 32-bit accumulator.
        let r = eval(AluOp::Mula, 200, 200, 0, 0);
        assert_eq!(r.acc, 40_000);
    }

    #[test]
    fn eval8_matches_scalar_eval_for_every_op() {
        // The 8-wide lane kernels (including the SSE2 paths for
        // Add/Mul/Cmul) must be bit-identical to eight scalar evals, for
        // every op, across wraparound-heavy operands and live accumulator
        // state.
        use crate::testkit::Rng;
        let mut rng = Rng::new(0xE8A1);
        for _ in 0..200 {
            let op = AluOp::from_bits(rng.below(16) as u8);
            let mut a = [0i16; LANES];
            let mut b = [0i16; LANES];
            let mut acc = [0i32; LANES];
            for l in 0..LANES {
                a[l] = rng.i16();
                b[l] = rng.i16();
                acc[l] = ((rng.i16() as i32) << 13) ^ rng.i16() as i32;
            }
            let imm = rng.range_i64(-128, 127) as i16;
            let (out, acc_out) = eval8(op, &a, &b, imm, &acc);
            for l in 0..LANES {
                let r = eval(op, a[l], b[l], imm, acc[l]);
                assert_eq!(out[l], r.out, "{op:?} out lane {l}");
                assert_eq!(acc_out[l], r.acc, "{op:?} acc lane {l}");
            }
        }
    }

    #[test]
    fn eval_tile_matches_eval8_rows() {
        // The 64-lane tile kernel (including the runtime-detected AVX2
        // path when built with `avx2-kernels`) must be bit-identical to
        // eight 8-lane rows — and therefore to 64 scalar evals — for
        // every accumulator-free op across wraparound-heavy operands.
        use crate::testkit::Rng;
        let mut rng = Rng::new(0x71E5);
        for case in 0..200 {
            let op = AluOp::from_bits(rng.below(16) as u8);
            if matches!(op, AluOp::Mula | AluOp::Nop) {
                continue;
            }
            let mut a = [0i16; LANES * LANES];
            let mut b = [0i16; LANES * LANES];
            for l in 0..LANES * LANES {
                a[l] = rng.i16();
                b[l] = rng.i16();
            }
            // Seed the wraparound edges into the first row.
            a[..8].copy_from_slice(&[i16::MAX, i16::MIN, -1, 0, 1, 300, -300, 0x7F00]);
            b[..8].copy_from_slice(&[1, -1, i16::MIN, i16::MAX, 300, 300, 300, 0x100]);
            let imm = rng.range_i64(-128, 127) as i16;
            let tile = eval_tile(op, &a, &b, imm);
            let zero_acc = [0i32; LANES];
            for r in 0..LANES {
                let ra: &[i16; LANES] = a[r * LANES..(r + 1) * LANES].try_into().unwrap();
                let rb: &[i16; LANES] = b[r * LANES..(r + 1) * LANES].try_into().unwrap();
                let (row, _) = eval8(op, ra, rb, imm, &zero_acc);
                for l in 0..LANES {
                    let i = r * LANES + l;
                    assert_eq!(tile[i], row[l], "case {case}: {op:?} lane {i}");
                    let scalar = eval(op, a[i], b[i], imm, 0);
                    assert_eq!(tile[i], scalar.out, "case {case}: {op:?} scalar lane {i}");
                }
            }
        }
    }

    #[test]
    fn eval8_wraparound_edges_match_scalar() {
        let a = [i16::MAX, i16::MIN, -1, 0, 1, 300, -300, 0x7F00];
        let b = [1, -1, i16::MIN, i16::MAX, 300, 300, 300, 0x100];
        for op in [AluOp::Add, AluOp::Mul, AluOp::Cmul, AluOp::Mula] {
            let acc = [i32::MAX, i32::MIN, 0, -1, 1, 1 << 20, -(1 << 20), 7];
            let (out, acc_out) = eval8(op, &a, &b, -128, &acc);
            for l in 0..LANES {
                let r = eval(op, a[l], b[l], -128, acc[l]);
                assert_eq!((out[l], acc_out[l]), (r.out, r.acc), "{op:?} lane {l}");
            }
        }
    }
}
