//! Snapshot/restore of the full M1 architectural state (§Robustness).
//!
//! [`M1System::snapshot`] serializes everything a program's execution can
//! observe — TinyRISC registers, frame buffer (including dirty spans, so
//! a restored system's `reset_chip` stays equivalent to full zeroing),
//! context memory, all four RC-array planes, the async-DMA engine's
//! readiness windows, and main memory — to a stable, versioned,
//! little-endian byte format. [`M1System::restore`] is its exact inverse:
//! `snapshot → restore → run` is bit-identical to `run` on the original
//! system, across both DMA modes and all three execution tiers (pinned by
//! the snapshot axis of `tests/conformance.rs`).
//!
//! The format is self-contained (magic + version + sized sections), so
//! repro artifacts (see [`crate::replay`]) can embed snapshots and replay
//! them in a later process, and a corrupt or truncated image fails with a
//! typed [`SnapshotError`] instead of garbage state.

use super::context_memory::{PLANES, PLANE_WORDS};
use super::frame_buffer::BANK_ELEMS;
use super::rc_array::ARRAY_DIM;
use super::system::M1System;
use super::timing::AsyncDma;

/// Leading magic of every snapshot image.
pub const SNAPSHOT_MAGIC: [u8; 4] = *b"M1SS";

/// Current (and only) format version.
pub const SNAPSHOT_VERSION: u16 = 1;

/// Why a snapshot image failed to restore.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SnapshotError {
    /// The image does not start with [`SNAPSHOT_MAGIC`].
    BadMagic,
    /// The image's version is not [`SNAPSHOT_VERSION`].
    UnsupportedVersion(u16),
    /// The image ended before a section was complete.
    Truncated,
    /// The image has bytes past the final section.
    TrailingBytes(usize),
    /// A field held an impossible value (e.g. a dirty span past the bank).
    BadValue(&'static str),
}

impl std::fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SnapshotError::BadMagic => write!(f, "not an M1 snapshot (bad magic)"),
            SnapshotError::UnsupportedVersion(v) => {
                write!(f, "unsupported snapshot version {v} (expected {SNAPSHOT_VERSION})")
            }
            SnapshotError::Truncated => write!(f, "snapshot truncated"),
            SnapshotError::TrailingBytes(n) => write!(f, "{n} trailing bytes after snapshot"),
            SnapshotError::BadValue(what) => write!(f, "snapshot field out of range: {what}"),
        }
    }
}

impl std::error::Error for SnapshotError {}

/// FNV-1a/64 over a byte string — the digest used to fingerprint per-step
/// snapshots in repro artifacts (stable across platforms and runs; no
/// dependency beyond arithmetic).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Little-endian byte sink with typed appenders.
struct Writer {
    out: Vec<u8>,
}

impl Writer {
    fn u8(&mut self, v: u8) {
        self.out.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }
    fn i16(&mut self, v: i16) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }
    fn i32(&mut self, v: i32) {
        self.out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Little-endian cursor over a snapshot image.
struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], SnapshotError> {
        let end = self.pos.checked_add(n).ok_or(SnapshotError::Truncated)?;
        if end > self.bytes.len() {
            return Err(SnapshotError::Truncated);
        }
        let s = &self.bytes[self.pos..end];
        self.pos = end;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, SnapshotError> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16, SnapshotError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, SnapshotError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, SnapshotError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn i16(&mut self) -> Result<i16, SnapshotError> {
        Ok(i16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn i32(&mut self) -> Result<i32, SnapshotError> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

impl M1System {
    /// Serialize the full architectural state to the versioned format
    /// (see the module docs). Transient observation plumbing (tracing) is
    /// deliberately excluded — it never affects architectural evolution.
    pub fn snapshot(&self) -> Vec<u8> {
        let (fb_data, fb_dirty) = self.fb.snapshot_parts();
        let mem_words = self.mem.snapshot_words();
        // Header + fixed sections + memory; sizing up front keeps this a
        // single allocation even for the 2 MiB default memory.
        let mut w = Writer {
            out: Vec::with_capacity(
                4 + 2 + 1
                    + 16 * 4
                    + fb_data.len() * 2
                    + 4 * 8
                    + 2 * PLANES * PLANE_WORDS * 4
                    + ARRAY_DIM * ARRAY_DIM * (2 + 4 * 2 + 4 + 3)
                    + 6 * 8
                    + 4
                    + mem_words.len() * 4,
            ),
        };
        w.out.extend_from_slice(&SNAPSHOT_MAGIC);
        w.u16(SNAPSHOT_VERSION);
        w.u8(self.async_dma() as u8);
        for v in self.regs.snapshot_regs() {
            w.u32(v);
        }
        for &e in fb_data {
            w.i16(e);
        }
        for &(lo, hi) in fb_dirty {
            w.u32(lo as u32);
            w.u32(hi as u32);
        }
        for &word in self.ctx.snapshot_words() {
            w.u32(word);
        }
        for row in 0..ARRAY_DIM {
            for col in 0..ARRAY_DIM {
                w.i16(self.array.out(row, col));
                for r in 0..4 {
                    w.i16(self.array.reg(row, col, r));
                }
                w.i32(self.array.acc(row, col));
                match self.array.express(row, col) {
                    Some(v) => {
                        w.u8(1);
                        w.i16(v);
                    }
                    None => {
                        w.u8(0);
                        w.i16(0);
                    }
                }
            }
        }
        for word in self.dma_state().to_words() {
            w.u64(word);
        }
        w.u32(mem_words.len() as u32);
        for &word in mem_words {
            w.u32(word);
        }
        w.out
    }

    /// Restore from a [`M1System::snapshot`] image, replacing **all**
    /// architectural state (including the DMA mode flag and main-memory
    /// size). On error the system is left unchanged — every field is
    /// validated before the first mutation.
    pub fn restore(&mut self, bytes: &[u8]) -> Result<(), SnapshotError> {
        let mut r = Reader { bytes, pos: 0 };
        if r.take(4)? != SNAPSHOT_MAGIC {
            return Err(SnapshotError::BadMagic);
        }
        let version = r.u16()?;
        if version != SNAPSHOT_VERSION {
            return Err(SnapshotError::UnsupportedVersion(version));
        }
        let async_dma = match r.u8()? {
            0 => false,
            1 => true,
            _ => return Err(SnapshotError::BadValue("async_dma flag")),
        };
        let mut regs = [0u32; 16];
        for v in &mut regs {
            *v = r.u32()?;
        }
        let mut fb_data = vec![0i16; 2 * 2 * BANK_ELEMS];
        for e in &mut fb_data {
            *e = r.i16()?;
        }
        let mut fb_dirty = [(0usize, 0usize); 4];
        for span in &mut fb_dirty {
            let (lo, hi) = (r.u32()? as usize, r.u32()? as usize);
            // A clean span is (BANK_ELEMS, 0); a dirty one is a subrange
            // of the bank. Anything else would defeat the span-clear
            // equivalence.
            if lo > BANK_ELEMS || (lo < hi && hi > BANK_ELEMS) {
                return Err(SnapshotError::BadValue("frame-buffer dirty span"));
            }
            *span = (lo, hi);
        }
        let mut ctx = vec![0u32; 2 * PLANES * PLANE_WORDS];
        for word in &mut ctx {
            *word = r.u32()?;
        }
        struct CellImage {
            out: i16,
            regs: [i16; 4],
            acc: i32,
            express: Option<i16>,
        }
        let mut cells = Vec::with_capacity(ARRAY_DIM * ARRAY_DIM);
        for _ in 0..ARRAY_DIM * ARRAY_DIM {
            let out = r.i16()?;
            let mut cregs = [0i16; 4];
            for v in &mut cregs {
                *v = r.i16()?;
            }
            let acc = r.i32()?;
            let flag = r.u8()?;
            let xv = r.i16()?;
            let express = match flag {
                0 => None,
                1 => Some(xv),
                _ => return Err(SnapshotError::BadValue("express flag")),
            };
            cells.push(CellImage { out, regs: cregs, acc, express });
        }
        let mut dma_words = [0u64; 6];
        for word in &mut dma_words {
            *word = r.u64()?;
        }
        let mem_len = r.u32()? as usize;
        let mut mem = vec![0u32; mem_len];
        for word in &mut mem {
            *word = r.u32()?;
        }
        if r.pos != bytes.len() {
            return Err(SnapshotError::TrailingBytes(bytes.len() - r.pos));
        }

        // Everything parsed and validated — commit.
        self.set_async_dma(async_dma);
        self.regs.restore_regs(&regs);
        self.fb.restore_parts(&fb_data, fb_dirty);
        self.ctx.restore_words(&ctx);
        for (i, cell) in cells.iter().enumerate() {
            let (row, col) = (i / ARRAY_DIM, i % ARRAY_DIM);
            self.array.set_out(row, col, cell.out);
            for (r, &v) in cell.regs.iter().enumerate() {
                self.array.set_reg(row, col, r, v);
            }
            self.array.set_acc(row, col, cell.acc);
            self.array.set_express(row, col, cell.express);
        }
        self.set_dma_state(AsyncDma::from_words(&dma_words));
        self.mem.restore_words(&mem);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::{runner::run_routine_on, VecVecMapping};
    use crate::morphosys::AluOp;

    fn populated_system() -> M1System {
        let mut sys = M1System::new();
        let u: Vec<i16> = (0..64).map(|i| 3 * i - 40).collect();
        let v: Vec<i16> = (0..64).map(|i| 7 - i).collect();
        let routine = VecVecMapping { n: 64, op: AluOp::Add }.compile();
        run_routine_on(&mut sys, &routine, &u, Some(&v));
        sys
    }

    #[test]
    fn roundtrip_restores_every_observable_plane() {
        let sys = populated_system();
        let image = sys.snapshot();
        let mut restored = M1System::new();
        restored.restore(&image).unwrap();
        // Byte-for-byte: re-snapshotting the restored system reproduces
        // the image, which covers every serialized plane at once.
        assert_eq!(restored.snapshot(), image);
    }

    #[test]
    fn roundtrip_preserves_the_dma_mode_flag() {
        let sys = M1System::new().with_async_dma();
        let mut restored = M1System::new();
        restored.restore(&sys.snapshot()).unwrap();
        assert!(restored.async_dma());
        let mut back = M1System::new().with_async_dma();
        back.restore(&M1System::new().snapshot()).unwrap();
        assert!(!back.async_dma());
    }

    #[test]
    fn corrupt_images_fail_with_typed_errors() {
        let image = populated_system().snapshot();
        let mut sys = M1System::new();
        assert_eq!(sys.restore(b"nope"), Err(SnapshotError::BadMagic));
        let mut wrong_version = image.clone();
        wrong_version[4] = 99;
        assert_eq!(sys.restore(&wrong_version), Err(SnapshotError::UnsupportedVersion(99)));
        assert_eq!(sys.restore(&image[..image.len() - 1]), Err(SnapshotError::Truncated));
        let mut trailing = image.clone();
        trailing.push(0);
        assert_eq!(sys.restore(&trailing), Err(SnapshotError::TrailingBytes(1)));
        let mut bad_flag = image.clone();
        bad_flag[6] = 7;
        assert_eq!(sys.restore(&bad_flag), Err(SnapshotError::BadValue("async_dma flag")));
        // A failed restore leaves the target untouched.
        assert_eq!(sys.snapshot(), M1System::new().snapshot());
    }

    #[test]
    fn mid_transfer_async_dma_state_roundtrips_and_continues_identically() {
        // Snapshot an async-DMA system *mid-routine* (in-flight readiness
        // windows live in the AsyncDma words) and at its end, restore
        // each, and require byte-identity — then run a second routine on
        // original and restored and require bit-identical continuation.
        let u: Vec<i16> = (0..64).map(|i| 5 * i - 150).collect();
        let v: Vec<i16> = (0..64).map(|i| 31 - 2 * i).collect();
        let routine = VecVecMapping { n: 64, op: AluOp::Add }.compile();
        let mut sys = M1System::new().with_async_dma();
        let mut mid = None;
        crate::mapping::runner::stage_routine3_on(&mut sys, &routine, &u, Some(&v), None);
        let total = routine.program.instructions.len() as u64;
        sys.run_with(&routine.program, |step, s| {
            // Early enough that DMA fills are still inside their windows.
            if step == total / 4 {
                mid = Some(s.snapshot());
            }
        });
        let mid = mid.expect("routine long enough to snapshot mid-run");
        let mut restored_mid = M1System::new();
        restored_mid.restore(&mid).unwrap();
        assert!(restored_mid.async_dma(), "mode flag rides in the image");
        assert_eq!(restored_mid.snapshot(), mid, "mid-transfer image roundtrips");

        let end = sys.snapshot();
        let mut restored = M1System::new();
        restored.restore(&end).unwrap();
        let o1 = run_routine_on(&mut sys, &routine, &v, Some(&u));
        let o2 = run_routine_on(&mut restored, &routine, &v, Some(&u));
        assert_eq!(o1.result, o2.result, "continuation results");
        assert_eq!(o1.report.cycles, o2.report.cycles, "continuation cycles");
    }

    #[test]
    fn mula_accumulator_state_survives_restore_and_carries_forward() {
        // `Mula` leaves live accumulator state in every cell; a restore
        // must reproduce it exactly, and a follow-up run on original vs
        // restored must stay bit-identical (the carry is architectural).
        use crate::morphosys::rc_array::ARRAY_DIM;
        let u: Vec<i16> = (0..64).map(|i| 2 * i - 63).collect();
        let v: Vec<i16> = (0..64).map(|i| i + 1).collect();
        let routine = VecVecMapping { n: 64, op: AluOp::Mula }.compile();
        let mut sys = M1System::new();
        run_routine_on(&mut sys, &routine, &u, Some(&v));
        let image = sys.snapshot();
        let mut restored = M1System::new();
        restored.restore(&image).unwrap();
        let mut any_live = false;
        for row in 0..ARRAY_DIM {
            for col in 0..ARRAY_DIM {
                assert_eq!(
                    sys.array.acc(row, col),
                    restored.array.acc(row, col),
                    "acc ({row},{col})"
                );
                any_live |= sys.array.acc(row, col) != 0;
            }
        }
        assert!(any_live, "Mula must leave nonzero accumulator state to pin");
        let o1 = run_routine_on(&mut sys, &routine, &v, Some(&u));
        let o2 = run_routine_on(&mut restored, &routine, &v, Some(&u));
        assert_eq!(o1.result, o2.result, "post-restore Mula run");
        assert_eq!(o1.report.cycles, o2.report.cycles);
    }

    #[test]
    fn dirty_span_clears_behave_identically_after_restore() {
        // The frame buffer serializes its dirty spans, so `reset_chip` on
        // a restored system must equal `reset_chip` on the original —
        // span-bounded clearing can't leave restored-but-untracked data
        // behind.
        let mut sys = populated_system();
        let mut restored = M1System::new();
        restored.restore(&sys.snapshot()).unwrap();
        sys.reset_chip();
        restored.reset_chip();
        assert_eq!(sys.snapshot(), restored.snapshot(), "post-reset state");
        // And a reset system is indistinguishable from pristine chip
        // state (memory aside, which reset_chip deliberately keeps).
        let mut pristine = M1System::new();
        let words = sys.mem.snapshot_words().to_vec();
        pristine.mem.restore_words(&words);
        assert_eq!(sys.snapshot(), pristine.snapshot(), "reset == pristine chip");
    }

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // Published FNV-1a/64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }
}
