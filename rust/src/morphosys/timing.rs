//! Cycle-timing model of the M1 system — the single place every latency
//! constant lives, so the calibration against the paper's Table 5 is
//! auditable.
//!
//! ## Derivation from the paper's listings
//!
//! The paper reports M1 cycle counts that equal the final instruction
//! index of its listings (Table 1 ends at instruction 96 → "96 cycles";
//! Table 2 ends at 55 → "55 cycles"). The listings show long runs of
//! NOPs after `ldfb` (instr. 2–32 of Table 1) — the TinyRISC waiting for
//! the DMA bus — and a 3-slot gap after `ldctxt`. Solving the four
//! published data points
//!
//! | routine          | paper cycles | structure |
//! |------------------|-----|-------------------------------------------|
//! | translation, n=64| 96  | 2×(ldui+ldfb₃₂) + ldui+ldctxt + 8×(ldli+dbcdc) + 8×wfbi + ldui+stfb |
//! | scaling, n=64    | 55  | ldui+ldfb₃₂ + ldui+ldctxt + 8×sbcb + 8×wfbi + ldui+stfb |
//! | translation, n=8 | 21  | 2×(ldui+ldfb₄) + ldui+ldctxt + ldli+dbcdc + wfbi + ldui+stfb |
//! | scaling, n=8     | 14  | ldui+ldfb₄ + ldui+ldctxt + sbcb + wfbi + ldui+stfb |
//!
//! for the unknown latencies gives exactly one consistent model:
//!
//! * a frame-buffer DMA of `w` 32-bit words occupies the bus for `w`
//!   cycles when the transfer is a full burst (`w ≥ 8`), and `w + 1`
//!   cycles for short transfers (the one-cycle bus setup is hidden by
//!   burst pipelining on long transfers but exposed on short ones);
//! * a context-memory load of `w` context words costs `3 + w` cycles
//!   (the context bus always pays its 3-cycle setup);
//! * every other TinyRISC instruction (including the broadcast triggers
//!   `dbcdc`/`sbcb` and the write-back `wfbi`) issues in a single cycle —
//!   the RC array executes concurrently with the control processor.
//!
//! Check: translation-64 = 1+32 + 1+32 + 1+4 + 16 + 8 + 1+1 = 97 slots →
//! 96 cycles ✓; scaling-64 = 1+32+1+4+8+8+1+1 = 56 → 55 ✓;
//! translation-8 = 1+5+1+5+1+4+2+1+1+1 = 22 → 21 ✓;
//! scaling-8 = 1+5+1+4+1+1+1+1 = 15 → 14 ✓.

/// Words per DMA burst; transfers of at least this many 32-bit words hide
/// the bus-setup cycle behind pipelining.
pub const DMA_BURST_WORDS: usize = 8;

/// Bus-setup penalty (cycles) paid by short (< [`DMA_BURST_WORDS`])
/// frame-buffer DMA transfers.
pub const DMA_SETUP_CYCLES: u64 = 1;

/// Fixed setup latency (cycles) of the context-memory bus.
pub const CTX_SETUP_CYCLES: u64 = 3;

/// Total issue slots occupied by a frame-buffer DMA (`ldfb`/`stfb`) of
/// `words` 32-bit words, including the issue slot itself.
pub fn fb_dma_slots(words: usize) -> u64 {
    let w = words.max(1) as u64;
    if words >= DMA_BURST_WORDS {
        w
    } else {
        w + DMA_SETUP_CYCLES
    }
}

/// Total issue slots occupied by a context-memory load (`ldctxt`) of
/// `words` context words, including the issue slot.
pub fn ctx_dma_slots(words: usize) -> u64 {
    CTX_SETUP_CYCLES + words.max(1) as u64
}

use super::frame_buffer::{Bank, Set};
use super::tinyrisc::Instruction;

/// The async-DMA issue model: one DMA engine running transfers in the
/// background, with per-resource readiness windows consumers stall on.
///
/// This is the **single implementation** of the non-blocking issue
/// discipline, shared by the interpreter
/// ([`crate::morphosys::M1System::run`]) and the schedule compiler
/// ([`crate::morphosys::BroadcastSchedule::compile`]) — so the
/// pre-decoded tier's precomputed async accounting is bit-for-bit the
/// interpreter's *by construction* (§Perf PR 5), on top of being pinned
/// by the conformance suite.
///
/// Every latency input is a **static instruction field** (`words`,
/// `count`, set/bank selects) — no TinyRISC register value feeds the
/// issue model — which is what makes the whole accounting computable at
/// schedule-compile time for any straight-line program.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct AsyncDma {
    /// When the single DMA engine is next free.
    engine_free: u64,
    /// Per (set, bank): cycle at which its last fill completes.
    bank_ready: [[u64; 2]; 2],
    /// Cycle at which the last context load completes.
    ctx_ready: u64,
}

impl AsyncDma {
    /// Cycle at which `instr` issues when offered at cycle `slots`,
    /// updating the engine/resource readiness windows.
    pub(crate) fn issue(&mut self, instr: &Instruction, slots: u64) -> u64 {
        let bank_idx = |set: &Set, bank: &Bank| (set.index(), bank.index());
        match instr {
            Instruction::Ldfb { set, bank, words, .. } => {
                // DMA instructions need the engine; they then run in the
                // background.
                let issue = slots.max(self.engine_free);
                let done = issue + fb_dma_slots(*words);
                self.engine_free = done;
                let (s, b) = bank_idx(set, bank);
                self.bank_ready[s][b] = done;
                issue
            }
            Instruction::Stfb { set, bank, words, .. } => {
                // A store additionally waits for any in-flight fill of
                // its source bank.
                let (s, b) = bank_idx(set, bank);
                let issue = slots.max(self.engine_free).max(self.bank_ready[s][b]);
                self.engine_free = issue + fb_dma_slots(*words);
                issue
            }
            Instruction::Ldctxt { count, .. } => {
                let issue = slots.max(self.engine_free);
                let done = issue + ctx_dma_slots(*count);
                self.engine_free = done;
                self.ctx_ready = done;
                issue
            }
            Instruction::Dbcdc { set, .. } | Instruction::Dbcdr { set, .. } => {
                let s = set.index();
                slots
                    .max(self.ctx_ready)
                    .max(self.bank_ready[s][0])
                    .max(self.bank_ready[s][1])
            }
            Instruction::Sbcb { set, bank, .. } | Instruction::Sbcbr { set, bank, .. } => {
                let (s, b) = bank_idx(set, bank);
                slots.max(self.ctx_ready).max(self.bank_ready[s][b])
            }
            Instruction::Wfbi { set, bank, .. } | Instruction::Wfbir { set, bank, .. } => {
                // Don't collide with an in-flight fill of the target bank.
                let (s, b) = bank_idx(set, bank);
                slots.max(self.bank_ready[s][b])
            }
            _ => slots,
        }
    }

    /// Flatten the readiness windows for [`crate::morphosys::snapshot`]:
    /// `[engine_free, bank_ready[0][0], bank_ready[0][1], bank_ready[1][0],
    /// bank_ready[1][1], ctx_ready]`.
    pub(crate) fn to_words(self) -> [u64; 6] {
        [
            self.engine_free,
            self.bank_ready[0][0],
            self.bank_ready[0][1],
            self.bank_ready[1][0],
            self.bank_ready[1][1],
            self.ctx_ready,
        ]
    }

    /// Inverse of [`AsyncDma::to_words`].
    pub(crate) fn from_words(w: &[u64; 6]) -> AsyncDma {
        AsyncDma {
            engine_free: w[0],
            bank_ready: [[w[1], w[2]], [w[3], w[4]]],
            ctx_ready: w[5],
        }
    }
}

/// M1 system clock, Hz (the paper: "operational at a frequency of
/// 100 MHz").
pub const M1_CLOCK_HZ: u64 = 100_000_000;

/// Convert a cycle count to microseconds at the M1 clock.
pub fn cycles_to_us(cycles: u64) -> f64 {
    cycles as f64 / (M1_CLOCK_HZ as f64 / 1e6)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_dma_hides_setup() {
        assert_eq!(fb_dma_slots(32), 32);
        assert_eq!(fb_dma_slots(8), 8);
    }

    #[test]
    fn short_dma_pays_setup() {
        assert_eq!(fb_dma_slots(4), 5);
        assert_eq!(fb_dma_slots(1), 2);
        assert_eq!(fb_dma_slots(7), 8);
    }

    #[test]
    fn zero_word_dma_still_occupies_issue_slot() {
        assert_eq!(fb_dma_slots(0), 2); // clamped to 1 word + setup
    }

    #[test]
    fn context_bus_always_pays_setup() {
        assert_eq!(ctx_dma_slots(1), 4);
        assert_eq!(ctx_dma_slots(8), 11);
    }

    #[test]
    fn derived_routine_slot_budgets_match_paper() {
        // The paper counts up to the *issue* of the final stfb; the
        // store-back DMA overlaps whatever follows. So the reported cycle
        // count is the slot sum of everything before the final store.
        // translation, n = 64 → 96 cycles
        let t64 = 1 + fb_dma_slots(32) + 1 + fb_dma_slots(32) + 1 + ctx_dma_slots(1)
            + 16 + 8 + 1;
        assert_eq!(t64, 96);
        // scaling, n = 64 → 55 cycles
        let s64 = 1 + fb_dma_slots(32) + 1 + ctx_dma_slots(1) + 8 + 8 + 1;
        assert_eq!(s64, 55);
        // translation, n = 8 → 21 cycles
        let t8 = 1 + fb_dma_slots(4) + 1 + fb_dma_slots(4) + 1 + ctx_dma_slots(1)
            + 2 + 1 + 1;
        assert_eq!(t8, 21);
        // scaling, n = 8 → 14 cycles
        let s8 = 1 + fb_dma_slots(4) + 1 + ctx_dma_slots(1) + 1 + 1 + 1;
        assert_eq!(s8, 14);
    }

    #[test]
    fn async_issue_model_serializes_the_single_dma_engine() {
        use crate::morphosys::tinyrisc::Reg;
        let mut dma = AsyncDma::default();
        let ldfb = |set, bank| Instruction::Ldfb { rs: Reg(1), set, bank, words: 32, fb_addr: 0 };
        // The first fill issues immediately and occupies the engine for
        // its 32-word burst; the second queues behind it.
        assert_eq!(dma.issue(&ldfb(Set::Zero, Bank::A), 0), 0);
        assert_eq!(dma.issue(&ldfb(Set::Zero, Bank::B), 1), 32);
        // A double-bank broadcast on the filling set stalls to the latest
        // bank-ready edge.
        let bc = Instruction::Dbcdc { plane: 0, cw: 0, col: 0, set: Set::Zero, addr_a: 0, addr_b: 0 };
        assert_eq!(dma.issue(&bc, 33), 64);
        // Scalar work never stalls on the engine.
        assert_eq!(dma.issue(&Instruction::NOP, 65), 65);
    }

    #[test]
    fn microseconds_at_100mhz() {
        assert!((cycles_to_us(96) - 0.96).abs() < 1e-12);
        assert!((cycles_to_us(55) - 0.55).abs() < 1e-12);
    }
}
