//! The M1 chip: TinyRISC + DMA + frame buffer + context memory + RC array
//! wired together, with cycle accounting.
//!
//! This is the simulator entry point: build an [`M1System`], stage input
//! data in [`MainMemory`], and [`M1System::run`] a TinyRISC [`Program`].
//! The returned [`ExecutionReport`] carries the cycle count under the
//! paper's convention (see [`crate::morphosys::timing`]) plus the final
//! memory state for correctness checks.

use super::context_memory::{Block, ContextMemory};
use super::dma::{self, MainMemory};
use super::frame_buffer::{Bank, FrameBuffer, Set};
use super::mulate::{Trace, TraceEvent};
use super::rc_array::{alu, AluOp, BroadcastMode, ContextWord, RcArray, ARRAY_DIM};
use super::schedule::{BroadcastSchedule, FusedRun, MegaStep, Megakernel, Step};
use super::timing::AsyncDma;
use super::tinyrisc::{Instruction, Program, RegFile};

/// Hard cap on executed instructions, so runaway branch loops fail fast
/// instead of hanging the simulator.
pub const MAX_EXECUTED: u64 = 50_000_000;

/// Result of running a TinyRISC program.
#[derive(Debug, Clone)]
pub struct ExecutionReport {
    /// Cycle count under the paper's convention: the cycle index at which
    /// the final instruction **issued**. Table 1's listing ends with its
    /// `stfb` at instruction 96 and the paper reports 96 cycles — the
    /// final store-back DMA overlaps subsequent work and is not counted.
    pub cycles: u64,
    /// Total issue slots consumed, including the final instruction's DMA
    /// occupancy.
    pub slots: u64,
    /// Dynamically executed instruction count.
    pub executed: u64,
    /// Broadcast steps performed by the RC array.
    pub broadcasts: u64,
}

impl ExecutionReport {
    /// Execution time in microseconds at the M1's 100 MHz clock.
    pub fn micros(&self) -> f64 {
        super::timing::cycles_to_us(self.cycles)
    }
}

/// The full M1 system.
pub struct M1System {
    pub regs: RegFile,
    pub fb: FrameBuffer,
    pub ctx: ContextMemory,
    pub array: RcArray,
    pub mem: MainMemory,
    trace: Option<Trace>,
    /// Non-blocking DMA mode (ablation): DMA instructions issue in one
    /// cycle and the single DMA engine runs in the background; consumers
    /// (broadcasts reading a bank, context reads) stall only if their
    /// resource is still in flight. The paper's published listings imply
    /// the *blocking* model (the NOP runs of Table 1), which stays the
    /// default; this mode quantifies the double-buffering overlap the M1
    /// hardware description advertises ("new application data can be
    /// loaded … without interrupting the operation of the RC array").
    async_dma: bool,
    /// Final async-DMA engine state of the last run (readiness windows of
    /// in-flight transfers) — architectural state under the async model,
    /// so [`crate::morphosys::snapshot`] captures and restores it. The
    /// interpreter deposits its issue-model state here when a run ends;
    /// the scheduled tier deposits the compile-time-replayed equivalent.
    /// Always default in blocking mode (the blocking path never touches
    /// the issue model).
    dma: AsyncDma,
}

impl Default for M1System {
    fn default() -> Self {
        Self::new()
    }
}

impl M1System {
    pub fn new() -> M1System {
        M1System {
            regs: RegFile::new(),
            fb: FrameBuffer::new(),
            ctx: ContextMemory::new(),
            array: RcArray::new(),
            mem: MainMemory::default_size(),
            trace: None,
            async_dma: false,
            dma: AsyncDma::default(),
        }
    }

    /// Whether this system runs the non-blocking DMA issue model.
    pub fn async_dma(&self) -> bool {
        self.async_dma
    }

    /// Switch the DMA mode in place (snapshot restore adopts the
    /// snapshotted system's mode).
    pub(crate) fn set_async_dma(&mut self, async_dma: bool) {
        self.async_dma = async_dma;
    }

    /// The async-DMA engine state after the last run (see the field docs).
    pub(crate) fn dma_state(&self) -> AsyncDma {
        self.dma
    }

    /// Restore the async-DMA engine state (snapshot restore path).
    pub(crate) fn set_dma_state(&mut self, dma: AsyncDma) {
        self.dma = dma;
    }

    /// Enable the non-blocking-DMA ablation mode (see the field docs).
    pub fn with_async_dma(mut self) -> M1System {
        self.async_dma = true;
        self
    }

    /// Fresh system with the DMA mode chosen by flag — the one place the
    /// "blocking or overlapped?" conditional construction lives (used by
    /// the tile pool's shards and the differential test grids).
    pub fn with_dma_mode(async_dma: bool) -> M1System {
        let mut sys = M1System::new();
        sys.async_dma = async_dma;
        sys
    }

    /// Enable mULATE-style instruction tracing (costs time; off by
    /// default).
    pub fn with_trace(mut self) -> M1System {
        self.trace = Some(Trace::new());
        self
    }

    /// Take the accumulated trace, if tracing was enabled.
    pub fn take_trace(&mut self) -> Option<Trace> {
        self.trace.take().map(|t| {
            self.trace = Some(Trace::new());
            t
        })
    }

    /// Reset all chip state (not main memory), in place — no
    /// reallocation, so a simulator instance can be reused cheaply across
    /// routine runs (§Perf: this took the per-routine cost from ~104 µs
    /// to ~8 µs together with the thread-local system in
    /// [`crate::mapping::runner::run_routine`]).
    pub fn reset_chip(&mut self) {
        self.regs = RegFile::new();
        self.fb.clear();
        self.ctx.clear();
        self.array.reset();
        self.dma = AsyncDma::default();
    }

    /// Record a trace event. The effect string is built **lazily** — with
    /// tracing off (the common case) no formatting or allocation happens,
    /// which used to dominate the interpreter loop (§Perf).
    fn record(&mut self, cycle: u64, pc: usize, instr: &Instruction, effect: impl FnOnce() -> String) {
        if let Some(trace) = &mut self.trace {
            trace.push(TraceEvent { cycle, pc, instr: *instr, effect: effect() });
        }
    }

    /// Execute one broadcast: fetch the context word, drive the operand
    /// buses from the frame buffer, step the array.
    fn broadcast(
        &mut self,
        mode: BroadcastMode,
        plane: usize,
        cw_addr: usize,
        line: usize,
        set: Set,
        bus_a: Option<(Bank, usize)>,
        bus_b: Option<(Bank, usize)>,
    ) -> ContextWord {
        self.broadcast_impl(mode, plane, cw_addr, line, set, bus_a, bus_b, false)
    }

    /// Broadcast with an optional unchecked operand-bus path. `validated`
    /// may only be true when every bus address was proven in range at
    /// schedule-compile time (see [`BroadcastSchedule`]); the interpreter
    /// always passes false and keeps the checked reads.
    #[allow(clippy::too_many_arguments)]
    fn broadcast_impl(
        &mut self,
        mode: BroadcastMode,
        plane: usize,
        cw_addr: usize,
        line: usize,
        set: Set,
        bus_a: Option<(Bank, usize)>,
        bus_b: Option<(Bank, usize)>,
        validated: bool,
    ) -> ContextWord {
        let block = match mode {
            BroadcastMode::Column => Block::Column,
            BroadcastMode::Row => Block::Row,
        };
        let cw = self.ctx.read_decoded(block, plane, cw_addr);
        let a = Self::bus_window(&self.fb, set, bus_a, 0, validated);
        let b = Self::bus_window(&self.fb, set, bus_b, 0, validated);
        self.array.broadcast(mode, line, &cw, &a, &b);
        cw
    }

    /// Fetch one operand-bus window (`bus` base address + `offset`
    /// elements), or zeros for an undriven bus. The **single** place the
    /// validated/unchecked read policy lives: `validated` may only be
    /// true when the executing schedule proved every static bus address
    /// in range at compile time (see [`BroadcastSchedule`]); both the
    /// per-step broadcast path and the fused runs dispatch through here.
    fn bus_window(
        fb: &FrameBuffer,
        set: Set,
        bus: Option<(Bank, usize)>,
        offset: usize,
        validated: bool,
    ) -> [i16; ARRAY_DIM] {
        match bus {
            Some((bank, addr)) if validated => fb.operand_bus_validated(set, bank, addr + offset),
            Some((bank, addr)) => fb.operand_bus(set, bank, addr + offset),
            None => [0; ARRAY_DIM],
        }
    }

    /// Run a program to completion (falling off the end or `halt`).
    pub fn run(&mut self, program: &Program) -> ExecutionReport {
        self.run_with(program, |_, _| {})
    }

    /// As [`M1System::run`], invoking `observer` after each executed
    /// instruction with the 0-based dynamic step index and the
    /// post-instruction system state. The replay tooling
    /// ([`crate::replay`]) uses this to digest per-step state; the
    /// ordinary path passes a no-op closure that compiles away.
    pub fn run_with(
        &mut self,
        program: &Program,
        mut observer: impl FnMut(u64, &M1System),
    ) -> ExecutionReport {
        let mut pc = 0usize;
        let mut slots = 0u64;
        let mut executed = 0u64;
        let mut broadcasts = 0u64;
        let mut last_issue = 0u64;
        // The shared async issue model (see [`AsyncDma`]): the schedule
        // compiler replays this exact state machine at compile time, so
        // the two tiers cannot drift.
        let mut dma = AsyncDma::default();
        let mut halted = false;

        while pc < program.len() {
            let instr = program.instructions[pc];
            let issue_cycle = if self.async_dma {
                dma.issue(&instr, slots)
            } else {
                slots += instr.issue_slots();
                slots - instr.issue_slots()
            };
            last_issue = issue_cycle;
            if self.async_dma {
                slots = issue_cycle + 1;
            }
            executed += 1;
            assert!(executed <= MAX_EXECUTED, "instruction budget exhausted at pc={pc}");
            let mut next_pc = pc + 1;

            match &instr {
                Instruction::Ldui { rd, imm } => {
                    self.regs.load_upper(*rd, *imm);
                    let v = self.regs.read(*rd);
                    self.record(issue_cycle, pc, &instr, || format!("r{} <- {v:#x}", rd.0));
                }
                Instruction::Ldli { rd, imm } => {
                    self.regs.load_lower(*rd, *imm);
                    let v = self.regs.read(*rd);
                    self.record(issue_cycle, pc, &instr, || format!("r{} <- {v:#x}", rd.0));
                }
                Instruction::Add { rd, rs, rt } => {
                    let v = self.regs.read(*rs).wrapping_add(self.regs.read(*rt));
                    self.regs.write(*rd, v);
                    let nop = instr == Instruction::NOP;
                    self.record(issue_cycle, pc, &instr, || {
                        if nop {
                            "nop".to_string()
                        } else {
                            format!("r{} <- {v:#x}", rd.0)
                        }
                    });
                }
                Instruction::Sub { rd, rs, rt } => {
                    let v = self.regs.read(*rs).wrapping_sub(self.regs.read(*rt));
                    self.regs.write(*rd, v);
                    self.record(issue_cycle, pc, &instr, || format!("r{} <- {v:#x}", rd.0));
                }
                Instruction::Addi { rd, rs, imm } => {
                    let v = self.regs.read(*rs).wrapping_add(*imm as i32 as u32);
                    self.regs.write(*rd, v);
                    self.record(issue_cycle, pc, &instr, || format!("r{} <- {v:#x}", rd.0));
                }
                Instruction::Ldfb { rs, set, bank, words, fb_addr } => {
                    let mem_addr = self.regs.read(*rs) as usize;
                    dma::mem_to_fb(&self.mem, &mut self.fb, mem_addr, *set, *bank, *fb_addr, *words);
                    self.record(issue_cycle, pc, &instr, || {
                        format!("FB[{set:?}][{bank:?}][{fb_addr:#x}..] <- mem[{mem_addr:#x}..], {words} words")
                    });
                }
                Instruction::Stfb { rs, set, bank, words, fb_addr } => {
                    let mem_addr = self.regs.read(*rs) as usize;
                    dma::fb_to_mem(&self.fb, &mut self.mem, *set, *bank, *fb_addr, mem_addr, *words);
                    self.record(issue_cycle, pc, &instr, || {
                        format!("mem[{mem_addr:#x}..] <- FB[{set:?}][{bank:?}][{fb_addr:#x}..], {words} words")
                    });
                }
                Instruction::Ldctxt { rs, block, plane, word, count } => {
                    let mem_addr = self.regs.read(*rs) as usize;
                    dma::mem_to_ctx(&self.mem, &mut self.ctx, mem_addr, *block, *plane, *word, *count);
                    self.record(issue_cycle, pc, &instr, || {
                        format!("ctx[{block:?}][{plane}][{word}..+{count}] <- mem[{mem_addr:#x}..]")
                    });
                }
                Instruction::Dbcdc { plane, cw, col, set, addr_a, addr_b } => {
                    let word = self.broadcast(
                        BroadcastMode::Column,
                        *plane,
                        *cw,
                        *col,
                        *set,
                        Some((Bank::A, *addr_a)),
                        Some((Bank::B, *addr_b)),
                    );
                    broadcasts += 1;
                    self.record(issue_cycle, pc, &instr, || {
                        format!("col {col}: {:?} A[{addr_a:#x}] B[{addr_b:#x}]", word.op)
                    });
                }
                Instruction::Dbcdr { plane, cw, row, set, addr_a, addr_b } => {
                    let word = self.broadcast(
                        BroadcastMode::Row,
                        *plane,
                        *cw,
                        *row,
                        *set,
                        Some((Bank::A, *addr_a)),
                        Some((Bank::B, *addr_b)),
                    );
                    broadcasts += 1;
                    self.record(issue_cycle, pc, &instr, || {
                        format!("row {row}: {:?} A[{addr_a:#x}] B[{addr_b:#x}]", word.op)
                    });
                }
                Instruction::Sbcb { plane, cw, col, set, bank, addr } => {
                    let word = self.broadcast(
                        BroadcastMode::Column,
                        *plane,
                        *cw,
                        *col,
                        *set,
                        Some((*bank, *addr)),
                        None,
                    );
                    broadcasts += 1;
                    self.record(issue_cycle, pc, &instr, || {
                        format!("col {col}: {:?} {bank:?}[{addr:#x}]", word.op)
                    });
                }
                Instruction::Sbcbr { plane, cw, row, set, bank, addr } => {
                    let word = self.broadcast(
                        BroadcastMode::Row,
                        *plane,
                        *cw,
                        *row,
                        *set,
                        Some((*bank, *addr)),
                        None,
                    );
                    broadcasts += 1;
                    self.record(issue_cycle, pc, &instr, || {
                        format!("row {row}: {:?} {bank:?}[{addr:#x}]", word.op)
                    });
                }
                Instruction::Wfbi { col, set, bank, addr } => {
                    let outs = self.array.column_outputs(*col);
                    self.fb.write_slice(*set, *bank, *addr, &outs);
                    self.record(issue_cycle, pc, &instr, || {
                        format!("FB[{set:?}][{bank:?}][{addr:#x}..] <- col {col} outputs")
                    });
                }
                Instruction::Wfbir { row, set, bank, addr } => {
                    let outs = self.array.row_outputs(*row);
                    self.fb.write_slice(*set, *bank, *addr, &outs);
                    self.record(issue_cycle, pc, &instr, || {
                        format!("FB[{set:?}][{bank:?}][{addr:#x}..] <- row {row} outputs")
                    });
                }
                Instruction::Jmp { target } => {
                    next_pc = *target;
                    self.record(issue_cycle, pc, &instr, || format!("pc <- {target}"));
                }
                Instruction::Bnez { rs, target } => {
                    let taken = self.regs.read(*rs) != 0;
                    if taken {
                        next_pc = *target;
                    }
                    self.record(issue_cycle, pc, &instr, || format!("taken={taken}"));
                }
                Instruction::Halt => {
                    self.record(issue_cycle, pc, &instr, || "halt".to_string());
                    halted = true;
                }
            }
            observer(executed - 1, self);
            if halted {
                break;
            }
            pc = next_pc;
        }

        // Deposit the final issue-model state (default in blocking mode —
        // the blocking path never calls `issue`), so snapshots taken after
        // a run capture in-flight async transfers.
        self.dma = dma;
        ExecutionReport {
            cycles: last_issue,
            slots,
            executed,
            broadcasts,
        }
    }

    /// Run a program, taking the pre-decoded fast path when a schedule is
    /// supplied and this system is not tracing. Schedules carry
    /// precomputed accounting for **both** DMA modes (§Perf PR 5): the
    /// blocking model and the async issue/readiness model, each
    /// bit-for-bit the interpreter's. Only tracing systems fall back to
    /// the interpreter (traces need per-instruction event plumbing).
    pub fn run_program(
        &mut self,
        program: &Program,
        schedule: Option<&BroadcastSchedule>,
    ) -> ExecutionReport {
        match schedule {
            Some(s) if self.trace.is_none() => self.run_scheduled(s),
            _ => self.run(program),
        }
    }

    /// Execute a pre-decoded schedule: no per-instruction fetch/dispatch,
    /// no cycle arithmetic, no trace plumbing — just the architectural
    /// effects. The report comes precomputed from compile time, in this
    /// system's DMA mode. (Architectural state evolution is identical in
    /// both DMA modes — the mode only changes *when* instructions issue,
    /// never what they do — so one step vector serves both.)
    fn run_scheduled(&mut self, schedule: &BroadcastSchedule) -> ExecutionReport {
        // Compile-time validation of every broadcast's static coordinates
        // unlocks unchecked frame-buffer plane reads (§Perf); unvalidated
        // schedules keep the interpreter's checked reads (and panics).
        let validated = schedule.is_validated();
        for step in schedule.steps() {
            self.exec_step(step, validated);
        }
        // Same deposit as the interpreter: the schedule's compile-time
        // replay of the issue model ends in exactly the state the
        // interpreter's run-time replay would (async mode), and blocking
        // mode never touches the model.
        self.dma = if self.async_dma { schedule.final_async() } else { AsyncDma::default() };
        schedule.report_for(self.async_dma)
    }

    /// Architectural effect of one pre-decoded step — the shared dispatch
    /// body of the scheduled tier and the megakernel tier's pass-through
    /// steps (one implementation, two executors).
    fn exec_step(&mut self, step: &Step, validated: bool) {
        match *step {
            Step::Plain(instr) => self.exec_plain(&instr),
            Step::Broadcast { mode, plane, cw, line, set, bus_a, bus_b } => {
                // Same effect path as the interpreter's broadcast
                // instructions — one implementation, two dispatchers.
                self.broadcast_impl(mode, plane, cw, line, set, bus_a, bus_b, validated);
            }
            Step::WriteBack { mode, line, set, bank, addr } => {
                let outs = match mode {
                    BroadcastMode::Column => self.array.column_outputs(line),
                    BroadcastMode::Row => self.array.row_outputs(line),
                };
                self.fb.write_slice(set, bank, addr, &outs);
            }
            Step::FusedRun(run) => self.exec_fused(&run, validated),
        }
    }

    /// Execute a compiled [`Megakernel`] (§Perf, megakernel tier): the
    /// whole plan's step stream with register-free DMA loads and
    /// single-call 64-lane tile kernels where the lowering proved them
    /// exact, and the scheduled tier's step dispatch everywhere else.
    /// Tracing systems fall back to the interpreter, exactly as
    /// [`M1System::run_program`] does; the report and the deposited
    /// async-DMA state come precomputed from the wrapped schedule, in this
    /// system's DMA mode.
    pub fn run_megakernel(&mut self, program: &Program, kernel: &Megakernel) -> ExecutionReport {
        if self.trace.is_some() {
            return self.run(program);
        }
        let validated = kernel.schedule().is_validated();
        for step in kernel.steps() {
            match *step {
                MegaStep::Step(ref s) => self.exec_step(s, validated),
                MegaStep::Load { mem_addr, set, bank, fb_addr, words } => {
                    self.exec_mega_load(mem_addr, set, bank, fb_addr, words);
                }
                MegaStep::Tile { plane, cw, set, bus_a, bus_b, wb_set, wb_bank, wb_addr } => {
                    self.exec_tile(plane, cw, set, bus_a, bus_b, wb_set, wb_bank, wb_addr, validated);
                }
            }
        }
        self.dma = if self.async_dma {
            kernel.schedule().final_async()
        } else {
            AsyncDma::default()
        };
        kernel.schedule().report_for(self.async_dma)
    }

    /// A lifted `ldfb`: main memory → frame buffer with the source address
    /// resolved at compile time. Splits each 32-bit word into its two
    /// little-endian `i16` elements on the stack and commits one slice —
    /// element-for-element (and panic-for-panic: memory reads first, then
    /// the frame-buffer write) what [`dma::mem_to_fb`] does, minus the
    /// register read and the per-transfer heap buffer.
    fn exec_mega_load(&mut self, mem_addr: usize, set: Set, bank: Bank, fb_addr: usize, words: usize) {
        debug_assert!(words <= 32, "mega load exceeds the staging buffer");
        let mut buf = [0i16; 2 * 32];
        for w in 0..words {
            let word = self.mem.read_word(mem_addr + w);
            buf[2 * w] = (word & 0xFFFF) as u16 as i16;
            buf[2 * w + 1] = (word >> 16) as u16 as i16;
        }
        self.fb.write_slice(set, bank, fb_addr, &buf[..2 * words]);
    }

    /// One whole 64-point tile (§Perf, megakernel tier). When the context
    /// word drives the dominant shape — both operands off the buses, no
    /// register-file writes, no express drive, no accumulation, an op that
    /// actually overwrites the outputs — the tile commits as: two
    /// contiguous frame-buffer reads, one 64-lane ALU evaluation
    /// ([`alu::eval_tile`], AVX2 under the `avx2-kernels` feature), one
    /// slice write-back, one array commit. That is bit-for-bit the fused
    /// pair's effect: per column `c`, `broadcast_lanes` computes
    /// `out[l][c] = res[c·8+l]` (op ≠ `Nop`), leaves the register files
    /// alone (`reg_write == 0`), releases the express lane (no
    /// `express_write`), and resets or preserves the accumulator
    /// (non-`Mula` ops pass it through `eval8` unchanged); the write-back
    /// run then gathers exactly `res` back out of the columns. Words
    /// outside the shape take the fused pair verbatim.
    #[allow(clippy::too_many_arguments)]
    fn exec_tile(
        &mut self,
        plane: usize,
        cw: usize,
        set: Set,
        bus_a: (Bank, usize),
        bus_b: (Bank, usize),
        wb_set: Set,
        wb_bank: Bank,
        wb_addr: usize,
        validated: bool,
    ) {
        let word = self.ctx.read_decoded(Block::Column, plane, cw);
        let fast = word.operand_plan().is_bus_bus()
            && word.reg_write == 0
            && !word.express_write
            && !word.acc_accumulate
            && word.op != AluOp::Nop
            && word.op != AluOp::Mula;
        if fast {
            // Copy the operand spans out before evaluating: the write-back
            // may alias the sources, and the fused pair's ordering (all
            // reads, then the write) must be preserved exactly.
            let mut a = [0i16; ARRAY_DIM * ARRAY_DIM];
            let mut b = [0i16; ARRAY_DIM * ARRAY_DIM];
            a.copy_from_slice(self.fb.read_slice(set, bus_a.0, bus_a.1, ARRAY_DIM * ARRAY_DIM));
            b.copy_from_slice(self.fb.read_slice(set, bus_b.0, bus_b.1, ARRAY_DIM * ARRAY_DIM));
            let res = alu::eval_tile(word.op, &a, &b, word.imm);
            self.fb.write_slice(wb_set, wb_bank, wb_addr, &res);
            self.array.commit_tile_columns(&res, word.acc_reset);
        } else {
            self.exec_fused(
                &FusedRun::Broadcasts {
                    mode: BroadcastMode::Column,
                    plane,
                    cw,
                    line0: 0,
                    set,
                    bus_a: Some(bus_a),
                    bus_b: Some(bus_b),
                    count: ARRAY_DIM,
                },
                validated,
            );
            self.exec_fused(
                &FusedRun::WriteBacks {
                    mode: BroadcastMode::Column,
                    line0: 0,
                    set: wb_set,
                    bank: wb_bank,
                    addr0: wb_addr,
                    count: ARRAY_DIM,
                },
                validated,
            );
        }
    }

    /// Execute one compile-time-fused run (§Perf, fused tile-kernel
    /// tier): the context word is fetched and classified **once**, then
    /// the run executes as a tight loop over the frame-buffer planes with
    /// 8-wide lane commits — no per-step dispatch, no per-broadcast
    /// re-resolution. Fusion proved every coordinate in range at compile
    /// time (see [`FusedRun`]), and the committed state is bit-for-bit
    /// what the equivalent unfused steps produce (pinned by the fused
    /// conformance sweep in `tests/conformance.rs`).
    fn exec_fused(&mut self, run: &FusedRun, validated: bool) {
        match *run {
            FusedRun::Broadcasts { mode, plane, cw, line0, set, bus_a, bus_b, count } => {
                let block = match mode {
                    BroadcastMode::Column => Block::Column,
                    BroadcastMode::Row => Block::Row,
                };
                let word = self.ctx.read_decoded(block, plane, cw);
                let bus_bus = word.operand_plan().is_bus_bus();
                for i in 0..count {
                    let a = Self::bus_window(&self.fb, set, bus_a, i * ARRAY_DIM, validated);
                    let b = Self::bus_window(&self.fb, set, bus_b, i * ARRAY_DIM, validated);
                    if bus_bus {
                        // The dominant path: both operands stream off the
                        // buses, all 8 lanes commit through the SIMD lane
                        // kernels.
                        self.array.broadcast_lanes(mode, line0 + i, &word, &a, &b);
                    } else {
                        // Interconnect/register-sourced word loaded into a
                        // fused-shaped program: same effects through the
                        // general gather/commit path.
                        self.array.broadcast(mode, line0 + i, &word, &a, &b);
                    }
                }
            }
            FusedRun::WriteBacks { mode, line0, set, bank, addr0, count } => {
                // The run writes one contiguous frame-buffer span: gather
                // all lines into a single buffer and commit it with one
                // slice write (one bounds check, one dirty-span widen).
                let mut buf = [0i16; ARRAY_DIM * ARRAY_DIM];
                for i in 0..count {
                    let outs = match mode {
                        BroadcastMode::Column => self.array.column_outputs(line0 + i),
                        BroadcastMode::Row => self.array.row_outputs(line0 + i),
                    };
                    buf[i * ARRAY_DIM..(i + 1) * ARRAY_DIM].copy_from_slice(&outs);
                }
                self.fb.write_slice(set, bank, addr0, &buf[..count * ARRAY_DIM]);
            }
        }
    }

    /// Architectural effect of a scalar/DMA instruction (the `Plain` steps
    /// of a schedule; broadcasts, write-backs and control flow never
    /// appear here).
    fn exec_plain(&mut self, instr: &Instruction) {
        match *instr {
            Instruction::Ldui { rd, imm } => self.regs.load_upper(rd, imm),
            Instruction::Ldli { rd, imm } => self.regs.load_lower(rd, imm),
            Instruction::Add { rd, rs, rt } => {
                let v = self.regs.read(rs).wrapping_add(self.regs.read(rt));
                self.regs.write(rd, v);
            }
            Instruction::Sub { rd, rs, rt } => {
                let v = self.regs.read(rs).wrapping_sub(self.regs.read(rt));
                self.regs.write(rd, v);
            }
            Instruction::Addi { rd, rs, imm } => {
                let v = self.regs.read(rs).wrapping_add(imm as i32 as u32);
                self.regs.write(rd, v);
            }
            Instruction::Ldfb { rs, set, bank, words, fb_addr } => {
                let mem_addr = self.regs.read(rs) as usize;
                dma::mem_to_fb(&self.mem, &mut self.fb, mem_addr, set, bank, fb_addr, words);
            }
            Instruction::Stfb { rs, set, bank, words, fb_addr } => {
                let mem_addr = self.regs.read(rs) as usize;
                dma::fb_to_mem(&self.fb, &mut self.mem, set, bank, fb_addr, mem_addr, words);
            }
            Instruction::Ldctxt { rs, block, plane, word, count } => {
                let mem_addr = self.regs.read(rs) as usize;
                dma::mem_to_ctx(&self.mem, &mut self.ctx, mem_addr, block, plane, word, count);
            }
            _ => unreachable!("non-plain instruction {instr:?} in schedule"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::morphosys::tinyrisc::asm::assemble;

    /// Build a system with vector U at word 0x100 and V at 0x200.
    fn stage_vectors(u: &[i16], v: &[i16]) -> M1System {
        let mut sys = M1System::new();
        sys.mem.store_elements(0x100, u);
        sys.mem.store_elements(0x200, v);
        // Context word for OUT = A + B at word 0x300.
        sys.mem.write_word(0x300, ContextWord::ADD_AB);
        sys
    }

    #[test]
    fn end_to_end_8_element_translation() {
        let u: Vec<i16> = (1..=8).collect();
        let v: Vec<i16> = (0..8).map(|i| 10 * i).collect();
        let mut sys = stage_vectors(&u, &v);
        let p = assemble(
            "
            ldui   r1, 0x0
            ldli   r1, 0x100
            ldfb   r1, 0, a, 4
            ldui   r2, 0x0
            ldli   r2, 0x200
            ldfb   r2, 0, b, 4
            ldli   r3, 0x300
            ldctxt r3, col, 0, 0, 1
            dbcdc  0, 0, 0, 0, 0x0, 0x0
            wfbi   0, 1, a, 0x0
            ldli   r5, 0x400
            stfb   r5, 1, a, 4
        ",
        )
        .unwrap();
        let report = sys.run(&p);
        let result = sys.mem.load_elements(0x400, 8);
        let expected: Vec<i16> = u.iter().zip(&v).map(|(a, b)| a + b).collect();
        assert_eq!(result, expected);
        assert_eq!(report.broadcasts, 1);
        // Slot accounting: 1+1+5 +1+1+5 +1+4 +1+1 +1+5 = 27 slots; the
        // final stfb issues at cycle 22 (its 5-slot DMA is uncounted).
        assert_eq!(report.slots, 27);
        assert_eq!(report.cycles, 22);
    }

    #[test]
    fn scaling_with_context_immediate() {
        let u: Vec<i16> = (1..=8).collect();
        let mut sys = M1System::new();
        sys.mem.store_elements(0x100, &u);
        sys.mem.write_word(0x300, ContextWord::immediate(crate::morphosys::AluOp::Cmul, 5).encode());
        let p = assemble(
            "
            ldli   r1, 0x100
            ldfb   r1, 0, a, 4
            ldli   r3, 0x300
            ldctxt r3, col, 0, 0, 1
            sbcb   0, 0, 0, 0, a, 0x0
            wfbi   0, 1, a, 0x0
            ldli   r5, 0x400
            stfb   r5, 1, a, 4
        ",
        )
        .unwrap();
        sys.run(&p);
        let result = sys.mem.load_elements(0x400, 8);
        assert_eq!(result, vec![5, 10, 15, 20, 25, 30, 35, 40]);
    }

    #[test]
    fn branch_loop_executes_and_counts_slots() {
        let mut sys = M1System::new();
        let p = assemble(
            "
            ldli r2, 3
            loop:
            addi r2, r2, -1
            bnez r2, loop
            halt
        ",
        )
        .unwrap();
        let r = sys.run(&p);
        // 1 (ldli) + 3×(addi+bnez) + 1 (halt) = 8 slots.
        assert_eq!(r.slots, 8);
        assert_eq!(r.executed, 8);
    }

    #[test]
    fn halt_stops_execution() {
        let mut sys = M1System::new();
        let p = assemble("ldli r1, 5\nhalt\nldli r1, 9").unwrap();
        sys.run(&p);
        assert_eq!(sys.regs.read(crate::morphosys::Reg(1)), 5);
    }

    #[test]
    fn async_dma_mode_overlaps_loads_with_scalar_work() {
        // ldfb issues in 1 slot; the following scalar ops overlap the
        // transfer; the broadcast stalls until the bank is ready.
        let src = "
            ldli   r1, 0x100
            ldfb   r1, 0, a, 32
            ldli   r2, 1
            ldli   r2, 2
            ldli   r3, 0x300
            ldctxt r3, col, 0, 0, 1
            sbcb   0, 0, 0, 0, a, 0x0
            wfbi   0, 1, a, 0x0
        ";
        let p = assemble(src).unwrap();
        let mut sync_sys = M1System::new();
        sync_sys.mem.write_word(0x300, ContextWord::immediate(crate::morphosys::AluOp::Cadd, 1).encode());
        let sync = sync_sys.run(&p);
        let mut async_sys = M1System::new().with_async_dma();
        async_sys.mem.write_word(0x300, ContextWord::immediate(crate::morphosys::AluOp::Cadd, 1).encode());
        let asn = async_sys.run(&p);
        assert!(asn.cycles < sync.cycles, "async {} !< sync {}", asn.cycles, sync.cycles);
        // Sync: 1+32+1+1+1+4+1 = 41 → wfbi at 41.
        assert_eq!(sync.cycles, 41);
        // Async: ldfb issues at 1 (bank ready 33); scalars at 2..5;
        // ldctxt waits engine_free=33 → ctx ready 37; sbcb at 37; wfbi 38.
        assert_eq!(asn.cycles, 38);
    }

    #[test]
    fn async_dma_is_never_slower() {
        use crate::mapping::{runner::run_routine_on, VecVecMapping};
        let routine = VecVecMapping { n: 64, op: crate::morphosys::AluOp::Add }.compile();
        let u: Vec<i16> = (0..64).collect();
        let v = vec![3i16; 64];
        let sync = run_routine_on(&mut M1System::new(), &routine, &u, Some(&v));
        let asn = run_routine_on(&mut M1System::new().with_async_dma(), &routine, &u, Some(&v));
        assert_eq!(sync.result, asn.result, "functional results identical");
        assert!(asn.report.cycles <= sync.report.cycles);
    }

    #[test]
    fn scheduled_execution_matches_interpreter_bit_for_bit() {
        use crate::morphosys::schedule::BroadcastSchedule;
        let src = "
            ldui   r1, 0x0
            ldli   r1, 0x100
            ldfb   r1, 0, a, 4
            ldui   r2, 0x0
            ldli   r2, 0x200
            ldfb   r2, 0, b, 4
            ldli   r3, 0x300
            ldctxt r3, col, 0, 0, 1
            dbcdc  0, 0, 0, 0, 0x0, 0x0
            wfbi   0, 1, a, 0x0
            ldli   r5, 0x400
            stfb   r5, 1, a, 4
        ";
        let p = assemble(src).unwrap();
        let u: Vec<i16> = (1..=8).collect();
        let v: Vec<i16> = (0..8).map(|i| 7 * i - 3).collect();

        let mut interp = stage_vectors(&u, &v);
        let ri = interp.run(&p);

        let schedule = BroadcastSchedule::compile(&p).expect("straight-line program");
        let mut sched = stage_vectors(&u, &v);
        let rs = sched.run_program(&p, Some(&schedule));

        assert_eq!((ri.cycles, ri.slots, ri.executed, ri.broadcasts), (rs.cycles, rs.slots, rs.executed, rs.broadcasts));
        assert_eq!(interp.mem.load_elements(0x400, 8), sched.mem.load_elements(0x400, 8));
        assert_eq!(interp.array.outputs(), sched.array.outputs());
    }

    #[test]
    fn run_program_falls_back_only_for_tracing_systems() {
        use crate::morphosys::schedule::BroadcastSchedule;
        let p = assemble("ldli r1, 5\nldli r2, 6").unwrap();
        let schedule = BroadcastSchedule::compile(&p).unwrap();
        // Tracing system: the fallback interpreter records events.
        let mut traced = M1System::new().with_trace();
        traced.run_program(&p, Some(&schedule));
        assert_eq!(traced.take_trace().unwrap().events.len(), 2);
        // Async system: the scheduled tier runs it (§Perf PR 5), with the
        // precomputed async accounting equal to the interpreter's.
        let mut asn = M1System::new().with_async_dma();
        let rs = asn.run_program(&p, Some(&schedule));
        let ri = M1System::new().with_async_dma().run(&p);
        assert_eq!((rs.cycles, rs.slots, rs.executed), (ri.cycles, ri.slots, ri.executed));
    }

    #[test]
    fn async_scheduled_tier_matches_interpreter_accounting_with_interleaved_dma() {
        // The overlap shape of `async_dma_mode_overlaps_loads_with_scalar
        // _work`, executed through the pre-decoded schedule on an
        // async-DMA system: the precomputed async report must reproduce
        // the interpreter's stall-or-proceed outcome exactly (ldfb at 1,
        // ldctxt queued behind the engine, sbcb stalled to ctx-ready 37,
        // wfbi at 38).
        use crate::morphosys::schedule::BroadcastSchedule;
        let src = "
            ldli   r1, 0x100
            ldfb   r1, 0, a, 32
            ldli   r2, 1
            ldli   r2, 2
            ldli   r3, 0x300
            ldctxt r3, col, 0, 0, 1
            sbcb   0, 0, 0, 0, a, 0x0
            wfbi   0, 1, a, 0x0
        ";
        let p = assemble(src).unwrap();
        let schedule = BroadcastSchedule::compile(&p).unwrap();
        let stage = |sys: &mut M1System| {
            sys.mem
                .write_word(0x300, ContextWord::immediate(crate::morphosys::AluOp::Cadd, 1).encode());
        };
        let mut interp = M1System::new().with_async_dma();
        stage(&mut interp);
        let ri = interp.run(&p);
        let mut sched = M1System::new().with_async_dma();
        stage(&mut sched);
        let rs = sched.run_program(&p, Some(&schedule));
        assert_eq!(rs.cycles, 38);
        assert_eq!((ri.cycles, ri.slots, ri.executed, ri.broadcasts), (rs.cycles, rs.slots, rs.executed, rs.broadcasts));
        assert_eq!(
            interp.fb.read_slice(Set::One, Bank::A, 0, 8),
            sched.fb.read_slice(Set::One, Bank::A, 0, 8),
            "write-back window"
        );
        // The same schedule still reports blocking accounting on a
        // blocking system (41-cycle wfbi issue — see the overlap test).
        let mut blocking = M1System::new();
        stage(&mut blocking);
        let rb = blocking.run_program(&p, Some(&schedule));
        assert_eq!(rb.cycles, 41);
    }

    #[test]
    fn reset_chip_dirty_range_tracking_equals_full_zeroing() {
        // Interleave routines that touch disjoint frame-buffer ranges —
        // the §5.1 mapping (banks A/B of both sets at 0..64), the
        // streamed tiled mapping (ping-pongs sets, results at 512..), and
        // direct writes at the top of a bank — and assert that after
        // every reset_chip the chip state is indistinguishable from a
        // fresh system's (the dirty-span clear must equal a full 16 KiB
        // zeroing).
        use crate::mapping::{runner::run_routine_on, TiledVecVecMapping, VecVecMapping};
        use crate::morphosys::frame_buffer::BANK_ELEMS;

        let assert_chip_fresh = |sys: &M1System| {
            let fresh = M1System::new();
            for set in [Set::Zero, Set::One] {
                for bank in [Bank::A, Bank::B] {
                    assert_eq!(
                        sys.fb.read_slice(set, bank, 0, BANK_ELEMS),
                        fresh.fb.read_slice(set, bank, 0, BANK_ELEMS),
                        "FB {set:?}/{bank:?} residue after reset_chip"
                    );
                }
            }
            assert_eq!(sys.array.outputs(), fresh.array.outputs());
        };

        let mut sys = M1System::new();
        let u: Vec<i16> = (0..64).map(|i| i - 11).collect();
        let v: Vec<i16> = (0..64).map(|i| 2 * i + 1).collect();
        run_routine_on(&mut sys, &VecVecMapping { n: 64, op: crate::morphosys::AluOp::Add }.compile(), &u, Some(&v));
        sys.reset_chip();
        assert_chip_fresh(&sys);

        let n = 128;
        let tu: Vec<i16> = (0..n as i16).collect();
        let tv = vec![7i16; n];
        let tiled = TiledVecVecMapping { n, op: crate::morphosys::AluOp::Add, streamed: true }.compile();
        run_routine_on(&mut sys, &tiled, &tu, Some(&tv));
        sys.fb.write(Set::One, Bank::B, BANK_ELEMS - 1, 99);
        sys.reset_chip();
        assert_chip_fresh(&sys);

        // A routine after the reset computes from clean state.
        let out = run_routine_on(&mut sys, &VecVecMapping { n: 8, op: crate::morphosys::AluOp::Add }.compile(), &u[..8], Some(&v[..8]));
        let expected: Vec<i16> = u[..8].iter().zip(&v[..8]).map(|(a, b)| a + b).collect();
        assert_eq!(out.result, expected);
    }

    #[test]
    fn trace_records_every_instruction() {
        let mut sys = M1System::new().with_trace();
        let p = assemble("ldli r1, 5\nnop\nhalt").unwrap();
        sys.run(&p);
        let trace = sys.take_trace().unwrap();
        assert_eq!(trace.events.len(), 3);
        assert_eq!(trace.events[1].effect, "nop");
    }

    #[test]
    fn row_broadcast_and_writeback() {
        let u: Vec<i16> = (1..=8).collect();
        let mut sys = M1System::new();
        sys.mem.store_elements(0x100, &u);
        sys.mem.write_word(0x300, ContextWord::immediate(crate::morphosys::AluOp::Cadd, 7).encode());
        let p = assemble(
            "
            ldli   r1, 0x100
            ldfb   r1, 0, a, 4
            ldli   r3, 0x300
            ldctxt r3, row, 0, 0, 1
            sbcbr  0, 0, 2, 0, a, 0x0
            wfbir  2, 1, b, 0x8
            ldli   r5, 0x400
            stfb   r5, 1, b, 4, 0x8
        ",
        )
        .unwrap();
        sys.run(&p);
        assert_eq!(sys.mem.load_elements(0x400, 8), vec![8, 9, 10, 11, 12, 13, 14, 15]);
    }
}
