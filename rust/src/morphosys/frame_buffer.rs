//! The frame buffer: the M1's on-chip data staging memory.
//!
//! Organised as **two sets × two banks** of 16-bit elements. The two banks
//! of a set feed the RC array's two operand buses (bank A → mux A's
//! operand bus, bank B → mux B's), which is what makes single-cycle
//! vector-vector operations possible. The two *sets* double-buffer: the
//! DMA controller fills one set while the RC array streams from the other
//! ("new application data can be loaded into it without interrupting the
//! operation of the RC array").
//!
//! Addresses are element (16-bit) granular.

use crate::morphosys::rc_array::ARRAY_DIM;

/// Elements per bank. Sized generously (the real FB is 2×128×64 bits);
/// capacity only bounds workload size, not timing.
pub const BANK_ELEMS: usize = 2048;

/// Frame-buffer set select (double buffering).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Set {
    Zero,
    One,
}

impl Set {
    pub fn index(self) -> usize {
        match self {
            Set::Zero => 0,
            Set::One => 1,
        }
    }

    pub fn from_index(i: usize) -> Set {
        if i == 0 {
            Set::Zero
        } else {
            Set::One
        }
    }
}

/// Frame-buffer bank select (operand bus A / B).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Bank {
    A,
    B,
}

impl Bank {
    pub fn index(self) -> usize {
        match self {
            Bank::A => 0,
            Bank::B => 1,
        }
    }

    pub fn from_index(i: usize) -> Bank {
        if i == 0 {
            Bank::A
        } else {
            Bank::B
        }
    }
}

/// The frame buffer.
#[derive(Debug, Clone)]
pub struct FrameBuffer {
    // [set][bank][element]
    data: Vec<i16>,
}

impl Default for FrameBuffer {
    fn default() -> Self {
        Self::new()
    }
}

impl FrameBuffer {
    pub fn new() -> FrameBuffer {
        FrameBuffer { data: vec![0; 2 * 2 * BANK_ELEMS] }
    }

    /// Zero all contents in place (no reallocation).
    pub fn clear(&mut self) {
        self.data.fill(0);
    }

    fn base(set: Set, bank: Bank) -> usize {
        (set.index() * 2 + bank.index()) * BANK_ELEMS
    }

    /// Read one element.
    pub fn read(&self, set: Set, bank: Bank, addr: usize) -> i16 {
        assert!(addr < BANK_ELEMS, "FB read {addr} out of range");
        self.data[Self::base(set, bank) + addr]
    }

    /// Write one element.
    pub fn write(&mut self, set: Set, bank: Bank, addr: usize, value: i16) {
        assert!(addr < BANK_ELEMS, "FB write {addr} out of range");
        self.data[Self::base(set, bank) + addr] = value;
    }

    /// Write a slice starting at `addr` (DMA fill).
    pub fn write_slice(&mut self, set: Set, bank: Bank, addr: usize, values: &[i16]) {
        assert!(addr + values.len() <= BANK_ELEMS, "FB fill out of range");
        let base = Self::base(set, bank) + addr;
        self.data[base..base + values.len()].copy_from_slice(values);
    }

    /// Read `len` elements starting at `addr` (DMA drain).
    pub fn read_slice(&self, set: Set, bank: Bank, addr: usize, len: usize) -> &[i16] {
        assert!(addr + len <= BANK_ELEMS, "FB drain out of range");
        let base = Self::base(set, bank) + addr;
        &self.data[base..base + len]
    }

    /// Fetch the eight consecutive elements an operand bus delivers for a
    /// broadcast step starting at `addr`.
    pub fn operand_bus(&self, set: Set, bank: Bank, addr: usize) -> [i16; ARRAY_DIM] {
        let mut bus = [0i16; ARRAY_DIM];
        for (i, v) in bus.iter_mut().enumerate() {
            *v = self.read(set, bank, addr + i);
        }
        bus
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sets_and_banks_are_disjoint() {
        let mut fb = FrameBuffer::new();
        fb.write(Set::Zero, Bank::A, 5, 10);
        fb.write(Set::Zero, Bank::B, 5, 20);
        fb.write(Set::One, Bank::A, 5, 30);
        fb.write(Set::One, Bank::B, 5, 40);
        assert_eq!(fb.read(Set::Zero, Bank::A, 5), 10);
        assert_eq!(fb.read(Set::Zero, Bank::B, 5), 20);
        assert_eq!(fb.read(Set::One, Bank::A, 5), 30);
        assert_eq!(fb.read(Set::One, Bank::B, 5), 40);
    }

    #[test]
    fn slice_roundtrip() {
        let mut fb = FrameBuffer::new();
        let v: Vec<i16> = (0..64).collect();
        fb.write_slice(Set::Zero, Bank::A, 100, &v);
        assert_eq!(fb.read_slice(Set::Zero, Bank::A, 100, 64), &v[..]);
    }

    #[test]
    fn operand_bus_reads_eight_consecutive() {
        let mut fb = FrameBuffer::new();
        let v: Vec<i16> = (10..26).collect();
        fb.write_slice(Set::One, Bank::B, 8, &v);
        assert_eq!(fb.operand_bus(Set::One, Bank::B, 8), [10, 11, 12, 13, 14, 15, 16, 17]);
        assert_eq!(fb.operand_bus(Set::One, Bank::B, 16), [18, 19, 20, 21, 22, 23, 24, 25]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_read_panics() {
        FrameBuffer::new().read(Set::Zero, Bank::A, BANK_ELEMS);
    }

    #[test]
    fn set_bank_index_roundtrip() {
        assert_eq!(Set::from_index(Set::Zero.index()), Set::Zero);
        assert_eq!(Set::from_index(Set::One.index()), Set::One);
        assert_eq!(Bank::from_index(Bank::A.index()), Bank::A);
        assert_eq!(Bank::from_index(Bank::B.index()), Bank::B);
    }
}
