//! The frame buffer: the M1's on-chip data staging memory.
//!
//! Organised as **two sets × two banks** of 16-bit elements. The two banks
//! of a set feed the RC array's two operand buses (bank A → mux A's
//! operand bus, bank B → mux B's), which is what makes single-cycle
//! vector-vector operations possible. The two *sets* double-buffer: the
//! DMA controller fills one set while the RC array streams from the other
//! ("new application data can be loaded into it without interrupting the
//! operation of the RC array").
//!
//! Addresses are element (16-bit) granular.

use crate::morphosys::rc_array::ARRAY_DIM;

/// Elements per bank. Sized generously (the real FB is 2×128×64 bits);
/// capacity only bounds workload size, not timing.
pub const BANK_ELEMS: usize = 2048;

/// Frame-buffer set select (double buffering).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Set {
    Zero,
    One,
}

impl Set {
    pub fn index(self) -> usize {
        match self {
            Set::Zero => 0,
            Set::One => 1,
        }
    }

    pub fn from_index(i: usize) -> Set {
        if i == 0 {
            Set::Zero
        } else {
            Set::One
        }
    }
}

/// Frame-buffer bank select (operand bus A / B).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Bank {
    A,
    B,
}

impl Bank {
    pub fn index(self) -> usize {
        match self {
            Bank::A => 0,
            Bank::B => 1,
        }
    }

    pub fn from_index(i: usize) -> Bank {
        if i == 0 {
            Bank::A
        } else {
            Bank::B
        }
    }
}

/// Per-bank dirty span sentinel: `lo >= hi` means the bank is clean.
const CLEAN: (usize, usize) = (BANK_ELEMS, 0);

/// The frame buffer.
#[derive(Debug, Clone)]
pub struct FrameBuffer {
    // [set][bank][element]
    data: Vec<i16>,
    /// Per-(set, bank) dirty span: the half-open element range written
    /// since the last [`FrameBuffer::clear`]. Routines touch a few dozen
    /// elements per bank, so `clear` zeroes only these spans instead of
    /// the full 16 KiB — the dominant cost of `reset_chip` on a reused
    /// system (§Perf).
    dirty: [(usize, usize); 4],
}

impl Default for FrameBuffer {
    fn default() -> Self {
        Self::new()
    }
}

impl FrameBuffer {
    pub fn new() -> FrameBuffer {
        FrameBuffer { data: vec![0; 2 * 2 * BANK_ELEMS], dirty: [CLEAN; 4] }
    }

    /// Zero all written contents in place (no reallocation): only the
    /// dirty span of each bank is touched, which is equivalent to a full
    /// zeroing because every write path widens the span.
    pub fn clear(&mut self) {
        for (bank, span) in self.dirty.iter_mut().enumerate() {
            if span.0 < span.1 {
                let base = bank * BANK_ELEMS;
                self.data[base + span.0..base + span.1].fill(0);
                *span = CLEAN;
            }
        }
    }

    fn base(set: Set, bank: Bank) -> usize {
        (set.index() * 2 + bank.index()) * BANK_ELEMS
    }

    /// Widen a bank's dirty span to cover `[lo, hi)`.
    #[inline]
    fn mark_dirty(&mut self, set: Set, bank: Bank, lo: usize, hi: usize) {
        let span = &mut self.dirty[set.index() * 2 + bank.index()];
        span.0 = span.0.min(lo);
        span.1 = span.1.max(hi);
    }

    /// Read one element.
    pub fn read(&self, set: Set, bank: Bank, addr: usize) -> i16 {
        assert!(addr < BANK_ELEMS, "FB read {addr} out of range");
        self.data[Self::base(set, bank) + addr]
    }

    /// Write one element.
    pub fn write(&mut self, set: Set, bank: Bank, addr: usize, value: i16) {
        assert!(addr < BANK_ELEMS, "FB write {addr} out of range");
        self.mark_dirty(set, bank, addr, addr + 1);
        self.data[Self::base(set, bank) + addr] = value;
    }

    /// Write a slice starting at `addr` (DMA fill).
    pub fn write_slice(&mut self, set: Set, bank: Bank, addr: usize, values: &[i16]) {
        assert!(addr + values.len() <= BANK_ELEMS, "FB fill out of range");
        if values.is_empty() {
            return;
        }
        self.mark_dirty(set, bank, addr, addr + values.len());
        let base = Self::base(set, bank) + addr;
        self.data[base..base + values.len()].copy_from_slice(values);
    }

    /// Read `len` elements starting at `addr` (DMA drain).
    pub fn read_slice(&self, set: Set, bank: Bank, addr: usize, len: usize) -> &[i16] {
        assert!(addr + len <= BANK_ELEMS, "FB drain out of range");
        let base = Self::base(set, bank) + addr;
        &self.data[base..base + len]
    }

    /// Fetch the eight consecutive elements an operand bus delivers for a
    /// broadcast step starting at `addr`.
    pub fn operand_bus(&self, set: Set, bank: Bank, addr: usize) -> [i16; ARRAY_DIM] {
        let mut bus = [0i16; ARRAY_DIM];
        for (i, v) in bus.iter_mut().enumerate() {
            *v = self.read(set, bank, addr + i);
        }
        bus
    }

    /// Raw storage plus dirty spans, for [`crate::morphosys::snapshot`]:
    /// the flat `[set][bank][element]` plane and the four per-bank spans
    /// (needed so a restored buffer's `clear` stays equivalent to full
    /// zeroing).
    pub(crate) fn snapshot_parts(&self) -> (&[i16], &[(usize, usize); 4]) {
        (&self.data, &self.dirty)
    }

    /// Restore from a [`FrameBuffer::snapshot_parts`] image.
    pub(crate) fn restore_parts(&mut self, data: &[i16], dirty: [(usize, usize); 4]) {
        assert_eq!(data.len(), self.data.len(), "FB snapshot size mismatch");
        self.data.copy_from_slice(data);
        self.dirty = dirty;
    }

    /// [`FrameBuffer::operand_bus`] without the per-element bounds checks,
    /// for broadcast steps whose bus addresses were validated when their
    /// [`BroadcastSchedule`] compiled (§Perf).
    ///
    /// Callers must guarantee `addr + ARRAY_DIM <= BANK_ELEMS`; the
    /// schedule compiler proves this for every static bus address before
    /// marking a schedule validated, and the debug assertion keeps the
    /// contract checked in debug/test builds.
    ///
    /// [`BroadcastSchedule`]: crate::morphosys::BroadcastSchedule
    #[inline]
    pub(crate) fn operand_bus_validated(&self, set: Set, bank: Bank, addr: usize) -> [i16; ARRAY_DIM] {
        debug_assert!(addr + ARRAY_DIM <= BANK_ELEMS, "validated FB read {addr} out of range");
        let base = Self::base(set, bank) + addr;
        let mut bus = [0i16; ARRAY_DIM];
        // SAFETY: `base + ARRAY_DIM <= data.len()` — `base` offsets by
        // whole banks and `addr + ARRAY_DIM <= BANK_ELEMS` is established
        // at schedule-compile time (re-checked by the debug assertion).
        bus.copy_from_slice(unsafe { self.data.get_unchecked(base..base + ARRAY_DIM) });
        bus
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sets_and_banks_are_disjoint() {
        let mut fb = FrameBuffer::new();
        fb.write(Set::Zero, Bank::A, 5, 10);
        fb.write(Set::Zero, Bank::B, 5, 20);
        fb.write(Set::One, Bank::A, 5, 30);
        fb.write(Set::One, Bank::B, 5, 40);
        assert_eq!(fb.read(Set::Zero, Bank::A, 5), 10);
        assert_eq!(fb.read(Set::Zero, Bank::B, 5), 20);
        assert_eq!(fb.read(Set::One, Bank::A, 5), 30);
        assert_eq!(fb.read(Set::One, Bank::B, 5), 40);
    }

    #[test]
    fn slice_roundtrip() {
        let mut fb = FrameBuffer::new();
        let v: Vec<i16> = (0..64).collect();
        fb.write_slice(Set::Zero, Bank::A, 100, &v);
        assert_eq!(fb.read_slice(Set::Zero, Bank::A, 100, 64), &v[..]);
    }

    #[test]
    fn operand_bus_reads_eight_consecutive() {
        let mut fb = FrameBuffer::new();
        let v: Vec<i16> = (10..26).collect();
        fb.write_slice(Set::One, Bank::B, 8, &v);
        assert_eq!(fb.operand_bus(Set::One, Bank::B, 8), [10, 11, 12, 13, 14, 15, 16, 17]);
        assert_eq!(fb.operand_bus(Set::One, Bank::B, 16), [18, 19, 20, 21, 22, 23, 24, 25]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_read_panics() {
        FrameBuffer::new().read(Set::Zero, Bank::A, BANK_ELEMS);
    }

    /// Assert the buffer is indistinguishable from a freshly constructed
    /// one, across all four banks.
    fn assert_fully_zero(fb: &FrameBuffer) {
        for set in [Set::Zero, Set::One] {
            for bank in [Bank::A, Bank::B] {
                assert_eq!(
                    fb.read_slice(set, bank, 0, BANK_ELEMS),
                    &[0i16; BANK_ELEMS][..],
                    "{set:?}/{bank:?} not fully zeroed"
                );
            }
        }
    }

    #[test]
    fn dirty_range_clear_equals_full_zeroing() {
        // Disjoint ranges across banks, including the top of a bank: the
        // span-based clear must leave no residue anywhere.
        let mut fb = FrameBuffer::new();
        fb.write_slice(Set::Zero, Bank::A, 0, &[7; 64]);
        fb.write_slice(Set::Zero, Bank::B, 512, &[-3; 64]);
        fb.write_slice(Set::One, Bank::A, BANK_ELEMS - 8, &[9; 8]);
        fb.write(Set::One, Bank::B, 1, 42);
        fb.write(Set::One, Bank::B, 2000, -1);
        fb.clear();
        assert_fully_zero(&fb);
        // Clearing a clean buffer is a no-op, and writes after a clear
        // re-mark their spans.
        fb.clear();
        fb.write(Set::Zero, Bank::A, 100, 5);
        fb.clear();
        assert_fully_zero(&fb);
    }

    #[test]
    fn empty_write_slice_marks_nothing() {
        let mut fb = FrameBuffer::new();
        fb.write_slice(Set::Zero, Bank::A, BANK_ELEMS, &[]);
        assert_eq!(fb.dirty, [CLEAN; 4]);
    }

    #[test]
    fn validated_operand_bus_matches_checked_reads() {
        let mut fb = FrameBuffer::new();
        let v: Vec<i16> = (0..64).map(|i| 3 * i - 40).collect();
        fb.write_slice(Set::One, Bank::B, BANK_ELEMS - 64, &v);
        for addr in [0, 8, 1024, BANK_ELEMS - 64, BANK_ELEMS - ARRAY_DIM] {
            assert_eq!(
                fb.operand_bus_validated(Set::One, Bank::B, addr),
                fb.operand_bus(Set::One, Bank::B, addr),
                "addr {addr}"
            );
        }
    }

    #[test]
    fn set_bank_index_roundtrip() {
        assert_eq!(Set::from_index(Set::Zero.index()), Set::Zero);
        assert_eq!(Set::from_index(Set::One.index()), Set::One);
        assert_eq!(Bank::from_index(Bank::A.index()), Bank::A);
        assert_eq!(Bank::from_index(Bank::B.index()), Bank::B);
    }
}
