//! The TinyRISC instruction set (the subset exercised by the paper's
//! listings, plus scalar/branch instructions for loop-driven workloads).

use crate::morphosys::context_memory::Block;
use crate::morphosys::frame_buffer::{Bank, Set};
use crate::morphosys::rc_array::BroadcastMode;
use crate::morphosys::timing;

/// TinyRISC register index (r0 is hardwired to zero).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Reg(pub u8);

impl Reg {
    pub const R0: Reg = Reg(0);

    pub fn index(self) -> usize {
        (self.0 & 0xF) as usize
    }
}

/// One TinyRISC instruction. `Copy` (all fields are small scalars) so the
/// interpreter fetch and the schedule pre-decode never heap-clone; `Hash`
/// so compiled programs can key the pre-decoded-schedule cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Instruction {
    /// `ldui rd, imm` — load upper immediate: `rd ← imm << 16`.
    Ldui { rd: Reg, imm: u16 },
    /// `ldli rd, imm` — load lower immediate: `rd ← (rd & 0xFFFF0000) | imm`.
    Ldli { rd: Reg, imm: u16 },
    /// `add rd, rs, rt` (with rd=rs=rt=r0 this is the canonical NOP).
    Add { rd: Reg, rs: Reg, rt: Reg },
    /// `sub rd, rs, rt`.
    Sub { rd: Reg, rs: Reg, rt: Reg },
    /// `addi rd, rs, imm`.
    Addi { rd: Reg, rs: Reg, imm: i16 },
    /// `ldfb rs, set, bank, words[, fb_addr]` — DMA main→FB: `words`
    /// 32-bit words (2 elements each) from main memory at address `rs`
    /// into the frame buffer starting at element `fb_addr`.
    Ldfb { rs: Reg, set: Set, bank: Bank, words: usize, fb_addr: usize },
    /// `stfb rs, set, bank, words[, fb_addr]` — DMA FB→main.
    Stfb { rs: Reg, set: Set, bank: Bank, words: usize, fb_addr: usize },
    /// `ldctxt rs, block, plane, word, count` — DMA main→context memory.
    Ldctxt { rs: Reg, block: Block, plane: usize, word: usize, count: usize },
    /// `dbcdc plane, cw, col, set, addr_a, addr_b` — *double-bank column
    /// broadcast*: trigger column `col` with context word `cw` of the
    /// column block, operand bus A fed from `FB[set][A][addr_a..]`, bus B
    /// from `FB[set][B][addr_b..]` (Table 1's workhorse).
    Dbcdc { plane: usize, cw: usize, col: usize, set: Set, addr_a: usize, addr_b: usize },
    /// `sbcb plane, cw, col, set, bank, addr` — *single-bank column
    /// broadcast*: one operand bus only (Table 2's workhorse; the scalar
    /// comes from the context-word immediate).
    Sbcb { plane: usize, cw: usize, col: usize, set: Set, bank: Bank, addr: usize },
    /// `dbcdr plane, cw, row, set, addr_a, addr_b` — row-mode double-bank
    /// broadcast.
    Dbcdr { plane: usize, cw: usize, row: usize, set: Set, addr_a: usize, addr_b: usize },
    /// `sbcbr plane, cw, row, set, bank, addr` — row-mode single-bank
    /// broadcast.
    Sbcbr { plane: usize, cw: usize, row: usize, set: Set, bank: Bank, addr: usize },
    /// `wfbi col, set, bank, addr` — write the eight output registers of
    /// column `col` back to the frame buffer.
    Wfbi { col: usize, set: Set, bank: Bank, addr: usize },
    /// `wfbir row, set, bank, addr` — row variant of `wfbi`.
    Wfbir { row: usize, set: Set, bank: Bank, addr: usize },
    /// `jmp target` — unconditional branch to instruction index.
    Jmp { target: usize },
    /// `bnez rs, target` — branch if `rs != 0`.
    Bnez { rs: Reg, target: usize },
    /// `halt` — stop execution.
    Halt,
}

impl Instruction {
    /// Canonical NOP (`add r0, r0, r0`), as used throughout the paper's
    /// listings.
    pub const NOP: Instruction = Instruction::Add { rd: Reg::R0, rs: Reg::R0, rt: Reg::R0 };

    /// Issue slots this instruction occupies (see [`timing`]): DMA
    /// instructions hold the issue stage for the bus transfer; everything
    /// else is single-cycle.
    pub fn issue_slots(&self) -> u64 {
        match self {
            Instruction::Ldfb { words, .. } | Instruction::Stfb { words, .. } => {
                timing::fb_dma_slots(*words)
            }
            Instruction::Ldctxt { count, .. } => timing::ctx_dma_slots(*count),
            _ => 1,
        }
    }

    /// Broadcast mode of a broadcast instruction, if any.
    pub fn broadcast_mode(&self) -> Option<BroadcastMode> {
        match self {
            Instruction::Dbcdc { .. } | Instruction::Sbcb { .. } => Some(BroadcastMode::Column),
            Instruction::Dbcdr { .. } | Instruction::Sbcbr { .. } => Some(BroadcastMode::Row),
            _ => None,
        }
    }
}

/// A TinyRISC program: a flat instruction vector, index == PC.
#[derive(Debug, Clone, Default, PartialEq, Eq, Hash)]
pub struct Program {
    pub instructions: Vec<Instruction>,
}

impl Program {
    pub fn new(instructions: Vec<Instruction>) -> Program {
        Program { instructions }
    }

    pub fn len(&self) -> usize {
        self.instructions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instructions.is_empty()
    }

    /// Total issue slots the program occupies when executed straight-line
    /// (no branches) — the static cost model used by
    /// [`crate::mapping::plan`].
    pub fn straight_line_slots(&self) -> u64 {
        self.instructions.iter().map(Instruction::issue_slots).sum()
    }

    /// The paper's cycle-count convention: the cycle index at which the
    /// final instruction of a straight-line routine **issues** (Table 1's
    /// listing ends with its `stfb` at instruction index 96 and is
    /// reported as "96 cycles" — the trailing store DMA is not counted).
    pub fn paper_cycles(&self) -> u64 {
        let last = self.instructions.last().map(Instruction::issue_slots).unwrap_or(0);
        self.straight_line_slots() - last
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nop_is_add_r0() {
        assert_eq!(
            Instruction::NOP,
            Instruction::Add { rd: Reg::R0, rs: Reg::R0, rt: Reg::R0 }
        );
        assert_eq!(Instruction::NOP.issue_slots(), 1);
    }

    #[test]
    fn dma_instructions_occupy_bus_slots() {
        let ldfb = Instruction::Ldfb {
            rs: Reg(1),
            set: Set::Zero,
            bank: Bank::A,
            words: 32,
            fb_addr: 0,
        };
        assert_eq!(ldfb.issue_slots(), 32);
        let short = Instruction::Ldfb {
            rs: Reg(1),
            set: Set::Zero,
            bank: Bank::A,
            words: 4,
            fb_addr: 0,
        };
        assert_eq!(short.issue_slots(), 5);
        let ldctxt = Instruction::Ldctxt {
            rs: Reg(3),
            block: Block::Column,
            plane: 0,
            word: 0,
            count: 1,
        };
        assert_eq!(ldctxt.issue_slots(), 4);
    }

    #[test]
    fn broadcast_modes() {
        let col = Instruction::Dbcdc { plane: 0, cw: 0, col: 0, set: Set::Zero, addr_a: 0, addr_b: 0 };
        assert_eq!(col.broadcast_mode(), Some(BroadcastMode::Column));
        let row = Instruction::Sbcbr { plane: 0, cw: 0, row: 2, set: Set::Zero, bank: Bank::A, addr: 0 };
        assert_eq!(row.broadcast_mode(), Some(BroadcastMode::Row));
        assert_eq!(Instruction::NOP.broadcast_mode(), None);
    }

    #[test]
    fn straight_line_slot_accounting() {
        let p = Program::new(vec![
            Instruction::Ldui { rd: Reg(1), imm: 1 },
            Instruction::Ldfb { rs: Reg(1), set: Set::Zero, bank: Bank::A, words: 32, fb_addr: 0 },
            Instruction::Halt,
        ]);
        assert_eq!(p.straight_line_slots(), 1 + 32 + 1);
        // paper_cycles = issue index of the final instruction.
        assert_eq!(p.paper_cycles(), 33);
        // A program ending in a DMA does not count the trailing transfer.
        let p2 = Program::new(vec![
            Instruction::Ldui { rd: Reg(1), imm: 1 },
            Instruction::Stfb { rs: Reg(1), set: Set::Zero, bank: Bank::A, words: 32, fb_addr: 0 },
        ]);
        assert_eq!(p2.paper_cycles(), 1);
    }
}
