//! The TinyRISC instruction set (the subset exercised by the paper's
//! listings, plus scalar/branch instructions for loop-driven workloads).

use crate::morphosys::context_memory::Block;
use crate::morphosys::frame_buffer::{Bank, Set};
use crate::morphosys::rc_array::BroadcastMode;
use crate::morphosys::timing;

/// TinyRISC register index (r0 is hardwired to zero).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Reg(pub u8);

impl Reg {
    pub const R0: Reg = Reg(0);

    pub fn index(self) -> usize {
        (self.0 & 0xF) as usize
    }
}

/// One TinyRISC instruction. `Copy` (all fields are small scalars) so the
/// interpreter fetch and the schedule pre-decode never heap-clone; `Hash`
/// so compiled programs can key the pre-decoded-schedule cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Instruction {
    /// `ldui rd, imm` — load upper immediate: `rd ← imm << 16`.
    Ldui { rd: Reg, imm: u16 },
    /// `ldli rd, imm` — load lower immediate: `rd ← (rd & 0xFFFF0000) | imm`.
    Ldli { rd: Reg, imm: u16 },
    /// `add rd, rs, rt` (with rd=rs=rt=r0 this is the canonical NOP).
    Add { rd: Reg, rs: Reg, rt: Reg },
    /// `sub rd, rs, rt`.
    Sub { rd: Reg, rs: Reg, rt: Reg },
    /// `addi rd, rs, imm`.
    Addi { rd: Reg, rs: Reg, imm: i16 },
    /// `ldfb rs, set, bank, words[, fb_addr]` — DMA main→FB: `words`
    /// 32-bit words (2 elements each) from main memory at address `rs`
    /// into the frame buffer starting at element `fb_addr`.
    Ldfb { rs: Reg, set: Set, bank: Bank, words: usize, fb_addr: usize },
    /// `stfb rs, set, bank, words[, fb_addr]` — DMA FB→main.
    Stfb { rs: Reg, set: Set, bank: Bank, words: usize, fb_addr: usize },
    /// `ldctxt rs, block, plane, word, count` — DMA main→context memory.
    Ldctxt { rs: Reg, block: Block, plane: usize, word: usize, count: usize },
    /// `dbcdc plane, cw, col, set, addr_a, addr_b` — *double-bank column
    /// broadcast*: trigger column `col` with context word `cw` of the
    /// column block, operand bus A fed from `FB[set][A][addr_a..]`, bus B
    /// from `FB[set][B][addr_b..]` (Table 1's workhorse).
    Dbcdc { plane: usize, cw: usize, col: usize, set: Set, addr_a: usize, addr_b: usize },
    /// `sbcb plane, cw, col, set, bank, addr` — *single-bank column
    /// broadcast*: one operand bus only (Table 2's workhorse; the scalar
    /// comes from the context-word immediate).
    Sbcb { plane: usize, cw: usize, col: usize, set: Set, bank: Bank, addr: usize },
    /// `dbcdr plane, cw, row, set, addr_a, addr_b` — row-mode double-bank
    /// broadcast.
    Dbcdr { plane: usize, cw: usize, row: usize, set: Set, addr_a: usize, addr_b: usize },
    /// `sbcbr plane, cw, row, set, bank, addr` — row-mode single-bank
    /// broadcast.
    Sbcbr { plane: usize, cw: usize, row: usize, set: Set, bank: Bank, addr: usize },
    /// `wfbi col, set, bank, addr` — write the eight output registers of
    /// column `col` back to the frame buffer.
    Wfbi { col: usize, set: Set, bank: Bank, addr: usize },
    /// `wfbir row, set, bank, addr` — row variant of `wfbi`.
    Wfbir { row: usize, set: Set, bank: Bank, addr: usize },
    /// `jmp target` — unconditional branch to instruction index.
    Jmp { target: usize },
    /// `bnez rs, target` — branch if `rs != 0`.
    Bnez { rs: Reg, target: usize },
    /// `halt` — stop execution.
    Halt,
}

impl Instruction {
    /// Canonical NOP (`add r0, r0, r0`), as used throughout the paper's
    /// listings.
    pub const NOP: Instruction = Instruction::Add { rd: Reg::R0, rs: Reg::R0, rt: Reg::R0 };

    /// Issue slots this instruction occupies (see [`timing`]): DMA
    /// instructions hold the issue stage for the bus transfer; everything
    /// else is single-cycle.
    pub fn issue_slots(&self) -> u64 {
        match self {
            Instruction::Ldfb { words, .. } | Instruction::Stfb { words, .. } => {
                timing::fb_dma_slots(*words)
            }
            Instruction::Ldctxt { count, .. } => timing::ctx_dma_slots(*count),
            _ => 1,
        }
    }

    /// Broadcast mode of a broadcast instruction, if any.
    pub fn broadcast_mode(&self) -> Option<BroadcastMode> {
        match self {
            Instruction::Dbcdc { .. } | Instruction::Sbcb { .. } => Some(BroadcastMode::Column),
            Instruction::Dbcdr { .. } | Instruction::Sbcbr { .. } => Some(BroadcastMode::Row),
            _ => None,
        }
    }

    /// Append this instruction's tag-byte encoding (little-endian fields)
    /// — the portable program codec used by repro artifacts
    /// ([`crate::replay`]). One tag byte per variant, fields in
    /// declaration order; `usize` fields travel as `u32` (all in-range
    /// values fit: addresses are bounded by the 2 MiB memory and word
    /// counts by the frame buffer).
    pub fn encode_bytes(&self, out: &mut Vec<u8>) {
        let u32f = |out: &mut Vec<u8>, v: usize| out.extend_from_slice(&(v as u32).to_le_bytes());
        match *self {
            Instruction::Ldui { rd, imm } => {
                out.push(0);
                out.push(rd.0);
                out.extend_from_slice(&imm.to_le_bytes());
            }
            Instruction::Ldli { rd, imm } => {
                out.push(1);
                out.push(rd.0);
                out.extend_from_slice(&imm.to_le_bytes());
            }
            Instruction::Add { rd, rs, rt } => out.extend_from_slice(&[2, rd.0, rs.0, rt.0]),
            Instruction::Sub { rd, rs, rt } => out.extend_from_slice(&[3, rd.0, rs.0, rt.0]),
            Instruction::Addi { rd, rs, imm } => {
                out.extend_from_slice(&[4, rd.0, rs.0]);
                out.extend_from_slice(&imm.to_le_bytes());
            }
            Instruction::Ldfb { rs, set, bank, words, fb_addr } => {
                out.extend_from_slice(&[5, rs.0, set.index() as u8, bank.index() as u8]);
                u32f(out, words);
                u32f(out, fb_addr);
            }
            Instruction::Stfb { rs, set, bank, words, fb_addr } => {
                out.extend_from_slice(&[6, rs.0, set.index() as u8, bank.index() as u8]);
                u32f(out, words);
                u32f(out, fb_addr);
            }
            Instruction::Ldctxt { rs, block, plane, word, count } => {
                out.extend_from_slice(&[7, rs.0, block.index() as u8]);
                u32f(out, plane);
                u32f(out, word);
                u32f(out, count);
            }
            Instruction::Dbcdc { plane, cw, col, set, addr_a, addr_b } => {
                out.push(8);
                u32f(out, plane);
                u32f(out, cw);
                u32f(out, col);
                out.push(set.index() as u8);
                u32f(out, addr_a);
                u32f(out, addr_b);
            }
            Instruction::Dbcdr { plane, cw, row, set, addr_a, addr_b } => {
                out.push(9);
                u32f(out, plane);
                u32f(out, cw);
                u32f(out, row);
                out.push(set.index() as u8);
                u32f(out, addr_a);
                u32f(out, addr_b);
            }
            Instruction::Sbcb { plane, cw, col, set, bank, addr } => {
                out.push(10);
                u32f(out, plane);
                u32f(out, cw);
                u32f(out, col);
                out.push(set.index() as u8);
                out.push(bank.index() as u8);
                u32f(out, addr);
            }
            Instruction::Sbcbr { plane, cw, row, set, bank, addr } => {
                out.push(11);
                u32f(out, plane);
                u32f(out, cw);
                u32f(out, row);
                out.push(set.index() as u8);
                out.push(bank.index() as u8);
                u32f(out, addr);
            }
            Instruction::Wfbi { col, set, bank, addr } => {
                out.push(12);
                u32f(out, col);
                out.push(set.index() as u8);
                out.push(bank.index() as u8);
                u32f(out, addr);
            }
            Instruction::Wfbir { row, set, bank, addr } => {
                out.push(13);
                u32f(out, row);
                out.push(set.index() as u8);
                out.push(bank.index() as u8);
                u32f(out, addr);
            }
            Instruction::Jmp { target } => {
                out.push(14);
                u32f(out, target);
            }
            Instruction::Bnez { rs, target } => {
                out.extend_from_slice(&[15, rs.0]);
                u32f(out, target);
            }
            Instruction::Halt => out.push(16),
        }
    }

    /// Decode one instruction from `bytes` at `*pos`, advancing `*pos`
    /// past it. The inverse of [`Instruction::encode_bytes`].
    pub fn decode_bytes(bytes: &[u8], pos: &mut usize) -> Result<Instruction, &'static str> {
        fn u8f(bytes: &[u8], pos: &mut usize) -> Result<u8, &'static str> {
            let v = *bytes.get(*pos).ok_or("truncated instruction")?;
            *pos += 1;
            Ok(v)
        }
        fn u16f(bytes: &[u8], pos: &mut usize) -> Result<u16, &'static str> {
            let end = pos.checked_add(2).filter(|&e| e <= bytes.len());
            let s = end.map(|e| &bytes[*pos..e]).ok_or("truncated instruction")?;
            *pos += 2;
            Ok(u16::from_le_bytes(s.try_into().unwrap()))
        }
        fn u32f(bytes: &[u8], pos: &mut usize) -> Result<usize, &'static str> {
            let end = pos.checked_add(4).filter(|&e| e <= bytes.len());
            let s = end.map(|e| &bytes[*pos..e]).ok_or("truncated instruction")?;
            *pos += 4;
            Ok(u32::from_le_bytes(s.try_into().unwrap()) as usize)
        }
        let reg = |bytes: &[u8], pos: &mut usize| u8f(bytes, pos).map(Reg);
        let set = |bytes: &[u8], pos: &mut usize| {
            u8f(bytes, pos).map(|v| Set::from_index(v as usize))
        };
        let bank = |bytes: &[u8], pos: &mut usize| {
            u8f(bytes, pos).map(|v| Bank::from_index(v as usize))
        };
        let tag = u8f(bytes, pos)?;
        Ok(match tag {
            0 => Instruction::Ldui { rd: reg(bytes, pos)?, imm: u16f(bytes, pos)? },
            1 => Instruction::Ldli { rd: reg(bytes, pos)?, imm: u16f(bytes, pos)? },
            2 => Instruction::Add {
                rd: reg(bytes, pos)?,
                rs: reg(bytes, pos)?,
                rt: reg(bytes, pos)?,
            },
            3 => Instruction::Sub {
                rd: reg(bytes, pos)?,
                rs: reg(bytes, pos)?,
                rt: reg(bytes, pos)?,
            },
            4 => Instruction::Addi {
                rd: reg(bytes, pos)?,
                rs: reg(bytes, pos)?,
                imm: u16f(bytes, pos)? as i16,
            },
            5 => Instruction::Ldfb {
                rs: reg(bytes, pos)?,
                set: set(bytes, pos)?,
                bank: bank(bytes, pos)?,
                words: u32f(bytes, pos)?,
                fb_addr: u32f(bytes, pos)?,
            },
            6 => Instruction::Stfb {
                rs: reg(bytes, pos)?,
                set: set(bytes, pos)?,
                bank: bank(bytes, pos)?,
                words: u32f(bytes, pos)?,
                fb_addr: u32f(bytes, pos)?,
            },
            7 => Instruction::Ldctxt {
                rs: reg(bytes, pos)?,
                block: Block::from_index(u8f(bytes, pos)? as usize),
                plane: u32f(bytes, pos)?,
                word: u32f(bytes, pos)?,
                count: u32f(bytes, pos)?,
            },
            8 => Instruction::Dbcdc {
                plane: u32f(bytes, pos)?,
                cw: u32f(bytes, pos)?,
                col: u32f(bytes, pos)?,
                set: set(bytes, pos)?,
                addr_a: u32f(bytes, pos)?,
                addr_b: u32f(bytes, pos)?,
            },
            9 => Instruction::Dbcdr {
                plane: u32f(bytes, pos)?,
                cw: u32f(bytes, pos)?,
                row: u32f(bytes, pos)?,
                set: set(bytes, pos)?,
                addr_a: u32f(bytes, pos)?,
                addr_b: u32f(bytes, pos)?,
            },
            10 => Instruction::Sbcb {
                plane: u32f(bytes, pos)?,
                cw: u32f(bytes, pos)?,
                col: u32f(bytes, pos)?,
                set: set(bytes, pos)?,
                bank: bank(bytes, pos)?,
                addr: u32f(bytes, pos)?,
            },
            11 => Instruction::Sbcbr {
                plane: u32f(bytes, pos)?,
                cw: u32f(bytes, pos)?,
                row: u32f(bytes, pos)?,
                set: set(bytes, pos)?,
                bank: bank(bytes, pos)?,
                addr: u32f(bytes, pos)?,
            },
            12 => Instruction::Wfbi {
                col: u32f(bytes, pos)?,
                set: set(bytes, pos)?,
                bank: bank(bytes, pos)?,
                addr: u32f(bytes, pos)?,
            },
            13 => Instruction::Wfbir {
                row: u32f(bytes, pos)?,
                set: set(bytes, pos)?,
                bank: bank(bytes, pos)?,
                addr: u32f(bytes, pos)?,
            },
            14 => Instruction::Jmp { target: u32f(bytes, pos)? },
            15 => Instruction::Bnez { rs: reg(bytes, pos)?, target: u32f(bytes, pos)? },
            16 => Instruction::Halt,
            _ => return Err("unknown instruction tag"),
        })
    }
}

/// A TinyRISC program: a flat instruction vector, index == PC.
#[derive(Debug, Clone, Default, PartialEq, Eq, Hash)]
pub struct Program {
    pub instructions: Vec<Instruction>,
}

impl Program {
    pub fn new(instructions: Vec<Instruction>) -> Program {
        Program { instructions }
    }

    pub fn len(&self) -> usize {
        self.instructions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instructions.is_empty()
    }

    /// Total issue slots the program occupies when executed straight-line
    /// (no branches) — the static cost model used by
    /// [`crate::mapping::plan`].
    pub fn straight_line_slots(&self) -> u64 {
        self.instructions.iter().map(Instruction::issue_slots).sum()
    }

    /// The paper's cycle-count convention: the cycle index at which the
    /// final instruction of a straight-line routine **issues** (Table 1's
    /// listing ends with its `stfb` at instruction index 96 and is
    /// reported as "96 cycles" — the trailing store DMA is not counted).
    pub fn paper_cycles(&self) -> u64 {
        let last = self.instructions.last().map(Instruction::issue_slots).unwrap_or(0);
        self.straight_line_slots() - last
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nop_is_add_r0() {
        assert_eq!(
            Instruction::NOP,
            Instruction::Add { rd: Reg::R0, rs: Reg::R0, rt: Reg::R0 }
        );
        assert_eq!(Instruction::NOP.issue_slots(), 1);
    }

    #[test]
    fn dma_instructions_occupy_bus_slots() {
        let ldfb = Instruction::Ldfb {
            rs: Reg(1),
            set: Set::Zero,
            bank: Bank::A,
            words: 32,
            fb_addr: 0,
        };
        assert_eq!(ldfb.issue_slots(), 32);
        let short = Instruction::Ldfb {
            rs: Reg(1),
            set: Set::Zero,
            bank: Bank::A,
            words: 4,
            fb_addr: 0,
        };
        assert_eq!(short.issue_slots(), 5);
        let ldctxt = Instruction::Ldctxt {
            rs: Reg(3),
            block: Block::Column,
            plane: 0,
            word: 0,
            count: 1,
        };
        assert_eq!(ldctxt.issue_slots(), 4);
    }

    #[test]
    fn broadcast_modes() {
        let col = Instruction::Dbcdc { plane: 0, cw: 0, col: 0, set: Set::Zero, addr_a: 0, addr_b: 0 };
        assert_eq!(col.broadcast_mode(), Some(BroadcastMode::Column));
        let row = Instruction::Sbcbr { plane: 0, cw: 0, row: 2, set: Set::Zero, bank: Bank::A, addr: 0 };
        assert_eq!(row.broadcast_mode(), Some(BroadcastMode::Row));
        assert_eq!(Instruction::NOP.broadcast_mode(), None);
    }

    #[test]
    fn tag_byte_codec_roundtrips_every_variant() {
        let all = vec![
            Instruction::Ldui { rd: Reg(3), imm: 0xBEEF },
            Instruction::Ldli { rd: Reg(4), imm: 0x1234 },
            Instruction::Add { rd: Reg(1), rs: Reg(2), rt: Reg(3) },
            Instruction::Sub { rd: Reg(4), rs: Reg(5), rt: Reg(6) },
            Instruction::Addi { rd: Reg(7), rs: Reg(8), imm: -42 },
            Instruction::Ldfb { rs: Reg(1), set: Set::Zero, bank: Bank::A, words: 32, fb_addr: 64 },
            Instruction::Stfb { rs: Reg(2), set: Set::One, bank: Bank::B, words: 4, fb_addr: 128 },
            Instruction::Ldctxt { rs: Reg(3), block: Block::Row, plane: 1, word: 7, count: 9 },
            Instruction::Dbcdc { plane: 1, cw: 5, col: 3, set: Set::One, addr_a: 10, addr_b: 20 },
            Instruction::Dbcdr { plane: 0, cw: 6, row: 2, set: Set::Zero, addr_a: 30, addr_b: 40 },
            Instruction::Sbcb { plane: 1, cw: 7, col: 4, set: Set::Zero, bank: Bank::B, addr: 50 },
            Instruction::Sbcbr { plane: 0, cw: 8, row: 5, set: Set::One, bank: Bank::A, addr: 60 },
            Instruction::Wfbi { col: 6, set: Set::Zero, bank: Bank::A, addr: 70 },
            Instruction::Wfbir { row: 7, set: Set::One, bank: Bank::B, addr: 80 },
            Instruction::Jmp { target: 12 },
            Instruction::Bnez { rs: Reg(9), target: 3 },
            Instruction::Halt,
        ];
        let mut bytes = Vec::new();
        for i in &all {
            i.encode_bytes(&mut bytes);
        }
        let mut pos = 0;
        for want in &all {
            let got = Instruction::decode_bytes(&bytes, &mut pos).expect("decodable");
            assert_eq!(&got, want);
        }
        assert_eq!(pos, bytes.len(), "decoder must consume exactly what the encoder wrote");
        // Corruption is a typed error, never a panic.
        assert!(Instruction::decode_bytes(&[200], &mut 0).is_err());
        assert!(Instruction::decode_bytes(&[5, 1], &mut 0).is_err());
        assert!(Instruction::decode_bytes(&[], &mut 0).is_err());
    }

    #[test]
    fn straight_line_slot_accounting() {
        let p = Program::new(vec![
            Instruction::Ldui { rd: Reg(1), imm: 1 },
            Instruction::Ldfb { rs: Reg(1), set: Set::Zero, bank: Bank::A, words: 32, fb_addr: 0 },
            Instruction::Halt,
        ]);
        assert_eq!(p.straight_line_slots(), 1 + 32 + 1);
        // paper_cycles = issue index of the final instruction.
        assert_eq!(p.paper_cycles(), 33);
        // A program ending in a DMA does not count the trailing transfer.
        let p2 = Program::new(vec![
            Instruction::Ldui { rd: Reg(1), imm: 1 },
            Instruction::Stfb { rs: Reg(1), set: Set::Zero, bank: Bank::A, words: 32, fb_addr: 0 },
        ]);
        assert_eq!(p2.paper_cycles(), 1);
    }
}
