//! Text assembler / disassembler for TinyRISC — the format used by the
//! mULATE-style traces and by `examples/mulate_trace.rs`.
//!
//! Syntax (one instruction per line, `;`/`#` comments, optional `label:`):
//!
//! ```text
//! start:
//!   ldui   r1, 0x1000          ; rd, imm16
//!   ldfb   r1, 0, a, 32        ; rs, set, bank, words[, fb_addr]
//!   ldctxt r3, col, 0, 0, 1    ; rs, block, plane, word, count
//!   dbcdc  0, 0, 3, 0, 0x18, 0x18  ; plane, cw, col, set, addr_a, addr_b
//!   sbcb   0, 0, 3, 0, a, 0x18 ; plane, cw, col, set, bank, addr
//!   wfbi   3, 1, a, 0x18       ; col, set, bank, addr
//!   bnez   r4, start
//!   halt
//! ```

use std::collections::HashMap;

use super::isa::{Instruction, Program, Reg};
use crate::morphosys::context_memory::Block;
use crate::morphosys::frame_buffer::{Bank, Set};

/// Assembly error with line context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AsmError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for AsmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for AsmError {}

fn err(line: usize, message: impl Into<String>) -> AsmError {
    AsmError { line, message: message.into() }
}

fn parse_num(tok: &str, line: usize) -> Result<i64, AsmError> {
    let t = tok.trim();
    let (neg, t) = match t.strip_prefix('-') {
        Some(rest) => (true, rest),
        None => (false, t),
    };
    let v = if let Some(hex) = t.strip_prefix("0x").or_else(|| t.strip_prefix("0X")) {
        i64::from_str_radix(hex, 16)
    } else {
        t.parse::<i64>()
    }
    .map_err(|_| err(line, format!("bad number `{tok}`")))?;
    Ok(if neg { -v } else { v })
}

fn parse_reg(tok: &str, line: usize) -> Result<Reg, AsmError> {
    let t = tok.trim();
    let n = t
        .strip_prefix('r')
        .or_else(|| t.strip_prefix('R'))
        .ok_or_else(|| err(line, format!("expected register, got `{tok}`")))?;
    let i: u8 = n.parse().map_err(|_| err(line, format!("bad register `{tok}`")))?;
    if i > 15 {
        return Err(err(line, format!("register out of range `{tok}`")));
    }
    Ok(Reg(i))
}

fn parse_set(tok: &str, line: usize) -> Result<Set, AsmError> {
    match parse_num(tok, line)? {
        0 => Ok(Set::Zero),
        1 => Ok(Set::One),
        _ => Err(err(line, format!("set must be 0 or 1, got `{tok}`"))),
    }
}

fn parse_bank(tok: &str, line: usize) -> Result<Bank, AsmError> {
    match tok.trim().to_ascii_lowercase().as_str() {
        "a" | "0" => Ok(Bank::A),
        "b" | "1" => Ok(Bank::B),
        _ => Err(err(line, format!("bank must be a/b/0/1, got `{tok}`"))),
    }
}

fn parse_block(tok: &str, line: usize) -> Result<Block, AsmError> {
    match tok.trim().to_ascii_lowercase().as_str() {
        "col" | "column" | "0" => Ok(Block::Column),
        "row" | "1" => Ok(Block::Row),
        _ => Err(err(line, format!("block must be col/row, got `{tok}`"))),
    }
}

fn parse_usize(tok: &str, line: usize) -> Result<usize, AsmError> {
    let v = parse_num(tok, line)?;
    usize::try_from(v).map_err(|_| err(line, format!("expected unsigned, got `{tok}`")))
}

/// Assemble TinyRISC source text into a [`Program`].
pub fn assemble(src: &str) -> Result<Program, AsmError> {
    // Pass 1: strip comments/labels, collect label addresses.
    let mut labels: HashMap<String, usize> = HashMap::new();
    let mut lines: Vec<(usize, String)> = Vec::new();
    for (lineno, raw) in src.lines().enumerate() {
        let lineno = lineno + 1;
        let mut text = raw;
        if let Some(i) = text.find([';', '#']) {
            text = &text[..i];
        }
        let mut text = text.trim().to_string();
        while let Some(i) = text.find(':') {
            let label = text[..i].trim().to_string();
            if label.is_empty() || label.contains(char::is_whitespace) {
                return Err(err(lineno, format!("bad label `{label}`")));
            }
            if labels.insert(label.clone(), lines.len()).is_some() {
                return Err(err(lineno, format!("duplicate label `{label}`")));
            }
            text = text[i + 1..].trim().to_string();
        }
        if !text.is_empty() {
            lines.push((lineno, text));
        }
    }

    let target = |tok: &str, line: usize| -> Result<usize, AsmError> {
        if let Some(&t) = labels.get(tok.trim()) {
            Ok(t)
        } else {
            parse_usize(tok, line)
        }
    };

    // Pass 2: parse instructions.
    let mut instructions = Vec::with_capacity(lines.len());
    for (lineno, text) in &lines {
        let lineno = *lineno;
        let (mnemonic, rest) = match text.split_once(char::is_whitespace) {
            Some((m, r)) => (m, r),
            None => (text.as_str(), ""),
        };
        let ops: Vec<&str> = rest
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .collect();
        let expect = |n: usize| -> Result<(), AsmError> {
            if ops.len() == n {
                Ok(())
            } else {
                Err(err(lineno, format!("`{mnemonic}` takes {n} operands, got {}", ops.len())))
            }
        };
        let instr = match mnemonic.to_ascii_lowercase().as_str() {
            "nop" => {
                expect(0)?;
                Instruction::NOP
            }
            "halt" => {
                expect(0)?;
                Instruction::Halt
            }
            "ldui" => {
                expect(2)?;
                Instruction::Ldui { rd: parse_reg(ops[0], lineno)?, imm: parse_num(ops[1], lineno)? as u16 }
            }
            "ldli" => {
                expect(2)?;
                Instruction::Ldli { rd: parse_reg(ops[0], lineno)?, imm: parse_num(ops[1], lineno)? as u16 }
            }
            "add" => {
                expect(3)?;
                Instruction::Add {
                    rd: parse_reg(ops[0], lineno)?,
                    rs: parse_reg(ops[1], lineno)?,
                    rt: parse_reg(ops[2], lineno)?,
                }
            }
            "sub" => {
                expect(3)?;
                Instruction::Sub {
                    rd: parse_reg(ops[0], lineno)?,
                    rs: parse_reg(ops[1], lineno)?,
                    rt: parse_reg(ops[2], lineno)?,
                }
            }
            "addi" => {
                expect(3)?;
                Instruction::Addi {
                    rd: parse_reg(ops[0], lineno)?,
                    rs: parse_reg(ops[1], lineno)?,
                    imm: parse_num(ops[2], lineno)? as i16,
                }
            }
            "ldfb" | "stfb" => {
                if ops.len() != 4 && ops.len() != 5 {
                    return Err(err(lineno, format!("`{mnemonic}` takes 4 or 5 operands")));
                }
                let rs = parse_reg(ops[0], lineno)?;
                let set = parse_set(ops[1], lineno)?;
                let bank = parse_bank(ops[2], lineno)?;
                let words = parse_usize(ops[3], lineno)?;
                let fb_addr = if ops.len() == 5 { parse_usize(ops[4], lineno)? } else { 0 };
                if mnemonic.eq_ignore_ascii_case("ldfb") {
                    Instruction::Ldfb { rs, set, bank, words, fb_addr }
                } else {
                    Instruction::Stfb { rs, set, bank, words, fb_addr }
                }
            }
            "ldctxt" => {
                expect(5)?;
                Instruction::Ldctxt {
                    rs: parse_reg(ops[0], lineno)?,
                    block: parse_block(ops[1], lineno)?,
                    plane: parse_usize(ops[2], lineno)?,
                    word: parse_usize(ops[3], lineno)?,
                    count: parse_usize(ops[4], lineno)?,
                }
            }
            "dbcdc" | "dbcdr" => {
                expect(6)?;
                let plane = parse_usize(ops[0], lineno)?;
                let cw = parse_usize(ops[1], lineno)?;
                let idx = parse_usize(ops[2], lineno)?;
                let set = parse_set(ops[3], lineno)?;
                let addr_a = parse_usize(ops[4], lineno)?;
                let addr_b = parse_usize(ops[5], lineno)?;
                if mnemonic.eq_ignore_ascii_case("dbcdc") {
                    Instruction::Dbcdc { plane, cw, col: idx, set, addr_a, addr_b }
                } else {
                    Instruction::Dbcdr { plane, cw, row: idx, set, addr_a, addr_b }
                }
            }
            "sbcb" | "sbcbr" => {
                expect(6)?;
                let plane = parse_usize(ops[0], lineno)?;
                let cw = parse_usize(ops[1], lineno)?;
                let idx = parse_usize(ops[2], lineno)?;
                let set = parse_set(ops[3], lineno)?;
                let bank = parse_bank(ops[4], lineno)?;
                let addr = parse_usize(ops[5], lineno)?;
                if mnemonic.eq_ignore_ascii_case("sbcb") {
                    Instruction::Sbcb { plane, cw, col: idx, set, bank, addr }
                } else {
                    Instruction::Sbcbr { plane, cw, row: idx, set, bank, addr }
                }
            }
            "wfbi" | "wfbir" => {
                expect(4)?;
                let idx = parse_usize(ops[0], lineno)?;
                let set = parse_set(ops[1], lineno)?;
                let bank = parse_bank(ops[2], lineno)?;
                let addr = parse_usize(ops[3], lineno)?;
                if mnemonic.eq_ignore_ascii_case("wfbi") {
                    Instruction::Wfbi { col: idx, set, bank, addr }
                } else {
                    Instruction::Wfbir { row: idx, set, bank, addr }
                }
            }
            "jmp" => {
                expect(1)?;
                Instruction::Jmp { target: target(ops[0], lineno)? }
            }
            "bnez" => {
                expect(2)?;
                Instruction::Bnez { rs: parse_reg(ops[0], lineno)?, target: target(ops[1], lineno)? }
            }
            other => return Err(err(lineno, format!("unknown mnemonic `{other}`"))),
        };
        instructions.push(instr);
    }
    Ok(Program::new(instructions))
}

fn set_s(set: Set) -> &'static str {
    match set {
        Set::Zero => "0",
        Set::One => "1",
    }
}

fn bank_s(bank: Bank) -> &'static str {
    match bank {
        Bank::A => "a",
        Bank::B => "b",
    }
}

fn block_s(block: Block) -> &'static str {
    match block {
        Block::Column => "col",
        Block::Row => "row",
    }
}

/// Render one instruction in assembler syntax.
pub fn disassemble(i: &Instruction) -> String {
    match i {
        Instruction::Ldui { rd, imm } => format!("ldui   r{}, {:#x}", rd.0, imm),
        Instruction::Ldli { rd, imm } => format!("ldli   r{}, {:#x}", rd.0, imm),
        Instruction::Add { rd, rs, rt } if *i == Instruction::NOP => {
            let _ = (rd, rs, rt);
            "nop".to_string()
        }
        Instruction::Add { rd, rs, rt } => format!("add    r{}, r{}, r{}", rd.0, rs.0, rt.0),
        Instruction::Sub { rd, rs, rt } => format!("sub    r{}, r{}, r{}", rd.0, rs.0, rt.0),
        Instruction::Addi { rd, rs, imm } => format!("addi   r{}, r{}, {}", rd.0, rs.0, imm),
        Instruction::Ldfb { rs, set, bank, words, fb_addr } => {
            format!("ldfb   r{}, {}, {}, {}, {:#x}", rs.0, set_s(*set), bank_s(*bank), words, fb_addr)
        }
        Instruction::Stfb { rs, set, bank, words, fb_addr } => {
            format!("stfb   r{}, {}, {}, {}, {:#x}", rs.0, set_s(*set), bank_s(*bank), words, fb_addr)
        }
        Instruction::Ldctxt { rs, block, plane, word, count } => {
            format!("ldctxt r{}, {}, {}, {}, {}", rs.0, block_s(*block), plane, word, count)
        }
        Instruction::Dbcdc { plane, cw, col, set, addr_a, addr_b } => {
            format!("dbcdc  {}, {}, {}, {}, {:#x}, {:#x}", plane, cw, col, set_s(*set), addr_a, addr_b)
        }
        Instruction::Dbcdr { plane, cw, row, set, addr_a, addr_b } => {
            format!("dbcdr  {}, {}, {}, {}, {:#x}, {:#x}", plane, cw, row, set_s(*set), addr_a, addr_b)
        }
        Instruction::Sbcb { plane, cw, col, set, bank, addr } => {
            format!("sbcb   {}, {}, {}, {}, {}, {:#x}", plane, cw, col, set_s(*set), bank_s(*bank), addr)
        }
        Instruction::Sbcbr { plane, cw, row, set, bank, addr } => {
            format!("sbcbr  {}, {}, {}, {}, {}, {:#x}", plane, cw, row, set_s(*set), bank_s(*bank), addr)
        }
        Instruction::Wfbi { col, set, bank, addr } => {
            format!("wfbi   {}, {}, {}, {:#x}", col, set_s(*set), bank_s(*bank), addr)
        }
        Instruction::Wfbir { row, set, bank, addr } => {
            format!("wfbir  {}, {}, {}, {:#x}", row, set_s(*set), bank_s(*bank), addr)
        }
        Instruction::Jmp { target } => format!("jmp    {}", target),
        Instruction::Bnez { rs, target } => format!("bnez   r{}, {}", rs.0, target),
        Instruction::Halt => "halt".to_string(),
    }
}

/// Render a whole program.
pub fn disassemble_program(p: &Program) -> String {
    p.instructions
        .iter()
        .enumerate()
        .map(|(pc, i)| format!("{pc:4}: {}", disassemble(i)))
        .collect::<Vec<_>>()
        .join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assemble_paper_style_listing() {
        let src = "
            ldui   r1, 0x1          ; vector U base
            ldfb   r1, 0, a, 32
            nop
            ldctxt r3, col, 0, 0, 1
            dbcdc  0, 0, 0, 0, 0x0, 0x0
            wfbi   0, 1, a, 0x0
            halt
        ";
        let p = assemble(src).unwrap();
        assert_eq!(p.len(), 7);
        assert_eq!(p.instructions[0], Instruction::Ldui { rd: Reg(1), imm: 1 });
        assert_eq!(p.instructions[2], Instruction::NOP);
        assert!(matches!(p.instructions[6], Instruction::Halt));
    }

    #[test]
    fn labels_resolve_for_branches() {
        let src = "
            ldli r2, 3
            loop:
            addi r2, r2, -1
            bnez r2, loop
            halt
        ";
        let p = assemble(src).unwrap();
        assert_eq!(p.instructions[2], Instruction::Bnez { rs: Reg(2), target: 1 });
    }

    #[test]
    fn roundtrip_through_disassembler() {
        let src = "
            ldui   r1, 0x1000
            ldli   r4, 0x40
            add    r2, r1, r4
            sub    r3, r2, r1
            addi   r5, r3, -7
            ldfb   r1, 0, a, 32, 0x0
            stfb   r1, 1, b, 4, 0x10
            ldctxt r3, row, 1, 2, 8
            dbcdc  0, 0, 3, 0, 0x18, 0x18
            dbcdr  0, 1, 4, 1, 0x20, 0x28
            sbcb   0, 0, 5, 0, b, 0x28
            sbcbr  1, 2, 6, 1, a, 0x30
            wfbi   7, 1, a, 0x38
            wfbir  2, 0, b, 0x40
            jmp    0
            bnez   r5, 3
            nop
            halt
        ";
        let p = assemble(src).unwrap();
        let text = disassemble_program(&p);
        // Strip the `pc:` prefixes and re-assemble.
        let stripped: String = text
            .lines()
            .map(|l| l.split_once(": ").unwrap().1)
            .collect::<Vec<_>>()
            .join("\n");
        let p2 = assemble(&stripped).unwrap();
        assert_eq!(p, p2);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = assemble("nop\nfrobnicate r1").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.message.contains("frobnicate"));
    }

    #[test]
    fn rejects_bad_operands() {
        assert!(assemble("ldui r16, 0").is_err());
        assert!(assemble("ldfb r1, 2, a, 4").is_err());
        assert!(assemble("ldfb r1, 0, q, 4").is_err());
        assert!(assemble("add r1, r2").is_err());
        assert!(assemble("ldui r1, zork").is_err());
    }

    #[test]
    fn duplicate_labels_rejected() {
        assert!(assemble("x:\nnop\nx:\nnop").is_err());
    }
}
