//! TinyRISC architectural state: the scalar register file.

use super::isa::Reg;

/// Sixteen 32-bit registers; r0 is hardwired to zero.
#[derive(Debug, Clone, Default)]
pub struct RegFile {
    regs: [u32; 16],
}

impl RegFile {
    pub fn new() -> RegFile {
        RegFile::default()
    }

    pub fn read(&self, r: Reg) -> u32 {
        if r.index() == 0 {
            0
        } else {
            self.regs[r.index()]
        }
    }

    pub fn write(&mut self, r: Reg, value: u32) {
        if r.index() != 0 {
            self.regs[r.index()] = value;
        }
    }

    /// `ldui`: load upper half, clearing the lower (the paper's listings
    /// use `ldui r1, 0x1` to mean `r1 ← 0x10000`).
    pub fn load_upper(&mut self, r: Reg, imm: u16) {
        self.write(r, (imm as u32) << 16);
    }

    /// `ldli`: replace the lower half, preserving the upper.
    pub fn load_lower(&mut self, r: Reg, imm: u16) {
        let v = (self.read(r) & 0xFFFF_0000) | imm as u32;
        self.write(r, v);
    }

    /// All sixteen registers, for [`crate::morphosys::snapshot`]. Slot 0
    /// always reads as zero (the hardwired r0).
    pub fn snapshot_regs(&self) -> [u32; 16] {
        let mut regs = self.regs;
        regs[0] = 0;
        regs
    }

    /// Restore from a [`RegFile::snapshot_regs`] image. Goes through
    /// [`RegFile::write`], so the r0-is-zero invariant survives even a
    /// hand-crafted image with a nonzero slot 0.
    pub fn restore_regs(&mut self, regs: &[u32; 16]) {
        for (i, &v) in regs.iter().enumerate() {
            self.write(Reg(i as u8), v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn r0_is_hardwired_zero() {
        let mut rf = RegFile::new();
        rf.write(Reg(0), 123);
        assert_eq!(rf.read(Reg(0)), 0);
    }

    #[test]
    fn ldui_matches_paper_semantics() {
        let mut rf = RegFile::new();
        rf.load_upper(Reg(1), 0x1);
        assert_eq!(rf.read(Reg(1)), 0x10000);
        rf.load_upper(Reg(1), 0x4);
        assert_eq!(rf.read(Reg(1)), 0x40000);
    }

    #[test]
    fn ldli_preserves_upper_half() {
        let mut rf = RegFile::new();
        rf.load_upper(Reg(4), 0x2);
        rf.load_lower(Reg(4), 0x40);
        assert_eq!(rf.read(Reg(4)), 0x20040);
    }
}
