//! TinyRISC — the M1's control processor.
//!
//! TinyRISC runs the scalar part of an application and steers the parallel
//! part: it programs the DMA controller (frame-buffer and context-memory
//! loads), triggers RC-array context broadcasts, and writes results back.
//! One instruction issues per cycle; DMA instructions occupy the issue
//! slot for the duration of the bus transfer (the NOP runs in the paper's
//! listings — see [`super::timing`]).

pub mod asm;
pub mod cpu;
pub mod isa;

pub use asm::{assemble, disassemble};
pub use cpu::RegFile;
pub use isa::{Instruction, Program, Reg};
