//! # MorphoSys M1 — cycle-accurate simulator
//!
//! The paper's numbers come from the authors' *mULATE* emulator of the
//! MorphoSys M1 chip (UC Irvine), not from silicon. This module is our
//! substitute: a cycle-accurate, instruction-level simulator of the whole
//! M1 system of Figure 1 of the paper:
//!
//! ```text
//!   main memory ──DMA──┬── frame buffer (2 sets × 2 banks, 16-bit data)
//!                      └── context memory (row/col blocks × 2 planes)
//!   TinyRISC ── issues DMA + broadcast instructions, 1 instr/cycle
//!   RC array ── 8×8 reconfigurable cells, context-word-programmed,
//!               column/row context broadcast, 3-level interconnect
//! ```
//!
//! Cycle accounting (calibrated in [`timing`], asserted by calibration
//! tests against the paper's Table 5) follows the paper's convention: the
//! reported cycle count of a routine is the cycle index at which its final
//! instruction issues — i.e. `total issue slots - 1` (Table 1's listing
//! occupies slots 0..=96 and the paper reports 96 cycles).
//!
//! ## Execution tiers (§Perf)
//!
//! One architectural semantics, three executors, two DMA timing models —
//! every cell of this grid produces bit-identical state and cycle
//! reports (pinned by `tests/conformance.rs`):
//!
//! | tier | blocking DMA (paper listings) | async DMA (double-buffered overlap) |
//! |------|-------------------------------|--------------------------------------|
//! | **interpreter** ([`M1System::run`]) | reference executor + slot accounting | reference executor + [`timing`]'s `AsyncDma` issue model |
//! | **scheduled** ([`M1System::run_program`] with a [`BroadcastSchedule`]) | pre-decoded steps, accounting precomputed at compile time | same steps; async issue/readiness accounting **also precomputed** (§Perf PR 5) |
//! | **fused** (`Step::FusedRun` inside a schedule) | broadcast/write-back runs as 8-wide SIMD lane kernels | identical — fusion is DMA-mode-independent |
//! | **megakernel** ([`M1System::run_megakernel`] with a [`Megakernel`]) | whole tile plan as one lowered stream: register-free DMA loads, one 64-lane kernel call per tile (AVX2 under `avx2-kernels`) | identical steps; the wrapped schedule's precomputed async accounting |
//!
//! Dispatch: `run_program` takes the scheduled/fused tier whenever a
//! schedule is supplied and the system is not tracing;
//! `run_megakernel` takes the megakernel tier under the same tracing
//! rule. The DMA mode only selects which precomputed report is
//! returned. Programs with branches never compile to schedules (or
//! megakernels); tracing systems always interpret. The async
//! accounting is compile-time computable because every latency input
//! of the issue model is a static instruction field — the only dynamic
//! hazard in the ISA is control flow.

pub mod context_memory;
pub mod dma;
pub mod frame_buffer;
pub mod mulate;
pub mod rc_array;
pub mod schedule;
pub mod snapshot;
pub mod system;
pub mod timing;
pub mod tinyrisc;

pub use frame_buffer::{Bank, FrameBuffer, Set};
pub use rc_array::{AluOp, ContextWord, RcArray};
pub use schedule::{BroadcastSchedule, Megakernel};
pub use snapshot::{fnv1a64, SnapshotError};
pub use system::{ExecutionReport, M1System};
pub use tinyrisc::{Instruction, Program, Reg};
