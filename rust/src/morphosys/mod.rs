//! # MorphoSys M1 — cycle-accurate simulator
//!
//! The paper's numbers come from the authors' *mULATE* emulator of the
//! MorphoSys M1 chip (UC Irvine), not from silicon. This module is our
//! substitute: a cycle-accurate, instruction-level simulator of the whole
//! M1 system of Figure 1 of the paper:
//!
//! ```text
//!   main memory ──DMA──┬── frame buffer (2 sets × 2 banks, 16-bit data)
//!                      └── context memory (row/col blocks × 2 planes)
//!   TinyRISC ── issues DMA + broadcast instructions, 1 instr/cycle
//!   RC array ── 8×8 reconfigurable cells, context-word-programmed,
//!               column/row context broadcast, 3-level interconnect
//! ```
//!
//! Cycle accounting (calibrated in [`timing`], asserted by calibration
//! tests against the paper's Table 5) follows the paper's convention: the
//! reported cycle count of a routine is the cycle index at which its final
//! instruction issues — i.e. `total issue slots - 1` (Table 1's listing
//! occupies slots 0..=96 and the paper reports 96 cycles).

pub mod context_memory;
pub mod dma;
pub mod frame_buffer;
pub mod mulate;
pub mod rc_array;
pub mod schedule;
pub mod system;
pub mod timing;
pub mod tinyrisc;

pub use frame_buffer::{Bank, FrameBuffer, Set};
pub use rc_array::{AluOp, ContextWord, RcArray};
pub use schedule::BroadcastSchedule;
pub use system::{ExecutionReport, M1System};
pub use tinyrisc::{Instruction, Program, Reg};
