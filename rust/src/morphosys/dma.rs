//! The DMA controller and main memory.
//!
//! Main memory is 32-bit-word addressed; 16-bit frame-buffer elements are
//! packed two per word (low half first). The DMA controller moves words
//! between main memory and the frame buffer / context memory; its bus
//! occupancy (and therefore the TinyRISC stall slots visible as NOP runs
//! in the paper's listings) is modelled in [`super::timing`].

use super::context_memory::{Block, ContextMemory};
use super::frame_buffer::{Bank, FrameBuffer, Set};

/// Word-addressed 32-bit main memory.
#[derive(Debug, Clone)]
pub struct MainMemory {
    words: Vec<u32>,
}

impl MainMemory {
    /// Memory sized in 32-bit words.
    pub fn new(words: usize) -> MainMemory {
        MainMemory { words: vec![0; words] }
    }

    /// 512K words (2 MiB) — covers the paper's address map (vector U at
    /// word 0x10000, V at 0x20000, context at 0x30000, result at 0x40000)
    /// with room for larger workloads.
    pub fn default_size() -> MainMemory {
        MainMemory::new(1 << 19)
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    pub fn read_word(&self, addr: usize) -> u32 {
        assert!(addr < self.words.len(), "main memory read {addr:#x} out of range");
        self.words[addr]
    }

    pub fn write_word(&mut self, addr: usize, value: u32) {
        assert!(addr < self.words.len(), "main memory write {addr:#x} out of range");
        self.words[addr] = value;
    }

    /// Store a vector of 16-bit elements starting at word address `addr`,
    /// packed two per word (low half first). Returns the number of words
    /// written.
    pub fn store_elements(&mut self, addr: usize, elems: &[i16]) -> usize {
        let words = elems.len().div_ceil(2);
        for w in 0..words {
            let lo = elems[2 * w] as u16 as u32;
            let hi = elems.get(2 * w + 1).copied().unwrap_or(0) as u16 as u32;
            self.write_word(addr + w, lo | (hi << 16));
        }
        words
    }

    /// Every word in storage order, for [`crate::morphosys::snapshot`].
    pub(crate) fn snapshot_words(&self) -> &[u32] {
        &self.words
    }

    /// Restore from a [`MainMemory::snapshot_words`] image, resizing to
    /// the snapshot's word count.
    pub(crate) fn restore_words(&mut self, words: &[u32]) {
        self.words.clear();
        self.words.extend_from_slice(words);
    }

    /// Load `count` 16-bit elements starting at word address `addr`.
    pub fn load_elements(&self, addr: usize, count: usize) -> Vec<i16> {
        let mut out = Vec::with_capacity(count);
        for i in 0..count {
            let w = self.read_word(addr + i / 2);
            let half = if i % 2 == 0 { w & 0xFFFF } else { w >> 16 };
            out.push(half as u16 as i16);
        }
        out
    }
}

/// Number of 32-bit words occupied by `elems` 16-bit elements.
pub fn words_for_elements(elems: usize) -> usize {
    elems.div_ceil(2)
}

/// DMA transfer: main memory → frame buffer. `words` 32-bit words from
/// `mem_addr` unpack into `2 × words` elements at `fb_addr`.
pub fn mem_to_fb(
    mem: &MainMemory,
    fb: &mut FrameBuffer,
    mem_addr: usize,
    set: Set,
    bank: Bank,
    fb_addr: usize,
    words: usize,
) {
    let elems = mem.load_elements(mem_addr, 2 * words);
    fb.write_slice(set, bank, fb_addr, &elems);
}

/// DMA transfer: frame buffer → main memory.
pub fn fb_to_mem(
    fb: &FrameBuffer,
    mem: &mut MainMemory,
    set: Set,
    bank: Bank,
    fb_addr: usize,
    mem_addr: usize,
    words: usize,
) {
    // Borrow the frame-buffer span directly — `fb` and `mem` are disjoint
    // borrows, so the old per-transfer `.to_vec()` copy (a heap
    // allocation on every `stfb`) was pure overhead.
    let elems = fb.read_slice(set, bank, fb_addr, 2 * words);
    mem.store_elements(mem_addr, elems);
}

/// DMA transfer: main memory → context memory (one 32-bit context word per
/// memory word).
pub fn mem_to_ctx(
    mem: &MainMemory,
    ctx: &mut ContextMemory,
    mem_addr: usize,
    block: Block,
    plane: usize,
    word: usize,
    count: usize,
) {
    let words: Vec<u32> = (0..count).map(|i| mem.read_word(mem_addr + i)).collect();
    ctx.write_slice(block, plane, word, &words);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn element_packing_roundtrip() {
        let mut mem = MainMemory::new(64);
        let v: Vec<i16> = vec![1, -2, 3, -4, 5];
        let words = mem.store_elements(0x10, &v);
        assert_eq!(words, 3); // 5 elements → 3 words (last padded)
        assert_eq!(mem.load_elements(0x10, 5), v);
    }

    #[test]
    fn words_for_elements_rounds_up() {
        assert_eq!(words_for_elements(64), 32);
        assert_eq!(words_for_elements(8), 4);
        assert_eq!(words_for_elements(7), 4);
        assert_eq!(words_for_elements(1), 1);
    }

    #[test]
    fn mem_to_fb_and_back() {
        let mut mem = MainMemory::new(256);
        let mut fb = FrameBuffer::new();
        let v: Vec<i16> = (0..64).map(|i| i * 3 - 50).collect();
        mem.store_elements(0x40, &v);
        mem_to_fb(&mem, &mut fb, 0x40, Set::Zero, Bank::A, 0, 32);
        assert_eq!(fb.read_slice(Set::Zero, Bank::A, 0, 64), &v[..]);

        let mut mem2 = MainMemory::new(256);
        fb_to_mem(&fb, &mut mem2, Set::Zero, Bank::A, 0, 0x80, 32);
        assert_eq!(mem2.load_elements(0x80, 64), v);
    }

    #[test]
    fn mem_to_ctx_loads_context_words() {
        let mut mem = MainMemory::new(64);
        mem.write_word(0x8, 0x0000_F400);
        mem.write_word(0x9, 0x0000_9005);
        let mut ctx = ContextMemory::new();
        mem_to_ctx(&mem, &mut ctx, 0x8, Block::Column, 0, 0, 2);
        assert_eq!(ctx.read(Block::Column, 0, 0), 0x0000_F400);
        assert_eq!(ctx.read(Block::Column, 0, 1), 0x0000_9005);
    }

    #[test]
    fn negative_elements_survive_packing() {
        let mut mem = MainMemory::new(8);
        mem.store_elements(0, &[-32768, 32767, -1, 0]);
        assert_eq!(mem.load_elements(0, 4), vec![-32768, 32767, -1, 0]);
    }
}
