//! Extended linear-algebraic mappings — the paper's §8 future work
//! ("effort could be invested in trying to map other algorithms that make
//! use of the mapped ones"): dot product, vector reduction, SAXPY and
//! matrix-vector multiplication, built from the same broadcast primitives
//! plus the mesh interconnect.
//!
//! The new ingredient over §5.1/§5.2 is the **ring reduction**: after the
//! per-column accumulation, seven `ADD(North, r0)` broadcasts circulate
//! partial sums around the (toroidal) column mesh so every cell ends up
//! holding the full column sum:
//!
//! ```text
//!   out⁰ᵢ = vᵢ (also in r0)
//!   outᵗᵢ = outᵗ⁻¹₍ᵢ₋₁₎ + vᵢ     ⇒ out⁷ᵢ = Σₖ vₖ  for every i
//! ```

use crate::morphosys::context_memory::Block;
use crate::morphosys::frame_buffer::{Bank, Set};
use crate::morphosys::rc_array::{AluOp, ContextWord, MuxASel, MuxBSel, ARRAY_DIM};
use crate::morphosys::tinyrisc::{Instruction, Program, Reg};

use super::layout::{Layout, CTX_ADDR, RESULT_ADDR, U_ADDR, V_ADDR};
use super::routines::{MappedRoutine, MatMulMapping};

fn words_for(elems: usize) -> usize {
    crate::morphosys::dma::words_for_elements(elems)
}

fn load_address(prog: &mut Vec<Instruction>, reg: Reg, addr: usize) {
    prog.push(Instruction::Ldui { rd: reg, imm: (addr >> 16) as u16 });
    if addr & 0xFFFF != 0 {
        prog.push(Instruction::Ldli { rd: reg, imm: (addr & 0xFFFF) as u16 });
    }
}

/// The ring-reduction context word: `out = North + r0`.
fn ring_add_word() -> u32 {
    let mut cw = ContextWord::two_port(AluOp::Add);
    cw.mux_a = MuxASel::North;
    cw.mux_b = MuxBSel::Reg(0);
    cw.encode()
}

/// Dot product `U · V` of two n-element vectors (n multiple of 8, ≤ 64).
///
/// All column chunks are MULA-broadcast into **column 0** (the cell
/// accumulators sum across chunks), then the ring reduction folds the
/// eight lane-partials; the scalar result is `result[0]` (replicated down
/// the column).
#[derive(Debug, Clone, Copy)]
pub struct DotProductMapping {
    pub n: usize,
}

impl DotProductMapping {
    pub fn compile(&self) -> MappedRoutine {
        let chunks = Layout::columns_for(self.n);
        let words = words_for(self.n);

        // Context plane: [0] MULA+acc_reset, [1] MULA, [2] MULA+wr(r0),
        // [3] ring add.
        let mut first = ContextWord::mula(true);
        let mut mid = ContextWord::mula(false);
        let mut last = ContextWord::mula(false);
        last.reg_write = 0b0001;
        if chunks == 1 {
            first.reg_write = 0b0001;
        }
        let _ = &mut mid;
        let ctx_words = vec![
            (CTX_ADDR, first.encode()),
            (CTX_ADDR + 1, mid.encode()),
            (CTX_ADDR + 2, last.encode()),
            (CTX_ADDR + 3, ring_add_word()),
        ];

        let mut prog = Vec::new();
        load_address(&mut prog, Reg(1), U_ADDR);
        prog.push(Instruction::Ldfb { rs: Reg(1), set: Set::Zero, bank: Bank::A, words, fb_addr: 0 });
        load_address(&mut prog, Reg(2), V_ADDR);
        prog.push(Instruction::Ldfb { rs: Reg(2), set: Set::Zero, bank: Bank::B, words, fb_addr: 0 });
        load_address(&mut prog, Reg(3), CTX_ADDR);
        prog.push(Instruction::Ldctxt { rs: Reg(3), block: Block::Column, plane: 0, word: 0, count: 4 });

        // Accumulate every chunk into column 0.
        for c in 0..chunks {
            let cw = if c == 0 {
                0
            } else if c == chunks - 1 {
                2
            } else {
                1
            };
            let chunk = Layout::column_chunk(c);
            prog.push(Instruction::Dbcdc { plane: 0, cw, col: 0, set: Set::Zero, addr_a: chunk, addr_b: chunk });
        }
        // Ring reduction: 7 steps, operand buses unused.
        for _ in 0..ARRAY_DIM - 1 {
            prog.push(Instruction::Dbcdc { plane: 0, cw: 3, col: 0, set: Set::Zero, addr_a: 0, addr_b: 0 });
        }
        prog.push(Instruction::Wfbi { col: 0, set: Set::One, bank: Bank::A, addr: 0 });
        load_address(&mut prog, Reg(5), RESULT_ADDR);
        prog.push(Instruction::Stfb { rs: Reg(5), set: Set::One, bank: Bank::A, words: 4, fb_addr: 0 });

        let program = Program::new(prog);
        let predicted_cycles = program.paper_cycles();
        MappedRoutine {
            name: format!("dot-{}", self.n),
            program,
            ctx_words,
            u_elems: self.n,
            v_elems: Some(self.n),
            w_elems: None,
            result_elems: 8,
            predicted_cycles,
        }
    }
}

/// Vector sum reduction `Σ U` (n multiple of 8, ≤ 64): like the dot
/// product with `ADD(busA, r0)` accumulation instead of MULA.
#[derive(Debug, Clone, Copy)]
pub struct VecReduceMapping {
    pub n: usize,
}

impl VecReduceMapping {
    pub fn compile(&self) -> MappedRoutine {
        let chunks = Layout::columns_for(self.n);
        let words = words_for(self.n);

        // [0]: out = busA + r0, write r0 (running per-lane sum)
        // [1]: ring add.
        let mut acc = ContextWord::two_port(AluOp::Add);
        acc.mux_a = MuxASel::OperandBusA;
        acc.mux_b = MuxBSel::Reg(0);
        acc.reg_write = 0b0001;
        let ctx_words = vec![(CTX_ADDR, acc.encode()), (CTX_ADDR + 1, ring_add_word())];

        let mut prog = Vec::new();
        load_address(&mut prog, Reg(1), U_ADDR);
        prog.push(Instruction::Ldfb { rs: Reg(1), set: Set::Zero, bank: Bank::A, words, fb_addr: 0 });
        load_address(&mut prog, Reg(3), CTX_ADDR);
        prog.push(Instruction::Ldctxt { rs: Reg(3), block: Block::Column, plane: 0, word: 0, count: 2 });
        for c in 0..chunks {
            prog.push(Instruction::Sbcb { plane: 0, cw: 0, col: 0, set: Set::Zero, bank: Bank::A, addr: Layout::column_chunk(c) });
        }
        for _ in 0..ARRAY_DIM - 1 {
            prog.push(Instruction::Sbcb { plane: 0, cw: 1, col: 0, set: Set::Zero, bank: Bank::A, addr: 0 });
        }
        prog.push(Instruction::Wfbi { col: 0, set: Set::One, bank: Bank::A, addr: 0 });
        load_address(&mut prog, Reg(5), RESULT_ADDR);
        prog.push(Instruction::Stfb { rs: Reg(5), set: Set::One, bank: Bank::A, words: 4, fb_addr: 0 });

        let program = Program::new(prog);
        let predicted_cycles = program.paper_cycles();
        MappedRoutine {
            name: format!("reduce-{}", self.n),
            program,
            ctx_words,
            u_elems: self.n,
            v_elems: None,
            w_elems: None,
            result_elems: 8,
            predicted_cycles,
        }
    }
}

/// SAXPY `a·U + V` (n multiple of 8, ≤ 64): per column one CMUL broadcast
/// (result → r0) and one `ADD(r0, busB)` double-bank broadcast.
#[derive(Debug, Clone, Copy)]
pub struct SaxpyMapping {
    pub n: usize,
    /// The scalar a, i8 context-immediate range.
    pub a: i16,
}

impl SaxpyMapping {
    pub fn compile(&self) -> MappedRoutine {
        let cols = Layout::columns_for(self.n);
        let words = words_for(self.n);

        let mut cmul = ContextWord::immediate(AluOp::Cmul, self.a);
        cmul.reg_write = 0b0001;
        let mut add = ContextWord::two_port(AluOp::Add);
        add.mux_a = MuxASel::Reg(0);
        add.mux_b = MuxBSel::OperandBusB;
        let ctx_words = vec![(CTX_ADDR, cmul.encode()), (CTX_ADDR + 1, add.encode())];

        let mut prog = Vec::new();
        load_address(&mut prog, Reg(1), U_ADDR);
        prog.push(Instruction::Ldfb { rs: Reg(1), set: Set::Zero, bank: Bank::A, words, fb_addr: 0 });
        load_address(&mut prog, Reg(2), V_ADDR);
        prog.push(Instruction::Ldfb { rs: Reg(2), set: Set::Zero, bank: Bank::B, words, fb_addr: 0 });
        load_address(&mut prog, Reg(3), CTX_ADDR);
        prog.push(Instruction::Ldctxt { rs: Reg(3), block: Block::Column, plane: 0, word: 0, count: 2 });
        for c in 0..cols {
            let chunk = Layout::column_chunk(c);
            prog.push(Instruction::Sbcb { plane: 0, cw: 0, col: c, set: Set::Zero, bank: Bank::A, addr: chunk });
            prog.push(Instruction::Dbcdc { plane: 0, cw: 1, col: c, set: Set::Zero, addr_a: chunk, addr_b: chunk });
        }
        for c in 0..cols {
            prog.push(Instruction::Wfbi { col: c, set: Set::One, bank: Bank::A, addr: Layout::column_chunk(c) });
        }
        load_address(&mut prog, Reg(5), RESULT_ADDR);
        prog.push(Instruction::Stfb { rs: Reg(5), set: Set::One, bank: Bank::A, words, fb_addr: 0 });

        let program = Program::new(prog);
        let predicted_cycles = program.paper_cycles();
        MappedRoutine {
            name: format!("saxpy-{}x{}", self.a, self.n),
            program,
            ctx_words,
            u_elems: self.n,
            v_elems: Some(self.n),
            w_elems: None,
            result_elems: self.n,
            predicted_cycles,
        }
    }
}

/// Matrix-vector product `A·x` (dim ≤ 8): reuses the §5.3 matmul with x
/// replicated across B's columns; every RC-array column computes the same
/// `A·x`, so column 0's write-back is the answer — zero extra cycles over
/// the matmul, which is exactly the paper's "composite algorithms reuse
/// the mapped ones" point.
#[derive(Debug, Clone)]
pub struct MatVecMapping {
    pub dim: usize,
    /// Row-major A, i8 entries.
    pub a: Vec<i16>,
}

impl MatVecMapping {
    pub fn inner(&self) -> MatMulMapping {
        MatMulMapping { dim: self.dim, a: self.a.clone(), shift: 0 }
    }

    pub fn compile(&self) -> MappedRoutine {
        let mut r = self.inner().compile();
        r.name = format!("matvec-{}", self.dim);
        r
    }

    /// Stage the replicated-B input for vector `x`.
    pub fn stage_input(&self, x: &[i16]) -> Vec<i16> {
        assert_eq!(x.len(), self.dim);
        let mut b = vec![0i16; self.dim * self.dim];
        for k in 0..self.dim {
            for j in 0..self.dim {
                b[k * self.dim + j] = x[k];
            }
        }
        b
    }

    /// Extract `A·x` from the raw result.
    pub fn extract(&self, raw: &[i16]) -> Vec<i16> {
        (0..self.dim).map(|i| raw[ARRAY_DIM * i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::runner::run_routine;
    use crate::testkit::{check, Rng};

    #[test]
    fn dot_product_matches_native() {
        check("dot == native", 30, |rng: &mut Rng| {
            let n = [8usize, 16, 32, 64][rng.below(4) as usize];
            let u: Vec<i16> = (0..n).map(|_| rng.range_i64(-20, 20) as i16).collect();
            let v: Vec<i16> = (0..n).map(|_| rng.range_i64(-20, 20) as i16).collect();
            let routine = DotProductMapping { n }.compile();
            let out = run_routine(&routine, &u, Some(&v));
            let expected: i32 = u.iter().zip(&v).map(|(a, b)| *a as i32 * *b as i32).sum();
            assert_eq!(out.result[0] as i32, expected, "n={n}");
            // Ring reduction replicates the result down the column.
            assert!(out.result[..8].iter().all(|&r| r as i32 == expected));
        });
    }

    #[test]
    fn dot_product_cycle_count_is_near_translation() {
        // Dot = translation's data movement + 7 extra broadcasts + 3 more
        // context words − the wfbi/stfb narrowing.
        let dot = DotProductMapping { n: 64 }.compile();
        assert!(dot.predicted_cycles < 110, "{}", dot.predicted_cycles);
        let out = run_routine(&dot, &vec![1; 64], Some(&vec![1; 64]));
        assert_eq!(out.report.cycles, dot.predicted_cycles);
    }

    #[test]
    fn reduction_matches_native() {
        check("reduce == native", 30, |rng: &mut Rng| {
            let n = [8usize, 24, 64][rng.below(3) as usize];
            let u: Vec<i16> = (0..n).map(|_| rng.range_i64(-100, 100) as i16).collect();
            let routine = VecReduceMapping { n }.compile();
            let out = run_routine(&routine, &u, None);
            let expected: i32 = u.iter().map(|&a| a as i32).sum();
            assert_eq!(out.result[0] as i32, expected, "n={n}");
        });
    }

    #[test]
    fn saxpy_matches_native() {
        check("saxpy == native", 30, |rng: &mut Rng| {
            let n = [8usize, 32, 64][rng.below(3) as usize];
            let a = rng.range_i64(-10, 10) as i16;
            let u: Vec<i16> = (0..n).map(|_| rng.range_i64(-50, 50) as i16).collect();
            let v: Vec<i16> = (0..n).map(|_| rng.range_i64(-50, 50) as i16).collect();
            let routine = SaxpyMapping { n, a }.compile();
            let out = run_routine(&routine, &u, Some(&v));
            for i in 0..n {
                assert_eq!(out.result[i] as i32, a as i32 * u[i] as i32 + v[i] as i32);
            }
        });
    }

    #[test]
    fn matvec_matches_native() {
        check("matvec == native", 20, |rng: &mut Rng| {
            let dim = rng.range_i64(2, 8) as usize;
            let a: Vec<i16> = (0..dim * dim).map(|_| rng.range_i64(-9, 9) as i16).collect();
            let x: Vec<i16> = (0..dim).map(|_| rng.range_i64(-9, 9) as i16).collect();
            let m = MatVecMapping { dim, a: a.clone() };
            let out = run_routine(&m.compile(), &m.stage_input(&x), None);
            let y = m.extract(&out.result);
            for i in 0..dim {
                let e: i32 = (0..dim).map(|k| a[i * dim + k] as i32 * x[k] as i32).sum();
                assert_eq!(y[i] as i32, e, "y[{i}]");
            }
        });
    }

    #[test]
    fn single_chunk_dot_sets_reg_write_on_first_word() {
        // n=8 has one MULA chunk: the "first" word must carry reg_write.
        let routine = DotProductMapping { n: 8 }.compile();
        let first = ContextWord::decode(routine.ctx_words[0].1);
        assert!(first.acc_reset);
        assert_eq!(first.reg_write, 0b0001);
        let out = run_routine(&routine, &[1, 2, 3, 4, 5, 6, 7, 8], Some(&[1; 8]));
        assert_eq!(out.result[0], 36);
    }

    #[test]
    fn extended_mappings_all_beat_the_obvious_x86_loop_bound() {
        // A 64-element dot product on the 486 costs at least
        // 64 × (2 loads + IMUL 18 + add + 3 pointer/loop ops) ≈ 1500+
        // cycles; the M1 mapping fits in ~100.
        let dot = DotProductMapping { n: 64 }.compile();
        assert!(dot.predicted_cycles * 10 < 1500);
    }
}
