//! Multi-tile streaming: vectors larger than one 64-element array tile,
//! with and without frame-buffer double-buffering.
//!
//! The M1 description (paper §2) promises that "since the frame buffer is
//! divided into two sets, new application data can be loaded into it
//! without interrupting the operation of the RC array". The published
//! listings never exercise it (single-tile workloads, blocking DMA). This
//! module does: [`StreamedTiledMapping`] is the first-class **set
//! ping-pong** schedule — tile t computes from set `t mod 2` while tile
//! t+1's DMA streams into the other set — measurable under the async-DMA
//! system mode (`M1System::with_async_dma`), which is exactly the
//! hardware the quote describes. [`TiledVecVecMapping`] keeps the
//! **naive** single-set load → compute → store baseline (and delegates
//! its `streamed` mode to the ping-pong mapping). The ablation bench
//! quantifies the claim.
//!
//! ## Emitted shape (§Perf PR 5)
//!
//! Every per-tile phase of the streamed schedule is emitted in the shape
//! the compiled tiers want, so the whole program rides the
//! scheduled/fused path in **both** DMA modes (see
//! [`crate::morphosys::BroadcastSchedule`]):
//!
//! * **loads** — both bank addresses are formed first, then the two
//!   same-set `ldfb` fills issue back-to-back (one contiguous engine
//!   stream per set, no scalar work splitting the transfers);
//! * **broadcasts** — one run of eight contiguous `dbcdc`s per tile
//!   (ascending columns, bus addresses advancing by 8), which fuses into
//!   a single SIMD lane-kernel loop;
//! * **write-backs** — one run of eight contiguous `wfbi`s to one
//!   frame-buffer span, which fuses into a single slice commit.
//!
//! Both schedules run every tile on **one** simulator instance. The third
//! way to scale multi-tile workloads is across simulators: the sharded
//! tile pool ([`crate::coordinator::pool::TilePool::run_vecvec`]) runs
//! the same 64-point tiles on per-shard systems in parallel, with results
//! pinned bit-for-bit against these monolithic schedules by the tests
//! below.

use crate::morphosys::context_memory::Block;
use crate::morphosys::frame_buffer::{Bank, Set};
use crate::morphosys::rc_array::{AluOp, ContextWord, ARRAY_DIM};
use crate::morphosys::tinyrisc::{Instruction, Program, Reg};

use super::layout::{CTX_ADDR, RESULT_ADDR, U_ADDR, V_ADDR};
use super::routines::{MappedRoutine, PointTransformMapping};

/// Elements per array tile (the full 8×8 RC array).
pub const TILE: usize = 64;
/// 32-bit words per tile per bank.
const TILE_WORDS: usize = TILE / 2;
/// Frame-buffer element offset where tile results are written back
/// (inputs occupy 0..64 of banks A/B; outputs go to 512.. of bank A).
const OUT_FB: usize = 512;

/// Emit the `ldui`/`ldli` pair loading the full 32-bit address `addr`
/// into `rd`. Always both halves (unlike the single-tile mappings'
/// skip-zero-low-half emission): tiles beyond the first need the low
/// half, and a uniform pair keeps every tile's shape identical.
fn emit_addr(prog: &mut Vec<Instruction>, rd: Reg, addr: usize) {
    prog.push(Instruction::Ldui { rd, imm: (addr >> 16) as u16 });
    prog.push(Instruction::Ldli { rd, imm: (addr & 0xFFFF) as u16 });
}

/// Emit the shared context-word preamble (one column-plane word from
/// [`CTX_ADDR`]).
fn emit_ctx_preamble(prog: &mut Vec<Instruction>) {
    prog.push(Instruction::Ldui { rd: Reg(3), imm: (CTX_ADDR >> 16) as u16 });
    prog.push(Instruction::Ldctxt { rs: Reg(3), block: Block::Column, plane: 0, word: 0, count: 1 });
}

/// Emit the load of tile `t` into `set`: addresses formed first, then
/// the two same-set fills back-to-back (contiguous loads — one unbroken
/// engine stream per set).
fn emit_tile_load(prog: &mut Vec<Instruction>, set: Set, t: usize) {
    let off = t * TILE_WORDS;
    emit_addr(prog, Reg(1), U_ADDR + off);
    emit_addr(prog, Reg(2), V_ADDR + off);
    prog.push(Instruction::Ldfb { rs: Reg(1), set, bank: Bank::A, words: TILE_WORDS, fb_addr: 0 });
    prog.push(Instruction::Ldfb { rs: Reg(2), set, bank: Bank::B, words: TILE_WORDS, fb_addr: 0 });
}

/// Emit one tile's compute phase against `set`: eight contiguous
/// double-bank column broadcasts (ascending columns, bus addresses
/// advancing by [`ARRAY_DIM`]) — exactly the fused-run shape.
fn emit_tile_compute(prog: &mut Vec<Instruction>, set: Set) {
    for c in 0..ARRAY_DIM {
        prog.push(Instruction::Dbcdc {
            plane: 0,
            cw: 0,
            col: c,
            set,
            addr_a: c * ARRAY_DIM,
            addr_b: c * ARRAY_DIM,
        });
    }
}

/// Emit tile `t`'s result drain from `set`: eight contiguous write-backs
/// into one frame-buffer span (the fused single-slice commit shape),
/// then the store DMA back to main memory.
fn emit_tile_store(prog: &mut Vec<Instruction>, set: Set, t: usize) {
    for c in 0..ARRAY_DIM {
        prog.push(Instruction::Wfbi { col: c, set, bank: Bank::A, addr: OUT_FB + c * ARRAY_DIM });
    }
    emit_addr(prog, Reg(5), RESULT_ADDR + t * TILE_WORDS);
    prog.push(Instruction::Stfb { rs: Reg(5), set, bank: Bank::A, words: TILE_WORDS, fb_addr: OUT_FB });
}

/// The streamed multi-tile element-wise mapping (n a multiple of 64),
/// built around explicit frame-buffer **set ping-pong**: tile `t` lives
/// in set `t mod 2`, so under async DMA the fills of tile t+1 overlap
/// the broadcasts of tile t — the paper's double-buffering scenario as a
/// software pipeline: `load(0); for t: load(t+1) ‖ compute(t); store(t)`.
///
/// The emitted per-tile programs are fusion-eligible by construction
/// (see the module docs), so this mapping executes on the
/// scheduled/fused tier in both DMA modes.
#[derive(Debug, Clone, Copy)]
pub struct StreamedTiledMapping {
    pub n: usize,
    pub op: AluOp,
}

impl StreamedTiledMapping {
    /// The ping-pong: tile `t` computes from (and stores through) set
    /// `t mod 2` while the other set is being filled.
    fn tile_set(t: usize) -> Set {
        Set::from_index(t % 2)
    }

    pub fn compile(&self) -> MappedRoutine {
        assert!(self.n >= TILE && self.n % TILE == 0, "n must be a multiple of {TILE}");
        assert!(!self.op.uses_immediate());
        let tiles = self.n / TILE;
        let mut prog = Vec::new();
        emit_ctx_preamble(&mut prog);

        // Software pipeline over the two sets:
        //   load(0); for t: [load(t+1) into the other set] ‖ compute(t);
        //   store(t).
        emit_tile_load(&mut prog, Self::tile_set(0), 0);
        for t in 0..tiles {
            if t + 1 < tiles {
                emit_tile_load(&mut prog, Self::tile_set(t + 1), t + 1);
            }
            emit_tile_compute(&mut prog, Self::tile_set(t));
            emit_tile_store(&mut prog, Self::tile_set(t), t);
        }

        let program = Program::new(prog);
        let predicted_cycles = program.paper_cycles();
        MappedRoutine {
            name: format!("streamed-vecvec-{:?}-{}", self.op, self.n),
            program,
            ctx_words: vec![(CTX_ADDR, ContextWord::two_port(self.op).encode())],
            u_elems: self.n,
            v_elems: Some(self.n),
            w_elems: None,
            result_elems: self.n,
            predicted_cycles,
        }
    }
}

/// The streamed multi-tile 2-D point transformation (n a multiple of 64):
/// `q = ((M · p) >> shift) + t` over the whole request as **one** program,
/// under the same set ping-pong as [`StreamedTiledMapping`] — the
/// plan-level emit path the megakernel tier compiles (§Perf, megakernel
/// tier). The per-coordinate context-word schedules are exactly
/// [`PointTransformMapping::coord_words`] (one source of truth for the
/// transform math), loaded **once** for the whole plan; every tile then
/// pays only its DMA fills, 2·`per` broadcasts per column, and two result
/// drains — no per-tile routine dispatch, context staging, or program
/// setup.
///
/// Result layout: all `n` x' coordinates at [`RESULT_ADDR`], then all `n`
/// y' — the whole-request analogue of the per-tile mapping's
/// `[x'][y']` halves.
#[derive(Debug, Clone, Copy)]
pub struct StreamedPointTransformMapping {
    /// Number of points; a multiple of 64.
    pub n: usize,
    /// Row-major 2×2 matrix, fixed-point `Q(shift)`, i8 range.
    pub m: [i16; 4],
    /// Translation, applied after the shift (plain integer).
    pub t: [i16; 2],
    /// Fixed-point shift for the matrix product.
    pub shift: u8,
}

impl StreamedPointTransformMapping {
    pub fn compile(&self) -> MappedRoutine {
        assert!(self.n >= TILE && self.n % TILE == 0, "n must be a multiple of {TILE}");
        assert!(
            (-128..=127).contains(&self.t[0]) && (-128..=127).contains(&self.t[1]),
            "translation components must fit the 8-bit context immediate"
        );
        let tiles = self.n / TILE;
        let per_tile = PointTransformMapping {
            n: TILE,
            m: self.m,
            t: self.t,
            shift: self.shift,
        };
        let x_sched = per_tile.coord_words(0);
        let y_sched = per_tile.coord_words(1);
        let per = x_sched.len(); // steps per coordinate (3 or 4)
        let mut ctx_words = Vec::new();
        for (w, raw) in x_sched.iter().chain(y_sched.iter()).enumerate() {
            ctx_words.push((CTX_ADDR + w, *raw));
        }

        let mut prog = Vec::new();
        // The whole plan's context words in one transfer, once.
        emit_addr(&mut prog, Reg(3), CTX_ADDR);
        prog.push(Instruction::Ldctxt {
            rs: Reg(3),
            block: Block::Column,
            plane: 0,
            word: 0,
            count: 2 * per,
        });

        // Same software pipeline as the streamed vecvec plan: tile t
        // computes from set t mod 2 while tile t+1's fills stream into
        // the other set. X coords ride bank A, Y coords bank B; x'/y'
        // results land in the same set's banks A/B at OUT_FB and drain
        // into the [all x'][all y'] halves of the result region.
        emit_tile_load(&mut prog, StreamedTiledMapping::tile_set(0), 0);
        let n_words = self.n / 2;
        for t in 0..tiles {
            let set = StreamedTiledMapping::tile_set(t);
            if t + 1 < tiles {
                emit_tile_load(&mut prog, StreamedTiledMapping::tile_set(t + 1), t + 1);
            }
            for c in 0..ARRAY_DIM {
                let chunk = c * ARRAY_DIM;
                for (base, out_bank) in [(0, Bank::A), (per, Bank::B)] {
                    // CMUL·x from bank A, CMUL·y from bank B, then
                    // shift/add (operand bus unused by the
                    // register-sourced steps).
                    prog.push(Instruction::Sbcb { plane: 0, cw: base, col: c, set, bank: Bank::A, addr: chunk });
                    prog.push(Instruction::Sbcb { plane: 0, cw: base + 1, col: c, set, bank: Bank::B, addr: chunk });
                    for s in 2..per {
                        prog.push(Instruction::Sbcb { plane: 0, cw: base + s, col: c, set, bank: Bank::A, addr: chunk });
                    }
                    prog.push(Instruction::Wfbi { col: c, set, bank: out_bank, addr: OUT_FB + chunk });
                }
            }
            emit_addr(&mut prog, Reg(5), RESULT_ADDR + t * TILE_WORDS);
            prog.push(Instruction::Stfb { rs: Reg(5), set, bank: Bank::A, words: TILE_WORDS, fb_addr: OUT_FB });
            emit_addr(&mut prog, Reg(6), RESULT_ADDR + n_words + t * TILE_WORDS);
            prog.push(Instruction::Stfb { rs: Reg(6), set, bank: Bank::B, words: TILE_WORDS, fb_addr: OUT_FB });
        }

        let program = Program::new(prog);
        let predicted_cycles = program.paper_cycles();
        MappedRoutine {
            name: format!("streamed-pointxf-{}", self.n),
            program,
            ctx_words,
            u_elems: self.n,
            v_elems: Some(self.n),
            w_elems: None,
            result_elems: 2 * self.n,
            predicted_cycles,
        }
    }
}

/// Multi-tile element-wise vector-vector mapping (n a multiple of 64).
/// `streamed: false` is the naive single-set baseline; `streamed: true`
/// delegates to [`StreamedTiledMapping`]'s set ping-pong.
#[derive(Debug, Clone, Copy)]
pub struct TiledVecVecMapping {
    pub n: usize,
    pub op: AluOp,
    /// Ping-pong the two FB sets to overlap DMA with compute.
    pub streamed: bool,
}

impl TiledVecVecMapping {
    pub fn compile(&self) -> MappedRoutine {
        if self.streamed {
            return StreamedTiledMapping { n: self.n, op: self.op }.compile();
        }
        assert!(self.n >= TILE && self.n % TILE == 0, "n must be a multiple of {TILE}");
        assert!(!self.op.uses_immediate());
        let tiles = self.n / TILE;
        let mut prog = Vec::new();
        emit_ctx_preamble(&mut prog);

        // Naive baseline: everything through set 0, strictly
        // load → compute → store per tile (no overlap to exploit).
        for t in 0..tiles {
            emit_tile_load(&mut prog, Set::Zero, t);
            emit_tile_compute(&mut prog, Set::Zero);
            emit_tile_store(&mut prog, Set::Zero, t);
        }

        let program = Program::new(prog);
        let predicted_cycles = program.paper_cycles();
        MappedRoutine {
            name: format!("tiled-vecvec-{:?}-{}", self.op, self.n),
            program,
            ctx_words: vec![(CTX_ADDR, ContextWord::two_port(self.op).encode())],
            u_elems: self.n,
            v_elems: Some(self.n),
            w_elems: None,
            result_elems: self.n,
            predicted_cycles,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::runner::run_routine_on;
    use crate::morphosys::M1System;
    use crate::testkit::{check, Rng};

    fn expected(u: &[i16], v: &[i16]) -> Vec<i16> {
        u.iter().zip(v).map(|(a, b)| a.wrapping_add(*b)).collect()
    }

    #[test]
    fn naive_tiled_computes_correctly() {
        let n = 256;
        let u: Vec<i16> = (0..n as i16).collect();
        let v: Vec<i16> = (0..n as i16).map(|i| 1000 - i).collect();
        let m = TiledVecVecMapping { n, op: AluOp::Add, streamed: false };
        let out = run_routine_on(&mut M1System::new(), &m.compile(), &u, Some(&v));
        assert_eq!(out.result, expected(&u, &v));
    }

    #[test]
    fn streamed_tiled_computes_correctly_in_both_dma_modes() {
        let n = 192;
        let u: Vec<i16> = (0..n as i16).collect();
        let v = vec![7i16; n];
        let m = TiledVecVecMapping { n, op: AluOp::Add, streamed: true };
        let routine = m.compile();
        for sys in [M1System::new(), M1System::new().with_async_dma()] {
            let mut sys = sys;
            let out = run_routine_on(&mut sys, &routine, &u, Some(&v));
            assert_eq!(out.result, expected(&u, &v));
        }
    }

    #[test]
    fn double_buffering_overlaps_dma_with_compute_to_the_dma_roofline() {
        // The paper's §2 claim, quantified. With one DMA engine the
        // workload is bandwidth-bound: per tile the engine moves
        // 64 load + 32 store = 96 words. Streaming + async DMA must (a)
        // clearly beat the naive blocking schedule and (b) land within
        // 10% of that DMA roofline — i.e. compute is fully hidden.
        let n = 512;
        let tiles = (n / TILE) as u64;
        let u: Vec<i16> = (0..n as i16).collect();
        let v = vec![1i16; n];
        let naive = TiledVecVecMapping { n, op: AluOp::Add, streamed: false }.compile();
        let streamed = TiledVecVecMapping { n, op: AluOp::Add, streamed: true }.compile();

        let sync_naive =
            run_routine_on(&mut M1System::new(), &naive, &u, Some(&v)).report.cycles;
        let async_streamed =
            run_routine_on(&mut M1System::new().with_async_dma(), &streamed, &u, Some(&v))
                .report
                .cycles;
        assert!(
            (async_streamed as f64) < 0.85 * sync_naive as f64,
            "streamed+async {async_streamed} !< 0.85 × naive+sync {sync_naive}"
        );
        let dma_roofline = tiles * (2 * TILE_WORDS as u64 + TILE_WORDS as u64);
        assert!(
            (async_streamed as f64) < 1.10 * dma_roofline as f64,
            "streamed+async {async_streamed} not at DMA roofline {dma_roofline}"
        );
    }

    #[test]
    fn streaming_without_async_dma_gains_nothing() {
        // On the blocking-DMA model the schedule permutation alone cannot
        // help — the TinyRISC stalls through every transfer anyway.
        let n = 256;
        let u: Vec<i16> = (0..n as i16).collect();
        let v = vec![1i16; n];
        let naive = TiledVecVecMapping { n, op: AluOp::Add, streamed: false }.compile();
        let streamed = TiledVecVecMapping { n, op: AluOp::Add, streamed: true }.compile();
        let a = run_routine_on(&mut M1System::new(), &naive, &u, Some(&v)).report.cycles;
        let b = run_routine_on(&mut M1System::new(), &streamed, &u, Some(&v)).report.cycles;
        assert_eq!(a, b);
    }

    #[test]
    fn property_tiled_matches_native_for_random_sizes() {
        check("tiled == native", 15, |rng: &mut Rng| {
            let n = 64 * rng.range_i64(1, 8) as usize;
            let u = rng.small_vec(n);
            let v = rng.small_vec(n);
            for streamed in [false, true] {
                let m = TiledVecVecMapping { n, op: AluOp::Add, streamed };
                let out =
                    run_routine_on(&mut M1System::new().with_async_dma(), &m.compile(), &u, Some(&v));
                assert_eq!(out.result, expected(&u, &v), "streamed={streamed} n={n}");
            }
        });
    }

    #[test]
    fn property_streamed_and_naive_agree_in_both_dma_modes() {
        // The streamed schedule is a pure permutation of the naive one:
        // for any input, any covered size and either DMA model it must
        // produce identical results (and match the native reference).
        check("streamed == naive across DMA modes", 8, |rng: &mut Rng| {
            for n in [64usize, 128, 512] {
                let u = rng.small_vec(n);
                let v = rng.small_vec(n);
                let want = expected(&u, &v);
                let naive = TiledVecVecMapping { n, op: AluOp::Add, streamed: false }.compile();
                let streamed = TiledVecVecMapping { n, op: AluOp::Add, streamed: true }.compile();
                for async_dma in [false, true] {
                    let mk = || M1System::with_dma_mode(async_dma);
                    let a = run_routine_on(&mut mk(), &naive, &u, Some(&v));
                    let b = run_routine_on(&mut mk(), &streamed, &u, Some(&v));
                    assert_eq!(a.result, want, "naive n={n} async={async_dma}");
                    assert_eq!(b.result, want, "streamed n={n} async={async_dma}");
                }
            }
        });
    }

    #[test]
    fn pooled_tiles_match_monolithic_schedules_across_shard_counts() {
        // The pool-targeted runner decomposes the same workload into
        // independent 64-point tiles; for any shard count its spliced
        // result must equal both monolithic schedules (and native).
        use crate::coordinator::pool::TilePool;
        check("pooled == tiled == native", 6, |rng: &mut Rng| {
            let n = 64 * rng.range_i64(1, 6) as usize;
            let u = rng.small_vec(n);
            let v = rng.small_vec(n);
            let want = expected(&u, &v);
            let naive = TiledVecVecMapping { n, op: AluOp::Add, streamed: false }.compile();
            let mono = run_routine_on(&mut M1System::new(), &naive, &u, Some(&v));
            assert_eq!(mono.result, want);
            let mut baseline_cycles = None;
            for shards in [1usize, 2, 4] {
                let mut pool = TilePool::new(shards);
                let (result, cycles) = pool.run_vecvec(AluOp::Add, &u, &v);
                assert_eq!(result, want, "shards={shards} n={n}");
                assert_eq!(*baseline_cycles.get_or_insert(cycles), cycles, "shards={shards}");
            }
        });
    }

    #[test]
    #[should_panic(expected = "multiple of 64")]
    fn ragged_sizes_rejected() {
        TiledVecVecMapping { n: 100, op: AluOp::Add, streamed: false }.compile();
    }

    #[test]
    #[should_panic(expected = "multiple of 64")]
    fn streamed_ragged_sizes_rejected() {
        StreamedTiledMapping { n: 100, op: AluOp::Add }.compile();
    }

    #[test]
    fn tiled_streamed_mode_delegates_to_the_streamed_mapping() {
        let tiled = TiledVecVecMapping { n: 192, op: AluOp::Add, streamed: true }.compile();
        let streamed = StreamedTiledMapping { n: 192, op: AluOp::Add }.compile();
        assert_eq!(tiled.program, streamed.program);
        assert_eq!(tiled.ctx_words, streamed.ctx_words);
    }

    #[test]
    fn streamed_point_transform_matches_per_tile_mapping_in_both_dma_modes() {
        // The plan-level program must agree with the per-64-point
        // PointTransformMapping on every tile: same transform words, same
        // math — only the dispatch granularity differs. Result layout is
        // [all x'][all y'] vs per-tile [x'][y'] halves.
        use crate::mapping::PointTransformMapping;
        let n = 192;
        let (m, t, shift) = ([3i16, -2, 1, 4], [17i16, -9], 2u8);
        let xs: Vec<i16> = (0..n as i16).map(|i| 5 * i - 400).collect();
        let ys: Vec<i16> = (0..n as i16).map(|i| 300 - 3 * i).collect();
        let plan = StreamedPointTransformMapping { n, m, t, shift }.compile();
        let tile_routine = PointTransformMapping { n: TILE, m, t, shift }.compile();
        for async_dma in [false, true] {
            let got = run_routine_on(
                &mut M1System::with_dma_mode(async_dma),
                &plan,
                &xs,
                Some(&ys),
            );
            assert_eq!(got.result.len(), 2 * n);
            for tile in 0..n / TILE {
                let span = tile * TILE..(tile + 1) * TILE;
                let per = run_routine_on(
                    &mut M1System::with_dma_mode(async_dma),
                    &tile_routine,
                    &xs[span.clone()],
                    Some(&ys[span.clone()]),
                );
                assert_eq!(
                    &got.result[span.clone()],
                    &per.result[..TILE],
                    "x' tile {tile} async={async_dma}"
                );
                assert_eq!(
                    &got.result[n + tile * TILE..n + (tile + 1) * TILE],
                    &per.result[TILE..],
                    "y' tile {tile} async={async_dma}"
                );
            }
        }
    }

    #[test]
    fn streamed_point_transform_shares_the_per_tile_context_words() {
        // One source of truth: the plan's context-word schedule is exactly
        // the per-tile mapping's.
        use crate::mapping::PointTransformMapping;
        let (m, t) = ([1i16, 0, 0, 1], [3i16, 4]);
        for shift in [0u8, 6] {
            let plan = StreamedPointTransformMapping { n: 128, m, t, shift }.compile();
            let tile = PointTransformMapping { n: TILE, m, t, shift }.compile();
            assert_eq!(plan.ctx_words, tile.ctx_words, "shift={shift}");
        }
    }

    #[test]
    #[should_panic(expected = "multiple of 64")]
    fn streamed_point_transform_ragged_sizes_rejected() {
        StreamedPointTransformMapping { n: 100, m: [1, 0, 0, 1], t: [0, 0], shift: 0 }.compile();
    }

    #[test]
    fn streamed_async_runs_on_the_scheduled_fused_tier() {
        // The §Perf PR 5 acceptance shape: the async-DMA streamed mapping
        // must ride the scheduled/fused tier — the shared cache compiles
        // it (no interpreter fallback), every tile's broadcast and
        // write-back runs fuse, and the scheduled execution is
        // bit-identical to the interpreter on results AND cycle reports
        // in both DMA modes.
        use crate::mapping::runner::{run_routine3_with, schedule_for};
        let n = 256;
        let routine = StreamedTiledMapping { n, op: AluOp::Add }.compile();
        let schedule = schedule_for(&routine.program).expect("streamed programs must compile");
        assert_eq!(
            schedule.fused_runs(),
            2 * (n / TILE),
            "one fused broadcast run + one fused write-back run per tile"
        );
        let u: Vec<i16> = (0..n as i16).collect();
        let v: Vec<i16> = (0..n as i16).map(|i| 3 * i - 7).collect();
        let want = expected(&u, &v);
        for async_dma in [false, true] {
            let mut interp_sys = M1System::with_dma_mode(async_dma);
            let interp = run_routine3_with(&mut interp_sys, &routine, &u, Some(&v), None, None);
            let mut sched_sys = M1System::with_dma_mode(async_dma);
            let sched =
                run_routine3_with(&mut sched_sys, &routine, &u, Some(&v), None, Some(&schedule));
            assert_eq!(interp.result, want, "interpreter result async={async_dma}");
            assert_eq!(sched.result, want, "scheduled result async={async_dma}");
            assert_eq!(interp.report.cycles, sched.report.cycles, "cycles async={async_dma}");
            assert_eq!(interp.report.slots, sched.report.slots, "slots async={async_dma}");
            assert_eq!(
                interp.report.executed, sched.report.executed,
                "executed async={async_dma}"
            );
            assert_eq!(
                interp.report.broadcasts, sched.report.broadcasts,
                "broadcasts async={async_dma}"
            );
        }
    }
}
