//! Static analysis of mapped routines: per-phase cycle breakdown and the
//! calibration of the cost model against the paper's published numbers.
//!
//! The breakdown splits a routine's issue slots into the four phases of
//! every M1 mapping — input DMA, configuration (context load), compute
//! (broadcast triggers) and write-back/store — which is the basis of the
//! ablation study in `EXPERIMENTS.md` (where does the M1's advantage come
//! from, and what would a slower context bus cost?).

use crate::morphosys::tinyrisc::{Instruction, Program};

/// Per-phase slot breakdown of a mapping.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MappingPlan {
    /// Slots spent loading application data (ldfb + address formation).
    pub load: u64,
    /// Slots spent loading configuration (ldctxt + address formation).
    pub config: u64,
    /// Slots spent triggering RC-array broadcasts.
    pub compute: u64,
    /// Slots spent writing back and storing results (wfbi + stfb).
    pub store: u64,
    /// Anything else (branches, scalar arithmetic).
    pub other: u64,
}

impl MappingPlan {
    /// Classify a straight-line program into phases. Address-formation
    /// instructions (`ldui`/`ldli`) are attributed to the phase of the
    /// *next* non-scalar instruction.
    pub fn analyze(program: &Program) -> MappingPlan {
        let mut plan = MappingPlan::default();
        let mut pending_scalar = 0u64;
        for instr in &program.instructions {
            let slots = instr.issue_slots();
            match instr {
                Instruction::Ldui { .. }
                | Instruction::Ldli { .. }
                | Instruction::Addi { .. }
                | Instruction::Add { .. }
                | Instruction::Sub { .. } => pending_scalar += slots,
                Instruction::Ldfb { .. } => {
                    plan.load += slots + pending_scalar;
                    pending_scalar = 0;
                }
                Instruction::Ldctxt { .. } => {
                    plan.config += slots + pending_scalar;
                    pending_scalar = 0;
                }
                Instruction::Dbcdc { .. }
                | Instruction::Dbcdr { .. }
                | Instruction::Sbcb { .. }
                | Instruction::Sbcbr { .. } => {
                    plan.compute += slots + pending_scalar;
                    pending_scalar = 0;
                }
                Instruction::Wfbi { .. } | Instruction::Wfbir { .. } | Instruction::Stfb { .. } => {
                    plan.store += slots + pending_scalar;
                    pending_scalar = 0;
                }
                Instruction::Jmp { .. } | Instruction::Bnez { .. } | Instruction::Halt => {
                    plan.other += slots + pending_scalar;
                    pending_scalar = 0;
                }
            }
        }
        plan.other += pending_scalar;
        plan
    }

    pub fn total_slots(&self) -> u64 {
        self.load + self.config + self.compute + self.store + self.other
    }

    /// Fraction of slots doing RC-array compute (vs data movement).
    pub fn compute_fraction(&self) -> f64 {
        self.compute as f64 / self.total_slots() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::routines::{VecScalarMapping, VecVecMapping};
    use crate::morphosys::AluOp;

    #[test]
    fn breakdown_of_translation_64() {
        // Table 1 structure: 66 load slots, 5 config, 16 compute; store =
        // 8 wfbi + 1 ldui + 32 stfb-DMA slots (the DMA tail beyond the
        // paper's counting window).
        let r = VecVecMapping { n: 64, op: AluOp::Add }.compile();
        let plan = MappingPlan::analyze(&r.program);
        assert_eq!(plan.load, 66);
        assert_eq!(plan.config, 5);
        assert_eq!(plan.compute, 16);
        assert_eq!(plan.store, 41);
        assert_eq!(plan.other, 0);
        assert_eq!(plan.total_slots(), 128);
    }

    #[test]
    fn breakdown_of_scaling_64() {
        let r = VecScalarMapping { n: 64, op: AluOp::Cmul, scalar: 5 }.compile();
        let plan = MappingPlan::analyze(&r.program);
        assert_eq!(plan.load, 33);
        assert_eq!(plan.config, 5);
        assert_eq!(plan.compute, 8);
        assert_eq!(plan.store, 41);
        assert_eq!(plan.total_slots(), 87);
    }

    #[test]
    fn data_movement_dominates_the_m1_budget() {
        // The headline insight the ablation bench quantifies: even on the
        // winning platform, ≥ 2/3 of the 64-element translation budget is
        // DMA, not compute.
        let r = VecVecMapping { n: 64, op: AluOp::Add }.compile();
        let plan = MappingPlan::analyze(&r.program);
        assert!(plan.compute_fraction() < 0.25);
        assert!((plan.load + plan.store) as f64 / plan.total_slots() as f64 > 0.6);
    }

    #[test]
    fn plan_total_matches_program_slots() {
        for n in [8, 16, 32, 64] {
            let r = VecVecMapping { n, op: AluOp::Add }.compile();
            assert_eq!(
                MappingPlan::analyze(&r.program).total_slots(),
                r.program.straight_line_slots()
            );
        }
    }
}
