//! Data layout conventions of the paper's mappings.
//!
//! A 64-element vector is tiled over the 8×8 RC array **column-major**
//! (paper Figures 7–8): element `i` lands in cell `(row = i mod 8,
//! col = i div 8)`, because each column broadcast consumes eight
//! consecutive frame-buffer elements. The frame buffer is element (16-bit)
//! addressed; the chunk feeding column `c` starts at element `8·c`.

use crate::morphosys::rc_array::ARRAY_DIM;

/// Main-memory word address of vector U / matrix B (paper: `10,000_hex`).
pub const U_ADDR: usize = 0x10000;
/// Main-memory word address of vector V (paper: `20,000_hex`).
pub const V_ADDR: usize = 0x20000;
/// Main-memory word address of the context words (paper: `30,000_hex`).
pub const CTX_ADDR: usize = 0x30000;
/// Main-memory word address of the result (paper: `40,000_hex`).
pub const RESULT_ADDR: usize = 0x40000;
/// Main-memory word address of a third input stream (z coordinates of
/// the 3-D mappings; outside the paper's 2-D address map).
pub const W_ADDR: usize = 0x50000;

/// The column-major vector→array layout.
#[derive(Debug, Clone, Copy)]
pub struct Layout;

impl Layout {
    /// Cell coordinates of vector element `i` (Figure 7/8).
    pub fn cell_of(i: usize) -> (usize, usize) {
        (i % ARRAY_DIM, i / ARRAY_DIM)
    }

    /// Vector element held by cell `(row, col)`.
    pub fn element_of(row: usize, col: usize) -> usize {
        col * ARRAY_DIM + row
    }

    /// Frame-buffer element address of the 8-element chunk feeding column
    /// `c`.
    pub fn column_chunk(c: usize) -> usize {
        c * ARRAY_DIM
    }

    /// Number of column broadcasts needed for an `n`-element vector.
    pub fn columns_for(n: usize) -> usize {
        assert!(n % ARRAY_DIM == 0, "vector length {n} must be a multiple of {ARRAY_DIM}");
        assert!(n <= ARRAY_DIM * ARRAY_DIM, "vector length {n} exceeds one array tile");
        n / ARRAY_DIM
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_matches_paper_figure7() {
        // Figure 7: U9+V9 sits at row 1, column 1; U56+V56 at row 0, col 7.
        assert_eq!(Layout::cell_of(9), (1, 1));
        assert_eq!(Layout::cell_of(56), (0, 7));
        assert_eq!(Layout::cell_of(63), (7, 7));
        assert_eq!(Layout::cell_of(0), (0, 0));
    }

    #[test]
    fn cell_of_and_element_of_are_inverse() {
        for i in 0..64 {
            let (r, c) = Layout::cell_of(i);
            assert_eq!(Layout::element_of(r, c), i);
        }
    }

    #[test]
    fn column_chunks_stride_by_eight() {
        assert_eq!(Layout::column_chunk(0), 0);
        assert_eq!(Layout::column_chunk(3), 24);
        assert_eq!(Layout::column_chunk(7), 56);
    }

    #[test]
    fn columns_for_valid_sizes() {
        assert_eq!(Layout::columns_for(8), 1);
        assert_eq!(Layout::columns_for(64), 8);
    }

    #[test]
    #[should_panic(expected = "multiple")]
    fn columns_for_rejects_ragged_sizes() {
        Layout::columns_for(12);
    }

    #[test]
    fn paper_address_map() {
        assert_eq!(U_ADDR, 0x10000);
        assert_eq!(V_ADDR, 0x20000);
        assert_eq!(CTX_ADDR, 0x30000);
        assert_eq!(RESULT_ADDR, 0x40000);
    }
}
