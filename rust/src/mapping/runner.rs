//! Execute a compiled mapping on the M1 simulator: stage inputs and
//! context words in main memory, run the TinyRISC program, read back the
//! result.

use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::morphosys::{AluOp, BroadcastSchedule, ExecutionReport, M1System, Megakernel, Program};

use super::layout::{RESULT_ADDR, U_ADDR, V_ADDR, W_ADDR};
use super::routines::MappedRoutine;
use super::streamed::{StreamedPointTransformMapping, StreamedTiledMapping, TILE};

/// Result of running a mapped routine.
#[derive(Debug, Clone)]
pub struct RoutineOutput {
    pub result: Vec<i16>,
    pub report: ExecutionReport,
}

std::thread_local! {
    // Reused per-thread system: constructing an M1System zeroes a 2 MiB
    // main memory, which dominated run_routine's cost (§Perf). Routines
    // stage all the memory they read, so chip-reset + reuse is sound.
    static SHARED_SYS: std::cell::RefCell<M1System> =
        std::cell::RefCell::new(M1System::new());

    // Async-DMA counterpart of SHARED_SYS (§Perf PR 5): the overlapped
    // execution mode of the paper's streamed mappings, reusable across
    // run_routine_async calls. One schedule cache serves both modes —
    // schedules carry precomputed accounting for each.
    static SHARED_ASYNC_SYS: std::cell::RefCell<M1System> =
        std::cell::RefCell::new(M1System::new().with_async_dma());

    // Per-thread fast path over [`GLOBAL_SCHEDULES`]: a hit costs one
    // HashMap probe and no locking, so the tile pool's shards stay
    // lock-free on the hot path. Keys are `Arc<Program>`s shared with the
    // global map, so the two tiers hold one allocation per program.
    static SCHEDULES: RefCell<HashMap<Arc<Program>, Option<Arc<BroadcastSchedule>>>> =
        RefCell::new(HashMap::new());
}

/// Cross-shard schedule cache (§Perf, fused tile-kernel tier): one
/// process-wide map consulted on thread-local miss, so an N-shard
/// [`crate::coordinator::pool::TilePool`] compiles each distinct program
/// **once** instead of once per shard. Keyed by the program itself (exact
/// structural equality, behind an `Arc`), so a hit can never serve a
/// stale schedule; `None` marks programs that don't compile (branches)
/// and always take the interpreter. Determinism is unaffected: a
/// schedule is a pure function of its program, so which shard compiles
/// it first cannot change any result.
static GLOBAL_SCHEDULES: OnceLock<
    Mutex<HashMap<Arc<Program>, Option<Arc<BroadcastSchedule>>>>,
> = OnceLock::new();

/// Bound on distinct cached programs per tier; the working set of any
/// real workload (a handful of mapping shapes) is far below this.
const SCHEDULE_CACHE_MAX: usize = 512;

/// Look up (or compile and cache) the pre-decoded schedule of a program:
/// thread-local probe first, then the shared cross-shard map.
pub fn schedule_for(program: &Program) -> Option<Arc<BroadcastSchedule>> {
    SCHEDULES.with(|cache| {
        let mut cache = cache.borrow_mut();
        // Probe before inserting: the hot path is a hit, and `entry`
        // would clone the whole program as a key on every call.
        if let Some(hit) = cache.get(program) {
            return hit.clone();
        }
        if cache.len() > SCHEDULE_CACHE_MAX {
            cache.clear(); // crude bound, same policy as the routine cache
        }
        let (key, compiled) = shared_schedule_for(program);
        cache.insert(key, compiled.clone());
        compiled
    })
}

/// Consult (or fill) the cross-shard cache, returning the shared key so
/// the thread-local tier can insert without cloning the program again.
/// Compilation happens under the lock — it is a fast linear scan, and
/// holding the lock guarantees each program compiles exactly once per
/// process.
fn shared_schedule_for(program: &Program) -> (Arc<Program>, Option<Arc<BroadcastSchedule>>) {
    let global = GLOBAL_SCHEDULES.get_or_init(|| Mutex::new(HashMap::new()));
    let mut map = global.lock().unwrap();
    if let Some((key, hit)) = map.get_key_value(program) {
        return (key.clone(), hit.clone());
    }
    if map.len() > SCHEDULE_CACHE_MAX {
        map.clear();
    }
    let key = Arc::new(program.clone());
    let compiled = BroadcastSchedule::compile(program).map(Arc::new);
    map.insert(key.clone(), compiled.clone());
    (key, compiled)
}

/// Transform shape of a whole-request tile plan — the megakernel cache
/// key (§Perf, megakernel tier). Two requests with the same spec differ
/// only in data and share one compiled megakernel; `n` is part of the
/// shape because the emitted program unrolls over the tile count.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MegaSpec {
    /// Element-wise vector-vector plan (`StreamedTiledMapping`).
    VecVec { n: usize, op: AluOp },
    /// 2-D point-transform plan (`StreamedPointTransformMapping`).
    PointTransform { n: usize, m: [i16; 4], t: [i16; 2], shift: u8 },
}

impl MegaSpec {
    /// Can this shape compile to a plan-level program at all? (Multiples
    /// of one full tile only; immediate-class vecvec ops and out-of-range
    /// translations would fail the mapping's own asserts.)
    fn compilable(&self) -> bool {
        match *self {
            MegaSpec::VecVec { n, op } => n >= TILE && n % TILE == 0 && !op.uses_immediate(),
            MegaSpec::PointTransform { n, t, .. } => {
                n >= TILE
                    && n % TILE == 0
                    && (-128..=127).contains(&t[0])
                    && (-128..=127).contains(&t[1])
            }
        }
    }

    /// Compile the plan-level routine for this shape.
    fn compile_routine(&self) -> MappedRoutine {
        match *self {
            MegaSpec::VecVec { n, op } => StreamedTiledMapping { n, op }.compile(),
            MegaSpec::PointTransform { n, m, t, shift } => {
                StreamedPointTransformMapping { n, m, t, shift }.compile()
            }
        }
    }
}

/// A whole request compiled once: the plan-level routine (program +
/// staging spec) and its lowered [`Megakernel`].
#[derive(Debug)]
pub struct CompiledMegakernel {
    pub routine: MappedRoutine,
    pub kernel: Megakernel,
}

std::thread_local! {
    // Per-thread fast path over [`GLOBAL_MEGAKERNELS`], mirroring
    // [`SCHEDULES`]: a hit costs one probe and no locking. Holding the
    // Arc keeps a shard's hot shapes alive even if the global FIFO
    // evicts them under churn.
    static MEGAKERNELS: RefCell<HashMap<MegaSpec, Arc<CompiledMegakernel>>> =
        RefCell::new(HashMap::new());
}

/// Bound on distinct cached megakernel shapes. Deliberately tighter than
/// [`SCHEDULE_CACHE_MAX`]: each entry owns a whole unrolled plan (program
/// + schedule + megakernel steps scale with `n / 64`), and any real
/// workload cycles through a handful of `(transform-shape, n)` pairs.
const MEGAKERNEL_CACHE_MAX: usize = 64;

/// Evictions from the global megakernel cache since process start —
/// surfaced as a coordinator metrics gauge so an unbounded-churn workload
/// (every request a new shape) is visible instead of silent.
static MEGA_EVICTIONS: AtomicU64 = AtomicU64::new(0);

/// FIFO-bounded cross-shard megakernel cache: compile each shape once
/// process-wide, evict the oldest shape (with a counted eviction) when
/// the bound is hit — unlike the schedule caches' clear-on-overflow,
/// steady-state working sets survive a one-off burst of odd shapes.
struct MegaCache {
    map: HashMap<MegaSpec, Arc<CompiledMegakernel>>,
    order: VecDeque<MegaSpec>,
}

static GLOBAL_MEGAKERNELS: OnceLock<Mutex<MegaCache>> = OnceLock::new();

/// Total megakernel-cache evictions so far (the `Metrics` gauge source).
pub fn megakernel_cache_evictions() -> u64 {
    MEGA_EVICTIONS.load(Ordering::Relaxed)
}

/// Look up (or compile and cache) the megakernel for a whole-request tile
/// plan: thread-local probe first, then the cross-shard FIFO cache.
/// Returns `None` for shapes that have no plan-level program (ragged
/// sizes, immediate-class vecvec ops) — callers fall back to the
/// per-tile path.
pub fn megakernel_for(spec: &MegaSpec) -> Option<Arc<CompiledMegakernel>> {
    if !spec.compilable() {
        return None;
    }
    MEGAKERNELS.with(|cache| {
        let mut cache = cache.borrow_mut();
        if let Some(hit) = cache.get(spec) {
            return Some(hit.clone());
        }
        if cache.len() > MEGAKERNEL_CACHE_MAX {
            cache.clear(); // thread-local tier: crude bound, like SCHEDULES
        }
        let compiled = shared_megakernel_for(spec)?;
        cache.insert(*spec, compiled.clone());
        Some(compiled)
    })
}

/// Consult (or fill) the cross-shard megakernel cache. Compilation
/// happens under the lock, so each shape compiles exactly once per
/// process no matter how many shards race for it.
fn shared_megakernel_for(spec: &MegaSpec) -> Option<Arc<CompiledMegakernel>> {
    let global = GLOBAL_MEGAKERNELS
        .get_or_init(|| Mutex::new(MegaCache { map: HashMap::new(), order: VecDeque::new() }));
    let mut cache = global.lock().unwrap();
    if let Some(hit) = cache.map.get(spec) {
        return Some(hit.clone());
    }
    let routine = spec.compile_routine();
    // Plan-level programs are straight-line by construction, so this
    // only fails if the emitter ever grew control flow — in which case
    // the caller's per-tile fallback keeps everything correct.
    let kernel = Megakernel::compile(&routine.program)?;
    while cache.map.len() >= MEGAKERNEL_CACHE_MAX {
        let oldest = cache.order.pop_front().expect("cache order tracks the map");
        cache.map.remove(&oldest);
        MEGA_EVICTIONS.fetch_add(1, Ordering::Relaxed);
    }
    let compiled = Arc::new(CompiledMegakernel { routine, kernel });
    cache.map.insert(*spec, compiled.clone());
    cache.order.push_back(*spec);
    Some(compiled)
}

/// Run a whole request through its compiled megakernel (§Perf,
/// megakernel tier): stage inputs once, execute the single plan-level
/// program, read the whole result back. Bit-identical to running the
/// same plan through the interpreter or the scheduled/fused tiers —
/// pinned by the conformance suite in both DMA modes.
pub fn run_plan(
    sys: &mut M1System,
    plan: &CompiledMegakernel,
    u: &[i16],
    v: Option<&[i16]>,
) -> RoutineOutput {
    stage_routine3_on(sys, &plan.routine, u, v, None);
    let report = sys.run_megakernel(&plan.routine.program, &plan.kernel);
    let result = sys.mem.load_elements(RESULT_ADDR, plan.routine.result_elems);
    RoutineOutput { result, report }
}

/// Stage `u` (and optionally `v`) per the routine's input spec, stage the
/// context words, run, and read the result back from main memory.
pub fn run_routine(routine: &MappedRoutine, u: &[i16], v: Option<&[i16]>) -> RoutineOutput {
    SHARED_SYS.with(|sys| {
        let mut sys = sys.borrow_mut();
        sys.reset_chip();
        run_routine_on(&mut sys, routine, u, v)
    })
}

/// As [`run_routine`] but on the per-thread **async-DMA** system — the
/// overlapped-execution mode the paper's streamed mappings are designed
/// for. Rides the same cross-shard schedule cache as the blocking path:
/// a [`BroadcastSchedule`] carries precomputed accounting for **both**
/// DMA modes (§Perf PR 5), so async execution takes the scheduled/fused
/// tier too, reporting the async cycle count.
pub fn run_routine_async(routine: &MappedRoutine, u: &[i16], v: Option<&[i16]>) -> RoutineOutput {
    SHARED_ASYNC_SYS.with(|sys| {
        let mut sys = sys.borrow_mut();
        sys.reset_chip();
        run_routine_on(&mut sys, routine, u, v)
    })
}

/// As [`run_routine`], but on a caller-provided system (so traces or
/// pre-staged memory can be observed).
pub fn run_routine_on(
    sys: &mut M1System,
    routine: &MappedRoutine,
    u: &[i16],
    v: Option<&[i16]>,
) -> RoutineOutput {
    run_routine3_on(sys, routine, u, v, None)
}

/// Three-stream variant for the 3-D mappings (`w` = z coordinates at
/// [`W_ADDR`]), taking the schedule from the shared cache.
pub fn run_routine3_on(
    sys: &mut M1System,
    routine: &MappedRoutine,
    u: &[i16],
    v: Option<&[i16]>,
    w: Option<&[i16]>,
) -> RoutineOutput {
    let schedule = schedule_for(&routine.program);
    run_routine3_with(sys, routine, u, v, w, schedule.as_deref())
}

/// As [`run_routine3_on`] but with an **explicit** (possibly differently
/// compiled) schedule, bypassing the caches — the simulator bench uses
/// this to pin the unfused scheduled baseline against the fused tier on
/// identical workloads.
pub fn run_routine3_with(
    sys: &mut M1System,
    routine: &MappedRoutine,
    u: &[i16],
    v: Option<&[i16]>,
    w: Option<&[i16]>,
    schedule: Option<&BroadcastSchedule>,
) -> RoutineOutput {
    stage_routine3_on(sys, routine, u, v, w);
    let report = sys.run_program(&routine.program, schedule);
    let result = sys.mem.load_elements(RESULT_ADDR, routine.result_elems);
    RoutineOutput { result, report }
}

/// Stage a routine's inputs and context words into `sys`'s main memory
/// **without running it** — the pre-execution state a repro artifact
/// ([`crate::replay`]) snapshots so a crashed tile can be re-executed
/// step by step offline.
pub fn stage_routine3_on(
    sys: &mut M1System,
    routine: &MappedRoutine,
    u: &[i16],
    v: Option<&[i16]>,
    w: Option<&[i16]>,
) {
    assert_eq!(u.len(), routine.u_elems, "{}: U length", routine.name);
    sys.mem.store_elements(U_ADDR, u);
    match (routine.v_elems, v) {
        (Some(n), Some(v)) => {
            assert_eq!(v.len(), n, "{}: V length", routine.name);
            sys.mem.store_elements(V_ADDR, v);
        }
        (None, None) => {}
        (Some(_), None) => panic!("{}: routine requires V input", routine.name),
        (None, Some(_)) => panic!("{}: routine takes no V input", routine.name),
    }
    match (routine.w_elems, w) {
        (Some(n), Some(w)) => {
            assert_eq!(w.len(), n, "{}: W length", routine.name);
            sys.mem.store_elements(W_ADDR, w);
        }
        (None, None) => {}
        (Some(_), None) => panic!("{}: routine requires W input", routine.name),
        (None, Some(_)) => panic!("{}: routine takes no W input", routine.name),
    }
    for &(addr, word) in &routine.ctx_words {
        sys.mem.write_word(addr, word);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::routines::{
        MatMulMapping, PointTransformMapping, VecScalarMapping, VecVecMapping,
    };
    use crate::morphosys::AluOp;
    use crate::testkit::{check, Rng};

    #[test]
    fn translation_64_computes_elementwise_sum() {
        let u: Vec<i16> = (0..64).collect();
        let v: Vec<i16> = (0..64).map(|i| 1000 + 3 * i).collect();
        let routine = VecVecMapping { n: 64, op: AluOp::Add }.compile();
        let out = run_routine(&routine, &u, Some(&v));
        let expected: Vec<i16> = u.iter().zip(&v).map(|(a, b)| a + b).collect();
        assert_eq!(out.result, expected);
        // Measured cycles equal the static prediction (and the paper).
        assert_eq!(out.report.cycles, routine.predicted_cycles);
        assert_eq!(out.report.cycles, 96);
    }

    #[test]
    fn translation_8_computes_and_matches_cycles() {
        let u: Vec<i16> = (1..=8).collect();
        let v: Vec<i16> = (11..=18).collect();
        let routine = VecVecMapping { n: 8, op: AluOp::Add }.compile();
        let out = run_routine(&routine, &u, Some(&v));
        assert_eq!(out.result, vec![12, 14, 16, 18, 20, 22, 24, 26]);
        assert_eq!(out.report.cycles, 21);
    }

    #[test]
    fn scaling_64_computes_and_matches_cycles() {
        let u: Vec<i16> = (0..64).collect();
        let routine = VecScalarMapping { n: 64, op: AluOp::Cmul, scalar: 5 }.compile();
        let out = run_routine(&routine, &u, None);
        let expected: Vec<i16> = u.iter().map(|a| 5 * a).collect();
        assert_eq!(out.result, expected);
        assert_eq!(out.report.cycles, 55);
    }

    #[test]
    fn scaling_8_computes_and_matches_cycles() {
        let u: Vec<i16> = (1..=8).collect();
        let routine = VecScalarMapping { n: 8, op: AluOp::Cmul, scalar: 5 }.compile();
        let out = run_routine(&routine, &u, None);
        assert_eq!(out.result, vec![5, 10, 15, 20, 25, 30, 35, 40]);
        assert_eq!(out.report.cycles, 14);
    }

    #[test]
    fn subtraction_and_logic_mappings_work() {
        let u: Vec<i16> = (0..8).map(|i| 10 * i).collect();
        let v: Vec<i16> = (0..8).collect();
        for (op, f) in [
            (AluOp::Sub, (|a: i16, b: i16| a.wrapping_sub(b)) as fn(i16, i16) -> i16),
            (AluOp::Mul, |a, b| a.wrapping_mul(b)),
            (AluOp::And, |a, b| a & b),
            (AluOp::Or, |a, b| a | b),
            (AluOp::Xor, |a, b| a ^ b),
        ] {
            let routine = VecVecMapping { n: 8, op }.compile();
            let out = run_routine(&routine, &u, Some(&v));
            let expected: Vec<i16> = u.iter().zip(&v).map(|(&a, &b)| f(a, b)).collect();
            assert_eq!(out.result, expected, "{op:?}");
        }
    }

    #[test]
    fn matmul_8x8_matches_reference() {
        let mut rng = Rng::new(99);
        let a: Vec<i16> = (0..64).map(|_| rng.range_i64(-9, 9) as i16).collect();
        let b: Vec<i16> = (0..64).map(|_| rng.range_i64(-9, 9) as i16).collect();
        let mapping = MatMulMapping { dim: 8, a: a.clone(), shift: 0 };
        let routine = mapping.compile();
        let out = run_routine(&routine, &b, None);
        let c = mapping.extract(&out.result);
        for i in 0..8 {
            for j in 0..8 {
                let expected: i32 = (0..8).map(|k| a[i * 8 + k] as i32 * b[k * 8 + j] as i32).sum();
                assert_eq!(c[i * 8 + j], expected as i16, "C[{i}][{j}]");
            }
        }
    }

    #[test]
    fn matmul_4x4_matches_reference() {
        let a: Vec<i16> = (1..=16).collect();
        let b: Vec<i16> = (0..16).map(|i| (i % 5) as i16 - 2).collect();
        let mapping = MatMulMapping { dim: 4, a: a.clone(), shift: 0 };
        let routine = mapping.compile();
        let out = run_routine(&routine, &b, None);
        let c = mapping.extract(&out.result);
        for i in 0..4 {
            for j in 0..4 {
                let expected: i32 = (0..4).map(|k| a[i * 4 + k] as i32 * b[k * 4 + j] as i32).sum();
                assert_eq!(c[i * 4 + j], expected as i16, "C[{i}][{j}]");
            }
        }
    }

    #[test]
    fn matmul_fixed_point_shift_scales_result() {
        // A = 2^4 · I, shift 4 → C = B.
        let mut a = vec![0i16; 16];
        for i in 0..4 {
            a[i * 4 + i] = 16;
        }
        let b: Vec<i16> = (1..=16).collect();
        let mapping = MatMulMapping { dim: 4, a, shift: 4 };
        let out = run_routine(&mapping.compile(), &b, None);
        assert_eq!(mapping.extract(&out.result), b);
    }

    #[test]
    fn point_transform_identity_plus_translation() {
        let xs: Vec<i16> = (0..8).collect();
        let ys: Vec<i16> = (10..18).collect();
        let mapping = PointTransformMapping { n: 8, m: [1, 0, 0, 1], t: [5, -3], shift: 0 };
        let out = run_routine(&mapping.compile(), &xs, Some(&ys));
        let (xp, yp) = out.result.split_at(8);
        for i in 0..8 {
            assert_eq!(xp[i], xs[i] + 5);
            assert_eq!(yp[i], ys[i] - 3);
        }
    }

    #[test]
    fn point_transform_fixed_point_rotation_90deg() {
        // 90° rotation in Q6: m = [[0,-64],[64,0]], shift 6:
        // x' = -y, y' = x.
        let xs: Vec<i16> = (1..=8).collect();
        let ys: Vec<i16> = (21..=28).collect();
        let mapping = PointTransformMapping { n: 8, m: [0, -64, 64, 0], t: [0, 0], shift: 6 };
        let out = run_routine(&mapping.compile(), &xs, Some(&ys));
        let (xp, yp) = out.result.split_at(8);
        for i in 0..8 {
            assert_eq!(xp[i], -ys[i], "x'[{i}]");
            assert_eq!(yp[i], xs[i], "y'[{i}]");
        }
    }

    #[test]
    fn property_vecvec_agrees_with_native_for_random_vectors() {
        check("vecvec == native", 40, |rng: &mut Rng| {
            let n = [8, 16, 24, 32, 40, 48, 56, 64][rng.below(8) as usize];
            let u = rng.small_vec(n);
            let v = rng.small_vec(n);
            let routine = VecVecMapping { n, op: AluOp::Add }.compile();
            let out = run_routine(&routine, &u, Some(&v));
            let expected: Vec<i16> = u.iter().zip(&v).map(|(a, b)| a.wrapping_add(*b)).collect();
            assert_eq!(out.result, expected);
            assert_eq!(out.report.cycles, routine.predicted_cycles);
        });
    }

    #[test]
    fn property_vecscalar_agrees_with_native() {
        check("vecscalar == native", 40, |rng: &mut Rng| {
            let n = [8, 16, 32, 64][rng.below(4) as usize];
            let u = rng.small_vec(n);
            let s = rng.range_i64(-128, 127) as i16;
            let routine = VecScalarMapping { n, op: AluOp::Cmul, scalar: s }.compile();
            let out = run_routine(&routine, &u, None);
            let expected: Vec<i16> =
                u.iter().map(|a| (s as i32).wrapping_mul(*a as i32) as i16).collect();
            assert_eq!(out.result, expected);
            assert_eq!(out.report.cycles, routine.predicted_cycles);
        });
    }

    #[test]
    fn property_matmul_agrees_with_native() {
        check("matmul == native", 25, |rng: &mut Rng| {
            let dim = rng.range_i64(1, 8) as usize;
            let a: Vec<i16> = (0..dim * dim).map(|_| rng.range_i64(-10, 10) as i16).collect();
            let b: Vec<i16> = (0..dim * dim).map(|_| rng.range_i64(-10, 10) as i16).collect();
            let mapping = MatMulMapping { dim, a: a.clone(), shift: 0 };
            let out = run_routine(&mapping.compile(), &b, None);
            let c = mapping.extract(&out.result);
            for i in 0..dim {
                for j in 0..dim {
                    let e: i32 =
                        (0..dim).map(|k| a[i * dim + k] as i32 * b[k * dim + j] as i32).sum();
                    assert_eq!(c[i * dim + j], e as i16);
                }
            }
        });
    }

    #[test]
    fn scheduled_path_is_bit_identical_to_the_interpreter() {
        // `with_trace` forces the interpreter (schedules skip trace
        // plumbing) with unchanged blocking-DMA accounting, so this pins
        // the pre-decoded path against the reference executor across
        // mapping shapes.
        let mut rng = Rng::new(7);
        let u64v = rng.small_vec(64);
        let v64 = rng.small_vec(64);
        let cases: Vec<(MappedRoutine, Vec<i16>, Option<Vec<i16>>)> = vec![
            (VecVecMapping { n: 64, op: AluOp::Add }.compile(), u64v.clone(), Some(v64.clone())),
            (VecVecMapping { n: 8, op: AluOp::Mul }.compile(), u64v[..8].to_vec(), Some(v64[..8].to_vec())),
            (
                VecScalarMapping { n: 64, op: AluOp::Cmul, scalar: 3 }.compile(),
                u64v.clone(),
                None,
            ),
            (
                MatMulMapping { dim: 8, a: rng.small_vec(64), shift: 0 }.compile(),
                u64v.clone(),
                None,
            ),
            (
                PointTransformMapping { n: 64, m: [0, -64, 64, 0], t: [3, -2], shift: 6 }.compile(),
                u64v.clone(),
                Some(v64.clone()),
            ),
        ];
        for (routine, u, v) in &cases {
            let fast = run_routine(routine, u, v.as_deref());
            let mut interp_sys = crate::morphosys::M1System::new().with_trace();
            let interp = run_routine_on(&mut interp_sys, routine, u, v.as_deref());
            assert_eq!(fast.result, interp.result, "{}", routine.name);
            assert_eq!(fast.report.cycles, interp.report.cycles, "{}", routine.name);
            assert_eq!(fast.report.slots, interp.report.slots, "{}", routine.name);
            assert_eq!(fast.report.executed, interp.report.executed, "{}", routine.name);
            assert_eq!(fast.report.broadcasts, interp.report.broadcasts, "{}", routine.name);
        }
    }

    #[test]
    fn run_routine_async_overlaps_dma_and_matches_blocking_results() {
        // The async thread-local runner: identical results to the
        // blocking runner, fewer cycles on the streamed multi-tile shape
        // (DMA hidden behind compute), and the async report equal to the
        // interpreter's for the same mode.
        use crate::mapping::StreamedTiledMapping;
        let n = 256;
        let routine = StreamedTiledMapping { n, op: AluOp::Add }.compile();
        let u: Vec<i16> = (0..n as i16).collect();
        let v: Vec<i16> = (0..n as i16).map(|i| 5 - i).collect();
        let blocking = run_routine(&routine, &u, Some(&v));
        let overlapped = run_routine_async(&routine, &u, Some(&v));
        assert_eq!(blocking.result, overlapped.result);
        assert!(
            overlapped.report.cycles < blocking.report.cycles,
            "async {} !< blocking {}",
            overlapped.report.cycles,
            blocking.report.cycles
        );
        let mut interp_sys = crate::morphosys::M1System::new().with_async_dma().with_trace();
        let interp = run_routine_on(&mut interp_sys, &routine, &u, Some(&v));
        assert_eq!(overlapped.report.cycles, interp.report.cycles);
        assert_eq!(overlapped.report.slots, interp.report.slots);
    }

    #[test]
    #[should_panic(expected = "requires V input")]
    fn missing_v_input_panics() {
        let routine = VecVecMapping { n: 8, op: AluOp::Add }.compile();
        run_routine(&routine, &[0; 8], None);
    }

    #[test]
    fn schedule_cache_is_shared_across_threads() {
        // The cross-shard promise: every thread (= pool shard) gets the
        // one process-wide compile of a program, not a private copy. The
        // program is unique to this test (the 0x7E57 marker immediate),
        // and the lib test binary's distinct-program population stays far
        // below SCHEDULE_CACHE_MAX, so the global map is never cleared
        // under this assertion.
        use crate::morphosys::{Instruction, Reg};
        let program = Program::new(vec![
            Instruction::Ldli { rd: Reg(7), imm: 0x7E57 },
            Instruction::Ldui { rd: Reg(7), imm: 0x7E57 },
        ]);
        let here = schedule_for(&program).expect("straight-line program");
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let program = program.clone();
                std::thread::spawn(move || schedule_for(&program).expect("straight-line program"))
            })
            .collect();
        for h in handles {
            let theirs = h.join().unwrap();
            assert!(
                Arc::ptr_eq(&here, &theirs),
                "threads must share the single cross-shard compile"
            );
        }
    }

    #[test]
    fn run_plan_matches_the_scheduled_tier_bit_for_bit() {
        // The megakernel entry point vs the cached scheduled/fused path,
        // on the same plan-level routine: identical results and identical
        // precomputed reports, in both DMA modes, for both plan shapes.
        let n = 256;
        let u: Vec<i16> = (0..n as i16).map(|i| 7 * i - 300).collect();
        let v: Vec<i16> = (0..n as i16).map(|i| 11 - 3 * i).collect();
        for spec in [
            MegaSpec::VecVec { n, op: AluOp::Add },
            MegaSpec::PointTransform { n, m: [3, -2, 1, 4], t: [17, -9], shift: 2 },
        ] {
            let plan = megakernel_for(&spec).expect("plan shapes compile");
            for async_dma in [false, true] {
                let mut mega_sys = M1System::with_dma_mode(async_dma);
                let mega = run_plan(&mut mega_sys, &plan, &u, Some(&v));
                let mut sched_sys = M1System::with_dma_mode(async_dma);
                let sched = run_routine_on(&mut sched_sys, &plan.routine, &u, Some(&v));
                assert_eq!(mega.result, sched.result, "{spec:?} async={async_dma}");
                assert_eq!(mega.report.cycles, sched.report.cycles, "{spec:?}");
                assert_eq!(mega.report.slots, sched.report.slots, "{spec:?}");
                assert_eq!(mega.report.executed, sched.report.executed, "{spec:?}");
                assert_eq!(mega.report.broadcasts, sched.report.broadcasts, "{spec:?}");
            }
        }
    }

    #[test]
    fn megakernel_cache_shares_one_compile_per_shape() {
        // Batched sibling requests of one shape dispatch through one
        // compiled plan (Arc-shared), and uncompilable shapes answer None.
        let spec = MegaSpec::VecVec { n: 832, op: AluOp::Xor };
        let first = megakernel_for(&spec).expect("compilable shape");
        let again = megakernel_for(&spec).expect("compilable shape");
        assert!(Arc::ptr_eq(&first, &again), "same-shape requests must share the compile");
        assert_eq!(first.kernel.fused_tiles(), 832 / 64);
        assert!(megakernel_for(&MegaSpec::VecVec { n: 100, op: AluOp::Add }).is_none());
        assert!(megakernel_for(&MegaSpec::VecVec { n: 64, op: AluOp::Cmul }).is_none());
        assert!(megakernel_for(&MegaSpec::PointTransform {
            n: 64,
            m: [1, 0, 0, 1],
            t: [1000, 0],
            shift: 0
        })
        .is_none());
    }

    #[test]
    fn megakernel_cache_is_bounded_and_counts_evictions() {
        // Flood the cache with more distinct shapes than the bound: the
        // global FIFO must evict (counted) instead of growing without
        // bound. Shapes here are unique to this test (op Sub over odd
        // multiples) so parallel tests only ever add to the counter.
        let before = megakernel_cache_evictions();
        for k in 1..=70usize {
            let spec = MegaSpec::VecVec { n: 64 * k, op: AluOp::Sub };
            assert!(megakernel_for(&spec).is_some(), "n={}", 64 * k);
        }
        let evicted = megakernel_cache_evictions() - before;
        assert!(evicted >= 6, "70 shapes through a 64-entry cache evicted only {evicted}");
    }

    #[test]
    fn explicit_unfused_schedule_matches_the_fused_cache_path() {
        // `run_routine` rides the shared cache (fused schedules);
        // `run_routine3_with` pins the same workload to an explicitly
        // unfused schedule. Results and reports must be bit-identical.
        use crate::morphosys::BroadcastSchedule;
        let u: Vec<i16> = (0..64).map(|i| 3 * i - 70).collect();
        let v: Vec<i16> = (0..64).map(|i| -5 * i + 9).collect();
        for routine in [
            VecVecMapping { n: 64, op: AluOp::Add }.compile(),
            PointTransformMapping { n: 64, m: [0, -64, 64, 0], t: [3, -2], shift: 6 }.compile(),
        ] {
            let fused = run_routine(&routine, &u, Some(&v));
            let unfused = BroadcastSchedule::compile_unfused(&routine.program).unwrap();
            assert_eq!(unfused.fused_runs(), 0);
            let out = run_routine3_with(
                &mut crate::morphosys::M1System::new(),
                &routine,
                &u,
                Some(&v),
                None,
                Some(&unfused),
            );
            assert_eq!(fused.result, out.result, "{}", routine.name);
            assert_eq!(fused.report.cycles, out.report.cycles, "{}", routine.name);
            assert_eq!(fused.report.slots, out.report.slots, "{}", routine.name);
            assert_eq!(fused.report.executed, out.report.executed, "{}", routine.name);
            assert_eq!(fused.report.broadcasts, out.report.broadcasts, "{}", routine.name);
        }
    }
}
