//! TinyRISC code generators for the paper's mappings.
//!
//! Each mapping compiles to a [`MappedRoutine`]: the TinyRISC program, the
//! context words to stage in main memory, the result location, and a
//! static cycle prediction (the paper's convention — final-instruction
//! issue index). The emitted structure mirrors the paper's listings
//! (Tables 1–2) including the `ldli r4` bank-address formation step before
//! every `dbcdc`, which is architecturally required by the real TinyRISC
//! and is part of the published cycle budget.

use crate::morphosys::context_memory::Block;
use crate::morphosys::frame_buffer::{Bank, Set};
use crate::morphosys::rc_array::{AluOp, ContextWord, MuxASel, ARRAY_DIM};
use crate::morphosys::tinyrisc::{Instruction, Program, Reg};

use super::layout::{Layout, CTX_ADDR, RESULT_ADDR, U_ADDR, V_ADDR, W_ADDR};

/// A compiled mapping: everything needed to run it on the simulator.
#[derive(Debug, Clone)]
pub struct MappedRoutine {
    pub name: String,
    pub program: Program,
    /// Context words to stage in main memory: `(word address, raw word)`.
    pub ctx_words: Vec<(usize, u32)>,
    /// Elements expected at [`U_ADDR`].
    pub u_elems: usize,
    /// Elements expected at [`V_ADDR`] (vector-vector mappings only).
    pub v_elems: Option<usize>,
    /// Elements expected at [`W_ADDR`] (third stream; 3-D mappings only).
    pub w_elems: Option<usize>,
    /// Result location and length (elements at [`RESULT_ADDR`]).
    pub result_elems: usize,
    /// Predicted cycles (paper convention). Asserted equal to the
    /// simulator-measured count by the `plan` tests.
    pub predicted_cycles: u64,
}

fn words_for(elems: usize) -> usize {
    crate::morphosys::dma::words_for_elements(elems)
}

/// Split a word address into the paper's `ldui`/`ldli` halves, emitting
/// `ldli` only when the low half is non-zero (the paper's base addresses
/// are `ldui`-only).
fn load_address(prog: &mut Vec<Instruction>, reg: Reg, addr: usize) {
    prog.push(Instruction::Ldui { rd: reg, imm: (addr >> 16) as u16 });
    if addr & 0xFFFF != 0 {
        prog.push(Instruction::Ldli { rd: reg, imm: (addr & 0xFFFF) as u16 });
    }
}

/// §5.1 — element-wise vector-vector operation (translation when
/// `op = Add`): `result[i] = U[i] op V[i]`.
#[derive(Debug, Clone, Copy)]
pub struct VecVecMapping {
    /// Vector length; multiple of 8, at most 64 (one array tile). Larger
    /// workloads are tiled by the coordinator.
    pub n: usize,
    /// Any two-port ALU op (Add for translation, Sub, Mul, And, …).
    pub op: AluOp,
}

impl VecVecMapping {
    pub fn compile(&self) -> MappedRoutine {
        assert!(!self.op.uses_immediate(), "vector-vector op must be two-port");
        let cols = Layout::columns_for(self.n);
        let words = words_for(self.n);
        let mut prog = Vec::new();

        // Load U into set 0 bank A, V into set 0 bank B (Table 1, 0–65).
        load_address(&mut prog, Reg(1), U_ADDR);
        prog.push(Instruction::Ldfb { rs: Reg(1), set: Set::Zero, bank: Bank::A, words, fb_addr: 0 });
        load_address(&mut prog, Reg(2), V_ADDR);
        prog.push(Instruction::Ldfb { rs: Reg(2), set: Set::Zero, bank: Bank::B, words, fb_addr: 0 });

        // Load the single context word (Table 1, 66–70).
        load_address(&mut prog, Reg(3), CTX_ADDR);
        prog.push(Instruction::Ldctxt { rs: Reg(3), block: Block::Column, plane: 0, word: 0, count: 1 });

        // One double-bank column broadcast per column, each preceded by
        // the bank-address formation `ldli r4` (Table 1, 71–86).
        for c in 0..cols {
            let chunk = Layout::column_chunk(c);
            prog.push(Instruction::Ldli { rd: Reg(4), imm: chunk as u16 });
            prog.push(Instruction::Dbcdc { plane: 0, cw: 0, col: c, set: Set::Zero, addr_a: chunk, addr_b: chunk });
        }

        // Write results back to set 1 bank A (Table 1, 87–94).
        for c in 0..cols {
            prog.push(Instruction::Wfbi { col: c, set: Set::One, bank: Bank::A, addr: Layout::column_chunk(c) });
        }

        // Store to main memory (Table 1, 95–96).
        load_address(&mut prog, Reg(5), RESULT_ADDR);
        prog.push(Instruction::Stfb { rs: Reg(5), set: Set::One, bank: Bank::A, words, fb_addr: 0 });

        let program = Program::new(prog);
        let predicted_cycles = program.paper_cycles();
        MappedRoutine {
            name: format!("vecvec-{:?}-{}", self.op, self.n),
            program,
            ctx_words: vec![(CTX_ADDR, ContextWord::two_port(self.op).encode())],
            u_elems: self.n,
            v_elems: Some(self.n),
            w_elems: None,
            result_elems: self.n,
            predicted_cycles,
        }
    }
}

/// §5.2 — vector-scalar operation (scaling when `op = Cmul`):
/// `result[i] = U[i] op scalar`, the scalar riding in the context-word
/// immediate.
#[derive(Debug, Clone, Copy)]
pub struct VecScalarMapping {
    pub n: usize,
    /// Any immediate-class op (Cmul for scaling, Cadd, Csub, Shl, Shr).
    pub op: AluOp,
    /// The scalar; must fit the 8-bit context immediate.
    pub scalar: i16,
}

impl VecScalarMapping {
    pub fn compile(&self) -> MappedRoutine {
        assert!(self.op.uses_immediate(), "vector-scalar op must be immediate-class");
        let cols = Layout::columns_for(self.n);
        let words = words_for(self.n);
        let mut prog = Vec::new();

        // Load U into set 0 bank A (Table 2, 0–32).
        load_address(&mut prog, Reg(1), U_ADDR);
        prog.push(Instruction::Ldfb { rs: Reg(1), set: Set::Zero, bank: Bank::A, words, fb_addr: 0 });

        // Load the context word (Table 2, 33–37).
        load_address(&mut prog, Reg(3), CTX_ADDR);
        prog.push(Instruction::Ldctxt { rs: Reg(3), block: Block::Column, plane: 0, word: 0, count: 1 });

        // One single-bank column broadcast per column (Table 2, 38–45) —
        // no address-formation step: the scalar is in the context word.
        for c in 0..cols {
            prog.push(Instruction::Sbcb { plane: 0, cw: 0, col: c, set: Set::Zero, bank: Bank::A, addr: Layout::column_chunk(c) });
        }

        // Write back and store (Table 2, 46–55).
        for c in 0..cols {
            prog.push(Instruction::Wfbi { col: c, set: Set::One, bank: Bank::A, addr: Layout::column_chunk(c) });
        }
        load_address(&mut prog, Reg(5), RESULT_ADDR);
        prog.push(Instruction::Stfb { rs: Reg(5), set: Set::One, bank: Bank::A, words, fb_addr: 0 });

        let program = Program::new(prog);
        let predicted_cycles = program.paper_cycles();
        MappedRoutine {
            name: format!("vecscalar-{:?}-{}x{}", self.op, self.n, self.scalar),
            program,
            ctx_words: vec![(CTX_ADDR, ContextWord::immediate(self.op, self.scalar).encode())],
            u_elems: self.n,
            v_elems: None,
            w_elems: None,
            result_elems: self.n,
            predicted_cycles,
        }
    }
}

/// §5.3 — dense matrix multiplication `C = A × B` (rotation/composite
/// transformations). Matrix A (compile-time, 8-bit entries) enters through
/// per-row context words as constant-multiply-accumulate steps; matrix B
/// (runtime) is broadcast row by row from the frame buffer. Column `i` of
/// the RC array accumulates row `i` of C.
///
/// With `shift > 0` every accumulated element is arithmetically
/// right-shifted before write-back, supporting fixed-point rotation
/// matrices (entries pre-scaled by `2^shift`).
#[derive(Debug, Clone)]
pub struct MatMulMapping {
    /// Matrix dimension (≤ 8).
    pub dim: usize,
    /// Row-major A, `dim × dim`, entries in the i8 immediate range.
    pub a: Vec<i16>,
    /// Post-accumulate arithmetic right shift (fixed-point scaling).
    pub shift: u8,
}

impl MatMulMapping {
    pub fn compile(&self) -> MappedRoutine {
        assert!(self.dim >= 1 && self.dim <= ARRAY_DIM, "dim must be 1..=8");
        assert_eq!(self.a.len(), self.dim * self.dim, "A must be dim×dim");
        let n = self.dim * self.dim;
        let b_words = words_for(n);
        // Each row i of A becomes `dim` CMUL-accumulate words (+ optional
        // shift word), staged consecutively in main memory.
        let words_per_row = self.dim + usize::from(self.shift > 0);
        let mut ctx_words = Vec::new();
        for i in 0..self.dim {
            for k in 0..self.dim {
                let mut cw = ContextWord::cmula(self.a[i * self.dim + k], k == 0);
                if self.shift > 0 && k == self.dim - 1 {
                    cw.reg_write = 0b0001; // final value → r0 for the shift
                }
                ctx_words.push((CTX_ADDR + i * words_per_row + k, cw.encode()));
            }
            if self.shift > 0 {
                let mut cw = ContextWord::immediate(AluOp::Shr, self.shift as i16);
                cw.mux_a = MuxASel::Reg(0);
                ctx_words.push((CTX_ADDR + i * words_per_row + self.dim, cw.encode()));
            }
        }

        let mut prog = Vec::new();
        // B (row-major) → set 0 bank A; row k occupies addresses
        // dim·k .. dim·k+dim.
        load_address(&mut prog, Reg(1), U_ADDR);
        prog.push(Instruction::Ldfb { rs: Reg(1), set: Set::Zero, bank: Bank::A, words: b_words, fb_addr: 0 });

        for i in 0..self.dim {
            // Context words for row i of A.
            load_address(&mut prog, Reg(3), CTX_ADDR + i * words_per_row);
            prog.push(Instruction::Ldctxt {
                rs: Reg(3),
                block: Block::Column,
                plane: 0,
                word: 0,
                count: words_per_row,
            });
            // k-loop: column i accumulates Σ_k A[i][k] · B[k][*].
            for k in 0..self.dim {
                prog.push(Instruction::Sbcb {
                    plane: 0,
                    cw: k,
                    col: i,
                    set: Set::Zero,
                    bank: Bank::A,
                    addr: self.dim * k,
                });
            }
            if self.shift > 0 {
                // Fixed-point post-shift (operand bus unused).
                prog.push(Instruction::Sbcb {
                    plane: 0,
                    cw: self.dim,
                    col: i,
                    set: Set::Zero,
                    bank: Bank::A,
                    addr: 0,
                });
            }
            // Row i of C → set 1 bank A at 8·i (array-column granularity).
            prog.push(Instruction::Wfbi { col: i, set: Set::One, bank: Bank::A, addr: ARRAY_DIM * i });
        }

        // Store all written columns.
        load_address(&mut prog, Reg(5), RESULT_ADDR);
        prog.push(Instruction::Stfb {
            rs: Reg(5),
            set: Set::One,
            bank: Bank::A,
            words: words_for(self.dim * ARRAY_DIM),
            fb_addr: 0,
        });

        let program = Program::new(prog);
        let predicted_cycles = program.paper_cycles();
        MappedRoutine {
            name: format!("matmul-{0}x{0}", self.dim),
            program,
            ctx_words,
            u_elems: n,
            v_elems: None,
            w_elems: None,
            // Row i of C lives at result[8·i .. 8·i+dim].
            result_elems: self.dim * ARRAY_DIM,
            predicted_cycles,
        }
    }

    /// Extract the dense `dim × dim` C from the raw (stride-8) result.
    pub fn extract(&self, raw: &[i16]) -> Vec<i16> {
        let mut c = Vec::with_capacity(self.dim * self.dim);
        for i in 0..self.dim {
            c.extend_from_slice(&raw[ARRAY_DIM * i..ARRAY_DIM * i + self.dim]);
        }
        c
    }
}

/// Composite 2-D point transformation `q = ((M · p) >> shift) + t` applied
/// element-wise to `n` points — the paper's "general composite algorithm"
/// realized with the §5.2/§5.3 machinery. X coordinates stream through
/// bank A, Y coordinates through bank B; each coordinate needs four
/// broadcast steps (two CMUL-accumulates, a fixed-point shift, and a
/// constant-add of the translation component).
#[derive(Debug, Clone)]
pub struct PointTransformMapping {
    /// Number of points; multiple of 8, at most 64 per tile.
    pub n: usize,
    /// Row-major 2×2 matrix, fixed-point `Q(shift)`, i8 range.
    pub m: [i16; 4],
    /// Translation, applied after the shift (plain integer).
    pub t: [i16; 2],
    /// Fixed-point shift for the matrix product.
    pub shift: u8,
}

impl PointTransformMapping {
    /// Context-word schedule for one output coordinate `r` (0 = x', 1 = y').
    /// Crate-visible so the plan-level streamed mapping
    /// ([`super::streamed::StreamedPointTransformMapping`]) shares the
    /// exact word encodings — one source of truth for the transform math.
    pub(crate) fn coord_words(&self, r: usize) -> Vec<u32> {
        let mut words = Vec::new();
        // acc = m[r][0]·x  (+ m[r][1]·y), final step latches to r0.
        let w0 = ContextWord::cmula(self.m[2 * r], true);
        let mut w1 = ContextWord::cmula(self.m[2 * r + 1], false);
        if self.shift > 0 {
            w1.reg_write = 0b0001;
            let mut ws = ContextWord::immediate(AluOp::Shr, self.shift as i16);
            ws.mux_a = MuxASel::Reg(0);
            ws.reg_write = 0b0001;
            let mut wt = ContextWord::immediate(AluOp::Cadd, self.t[r]);
            wt.mux_a = MuxASel::Reg(0);
            words.extend([w0.encode(), w1.encode(), ws.encode(), wt.encode()]);
        } else {
            w1.reg_write = 0b0001;
            let mut wt = ContextWord::immediate(AluOp::Cadd, self.t[r]);
            wt.mux_a = MuxASel::Reg(0);
            words.extend([w0.encode(), w1.encode(), wt.encode()]);
        }
        words
    }

    pub fn compile(&self) -> MappedRoutine {
        assert!(
            (-128..=127).contains(&self.t[0]) && (-128..=127).contains(&self.t[1]),
            "translation components must fit the 8-bit context immediate"
        );
        let cols = Layout::columns_for(self.n);
        let words = words_for(self.n);
        let x_sched = self.coord_words(0);
        let y_sched = self.coord_words(1);
        let per = x_sched.len(); // steps per coordinate (3 or 4)
        let mut ctx_words = Vec::new();
        for (w, raw) in x_sched.iter().chain(y_sched.iter()).enumerate() {
            ctx_words.push((CTX_ADDR + w, *raw));
        }

        let mut prog = Vec::new();
        // X coords → set 0 bank A; Y coords → set 0 bank B.
        load_address(&mut prog, Reg(1), U_ADDR);
        prog.push(Instruction::Ldfb { rs: Reg(1), set: Set::Zero, bank: Bank::A, words, fb_addr: 0 });
        load_address(&mut prog, Reg(2), V_ADDR);
        prog.push(Instruction::Ldfb { rs: Reg(2), set: Set::Zero, bank: Bank::B, words, fb_addr: 0 });

        // All context words in one transfer.
        load_address(&mut prog, Reg(3), CTX_ADDR);
        prog.push(Instruction::Ldctxt {
            rs: Reg(3),
            block: Block::Column,
            plane: 0,
            word: 0,
            count: 2 * per,
        });

        for c in 0..cols {
            let chunk = Layout::column_chunk(c);
            for (base, out_bank) in [(0, Bank::A), (per, Bank::B)] {
                // CMUL·x from bank A, CMUL·y from bank B, then shift/add
                // (operand bus unused by the register-sourced steps).
                prog.push(Instruction::Sbcb { plane: 0, cw: base, col: c, set: Set::Zero, bank: Bank::A, addr: chunk });
                prog.push(Instruction::Sbcb { plane: 0, cw: base + 1, col: c, set: Set::Zero, bank: Bank::B, addr: chunk });
                for s in 2..per {
                    prog.push(Instruction::Sbcb { plane: 0, cw: base + s, col: c, set: Set::Zero, bank: Bank::A, addr: chunk });
                }
                prog.push(Instruction::Wfbi { col: c, set: Set::One, bank: out_bank, addr: chunk });
            }
        }

        // x' then y' stored contiguously at RESULT_ADDR.
        load_address(&mut prog, Reg(5), RESULT_ADDR);
        prog.push(Instruction::Stfb { rs: Reg(5), set: Set::One, bank: Bank::A, words, fb_addr: 0 });
        load_address(&mut prog, Reg(6), RESULT_ADDR + words);
        prog.push(Instruction::Stfb { rs: Reg(6), set: Set::One, bank: Bank::B, words, fb_addr: 0 });

        let program = Program::new(prog);
        let predicted_cycles = program.paper_cycles();
        MappedRoutine {
            name: format!("pointxf-{}", self.n),
            program,
            ctx_words,
            u_elems: self.n,
            v_elems: Some(self.n),
            w_elems: None,
            result_elems: 2 * self.n,
            predicted_cycles,
        }
    }
}

/// Composite 3-D point transformation `q = ((M·p) >> shift) + t` over `n`
/// points — the extension of [`PointTransformMapping`] the authors pursued
/// in reference [8] ("2D and 3D Computer Graphics Algorithms under
/// MorphoSys"). The third coordinate stream occupies **frame-buffer set 1
/// bank A** (the M1's four banks exactly cover x/y/z plus an output
/// region), so one tile needs no extra DMA passes.
#[derive(Debug, Clone)]
pub struct Point3TransformMapping {
    /// Number of points; multiple of 8, at most 64 per tile.
    pub n: usize,
    /// Row-major 3×3 matrix, fixed-point `Q(shift)`, i8 range.
    pub m: [i16; 9],
    /// Translation, applied after the shift.
    pub t: [i16; 3],
    pub shift: u8,
}

impl Point3TransformMapping {
    /// Context words for output coordinate `r` (x'=0, y'=1, z'=2).
    fn coord_words(&self, r: usize) -> Vec<u32> {
        let w0 = ContextWord::cmula(self.m[3 * r], true);
        let w1 = ContextWord::cmula(self.m[3 * r + 1], false);
        let mut w2 = ContextWord::cmula(self.m[3 * r + 2], false);
        w2.reg_write = 0b0001;
        let mut wt = ContextWord::immediate(AluOp::Cadd, self.t[r]);
        wt.mux_a = MuxASel::Reg(0);
        if self.shift > 0 {
            let mut ws = ContextWord::immediate(AluOp::Shr, self.shift as i16);
            ws.mux_a = MuxASel::Reg(0);
            ws.reg_write = 0b0001;
            vec![w0.encode(), w1.encode(), w2.encode(), ws.encode(), wt.encode()]
        } else {
            vec![w0.encode(), w1.encode(), w2.encode(), wt.encode()]
        }
    }

    pub fn compile(&self) -> MappedRoutine {
        for &t in &self.t {
            assert!((-128..=127).contains(&t), "translation must fit the 8-bit immediate");
        }
        let cols = Layout::columns_for(self.n);
        let words = words_for(self.n);
        let per = self.coord_words(0).len(); // 4 or 5 steps per coordinate
        let mut ctx_words = Vec::new();
        for r in 0..3 {
            for (k, raw) in self.coord_words(r).into_iter().enumerate() {
                ctx_words.push((CTX_ADDR + r * per + k, raw));
            }
        }

        let mut prog = Vec::new();
        // x → set0/A, y → set0/B, z → set1/A.
        load_address(&mut prog, Reg(1), U_ADDR);
        prog.push(Instruction::Ldfb { rs: Reg(1), set: Set::Zero, bank: Bank::A, words, fb_addr: 0 });
        load_address(&mut prog, Reg(2), V_ADDR);
        prog.push(Instruction::Ldfb { rs: Reg(2), set: Set::Zero, bank: Bank::B, words, fb_addr: 0 });
        load_address(&mut prog, Reg(6), W_ADDR);
        prog.push(Instruction::Ldfb { rs: Reg(6), set: Set::One, bank: Bank::A, words, fb_addr: 0 });

        load_address(&mut prog, Reg(3), CTX_ADDR);
        prog.push(Instruction::Ldctxt {
            rs: Reg(3),
            block: Block::Column,
            plane: 0,
            word: 0,
            count: 3 * per,
        });

        // Output regions in set1 bank B (x' at 0.., y' at 512.., z' at
        // 1024..).
        const OUT: usize = 512;
        for c in 0..cols {
            let chunk = Layout::column_chunk(c);
            for r in 0..3 {
                let base = r * per;
                // The three CMUL-accumulate steps read x, y, z.
                prog.push(Instruction::Sbcb { plane: 0, cw: base, col: c, set: Set::Zero, bank: Bank::A, addr: chunk });
                prog.push(Instruction::Sbcb { plane: 0, cw: base + 1, col: c, set: Set::Zero, bank: Bank::B, addr: chunk });
                prog.push(Instruction::Sbcb { plane: 0, cw: base + 2, col: c, set: Set::One, bank: Bank::A, addr: chunk });
                // Shift/translate steps (operand bus unused).
                for s in 3..per {
                    prog.push(Instruction::Sbcb { plane: 0, cw: base + s, col: c, set: Set::Zero, bank: Bank::A, addr: chunk });
                }
                prog.push(Instruction::Wfbi { col: c, set: Set::One, bank: Bank::B, addr: r * OUT + chunk });
            }
        }

        // Store x', y', z' contiguously at RESULT_ADDR.
        for r in 0..3 {
            load_address(&mut prog, Reg(5), RESULT_ADDR + r * words);
            prog.push(Instruction::Stfb { rs: Reg(5), set: Set::One, bank: Bank::B, words, fb_addr: r * OUT });
        }

        let program = Program::new(prog);
        let predicted_cycles = program.paper_cycles();
        MappedRoutine {
            name: format!("point3xf-{}", self.n),
            program,
            ctx_words,
            u_elems: self.n,
            v_elems: Some(self.n),
            w_elems: Some(self.n),
            result_elems: 3 * self.n,
            predicted_cycles,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn translation_64_matches_paper_cycle_count() {
        // Table 5: 64-element vector-vector translation = 96 cycles.
        let r = VecVecMapping { n: 64, op: AluOp::Add }.compile();
        assert_eq!(r.predicted_cycles, 96);
    }

    #[test]
    fn translation_8_matches_paper_cycle_count() {
        // Table 5: 8-element translation = 21 cycles.
        let r = VecVecMapping { n: 8, op: AluOp::Add }.compile();
        assert_eq!(r.predicted_cycles, 21);
    }

    #[test]
    fn scaling_64_matches_paper_cycle_count() {
        // Table 5: 64-element vector-scalar scaling = 55 cycles.
        let r = VecScalarMapping { n: 64, op: AluOp::Cmul, scalar: 5 }.compile();
        assert_eq!(r.predicted_cycles, 55);
    }

    #[test]
    fn scaling_8_matches_paper_cycle_count() {
        // Table 5: 8-element scaling = 14 cycles.
        let r = VecScalarMapping { n: 8, op: AluOp::Cmul, scalar: 5 }.compile();
        assert_eq!(r.predicted_cycles, 14);
    }

    #[test]
    fn translation_routine_uses_paper_context_word() {
        let r = VecVecMapping { n: 64, op: AluOp::Add }.compile();
        assert_eq!(r.ctx_words, vec![(CTX_ADDR, 0x0000_F400)]);
    }

    #[test]
    fn scaling_routine_uses_paper_context_word() {
        let r = VecScalarMapping { n: 64, op: AluOp::Cmul, scalar: 5 }.compile();
        assert_eq!(r.ctx_words, vec![(CTX_ADDR, 0x0000_9005)]);
    }

    #[test]
    fn matmul_context_schedule_covers_all_rows() {
        let m = MatMulMapping { dim: 4, a: (1..=16).collect(), shift: 0 };
        let r = m.compile();
        assert_eq!(r.ctx_words.len(), 16);
        // First word of each row resets the accumulator.
        for i in 0..4 {
            let (_, raw) = r.ctx_words[i * 4];
            assert!(ContextWord::decode(raw).acc_reset);
            let (_, raw1) = r.ctx_words[i * 4 + 1];
            assert!(!ContextWord::decode(raw1).acc_reset);
        }
    }

    #[test]
    fn matmul_with_shift_appends_shift_word_per_row() {
        let m = MatMulMapping { dim: 4, a: vec![1; 16], shift: 3 };
        let r = m.compile();
        assert_eq!(r.ctx_words.len(), 20);
        let (_, raw) = r.ctx_words[4]; // row 0, word 4 = shift word
        let cw = ContextWord::decode(raw);
        assert_eq!(cw.op, AluOp::Shr);
        assert_eq!(cw.imm, 3);
        assert_eq!(cw.mux_a, MuxASel::Reg(0));
    }

    #[test]
    fn point_transform_word_counts() {
        let no_shift = PointTransformMapping { n: 8, m: [1, 0, 0, 1], t: [3, 4], shift: 0 };
        assert_eq!(no_shift.compile().ctx_words.len(), 6);
        let with_shift = PointTransformMapping { n: 8, m: [64, 0, 0, 64], t: [3, 4], shift: 6 };
        assert_eq!(with_shift.compile().ctx_words.len(), 8);
    }

    #[test]
    fn vecvec_rejects_immediate_ops() {
        let r = std::panic::catch_unwind(|| VecVecMapping { n: 8, op: AluOp::Cmul }.compile());
        assert!(r.is_err());
    }

    #[test]
    fn point3_transform_identity_translation() {
        use crate::mapping::runner::run_routine3_on;
        use crate::morphosys::M1System;
        let xs: Vec<i16> = (0..8).collect();
        let ys: Vec<i16> = (10..18).collect();
        let zs: Vec<i16> = (20..28).collect();
        let m = Point3TransformMapping {
            n: 8,
            m: [1, 0, 0, 0, 1, 0, 0, 0, 1],
            t: [5, -3, 7],
            shift: 0,
        };
        let out = run_routine3_on(&mut M1System::new(), &m.compile(), &xs, Some(&ys), Some(&zs));
        let (xp, rest) = out.result.split_at(8);
        let (yp, zp) = rest.split_at(8);
        for i in 0..8 {
            assert_eq!(xp[i], xs[i] + 5);
            assert_eq!(yp[i], ys[i] - 3);
            assert_eq!(zp[i], zs[i] + 7);
        }
    }

    #[test]
    fn point3_transform_q6_rotation_about_z() {
        use crate::mapping::runner::run_routine3_on;
        use crate::morphosys::M1System;
        // 90° about Z in Q6: x' = -y, y' = x, z' = z.
        let m = Point3TransformMapping {
            n: 64,
            m: [0, -64, 0, 64, 0, 0, 0, 0, 64],
            t: [0, 0, 0],
            shift: 6,
        };
        let xs: Vec<i16> = (0..64).collect();
        let ys: Vec<i16> = (0..64).map(|i| 100 - i).collect();
        let zs: Vec<i16> = (0..64).map(|i| -i).collect();
        let out = run_routine3_on(&mut M1System::new(), &m.compile(), &xs, Some(&ys), Some(&zs));
        let (xp, rest) = out.result.split_at(64);
        let (yp, zp) = rest.split_at(64);
        for i in 0..64 {
            assert_eq!(xp[i], -ys[i], "x'[{i}]");
            assert_eq!(yp[i], xs[i], "y'[{i}]");
            assert_eq!(zp[i], zs[i], "z'[{i}]");
        }
    }

    #[test]
    fn point3_context_schedule_fits_one_plane() {
        let m = Point3TransformMapping {
            n: 8,
            m: [64, 0, 0, 0, 64, 0, 0, 0, 64],
            t: [1, 2, 3],
            shift: 6,
        };
        let r = m.compile();
        assert_eq!(r.ctx_words.len(), 15); // 3 coords × 5 steps ≤ 16/plane
        assert!(r.w_elems == Some(8));
    }

    #[test]
    fn vecscalar_rejects_two_port_ops() {
        let r = std::panic::catch_unwind(|| {
            VecScalarMapping { n: 8, op: AluOp::Add, scalar: 1 }.compile()
        });
        assert!(r.is_err());
    }
}
