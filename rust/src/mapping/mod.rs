//! # Algorithm mapping — the paper's contribution
//!
//! §5 of the paper maps linear-algebraic kernels onto the M1:
//!
//! * **§5.1 vector-vector operations** (translation): both operands DMA'd
//!   into the two frame-buffer banks, one *double-bank column broadcast*
//!   (`dbcdc`) per 8-element column, context word `0000F400` (`OUT=A+B`).
//! * **§5.2 vector-scalar operations** (scaling): one operand in bank A,
//!   the scalar carried in the context-word immediate (`00009005` =
//!   `OUT = 5×A`), one *single-bank column broadcast* (`sbcb`) per column.
//! * **§5.3 matrix multiplication** (rotation/composite): matrix A enters
//!   through per-step context words (constant-multiply-accumulate), matrix
//!   B is broadcast row by row.
//!
//! This module is the *mapping compiler*: given an operation and a size it
//! emits the TinyRISC program, the context words to stage in main memory,
//! and a static cycle prediction — and the prediction is asserted equal to
//! the simulator's measured cycles by the [`plan`] tests. The paper's
//! main-memory address map is kept: vector U at word `0x10000`, V at
//! `0x20000`, context words at `0x30000`, results at `0x40000`.

pub mod extended;
pub mod layout;
pub mod plan;
pub mod routines;
pub mod runner;
pub mod streamed;

pub use extended::{DotProductMapping, MatVecMapping, SaxpyMapping, VecReduceMapping};
pub use layout::{Layout, CTX_ADDR, RESULT_ADDR, U_ADDR, V_ADDR};
pub use plan::MappingPlan;
pub use routines::{
    MappedRoutine, MatMulMapping, Point3TransformMapping, PointTransformMapping,
    VecScalarMapping, VecVecMapping,
};
pub use runner::{
    megakernel_cache_evictions, megakernel_for, run_plan, run_routine, CompiledMegakernel,
    MegaSpec, RoutineOutput,
};
pub use streamed::{StreamedPointTransformMapping, StreamedTiledMapping, TiledVecVecMapping};
