//! Self-contained micro-benchmark harness (criterion is unavailable in
//! this offline workspace). Used by the `rust/benches/*` targets
//! (`cargo bench`).
//!
//! Methodology: warm up, then run timed batches until both a minimum
//! wall-clock budget and a minimum iteration count are met; report mean,
//! p50, p95 and min over per-iteration times, plus derived throughput.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Measurement {
    /// Items/second given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean.as_secs_f64()
    }

    pub fn render(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12?}  p50 {:>12?}  p95 {:>12?}  min {:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p95, self.min
        )
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: u64,
    pub max_iters: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            warmup: Duration::from_millis(100),
            budget: Duration::from_millis(700),
            min_iters: 10,
            max_iters: 100_000,
        }
    }
}

/// Run one benchmark; `f` is a single iteration.
pub fn bench_with(config: Config, name: &str, mut f: impl FnMut()) -> Measurement {
    // Warm-up.
    let w0 = Instant::now();
    while w0.elapsed() < config.warmup {
        f();
    }
    // Timed iterations.
    let mut samples: Vec<Duration> = Vec::new();
    let t0 = Instant::now();
    while (t0.elapsed() < config.budget || (samples.len() as u64) < config.min_iters)
        && (samples.len() as u64) < config.max_iters
    {
        let s = Instant::now();
        f();
        samples.push(s.elapsed());
    }
    samples.sort_unstable();
    let iters = samples.len() as u64;
    let total: Duration = samples.iter().sum();
    let p95_idx = ((samples.len() - 1) as f64 * 0.95) as usize;
    let m = Measurement {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: samples[samples.len() / 2],
        p95: samples[p95_idx],
        min: samples[0],
    };
    println!("{}", m.render());
    m
}

/// Run with defaults.
pub fn bench(name: &str, f: impl FnMut()) -> Measurement {
    bench_with(Config::default(), name, f)
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Atomically write `contents` to `path`: write a sibling temp file, then
/// rename it over the target, so a concurrent reader (CI artifact
/// collection, the bench-regression gate, cross-PR trajectory tooling)
/// never observes a half-written file. Shared by every `BENCH_*.json`
/// emitter. The temp file is removed on failure.
pub fn write_atomic(path: &str, contents: &str) -> std::io::Result<()> {
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, contents)
        .and_then(|()| std::fs::rename(&tmp, path))
        .map_err(|e| {
            let _ = std::fs::remove_file(&tmp);
            e
        })
}

#[cfg(test)]
mod tests {
    #[test]
    fn write_atomic_replaces_target_and_leaves_no_temp() {
        let dir = std::env::temp_dir().join("morpho_benchkit_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.json");
        let path = path.to_str().unwrap();
        super::write_atomic(path, "[1]").unwrap();
        assert_eq!(std::fs::read_to_string(path).unwrap(), "[1]");
        super::write_atomic(path, "[2]").unwrap();
        assert_eq!(std::fs::read_to_string(path).unwrap(), "[2]");
        assert!(!std::path::Path::new(&format!("{path}.tmp")).exists());
    }
}
