//! # Coordinator — the transform-serving runtime (L3)
//!
//! The serving layer that turns the paper's "graphics acceleration
//! library" into a deployable service:
//!
//! ```text
//!  remote clients ──TCP frames──► Router (front-end, optional: one wire
//!  (wire.rs protocol)             listener proxying to N backend
//!                              │  coordinators; kind-5 health polls drive
//!                              │  a per-backend Healthy→Suspect→Dead
//!                              │  breaker; least-reported-queue-depth
//!                              │  balancing, round-robin on ties/stale;
//!                              │  in-flight requests of a dying backend
//!                              │  are re-dispatched exactly once; every
//!                              │  backend dead ⇒ immediate Unavailable)
//!                              ▼
//!  remote clients ──TCP frames──► WireServer (accept loop + per-connection
//!  (wire.rs protocol)             reader/writer threads; malformed frame ⇒
//!                                 ProtocolError + close THAT connection;
//!                                 kind-5 health poll ⇒ inline report;
//!                                 shutdown ⇒ stop accepting, drain admitted,
//!                                 then close — exactly-one-reply holds)
//!                              │
//!  clients ──submit()───────► bounded two-lane queue (interactive rides
//!          ──try_submit()──►   │  the express lane, bulk the standard
//!          ◄─QueueFull reject──┘  lane, one shared capacity; full ⇒
//!                              │  block / instant rejection)
//!                              │
//!                        batcher thread: shed requests whose deadline
//!                        (TTL) expired while queued ──► Rejection to the
//!                        client (lane-weighted: congested windows shed
//!                        near-deadline BULK first — interactive is never
//!                        preempted while bulk remains); group the rest by
//!                        transform, pack into tiles (64 points — the M1's
//!                        natural unit — up to 4096 for bulk); the batch
//!                        window is deadline-bounded, either static
//!                        (`max_wait`) or sized per-window by the
//!                        `AdaptiveWindow` controller from the queue-depth
//!                        gauge (deep ⇒ widen for throughput, drained ⇒
//!                        shrink for latency)
//!                              │
//!                        worker threads: each owns ONE backend instance
//!                        (PJRT executors are thread-pinned) and executes
//!                        tile jobs, scattering results back per request
//!                              │
//!                        shard supervision (M1 backend): tile panics are
//!                        caught, the shard warm-restarts from its boot
//!                        snapshot and the tile re-runs; dead shard
//!                        threads are respawned and their abandoned tiles
//!                        re-dispatched on a recovery shard — results stay
//!                        bit-identical and exactly-one-reply holds even
//!                        under injected chaos (`FaultPlan`)
//!                              │
//!  clients ◄──per-request channel── ServeResult: response + timing, or
//!                                   an explicit Rejection (shed/full)
//! ```
//!
//! Every admitted request gets exactly one [`request::ServeResult`] on its
//! channel — shedding is a message, never a silently dropped channel.
//! Capacity and admission behaviour under load are measured by the
//! [`crate::loadgen`] harness (`repro loadtest <scenario>`), which writes
//! `BENCH_coordinator.json`.
//!
//! Backends: [`backend::NativeBackend`] (plain rust), [`backend::XlaBackend`]
//! (the AOT artifacts via PJRT) and [`backend::M1SimBackend`] (the
//! cycle-accurate MorphoSys simulator running the paper's mappings, which
//! additionally reports simulated M1 cycles). The M1 backend executes its
//! 64-point tile plan on the sharded [`pool::TilePool`] — serial with
//! `shards = 1`, fanned out across per-shard simulators otherwise, with
//! bit-identical outputs and cycle totals either way.

pub mod backend;
pub mod batcher;
pub mod faults;
pub mod metrics;
pub mod pool;
pub mod queue;
pub mod request;
pub mod router;
pub mod server;
pub mod wire;

pub use backend::{Backend, BackendKind, M1SimBackend, NativeBackend, XlaBackend};
pub use batcher::{AdaptiveWindow, AdaptiveWindowConfig, Batcher, BatcherConfig};
pub use faults::{BackendKillPlan, FaultPlan, KillEvent};
pub use metrics::{BackendSnapshot, ClusterSnapshot, Metrics, MetricsSnapshot};
pub use pool::{PoolHealth, RoutineSpec, TileOutcome, TilePool, TileRequest};
pub use queue::{BoundedQueue, Lane, PopResult, PushError};
pub use request::{
    Priority, RejectReason, Rejection, ServeResult, TransformRequest, TransformResponse,
};
pub use router::{BreakerState, Router, RouterConfig};
pub use server::{BackendChoice, Coordinator, CoordinatorConfig, WireServer};
pub use wire::{Frame, HealthStats, WireError, MAX_FRAME, WIRE_VERSION};
