//! Deterministic fault injection for the tile pool (§Robustness).
//!
//! A [`FaultPlan`] is a **seeded, test-only** schedule of failures that
//! the pool's shards consult at every tile dispatch: panic here, stall
//! there, kill this shard thread outright, drop that reply. All decisions
//! are pure functions of the seed and the pool-wide dispatch counter, so
//! a failing chaos run is re-executable bit-for-bit from its seed alone —
//! the property the repro artifacts ([`crate::replay`]) and the CI
//! `chaos-smoke` job build on.
//!
//! The plan is shared (`Arc` internals, cheap `Clone`) so one schedule
//! spans every worker's pool in a coordinator; production configs leave
//! [`super::CoordinatorConfig::fault_plan`] as `None` and none of this
//! code runs on the serving path.
//!
//! Injection never compromises the determinism contract: a panicked or
//! killed tile is re-run by the supervision layer (see
//! [`super::pool::TilePool`]), and tiles are pure functions of their
//! inputs, so results stay bit-identical to a fault-free run.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// What the plan tells a shard to do at one tile dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum FaultAction {
    /// Run the tile normally.
    None,
    /// Sleep before running the tile — a stalled DMA engine / descheduled
    /// shard. Timing-only: results are untouched.
    Stall(Duration),
    /// Panic before running the tile (caught by the shard supervisor,
    /// which warm-restarts the simulator and retries).
    Panic,
    /// Kill the shard thread outright, abandoning the rest of its claimed
    /// chunk (the caller-side recovery pass re-dispatches those tiles and
    /// respawns the thread).
    Die,
}

#[derive(Debug)]
struct FaultInner {
    seed: u64,
    /// Panic on every `panic_every`-th dispatch (1-indexed); `0` disables.
    panic_every: u64,
    max_panics: u64,
    /// Kill the shard thread on every `die_every`-th dispatch; `0` disables.
    die_every: u64,
    max_deaths: u64,
    /// Stall on every `stall_every`-th dispatch; `0` disables.
    stall_every: u64,
    stall: Duration,
    /// Drop the reply of every `drop_every`-th *completed* tile; `0`
    /// disables.
    drop_every: u64,
    max_drops: u64,
    /// Extra delay the batcher pump sleeps per batch window (a stalled
    /// upstream queue); `None` disables.
    queue_stall: Option<Duration>,
    dispatched: AtomicU64,
    completed: AtomicU64,
    panics: AtomicU64,
    deaths: AtomicU64,
    drops: AtomicU64,
}

/// A seeded, shareable fault-injection schedule. See the module docs.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    inner: Arc<FaultInner>,
}

/// SplitMix64 — the crate-standard cheap deterministic scrambler, used to
/// derive the chaos profile's knobs from one seed.
pub(crate) fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Bump `ctr` if it is still below `max`; `true` when the bump happened
/// (i.e. this fault instance may fire).
fn bump_below(ctr: &AtomicU64, max: u64) -> bool {
    ctr.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| (v < max).then_some(v + 1))
        .is_ok()
}

impl FaultPlan {
    fn quiet(seed: u64) -> FaultInner {
        FaultInner {
            seed,
            panic_every: 0,
            max_panics: 0,
            die_every: 0,
            max_deaths: 0,
            stall_every: 0,
            stall: Duration::ZERO,
            drop_every: 0,
            max_drops: 0,
            queue_stall: None,
            dispatched: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            deaths: AtomicU64::new(0),
            drops: AtomicU64::new(0),
        }
    }

    /// The full chaos profile, every knob derived deterministically from
    /// `seed`: recurring shard panics, a couple of outright shard-thread
    /// deaths, periodic DMA stalls, dropped tile replies and a stalled
    /// batcher pump. This is what `repro loadtest chaos` runs.
    pub fn chaos(seed: u64) -> FaultPlan {
        let mut s = seed;
        let mut next = || splitmix64(&mut s);
        FaultPlan {
            inner: Arc::new(FaultInner {
                panic_every: 6 + next() % 5,
                max_panics: 4,
                die_every: 25 + next() % 10,
                max_deaths: 2,
                stall_every: 16,
                stall: Duration::from_micros(100 + next() % 200),
                drop_every: 9 + next() % 4,
                max_drops: 3,
                queue_stall: Some(Duration::from_micros(200)),
                ..Self::quiet(seed)
            }),
        }
    }

    /// Panic (exactly once) on the `nth` tile dispatch, 1-indexed.
    pub fn panic_at(seed: u64, nth: u64) -> FaultPlan {
        assert!(nth > 0, "dispatch counts are 1-indexed");
        FaultPlan {
            inner: Arc::new(FaultInner { panic_every: nth, max_panics: 1, ..Self::quiet(seed) }),
        }
    }

    /// Kill the dispatching shard thread (exactly once) on the `nth` tile
    /// dispatch, 1-indexed.
    pub fn shard_death_at(seed: u64, nth: u64) -> FaultPlan {
        assert!(nth > 0, "dispatch counts are 1-indexed");
        FaultPlan {
            inner: Arc::new(FaultInner { die_every: nth, max_deaths: 1, ..Self::quiet(seed) }),
        }
    }

    /// Drop (exactly once) the reply of the `nth` completed tile,
    /// 1-indexed.
    pub fn drop_reply_at(seed: u64, nth: u64) -> FaultPlan {
        assert!(nth > 0, "completion counts are 1-indexed");
        FaultPlan {
            inner: Arc::new(FaultInner { drop_every: nth, max_drops: 1, ..Self::quiet(seed) }),
        }
    }

    /// Stall every `every`-th dispatch by `stall` (timing-only).
    pub fn stall_every(seed: u64, every: u64, stall: Duration) -> FaultPlan {
        assert!(every > 0, "dispatch counts are 1-indexed");
        FaultPlan {
            inner: Arc::new(FaultInner { stall_every: every, stall, ..Self::quiet(seed) }),
        }
    }

    pub fn seed(&self) -> u64 {
        self.inner.seed
    }

    /// The pump-loop stall this plan injects per batch window, if any.
    pub fn queue_stall(&self) -> Option<Duration> {
        self.inner.queue_stall
    }

    /// Consult the plan at one tile dispatch (shard side). Advances the
    /// pool-wide dispatch counter.
    pub(crate) fn on_dispatch(&self) -> FaultAction {
        let i = &*self.inner;
        let n = i.dispatched.fetch_add(1, Ordering::Relaxed) + 1;
        if i.die_every != 0 && n % i.die_every == 0 && bump_below(&i.deaths, i.max_deaths) {
            return FaultAction::Die;
        }
        if i.panic_every != 0 && n % i.panic_every == 0 && bump_below(&i.panics, i.max_panics) {
            return FaultAction::Panic;
        }
        if i.stall_every != 0 && n % i.stall_every == 0 {
            return FaultAction::Stall(i.stall);
        }
        FaultAction::None
    }

    /// Consult the plan after one tile completed (shard side): `true`
    /// means the shard must *drop* the reply instead of sending it, and
    /// the caller-side recovery pass must make the result whole again.
    pub(crate) fn take_drop_reply(&self) -> bool {
        let i = &*self.inner;
        if i.drop_every == 0 {
            return false;
        }
        let n = i.completed.fetch_add(1, Ordering::Relaxed) + 1;
        n % i.drop_every == 0 && bump_below(&i.drops, i.max_drops)
    }

    /// Injected panics that actually fired so far.
    pub fn panics_fired(&self) -> u64 {
        self.inner.panics.load(Ordering::Relaxed)
    }

    /// Injected shard-thread deaths that actually fired so far.
    pub fn deaths_fired(&self) -> u64 {
        self.inner.deaths.load(Ordering::Relaxed)
    }

    /// Injected reply drops that actually fired so far.
    pub fn drops_fired(&self) -> u64 {
        self.inner.drops.load(Ordering::Relaxed)
    }
}

// ── process-level fault schedules ──────────────────────────────────────

/// One scheduled backend-process failure: at `at` into the run, kill
/// backend `backend`; bring a fresh process up on the same address
/// `restart_after` later.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KillEvent {
    /// Offset from run start at which the backend dies.
    pub at: Duration,
    /// Index of the backend to kill (into the router's backend list).
    pub backend: usize,
    /// How long the backend stays down before restarting.
    pub restart_after: Duration,
}

/// A seeded schedule of backend-process kills for the failover loadgen
/// scenario — [`FaultPlan`]'s discipline lifted from shard-level to
/// process-level: every event is a pure function of `(seed, backends,
/// duration)`, so a failing failover run is re-executable from its seed.
#[derive(Debug, Clone)]
pub struct BackendKillPlan {
    events: Vec<KillEvent>,
}

impl BackendKillPlan {
    /// Derive the schedule: one kill at ~25% of the run aimed at a
    /// seed-chosen backend, restarting after ~20% of the run — leaving
    /// more than half the run for the router to heal and the revived
    /// backend to rejoin the rotation (what the failover gate asserts).
    pub fn seeded(seed: u64, backends: usize, duration: Duration) -> BackendKillPlan {
        assert!(backends > 0, "a kill plan needs at least one backend");
        let mut s = seed;
        let victim = (splitmix64(&mut s) as usize) % backends;
        // ±5% seeded jitter on the kill point keeps runs honest about
        // not depending on an exact phase, while staying deterministic.
        let jitter_pct = 20 + splitmix64(&mut s) % 11; // 20..=30 (% of run)
        BackendKillPlan {
            events: vec![KillEvent {
                at: duration.mul_f64(jitter_pct as f64 / 100.0),
                backend: victim,
                restart_after: duration.mul_f64(0.20),
            }],
        }
    }

    /// The schedule, ordered by `at`.
    pub fn events(&self) -> &[KillEvent] {
        &self.events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kill_plan_is_deterministic_and_leaves_time_to_heal() {
        let a = BackendKillPlan::seeded(0xFA11, 2, Duration::from_secs(2));
        let b = BackendKillPlan::seeded(0xFA11, 2, Duration::from_secs(2));
        assert_eq!(a.events(), b.events(), "same seed, same schedule");
        assert_eq!(a.events().len(), 1);
        let e = a.events()[0];
        assert!(e.backend < 2);
        // Down by ~30% of the run, back by ~50%: over half the run
        // remains for the rejoin the failover gate requires.
        assert!(e.at + e.restart_after <= Duration::from_secs(2).mul_f64(0.55));
        let c = BackendKillPlan::seeded(0xFA12, 2, Duration::from_secs(2));
        let differs = c.events()[0].backend != e.backend || c.events()[0].at != e.at;
        assert!(differs, "different seed, different schedule");
    }

    #[test]
    fn panic_at_fires_exactly_once_at_the_scheduled_dispatch() {
        let plan = FaultPlan::panic_at(7, 3);
        let actions: Vec<FaultAction> = (0..9).map(|_| plan.on_dispatch()).collect();
        assert_eq!(actions[2], FaultAction::Panic, "fires on the 3rd dispatch");
        assert_eq!(
            actions.iter().filter(|a| **a == FaultAction::Panic).count(),
            1,
            "max_panics bounds recurrence even though 6 and 9 are also multiples"
        );
        assert_eq!(plan.panics_fired(), 1);
    }

    #[test]
    fn clones_share_one_schedule() {
        let plan = FaultPlan::panic_at(7, 2);
        let other = plan.clone();
        assert_eq!(plan.on_dispatch(), FaultAction::None);
        // The clone sees the shared counter: its first call is dispatch 2.
        assert_eq!(other.on_dispatch(), FaultAction::Panic);
        assert_eq!(plan.panics_fired(), 1);
    }

    #[test]
    fn drop_reply_counts_completions_not_dispatches() {
        let plan = FaultPlan::drop_reply_at(7, 2);
        assert_eq!(plan.on_dispatch(), FaultAction::None);
        assert_eq!(plan.on_dispatch(), FaultAction::None);
        assert!(!plan.take_drop_reply());
        assert!(plan.take_drop_reply(), "2nd completion drops");
        assert!(!plan.take_drop_reply(), "bounded by max_drops");
        assert_eq!(plan.drops_fired(), 1);
    }

    #[test]
    fn chaos_profile_is_deterministic_in_the_seed() {
        let a = FaultPlan::chaos(42);
        let b = FaultPlan::chaos(42);
        let seq_a: Vec<FaultAction> = (0..200).map(|_| a.on_dispatch()).collect();
        let seq_b: Vec<FaultAction> = (0..200).map(|_| b.on_dispatch()).collect();
        assert_eq!(seq_a, seq_b, "same seed, same schedule");
        assert!(seq_a.iter().any(|x| *x == FaultAction::Panic));
        assert!(seq_a.iter().any(|x| *x == FaultAction::Die));
        assert!(seq_a.iter().any(|x| matches!(x, FaultAction::Stall(_))));
        assert!(a.queue_stall().is_some());
        let c = FaultPlan::chaos(43);
        let seq_c: Vec<FaultAction> = (0..200).map(|_| c.on_dispatch()).collect();
        assert_ne!(seq_a, seq_c, "different seed, different schedule");
    }

    #[test]
    fn stall_plan_only_stalls() {
        let plan = FaultPlan::stall_every(1, 2, Duration::from_micros(50));
        assert_eq!(plan.on_dispatch(), FaultAction::None);
        assert_eq!(plan.on_dispatch(), FaultAction::Stall(Duration::from_micros(50)));
        assert_eq!(plan.panics_fired() + plan.deaths_fired() + plan.drops_fired(), 0);
    }
}
