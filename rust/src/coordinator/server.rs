//! The coordinator service: submit → queue → batcher pump → worker pool →
//! per-request response channels — plus [`WireServer`], the TCP listener
//! that feeds the same admission path from remote connections speaking
//! the [`super::wire`] protocol.

use std::io;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::graphics::Transform;

use super::backend::{apply_native, Backend, M1SimBackend, NativeBackend, XlaBackend};
use super::batcher::{AdaptiveWindow, Batcher, BatcherConfig, TileJob};
use super::faults::FaultPlan;
use super::metrics::{Metrics, MetricsSnapshot};
use super::queue::{BoundedQueue, Lane, PopResult, PushError};
use super::request::{
    PendingRequest, Priority, RejectReason, Rejection, ServeResult, TransformRequest,
    TransformResponse,
};
use super::wire::{self, Frame};

/// Which backend the workers construct (each worker builds its own
/// instance on its own thread — PJRT clients are thread-pinned).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendChoice {
    Native,
    Xla,
    M1Sim,
}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub backend: BackendChoice,
    /// Admission queue capacity (requests) — the backpressure bound.
    pub queue_capacity: usize,
    /// In-flight job queue capacity.
    pub job_capacity: usize,
    pub workers: usize,
    /// Tile-pool shards per `M1Sim` worker (each worker owns its own
    /// pool). `1` is the serial mode; with more shards a worker fans a
    /// job's independent 64-point tiles across per-shard simulators —
    /// results are bit-identical either way, so this is purely a
    /// throughput knob. Total simulator threads ≈ `workers × m1_shards`;
    /// scale shards (which parallelize within a job) before workers
    /// (which parallelize across jobs). Ignored by other backends.
    pub m1_shards: usize,
    /// Run the `M1Sim` workers' shard simulators in **async-DMA** mode
    /// (§Perf PR 5): frame-buffer DMA overlaps RC-array compute, so
    /// reported simulated cycles reflect the M1's double-buffered
    /// streaming rather than the paper's blocking listings. Purely a
    /// cycle-accounting mode — transformed outputs are identical either
    /// way. Ignored by other backends.
    pub m1_async_dma: bool,
    /// Default time budget applied to requests that carry no explicit
    /// [`TransformRequest::ttl`]. A request still queued past its budget
    /// is shed by the batcher with an explicit rejection (admission
    /// control); one that completes late is counted `deadline_missed`.
    /// `None` (the default) disables deadlines entirely.
    pub default_ttl: Option<Duration>,
    /// Deterministic fault-injection schedule shared by every `M1Sim`
    /// worker's tile pool (chaos/test only — see [`FaultPlan`]). Injected
    /// shard panics, deaths, stalls and dropped replies exercise the
    /// supervision paths; results stay bit-identical and every admitted
    /// request still gets exactly one reply. `None` (the default, and the
    /// only sensible production value) makes all injection code dormant.
    pub fault_plan: Option<FaultPlan>,
    pub batcher: BatcherConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            backend: BackendChoice::Native,
            queue_capacity: 1024,
            job_capacity: 256,
            workers: 2,
            m1_shards: 1,
            m1_async_dma: false,
            default_ttl: None,
            fault_plan: None,
            batcher: BatcherConfig::default(),
        }
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    submit_q: Arc<BoundedQueue<PendingRequest>>,
    metrics: Arc<Metrics>,
    default_ttl: Option<Duration>,
    next_id: AtomicU64,
    threads: Vec<JoinHandle<()>>,
}

impl Coordinator {
    /// Start the pump and worker threads.
    pub fn start(config: CoordinatorConfig) -> Result<Coordinator> {
        let submit_q = Arc::new(BoundedQueue::<PendingRequest>::new(config.queue_capacity));
        let job_q = Arc::new(BoundedQueue::<TileJob>::new(config.job_capacity));
        let metrics = Arc::new(Metrics::default());
        let mut threads = Vec::new();

        // Batcher pump.
        {
            let submit_q = submit_q.clone();
            let job_q = job_q.clone();
            let metrics = metrics.clone();
            let batcher = Batcher::new(config.batcher);
            // Injected upstream stall per batch window (chaos only).
            let stall = config.fault_plan.as_ref().and_then(|f| f.queue_stall());
            threads.push(std::thread::Builder::new().name("morpho-pump".into()).spawn(
                move || {
                    pump_loop(&submit_q, &job_q, &metrics, &batcher, stall);
                    job_q.close();
                },
            )?);
        }

        // Workers.
        for w in 0..config.workers.max(1) {
            let job_q = job_q.clone();
            let metrics = metrics.clone();
            let choice = config.backend;
            let m1_shards = config.m1_shards;
            let m1_async_dma = config.m1_async_dma;
            let faults = config.fault_plan.clone();
            threads.push(std::thread::Builder::new().name(format!("morpho-worker-{w}")).spawn(
                move || {
                    // Backend construction happens on the worker thread
                    // (XLA executors are not Send).
                    let mut backend: Box<dyn Backend> = match choice {
                        BackendChoice::Native => Box::new(NativeBackend),
                        BackendChoice::M1Sim => {
                            Box::new(M1SimBackend::with_faults(m1_shards, m1_async_dma, faults))
                        }
                        BackendChoice::Xla => match XlaBackend::discover() {
                            Ok(b) => Box::new(b),
                            Err(e) => {
                                eprintln!(
                                    "morpho-worker-{w}: XLA backend unavailable ({e:#}); \
                                     falling back to native"
                                );
                                Box::new(NativeBackend)
                            }
                        },
                    };
                    worker_loop(&job_q, &metrics, backend.as_mut());
                },
            )?);
        }

        Ok(Coordinator {
            submit_q,
            metrics,
            default_ttl: config.default_ttl,
            next_id: AtomicU64::new(1),
            threads,
        })
    }

    /// Submit a request; returns the channel the response arrives on.
    /// Blocks when the admission queue is full (backpressure).
    pub fn submit(
        &self,
        xs: Vec<f32>,
        ys: Vec<f32>,
        transforms: Vec<Transform>,
    ) -> Result<mpsc::Receiver<ServeResult>> {
        self.submit_with_priority(xs, ys, transforms, Priority::Interactive)
    }

    /// [`Coordinator::submit`] with an explicit lane: interactive rides
    /// the express admission lane, bulk the standard one.
    pub fn submit_with_priority(
        &self,
        xs: Vec<f32>,
        ys: Vec<f32>,
        transforms: Vec<Transform>,
        priority: Priority,
    ) -> Result<mpsc::Receiver<ServeResult>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.submit_request(TransformRequest::new(id, xs, ys, transforms).with_priority(priority))
    }

    /// Submit a pre-built request.
    pub fn submit_request(&self, req: TransformRequest) -> Result<mpsc::Receiver<ServeResult>> {
        let (tx, rx) = mpsc::channel();
        self.submit_request_shared(req, tx)
            .map_err(|_| anyhow::anyhow!("coordinator is shut down"))?;
        Ok(rx)
    }

    /// Blocking submit replying on a caller-supplied sender — the wire
    /// path, where one per-connection channel muxes every reply for that
    /// connection (tagged by request id) instead of a channel per
    /// request. Errs only when the coordinator is shutting down; the
    /// rejection is *returned*, not sent, so the caller controls whether
    /// it goes onto the shared channel.
    pub fn submit_request_shared(
        &self,
        req: TransformRequest,
        reply: mpsc::Sender<ServeResult>,
    ) -> std::result::Result<(), Rejection> {
        let id = req.id;
        let points = req.points();
        let lane = lane_for(req.priority);
        match self.submit_q.push_lane(self.pending(req, reply), lane) {
            Ok(()) => {
                self.metrics.record_request(points);
                Ok(())
            }
            Err(_) => {
                self.metrics.closed.fetch_add(1, Ordering::Relaxed);
                Err(Rejection { id, reason: RejectReason::ShuttingDown })
            }
        }
    }

    /// Admission-control fast path: submit without blocking. Where
    /// [`Coordinator::submit`] parks the caller while the admission queue
    /// is full (backpressure), `try_submit` answers immediately with a
    /// [`Rejection`] — the open-loop serving discipline, where clients
    /// cannot be slowed down and overload must be shed at the door.
    /// `metrics.rejected` counts the fast rejections.
    pub fn try_submit(
        &self,
        xs: Vec<f32>,
        ys: Vec<f32>,
        transforms: Vec<Transform>,
    ) -> std::result::Result<mpsc::Receiver<ServeResult>, Rejection> {
        self.try_submit_with_priority(xs, ys, transforms, Priority::Interactive)
    }

    /// [`Coordinator::try_submit`] with an explicit lane.
    pub fn try_submit_with_priority(
        &self,
        xs: Vec<f32>,
        ys: Vec<f32>,
        transforms: Vec<Transform>,
        priority: Priority,
    ) -> std::result::Result<mpsc::Receiver<ServeResult>, Rejection> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.try_submit_request(TransformRequest::new(id, xs, ys, transforms).with_priority(priority))
    }

    /// Non-blocking submit of a pre-built request (see
    /// [`Coordinator::try_submit`]).
    pub fn try_submit_request(
        &self,
        req: TransformRequest,
    ) -> std::result::Result<mpsc::Receiver<ServeResult>, Rejection> {
        let (tx, rx) = mpsc::channel();
        self.try_submit_request_shared(req, tx)?;
        Ok(rx)
    }

    /// Non-blocking submit replying on a caller-supplied sender (the wire
    /// path's fast-reject discipline — see
    /// [`Coordinator::submit_request_shared`]).
    pub fn try_submit_request_shared(
        &self,
        req: TransformRequest,
        reply: mpsc::Sender<ServeResult>,
    ) -> std::result::Result<(), Rejection> {
        let id = req.id;
        let points = req.points();
        let lane = lane_for(req.priority);
        match self.submit_q.try_push_lane(self.pending(req, reply), lane) {
            Ok(()) => {
                self.metrics.record_request(points);
                Ok(())
            }
            Err((_, PushError::Full)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(Rejection { id, reason: RejectReason::QueueFull })
            }
            Err((_, PushError::Closed)) => {
                // Distinct from `rejected`: this is shutdown, not
                // overload — capacity reports keep the two apart.
                self.metrics.closed.fetch_add(1, Ordering::Relaxed);
                Err(Rejection { id, reason: RejectReason::ShuttingDown })
            }
        }
    }

    fn pending(&self, req: TransformRequest, tx: mpsc::Sender<ServeResult>) -> PendingRequest {
        let now = Instant::now();
        let deadline = req.ttl.or(self.default_ttl).map(|ttl| now + ttl);
        PendingRequest { req, submitted: now, deadline, reply: tx }
    }

    /// Convenience: submit and wait. A rejection (deadline shed) surfaces
    /// as an error.
    pub fn transform_blocking(
        &self,
        xs: Vec<f32>,
        ys: Vec<f32>,
        transforms: Vec<Transform>,
    ) -> Result<TransformResponse> {
        let rx = self.submit(xs, ys, transforms)?;
        match rx.recv()? {
            Ok(resp) => Ok(resp),
            Err(rej) => Err(anyhow::anyhow!("request {} rejected: {:?}", rej.id, rej.reason)),
        }
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Current admission-queue depth (requests admitted but not yet
    /// batched) — the load-generation harness's saturation gauge.
    pub fn queue_depth(&self) -> usize {
        self.submit_q.len()
    }

    /// The live admission ledger as a kind-5 wire health report — what
    /// serving connections answer health polls with, and the front-end
    /// router's breaker/least-loaded input.
    pub fn health_stats(&self) -> wire::HealthStats {
        let m = &self.metrics;
        wire::HealthStats {
            queue_depth: self.submit_q.len() as u64,
            requests: m.requests.load(Ordering::Relaxed),
            responses: m.responses.load(Ordering::Relaxed),
            shed: m.shed.load(Ordering::Relaxed),
            rejected: m.rejected.load(Ordering::Relaxed),
            closed: m.closed.load(Ordering::Relaxed),
            deadline_missed: m.deadline_missed.load(Ordering::Relaxed),
            shard_crashes: m.shard_crashes.load(Ordering::Relaxed),
            shard_restarts: m.shard_restarts.load(Ordering::Relaxed),
            tiles_redispatched: m.tiles_redispatched.load(Ordering::Relaxed),
            recovery_max_us: m.recovery_max_us.load(Ordering::Relaxed),
        }
    }

    /// Graceful shutdown without consuming the handle: new submissions
    /// fail immediately (`ShuttingDown` rejections, counted in
    /// `metrics.closed`), and `close` then **waits for every
    /// already-admitted request to receive its reply** — response or
    /// explicit rejection — before returning, so the exactly-one-reply
    /// invariant survives shutdown. Useful when the coordinator is shared
    /// behind an `Arc` (threads are joined when the last handle drops, or
    /// by [`Coordinator::shutdown`]). The drain wait is bounded (~30 s)
    /// so a wedged backend cannot hang the caller forever.
    pub fn close(&self) {
        self.submit_q.close();
        let cap = Instant::now() + Duration::from_secs(30);
        loop {
            let requests = self.metrics.requests.load(Ordering::Relaxed);
            let responses = self.metrics.responses.load(Ordering::Relaxed);
            if (responses >= requests && self.submit_q.is_empty()) || Instant::now() >= cap {
                return;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Drain and stop all threads (graceful: admitted requests are
    /// answered before the queues wind down).
    pub fn shutdown(mut self) {
        self.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.submit_q.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

// ── the network serving tier ───────────────────────────────────────────

/// Accept-loop poll interval: the listener runs nonblocking so the
/// accept thread can observe the stop flag without a self-connect trick.
const ACCEPT_POLL: Duration = Duration::from_millis(1);

/// A live connection: the server-side stream (kept for shutdown
/// signalling) plus its reader/writer thread pair.
struct Conn {
    stream: TcpStream,
    reader: JoinHandle<()>,
    writer: JoinHandle<()>,
}

/// The TCP serving surface: a listener whose connections speak the
/// [`super::wire`] protocol and feed the shared [`Coordinator`]
/// admission path.
///
/// Per connection, a **reader** thread decodes request frames and
/// submits them (`fast_reject` flag selects `try_submit` semantics)
/// with a clone of the connection's shared reply sender; a **writer**
/// thread drains that channel and writes response/rejection frames
/// back, muxed out of order by request id. A malformed frame is
/// answered with a `ProtocolError` frame and closes *that connection
/// only* — the listener and every other connection keep serving.
///
/// [`WireServer::shutdown`] drains gracefully: stop accepting (late
/// connects are refused at the OS level), close the coordinator — which
/// waits until every admitted request has its reply — then unblock the
/// readers so the writers can flush and exit. The exactly-one-reply
/// contract holds across the wire: every request frame read before
/// shutdown gets exactly one result frame (requests racing the close
/// get an explicit `ShuttingDown` rejection).
pub struct WireServer {
    local_addr: SocketAddr,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<Conn>>>,
    accept: Option<JoinHandle<()>>,
    coordinator: Arc<Coordinator>,
    killed: bool,
}

impl WireServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"`) and start accepting.
    pub fn bind(addr: &str, coordinator: Arc<Coordinator>) -> Result<WireServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(Mutex::new(Vec::<Conn>::new()));
        let accept = {
            let stop = stop.clone();
            let conns = conns.clone();
            let coordinator = coordinator.clone();
            std::thread::Builder::new().name("morpho-accept".into()).spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            match spawn_connection(stream, coordinator.clone()) {
                                Ok(conn) => conns.lock().unwrap().push(conn),
                                Err(e) => eprintln!("morpho-accept: connection setup: {e}"),
                            }
                        }
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                            std::thread::sleep(ACCEPT_POLL);
                        }
                        Err(e) => {
                            eprintln!("morpho-accept: {e}");
                            std::thread::sleep(ACCEPT_POLL);
                        }
                    }
                    reap_finished(&conns);
                }
                // The listener drops here: late connects are refused by
                // the OS — the clean end-of-service signal.
            })?
        };
        Ok(WireServer { local_addr, stop, conns, accept: Some(accept), coordinator, killed: false })
    }

    /// The bound address (resolves `:0` ephemeral ports for clients).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Graceful drain: stop accepting, answer everything admitted, close.
    pub fn shutdown(mut self) {
        self.teardown();
    }

    /// Park the caller until `stop` goes true, then run the graceful
    /// drain ([`WireServer::shutdown`]). The listener serves on its own
    /// threads the whole time — `repro serve --listen` uses this to turn
    /// SIGINT / stdin-EOF into a drain instead of a mid-request kill.
    pub fn serve_until(self, stop: &AtomicBool) {
        while !stop.load(Ordering::Relaxed) {
            std::thread::sleep(Duration::from_millis(50));
        }
        self.shutdown();
    }

    /// Abrupt teardown — the failover harness's stand-in for a crashed
    /// backend process. **No drain**: the listener stops and every live
    /// connection's socket is shut down both ways mid-stream, so peers
    /// observe exactly what a SIGKILL'd process would give them — dead
    /// connections with requests still in flight. Connection threads are
    /// detached, not joined (they exit once the sockets error and the
    /// coordinator's in-flight reply senders drop); the coordinator
    /// itself is untouched — the caller decides its fate, as the OS
    /// would for a separate process.
    pub fn kill(mut self) {
        self.killed = true;
        self.stop.store(true, Ordering::Relaxed);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        // Dropping each Conn detaches its JoinHandles: no drain, no join.
        for c in std::mem::take(&mut *self.conns.lock().unwrap()) {
            let _ = c.stream.shutdown(Shutdown::Both);
        }
    }

    fn teardown(&mut self) {
        if self.killed {
            return;
        }
        // 1. Stop accepting; joining the accept thread drops the
        //    listener, so late connects fail fast at connect().
        self.stop.store(true, Ordering::Relaxed);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        // 2. Drain: close() waits until every admitted request has its
        //    reply on its connection channel. Requests that race the
        //    close get explicit ShuttingDown rejections from the readers.
        self.coordinator.close();
        // 3. Unblock readers (EOF on the read half). Each reader drops
        //    its reply sender; once the in-flight clones inside the
        //    coordinator are gone too, the writer drains the channel tail
        //    and exits — replies flush before the streams drop.
        let conns = std::mem::take(&mut *self.conns.lock().unwrap());
        for c in &conns {
            let _ = c.stream.shutdown(Shutdown::Read);
        }
        for c in conns {
            let _ = c.reader.join();
            let _ = c.writer.join();
        }
    }
}

impl Drop for WireServer {
    fn drop(&mut self) {
        self.teardown();
    }
}

/// Join and drop connections whose threads have both exited (clients
/// that disconnected) so a long-lived listener doesn't accumulate dead
/// handles.
fn reap_finished(conns: &Mutex<Vec<Conn>>) {
    let mut guard = conns.lock().unwrap();
    let mut i = 0;
    while i < guard.len() {
        if guard[i].reader.is_finished() && guard[i].writer.is_finished() {
            let c = guard.swap_remove(i);
            let _ = c.reader.join();
            let _ = c.writer.join();
        } else {
            i += 1;
        }
    }
}

fn spawn_connection(stream: TcpStream, coordinator: Arc<Coordinator>) -> io::Result<Conn> {
    // Accepted sockets inherit the listener's nonblocking flag on some
    // platforms; connection threads want plain blocking I/O.
    stream.set_nonblocking(false)?;
    stream.set_nodelay(true)?;
    let mut read_half = stream.try_clone()?;
    // Writes go through a mutex'd clone so the reader can emit a
    // connection-fatal ProtocolError frame without tearing a response
    // frame the writer is mid-way through.
    let write_half = Arc::new(Mutex::new(stream.try_clone()?));
    let (tx, rx) = mpsc::channel::<ServeResult>();
    let writer = {
        let write_half = write_half.clone();
        std::thread::Builder::new().name("morpho-conn-writer".into()).spawn(move || {
            while let Ok(res) = rx.recv() {
                let bytes = wire::encode_result(&res);
                let mut w = write_half.lock().unwrap();
                if wire::write_frame(&mut *w, &bytes).is_err() {
                    break; // peer gone; remaining replies are undeliverable
                }
            }
        })?
    };
    let reader = std::thread::Builder::new().name("morpho-conn-reader".into()).spawn(move || {
        reader_loop(&mut read_half, &write_half, &coordinator, tx);
    })?;
    Ok(Conn { stream, reader, writer })
}

/// Per-connection request pump: read frames until EOF or a protocol
/// error, submitting each request with a clone of this connection's
/// shared reply sender. Dropping `reply` on exit is what lets the writer
/// finish once the last in-flight result lands.
fn reader_loop(
    stream: &mut TcpStream,
    write_half: &Mutex<TcpStream>,
    coordinator: &Coordinator,
    reply: mpsc::Sender<ServeResult>,
) {
    let fatal = |code: u8, message: &str| {
        let bytes = wire::encode_protocol_error(code, message);
        let mut w = write_half.lock().unwrap();
        let _ = wire::write_frame(&mut *w, &bytes);
        let _ = w.shutdown(Shutdown::Both);
    };
    loop {
        let payload = match wire::read_frame(stream) {
            Ok(Some(p)) => p,
            Ok(None) => return, // clean EOF at a frame boundary
            Err(e) => return fatal(wire::ERR_MALFORMED, &e.to_string()),
        };
        match wire::decode_frame(&payload) {
            Ok(Frame::Request { req, fast_reject }) => {
                let submitted = if fast_reject {
                    coordinator.try_submit_request_shared(req, reply.clone())
                } else {
                    coordinator.submit_request_shared(req, reply.clone())
                };
                if let Err(rej) = submitted {
                    // Exactly one reply even when admission refuses: the
                    // rejection goes back over the same channel.
                    let _ = reply.send(Err(rej));
                }
            }
            // A health poll is answered inline on the write half (same
            // serialization as protocol errors, so a report can never
            // tear a response frame) — polls don't ride the reply
            // channel because they aren't requests and must keep
            // answering while the admission path is saturated.
            Ok(Frame::Health { seq, stats: None }) => {
                let report = wire::encode_health(seq, Some(&coordinator.health_stats()));
                let mut w = write_half.lock().unwrap();
                if wire::write_frame(&mut *w, &report).is_err() {
                    return; // peer gone mid-poll: the connection is done
                }
            }
            Ok(_) => {
                return fatal(wire::ERR_UNEXPECTED_KIND, "client sent a server-only frame kind")
            }
            Err(e) => return fatal(wire::ERR_MALFORMED, &e.to_string()),
        }
    }
}

/// Map a request's serving lane onto the queue lanes (interactive =
/// express, end to end: admission queue here, job queue in the pump).
fn lane_for(priority: Priority) -> Lane {
    match priority {
        Priority::Interactive => Lane::Express,
        Priority::Bulk => Lane::Standard,
    }
}

/// Batch-window loop: wait for a first request, give it `max_wait` to
/// attract company (or until `flush_points` accumulate), then plan jobs.
/// With `BatcherConfig::adaptive` set, the window is re-sized every
/// iteration by an [`AdaptiveWindow`] controller fed the queue-depth
/// gauge observed at window start. `stall` is the injected per-window
/// upstream delay of a chaos run (`None` on every production path).
fn pump_loop(
    submit_q: &BoundedQueue<PendingRequest>,
    job_q: &BoundedQueue<TileJob>,
    metrics: &Arc<Metrics>,
    batcher: &Batcher,
    stall: Option<Duration>,
) {
    let mut adaptive = batcher.config.adaptive.map(AdaptiveWindow::new);
    while let Some(first) = submit_q.pop() {
        if let Some(d) = stall {
            std::thread::sleep(d); // injected stalled-upstream-queue fault
        }
        let max_wait = match adaptive.as_mut() {
            // +1: the popped first request is part of the observed load.
            Some(ctl) => ctl.observe(submit_q.len() + 1),
            None => batcher.config.max_wait,
        };
        let mut window = vec![first];
        let mut points = window[0].req.points();
        let deadline = Instant::now() + max_wait;
        while points < batcher.config.flush_points {
            match submit_q.pop_until(deadline) {
                PopResult::Item(p) => {
                    points += p.req.points();
                    window.push(p);
                }
                // Window expired, or the queue closed: plan what we have
                // (a closed queue still drains admitted requests).
                PopResult::TimedOut | PopResult::Closed => break,
            }
        }
        let now = Instant::now();
        for p in &window {
            metrics.queue_wait.record(now.saturating_duration_since(p.submitted));
        }
        for job in batcher.plan(window, now, metrics) {
            let lane = if job.express { Lane::Express } else { Lane::Standard };
            if job_q.push_lane(job, lane).is_err() {
                return; // shutting down
            }
        }
    }
}

/// Worker loop: execute jobs on the backend, scatter results, and fold
/// the backend's supervision-counter deltas into the service metrics
/// (several workers share one `Metrics`, so each diffs its own backend's
/// cumulative [`super::PoolHealth`] snapshots).
fn worker_loop(job_q: &BoundedQueue<TileJob>, metrics: &Metrics, backend: &mut dyn Backend) {
    let mut last_health = backend.health().unwrap_or_default();
    while let Some(mut job) = job_q.pop() {
        let params = job.params;
        let t0 = Instant::now();
        let cycles = match backend.apply(&params, &mut job.xs, &mut job.ys) {
            Ok(c) => c,
            Err(e) => {
                metrics.backend_errors.fetch_add(1, Ordering::Relaxed);
                eprintln!("backend {} failed ({e:#}); native fallback", backend.kind().name());
                apply_native(&params, &mut job.xs, &mut job.ys);
                None
            }
        };
        let exec = t0.elapsed();
        metrics.record_job(job.points(), exec, cycles);
        job.scatter(backend.kind(), exec, cycles);
        if let Some(h) = backend.health() {
            metrics.record_pool_delta(
                h.crashes - last_health.crashes,
                h.restarts - last_health.restarts,
                h.redispatched - last_health.redispatched,
                h.recovery_max_us,
            );
            last_health = h;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::backend::BackendKind;
    use crate::testkit::{check, Rng};
    use std::time::Duration;

    fn native_coordinator() -> Coordinator {
        Coordinator::start(CoordinatorConfig {
            backend: BackendChoice::Native,
            batcher: BatcherConfig {
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn end_to_end_translate() {
        let c = native_coordinator();
        let resp = c
            .transform_blocking(
                vec![1.0, 2.0, 3.0],
                vec![4.0, 5.0, 6.0],
                vec![Transform::Translate { tx: 10.0, ty: 20.0 }],
            )
            .unwrap();
        assert_eq!(resp.xs, vec![11.0, 12.0, 13.0]);
        assert_eq!(resp.ys, vec![24.0, 25.0, 26.0]);
        assert_eq!(resp.timing.backend, BackendKind::Native);
        c.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_answered_correctly() {
        let c = Arc::new(native_coordinator());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for i in 0..20u64 {
                        let n = (t * 37 + i * 13) as usize % 300 + 1;
                        let xs: Vec<f32> = (0..n).map(|k| k as f32).collect();
                        let ys: Vec<f32> = (0..n).map(|k| -(k as f32)).collect();
                        let tx = (t % 3) as f32;
                        let resp = c
                            .transform_blocking(
                                xs.clone(),
                                ys,
                                vec![Transform::Translate { tx, ty: 1.0 }],
                            )
                            .unwrap();
                        for (k, x) in resp.xs.iter().enumerate() {
                            assert_eq!(*x, xs[k] + tx);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let m = c.metrics();
        assert_eq!(m.requests, 160);
        assert!(m.jobs > 0);
        assert!(m.backend_errors == 0);
    }

    #[test]
    fn m1sim_coordinator_reports_simulated_cycles() {
        let c = Coordinator::start(CoordinatorConfig {
            backend: BackendChoice::M1Sim,
            workers: 1,
            ..Default::default()
        })
        .unwrap();
        let resp = c
            .transform_blocking(
                vec![1.0; 64],
                vec![2.0; 64],
                vec![Transform::Translate { tx: 3.0, ty: 4.0 }],
            )
            .unwrap();
        assert_eq!(resp.timing.backend, BackendKind::M1Sim);
        assert!(resp.timing.simulated_cycles.unwrap() > 0);
        assert_eq!(resp.xs, vec![4.0; 64]);
        let m = c.metrics();
        assert!(m.simulated_cycles > 0);
        c.shutdown();
    }

    #[test]
    fn async_dma_m1sim_coordinator_matches_blocking_outputs() {
        // The §Perf PR 5 serving knob: identical transformed points, a
        // strictly smaller simulated-cycle total (DMA hidden behind
        // compute), for any shard count.
        let run = |async_dma: bool, shards: usize| {
            let c = Coordinator::start(CoordinatorConfig {
                backend: BackendChoice::M1Sim,
                workers: 1,
                m1_shards: shards,
                m1_async_dma: async_dma,
                batcher: BatcherConfig { max_wait: Duration::from_millis(1), ..Default::default() },
                ..Default::default()
            })
            .unwrap();
            let n = 500;
            let xs: Vec<f32> = (0..n).map(|i| (i as f32) - 250.0).collect();
            let ys: Vec<f32> = (0..n).map(|i| (i % 53) as f32).collect();
            let resp = c
                .transform_blocking(xs, ys, vec![Transform::Translate { tx: 3.0, ty: 4.0 }])
                .unwrap();
            c.shutdown();
            resp
        };
        let blocking = run(false, 1);
        let overlapped = run(true, 1);
        assert_eq!(blocking.xs, overlapped.xs);
        assert_eq!(blocking.ys, overlapped.ys);
        let (bc, ac) = (
            blocking.timing.simulated_cycles.unwrap(),
            overlapped.timing.simulated_cycles.unwrap(),
        );
        assert!(ac < bc, "async cycles {ac} !< blocking {bc}");
        // Sharded async equals serial async bit-for-bit.
        let sharded = run(true, 4);
        assert_eq!(overlapped.xs, sharded.xs);
        assert_eq!(overlapped.timing.simulated_cycles, sharded.timing.simulated_cycles);
    }

    #[test]
    fn sharded_m1sim_coordinator_matches_serial_responses() {
        let run = |shards: usize| {
            let c = Coordinator::start(CoordinatorConfig {
                backend: BackendChoice::M1Sim,
                workers: 1,
                m1_shards: shards,
                batcher: BatcherConfig { max_wait: Duration::from_millis(1), ..Default::default() },
                ..Default::default()
            })
            .unwrap();
            let n = 1000;
            let xs: Vec<f32> = (0..n).map(|i| (i as f32) - 500.0).collect();
            let ys: Vec<f32> = (0..n).map(|i| (i % 61) as f32).collect();
            let resp = c
                .transform_blocking(xs, ys, vec![Transform::Translate { tx: 3.0, ty: 4.0 }])
                .unwrap();
            c.shutdown();
            resp
        };
        let serial = run(1);
        let pooled = run(4);
        assert_eq!(serial.xs, pooled.xs);
        assert_eq!(serial.ys, pooled.ys);
        assert_eq!(serial.timing.simulated_cycles, pooled.timing.simulated_cycles);
        assert_eq!(pooled.timing.backend, BackendKind::M1Sim);
    }

    #[test]
    fn closed_coordinator_counts_shutdown_rejections_distinctly() {
        let c = native_coordinator();
        c.close();
        match c.try_submit(vec![1.0], vec![2.0], vec![]) {
            Err(Rejection { reason: RejectReason::ShuttingDown, .. }) => {}
            other => panic!("expected shutdown rejection, got {other:?}"),
        }
        let m = c.metrics();
        assert_eq!(m.closed, 1, "shutdown rejections get their own counter");
        assert_eq!(m.rejected, 0, "…and must not masquerade as overload");
        c.shutdown();
    }

    #[test]
    fn close_drains_every_admitted_request_before_returning() {
        let c = native_coordinator();
        let t = vec![Transform::Translate { tx: 1.0, ty: 0.0 }];
        let receivers: Vec<_> = (0..16)
            .map(|i| c.submit(vec![i as f32; 32], vec![0.0; 32], t.clone()).unwrap())
            .collect();
        c.close();
        // Graceful drain: by the time close() returns, every admitted
        // request already has its reply waiting — no recv() blocking, no
        // dropped channels.
        for (i, rx) in receivers.iter().enumerate() {
            let resp = rx.try_recv().unwrap_or_else(|_| panic!("request {i} not drained"));
            assert_eq!(resp.unwrap().xs[0], i as f32 + 1.0);
        }
        let m = c.metrics();
        assert_eq!(m.requests, 16);
        assert_eq!(m.responses, 16, "exactly one reply per admitted request");
        c.shutdown();
    }

    #[test]
    fn chaos_fault_plan_serves_bit_identical_results_with_one_reply_each() {
        // End-to-end supervision: a chaos plan injects shard panics,
        // deaths and dropped replies under the M1 backend, yet every
        // response is bit-identical to the fault-free run and every
        // request gets exactly one reply.
        let run = |faults: Option<FaultPlan>| {
            let c = Coordinator::start(CoordinatorConfig {
                backend: BackendChoice::M1Sim,
                workers: 1,
                m1_shards: 2,
                fault_plan: faults,
                batcher: BatcherConfig {
                    max_wait: Duration::from_millis(1),
                    ..Default::default()
                },
                ..Default::default()
            })
            .unwrap();
            // 2048 points = 32 tile dispatches: enough for the chaos
            // profile (panic_every ∈ [6,10]) to fire several times.
            let n = 2048;
            let xs: Vec<f32> = (0..n).map(|i| ((i % 127) as f32) - 63.0).collect();
            let ys: Vec<f32> = (0..n).map(|i| ((i % 89) as f32) - 44.0).collect();
            let resp = c
                .transform_blocking(xs, ys, vec![Transform::Translate { tx: 3.0, ty: -2.0 }])
                .unwrap();
            let m = c.metrics();
            c.shutdown();
            (resp, m)
        };
        let (clean, _) = run(None);
        let plan = FaultPlan::chaos(2024);
        let (chaotic, m) = run(Some(plan.clone()));
        assert_eq!(clean.xs, chaotic.xs, "injected faults must not change results");
        assert_eq!(clean.ys, chaotic.ys);
        assert_eq!(
            clean.timing.simulated_cycles, chaotic.timing.simulated_cycles,
            "cycle accounting is fault-independent"
        );
        assert!(plan.panics_fired() > 0, "chaos must fire over 32 dispatches");
        assert!(m.shard_crashes > 0, "worker must fold pool health into metrics");
        assert!(m.shard_restarts > 0);
        assert_eq!(m.requests, 1);
        assert_eq!(m.responses, 1);
    }

    #[test]
    fn health_poll_over_the_wire_reports_the_admission_ledger() {
        let c = Arc::new(native_coordinator());
        let server = WireServer::bind("127.0.0.1:0", c.clone()).unwrap();
        let mut stream = TcpStream::connect(server.local_addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();

        let poll_health = |stream: &mut TcpStream, seq: u64| -> wire::HealthStats {
            wire::write_frame(stream, &wire::encode_health(seq, None)).unwrap();
            let payload = wire::read_frame(stream).unwrap().expect("health report");
            match wire::decode_frame(&payload).unwrap() {
                Frame::Health { seq: got, stats: Some(stats) } => {
                    assert_eq!(got, seq, "the report echoes the poll's seq");
                    stats
                }
                other => panic!("expected health report, got {other:?}"),
            }
        };
        let before = poll_health(&mut stream, 7);
        assert_eq!(before.requests, 0);

        c.transform_blocking(
            vec![1.0, 2.0],
            vec![3.0, 4.0],
            vec![Transform::Translate { tx: 1.0, ty: 1.0 }],
        )
        .unwrap();
        let after = poll_health(&mut stream, 8);
        assert_eq!(after.requests, 1);
        assert_eq!(after.responses, 1);

        drop(stream);
        server.shutdown();
        if let Ok(c) = Arc::try_unwrap(c) {
            c.shutdown();
        }
    }

    #[test]
    fn health_report_from_a_client_is_an_unexpected_kind() {
        let c = Arc::new(native_coordinator());
        let server = WireServer::bind("127.0.0.1:0", c.clone()).unwrap();
        let mut stream = TcpStream::connect(server.local_addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        // A *report* from a client is well-formed but nonsensical: only
        // the server answers polls. Connection-fatal, typed.
        let bogus = wire::encode_health(1, Some(&wire::HealthStats::default()));
        wire::write_frame(&mut stream, &bogus).unwrap();
        let payload = wire::read_frame(&mut stream).unwrap().expect("protocol error frame");
        match wire::decode_frame(&payload).unwrap() {
            Frame::ProtocolError { code, .. } => assert_eq!(code, wire::ERR_UNEXPECTED_KIND),
            other => panic!("expected protocol error, got {other:?}"),
        }
        drop(stream);
        server.shutdown();
        if let Ok(c) = Arc::try_unwrap(c) {
            c.shutdown();
        }
    }

    #[test]
    fn serve_until_drains_when_the_flag_flips() {
        let c = Arc::new(native_coordinator());
        let server = WireServer::bind("127.0.0.1:0", c.clone()).unwrap();
        let addr = server.local_addr();
        let stop = Arc::new(AtomicBool::new(false));
        let waiter = {
            let stop = stop.clone();
            std::thread::spawn(move || server.serve_until(&stop))
        };
        // The listener keeps serving while the flag is down.
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        wire::write_frame(&mut stream, &wire::encode_health(1, None)).unwrap();
        assert!(wire::read_frame(&mut stream).unwrap().is_some());
        drop(stream);

        stop.store(true, Ordering::Relaxed);
        waiter.join().unwrap();
        // serve_until ran the graceful drain: late connects are refused.
        assert!(TcpStream::connect(addr).is_err());
        if let Ok(c) = Arc::try_unwrap(c) {
            c.shutdown();
        }
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let c = native_coordinator();
        let q = c.submit_q.clone();
        c.shutdown();
        assert!(q
            .push(PendingRequest {
                req: TransformRequest::new(9, vec![], vec![], vec![]),
                submitted: Instant::now(),
                deadline: None,
                reply: mpsc::channel().0,
            })
            .is_err());
    }

    #[test]
    fn try_submit_fast_rejects_when_queue_is_full() {
        // Saturate a 1-slot admission queue through the (deliberately
        // slow) cycle-accurate simulator backend with a blocking feeder
        // thread; try_submit offers must then observe QueueFull and
        // reject instantly instead of parking.
        let c = Arc::new(
            Coordinator::start(CoordinatorConfig {
                backend: BackendChoice::M1Sim,
                queue_capacity: 1,
                job_capacity: 1,
                workers: 1,
                batcher: BatcherConfig {
                    max_wait: Duration::from_micros(100),
                    ..Default::default()
                },
                ..Default::default()
            })
            .unwrap(),
        );
        let t = vec![Transform::Translate { tx: 1.0, ty: 1.0 }];
        let feeder = {
            let c = c.clone();
            let t = t.clone();
            std::thread::spawn(move || {
                // Blocking submits re-fill the single queue slot the
                // moment the pump drains it.
                (0..24)
                    .map(|_| c.submit(vec![1.0; 4096], vec![2.0; 4096], t.clone()).unwrap())
                    .collect::<Vec<_>>()
            })
        };
        let mut accepted = Vec::new();
        let mut rejected = 0u64;
        let deadline = Instant::now() + Duration::from_secs(10);
        while rejected == 0 && Instant::now() < deadline {
            match c.try_submit(vec![0.0; 8], vec![0.0; 8], t.clone()) {
                Ok(rx) => accepted.push(rx),
                Err(rej) => {
                    assert_eq!(rej.reason, RejectReason::QueueFull);
                    rejected += 1;
                }
            }
        }
        assert!(rejected > 0, "full queue must fast-reject");
        assert!(c.metrics().rejected >= rejected);
        // Everything admitted (either path) still completes.
        for rx in feeder.join().unwrap().into_iter().chain(accepted) {
            assert!(rx.recv().unwrap().is_ok());
        }
    }

    #[test]
    fn default_ttl_sheds_stale_requests_with_rejection() {
        // TTL far smaller than the batch window: the request expires while
        // queued and the batcher sheds it with an explicit rejection.
        let c = Coordinator::start(CoordinatorConfig {
            backend: BackendChoice::Native,
            workers: 1,
            default_ttl: Some(Duration::from_millis(1)),
            batcher: BatcherConfig {
                max_wait: Duration::from_millis(50),
                flush_points: usize::MAX,
                ..Default::default()
            },
            ..Default::default()
        })
        .unwrap();
        let rx = c
            .submit(vec![1.0; 8], vec![2.0; 8], vec![Transform::Translate { tx: 1.0, ty: 0.0 }])
            .unwrap();
        match rx.recv().unwrap() {
            Err(Rejection { reason: RejectReason::DeadlineExceeded, .. }) => {}
            other => panic!("expected deadline shed, got {other:?}"),
        }
        let m = c.metrics();
        assert_eq!(m.shed, 1);
        c.shutdown();
    }

    #[test]
    fn per_request_ttl_overrides_coordinator_default() {
        // Generous default, tiny per-request TTL: still shed.
        let c = Coordinator::start(CoordinatorConfig {
            backend: BackendChoice::Native,
            workers: 1,
            default_ttl: Some(Duration::from_secs(60)),
            batcher: BatcherConfig {
                max_wait: Duration::from_millis(50),
                flush_points: usize::MAX,
                ..Default::default()
            },
            ..Default::default()
        })
        .unwrap();
        let req = TransformRequest::new(
            1,
            vec![1.0; 8],
            vec![2.0; 8],
            vec![Transform::Translate { tx: 1.0, ty: 0.0 }],
        )
        .with_ttl(Duration::from_millis(1));
        let rx = c.submit_request(req).unwrap();
        assert!(rx.recv().unwrap().is_err(), "tiny per-request TTL must shed");
        c.shutdown();
    }

    #[test]
    fn property_random_pipelines_match_native_reference() {
        let c = native_coordinator();
        check("coordinator == native", 20, |rng: &mut Rng| {
            let n = rng.range_i64(1, 200) as usize;
            let xs: Vec<f32> = (0..n).map(|_| rng.f32_range(-50.0, 50.0)).collect();
            let ys: Vec<f32> = (0..n).map(|_| rng.f32_range(-50.0, 50.0)).collect();
            let transforms = vec![
                Transform::Rotate { theta: rng.f32_range(-3.0, 3.0) },
                Transform::Scale { sx: rng.f32_range(0.5, 2.0), sy: rng.f32_range(0.5, 2.0) },
                Transform::Translate {
                    tx: rng.f32_range(-10.0, 10.0),
                    ty: rng.f32_range(-10.0, 10.0),
                },
            ];
            let resp =
                c.transform_blocking(xs.clone(), ys.clone(), transforms.clone()).unwrap();
            let pipe = crate::graphics::TransformPipeline::new(transforms);
            let mut nx = xs;
            let mut ny = ys;
            pipe.apply_native(&mut nx, &mut ny);
            for i in 0..n {
                assert!((resp.xs[i] - nx[i]).abs() < 1e-3);
                assert!((resp.ys[i] - ny[i]).abs() < 1e-3);
            }
        });
        c.shutdown();
    }
}
