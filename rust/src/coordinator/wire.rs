//! The serving wire protocol (§Scale): versioned, length-prefixed binary
//! frames carrying [`TransformRequest`]s to the coordinator and
//! [`ServeResult`]s back — the format `repro serve --listen` speaks and
//! the loadgen TCP transport drives over loopback.
//!
//! ## Frame layout (version 1, all integers little-endian)
//!
//! ```text
//! frame   := len u32 | payload                (len = payload bytes, ≤ MAX_FRAME)
//! payload := version u8 (=1) | kind u8 | body
//!
//! kind 1 — Request (client → server):
//!   id u64 | flags u8 (bit0: fast-reject admission; bit1: bulk priority
//!   lane; other bits must be 0) |
//!   ttl: tag u8 (0 none / 1 some) [+ nanos u64] |
//!   transforms: count u32, each tag u8 + f32-bit params
//!     (1 Translate: tx ty · 2 Scale: sx sy · 3 Rotate: theta ·
//!      4 RotateAbout: theta cx cy) |
//!   points: count u32 | xs f32-bits × count | ys f32-bits × count
//!
//! kind 2 — Response (server → client):
//!   id u64 | queued_ns u64 | execute_ns u64 | backend u8
//!   (1 native / 2 xla / 3 m1sim) | cycles: tag u8 [+ u64] |
//!   points: count u32 | xs f32-bits × count | ys f32-bits × count
//!
//! kind 3 — Rejection (server → client):
//!   id u64 | reason u8 (1 queue-full / 2 deadline-exceeded / 3 shutting-down /
//!   4 unavailable)
//!
//! kind 4 — ProtocolError (server → client, then the connection closes):
//!   code u8 | message: len u32 + UTF-8
//!
//! kind 5 — Health (both directions):
//!   seq u64 | tag u8 (0 poll, empty body / 1 report + stats) |
//!   stats: queue_depth, requests, responses, shed, rejected, closed,
//!   deadline_missed, shard_crashes, shard_restarts, tiles_redispatched,
//!   recovery_max_us — 11 × u64. A poll (tag 0) asks the receiver to
//!   answer with a report (tag 1) echoing the same seq; the front-end
//!   router drives its per-backend breakers off these round-trips.
//! ```
//!
//! Every `f32` travels as its IEEE-754 bit pattern (`to_bits`), so a
//! decoded value re-encodes byte-identically — the canonical-encoding
//! property the differential transport tests pin. Decoding is strict:
//! unknown versions/kinds/tags, length mismatches and trailing bytes are
//! typed [`WireError`]s, and a frame announcing more than [`MAX_FRAME`]
//! bytes is refused before any allocation. A malformed frame is a
//! connection-fatal protocol error: the server answers with a `kind 4`
//! frame and closes **that connection only**.

use std::io::{self, Read, Write};
use std::time::Duration;

use crate::graphics::Transform;

use super::backend::BackendKind;
use super::request::{
    Priority, RejectReason, Rejection, RequestTiming, ServeResult, TransformRequest,
    TransformResponse,
};

/// Wire protocol version carried in every frame.
pub const WIRE_VERSION: u8 = 1;

/// Hard ceiling on a frame's payload size. The largest legitimate frame
/// (a 4096-point response) is ~32 KiB; anything claiming more than this
/// is corruption or abuse and is refused before allocation.
pub const MAX_FRAME: usize = 1 << 20;

const KIND_REQUEST: u8 = 1;
const KIND_RESPONSE: u8 = 2;
const KIND_REJECTION: u8 = 3;
const KIND_PROTOCOL_ERROR: u8 = 4;
const KIND_HEALTH: u8 = 5;

/// ProtocolError code: the frame could not be read or decoded.
pub const ERR_MALFORMED: u8 = 1;
/// ProtocolError code: a well-formed frame of a kind the receiver does
/// not accept (e.g. a client sending a server-only Response).
pub const ERR_UNEXPECTED_KIND: u8 = 2;

/// Why a frame could not be read or decoded. Any variant is fatal for
/// the connection that produced it (the stream offset is unrecoverable),
/// but never for the listener or for other connections.
#[derive(Debug)]
pub enum WireError {
    /// The underlying socket/stream failed.
    Io(io::Error),
    /// EOF or end-of-buffer in the middle of a frame.
    Truncated { context: &'static str },
    /// The length prefix announced more than [`MAX_FRAME`] bytes.
    Oversized { announced: usize },
    /// The frame's version byte is not [`WIRE_VERSION`].
    BadVersion { found: u8 },
    /// Unknown frame kind byte.
    BadKind { found: u8 },
    /// Unknown enum tag (transform kind, backend, rejection reason, …).
    BadTag { what: &'static str, found: u8 },
    /// The payload decoded cleanly but bytes were left over.
    TrailingBytes { count: usize },
    /// A declared element count is implausible for the payload size.
    BadCount { what: &'static str, count: usize },
    /// A string field is not UTF-8.
    BadUtf8,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire i/o error: {e}"),
            WireError::Truncated { context } => write!(f, "truncated frame ({context})"),
            WireError::Oversized { announced } => {
                write!(f, "oversized frame: {announced} bytes announced (max {MAX_FRAME})")
            }
            WireError::BadVersion { found } => {
                write!(f, "unsupported wire version {found} (this build speaks {WIRE_VERSION})")
            }
            WireError::BadKind { found } => write!(f, "unknown frame kind {found}"),
            WireError::BadTag { what, found } => write!(f, "unknown {what} tag {found}"),
            WireError::TrailingBytes { count } => {
                write!(f, "{count} trailing bytes after frame payload")
            }
            WireError::BadCount { what, count } => {
                write!(f, "implausible {what} count {count} for frame size")
            }
            WireError::BadUtf8 => write!(f, "string field is not UTF-8"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> WireError {
        WireError::Io(e)
    }
}

/// A decoded frame.
#[derive(Debug, Clone)]
pub enum Frame {
    /// A client request plus its admission discipline: `fast_reject`
    /// asks for `try_submit` semantics (instant [`Rejection`] on a full
    /// queue) instead of blocking backpressure.
    Request { req: TransformRequest, fast_reject: bool },
    /// The exactly-one reply for an accepted request frame: a response
    /// or an explicit rejection.
    Result(ServeResult),
    /// Connection-fatal protocol error report; the sender closes the
    /// connection after this frame.
    ProtocolError { code: u8, message: String },
    /// Health poll (`stats: None`) or report (`stats: Some`). The poller
    /// sends an empty-bodied poll; the receiver answers with a report
    /// echoing the same `seq`, so a poller can match replies to polls
    /// and time out the ones that never come back.
    Health { seq: u64, stats: Option<HealthStats> },
}

/// The kind-5 health report body: a coordinator's live admission ledger
/// plus its pool-supervision counters, all cumulative except
/// `queue_depth` (an instantaneous gauge). The router reads
/// `queue_depth` for least-loaded backend choice and sums the rest into
/// the cluster-wide snapshot [`Router::metrics`] reports.
///
/// [`Router::metrics`]: super::Router::metrics
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct HealthStats {
    /// Requests admitted but not yet answered (gauge).
    pub queue_depth: u64,
    /// Requests admitted past the door, cumulative.
    pub requests: u64,
    /// Replies delivered (responses + shed rejections), cumulative.
    pub responses: u64,
    /// Admitted requests shed at their TTL deadline, cumulative.
    pub shed: u64,
    /// Requests refused at the door (queue full / shutting down), cumulative.
    pub rejected: u64,
    /// Connections the serving tier has closed, cumulative.
    pub closed: u64,
    /// TTL deadlines observed missed at dispatch, cumulative.
    pub deadline_missed: u64,
    /// Supervised shard crashes healed by the tile pool, cumulative.
    pub shard_crashes: u64,
    /// Shard warm-restarts performed, cumulative.
    pub shard_restarts: u64,
    /// Tiles re-dispatched after a shard death, cumulative.
    pub tiles_redispatched: u64,
    /// Slowest single shard recovery observed, microseconds (gauge).
    pub recovery_max_us: u64,
}

// ── encoding ───────────────────────────────────────────────────────────

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_points(out: &mut Vec<u8>, xs: &[f32], ys: &[f32]) {
    debug_assert_eq!(xs.len(), ys.len());
    out.extend_from_slice(&(xs.len() as u32).to_le_bytes());
    for &x in xs {
        put_f32(out, x);
    }
    for &y in ys {
        put_f32(out, y);
    }
}

fn put_transform(out: &mut Vec<u8>, t: &Transform) {
    match *t {
        Transform::Translate { tx, ty } => {
            out.push(1);
            put_f32(out, tx);
            put_f32(out, ty);
        }
        Transform::Scale { sx, sy } => {
            out.push(2);
            put_f32(out, sx);
            put_f32(out, sy);
        }
        Transform::Rotate { theta } => {
            out.push(3);
            put_f32(out, theta);
        }
        Transform::RotateAbout { theta, cx, cy } => {
            out.push(4);
            put_f32(out, theta);
            put_f32(out, cx);
            put_f32(out, cy);
        }
    }
}

fn backend_tag(kind: BackendKind) -> u8 {
    match kind {
        BackendKind::Native => 1,
        BackendKind::Xla => 2,
        BackendKind::M1Sim => 3,
    }
}

fn reason_tag(reason: RejectReason) -> u8 {
    match reason {
        RejectReason::QueueFull => 1,
        RejectReason::DeadlineExceeded => 2,
        RejectReason::ShuttingDown => 3,
        RejectReason::Unavailable => 4,
    }
}

fn duration_ns(d: Duration) -> u64 {
    u64::try_from(d.as_nanos()).unwrap_or(u64::MAX)
}

/// Wrap a finished payload in the length prefix.
fn finish(payload: Vec<u8>) -> Vec<u8> {
    debug_assert!(payload.len() <= MAX_FRAME);
    let mut out = Vec::with_capacity(4 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

fn header(kind: u8) -> Vec<u8> {
    vec![WIRE_VERSION, kind]
}

/// Encode a request frame (length prefix included).
pub fn encode_request(req: &TransformRequest, fast_reject: bool) -> Vec<u8> {
    let mut p = header(KIND_REQUEST);
    p.extend_from_slice(&req.id.to_le_bytes());
    let mut flags = fast_reject as u8;
    if req.priority == Priority::Bulk {
        flags |= 2;
    }
    p.push(flags);
    match req.ttl {
        None => p.push(0),
        Some(ttl) => {
            p.push(1);
            p.extend_from_slice(&duration_ns(ttl).to_le_bytes());
        }
    }
    p.extend_from_slice(&(req.transforms.len() as u32).to_le_bytes());
    for t in &req.transforms {
        put_transform(&mut p, t);
    }
    put_points(&mut p, &req.xs, &req.ys);
    finish(p)
}

/// Encode a result frame — response or rejection (length prefix included).
pub fn encode_result(res: &ServeResult) -> Vec<u8> {
    let mut p;
    match res {
        Ok(resp) => {
            p = header(KIND_RESPONSE);
            p.extend_from_slice(&resp.id.to_le_bytes());
            p.extend_from_slice(&duration_ns(resp.timing.queued).to_le_bytes());
            p.extend_from_slice(&duration_ns(resp.timing.execute).to_le_bytes());
            p.push(backend_tag(resp.timing.backend));
            match resp.timing.simulated_cycles {
                None => p.push(0),
                Some(c) => {
                    p.push(1);
                    p.extend_from_slice(&c.to_le_bytes());
                }
            }
            put_points(&mut p, &resp.xs, &resp.ys);
        }
        Err(rej) => {
            p = header(KIND_REJECTION);
            p.extend_from_slice(&rej.id.to_le_bytes());
            p.push(reason_tag(rej.reason));
        }
    }
    finish(p)
}

/// Encode a health frame (length prefix included): a poll when `stats`
/// is `None`, a report when `Some`.
pub fn encode_health(seq: u64, stats: Option<&HealthStats>) -> Vec<u8> {
    let mut p = header(KIND_HEALTH);
    p.extend_from_slice(&seq.to_le_bytes());
    match stats {
        None => p.push(0),
        Some(s) => {
            p.push(1);
            for v in [
                s.queue_depth,
                s.requests,
                s.responses,
                s.shed,
                s.rejected,
                s.closed,
                s.deadline_missed,
                s.shard_crashes,
                s.shard_restarts,
                s.tiles_redispatched,
                s.recovery_max_us,
            ] {
                p.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
    finish(p)
}

/// Encode a connection-fatal protocol-error frame (length prefix included).
pub fn encode_protocol_error(code: u8, message: &str) -> Vec<u8> {
    let mut p = header(KIND_PROTOCOL_ERROR);
    p.push(code);
    let mut cut = message.len().min(512);
    while !message.is_char_boundary(cut) {
        cut -= 1;
    }
    let msg = &message.as_bytes()[..cut];
    p.extend_from_slice(&(msg.len() as u32).to_le_bytes());
    p.extend_from_slice(msg);
    finish(p)
}

// ── decoding ───────────────────────────────────────────────────────────

struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize, context: &'static str) -> Result<&'a [u8], WireError> {
        match self.pos.checked_add(n).filter(|&e| e <= self.bytes.len()) {
            Some(end) => {
                let s = &self.bytes[self.pos..end];
                self.pos = end;
                Ok(s)
            }
            None => Err(WireError::Truncated { context }),
        }
    }

    fn u8(&mut self, context: &'static str) -> Result<u8, WireError> {
        Ok(self.take(1, context)?[0])
    }

    fn u32(&mut self, context: &'static str) -> Result<usize, WireError> {
        Ok(u32::from_le_bytes(self.take(4, context)?.try_into().unwrap()) as usize)
    }

    fn u64(&mut self, context: &'static str) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8, context)?.try_into().unwrap()))
    }

    fn f32(&mut self, context: &'static str) -> Result<f32, WireError> {
        Ok(f32::from_bits(u32::from_le_bytes(self.take(4, context)?.try_into().unwrap())))
    }

    /// A count whose elements each occupy at least `elem_bytes` of the
    /// remaining payload — rejects counts a corrupt frame cannot hold.
    fn count(&mut self, elem_bytes: usize, what: &'static str) -> Result<usize, WireError> {
        let count = self.u32(what)?;
        if count.saturating_mul(elem_bytes) > self.bytes.len() - self.pos {
            return Err(WireError::BadCount { what, count });
        }
        Ok(count)
    }

    fn remaining(&self) -> usize {
        self.bytes.len() - self.pos
    }
}

fn read_points(c: &mut Cursor) -> Result<(Vec<f32>, Vec<f32>), WireError> {
    let n = c.count(8, "points")?;
    let mut xs = Vec::with_capacity(n);
    for _ in 0..n {
        xs.push(c.f32("xs")?);
    }
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        ys.push(c.f32("ys")?);
    }
    Ok((xs, ys))
}

fn read_transform(c: &mut Cursor) -> Result<Transform, WireError> {
    match c.u8("transform tag")? {
        1 => Ok(Transform::Translate { tx: c.f32("tx")?, ty: c.f32("ty")? }),
        2 => Ok(Transform::Scale { sx: c.f32("sx")?, sy: c.f32("sy")? }),
        3 => Ok(Transform::Rotate { theta: c.f32("theta")? }),
        4 => Ok(Transform::RotateAbout {
            theta: c.f32("theta")?,
            cx: c.f32("cx")?,
            cy: c.f32("cy")?,
        }),
        found => Err(WireError::BadTag { what: "transform", found }),
    }
}

/// Decode one frame payload (the bytes after the length prefix).
pub fn decode_frame(payload: &[u8]) -> Result<Frame, WireError> {
    let mut c = Cursor { bytes: payload, pos: 0 };
    let version = c.u8("version")?;
    if version != WIRE_VERSION {
        return Err(WireError::BadVersion { found: version });
    }
    let kind = c.u8("kind")?;
    let frame = match kind {
        KIND_REQUEST => {
            let id = c.u64("id")?;
            let flags = c.u8("flags")?;
            if flags & !3 != 0 {
                return Err(WireError::BadTag { what: "request flags", found: flags });
            }
            let ttl = match c.u8("ttl tag")? {
                0 => None,
                1 => Some(Duration::from_nanos(c.u64("ttl")?)),
                found => return Err(WireError::BadTag { what: "ttl", found }),
            };
            let n_transforms = c.count(5, "transforms")?;
            let mut transforms = Vec::with_capacity(n_transforms);
            for _ in 0..n_transforms {
                transforms.push(read_transform(&mut c)?);
            }
            let (xs, ys) = read_points(&mut c)?;
            let priority =
                if flags & 2 != 0 { Priority::Bulk } else { Priority::Interactive };
            Frame::Request {
                req: TransformRequest { id, xs, ys, transforms, ttl, priority },
                fast_reject: flags & 1 != 0,
            }
        }
        KIND_RESPONSE => {
            let id = c.u64("id")?;
            let queued = Duration::from_nanos(c.u64("queued")?);
            let execute = Duration::from_nanos(c.u64("execute")?);
            let backend = match c.u8("backend tag")? {
                1 => BackendKind::Native,
                2 => BackendKind::Xla,
                3 => BackendKind::M1Sim,
                found => return Err(WireError::BadTag { what: "backend", found }),
            };
            let simulated_cycles = match c.u8("cycles tag")? {
                0 => None,
                1 => Some(c.u64("cycles")?),
                found => return Err(WireError::BadTag { what: "cycles", found }),
            };
            let (xs, ys) = read_points(&mut c)?;
            Frame::Result(Ok(TransformResponse {
                id,
                xs,
                ys,
                timing: RequestTiming { queued, execute, backend, simulated_cycles },
            }))
        }
        KIND_REJECTION => {
            let id = c.u64("id")?;
            let reason = match c.u8("reason tag")? {
                1 => RejectReason::QueueFull,
                2 => RejectReason::DeadlineExceeded,
                3 => RejectReason::ShuttingDown,
                4 => RejectReason::Unavailable,
                found => return Err(WireError::BadTag { what: "rejection reason", found }),
            };
            Frame::Result(Err(Rejection { id, reason }))
        }
        KIND_PROTOCOL_ERROR => {
            let code = c.u8("error code")?;
            let len = c.count(1, "error message")?;
            let message = std::str::from_utf8(c.take(len, "error message")?)
                .map_err(|_| WireError::BadUtf8)?
                .to_string();
            Frame::ProtocolError { code, message }
        }
        KIND_HEALTH => {
            let seq = c.u64("health seq")?;
            let stats = match c.u8("health tag")? {
                0 => None,
                1 => Some(HealthStats {
                    queue_depth: c.u64("queue_depth")?,
                    requests: c.u64("requests")?,
                    responses: c.u64("responses")?,
                    shed: c.u64("shed")?,
                    rejected: c.u64("rejected")?,
                    closed: c.u64("closed")?,
                    deadline_missed: c.u64("deadline_missed")?,
                    shard_crashes: c.u64("shard_crashes")?,
                    shard_restarts: c.u64("shard_restarts")?,
                    tiles_redispatched: c.u64("tiles_redispatched")?,
                    recovery_max_us: c.u64("recovery_max_us")?,
                }),
                found => return Err(WireError::BadTag { what: "health", found }),
            };
            Frame::Health { seq, stats }
        }
        found => return Err(WireError::BadKind { found }),
    };
    if c.remaining() != 0 {
        return Err(WireError::TrailingBytes { count: c.remaining() });
    }
    Ok(frame)
}

/// Re-encode a decoded frame. Decoding is canonical: for any byte string
/// that decodes, `encode(decode(bytes)) == bytes` (pinned by the wire
/// property tests) — so a bit flip either fails to decode or produces a
/// *different* frame, never a silent alias.
pub fn encode_frame(frame: &Frame) -> Vec<u8> {
    match frame {
        Frame::Request { req, fast_reject } => encode_request(req, *fast_reject),
        Frame::Result(res) => encode_result(res),
        Frame::ProtocolError { code, message } => encode_protocol_error(*code, message),
        Frame::Health { seq, stats } => encode_health(*seq, stats.as_ref()),
    }
}

// ── stream I/O ─────────────────────────────────────────────────────────

/// Read one frame payload from a stream. `Ok(None)` is a clean EOF at a
/// frame boundary (the peer closed); EOF mid-frame is
/// [`WireError::Truncated`], and an announced length beyond
/// [`MAX_FRAME`] is refused before any allocation.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>, WireError> {
    let mut len_buf = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => return Err(WireError::Truncated { context: "length prefix" }),
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(WireError::Oversized { announced: len });
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            WireError::Truncated { context: "payload" }
        } else {
            WireError::Io(e)
        }
    })?;
    Ok(Some(payload))
}

/// Write pre-encoded frame bytes (as produced by the `encode_*` helpers).
pub fn write_frame(w: &mut impl Write, frame_bytes: &[u8]) -> io::Result<()> {
    w.write_all(frame_bytes)?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_request() -> TransformRequest {
        TransformRequest {
            id: 42,
            xs: vec![1.0, -2.5, f32::MIN_POSITIVE],
            ys: vec![0.0, 3.25, -0.0],
            transforms: vec![
                Transform::Translate { tx: 1.0, ty: -2.0 },
                Transform::RotateAbout { theta: 0.5, cx: 3.0, cy: 4.0 },
            ],
            ttl: Some(Duration::from_micros(1500)),
            priority: Priority::Interactive,
        }
    }

    #[test]
    fn request_roundtrips_through_the_frame_layer() {
        let req = sample_request();
        let bytes = encode_request(&req, true);
        let payload = read_frame(&mut &bytes[..]).unwrap().unwrap();
        match decode_frame(&payload).unwrap() {
            Frame::Request { req: back, fast_reject } => {
                assert!(fast_reject);
                assert_eq!(back.id, req.id);
                assert_eq!(back.ttl, req.ttl);
                assert_eq!(back.priority, req.priority);
                assert_eq!(back.transforms, req.transforms);
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&back.xs), bits(&req.xs));
                assert_eq!(bits(&back.ys), bits(&req.ys));
            }
            other => panic!("expected request frame, got {other:?}"),
        }
        // Canonical: re-encoding reproduces the wire bytes exactly.
        let payload2 = read_frame(&mut &bytes[..]).unwrap().unwrap();
        assert_eq!(encode_frame(&decode_frame(&payload2).unwrap()), bytes);
    }

    #[test]
    fn bulk_priority_rides_flags_bit1_and_roundtrips() {
        let req = sample_request().with_priority(Priority::Bulk);
        let bytes = encode_request(&req, false);
        // Payload layout: len u32 | version | kind | id u64 | flags — the
        // flags byte sits at offset 4 + 2 + 8.
        assert_eq!(bytes[14], 2, "bulk priority is flags bit 1");
        let payload = read_frame(&mut &bytes[..]).unwrap().unwrap();
        match decode_frame(&payload).unwrap() {
            Frame::Request { req: back, fast_reject } => {
                assert!(!fast_reject);
                assert_eq!(back.priority, Priority::Bulk);
            }
            other => panic!("expected request frame, got {other:?}"),
        }
        // Both bits together stay canonical.
        let both = encode_request(&req, true);
        assert_eq!(both[14], 3);
        let payload = read_frame(&mut &both[..]).unwrap().unwrap();
        assert_eq!(encode_frame(&decode_frame(&payload).unwrap()), both);
    }

    #[test]
    fn results_roundtrip_both_variants() {
        let ok: ServeResult = Ok(TransformResponse {
            id: 7,
            xs: vec![9.5],
            ys: vec![-1.5],
            timing: RequestTiming {
                queued: Duration::from_nanos(1234),
                execute: Duration::from_nanos(567_890),
                backend: BackendKind::M1Sim,
                simulated_cycles: Some(314),
            },
        });
        let rej: ServeResult = Err(Rejection { id: 8, reason: RejectReason::DeadlineExceeded });
        for res in [ok, rej] {
            let bytes = encode_result(&res);
            let payload = read_frame(&mut &bytes[..]).unwrap().unwrap();
            match decode_frame(&payload).unwrap() {
                Frame::Result(back) => match (&res, &back) {
                    (Ok(a), Ok(b)) => {
                        assert_eq!(a.id, b.id);
                        assert_eq!(a.timing.queued, b.timing.queued);
                        assert_eq!(a.timing.execute, b.timing.execute);
                        assert_eq!(a.timing.backend, b.timing.backend);
                        assert_eq!(a.timing.simulated_cycles, b.timing.simulated_cycles);
                        assert_eq!(a.xs, b.xs);
                        assert_eq!(a.ys, b.ys);
                    }
                    (Err(a), Err(b)) => assert_eq!(a, b),
                    _ => panic!("variant flipped in transit"),
                },
                other => panic!("expected result frame, got {other:?}"),
            }
        }
    }

    #[test]
    fn protocol_error_roundtrips_and_truncates_long_messages() {
        let bytes = encode_protocol_error(3, &"x".repeat(2000));
        let payload = read_frame(&mut &bytes[..]).unwrap().unwrap();
        match decode_frame(&payload).unwrap() {
            Frame::ProtocolError { code: 3, message } => assert_eq!(message.len(), 512),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn clean_eof_vs_truncation_are_distinguished() {
        assert!(read_frame(&mut &[][..]).unwrap().is_none(), "empty stream is clean EOF");
        let bytes = encode_request(&sample_request(), false);
        for cut in [1, 3, 5, bytes.len() - 1] {
            match read_frame(&mut &bytes[..cut]) {
                Err(WireError::Truncated { .. }) => {}
                other => panic!("cut at {cut}: expected truncation, got {other:?}"),
            }
        }
    }

    #[test]
    fn oversized_length_prefix_is_refused_before_allocation() {
        let mut bytes = ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
        bytes.extend_from_slice(&[0; 16]);
        match read_frame(&mut &bytes[..]) {
            Err(WireError::Oversized { announced }) => assert_eq!(announced, MAX_FRAME + 1),
            other => panic!("expected oversized, got {other:?}"),
        }
    }

    #[test]
    fn unknown_version_kind_and_tags_are_typed_errors() {
        assert!(matches!(decode_frame(&[9, 1]), Err(WireError::BadVersion { found: 9 })));
        assert!(matches!(decode_frame(&[WIRE_VERSION, 99]), Err(WireError::BadKind { found: 99 })));
        let mut p = vec![WIRE_VERSION, KIND_REJECTION];
        p.extend_from_slice(&5u64.to_le_bytes());
        p.push(77); // unknown rejection reason
        assert!(matches!(decode_frame(&p), Err(WireError::BadTag { .. })));
        // Undefined request-flag bits are rejected, not ignored — ignoring
        // them would let a flipped bit alias the canonical encoding.
        // (Bits 0 and 1 are defined: fast-reject and bulk priority.)
        let mut q = vec![WIRE_VERSION, KIND_REQUEST];
        q.extend_from_slice(&5u64.to_le_bytes());
        q.push(4); // flags: undefined bit 2
        assert!(matches!(
            decode_frame(&q),
            Err(WireError::BadTag { what: "request flags", found: 4 })
        ));
    }

    #[test]
    fn health_poll_and_report_roundtrip_canonically() {
        let poll = encode_health(17, None);
        let payload = read_frame(&mut &poll[..]).unwrap().unwrap();
        match decode_frame(&payload).unwrap() {
            Frame::Health { seq: 17, stats: None } => {}
            other => panic!("expected health poll, got {other:?}"),
        }
        assert_eq!(encode_frame(&decode_frame(&payload).unwrap()), poll);

        let stats = HealthStats {
            queue_depth: 3,
            requests: 100,
            responses: 97,
            shed: 2,
            rejected: 5,
            closed: 1,
            deadline_missed: 2,
            shard_crashes: 4,
            shard_restarts: 4,
            tiles_redispatched: 9,
            recovery_max_us: 1234,
        };
        let report = encode_health(18, Some(&stats));
        let payload = read_frame(&mut &report[..]).unwrap().unwrap();
        match decode_frame(&payload).unwrap() {
            Frame::Health { seq: 18, stats: Some(back) } => assert_eq!(back, stats),
            other => panic!("expected health report, got {other:?}"),
        }
        assert_eq!(encode_frame(&decode_frame(&payload).unwrap()), report);
    }

    #[test]
    fn health_report_with_bad_tag_or_truncated_stats_is_rejected() {
        let mut p = vec![WIRE_VERSION, KIND_HEALTH];
        p.extend_from_slice(&9u64.to_le_bytes());
        p.push(7); // unknown health tag
        assert!(matches!(decode_frame(&p), Err(WireError::BadTag { what: "health", found: 7 })));

        let full = encode_health(9, Some(&HealthStats::default()));
        let payload = read_frame(&mut &full[..]).unwrap().unwrap();
        // Cutting any suffix off the stats block is a typed truncation.
        assert!(matches!(
            decode_frame(&payload[..payload.len() - 1]),
            Err(WireError::Truncated { .. })
        ));
    }

    #[test]
    fn unavailable_rejection_roundtrips() {
        let bytes = encode_result(&Err(Rejection { id: 12, reason: RejectReason::Unavailable }));
        let payload = read_frame(&mut &bytes[..]).unwrap().unwrap();
        match decode_frame(&payload).unwrap() {
            Frame::Result(Err(rej)) => {
                assert_eq!(rej.reason, RejectReason::Unavailable);
                assert_eq!(rej.id, 12);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(encode_frame(&decode_frame(&payload).unwrap()), bytes);
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let bytes = encode_result(&Err(Rejection { id: 1, reason: RejectReason::QueueFull }));
        let mut payload = read_frame(&mut &bytes[..]).unwrap().unwrap();
        payload.push(0);
        assert!(matches!(decode_frame(&payload), Err(WireError::TrailingBytes { count: 1 })));
    }

    #[test]
    fn implausible_counts_are_rejected_without_allocation() {
        // A request frame claiming u32::MAX points in a tiny payload.
        let mut p = vec![WIRE_VERSION, KIND_REQUEST];
        p.extend_from_slice(&1u64.to_le_bytes());
        p.push(0); // flags
        p.push(0); // no ttl
        p.extend_from_slice(&0u32.to_le_bytes()); // no transforms
        p.extend_from_slice(&u32::MAX.to_le_bytes()); // absurd point count
        assert!(matches!(decode_frame(&p), Err(WireError::BadCount { .. })));
    }
}
