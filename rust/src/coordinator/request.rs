//! Request/response types of the transform service.

use std::time::{Duration, Instant};

use crate::graphics::{Transform, TransformPipeline};

use super::backend::BackendKind;

/// Serving lane of a request. Interactive traffic rides the express lane
/// end to end — admission queue, batch planning order, job dispatch —
/// and is the last to be shed; bulk traffic yields at every one of those
/// points, so a burst of bulk work cannot push interactive requests past
/// their TTLs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-sensitive traffic (the default): planned first, shed last.
    Interactive,
    /// Throughput traffic: yields the admission queue and the batch
    /// window to interactive requests, and is the first lane shed under
    /// congestion.
    Bulk,
}

/// A client request: apply a transform sequence to a point set.
#[derive(Debug, Clone)]
pub struct TransformRequest {
    pub id: u64,
    pub xs: Vec<f32>,
    pub ys: Vec<f32>,
    pub transforms: Vec<Transform>,
    /// Optional time budget measured from submission. A request still
    /// waiting in the admission queue when its budget expires is shed by
    /// the batcher (the client receives a [`Rejection`] with
    /// [`RejectReason::DeadlineExceeded`] instead of silently stale
    /// results). `None` falls back to the coordinator's configured
    /// default, if any.
    pub ttl: Option<Duration>,
    /// Serving lane; [`Priority::Interactive`] unless tagged otherwise.
    pub priority: Priority,
}

impl TransformRequest {
    pub fn new(id: u64, xs: Vec<f32>, ys: Vec<f32>, transforms: Vec<Transform>) -> Self {
        assert_eq!(xs.len(), ys.len(), "xs/ys must be parallel");
        TransformRequest { id, xs, ys, transforms, ttl: None, priority: Priority::Interactive }
    }

    /// Attach a per-request deadline budget (see [`TransformRequest::ttl`]).
    pub fn with_ttl(mut self, ttl: Duration) -> Self {
        self.ttl = Some(ttl);
        self
    }

    /// Tag the request's serving lane (see [`Priority`]).
    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    pub fn points(&self) -> usize {
        self.xs.len()
    }

    /// The composed affine parameters `[a, b, c, d, tx, ty]` — the
    /// batcher's grouping key and the artifact's runtime input.
    pub fn affine_params(&self) -> [f32; 6] {
        let m = TransformPipeline::new(self.transforms.clone()).matrix();
        let [a, b, c, d] = m.linear();
        let (tx, ty) = m.translation();
        [a, b, c, d, tx, ty]
    }

    /// Bitwise grouping key over the composed parameters (batching only
    /// merges requests whose transforms are *identical*).
    pub fn batch_key(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a over param bits
        for p in self.affine_params() {
            h ^= p.to_bits() as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        h
    }
}

/// Per-request service timing.
#[derive(Debug, Clone, Copy)]
pub struct RequestTiming {
    /// Queue wait (submit → batch formation).
    pub queued: Duration,
    /// Backend execution (batch dispatch → completion).
    pub execute: Duration,
    /// Which backend served it.
    pub backend: BackendKind,
    /// Simulated M1 cycles (M1Sim backend only).
    pub simulated_cycles: Option<u64>,
}

/// The service's reply.
#[derive(Debug, Clone)]
pub struct TransformResponse {
    pub id: u64,
    pub xs: Vec<f32>,
    pub ys: Vec<f32>,
    pub timing: RequestTiming,
}

/// Why the service refused (or shed) a request instead of serving it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// `try_submit` fast-reject: the admission queue was full.
    QueueFull,
    /// The request's deadline expired before a batch picked it up.
    DeadlineExceeded,
    /// The coordinator is shutting down.
    ShuttingDown,
    /// No backend could take the request — the front-end router had
    /// every coordinator marked dead (or exhausted its redispatch
    /// budget). Clients get this immediately instead of hanging.
    Unavailable,
}

/// An explicit negative reply: the request was admitted (or offered) but
/// will not be executed. Every admitted request receives exactly one
/// [`ServeResult`] — a rejection is a message, never a dropped channel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Rejection {
    pub id: u64,
    pub reason: RejectReason,
}

/// What arrives on a request's reply channel.
pub type ServeResult = std::result::Result<TransformResponse, Rejection>;

/// Internal: a request annotated with its submit time, absolute deadline
/// (from the request's or the coordinator's TTL) and reply channel.
pub(crate) struct PendingRequest {
    pub req: TransformRequest,
    pub submitted: Instant,
    pub deadline: Option<Instant>,
    pub reply: std::sync::mpsc::Sender<ServeResult>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_key_groups_identical_transforms() {
        let t = vec![Transform::Translate { tx: 1.0, ty: 2.0 }];
        let a = TransformRequest::new(1, vec![0.0], vec![0.0], t.clone());
        let b = TransformRequest::new(2, vec![5.0], vec![6.0], t);
        assert_eq!(a.batch_key(), b.batch_key());
        let c = TransformRequest::new(
            3,
            vec![0.0],
            vec![0.0],
            vec![Transform::Translate { tx: 1.0, ty: 2.5 }],
        );
        assert_ne!(a.batch_key(), c.batch_key());
    }

    #[test]
    fn affine_params_compose() {
        let r = TransformRequest::new(
            1,
            vec![],
            vec![],
            vec![
                Transform::Scale { sx: 2.0, sy: 2.0 },
                Transform::Translate { tx: 1.0, ty: 0.0 },
            ],
        );
        assert_eq!(r.affine_params(), [2.0, 0.0, 0.0, 2.0, 1.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "parallel")]
    fn mismatched_coords_rejected() {
        TransformRequest::new(1, vec![0.0], vec![], vec![]);
    }

    #[test]
    fn ttl_defaults_to_none_and_builds() {
        let r = TransformRequest::new(1, vec![0.0], vec![0.0], vec![]);
        assert_eq!(r.ttl, None);
        let r = r.with_ttl(Duration::from_millis(5));
        assert_eq!(r.ttl, Some(Duration::from_millis(5)));
    }

    #[test]
    fn priority_defaults_to_interactive_and_builds() {
        let r = TransformRequest::new(1, vec![0.0], vec![0.0], vec![]);
        assert_eq!(r.priority, Priority::Interactive);
        let r = r.with_priority(Priority::Bulk);
        assert_eq!(r.priority, Priority::Bulk);
        // Priority does not change the batching key: a bulk request with
        // the same transform can still share an interactive request's tile.
        let a = TransformRequest::new(2, vec![0.0], vec![0.0], vec![]);
        assert_eq!(r.batch_key(), a.batch_key());
    }
}
