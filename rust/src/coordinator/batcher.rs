//! Dynamic batching: group pending requests by (identical) transform,
//! pack their points into tile-sized backend jobs, and scatter results
//! back — the serving technique that lets many small transform requests
//! share one artifact execution, exactly as the M1 amortized one context
//! word over many data broadcasts.
//!
//! Batching composes with the megakernel tier (§Perf): an M1-backed job
//! cut here executes its runs of full 64-point tiles as one plan-level
//! megakernel keyed on `(transform shape, points)` — so same-shape jobs,
//! within a window and across windows, share a single compiled schedule
//! from the process-wide megakernel cache.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::mapping::streamed::TILE as M1_TILE;

use super::backend::BackendKind;
use super::metrics::Metrics;
use super::request::{
    PendingRequest, Priority, RejectReason, Rejection, RequestTiming, ServeResult,
    TransformResponse,
};

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Max time the first request of a batch window waits for company.
    /// When `adaptive` is set this is only the *initial* window; the
    /// controller then re-sizes it every window from the queue-depth
    /// gauge.
    pub max_wait: Duration,
    /// Flush the window once this many points are pending. Also the
    /// congestion threshold of the weighted shed path: a window carrying
    /// more points than this is congested, and near-deadline bulk
    /// requests are shed preemptively instead of clogging the tile jobs
    /// ahead of interactive traffic.
    pub flush_points: usize,
    /// Largest tile a single backend job may carry (points). A value
    /// that is not a multiple of the M1 tile size (64) is rounded **down**
    /// to whole tiles by [`Batcher::new`] (with a minimum of one tile),
    /// so backend jobs never carry a ragged tail the simulator would pad
    /// on every job instead of only on the final one.
    pub max_tile: usize,
    /// Adaptive window sizing. `None` keeps the static `max_wait`;
    /// `Some` lets an [`AdaptiveWindow`] controller widen the window
    /// under queue pressure (batch greedily for throughput) and shrink
    /// it when the queue is empty (cut the window for latency).
    pub adaptive: Option<AdaptiveWindowConfig>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_wait: Duration::from_millis(2),
            flush_points: 4096,
            max_tile: 4096,
            adaptive: None,
        }
    }
}

/// Bounds and thresholds of the adaptive batch-window controller.
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveWindowConfig {
    /// Floor: the window when the queue is drained (latency mode).
    pub min_wait: Duration,
    /// Ceiling: the window under sustained queue pressure (throughput
    /// mode).
    pub max_wait: Duration,
    /// Queue depth at or below which the window halves.
    pub low_depth: usize,
    /// Queue depth at or above which the window doubles.
    pub high_depth: usize,
}

impl Default for AdaptiveWindowConfig {
    fn default() -> Self {
        AdaptiveWindowConfig {
            min_wait: Duration::from_micros(100),
            max_wait: Duration::from_millis(5),
            low_depth: 2,
            high_depth: 16,
        }
    }
}

/// The adaptive batch-window controller: multiplicative
/// increase/decrease of the window between the configured bounds, driven
/// purely by the observed queue-depth gauge. Pure state machine — the
/// window sequence is a deterministic function of the gauge trace, so
/// fixed-seed scenarios stay bit-reproducible.
#[derive(Debug, Clone)]
pub struct AdaptiveWindow {
    cfg: AdaptiveWindowConfig,
    current: Duration,
}

impl AdaptiveWindow {
    pub fn new(cfg: AdaptiveWindowConfig) -> AdaptiveWindow {
        assert!(cfg.min_wait <= cfg.max_wait, "window bounds inverted");
        assert!(cfg.low_depth < cfg.high_depth, "depth thresholds inverted");
        AdaptiveWindow { cfg, current: cfg.min_wait }
    }

    pub fn current(&self) -> Duration {
        self.current
    }

    /// Feed one queue-depth observation; returns the window to use for
    /// the next batch. Deep queue → double (clamped to `max_wait`);
    /// drained queue → halve (clamped to `min_wait`); in between → hold.
    pub fn observe(&mut self, queue_depth: usize) -> Duration {
        if queue_depth >= self.cfg.high_depth {
            // A zero floor must still be escapable under pressure.
            let base = self.current.max(Duration::from_micros(1));
            self.current = (base * 2).min(self.cfg.max_wait);
        } else if queue_depth <= self.cfg.low_depth {
            self.current = (self.current / 2).max(self.cfg.min_wait);
        }
        self.current
    }
}

/// Scatter-gather state for one in-flight request that may have been
/// split across several tile jobs.
pub(crate) struct Assembly {
    pub id: u64,
    pub reply: std::sync::mpsc::Sender<ServeResult>,
    pub queued: Duration,
    /// Absolute deadline; a completion after this instant counts as
    /// `deadline_missed` (served late — shedding only happens *before*
    /// execution, in [`Batcher::plan`]).
    deadline: Option<Instant>,
    metrics: Arc<Metrics>,
    state: Mutex<AsmState>,
    /// Max over parts of backend execution time, in nanoseconds.
    exec_ns: AtomicU64,
    cycles: AtomicU64,
}

struct AsmState {
    xs: Vec<f32>,
    ys: Vec<f32>,
    remaining: usize,
    backend: BackendKind,
}

impl Assembly {
    /// Record one completed part; the final part sends the response.
    pub(crate) fn complete_part(
        &self,
        src_offset: usize,
        xs: &[f32],
        ys: &[f32],
        backend: BackendKind,
        exec: Duration,
        cycles: Option<u64>,
    ) {
        self.exec_ns.fetch_max(exec.as_nanos() as u64, Ordering::Relaxed);
        if let Some(c) = cycles {
            self.cycles.fetch_add(c, Ordering::Relaxed);
        }
        let mut st = self.state.lock().unwrap();
        st.xs[src_offset..src_offset + xs.len()].copy_from_slice(xs);
        st.ys[src_offset..src_offset + ys.len()].copy_from_slice(ys);
        st.backend = backend;
        st.remaining -= 1;
        if st.remaining == 0 {
            let cycles_total = self.cycles.load(Ordering::Relaxed);
            let resp = TransformResponse {
                id: self.id,
                xs: std::mem::take(&mut st.xs),
                ys: std::mem::take(&mut st.ys),
                timing: RequestTiming {
                    queued: self.queued,
                    execute: Duration::from_nanos(self.exec_ns.load(Ordering::Relaxed)),
                    backend: st.backend,
                    simulated_cycles: (cycles_total > 0).then_some(cycles_total),
                },
            };
            if matches!(self.deadline, Some(d) if Instant::now() > d) {
                self.metrics.deadline_missed.fetch_add(1, Ordering::Relaxed);
            }
            // One reply per admitted request: count it even if the
            // receiver hung up (client gone) — the coordinator's graceful
            // drain waits on responses == requests.
            self.metrics.responses.fetch_add(1, Ordering::Relaxed);
            let _ = self.reply.send(Ok(resp));
        }
    }
}

/// One backend invocation: ≤ `max_tile` points sharing one transform.
pub struct TileJob {
    pub params: [f32; 6],
    pub xs: Vec<f32>,
    pub ys: Vec<f32>,
    /// True if any request in this job is interactive: the job rides the
    /// express lane of the job queue so a backlog of bulk jobs cannot
    /// delay it.
    pub express: bool,
    /// Scatter list: `(assembly, dst_offset_in_job, src_offset_in_request,
    /// len)`.
    pub(crate) parts: Vec<(Arc<Assembly>, usize, usize, usize)>,
}

impl TileJob {
    pub fn points(&self) -> usize {
        self.xs.len()
    }

    /// Scatter the (already transformed, in-place) job buffers back to
    /// their requests.
    pub(crate) fn scatter(
        self,
        backend: BackendKind,
        exec: Duration,
        cycles_per_point: Option<f64>,
    ) {
        for (assembly, dst, src, len) in self.parts {
            let cycles = cycles_per_point.map(|c| (c * len as f64).round() as u64);
            assembly.complete_part(
                src,
                &self.xs[dst..dst + len],
                &self.ys[dst..dst + len],
                backend,
                exec,
                cycles,
            );
        }
    }
}

/// The batching planner (pure logic; the pump thread lives in
/// [`super::server`]).
pub struct Batcher {
    pub config: BatcherConfig,
}

impl Batcher {
    pub fn new(mut config: BatcherConfig) -> Batcher {
        assert!(config.max_tile > 0);
        // Round a non-multiple `max_tile` down to whole 64-point M1 tiles
        // (minimum one tile): a 100-point job bound would make *every*
        // backend job end in a padded 36-lane tail tile, where a 64-point
        // bound pads at most the final job of a request.
        config.max_tile = (config.max_tile / M1_TILE).max(1) * M1_TILE;
        Batcher { config }
    }

    /// Turn a window of pending requests into tile jobs: group by
    /// transform key (first-arrival order of keys, interactive lane
    /// first), concatenate each group's points, cut at `max_tile`
    /// boundaries.
    ///
    /// Admission control happens here, and it is **lane-weighted**:
    ///
    /// - A request whose deadline has already passed at plan time is shed
    ///   (either lane) — its client receives an explicit [`Rejection`]
    ///   instead of stale (and still-costly) results.
    /// - When the window is *congested* (more points than
    ///   `flush_points`), bulk requests whose deadline falls inside the
    ///   current batch window (`now + max_wait`) are shed preemptively:
    ///   they would expire in the backlog anyway, and planning them would
    ///   only delay the interactive lane. Interactive requests are never
    ///   shed while that rule is the only one firing — bulk always sheds
    ///   first at equal deadlines.
    ///
    /// `metrics.shed` counts every shed request; `metrics.shed_bulk`
    /// counts the bulk subset. Requests that make it into a job but
    /// finish late are counted as `deadline_missed` on completion.
    pub(crate) fn plan(
        &self,
        mut window: Vec<PendingRequest>,
        now: Instant,
        metrics: &Arc<Metrics>,
    ) -> Vec<TileJob> {
        // Interactive lane plans (and thus executes) first; stable sort
        // preserves arrival order within each lane.
        window.sort_by_key(|p| p.req.priority);
        let window_points: usize = window.iter().map(|p| p.req.points()).sum();
        let congested = window_points > self.config.flush_points;
        let horizon = now + self.config.max_wait;

        // Group preserving first-arrival order of keys (per lane order).
        let mut groups: Vec<(u64, [f32; 6], Vec<PendingRequest>)> = Vec::new();
        for p in window {
            let expired = matches!(p.deadline, Some(d) if now > d);
            // Weighted shed: under congestion a near-deadline bulk
            // request is shed before any interactive one is touched.
            let bulk_doomed = congested
                && p.req.priority == Priority::Bulk
                && matches!(p.deadline, Some(d) if d <= horizon);
            if expired || bulk_doomed {
                metrics.shed.fetch_add(1, Ordering::Relaxed);
                if p.req.priority == Priority::Bulk {
                    metrics.shed_bulk.fetch_add(1, Ordering::Relaxed);
                }
                metrics.responses.fetch_add(1, Ordering::Relaxed);
                let _ = p.reply.send(Err(Rejection {
                    id: p.req.id,
                    reason: RejectReason::DeadlineExceeded,
                }));
                continue;
            }
            let key = p.req.batch_key();
            match groups.iter_mut().find(|(k, _, _)| *k == key) {
                Some((_, _, v)) => v.push(p),
                None => {
                    let params = p.req.affine_params();
                    groups.push((key, params, vec![p]));
                }
            }
        }

        let mut jobs = Vec::new();
        for (_, params, pendings) in groups {
            let express =
                pendings.iter().any(|p| p.req.priority == Priority::Interactive);
            let mut job_xs: Vec<f32> = Vec::new();
            let mut job_ys: Vec<f32> = Vec::new();
            let mut parts: Vec<(Arc<Assembly>, usize, usize, usize)> = Vec::new();
            for p in pendings {
                let n = p.req.points();
                let assembly = Arc::new(Assembly {
                    id: p.req.id,
                    reply: p.reply,
                    queued: now.saturating_duration_since(p.submitted),
                    deadline: p.deadline,
                    metrics: metrics.clone(),
                    state: Mutex::new(AsmState {
                        xs: vec![0.0; n],
                        ys: vec![0.0; n],
                        remaining: 0, // fixed up below
                        backend: BackendKind::Native,
                    }),
                    exec_ns: AtomicU64::new(0),
                    cycles: AtomicU64::new(0),
                });
                if n == 0 {
                    // Zero-point request: nothing to execute; answer now.
                    assembly.state.lock().unwrap().remaining = 1;
                    assembly.complete_part(
                        0,
                        &[],
                        &[],
                        BackendKind::Native,
                        Duration::ZERO,
                        None,
                    );
                    continue;
                }
                // Split the request across tile boundaries.
                let mut src = 0usize;
                let mut n_parts = 0usize;
                while src < n {
                    let room = self.config.max_tile - job_xs.len();
                    if room == 0 {
                        jobs.push(TileJob {
                            params,
                            xs: std::mem::take(&mut job_xs),
                            ys: std::mem::take(&mut job_ys),
                            express,
                            parts: std::mem::take(&mut parts),
                        });
                        continue;
                    }
                    let len = room.min(n - src);
                    let dst = job_xs.len();
                    job_xs.extend_from_slice(&p.req.xs[src..src + len]);
                    job_ys.extend_from_slice(&p.req.ys[src..src + len]);
                    parts.push((assembly.clone(), dst, src, len));
                    src += len;
                    n_parts += 1;
                }
                assembly.state.lock().unwrap().remaining = n_parts;
            }
            if !job_xs.is_empty() {
                jobs.push(TileJob { params, xs: job_xs, ys: job_ys, express, parts });
            }
        }
        jobs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::TransformRequest;
    use crate::graphics::Transform;
    use crate::testkit::{check, Rng};
    use std::sync::mpsc;

    fn pending(
        id: u64,
        n: usize,
        t: Vec<Transform>,
    ) -> (PendingRequest, mpsc::Receiver<ServeResult>) {
        let (tx, rx) = mpsc::channel();
        let xs: Vec<f32> = (0..n).map(|i| (id * 1000 + i as u64) as f32).collect();
        let ys: Vec<f32> = (0..n).map(|i| -((id * 1000 + i as u64) as f32)).collect();
        let p = PendingRequest {
            req: TransformRequest::new(id, xs, ys, t),
            submitted: Instant::now(),
            deadline: None,
            reply: tx,
        };
        (p, rx)
    }

    fn pending_bulk(
        id: u64,
        n: usize,
        t: Vec<Transform>,
    ) -> (PendingRequest, mpsc::Receiver<ServeResult>) {
        let (mut p, rx) = pending(id, n, t);
        p.req.priority = Priority::Bulk;
        (p, rx)
    }

    fn metrics() -> Arc<Metrics> {
        Arc::new(Metrics::default())
    }

    fn drain(job: TileJob) {
        job.scatter(BackendKind::Native, Duration::from_micros(5), None);
    }

    #[test]
    fn same_transform_requests_share_a_tile() {
        let b = Batcher::new(BatcherConfig { max_tile: 64, ..Default::default() });
        let t = vec![Transform::Translate { tx: 1.0, ty: 1.0 }];
        let (p1, _r1) = pending(1, 16, t.clone());
        let (p2, _r2) = pending(2, 16, t);
        let jobs = b.plan(vec![p1, p2], Instant::now(), &metrics());
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].points(), 32);
        assert_eq!(jobs[0].parts.len(), 2);
    }

    #[test]
    fn different_transforms_get_separate_jobs() {
        let b = Batcher::new(BatcherConfig { max_tile: 64, ..Default::default() });
        let (p1, _r1) = pending(1, 8, vec![Transform::Translate { tx: 1.0, ty: 0.0 }]);
        let (p2, _r2) = pending(2, 8, vec![Transform::Translate { tx: 2.0, ty: 0.0 }]);
        let jobs = b.plan(vec![p1, p2], Instant::now(), &metrics());
        assert_eq!(jobs.len(), 2);
    }

    #[test]
    fn oversized_request_splits_and_reassembles() {
        let b = Batcher::new(BatcherConfig { max_tile: 64, ..Default::default() });
        let (p, rx) = pending(7, 200, vec![Transform::Scale { sx: 1.0, sy: 1.0 }]);
        let expected_xs = p.req.xs.clone();
        let jobs = b.plan(vec![p], Instant::now(), &metrics());
        assert_eq!(jobs.len(), 4); // 64+64+64+8
        assert!(jobs.iter().all(|j| j.points() <= 64));
        for j in jobs {
            drain(j);
        }
        let resp =
            rx.try_recv().expect("response after all parts scattered").expect("served");
        assert_eq!(resp.id, 7);
        assert_eq!(resp.xs, expected_xs);
    }

    #[test]
    fn non_multiple_max_tile_rounds_down_to_whole_tiles() {
        // 100 points/job would give every backend job a ragged 36-lane
        // tail tile; the batcher normalizes to whole 64-point tiles.
        let b = Batcher::new(BatcherConfig { max_tile: 100, ..Default::default() });
        assert_eq!(b.config.max_tile, 64);
        // Below one tile: clamp up to the minimum of one whole tile.
        let b = Batcher::new(BatcherConfig { max_tile: 8, ..Default::default() });
        assert_eq!(b.config.max_tile, 64);
        // Multiples pass through untouched.
        let b = Batcher::new(BatcherConfig { max_tile: 4096, ..Default::default() });
        assert_eq!(b.config.max_tile, 4096);
        // And the plan respects the rounded bound: a 150-point request
        // under a nominal 100-point bound cuts at 64, not 100.
        let b = Batcher::new(BatcherConfig { max_tile: 100, ..Default::default() });
        let (p, rx) = pending(9, 150, vec![Transform::Translate { tx: 1.0, ty: 0.0 }]);
        let expected_xs = p.req.xs.clone();
        let jobs = b.plan(vec![p], Instant::now(), &metrics());
        let sizes: Vec<usize> = jobs.iter().map(|j| j.points()).collect();
        assert_eq!(sizes, vec![64, 64, 22]);
        for j in jobs {
            drain(j);
        }
        let resp =
            rx.try_recv().expect("response after all parts scattered").expect("served");
        assert_eq!(resp.xs, expected_xs, "reassembly unaffected by rounding");
    }

    #[test]
    fn zero_point_request_still_gets_a_response() {
        let b = Batcher::new(BatcherConfig::default());
        let (p, rx) = pending(3, 0, vec![]);
        let jobs = b.plan(vec![p], Instant::now(), &metrics());
        assert!(jobs.is_empty());
        assert_eq!(rx.try_recv().unwrap().unwrap().id, 3);
    }

    #[test]
    fn property_no_request_lost_duplicated_or_reordered() {
        check("batcher conservation", 25, |rng: &mut Rng| {
            let b = Batcher::new(BatcherConfig {
                max_tile: [8, 64, 100][rng.below(3) as usize],
                ..Default::default()
            });
            let n_reqs = rng.range_i64(1, 12) as u64;
            let mut pendings = Vec::new();
            let mut receivers = Vec::new();
            let mut expected = Vec::new();
            for id in 0..n_reqs {
                let n = rng.range_i64(0, 150) as usize;
                let t = vec![Transform::Translate {
                    tx: rng.below(3) as f32, // 3 distinct transform groups
                    ty: 0.0,
                }];
                let (p, rx) = pending(id, n, t);
                expected.push((id, p.req.xs.clone(), p.req.ys.clone()));
                pendings.push(p);
                receivers.push(rx);
            }
            let jobs = b.plan(pendings, Instant::now(), &metrics());
            // Tile bound respected.
            for j in &jobs {
                assert!(j.points() <= b.config.max_tile);
                assert_eq!(j.xs.len(), j.ys.len());
            }
            // Total points conserved.
            let total: usize = jobs.iter().map(|j| j.points()).sum();
            let expected_total: usize = expected.iter().map(|(_, xs, _)| xs.len()).sum();
            assert_eq!(total, expected_total);
            for j in jobs {
                drain(j);
            }
            // Every request answered exactly once, points in order.
            for (i, rx) in receivers.iter().enumerate() {
                let resp = rx.try_recv().expect("one response per request").expect("served");
                let (id, xs, ys) = &expected[i];
                assert_eq!(resp.id, *id);
                assert_eq!(&resp.xs, xs, "x order preserved (identity scatter)");
                assert_eq!(&resp.ys, ys);
                assert!(rx.try_recv().is_err(), "no duplicate responses");
            }
        });
    }

    #[test]
    fn expired_deadline_is_shed_with_explicit_rejection() {
        let b = Batcher::new(BatcherConfig::default());
        let m = metrics();
        let t = vec![Transform::Translate { tx: 1.0, ty: 0.0 }];
        let (mut dead, dead_rx) = pending(1, 8, t.clone());
        dead.deadline = Some(Instant::now() - Duration::from_millis(1));
        let (live, live_rx) = pending(2, 8, t);
        let jobs = b.plan(vec![dead, live], Instant::now(), &m);
        // Only the live request was planned.
        let total: usize = jobs.iter().map(|j| j.points()).sum();
        assert_eq!(total, 8);
        for j in jobs {
            drain(j);
        }
        match dead_rx.try_recv().expect("shed request still gets a reply") {
            Err(Rejection { id: 1, reason: RejectReason::DeadlineExceeded }) => {}
            other => panic!("expected deadline rejection, got {other:?}"),
        }
        assert!(live_rx.try_recv().unwrap().is_ok());
        assert_eq!(m.shed.load(Ordering::Relaxed), 1);
        assert_eq!(m.deadline_missed.load(Ordering::Relaxed), 0);
        // Both requests got exactly one reply (one served, one rejected).
        assert_eq!(m.responses.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn late_completion_counts_deadline_missed_but_still_serves() {
        let b = Batcher::new(BatcherConfig::default());
        let m = metrics();
        // Deadline is ahead of `now` at plan time (so the request is NOT
        // shed) but already behind wall-clock when scatter completes.
        let (mut p, rx) = pending(4, 8, vec![Transform::Translate { tx: 1.0, ty: 0.0 }]);
        let plan_now = Instant::now() - Duration::from_millis(10);
        p.deadline = Some(plan_now + Duration::from_millis(5));
        let jobs = b.plan(vec![p], plan_now, &m);
        assert_eq!(jobs.len(), 1);
        for j in jobs {
            drain(j);
        }
        assert!(rx.try_recv().unwrap().is_ok(), "late requests are served, not dropped");
        assert_eq!(m.shed.load(Ordering::Relaxed), 0);
        assert_eq!(m.deadline_missed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn bulk_sheds_first_at_equal_deadline() {
        // Congested window (more points than flush_points), one request
        // per lane, *identical* deadlines inside the batch horizon: the
        // bulk request is shed, the interactive one is planned.
        let b = Batcher::new(BatcherConfig {
            flush_points: 64,
            max_tile: 64,
            ..Default::default()
        });
        let m = metrics();
        let now = Instant::now();
        let deadline = Some(now + Duration::from_millis(1)); // < max_wait (2ms)
        let t = vec![Transform::Translate { tx: 1.0, ty: 0.0 }];
        let (mut inter, inter_rx) = pending(1, 64, t.clone());
        inter.deadline = deadline;
        let (mut bulk, bulk_rx) = pending_bulk(2, 64, t);
        bulk.deadline = deadline;
        // Bulk arrived *first* — lane weighting, not arrival order, must
        // pick the victim.
        let jobs = b.plan(vec![bulk, inter], now, &m);
        let total: usize = jobs.iter().map(|j| j.points()).sum();
        assert_eq!(total, 64, "only the interactive request is planned");
        for j in jobs {
            drain(j);
        }
        assert!(inter_rx.try_recv().unwrap().is_ok(), "interactive served");
        match bulk_rx.try_recv().expect("bulk still gets a reply") {
            Err(Rejection { id: 2, reason: RejectReason::DeadlineExceeded }) => {}
            other => panic!("expected bulk shed, got {other:?}"),
        }
        assert_eq!(m.shed.load(Ordering::Relaxed), 1);
        assert_eq!(m.shed_bulk.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn interactive_never_shed_while_bulk_remains() {
        // Heavily congested window, every deadline equally near: the
        // weighted shed may only ever pick bulk victims — all interactive
        // requests are planned and served.
        let b = Batcher::new(BatcherConfig {
            flush_points: 64,
            max_tile: 64,
            ..Default::default()
        });
        let m = metrics();
        let now = Instant::now();
        let deadline = Some(now + Duration::from_millis(1));
        let t = vec![Transform::Translate { tx: 1.0, ty: 0.0 }];
        let mut window = Vec::new();
        let mut inter_rx = Vec::new();
        let mut bulk_rx = Vec::new();
        for id in 0..4u64 {
            let (mut p, rx) = pending_bulk(id, 64, t.clone());
            p.deadline = deadline;
            window.push(p);
            bulk_rx.push(rx);
        }
        for id in 4..8u64 {
            let (mut p, rx) = pending(id, 64, t.clone());
            p.deadline = deadline;
            window.push(p);
            inter_rx.push(rx);
        }
        let jobs = b.plan(window, now, &m);
        for j in jobs {
            drain(j);
        }
        for rx in &inter_rx {
            assert!(
                rx.try_recv().expect("interactive always answered").is_ok(),
                "interactive must never be shed while bulk remains"
            );
        }
        for rx in &bulk_rx {
            match rx.try_recv().expect("bulk answered") {
                Err(Rejection { reason: RejectReason::DeadlineExceeded, .. }) => {}
                other => panic!("expected bulk shed, got {other:?}"),
            }
        }
        assert_eq!(m.shed.load(Ordering::Relaxed), 4);
        assert_eq!(m.shed_bulk.load(Ordering::Relaxed), 4, "every victim was bulk");
    }

    #[test]
    fn interactive_jobs_plan_ahead_of_bulk_and_ride_express() {
        // Distinct transforms so the lanes land in distinct jobs: the
        // interactive job comes first in the plan and is marked express.
        let b = Batcher::new(BatcherConfig { max_tile: 64, ..Default::default() });
        let (bulk, _b_rx) = pending_bulk(1, 8, vec![Transform::Translate { tx: 1.0, ty: 0.0 }]);
        let (inter, _i_rx) = pending(2, 8, vec![Transform::Translate { tx: 2.0, ty: 0.0 }]);
        let jobs = b.plan(vec![bulk, inter], Instant::now(), &metrics());
        assert_eq!(jobs.len(), 2);
        assert!(jobs[0].express, "interactive job plans first");
        assert!(!jobs[1].express, "pure-bulk job rides the standard lane");
        assert_eq!(jobs[0].parts[0].0.id, 2);
    }

    #[test]
    fn uncongested_window_never_sheds_live_bulk() {
        // The preemptive bulk shed only fires under congestion; a window
        // within flush_points plans both lanes.
        let b = Batcher::new(BatcherConfig::default()); // flush_points 4096
        let m = metrics();
        let now = Instant::now();
        let t = vec![Transform::Translate { tx: 1.0, ty: 0.0 }];
        let (mut bulk, bulk_rx) = pending_bulk(1, 64, t.clone());
        bulk.deadline = Some(now + Duration::from_millis(1));
        let (inter, inter_rx) = pending(2, 64, t);
        let jobs = b.plan(vec![bulk, inter], now, &m);
        for j in jobs {
            drain(j);
        }
        assert!(bulk_rx.try_recv().unwrap().is_ok());
        assert!(inter_rx.try_recv().unwrap().is_ok());
        assert_eq!(m.shed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn adaptive_window_is_deterministic_for_a_gauge_trace() {
        // Same seed → same gauge trace → bit-identical window sequence.
        let cfg = AdaptiveWindowConfig::default();
        let trace = |seed: u64| -> Vec<Duration> {
            let mut rng = Rng::new(seed);
            let mut w = AdaptiveWindow::new(cfg);
            (0..200).map(|_| w.observe(rng.below(64) as usize)).collect()
        };
        assert_eq!(trace(7), trace(7), "same seed, same window sequence");
        assert_ne!(
            trace(7),
            trace(8),
            "different gauge traces actually steer the controller"
        );
    }

    #[test]
    fn adaptive_window_tracks_pressure_within_bounds() {
        let cfg = AdaptiveWindowConfig {
            min_wait: Duration::from_micros(100),
            max_wait: Duration::from_millis(4),
            low_depth: 2,
            high_depth: 16,
        };
        let mut w = AdaptiveWindow::new(cfg);
        assert_eq!(w.current(), cfg.min_wait, "starts at the latency floor");
        // Sustained pressure: doubles every window, clamps at the ceiling.
        let mut last = w.current();
        for _ in 0..10 {
            let next = w.observe(64);
            assert!(next >= last);
            assert!(next <= cfg.max_wait);
            last = next;
        }
        assert_eq!(w.current(), cfg.max_wait);
        // Mid-band depth holds the window steady.
        assert_eq!(w.observe(8), cfg.max_wait);
        // Drained queue: halves back down, clamps at the floor.
        for _ in 0..10 {
            w.observe(0);
        }
        assert_eq!(w.current(), cfg.min_wait);
    }

    #[test]
    fn batched_same_shape_jobs_share_one_compiled_megakernel() {
        // Two batch windows of same-transform sibling requests cut
        // identical 128-point jobs, so the M1 backend derives the same
        // megakernel spec from each — and the megakernel cache hands back
        // literally the same compiled plan (thread-local tier: pointer
        // equality is stable even if the global FIFO churns underneath).
        use crate::mapping::{megakernel_for, MegaSpec};
        let b = Batcher::new(BatcherConfig { max_tile: 128, ..Default::default() });
        let t = vec![Transform::Translate { tx: 3.0, ty: -1.0 }];
        let mut windows = Vec::new();
        for _ in 0..2 {
            let (p1, r1) = pending(1, 64, t.clone());
            let (p2, r2) = pending(2, 64, t.clone());
            windows.push((vec![p1, p2], (r1, r2)));
        }
        let jobs: Vec<Vec<TileJob>> = windows
            .iter_mut()
            .map(|(w, _)| b.plan(std::mem::take(w), Instant::now(), &metrics()))
            .collect();
        assert_eq!(jobs[0].len(), 1, "siblings share one job");
        assert_eq!(jobs[0][0].points(), 128);
        assert_eq!(jobs[1][0].points(), 128);
        assert_eq!(jobs[0][0].params, jobs[1][0].params, "same shape across windows");
        let spec = MegaSpec::PointTransform { n: 128, m: [64, 0, 0, 64], t: [3, -1], shift: 6 };
        let first = megakernel_for(&spec).expect("plan shape compiles");
        let second = megakernel_for(&spec).expect("cached");
        assert!(Arc::ptr_eq(&first, &second), "one compile per shape across windows");
    }

    #[test]
    fn queued_duration_measured_from_submit() {
        let b = Batcher::new(BatcherConfig::default());
        let (mut p, rx) = pending(1, 4, vec![]);
        p.submitted = Instant::now() - Duration::from_millis(50);
        let jobs = b.plan(vec![p], Instant::now(), &metrics());
        for j in jobs {
            drain(j);
        }
        let resp = rx.try_recv().unwrap().unwrap();
        assert!(resp.timing.queued >= Duration::from_millis(50));
    }
}
