//! Service metrics: lock-free counters plus log₂-bucketed latency
//! histograms (microsecond resolution).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use super::wire::HealthStats;

const BUCKETS: usize = 32; // bucket i: [2^i, 2^(i+1)) µs

/// A log₂ histogram over microseconds.
#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Histogram {
    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate quantile (upper bucket bound), `q` in [0, 1].
    pub fn quantile_us(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((n as f64) * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        u64::MAX
    }
}

/// Coordinator-wide metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub points: AtomicU64,
    pub jobs: AtomicU64,
    pub job_points: AtomicU64,
    pub backend_errors: AtomicU64,
    pub simulated_cycles: AtomicU64,
    /// Replies actually delivered to request channels — successful
    /// responses *and* explicit rejections. Graceful drain
    /// (`Coordinator::close`) waits for `responses == requests`; the
    /// exactly-one-reply invariant is `responses ≤ requests` at every
    /// instant and equality at quiescence.
    pub responses: AtomicU64,
    /// Requests shed by the batcher because their deadline expired while
    /// they waited in the admission queue (admission control), plus
    /// near-deadline bulk requests shed preemptively under congestion.
    pub shed: AtomicU64,
    /// The bulk-lane subset of `shed` — the weighted shed path's victims.
    /// Kept out of the wire health frame (its 11-field stats block is
    /// pinned); capacity reports read it straight from the snapshot.
    pub shed_bulk: AtomicU64,
    /// Requests fast-rejected at `try_submit` because the admission queue
    /// was full.
    pub rejected: AtomicU64,
    /// Requests fast-rejected at `try_submit` because the coordinator was
    /// shutting down (queue closed) — distinct from `rejected` so
    /// capacity reports can separate overload from shutdown.
    pub closed: AtomicU64,
    /// Requests that completed, but only after their deadline had passed
    /// (served late rather than shed — the tail the TTL should bound).
    pub deadline_missed: AtomicU64,
    /// Supervised tile crashes in the M1 pool (real or injected), folded
    /// in from [`super::PoolHealth`] by the workers.
    pub shard_crashes: AtomicU64,
    /// Warm restarts of M1 pool shards.
    pub shard_restarts: AtomicU64,
    /// Tiles re-run on a recovery shard after a shard death / lost reply.
    pub tiles_redispatched: AtomicU64,
    /// Slowest single pool recovery pass observed, in µs (gauge, max).
    pub recovery_max_us: AtomicU64,
    /// Queue wait per request (submit → batch formation).
    pub queue_wait: Histogram,
    /// Backend execution per job.
    pub execute: Histogram,
}

/// A point-in-time copy for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub points: u64,
    pub jobs: u64,
    pub job_points: u64,
    pub backend_errors: u64,
    pub simulated_cycles: u64,
    pub responses: u64,
    pub shed: u64,
    pub shed_bulk: u64,
    pub rejected: u64,
    pub closed: u64,
    pub deadline_missed: u64,
    pub shard_crashes: u64,
    pub shard_restarts: u64,
    pub tiles_redispatched: u64,
    pub recovery_max_us: u64,
    /// Process-wide megakernel-cache evictions (§Perf, megakernel tier),
    /// sampled from [`crate::mapping::megakernel_cache_evictions`] at
    /// snapshot time — a gauge, not a per-coordinator counter, so an
    /// unbounded-churn workload (every request a new transform shape)
    /// is visible instead of silently recompiling. Like `shed_bulk`,
    /// kept out of the wire health frame (its 11-field stats block is
    /// pinned); capacity reports read it straight from the snapshot.
    pub megakernel_evictions: u64,
    pub queue_wait_mean_us: f64,
    pub queue_wait_p99_us: u64,
    pub execute_mean_us: f64,
    pub execute_p50_us: u64,
    pub execute_p99_us: u64,
}

impl Metrics {
    pub fn record_request(&self, points: usize) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.points.fetch_add(points as u64, Ordering::Relaxed);
    }

    pub fn record_job(&self, points: usize, exec: Duration, cycles: Option<f64>) {
        self.jobs.fetch_add(1, Ordering::Relaxed);
        self.job_points.fetch_add(points as u64, Ordering::Relaxed);
        self.execute.record(exec);
        if let Some(c) = cycles {
            self.simulated_cycles
                .fetch_add((c * points as f64).round() as u64, Ordering::Relaxed);
        }
    }

    /// Fold a per-worker pool-health *delta* into the service counters
    /// (the cumulative [`super::PoolHealth`] snapshots are diffed by the
    /// worker so several workers can share one `Metrics`).
    pub fn record_pool_delta(
        &self,
        crashes: u64,
        restarts: u64,
        redispatched: u64,
        recovery_max_us: u64,
    ) {
        if crashes > 0 {
            self.shard_crashes.fetch_add(crashes, Ordering::Relaxed);
        }
        if restarts > 0 {
            self.shard_restarts.fetch_add(restarts, Ordering::Relaxed);
        }
        if redispatched > 0 {
            self.tiles_redispatched.fetch_add(redispatched, Ordering::Relaxed);
        }
        self.recovery_max_us.fetch_max(recovery_max_us, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            points: self.points.load(Ordering::Relaxed),
            jobs: self.jobs.load(Ordering::Relaxed),
            job_points: self.job_points.load(Ordering::Relaxed),
            backend_errors: self.backend_errors.load(Ordering::Relaxed),
            simulated_cycles: self.simulated_cycles.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            shed_bulk: self.shed_bulk.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            closed: self.closed.load(Ordering::Relaxed),
            deadline_missed: self.deadline_missed.load(Ordering::Relaxed),
            shard_crashes: self.shard_crashes.load(Ordering::Relaxed),
            shard_restarts: self.shard_restarts.load(Ordering::Relaxed),
            tiles_redispatched: self.tiles_redispatched.load(Ordering::Relaxed),
            recovery_max_us: self.recovery_max_us.load(Ordering::Relaxed),
            megakernel_evictions: crate::mapping::megakernel_cache_evictions(),
            queue_wait_mean_us: self.queue_wait.mean_us(),
            queue_wait_p99_us: self.queue_wait.quantile_us(0.99),
            execute_mean_us: self.execute.mean_us(),
            execute_p50_us: self.execute.quantile_us(0.5),
            execute_p99_us: self.execute.quantile_us(0.99),
        }
    }
}

impl MetricsSnapshot {
    /// Mean points per backend job — the batching efficiency signal.
    pub fn mean_batch_points(&self) -> f64 {
        if self.jobs == 0 {
            0.0
        } else {
            self.job_points as f64 / self.jobs as f64
        }
    }

    pub fn render(&self) -> String {
        format!(
            "requests={} responses={} points={} jobs={} mean_batch={:.1}pts errors={}\n\
             admission:  shed={} (bulk={}) rejected={} deadline_missed={} closed={}\n\
             supervision: crashes={} restarts={} redispatched={} recovery_max={}us \
             megakernel_evictions={}\n\
             queue_wait: mean={:.1}us p99<={}us\n\
             execute:    mean={:.1}us p50<={}us p99<={}us\n\
             simulated M1 cycles={}",
            self.requests,
            self.responses,
            self.points,
            self.jobs,
            self.mean_batch_points(),
            self.backend_errors,
            self.shed,
            self.shed_bulk,
            self.rejected,
            self.deadline_missed,
            self.closed,
            self.shard_crashes,
            self.shard_restarts,
            self.tiles_redispatched,
            self.recovery_max_us,
            self.megakernel_evictions,
            self.queue_wait_mean_us,
            self.queue_wait_p99_us,
            self.execute_mean_us,
            self.execute_p50_us,
            self.execute_p99_us,
            self.simulated_cycles,
        )
    }
}

// ── cluster-wide aggregation (the router tier) ─────────────────────────

/// One backend's row in the router's cluster snapshot.
#[derive(Debug, Clone)]
pub struct BackendSnapshot {
    /// Position in the router's backend list.
    pub index: usize,
    /// The backend's listen address.
    pub addr: String,
    /// Breaker state at snapshot time: `"healthy"`, `"suspect"` or
    /// `"dead"`.
    pub state: &'static str,
    /// Requests the router dispatched to this backend.
    pub proxied: u64,
    /// Replies this backend delivered back through the router.
    pub replies: u64,
    /// Times this backend's breaker fell to dead after having served.
    pub deaths: u64,
    /// Times it re-entered the rotation after being dead.
    pub rejoins: u64,
    /// Queue depth from its most recent health report (stale unless the
    /// breaker is healthy).
    pub queue_depth: u64,
}

/// The router's one-consistent-read metrics view: every backend's most
/// recent kind-5 health report summed into `health`, per-backend rows,
/// and the router's own proxy/failover counters — so the loadgen report
/// reads a single snapshot instead of racing N backends.
#[derive(Debug, Clone, Default)]
pub struct ClusterSnapshot {
    /// Per-backend rows, in backend-list order.
    pub backends: Vec<BackendSnapshot>,
    /// Sum of the most recent health report from every backend that has
    /// delivered one (cumulative counters add; `queue_depth` sums
    /// gauges; `recovery_max_us` keeps the max).
    pub health: HealthStats,
    /// Requests the router accepted from clients and dispatched.
    pub proxied: u64,
    /// Replies the router forwarded back to clients.
    pub replies: u64,
    /// Requests re-dispatched to another backend after their first
    /// backend died mid-flight.
    pub redispatched: u64,
    /// Requests answered with an immediate `Unavailable` rejection
    /// because no live backend remained (or the redispatch budget ran
    /// out).
    pub unavailable_rejected: u64,
    /// Backend breaker deaths observed (connection loss or health-poll
    /// starvation on a backend that had served).
    pub backend_deaths: u64,
    /// Backends that healed and re-entered the rotation.
    pub backend_rejoins: u64,
}

impl ClusterSnapshot {
    /// Fold one backend's latest health report into the cluster totals.
    pub(crate) fn absorb(&mut self, h: &HealthStats) {
        let t = &mut self.health;
        t.queue_depth += h.queue_depth;
        t.requests += h.requests;
        t.responses += h.responses;
        t.shed += h.shed;
        t.rejected += h.rejected;
        t.closed += h.closed;
        t.deadline_missed += h.deadline_missed;
        t.shard_crashes += h.shard_crashes;
        t.shard_restarts += h.shard_restarts;
        t.tiles_redispatched += h.tiles_redispatched;
        t.recovery_max_us = t.recovery_max_us.max(h.recovery_max_us);
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "router: proxied={} replies={} redispatched={} unavailable={} \
             deaths={} rejoins={}\n\
             cluster: requests={} responses={} shed={} rejected={} depth={}",
            self.proxied,
            self.replies,
            self.redispatched,
            self.unavailable_rejected,
            self.backend_deaths,
            self.backend_rejoins,
            self.health.requests,
            self.health.responses,
            self.health.shed,
            self.health.rejected,
            self.health.queue_depth,
        );
        for b in &self.backends {
            out.push_str(&format!(
                "\n  backend[{}] {} ({}): proxied={} replies={} deaths={} rejoins={} depth={}",
                b.index, b.addr, b.state, b.proxied, b.replies, b.deaths, b.rejoins, b.queue_depth,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_snapshot_sums_health_reports_and_renders_rows() {
        let mut s = ClusterSnapshot::default();
        s.absorb(&HealthStats {
            queue_depth: 3,
            requests: 10,
            responses: 9,
            recovery_max_us: 100,
            ..Default::default()
        });
        s.absorb(&HealthStats {
            queue_depth: 1,
            requests: 5,
            responses: 5,
            recovery_max_us: 700,
            ..Default::default()
        });
        assert_eq!(s.health.queue_depth, 4, "gauges sum");
        assert_eq!(s.health.requests, 15, "cumulative counters add");
        assert_eq!(s.health.recovery_max_us, 700, "maxes keep the max");
        s.backends.push(BackendSnapshot {
            index: 0,
            addr: "127.0.0.1:9000".into(),
            state: "healthy",
            proxied: 12,
            replies: 12,
            deaths: 1,
            rejoins: 1,
            queue_depth: 3,
        });
        let r = s.render();
        assert!(r.contains("requests=15"));
        assert!(r.contains("backend[0] 127.0.0.1:9000 (healthy)"));
    }

    #[test]
    fn histogram_buckets_by_log2() {
        let h = Histogram::default();
        h.record(Duration::from_micros(3)); // bucket 1 ([2,4))
        h.record(Duration::from_micros(100)); // bucket 6 ([64,128))
        h.record(Duration::from_micros(100));
        assert_eq!(h.count(), 3);
        assert!(h.quantile_us(0.33) <= 4);
        assert_eq!(h.quantile_us(1.0), 128);
        assert!((h.mean_us() - (3.0 + 100.0 + 100.0) / 3.0).abs() < 1.0);
    }

    #[test]
    fn zero_duration_lands_in_first_bucket() {
        let h = Histogram::default();
        h.record(Duration::ZERO);
        assert_eq!(h.quantile_us(1.0), 2);
    }

    #[test]
    fn snapshot_reflects_counters() {
        let m = Metrics::default();
        m.record_request(100);
        m.record_request(28);
        m.record_job(64, Duration::from_micros(50), Some(1.5));
        m.record_job(64, Duration::from_micros(70), None);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.points, 128);
        assert_eq!(s.jobs, 2);
        assert_eq!(s.mean_batch_points(), 64.0);
        assert_eq!(s.simulated_cycles, 96);
        assert!(s.render().contains("requests=2"));
    }

    #[test]
    fn admission_counters_flow_to_snapshot_and_render() {
        let m = Metrics::default();
        m.shed.fetch_add(3, Ordering::Relaxed);
        m.shed_bulk.fetch_add(2, Ordering::Relaxed);
        m.rejected.fetch_add(2, Ordering::Relaxed);
        m.deadline_missed.fetch_add(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!((s.shed, s.shed_bulk, s.rejected, s.deadline_missed), (3, 2, 2, 1));
        assert!(s.render().contains("shed=3 (bulk=2) rejected=2 deadline_missed=1"));
    }

    #[test]
    fn supervision_counters_flow_to_snapshot_and_render() {
        let m = Metrics::default();
        m.responses.fetch_add(5, Ordering::Relaxed);
        m.closed.fetch_add(2, Ordering::Relaxed);
        m.record_pool_delta(3, 3, 7, 450);
        m.record_pool_delta(0, 0, 0, 120); // gauge keeps the max
        let s = m.snapshot();
        assert_eq!(s.responses, 5);
        assert_eq!(s.closed, 2);
        assert_eq!((s.shard_crashes, s.shard_restarts), (3, 3));
        assert_eq!(s.tiles_redispatched, 7);
        assert_eq!(s.recovery_max_us, 450);
        assert!(s.render().contains("crashes=3 restarts=3 redispatched=7 recovery_max=450us"));
        assert!(s.render().contains("closed=2"));
    }

    #[test]
    fn megakernel_eviction_gauge_is_sampled_into_snapshots() {
        // The gauge mirrors a process-wide counter (other tests may bump
        // it concurrently), so pin the render wiring and monotonicity
        // rather than an absolute value.
        let m = Metrics::default();
        let s = m.snapshot();
        assert!(s.render().contains("megakernel_evictions="));
        assert!(m.snapshot().megakernel_evictions >= s.megakernel_evictions);
    }

    #[test]
    fn empty_metrics_do_not_divide_by_zero() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.mean_batch_points(), 0.0);
        assert_eq!(s.execute_mean_us, 0.0);
        assert_eq!(s.execute_p50_us, 0);
    }
}
