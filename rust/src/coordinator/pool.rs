//! # Sharded tile-execution pool (§Perf)
//!
//! The M1 mappings decompose every workload into independent 64-point
//! tiles (one full 8×8 RC-array configuration); the serial `M1SimBackend`
//! ran them one after another on a single simulator instance. This module
//! parallelizes that tile plan across **shards**: worker threads that each
//! own a private [`M1System`]. Compiled artifacts are **shared across
//! shards** (§Perf, fused tile-kernel tier): one pool-wide
//! compiled-routine cache ([`SharedRoutines`]) and one process-wide
//! [`BroadcastSchedule`] cache (in [`crate::mapping::runner`]), each
//! fronted by a thread-private read cache — so an N-shard pool compiles
//! every distinct program once, and the steady-state hot path stays
//! lock-free.
//!
//! ## Design
//!
//! ```text
//!  caller ── run(tiles) ──► TaskSet { tiles, next: AtomicUsize }
//!                               │ (chunked self-balancing dispatch:
//!                               │  each shard repeatedly claims the next
//!                               │  chunk of tile indices until drained)
//!               shard 0 ─ M1System ──┐    ┌─ shared routine cache
//!               shard 1 ─ M1System ──┼────┤  (one compile per spec)
//!               …                    │    └─ shared schedule cache
//!  caller ◄── results spliced ───────┴─► (index, outcome) per tile
//! ```
//!
//! Dispatch is *chunked work claiming*: tiles live in one shared,
//! immutable `TaskSet`, and shards claim the next chunk of indices from an
//! atomic cursor. Like work stealing this self-balances (a slow shard
//! simply claims fewer chunks) without per-tile channel traffic or a
//! per-shard deque.
//!
//! ## Determinism contract
//!
//! Pooled execution is **bit-for-bit identical** to serial execution,
//! independent of shard count and interleaving:
//!
//! * every tile runs on a freshly `reset_chip`-ed system, so a tile's
//!   result depends only on its own inputs — never on which shard ran it
//!   or what ran before;
//! * results are spliced back by tile index, so output order equals the
//!   serial order;
//! * cycle accounting is aggregated as the sum of per-tile cycle counts
//!   (u64 addition — order-independent), which equals the serial backend's
//!   running total exactly.
//!
//! The randomized conformance suite (`tests/conformance.rs`) pins all
//! three properties across shard counts {1, 2, 4, 8}.
//!
//! ## Choosing a shard count
//!
//! A tile simulates in ~10 µs, so sharding pays off once a request carries
//! several tiles (n ≳ 256). `shards = 1` is the serial mode (tiles run
//! inline on the caller thread — no worker threads, no channels, identical
//! to the pre-pool backend). For throughput serving, `shards ≈ physical
//! cores / coordinator workers` is the right starting point; beyond the
//! tile count of a typical request the extra shards just idle.
//!
//! [`BroadcastSchedule`]: crate::morphosys::BroadcastSchedule

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use crate::mapping::{runner::run_routine_on, MappedRoutine, PointTransformMapping, VecVecMapping};
use crate::morphosys::{AluOp, ExecutionReport, M1System};

/// Compact, hashable description of the routine a tile runs. Shards
/// compile specs on demand and cache the result, so a transform repeated
/// across the tiles of a frame compiles once per shard, not once per tile.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RoutineSpec {
    /// §5.2/§5.3 point transform: `n` points through fixed-point matrix
    /// `m` (Q`shift`) plus translation `t`.
    PointTransform { n: usize, m: [i16; 4], t: [i16; 2], shift: u8 },
    /// §5.1 element-wise vector-vector op on one tile.
    VecVec { n: usize, op: AluOp },
}

impl RoutineSpec {
    fn compile(&self) -> MappedRoutine {
        match *self {
            RoutineSpec::PointTransform { n, m, t, shift } => {
                PointTransformMapping { n, m, t, shift }.compile()
            }
            RoutineSpec::VecVec { n, op } => VecVecMapping { n, op }.compile(),
        }
    }
}

/// One tile of work: the routine to run and its staged input vectors.
#[derive(Debug, Clone)]
pub struct TileRequest {
    pub spec: RoutineSpec,
    pub u: Vec<i16>,
    pub v: Option<Vec<i16>>,
}

/// One tile's outcome: the result vector read back from main memory and
/// the simulator's execution report.
#[derive(Debug, Clone)]
pub struct TileOutcome {
    pub result: Vec<i16>,
    pub report: ExecutionReport,
}

/// Bound on distinct cached routines per tier (same crude policy as the
/// schedule cache in [`crate::mapping::runner`]).
const ROUTINE_CACHE_MAX: usize = 512;

/// Cross-shard compiled-routine cache (§Perf, fused tile-kernel tier):
/// one mutex-guarded map shared by every shard of a pool, so a spec
/// compiles once per pool instead of once per shard. Shards keep a
/// thread-private read cache in front of it, so the steady state (every
/// tile after a shard's first sighting of a spec) takes no lock.
/// Determinism is unaffected: a compiled routine is a pure function of
/// its spec, so which shard compiles it first cannot change any result.
type SharedRoutines = Arc<Mutex<HashMap<RoutineSpec, Arc<MappedRoutine>>>>;

/// Per-shard execution state: a private simulator plus the private fast
/// path over the pool-shared routine cache. Never shared between threads.
struct Shard {
    sys: M1System,
    /// Thread-private hits over `shared` (no locking once warm).
    routines: HashMap<RoutineSpec, Arc<MappedRoutine>>,
    shared: SharedRoutines,
}

impl Shard {
    fn new(shared: SharedRoutines, async_dma: bool) -> Shard {
        Shard { sys: M1System::with_dma_mode(async_dma), routines: HashMap::new(), shared }
    }

    /// Compiled routine for a spec: local probe, then the shared map
    /// (compiling under its lock exactly once per pool).
    fn routine_for(&mut self, spec: RoutineSpec) -> Arc<MappedRoutine> {
        if let Some(hit) = self.routines.get(&spec) {
            return hit.clone();
        }
        if self.routines.len() > ROUTINE_CACHE_MAX {
            self.routines.clear();
        }
        let routine = {
            let mut shared = self.shared.lock().unwrap();
            if shared.len() > ROUTINE_CACHE_MAX {
                shared.clear();
            }
            shared.entry(spec).or_insert_with(|| Arc::new(spec.compile())).clone()
        };
        self.routines.insert(spec, routine.clone());
        routine
    }

    fn run_tile(&mut self, tile: &TileRequest) -> TileOutcome {
        let routine = self.routine_for(tile.spec);
        self.sys.reset_chip();
        let out = run_routine_on(&mut self.sys, &routine, &tile.u, tile.v.as_deref());
        TileOutcome { result: out.result, report: out.report }
    }
}

/// One `run` call's worth of work, shared read-only across shards; `next`
/// is the chunk-claim cursor.
struct TaskSet {
    tiles: Vec<TileRequest>,
    next: AtomicUsize,
    chunk: usize,
}

/// A batch handed to every shard: the shared task set plus the reply
/// channel results come back on, tagged with their tile index.
struct Batch {
    tasks: Arc<TaskSet>,
    reply: mpsc::Sender<(usize, TileOutcome)>,
}

enum Exec {
    /// `shards == 1`: tiles run inline on the caller thread.
    Inline(Box<Shard>),
    /// `shards > 1`: persistent worker threads fed through per-shard
    /// channels.
    Threads { feeds: Vec<mpsc::Sender<Batch>>, handles: Vec<JoinHandle<()>> },
}

/// The sharded tile-execution pool. See the module docs for the design
/// and the determinism contract.
pub struct TilePool {
    shards: usize,
    /// Every shard simulator runs in async-DMA mode (§Perf PR 5): tiles
    /// report the overlapped cycle counts and execute on the async
    /// scheduled/fused tier. Functional results are identical to
    /// blocking mode — the DMA mode only changes cycle accounting.
    async_dma: bool,
    exec: Exec,
    /// The cross-shard routine cache every shard of this pool fills and
    /// reads (see [`SharedRoutines`]).
    routines: SharedRoutines,
}

impl TilePool {
    /// Build a pool with `shards` execution shards (`0` is treated as
    /// `1`). `shards == 1` spawns no threads.
    pub fn new(shards: usize) -> TilePool {
        Self::with_mode(shards, false)
    }

    /// As [`TilePool::new`], choosing the shards' DMA mode: `async_dma`
    /// runs every shard simulator in the overlapped non-blocking-DMA
    /// mode (`M1System::with_async_dma`), so tile reports carry the
    /// double-buffered cycle counts (§Perf PR 5). The determinism
    /// contract is unchanged within a mode: pooled output and accounting
    /// are bit-for-bit serial execution's, for any shard count.
    pub fn with_mode(shards: usize, async_dma: bool) -> TilePool {
        let shards = shards.max(1);
        let routines: SharedRoutines = Arc::new(Mutex::new(HashMap::new()));
        if shards == 1 {
            return TilePool {
                shards,
                async_dma,
                exec: Exec::Inline(Box::new(Shard::new(routines.clone(), async_dma))),
                routines,
            };
        }
        let mut feeds = Vec::with_capacity(shards);
        let mut handles = Vec::with_capacity(shards);
        for s in 0..shards {
            let (tx, rx) = mpsc::channel::<Batch>();
            feeds.push(tx);
            let shared = routines.clone();
            let handle = std::thread::Builder::new()
                .name(format!("m1-shard-{s}"))
                .spawn(move || {
                    let mut shard = Shard::new(shared, async_dma);
                    while let Ok(batch) = rx.recv() {
                        drain_batch(&mut shard, &batch);
                    }
                })
                .expect("spawn tile-pool shard");
            handles.push(handle);
        }
        TilePool { shards, async_dma, exec: Exec::Threads { feeds, handles }, routines }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Whether this pool's shards run in async-DMA mode.
    pub fn async_dma(&self) -> bool {
        self.async_dma
    }

    /// Number of distinct routine specs compiled into the cross-shard
    /// cache so far (each compiled exactly once per pool).
    pub fn cached_routines(&self) -> usize {
        self.routines.lock().unwrap().len()
    }

    /// Execute a tile plan. Outcomes are returned in tile order; see the
    /// module docs for the determinism contract.
    pub fn run(&mut self, tiles: Vec<TileRequest>) -> Vec<TileOutcome> {
        match &mut self.exec {
            Exec::Inline(shard) => tiles.iter().map(|t| shard.run_tile(t)).collect(),
            Exec::Threads { feeds, .. } => {
                let n = tiles.len();
                if n == 0 {
                    return Vec::new();
                }
                // Chunks small enough that every shard claims several
                // (self-balancing), large enough to amortize the claim.
                let chunk = (n / (feeds.len() * 4)).max(1);
                let tasks = Arc::new(TaskSet { tiles, next: AtomicUsize::new(0), chunk });
                let (tx, rx) = mpsc::channel();
                for feed in feeds.iter() {
                    // A send only fails if a shard died; the recv below
                    // surfaces that as a panic with context.
                    let _ = feed.send(Batch { tasks: tasks.clone(), reply: tx.clone() });
                }
                drop(tx);
                let mut out: Vec<Option<TileOutcome>> = Vec::with_capacity(n);
                out.resize_with(n, || None);
                for _ in 0..n {
                    let (i, outcome) =
                        rx.recv().expect("tile-pool shard died mid-batch");
                    out[i] = Some(outcome);
                }
                out.into_iter()
                    .map(|o| o.expect("every tile completes exactly once"))
                    .collect()
            }
        }
    }

    /// Convenience for the §5.1 multi-tile workloads: run an element-wise
    /// vector-vector op (`n` a multiple of 64) as independent 64-point
    /// tiles across the pool. Returns the spliced result and the summed
    /// cycle count — the pool-targeted counterpart of the monolithic
    /// [`crate::mapping::TiledVecVecMapping`] program, with identical
    /// results (pinned by the `streamed` tests).
    pub fn run_vecvec(&mut self, op: AluOp, u: &[i16], v: &[i16]) -> (Vec<i16>, u64) {
        assert_eq!(u.len(), v.len(), "operand length mismatch");
        assert!(
            !u.is_empty() && u.len() % 64 == 0,
            "pooled vecvec needs a multiple of 64 elements"
        );
        let tiles: Vec<TileRequest> = u
            .chunks(64)
            .zip(v.chunks(64))
            .map(|(uc, vc)| TileRequest {
                spec: RoutineSpec::VecVec { n: 64, op },
                u: uc.to_vec(),
                v: Some(vc.to_vec()),
            })
            .collect();
        let mut result = Vec::with_capacity(u.len());
        let mut cycles = 0u64;
        for outcome in self.run(tiles) {
            cycles += outcome.report.cycles;
            result.extend_from_slice(&outcome.result);
        }
        (result, cycles)
    }
}

impl Drop for TilePool {
    fn drop(&mut self) {
        if let Exec::Threads { feeds, handles } = &mut self.exec {
            feeds.clear(); // closing the feeds ends every shard's recv loop
            for h in handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

/// Shard side of a batch: claim chunks of tile indices until the cursor
/// passes the end, running each tile and replying with its index.
fn drain_batch(shard: &mut Shard, batch: &Batch) {
    let tasks = &batch.tasks;
    loop {
        let start = tasks.next.fetch_add(tasks.chunk, Ordering::Relaxed);
        if start >= tasks.tiles.len() {
            return;
        }
        let end = (start + tasks.chunk).min(tasks.tiles.len());
        for i in start..end {
            let outcome = shard.run_tile(&tasks.tiles[i]);
            if batch.reply.send((i, outcome)).is_err() {
                return; // caller went away mid-batch
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn add_tiles(n_tiles: usize) -> (Vec<TileRequest>, Vec<i16>) {
        let mut tiles = Vec::new();
        let mut expected = Vec::new();
        for t in 0..n_tiles {
            let u: Vec<i16> = (0..64).map(|i| (t * 64 + i) as i16).collect();
            let v: Vec<i16> = (0..64).map(|i| 1000 - (t as i16) - (i as i16)).collect();
            expected.extend(u.iter().zip(&v).map(|(a, b)| a + b));
            tiles.push(TileRequest {
                spec: RoutineSpec::VecVec { n: 64, op: AluOp::Add },
                u,
                v: Some(v),
            });
        }
        (tiles, expected)
    }

    fn splice(outcomes: &[TileOutcome]) -> Vec<i16> {
        outcomes.iter().flat_map(|o| o.result.iter().copied()).collect()
    }

    #[test]
    fn inline_pool_runs_tiles_in_order() {
        let mut pool = TilePool::new(1);
        assert_eq!(pool.shards(), 1);
        let (tiles, expected) = add_tiles(5);
        let out = pool.run(tiles);
        assert_eq!(splice(&out), expected);
        assert!(out.iter().all(|o| o.report.cycles == 96), "translation-64 is 96 cycles");
    }

    #[test]
    fn threaded_pool_matches_inline_bit_for_bit() {
        let (tiles, _) = add_tiles(13);
        let mut serial = TilePool::new(1);
        let baseline = serial.run(tiles.clone());
        for shards in [2usize, 4, 8] {
            let mut pool = TilePool::new(shards);
            let out = pool.run(tiles.clone());
            assert_eq!(splice(&out), splice(&baseline), "shards={shards}");
            for (a, b) in out.iter().zip(&baseline) {
                assert_eq!(a.report.cycles, b.report.cycles);
                assert_eq!(a.report.slots, b.report.slots);
                assert_eq!(a.report.broadcasts, b.report.broadcasts);
            }
        }
    }

    #[test]
    fn more_shards_than_tiles_is_fine() {
        let (tiles, expected) = add_tiles(2);
        let mut pool = TilePool::new(8);
        assert_eq!(splice(&pool.run(tiles)), expected);
        // And an empty plan returns an empty result without deadlock.
        assert!(pool.run(Vec::new()).is_empty());
    }

    #[test]
    fn pool_is_reusable_across_batches() {
        let mut pool = TilePool::new(3);
        for round in 0..4 {
            let (tiles, expected) = add_tiles(round + 1);
            assert_eq!(splice(&pool.run(tiles)), expected, "round {round}");
        }
    }

    #[test]
    fn run_vecvec_matches_native_reference() {
        let n = 320;
        let u: Vec<i16> = (0..n as i16).collect();
        let v: Vec<i16> = (0..n as i16).map(|i| 3 * i - 7).collect();
        let expected: Vec<i16> = u.iter().zip(&v).map(|(a, b)| a.wrapping_add(*b)).collect();
        let mut serial = TilePool::new(1);
        let (r1, c1) = serial.run_vecvec(AluOp::Add, &u, &v);
        let mut pooled = TilePool::new(4);
        let (r4, c4) = pooled.run_vecvec(AluOp::Add, &u, &v);
        assert_eq!(r1, expected);
        assert_eq!(r4, expected);
        assert_eq!(c1, c4, "cycle aggregation must not depend on shard count");
        assert_eq!(c1, (n as u64 / 64) * 96);
    }

    #[test]
    fn routine_cache_is_shared_across_shards() {
        // 32 tiles of one spec across 4 shards: every shard touches the
        // spec, yet the pool-wide cache holds exactly one compile.
        let (tiles, expected) = add_tiles(32);
        let mut pool = TilePool::new(4);
        assert_eq!(pool.cached_routines(), 0);
        let out = pool.run(tiles);
        assert_eq!(splice(&out), expected);
        assert_eq!(pool.cached_routines(), 1);
        // A second spec adds exactly one more entry.
        let xs: Vec<i16> = (0..64).collect();
        pool.run(vec![TileRequest {
            spec: RoutineSpec::VecVec { n: 64, op: AluOp::Sub },
            u: xs.clone(),
            v: Some(xs),
        }]);
        assert_eq!(pool.cached_routines(), 2);
    }

    #[test]
    fn async_dma_pool_matches_serial_async_bit_for_bit() {
        // The §Perf PR 5 mode: every shard simulator in async-DMA mode.
        // Results are identical to the blocking pool's; cycle reports are
        // the overlapped counts, and both are shard-count-independent.
        let (tiles, expected) = add_tiles(13);
        let mut serial = TilePool::with_mode(1, true);
        assert!(serial.async_dma());
        let baseline = serial.run(tiles.clone());
        assert_eq!(splice(&baseline), expected);
        let blocking = TilePool::new(1).run(tiles.clone());
        for (a, b) in baseline.iter().zip(&blocking) {
            assert_eq!(a.result, b.result, "DMA mode must not change results");
            assert!(a.report.cycles <= b.report.cycles, "async must not be slower");
        }
        for shards in [2usize, 4, 8] {
            let mut pool = TilePool::with_mode(shards, true);
            let out = pool.run(tiles.clone());
            assert_eq!(splice(&out), splice(&baseline), "shards={shards}");
            for (a, b) in out.iter().zip(&baseline) {
                assert_eq!(a.report.cycles, b.report.cycles);
                assert_eq!(a.report.slots, b.report.slots);
                assert_eq!(a.report.broadcasts, b.report.broadcasts);
            }
        }
    }

    #[test]
    fn mixed_specs_in_one_batch() {
        // Point-transform and vecvec tiles interleaved: the shared
        // routine cache (and each shard's read cache over it) must key
        // correctly on the spec.
        let xs: Vec<i16> = (0..64).collect();
        let ys: Vec<i16> = (0..64).map(|i| i - 32).collect();
        let tiles = vec![
            TileRequest {
                spec: RoutineSpec::PointTransform { n: 64, m: [1, 0, 0, 1], t: [5, -3], shift: 0 },
                u: xs.clone(),
                v: Some(ys.clone()),
            },
            TileRequest {
                spec: RoutineSpec::VecVec { n: 64, op: AluOp::Sub },
                u: xs.clone(),
                v: Some(ys.clone()),
            },
        ];
        let mut pool = TilePool::new(2);
        let out = pool.run(tiles);
        let (xp, yp) = out[0].result.split_at(64);
        for i in 0..64 {
            assert_eq!(xp[i], xs[i] + 5);
            assert_eq!(yp[i], ys[i] - 3);
            assert_eq!(out[1].result[i], xs[i] - ys[i]);
        }
    }
}
