//! # Sharded tile-execution pool (§Perf, §Robustness)
//!
//! The M1 mappings decompose every workload into independent 64-point
//! tiles (one full 8×8 RC-array configuration); the serial `M1SimBackend`
//! ran them one after another on a single simulator instance. This module
//! parallelizes that tile plan across **shards**: worker threads that each
//! own a private [`M1System`]. Compiled artifacts are **shared across
//! shards** (§Perf, fused tile-kernel tier): one pool-wide
//! compiled-routine cache ([`SharedRoutines`]) and one process-wide
//! [`BroadcastSchedule`] cache (in [`crate::mapping::runner`]), each
//! fronted by a thread-private read cache — so an N-shard pool compiles
//! every distinct program once, and the steady-state hot path stays
//! lock-free.
//!
//! ## Design
//!
//! ```text
//!  caller ── run(tiles) ──► TaskSet { tiles, next: AtomicUsize }
//!                               │ (chunked self-balancing dispatch:
//!                               │  each shard repeatedly claims the next
//!                               │  chunk of tile indices until drained)
//!               shard 0 ─ M1System ──┐    ┌─ shared routine cache
//!               shard 1 ─ M1System ──┼────┤  (one compile per spec)
//!               …                    │    └─ shared schedule cache
//!  caller ◄── results spliced ───────┴─► (index, outcome) per tile
//! ```
//!
//! Dispatch is *chunked work claiming*: tiles live in one shared,
//! immutable `TaskSet`, and shards claim the next chunk of indices from an
//! atomic cursor. Like work stealing this self-balances (a slow shard
//! simply claims fewer chunks) without per-tile channel traffic or a
//! per-shard deque.
//!
//! ## Self-healing supervision (§Robustness)
//!
//! Shards are supervised, so a crash inside a tile — a simulator bug, or
//! an injected [`FaultPlan`] fault — degrades capacity instead of losing
//! work or wedging the caller:
//!
//! * **crash containment**: each tile runs under `catch_unwind`; on panic
//!   the shard dumps a repro artifact ([`crate::replay`], opt-in via
//!   `MORPHO_REPRO_DIR`), **warm-restarts** its simulator from the
//!   pristine boot snapshot taken at construction, and retries the tile
//!   once fault-free;
//! * **shard death**: if a shard thread dies outright, its claimed but
//!   unfinished tiles never reply. The caller notices the reply channel
//!   closing short of `n` results, re-runs exactly the missing tiles on a
//!   dedicated fault-free **recovery shard**, and respawns dead threads
//!   before the next batch;
//! * **lost replies** take the same recovery path — every tile of every
//!   batch completes **exactly once** from the caller's point of view.
//!
//! Because tiles are pure functions of their inputs (fresh `reset_chip`
//! per tile), a re-run is bit-identical to the lost run, so the
//! determinism contract below survives arbitrary crash/restart
//! interleavings. [`TilePool::health`] exposes the crash/restart/
//! redispatch counters the coordinator folds into its metrics.
//!
//! ## Determinism contract
//!
//! Pooled execution is **bit-for-bit identical** to serial execution,
//! independent of shard count, interleaving and injected faults:
//!
//! * every tile runs on a freshly `reset_chip`-ed system, so a tile's
//!   result depends only on its own inputs — never on which shard ran it
//!   or what ran before;
//! * results are spliced back by tile index, so output order equals the
//!   serial order;
//! * cycle accounting is aggregated as the sum of per-tile cycle counts
//!   (u64 addition — order-independent), which equals the serial backend's
//!   running total exactly.
//!
//! The randomized conformance suite (`tests/conformance.rs`) pins all
//! three properties across shard counts {1, 2, 4, 8}.
//!
//! ## Choosing a shard count
//!
//! A tile simulates in ~10 µs, so sharding pays off once a request carries
//! several tiles (n ≳ 256). `shards = 1` is the serial mode (tiles run
//! inline on the caller thread — no worker threads, no channels, identical
//! to the pre-pool backend). For throughput serving, `shards ≈ physical
//! cores / coordinator workers` is the right starting point; beyond the
//! tile count of a typical request the extra shards just idle.
//!
//! [`BroadcastSchedule`]: crate::morphosys::BroadcastSchedule

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::faults::{FaultAction, FaultPlan};
use crate::mapping::{
    megakernel_for, run_plan, runner::run_routine_on, runner::stage_routine3_on, MappedRoutine,
    MegaSpec, PointTransformMapping, StreamedPointTransformMapping, StreamedTiledMapping,
    VecVecMapping, RESULT_ADDR,
};
use crate::morphosys::{AluOp, ExecutionReport, M1System};
use crate::replay::ReproArtifact;

/// Compact, hashable description of the routine a tile runs. Shards
/// compile specs on demand and cache the result, so a transform repeated
/// across the tiles of a frame compiles once per shard, not once per tile.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RoutineSpec {
    /// §5.2/§5.3 point transform: `n` points through fixed-point matrix
    /// `m` (Q`shift`) plus translation `t`.
    PointTransform { n: usize, m: [i16; 4], t: [i16; 2], shift: u8 },
    /// §5.1 element-wise vector-vector op on one tile.
    VecVec { n: usize, op: AluOp },
    /// Plan-level point transform (§Perf, megakernel tier): `n` a
    /// multiple of 64, the whole multi-tile plan compiled into one
    /// megakernel — context loaded once, DMA streams batched across tile
    /// boundaries. Result layout is `[all x'][all y']` (2·`n` elements),
    /// unlike the per-tile spec's per-tile interleaving.
    PointTransformPlan { n: usize, m: [i16; 4], t: [i16; 2], shift: u8 },
    /// Plan-level element-wise vector-vector op over `n` (multiple of
    /// 64) elements, megakernel tier.
    VecVecPlan { n: usize, op: AluOp },
}

impl RoutineSpec {
    fn compile(&self) -> MappedRoutine {
        match *self {
            RoutineSpec::PointTransform { n, m, t, shift } => {
                PointTransformMapping { n, m, t, shift }.compile()
            }
            RoutineSpec::VecVec { n, op } => VecVecMapping { n, op }.compile(),
            RoutineSpec::PointTransformPlan { n, m, t, shift } => {
                StreamedPointTransformMapping { n, m, t, shift }.compile()
            }
            RoutineSpec::VecVecPlan { n, op } => StreamedTiledMapping { n, op }.compile(),
        }
    }

    /// The megakernel cache key for plan-level specs. `None` for the
    /// per-tile specs, which stay on the scheduled/fused tier (their
    /// per-tile cycle accounting is part of the determinism contract).
    fn mega_spec(&self) -> Option<MegaSpec> {
        match *self {
            RoutineSpec::PointTransformPlan { n, m, t, shift } => {
                Some(MegaSpec::PointTransform { n, m, t, shift })
            }
            RoutineSpec::VecVecPlan { n, op } => Some(MegaSpec::VecVec { n, op }),
            RoutineSpec::PointTransform { .. } | RoutineSpec::VecVec { .. } => None,
        }
    }
}

/// One tile of work: the routine to run and its staged input vectors.
#[derive(Debug, Clone)]
pub struct TileRequest {
    pub spec: RoutineSpec,
    pub u: Vec<i16>,
    pub v: Option<Vec<i16>>,
}

/// One tile's outcome: the result vector read back from main memory and
/// the simulator's execution report.
#[derive(Debug, Clone)]
pub struct TileOutcome {
    pub result: Vec<i16>,
    pub report: ExecutionReport,
}

/// Bound on distinct cached routines per tier (same crude policy as the
/// schedule cache in [`crate::mapping::runner`]).
const ROUTINE_CACHE_MAX: usize = 512;

/// Cross-shard compiled-routine cache (§Perf, fused tile-kernel tier):
/// one mutex-guarded map shared by every shard of a pool, so a spec
/// compiles once per pool instead of once per shard. Shards keep a
/// thread-private read cache in front of it, so the steady state (every
/// tile after a shard's first sighting of a spec) takes no lock.
/// Determinism is unaffected: a compiled routine is a pure function of
/// its spec, so which shard compiles it first cannot change any result.
type SharedRoutines = Arc<Mutex<HashMap<RoutineSpec, Arc<MappedRoutine>>>>;

/// Shared supervision counters, written by shards and the caller-side
/// recovery pass, read out as a [`PoolHealth`] snapshot.
#[derive(Debug, Default)]
struct PoolStats {
    crashes: AtomicU64,
    restarts: AtomicU64,
    redispatched: AtomicU64,
    recovery_max_us: AtomicU64,
}

/// Snapshot of a pool's supervision counters (cumulative since
/// construction). The coordinator's workers diff successive snapshots
/// into the serving metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolHealth {
    /// Tile executions that panicked (real bugs or injected faults).
    pub crashes: u64,
    /// Warm restarts of a shard simulator from its boot snapshot.
    pub restarts: u64,
    /// Tiles re-run on the recovery shard after a shard death or a lost
    /// reply.
    pub redispatched: u64,
    /// Slowest single caller-side recovery pass observed, in µs — the
    /// latency cost of a shard death under load.
    pub recovery_max_us: u64,
}

/// Per-shard execution state: a private simulator plus the private fast
/// path over the pool-shared routine cache. Never shared between threads.
struct Shard {
    sys: M1System,
    /// Pristine boot-state snapshot taken at construction; crash recovery
    /// warm-restarts the simulator from this image instead of paying a
    /// full reconstruction.
    warm: Vec<u8>,
    async_dma: bool,
    faults: Option<FaultPlan>,
    stats: Arc<PoolStats>,
    /// Thread-private hits over `shared` (no locking once warm).
    routines: HashMap<RoutineSpec, Arc<MappedRoutine>>,
    shared: SharedRoutines,
}

impl Shard {
    fn new(
        shared: SharedRoutines,
        async_dma: bool,
        faults: Option<FaultPlan>,
        stats: Arc<PoolStats>,
    ) -> Shard {
        let sys = M1System::with_dma_mode(async_dma);
        let warm = sys.snapshot();
        Shard { sys, warm, async_dma, faults, stats, routines: HashMap::new(), shared }
    }

    /// Compiled routine for a spec: local probe, then the shared map
    /// (compiling under its lock exactly once per pool).
    fn routine_for(&mut self, spec: RoutineSpec) -> Arc<MappedRoutine> {
        if let Some(hit) = self.routines.get(&spec) {
            return hit.clone();
        }
        if self.routines.len() > ROUTINE_CACHE_MAX {
            self.routines.clear();
        }
        let routine = {
            let mut shared = self.shared.lock().unwrap();
            if shared.len() > ROUTINE_CACHE_MAX {
                shared.clear();
            }
            shared.entry(spec).or_insert_with(|| Arc::new(spec.compile())).clone()
        };
        self.routines.insert(spec, routine.clone());
        routine
    }

    fn run_tile(&mut self, tile: &TileRequest) -> TileOutcome {
        // Plan-level specs take the megakernel tier when the shape has a
        // plan-level program (compiled once process-wide, shared across
        // shards); otherwise they fall back to the scheduled tier over
        // the same streamed routine — bit-identical results either way,
        // pinned by the conformance suite.
        if let Some(mega) = tile.spec.mega_spec() {
            if let Some(plan) = megakernel_for(&mega) {
                self.sys.reset_chip();
                let out = run_plan(&mut self.sys, &plan, &tile.u, tile.v.as_deref());
                return TileOutcome { result: out.result, report: out.report };
            }
        }
        let routine = self.routine_for(tile.spec);
        self.sys.reset_chip();
        let out = run_routine_on(&mut self.sys, &routine, &tile.u, tile.v.as_deref());
        TileOutcome { result: out.result, report: out.report }
    }

    /// Run one tile under crash supervision, applying an injected fault.
    /// On panic (injected or real): dump a repro artifact, warm-restart
    /// the simulator and retry once fault-free — bit-identical, because a
    /// tile is a pure function of its inputs. `None` means even the
    /// fault-free retry crashed (the shard restarts and survives; the
    /// caller's recovery pass owns the tile).
    fn run_tile_supervised(&mut self, tile: &TileRequest, action: FaultAction) -> Option<TileOutcome> {
        if let FaultAction::Stall(d) = action {
            std::thread::sleep(d);
        }
        let inject = action == FaultAction::Panic;
        let first = catch_unwind(AssertUnwindSafe(|| {
            if inject {
                panic!("injected shard fault (seed-scheduled)");
            }
            self.run_tile(tile)
        }));
        match first {
            Ok(outcome) => Some(outcome),
            Err(_) => {
                self.stats.crashes.fetch_add(1, Ordering::Relaxed);
                self.dump_crash_artifact(tile);
                self.restart();
                match catch_unwind(AssertUnwindSafe(|| self.run_tile(tile))) {
                    Ok(outcome) => Some(outcome),
                    Err(_) => {
                        // Double fault: restart again and hand the tile to
                        // the caller-side recovery pass.
                        self.restart();
                        None
                    }
                }
            }
        }
    }

    /// Warm-restart the simulator from the boot snapshot (full rebuild if
    /// even the snapshot image is unusable).
    fn restart(&mut self) {
        if self.sys.restore(&self.warm).is_err() {
            self.sys = M1System::with_dma_mode(self.async_dma);
        }
        self.stats.restarts.fetch_add(1, Ordering::Relaxed);
    }

    /// Best-effort repro-artifact dump for a crashed tile (no-op unless
    /// `MORPHO_REPRO_DIR` is set — see [`crate::replay`]). Stages the tile
    /// on a *fresh* simulator so the artifact's pre-state is exactly what
    /// a clean run would start from, then records the per-step digests.
    /// Guarded by `catch_unwind`: a failing dump never takes down the
    /// supervisor that is handling the original crash.
    fn dump_crash_artifact(&mut self, tile: &TileRequest) {
        let Some(dir) = crate::replay::dump_dir() else { return };
        let seed = self.faults.as_ref().map(|f| f.seed()).unwrap_or(0);
        let routine = self.routine_for(tile.spec);
        let async_dma = self.async_dma;
        let summary = format!(
            "shard crash while running {:?} ({} elems, async_dma={async_dma}, fault seed {seed})",
            tile.spec,
            tile.u.len(),
        );
        let dumped = catch_unwind(AssertUnwindSafe(|| -> crate::Result<std::path::PathBuf> {
            let mut sys = M1System::with_dma_mode(async_dma);
            stage_routine3_on(&mut sys, &routine, &tile.u, tile.v.as_deref(), None);
            let pre = sys.snapshot();
            let artifact = ReproArtifact::capture(
                seed,
                summary,
                routine.program.clone(),
                pre,
                RESULT_ADDR,
                Vec::new(),
            )?;
            artifact.write_into(&dir)
        }));
        if let Ok(Ok(path)) = dumped {
            eprintln!("m1-shard: crash repro artifact dumped to {}", path.display());
        }
    }
}

/// One `run` call's worth of work, shared read-only across shards; `next`
/// is the chunk-claim cursor.
struct TaskSet {
    tiles: Vec<TileRequest>,
    next: AtomicUsize,
    chunk: usize,
}

/// A batch handed to every shard: the shared task set plus the reply
/// channel results come back on, tagged with their tile index.
struct Batch {
    tasks: Arc<TaskSet>,
    reply: mpsc::Sender<(usize, TileOutcome)>,
}

enum Exec {
    /// `shards == 1`: tiles run inline on the caller thread.
    Inline(Box<Shard>),
    /// `shards > 1`: persistent worker threads fed through per-shard
    /// channels.
    Threads { feeds: Vec<mpsc::Sender<Batch>>, handles: Vec<JoinHandle<()>> },
}

/// The sharded tile-execution pool. See the module docs for the design,
/// the determinism contract and the supervision model.
pub struct TilePool {
    shards: usize,
    /// Every shard simulator runs in async-DMA mode (§Perf PR 5): tiles
    /// report the overlapped cycle counts and execute on the async
    /// scheduled/fused tier. Functional results are identical to
    /// blocking mode — the DMA mode only changes cycle accounting.
    async_dma: bool,
    exec: Exec,
    /// The cross-shard routine cache every shard of this pool fills and
    /// reads (see [`SharedRoutines`]).
    routines: SharedRoutines,
    /// Test-only injected-fault schedule shared with every shard; `None`
    /// on every production path.
    faults: Option<FaultPlan>,
    stats: Arc<PoolStats>,
    /// Caller-thread shard that re-runs tiles lost to shard deaths or
    /// dropped replies. Always fault-free: recovery must terminate.
    recovery: Box<Shard>,
}

impl TilePool {
    /// Build a pool with `shards` execution shards (`0` is treated as
    /// `1`). `shards == 1` spawns no threads.
    pub fn new(shards: usize) -> TilePool {
        Self::with_mode(shards, false)
    }

    /// As [`TilePool::new`], choosing the shards' DMA mode: `async_dma`
    /// runs every shard simulator in the overlapped non-blocking-DMA
    /// mode (`M1System::with_async_dma`), so tile reports carry the
    /// double-buffered cycle counts (§Perf PR 5). The determinism
    /// contract is unchanged within a mode: pooled output and accounting
    /// are bit-for-bit serial execution's, for any shard count.
    pub fn with_mode(shards: usize, async_dma: bool) -> TilePool {
        Self::with_faults(shards, async_dma, None)
    }

    /// As [`TilePool::with_mode`], with a deterministic fault-injection
    /// schedule every shard consults at each dispatch (test/chaos only —
    /// see [`FaultPlan`]). Injected faults exercise the supervision paths
    /// without changing any result.
    pub fn with_faults(shards: usize, async_dma: bool, faults: Option<FaultPlan>) -> TilePool {
        let shards = shards.max(1);
        let routines: SharedRoutines = Arc::new(Mutex::new(HashMap::new()));
        let stats = Arc::new(PoolStats::default());
        let recovery = Box::new(Shard::new(routines.clone(), async_dma, None, stats.clone()));
        if shards == 1 {
            let inline =
                Box::new(Shard::new(routines.clone(), async_dma, faults.clone(), stats.clone()));
            return TilePool {
                shards,
                async_dma,
                exec: Exec::Inline(inline),
                routines,
                faults,
                stats,
                recovery,
            };
        }
        let mut feeds = Vec::with_capacity(shards);
        let mut handles = Vec::with_capacity(shards);
        for s in 0..shards {
            let (tx, handle) =
                spawn_shard(s, routines.clone(), async_dma, faults.clone(), stats.clone());
            feeds.push(tx);
            handles.push(handle);
        }
        TilePool {
            shards,
            async_dma,
            exec: Exec::Threads { feeds, handles },
            routines,
            faults,
            stats,
            recovery,
        }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Whether this pool's shards run in async-DMA mode.
    pub fn async_dma(&self) -> bool {
        self.async_dma
    }

    /// Number of distinct routine specs compiled into the cross-shard
    /// cache so far (each compiled exactly once per pool).
    pub fn cached_routines(&self) -> usize {
        self.routines.lock().unwrap().len()
    }

    /// Cumulative supervision counters (see [`PoolHealth`]).
    pub fn health(&self) -> PoolHealth {
        PoolHealth {
            crashes: self.stats.crashes.load(Ordering::Relaxed),
            restarts: self.stats.restarts.load(Ordering::Relaxed),
            redispatched: self.stats.redispatched.load(Ordering::Relaxed),
            recovery_max_us: self.stats.recovery_max_us.load(Ordering::Relaxed),
        }
    }

    /// Execute a tile plan. Outcomes are returned in tile order, each tile
    /// completing **exactly once** even across shard crashes, deaths and
    /// lost replies; see the module docs for the determinism contract.
    pub fn run(&mut self, tiles: Vec<TileRequest>) -> Vec<TileOutcome> {
        let faults = self.faults.clone();
        if let Exec::Inline(shard) = &mut self.exec {
            return tiles
                .iter()
                .map(|t| {
                    let mut action =
                        faults.as_ref().map(|f| f.on_dispatch()).unwrap_or(FaultAction::None);
                    if action == FaultAction::Die {
                        // There is no thread to kill inline; a death
                        // injection degrades to a supervised crash.
                        action = FaultAction::Panic;
                    }
                    shard
                        .run_tile_supervised(t, action)
                        .unwrap_or_else(|| shard.run_tile(t))
                })
                .collect();
        }
        let n = tiles.len();
        if n == 0 {
            return Vec::new();
        }
        let mut out: Vec<Option<TileOutcome>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        let mut filled = 0usize;
        let tasks;
        {
            let Exec::Threads { feeds, .. } = &mut self.exec else { unreachable!() };
            // Chunks small enough that every shard claims several
            // (self-balancing), large enough to amortize the claim.
            let chunk = (n / (feeds.len() * 4)).max(1);
            tasks = Arc::new(TaskSet { tiles, next: AtomicUsize::new(0), chunk });
            let (tx, rx) = mpsc::channel();
            for feed in feeds.iter() {
                // A send only fails if that shard is already dead; its
                // tiles reach the recovery pass below either way.
                let _ = feed.send(Batch { tasks: tasks.clone(), reply: tx.clone() });
            }
            drop(tx);
            while filled < n {
                match rx.recv() {
                    Ok((i, outcome)) => {
                        if out[i].is_none() {
                            out[i] = Some(outcome);
                            filled += 1;
                        }
                    }
                    // Every shard finished the batch (or died) with
                    // replies still missing: recover below.
                    Err(_) => break,
                }
            }
        }
        if filled < n {
            let t0 = Instant::now();
            for (i, slot) in out.iter_mut().enumerate() {
                if slot.is_some() {
                    continue;
                }
                let tile = &tasks.tiles[i];
                let outcome = self
                    .recovery
                    .run_tile_supervised(tile, FaultAction::None)
                    .unwrap_or_else(|| self.recovery.run_tile(tile));
                self.stats.redispatched.fetch_add(1, Ordering::Relaxed);
                *slot = Some(outcome);
            }
            self.stats
                .recovery_max_us
                .fetch_max(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
            self.respawn_dead_shards();
        }
        out.into_iter()
            .map(|o| o.expect("every tile completes exactly once"))
            .collect()
    }

    /// Replace any shard thread that has exited (injected `Die` faults or
    /// a real thread death) with a fresh one on the same feed slot, so
    /// capacity recovers before the next batch.
    fn respawn_dead_shards(&mut self) {
        let Exec::Threads { feeds, handles } = &mut self.exec else { return };
        for s in 0..handles.len() {
            if !handles[s].is_finished() {
                continue;
            }
            let (tx, handle) = spawn_shard(
                s,
                self.routines.clone(),
                self.async_dma,
                self.faults.clone(),
                self.stats.clone(),
            );
            feeds[s] = tx;
            let old = std::mem::replace(&mut handles[s], handle);
            let _ = old.join();
        }
    }

    /// Convenience for the §5.1 multi-tile workloads: run an element-wise
    /// vector-vector op (`n` a multiple of 64) as independent 64-point
    /// tiles across the pool. Returns the spliced result and the summed
    /// cycle count — the pool-targeted counterpart of the monolithic
    /// [`crate::mapping::TiledVecVecMapping`] program, with identical
    /// results (pinned by the `streamed` tests).
    pub fn run_vecvec(&mut self, op: AluOp, u: &[i16], v: &[i16]) -> (Vec<i16>, u64) {
        assert_eq!(u.len(), v.len(), "operand length mismatch");
        assert!(
            !u.is_empty() && u.len() % 64 == 0,
            "pooled vecvec needs a multiple of 64 elements"
        );
        let tiles: Vec<TileRequest> = u
            .chunks(64)
            .zip(v.chunks(64))
            .map(|(uc, vc)| TileRequest {
                spec: RoutineSpec::VecVec { n: 64, op },
                u: uc.to_vec(),
                v: Some(vc.to_vec()),
            })
            .collect();
        let mut result = Vec::with_capacity(u.len());
        let mut cycles = 0u64;
        for outcome in self.run(tiles) {
            cycles += outcome.report.cycles;
            result.extend_from_slice(&outcome.result);
        }
        (result, cycles)
    }
}

impl Drop for TilePool {
    fn drop(&mut self) {
        if let Exec::Threads { feeds, handles } = &mut self.exec {
            feeds.clear(); // closing the feeds ends every shard's recv loop
            for h in handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

/// Spawn one shard worker thread; returns its feed plus the join handle.
fn spawn_shard(
    s: usize,
    shared: SharedRoutines,
    async_dma: bool,
    faults: Option<FaultPlan>,
    stats: Arc<PoolStats>,
) -> (mpsc::Sender<Batch>, JoinHandle<()>) {
    let (tx, rx) = mpsc::channel::<Batch>();
    let handle = std::thread::Builder::new()
        .name(format!("m1-shard-{s}"))
        .spawn(move || {
            let mut shard = Shard::new(shared, async_dma, faults, stats);
            while let Ok(batch) = rx.recv() {
                if !drain_batch(&mut shard, &batch) {
                    return; // injected shard death: abandon the feed
                }
            }
        })
        .expect("spawn tile-pool shard");
    (tx, handle)
}

/// Shard side of a batch: claim chunks of tile indices until the cursor
/// passes the end, running each tile supervised and replying with its
/// index. Returns `false` when an injected `Die` fault kills the shard —
/// the thread must exit, abandoning the rest of its claimed chunk (the
/// caller's recovery pass picks those tiles up).
fn drain_batch(shard: &mut Shard, batch: &Batch) -> bool {
    let tasks = &batch.tasks;
    loop {
        let start = tasks.next.fetch_add(tasks.chunk, Ordering::Relaxed);
        if start >= tasks.tiles.len() {
            return true;
        }
        let end = (start + tasks.chunk).min(tasks.tiles.len());
        for i in start..end {
            let action =
                shard.faults.as_ref().map(|f| f.on_dispatch()).unwrap_or(FaultAction::None);
            if action == FaultAction::Die {
                shard.stats.crashes.fetch_add(1, Ordering::Relaxed);
                return false; // hard shard death mid-chunk
            }
            let Some(outcome) = shard.run_tile_supervised(&tasks.tiles[i], action) else {
                continue; // double fault: the caller's recovery pass owns it
            };
            if shard.faults.as_ref().is_some_and(|f| f.take_drop_reply()) {
                continue; // injected lost reply: recovery makes it whole
            }
            if batch.reply.send((i, outcome)).is_err() {
                return true; // caller went away mid-batch
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn add_tiles(n_tiles: usize) -> (Vec<TileRequest>, Vec<i16>) {
        let mut tiles = Vec::new();
        let mut expected = Vec::new();
        for t in 0..n_tiles {
            let u: Vec<i16> = (0..64).map(|i| (t * 64 + i) as i16).collect();
            let v: Vec<i16> = (0..64).map(|i| 1000 - (t as i16) - (i as i16)).collect();
            expected.extend(u.iter().zip(&v).map(|(a, b)| a + b));
            tiles.push(TileRequest {
                spec: RoutineSpec::VecVec { n: 64, op: AluOp::Add },
                u,
                v: Some(v),
            });
        }
        (tiles, expected)
    }

    fn splice(outcomes: &[TileOutcome]) -> Vec<i16> {
        outcomes.iter().flat_map(|o| o.result.iter().copied()).collect()
    }

    /// Full comparison of a faulted run against the fault-free baseline:
    /// results, cycles, slots — the bit-identical contract.
    fn assert_identical(out: &[TileOutcome], baseline: &[TileOutcome], what: &str) {
        assert_eq!(splice(out), splice(baseline), "{what}: results");
        for (a, b) in out.iter().zip(baseline) {
            assert_eq!(a.report.cycles, b.report.cycles, "{what}: cycles");
            assert_eq!(a.report.slots, b.report.slots, "{what}: slots");
        }
    }

    #[test]
    fn inline_pool_runs_tiles_in_order() {
        let mut pool = TilePool::new(1);
        assert_eq!(pool.shards(), 1);
        let (tiles, expected) = add_tiles(5);
        let out = pool.run(tiles);
        assert_eq!(splice(&out), expected);
        assert!(out.iter().all(|o| o.report.cycles == 96), "translation-64 is 96 cycles");
    }

    #[test]
    fn threaded_pool_matches_inline_bit_for_bit() {
        let (tiles, _) = add_tiles(13);
        let mut serial = TilePool::new(1);
        let baseline = serial.run(tiles.clone());
        for shards in [2usize, 4, 8] {
            let mut pool = TilePool::new(shards);
            let out = pool.run(tiles.clone());
            assert_eq!(splice(&out), splice(&baseline), "shards={shards}");
            for (a, b) in out.iter().zip(&baseline) {
                assert_eq!(a.report.cycles, b.report.cycles);
                assert_eq!(a.report.slots, b.report.slots);
                assert_eq!(a.report.broadcasts, b.report.broadcasts);
            }
        }
    }

    #[test]
    fn more_shards_than_tiles_is_fine() {
        let (tiles, expected) = add_tiles(2);
        let mut pool = TilePool::new(8);
        assert_eq!(splice(&pool.run(tiles)), expected);
        // And an empty plan returns an empty result without deadlock.
        assert!(pool.run(Vec::new()).is_empty());
    }

    #[test]
    fn pool_is_reusable_across_batches() {
        let mut pool = TilePool::new(3);
        for round in 0..4 {
            let (tiles, expected) = add_tiles(round + 1);
            assert_eq!(splice(&pool.run(tiles)), expected, "round {round}");
        }
    }

    #[test]
    fn run_vecvec_matches_native_reference() {
        let n = 320;
        let u: Vec<i16> = (0..n as i16).collect();
        let v: Vec<i16> = (0..n as i16).map(|i| 3 * i - 7).collect();
        let expected: Vec<i16> = u.iter().zip(&v).map(|(a, b)| a.wrapping_add(*b)).collect();
        let mut serial = TilePool::new(1);
        let (r1, c1) = serial.run_vecvec(AluOp::Add, &u, &v);
        let mut pooled = TilePool::new(4);
        let (r4, c4) = pooled.run_vecvec(AluOp::Add, &u, &v);
        assert_eq!(r1, expected);
        assert_eq!(r4, expected);
        assert_eq!(c1, c4, "cycle aggregation must not depend on shard count");
        assert_eq!(c1, (n as u64 / 64) * 96);
    }

    #[test]
    fn routine_cache_is_shared_across_shards() {
        // 32 tiles of one spec across 4 shards: every shard touches the
        // spec, yet the pool-wide cache holds exactly one compile.
        let (tiles, expected) = add_tiles(32);
        let mut pool = TilePool::new(4);
        assert_eq!(pool.cached_routines(), 0);
        let out = pool.run(tiles);
        assert_eq!(splice(&out), expected);
        assert_eq!(pool.cached_routines(), 1);
        // A second spec adds exactly one more entry.
        let xs: Vec<i16> = (0..64).collect();
        pool.run(vec![TileRequest {
            spec: RoutineSpec::VecVec { n: 64, op: AluOp::Sub },
            u: xs.clone(),
            v: Some(xs),
        }]);
        assert_eq!(pool.cached_routines(), 2);
    }

    #[test]
    fn async_dma_pool_matches_serial_async_bit_for_bit() {
        // The §Perf PR 5 mode: every shard simulator in async-DMA mode.
        // Results are identical to the blocking pool's; cycle reports are
        // the overlapped counts, and both are shard-count-independent.
        let (tiles, expected) = add_tiles(13);
        let mut serial = TilePool::with_mode(1, true);
        assert!(serial.async_dma());
        let baseline = serial.run(tiles.clone());
        assert_eq!(splice(&baseline), expected);
        let blocking = TilePool::new(1).run(tiles.clone());
        for (a, b) in baseline.iter().zip(&blocking) {
            assert_eq!(a.result, b.result, "DMA mode must not change results");
            assert!(a.report.cycles <= b.report.cycles, "async must not be slower");
        }
        for shards in [2usize, 4, 8] {
            let mut pool = TilePool::with_mode(shards, true);
            let out = pool.run(tiles.clone());
            assert_eq!(splice(&out), splice(&baseline), "shards={shards}");
            for (a, b) in out.iter().zip(&baseline) {
                assert_eq!(a.report.cycles, b.report.cycles);
                assert_eq!(a.report.slots, b.report.slots);
                assert_eq!(a.report.broadcasts, b.report.broadcasts);
            }
        }
    }

    #[test]
    fn mixed_specs_in_one_batch() {
        // Point-transform and vecvec tiles interleaved: the shared
        // routine cache (and each shard's read cache over it) must key
        // correctly on the spec.
        let xs: Vec<i16> = (0..64).collect();
        let ys: Vec<i16> = (0..64).map(|i| i - 32).collect();
        let tiles = vec![
            TileRequest {
                spec: RoutineSpec::PointTransform { n: 64, m: [1, 0, 0, 1], t: [5, -3], shift: 0 },
                u: xs.clone(),
                v: Some(ys.clone()),
            },
            TileRequest {
                spec: RoutineSpec::VecVec { n: 64, op: AluOp::Sub },
                u: xs.clone(),
                v: Some(ys.clone()),
            },
        ];
        let mut pool = TilePool::new(2);
        let out = pool.run(tiles);
        let (xp, yp) = out[0].result.split_at(64);
        for i in 0..64 {
            assert_eq!(xp[i], xs[i] + 5);
            assert_eq!(yp[i], ys[i] - 3);
            assert_eq!(out[1].result[i], xs[i] - ys[i]);
        }
    }

    #[test]
    fn plan_level_specs_run_on_the_megakernel_tier() {
        // One VecVecPlan request covers what four per-tile requests
        // would, and a PointTransformPlan returns the plan layout
        // ([all x'][all y']) with the same transformed values.
        let n = 256;
        let u: Vec<i16> = (0..n as i16).collect();
        let v: Vec<i16> = (0..n as i16).map(|i| 2 * i - 100).collect();
        let mut pool = TilePool::new(1);
        let sum = pool.run(vec![TileRequest {
            spec: RoutineSpec::VecVecPlan { n, op: AluOp::Add },
            u: u.clone(),
            v: Some(v.clone()),
        }]);
        let expected: Vec<i16> = u.iter().zip(&v).map(|(a, b)| a.wrapping_add(*b)).collect();
        assert_eq!(sum[0].result, expected);

        let xf = pool.run(vec![TileRequest {
            spec: RoutineSpec::PointTransformPlan { n, m: [1, 0, 0, 1], t: [5, -3], shift: 0 },
            u: u.clone(),
            v: Some(v.clone()),
        }]);
        let (xp, yp) = xf[0].result.split_at(n);
        for i in 0..n {
            assert_eq!(xp[i], u[i] + 5, "x'[{i}]");
            assert_eq!(yp[i], v[i] - 3, "y'[{i}]");
        }
    }

    #[test]
    fn plan_level_specs_are_bit_identical_across_shard_counts() {
        let mk = |k: usize| {
            let u: Vec<i16> = (0..128).map(|i| (i + 64 * k) as i16).collect();
            let v: Vec<i16> = (0..128).map(|i| (i as i16) - 7 * k as i16).collect();
            TileRequest {
                spec: RoutineSpec::PointTransformPlan {
                    n: 128,
                    m: [2, -1, 1, 2],
                    t: [9, -4],
                    shift: 0,
                },
                u,
                v: Some(v),
            }
        };
        let tiles: Vec<TileRequest> = (0..6).map(mk).collect();
        let baseline = TilePool::with_mode(1, true).run(tiles.clone());
        for shards in [2usize, 4] {
            let out = TilePool::with_mode(shards, true).run(tiles.clone());
            assert_identical(&out, &baseline, "plan specs");
        }
    }

    // ── supervision ────────────────────────────────────────────────────

    #[test]
    fn injected_panic_is_supervised_and_results_stay_bit_identical() {
        let (tiles, _) = add_tiles(16);
        let baseline = TilePool::new(1).run(tiles.clone());
        let plan = FaultPlan::panic_at(11, 5);
        let mut pool = TilePool::with_faults(4, false, Some(plan.clone()));
        let out = pool.run(tiles);
        assert_identical(&out, &baseline, "panic injection");
        assert_eq!(plan.panics_fired(), 1, "the scheduled fault must actually fire");
        let health = pool.health();
        assert!(health.crashes >= 1, "crash must be counted: {health:?}");
        assert!(health.restarts >= 1, "shard must warm-restart: {health:?}");
    }

    #[test]
    fn inline_pool_survives_injected_panic() {
        let (tiles, expected) = add_tiles(6);
        let plan = FaultPlan::panic_at(3, 2);
        let mut pool = TilePool::with_faults(1, false, Some(plan.clone()));
        assert_eq!(splice(&pool.run(tiles)), expected);
        assert_eq!(plan.panics_fired(), 1);
        assert!(pool.health().restarts >= 1);
    }

    #[test]
    fn shard_death_redispatches_the_lost_tiles_and_respawns() {
        let (tiles, _) = add_tiles(24);
        let baseline = TilePool::new(1).run(tiles.clone());
        let plan = FaultPlan::shard_death_at(5, 7);
        let mut pool = TilePool::with_faults(3, false, Some(plan.clone()));
        let out = pool.run(tiles.clone());
        assert_identical(&out, &baseline, "shard death");
        assert_eq!(plan.deaths_fired(), 1);
        let health = pool.health();
        assert!(health.redispatched >= 1, "abandoned tiles must be re-run: {health:?}");
        assert!(health.recovery_max_us > 0, "recovery time must be recorded");
        // The dead shard was respawned: the pool serves the next batch at
        // full capacity, still bit-identical.
        let again = pool.run(tiles);
        assert_identical(&again, &baseline, "post-respawn batch");
    }

    #[test]
    fn dropped_replies_are_recovered_exactly_once() {
        let (tiles, _) = add_tiles(12);
        let baseline = TilePool::new(1).run(tiles.clone());
        let plan = FaultPlan::drop_reply_at(9, 4);
        let mut pool = TilePool::with_faults(2, false, Some(plan.clone()));
        let out = pool.run(tiles);
        assert_identical(&out, &baseline, "dropped reply");
        assert_eq!(plan.drops_fired(), 1);
        assert!(pool.health().redispatched >= 1);
    }

    #[test]
    fn chaos_profile_stays_bit_identical_under_compound_faults() {
        // Panics, deaths, stalls and drops all firing in one batch — the
        // whole supervision stack at once, and the result must still be
        // exactly the fault-free serial result, every tile exactly once.
        let (tiles, _) = add_tiles(64);
        let baseline = TilePool::new(1).run(tiles.clone());
        let plan = FaultPlan::chaos(0xC0FFEE);
        let mut pool = TilePool::with_faults(4, false, Some(plan.clone()));
        let out = pool.run(tiles.clone());
        assert_identical(&out, &baseline, "chaos");
        assert!(
            plan.panics_fired() + plan.deaths_fired() + plan.drops_fired() > 0,
            "chaos must actually inject something over 64 dispatches"
        );
        // And the pool keeps serving after the storm.
        assert_identical(&pool.run(tiles), &baseline, "post-chaos batch");
    }

    #[test]
    fn stall_faults_change_timing_only() {
        let (tiles, _) = add_tiles(8);
        let baseline = TilePool::new(1).run(tiles.clone());
        let plan = FaultPlan::stall_every(3, 2, Duration::from_micros(200));
        let mut pool = TilePool::with_faults(2, false, Some(plan));
        let out = pool.run(tiles);
        assert_identical(&out, &baseline, "stalls");
        let health = pool.health();
        assert_eq!(health.crashes, 0);
        assert_eq!(health.restarts, 0);
    }

    #[test]
    fn crash_dumps_a_replayable_repro_artifact() {
        // Opt into artifact dumping via the env knob, crash one tile, and
        // check the artifact replays cleanly to its recorded digests.
        let dir = std::env::temp_dir().join(format!("m1-repro-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::env::set_var("MORPHO_REPRO_DIR", &dir);
        let (tiles, expected) = add_tiles(4);
        let plan = FaultPlan::panic_at(99, 2);
        let mut pool = TilePool::with_faults(1, false, Some(plan));
        let out = pool.run(tiles);
        std::env::remove_var("MORPHO_REPRO_DIR");
        assert_eq!(splice(&out), expected, "results survive the crash");
        // Other concurrently-crashing tests may dump here while the env
        // var is set; key on the seed baked into the artifact name.
        let artifact = std::fs::read_dir(&dir)
            .expect("repro dir must exist")
            .filter_map(|e| e.ok())
            .find(|e| {
                e.file_name().to_string_lossy().starts_with("repro-seed99-")
            })
            .expect("crash must dump an artifact");
        let art = ReproArtifact::read_from(&artifact.path()).unwrap();
        assert_eq!(art.seed, 99);
        assert!(art.summary.contains("shard crash"));
        assert!(art.replay().unwrap().is_match(), "artifact must reproduce cleanly");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
