//! Execution backends for tile jobs: native rust, the XLA runtime over
//! the AOT artifacts, and the cycle-accurate M1 simulator running the
//! paper's mappings.

use anyhow::Result;

use crate::graphics::{FixedPointParams, Mat3};
use crate::runtime::Executor;

use crate::mapping::{megakernel_for, MegaSpec};

use super::faults::FaultPlan;
use super::pool::{PoolHealth, RoutineSpec, TilePool, TileRequest};

/// Which backend served a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendKind {
    Native,
    Xla,
    M1Sim,
}

impl BackendKind {
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Xla => "xla",
            BackendKind::M1Sim => "m1sim",
        }
    }
}

/// A tile-job executor. Implementations live on one worker thread (the
/// XLA backend is deliberately `!Send`: PJRT clients are thread-pinned).
pub trait Backend {
    fn kind(&self) -> BackendKind;

    /// Apply the affine transform `params = [a,b,c,d,tx,ty]` to the job
    /// buffers in place. Returns simulated cycles per point when the
    /// backend models hardware (the M1 simulator).
    fn apply(&mut self, params: &[f32; 6], xs: &mut [f32], ys: &mut [f32]) -> Result<Option<f64>>;

    /// Cumulative supervision counters, for backends that run supervised
    /// execution shards (the M1 pool). `None` for stateless backends.
    fn health(&self) -> Option<PoolHealth> {
        None
    }
}

/// Apply the affine params on the CPU (shared by the native backend and
/// the error/overflow fallbacks).
pub fn apply_native(params: &[f32; 6], xs: &mut [f32], ys: &mut [f32]) {
    let [a, b, c, d, tx, ty] = *params;
    for i in 0..xs.len() {
        let (x, y) = (xs[i], ys[i]);
        xs[i] = a * x + b * y + tx;
        ys[i] = c * x + d * y + ty;
    }
}

/// Plain rust reference backend.
#[derive(Debug, Default)]
pub struct NativeBackend;

impl Backend for NativeBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Native
    }

    fn apply(&mut self, params: &[f32; 6], xs: &mut [f32], ys: &mut [f32]) -> Result<Option<f64>> {
        apply_native(params, xs, ys);
        Ok(None)
    }
}

/// The AOT-artifact backend: picks the smallest `affine<n>` artifact that
/// fits, pads, executes on PJRT, and slices the outputs back.
pub struct XlaBackend {
    exec: Executor,
    /// Available affine tile sizes, ascending (e.g. [64, 1024, 4096]).
    tiles: Vec<usize>,
}

impl XlaBackend {
    pub fn new(exec: Executor) -> Result<XlaBackend> {
        let mut tiles: Vec<usize> = exec
            .registry()
            .names()
            .filter_map(|n| n.strip_prefix("affine").and_then(|s| s.parse().ok()))
            .collect();
        tiles.sort_unstable();
        anyhow::ensure!(!tiles.is_empty(), "no affine<n> artifacts found");
        // Warm the executable cache so serving latency excludes compiles.
        let names: Vec<String> = tiles.iter().map(|t| format!("affine{t}")).collect();
        exec.warm_up(names.iter().map(String::as_str))?;
        Ok(XlaBackend { exec, tiles })
    }

    pub fn discover() -> Result<XlaBackend> {
        XlaBackend::new(Executor::discover()?)
    }

    /// Tile choice (§Perf): see [`choose_tile`]. (The original smallest-≥
    /// rule padded a 2 117-point job to 4 096 — a 2× waste; greedy
    /// 1024+1024+64×2 chunks cut the animation pipeline's XLA job latency
    /// ~40%.)
    fn tile_for(&self, n: usize) -> usize {
        choose_tile(&self.tiles, n)
    }
}

/// Pick the artifact tile for `n` remaining points from `tiles` (sorted
/// ascending, non-empty): greedily the *largest* tile that fits, unless a
/// single covering tile finishes the job with less padding waste than the
/// big tile would process — e.g. with tiles {64, 128}, 80 points run as
/// one padded 128-tile call (48 wasted lanes) rather than two 64-tile
/// calls. With no tile ≤ n, the smallest covering tile is the only
/// choice.
pub(crate) fn choose_tile(tiles: &[usize], n: usize) -> usize {
    let biggest_fitting = tiles.iter().rev().find(|&&t| t <= n).copied();
    let smallest_covering = tiles.iter().find(|&&t| t >= n).copied();
    match (biggest_fitting, smallest_covering) {
        (Some(fit), Some(cover)) => {
            if cover - n < fit {
                cover
            } else {
                fit
            }
        }
        (Some(fit), None) => fit,
        (None, Some(cover)) => cover,
        (None, None) => unreachable!("XlaBackend guarantees a non-empty tile list"),
    }
}

impl Backend for XlaBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::Xla
    }

    fn apply(&mut self, params: &[f32; 6], xs: &mut [f32], ys: &mut [f32]) -> Result<Option<f64>> {
        let n = xs.len();
        let mut done = 0usize;
        while done < n {
            let tile = self.tile_for(n - done);
            let len = tile.min(n - done);
            let mut tx = vec![0.0f32; tile];
            let mut ty = vec![0.0f32; tile];
            tx[..len].copy_from_slice(&xs[done..done + len]);
            ty[..len].copy_from_slice(&ys[done..done + len]);
            let out = self.exec.run_f32(&format!("affine{tile}"), &[&tx, &ty, params])?;
            xs[done..done + len].copy_from_slice(&out[0][..len]);
            ys[done..done + len].copy_from_slice(&out[1][..len]);
            done += len;
        }
        Ok(None)
    }
}

/// The MorphoSys backend: quantizes the transform to the M1's fixed-point
/// context immediates, runs the §5.2/§5.3 point-transform mapping on the
/// cycle-accurate simulator 64 points at a time, and reports simulated
/// cycles. Falls back to the native path (with a `None` cycle count) when
/// the transform or coordinates exceed the 16-bit datapath.
///
/// Execution targets the sharded [`TilePool`]: with the default
/// `shards = 1` the tile plan runs inline on the caller thread (the
/// serial mode, bit-for-bit the pre-pool behaviour); with
/// [`M1SimBackend::with_shards`] the independent 64-point tiles fan out
/// across pool shards, each owning its own simulator while sharing one
/// pool-wide compiled-routine cache and the process-wide schedule cache
/// (one compile per distinct program, not per shard — §Perf). Runs of
/// full tiles dispatch as plan-level **megakernel** chunks (fixed
/// `MEGA_TILES`-tile granularity, compiled once per transform shape in
/// the process-wide megakernel cache); only single full tiles and the
/// ragged tail take the per-tile path. Outputs and aggregate cycle
/// counts are identical across shard counts (see the pool's determinism
/// contract; pinned by `tests/conformance.rs`).
pub struct M1SimBackend {
    pool: TilePool,
    /// Fixed-point shift for the 2×2 matrix (Q6 default).
    pub shift: u8,
}

impl M1SimBackend {
    /// Serial backend (`shards = 1`).
    pub fn new() -> M1SimBackend {
        M1SimBackend::with_shards(1)
    }

    /// Backend over a pool with `shards` execution shards (blocking-DMA
    /// simulators, the paper's published listing model).
    pub fn with_shards(shards: usize) -> M1SimBackend {
        M1SimBackend::with_config(shards, false)
    }

    /// Backend over a pool with `shards` execution shards and an explicit
    /// DMA mode: `async_dma` runs every shard simulator in the
    /// overlapped non-blocking mode (§Perf PR 5), so reported simulated
    /// cycles reflect the M1's double-buffered frame-buffer overlap.
    /// Functional outputs are identical in both modes.
    pub fn with_config(shards: usize, async_dma: bool) -> M1SimBackend {
        M1SimBackend::with_faults(shards, async_dma, None)
    }

    /// As [`M1SimBackend::with_config`], with a deterministic
    /// fault-injection schedule for the pool's shards (chaos/test only —
    /// see [`FaultPlan`]). Results stay bit-identical to a fault-free
    /// backend; only timing and the [`PoolHealth`] counters change.
    pub fn with_faults(
        shards: usize,
        async_dma: bool,
        faults: Option<FaultPlan>,
    ) -> M1SimBackend {
        M1SimBackend { pool: TilePool::with_faults(shards, async_dma, faults), shift: 6 }
    }

    pub fn shards(&self) -> usize {
        self.pool.shards()
    }

    /// Whether the backing pool simulates in async-DMA mode.
    pub fn async_dma(&self) -> bool {
        self.pool.async_dma()
    }

    fn quantizable(params: &[f32; 6], shift: u8) -> Option<FixedPointParams> {
        let [a, b, c, d, tx, ty] = *params;
        let mat = Mat3 { m: [[a, b, tx], [c, d, ty], [0.0, 0.0, 1.0]] };
        FixedPointParams::quantize(&mat, shift)
    }
}

impl Default for M1SimBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for M1SimBackend {
    fn kind(&self) -> BackendKind {
        BackendKind::M1Sim
    }

    fn health(&self) -> Option<PoolHealth> {
        Some(self.pool.health())
    }

    fn apply(&mut self, params: &[f32; 6], xs: &mut [f32], ys: &mut [f32]) -> Result<Option<f64>> {
        let n = xs.len();
        if n == 0 {
            return Ok(None);
        }
        let fp = match Self::quantizable(params, self.shift) {
            Some(fp) => fp,
            None => {
                apply_native(params, xs, ys);
                return Ok(None);
            }
        };
        // Coordinates must fit i16 after transform; headroom check on
        // inputs (|coord| ≤ 2^13 keeps Q6 products inside 32-bit acc and
        // outputs inside i16 for |entries| ≤ 2).
        let limit = 8192.0f32;
        if xs.iter().chain(ys.iter()).any(|v| v.abs() > limit) {
            apply_native(params, xs, ys);
            return Ok(None);
        }

        // Build the tile plan. Runs of full 64-point tiles group into
        // plan-level megakernel requests of up to MEGA_TILES tiles each
        // (§Perf, megakernel tier): one compiled schedule per chunk
        // shape, context loaded once, DMA streams batched across tile
        // boundaries. The chunk size is a constant so the decomposition
        // — and therefore the aggregate cycle count — is independent of
        // shard count. A single full tile gains nothing from a plan, and
        // the ragged tail needs per-tile padding, so both keep the
        // per-tile path; shapes with no plan-level program (out-of-range
        // translations) degrade to all-per-tile.
        const MEGA_TILES: usize = 16;
        // Per-request splice info: (live points, x/y split offset) —
        // plan results are [all x'][all y'], per-tile results are
        // [x'; padded][y'; padded].
        let mut tiles = Vec::with_capacity(n.div_ceil(64 * MEGA_TILES) + 2);
        let mut pieces: Vec<(usize, usize)> = Vec::with_capacity(tiles.capacity());
        let mut done = 0usize;
        let mut remaining_full = n / 64;
        while remaining_full >= 2 {
            let take = remaining_full.min(MEGA_TILES);
            let len = take * 64;
            let mega = MegaSpec::PointTransform { n: len, m: fp.m, t: fp.t, shift: fp.shift };
            if megakernel_for(&mega).is_none() {
                break; // no plan-level program for this shape: per-tile below
            }
            let mut ix = vec![0i16; len];
            let mut iy = vec![0i16; len];
            for i in 0..len {
                ix[i] = xs[done + i].round() as i16;
                iy[i] = ys[done + i].round() as i16;
            }
            tiles.push(TileRequest {
                spec: RoutineSpec::PointTransformPlan {
                    n: len,
                    m: fp.m,
                    t: fp.t,
                    shift: fp.shift,
                },
                u: ix,
                v: Some(iy),
            });
            pieces.push((len, len));
            done += len;
            remaining_full -= take;
        }
        // Leftover full tiles and the ragged tail: 64-point tiles, the
        // last one padded to a whole column broadcast (multiple of 8).
        while done < n {
            let len = (n - done).min(64);
            let tile = len.div_ceil(8) * 8;
            let mut ix = vec![0i16; tile];
            let mut iy = vec![0i16; tile];
            for i in 0..len {
                ix[i] = xs[done + i].round() as i16;
                iy[i] = ys[done + i].round() as i16;
            }
            tiles.push(TileRequest {
                spec: RoutineSpec::PointTransform { n: tile, m: fp.m, t: fp.t, shift: fp.shift },
                u: ix,
                v: Some(iy),
            });
            pieces.push((len, tile));
            done += len;
        }

        // Fan the plan out across the pool; outcomes come back in tile
        // order and cycles aggregate as the order-independent sum.
        let outcomes = self.pool.run(tiles);
        let mut cycles = 0u64;
        done = 0;
        for (outcome, &(len, half)) in outcomes.iter().zip(&pieces) {
            cycles += outcome.report.cycles;
            let (ox, oy) = outcome.result.split_at(half);
            for i in 0..len {
                xs[done + i] = ox[i] as f32;
                ys[done + i] = oy[i] as f32;
            }
            done += len;
        }
        Ok(Some(cycles as f64 / n as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The tile sequence `apply` would execute for an `n`-point job.
    fn tile_plan(tiles: &[usize], mut n: usize) -> Vec<usize> {
        let mut plan = Vec::new();
        while n > 0 {
            let t = choose_tile(tiles, n);
            plan.push(t);
            n -= t.min(n);
        }
        plan
    }

    #[test]
    fn tile_plan_for_2117_points_is_greedy() {
        // The §Perf doc's motivating case: 2 117 points over the standard
        // {64, 1024, 4096} artifact set run as 1024+1024+64+64 — the
        // 4096-covering tile would waste 1 979 padded lanes, more than a
        // whole 1024 tile processes, so greedy wins at every step.
        assert_eq!(tile_plan(&[64, 1024, 4096], 2117), vec![1024, 1024, 64, 64]);
    }

    #[test]
    fn covering_tile_preferred_when_padding_waste_is_small() {
        // 1 000 points with tiles {64, 1024}: one padded 1024 call (24
        // wasted lanes) beats 15 × 64 + remainder.
        assert_eq!(choose_tile(&[64, 1024], 1000), 1024);
        assert_eq!(tile_plan(&[64, 1024], 1000), vec![1024]);
        // 80 points with tiles {64, 128}: one 128 call (48 wasted lanes,
        // less than the 64 the greedy tile would process) beats 64+64.
        assert_eq!(tile_plan(&[64, 128], 80), vec![128]);
        // Below the smallest tile, the only choice is the smallest tile.
        assert_eq!(choose_tile(&[64, 1024], 5), 64);
        // Exact fits stay exact.
        assert_eq!(choose_tile(&[64, 1024], 1024), 1024);
        assert_eq!(choose_tile(&[64, 1024], 64), 64);
    }

    #[test]
    fn native_backend_applies_affine() {
        let mut b = NativeBackend;
        let mut xs = vec![1.0, 2.0];
        let mut ys = vec![3.0, 4.0];
        let cycles = b.apply(&[2.0, 0.0, 0.0, 2.0, 1.0, -1.0], &mut xs, &mut ys).unwrap();
        assert_eq!(xs, vec![3.0, 5.0]);
        assert_eq!(ys, vec![5.0, 7.0]);
        assert_eq!(cycles, None);
    }

    #[test]
    fn m1sim_backend_matches_native_for_integer_translations() {
        let mut m1 = M1SimBackend::new();
        let params = [1.0, 0.0, 0.0, 1.0, 7.0, -3.0];
        let mut xs: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let mut ys: Vec<f32> = (0..100).map(|i| -(i as f32)).collect();
        let cycles = m1.apply(&params, &mut xs, &mut ys).unwrap();
        assert!(cycles.unwrap() > 0.0);
        let mut nx: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let mut ny: Vec<f32> = (0..100).map(|i| -(i as f32)).collect();
        apply_native(&params, &mut nx, &mut ny);
        assert_eq!(xs, nx);
        assert_eq!(ys, ny);
    }

    #[test]
    fn m1sim_backend_rotation_close_to_native() {
        let mut m1 = M1SimBackend::new();
        let theta = 0.5f32;
        let (s, c) = theta.sin_cos();
        let params = [c, -s, s, c, 0.0, 0.0];
        let mut xs: Vec<f32> = (0..64).map(|i| (i as f32) - 32.0).collect();
        let mut ys: Vec<f32> = (0..64).map(|i| (i as f32) * 0.5).collect();
        let (ox, oy) = (xs.clone(), ys.clone());
        m1.apply(&params, &mut xs, &mut ys).unwrap();
        let (mut nx, mut ny) = (ox, oy);
        apply_native(&params, &mut nx, &mut ny);
        for i in 0..64 {
            assert!((xs[i] - nx[i]).abs() <= 2.5, "x[{i}]: {} vs {}", xs[i], nx[i]);
            assert!((ys[i] - ny[i]).abs() <= 2.5);
        }
    }

    #[test]
    fn m1sim_backend_falls_back_on_unquantizable_transforms() {
        let mut m1 = M1SimBackend::new();
        // Scale 100× is far outside the Q6 i8 range.
        let params = [100.0, 0.0, 0.0, 100.0, 0.0, 0.0];
        let mut xs = vec![1.0, 2.0];
        let mut ys = vec![1.0, 2.0];
        let cycles = m1.apply(&params, &mut xs, &mut ys).unwrap();
        assert_eq!(cycles, None);
        assert_eq!(xs, vec![100.0, 200.0]);
    }

    #[test]
    fn async_dma_backend_matches_blocking_outputs_with_fewer_cycles() {
        // DMA mode is a timing knob, never a results knob: identical
        // transformed points, and the overlapped mode reports at most the
        // blocking cycle count (strictly fewer once a job spans tiles).
        let params = [1.0, 0.0, 0.0, 1.0, 7.0, -3.0];
        let base_x: Vec<f32> = (0..500).map(|i| (i as f32) - 250.0).collect();
        let base_y: Vec<f32> = (0..500).map(|i| (i % 89) as f32).collect();
        let mut blocking = M1SimBackend::new();
        assert!(!blocking.async_dma());
        let (mut bx, mut by) = (base_x.clone(), base_y.clone());
        let bc = blocking.apply(&params, &mut bx, &mut by).unwrap().unwrap();
        let mut overlapped = M1SimBackend::with_config(1, true);
        assert!(overlapped.async_dma());
        let (mut ax, mut ay) = (base_x, base_y);
        let ac = overlapped.apply(&params, &mut ax, &mut ay).unwrap().unwrap();
        assert_eq!(bx, ax);
        assert_eq!(by, ay);
        assert!(ac < bc, "async cycles/point {ac} !< blocking {bc}");
    }

    #[test]
    fn sharded_backend_is_bit_identical_to_serial() {
        let params = [1.0, 0.0, 0.0, 1.0, 7.0, -3.0];
        let base_x: Vec<f32> = (0..500).map(|i| (i as f32) - 250.0).collect();
        let base_y: Vec<f32> = (0..500).map(|i| (i % 97) as f32).collect();
        let mut serial = M1SimBackend::new();
        let (mut sx, mut sy) = (base_x.clone(), base_y.clone());
        let sc = serial.apply(&params, &mut sx, &mut sy).unwrap();
        let mut pooled = M1SimBackend::with_shards(4);
        assert_eq!(pooled.shards(), 4);
        let (mut px, mut py) = (base_x, base_y);
        let pc = pooled.apply(&params, &mut px, &mut py).unwrap();
        assert_eq!(sx, px);
        assert_eq!(sy, py);
        assert_eq!(sc.unwrap().to_bits(), pc.unwrap().to_bits(), "aggregate cycles differ");
    }

    #[test]
    fn megakernel_chunked_jobs_match_native_and_amortize_cycles() {
        // 2 117 points carry 33 full tiles: two 16-tile megakernel chunks
        // plus a leftover full tile and a padded ragged tail. Outputs
        // must equal the native transform exactly for an integer
        // translation, and the plan chunks amortize the per-tile
        // context/DMA preamble, so cycles/point beat a one-tile job.
        let params = [1.0, 0.0, 0.0, 1.0, 7.0, -3.0];
        let mut m1 = M1SimBackend::new();
        let mut xs: Vec<f32> = (0..2117).map(|i| ((i % 167) as f32) - 80.0).collect();
        let mut ys: Vec<f32> = (0..2117).map(|i| ((i % 59) as f32) - 30.0).collect();
        let (mut nx, mut ny) = (xs.clone(), ys.clone());
        let cpp_big = m1.apply(&params, &mut xs, &mut ys).unwrap().unwrap();
        apply_native(&params, &mut nx, &mut ny);
        assert_eq!(xs, nx);
        assert_eq!(ys, ny);
        let mut small = (vec![1.0f32; 64], vec![2.0f32; 64]);
        let cpp_small = m1.apply(&params, &mut small.0, &mut small.1).unwrap().unwrap();
        assert!(cpp_big < cpp_small, "megakernel {cpp_big} !< per-tile {cpp_small}");
    }

    #[test]
    fn m1sim_cycle_rate_improves_with_batch_size() {
        // The paper's Figure 11 vs 12 insight: bigger tiles amortize the
        // DMA/config preamble, so cycles/point falls with n.
        let mut m1 = M1SimBackend::new();
        let params = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let mut small = (vec![1.0f32; 8], vec![1.0f32; 8]);
        let cpp_small =
            m1.apply(&params, &mut small.0, &mut small.1).unwrap().unwrap();
        let mut big = (vec![1.0f32; 64], vec![1.0f32; 64]);
        let cpp_big = m1.apply(&params, &mut big.0, &mut big.1).unwrap().unwrap();
        assert!(cpp_big < cpp_small, "{cpp_big} !< {cpp_small}");
    }
}
