//! The fault-tolerant front-end router (§Scale): one wire-protocol-v1
//! listener proxying requests over loopback to N backend coordinator
//! processes — the horizontal scale-out tier above [`super::WireServer`].
//!
//! ## Balancing
//!
//! Each backend is polled with kind-5 health frames every
//! [`RouterConfig::health_interval`]; a request goes to the live backend
//! with the **least reported queue depth**, falling back to round-robin
//! when depths tie or any report is stale. Clients speak plain wire v1
//! to the router and cannot tell it from a single coordinator.
//!
//! ## Breakers and failover
//!
//! Every backend runs a three-state breaker:
//!
//! ```text
//!            poll timeout                poll timeout / conn error
//!  Healthy ───────────────▶ Suspect ───────────────▶ Dead
//!     ▲                        │                       │
//!     └── health reply ────────┘      reconnect with seeded-jitter
//!     ▲                               exponential backoff, healthy on
//!     └───────────────────────────────the first health reply ◀───────┘
//! ```
//!
//! A dying backend's in-flight requests are harvested (after its link
//! reader is joined, so no reply can race the harvest) and re-dispatched
//! to a live backend — safe because requests are pure functions of their
//! payload, and **exactly-once** because a pending-map entry is removed
//! by exactly one party: the link reader (reply arrived) or the breaker
//! (link dead). When every backend is dead, clients get an immediate
//! [`RejectReason::Unavailable`] rejection instead of a hang.
//!
//! [`Router::metrics`] aggregates the newest health report from every
//! backend plus the router's own proxy/failover counters into one
//! consistent [`ClusterSnapshot`] — what the failover loadgen scenario
//! reads into `BENCH_coordinator.json`.

use std::collections::HashMap;
use std::io;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::faults::splitmix64;
use super::metrics::{BackendSnapshot, ClusterSnapshot};
use super::request::{RejectReason, Rejection, ServeResult, TransformRequest};
use super::wire::{self, Frame, HealthStats};

/// Router knobs. The defaults suit loopback backends; everything is a
/// plain field so tests and scenarios can tighten or loosen at will.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Backend coordinator listen addresses, in index order.
    pub backends: Vec<SocketAddr>,
    /// How often each live backend is health-polled.
    pub health_interval: Duration,
    /// How long a poll may go unanswered before it counts as a strike
    /// (Healthy → Suspect → Dead). Also the per-attempt connect timeout.
    pub health_timeout: Duration,
    /// First reconnect backoff step for a dead backend.
    pub reconnect_base: Duration,
    /// Backoff ceiling (with seeded jitter the sleep stays below this).
    pub reconnect_max: Duration,
    /// How many times one request may be re-dispatched after backend
    /// deaths before it is rejected `Unavailable`.
    pub max_redispatch: u32,
    /// Seed for the reconnect jitter (determinism under test).
    pub seed: u64,
}

impl RouterConfig {
    pub fn new(backends: Vec<SocketAddr>) -> RouterConfig {
        RouterConfig {
            backends,
            health_interval: Duration::from_millis(10),
            health_timeout: Duration::from_millis(50),
            reconnect_base: Duration::from_millis(10),
            reconnect_max: Duration::from_millis(250),
            max_redispatch: 3,
            seed: 0,
        }
    }
}

/// A backend's breaker state (see the module docs for the transitions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Answering health polls; in the rotation.
    Healthy,
    /// Missed one poll; still in the rotation (last resort) but one more
    /// strike kills it.
    Suspect,
    /// Unreachable; its manager is reconnecting with backoff.
    Dead,
}

impl BreakerState {
    fn label(self) -> &'static str {
        match self {
            BreakerState::Healthy => "healthy",
            BreakerState::Suspect => "suspect",
            BreakerState::Dead => "dead",
        }
    }
}

/// An in-flight proxied request: everything needed to answer the client
/// or to re-dispatch to another backend. Lives in exactly one link's
/// pending map at a time — removal is the ownership transfer that makes
/// replies exactly-once.
struct ProxyEntry {
    /// The id the client sent (restored onto the reply).
    client_id: u64,
    /// The client connection's muxed reply channel.
    reply: mpsc::Sender<ServeResult>,
    req: TransformRequest,
    fast_reject: bool,
    /// Absolute deadline derived from the request's TTL at admission;
    /// re-dispatch forwards only the *remaining* budget.
    deadline: Option<Instant>,
    /// Re-dispatch count so far (bounded by `max_redispatch`).
    hops: u32,
}

/// The pending map plus its hearse flag: once `dead` is set (under the
/// lock, after the link reader is joined) no dispatch may insert, so the
/// breaker's harvest is complete and final.
struct PendingMap {
    dead: bool,
    map: HashMap<u64, ProxyEntry>,
}

/// One live TCP connection to a backend.
struct Link {
    /// Handle for shutdown signalling.
    stream: TcpStream,
    /// Serialized write half (dispatchers and the health poller share it).
    writer: Mutex<TcpStream>,
    pending: Mutex<PendingMap>,
    /// Highest health-report seq the reader has seen.
    last_seq: AtomicU64,
    /// The link received at least one health report (deaths only count
    /// for backends that were genuinely up).
    saw_health: AtomicBool,
    /// The reader thread exited (EOF or error) — a connection error the
    /// manager treats as an immediate breaker trip.
    reader_done: AtomicBool,
}

/// Per-backend state: breaker, live link, freshest health report, and
/// the counters behind the report's per-backend rows.
struct BackendSlot {
    index: usize,
    addr: SocketAddr,
    state: Mutex<BreakerState>,
    link: Mutex<Option<Arc<Link>>>,
    last_health: Mutex<Option<(Instant, HealthStats)>>,
    proxied: AtomicU64,
    replies: AtomicU64,
    deaths: AtomicU64,
    rejoins: AtomicU64,
    /// The backend has been healthy at least once (so the *next* first
    /// health reply is a rejoin, not a first join).
    ever_up: AtomicBool,
}

/// State shared by the accept loop, client connections and managers.
struct RouterCore {
    config: RouterConfig,
    slots: Vec<Arc<BackendSlot>>,
    /// Stops the backend managers (the accept loop has its own flag so
    /// shutdown can stage the two independently).
    stop: AtomicBool,
    /// Router-assigned wire ids (globally unique across backends, so
    /// replies demux unambiguously; client ids are restored on forward).
    next_id: AtomicU64,
    /// Round-robin cursor for tie/stale fallback.
    rr: AtomicU64,
    proxied: AtomicU64,
    replies: AtomicU64,
    redispatched: AtomicU64,
    unavailable: AtomicU64,
}

impl RouterCore {
    /// Choose a backend: healthy pool first, suspect pool as last
    /// resort. Within the pool, least *fresh* reported queue depth; when
    /// depths tie or any report is stale, round-robin over the pool.
    fn pick(&self) -> Option<Arc<BackendSlot>> {
        let healthy: Vec<&Arc<BackendSlot>> = self
            .slots
            .iter()
            .filter(|s| *s.state.lock().unwrap() == BreakerState::Healthy)
            .collect();
        let pool = if healthy.is_empty() {
            let suspect: Vec<&Arc<BackendSlot>> = self
                .slots
                .iter()
                .filter(|s| *s.state.lock().unwrap() == BreakerState::Suspect)
                .collect();
            if suspect.is_empty() {
                return None;
            }
            suspect
        } else {
            healthy
        };
        let now = Instant::now();
        let fresh_for = self.config.health_interval * 4;
        let depths: Vec<Option<u64>> = pool
            .iter()
            .map(|s| {
                s.last_health.lock().unwrap().as_ref().and_then(|(at, h)| {
                    (now.saturating_duration_since(*at) <= fresh_for).then_some(h.queue_depth)
                })
            })
            .collect();
        let fresh: Option<Vec<u64>> = depths.into_iter().collect();
        let candidates: Vec<&Arc<BackendSlot>> = match fresh {
            // Every pool member has a fresh depth: least-loaded wins.
            Some(fresh) => {
                let min = *fresh.iter().min().unwrap();
                pool.iter().zip(&fresh).filter(|(_, d)| **d == min).map(|(s, _)| *s).collect()
            }
            // Any stale report poisons the comparison: round-robin.
            None => pool,
        };
        let i = self.rr.fetch_add(1, Ordering::Relaxed) as usize % candidates.len();
        Some(candidates[i].clone())
    }

    /// Admit one client request: dispatch it to a backend (possibly
    /// after retries), or answer it with an immediate rejection. Always
    /// leaves the request owned by exactly one party.
    fn submit(&self, req: TransformRequest, fast_reject: bool, reply: mpsc::Sender<ServeResult>) {
        let deadline = req.ttl.map(|ttl| Instant::now() + ttl);
        let entry = ProxyEntry { client_id: req.id, reply, req, fast_reject, deadline, hops: 0 };
        self.dispatch(entry);
    }

    /// One dispatch pass: pick a backend, register the entry in its
    /// link's pending map, write the frame. Bounded retries over other
    /// backends absorb pick/death races; exhaustion (or no live backend
    /// at all) is an immediate `Unavailable` reply.
    fn dispatch(&self, entry: ProxyEntry) {
        let mut entry = Some(entry);
        for _ in 0..self.slots.len() + 2 {
            let e = entry.as_ref().unwrap();
            if let Some(d) = e.deadline {
                if Instant::now() >= d {
                    let rej = Rejection {
                        id: e.client_id,
                        reason: RejectReason::DeadlineExceeded,
                    };
                    let _ = entry.take().unwrap().reply.send(Err(rej));
                    return;
                }
            }
            let Some(slot) = self.pick() else { break };
            let Some(link) = slot.link.lock().unwrap().clone() else { continue };
            let router_id = self.next_id.fetch_add(1, Ordering::Relaxed);
            let bytes = {
                let e = entry.as_ref().unwrap();
                let mut wire_req = e.req.clone();
                wire_req.id = router_id;
                if let Some(d) = e.deadline {
                    wire_req.ttl = Some(d.saturating_duration_since(Instant::now()));
                }
                wire::encode_request(&wire_req, e.fast_reject)
            };
            {
                let mut p = link.pending.lock().unwrap();
                if p.dead {
                    continue; // breaker tripped between pick and here
                }
                p.map.insert(router_id, entry.take().unwrap());
            }
            let wrote = {
                let mut w = link.writer.lock().unwrap();
                wire::write_frame(&mut *w, &bytes).is_ok()
            };
            if wrote {
                slot.proxied.fetch_add(1, Ordering::Relaxed);
                self.proxied.fetch_add(1, Ordering::Relaxed);
                return;
            }
            // Write failed. If the entry is still in the map it is ours
            // again — retry elsewhere. If not, the breaker already
            // harvested it (and owns the reply): hands off.
            match link.pending.lock().unwrap().map.remove(&router_id) {
                Some(back) => entry = Some(back),
                None => return,
            }
        }
        let e = entry.take().unwrap();
        self.unavailable.fetch_add(1, Ordering::Relaxed);
        let _ = e.reply.send(Err(Rejection { id: e.client_id, reason: RejectReason::Unavailable }));
    }

    /// Re-dispatch a request harvested from a dying backend, respecting
    /// its remaining TTL and the hop budget.
    fn redispatch(&self, mut entry: ProxyEntry) {
        entry.hops += 1;
        if entry.hops > self.config.max_redispatch {
            self.unavailable.fetch_add(1, Ordering::Relaxed);
            let rej = Rejection { id: entry.client_id, reason: RejectReason::Unavailable };
            let _ = entry.reply.send(Err(rej));
            return;
        }
        self.redispatched.fetch_add(1, Ordering::Relaxed);
        self.dispatch(entry);
    }

    /// In-flight proxied requests across every live link.
    fn inflight(&self) -> usize {
        self.slots
            .iter()
            .map(|s| {
                s.link
                    .lock()
                    .unwrap()
                    .as_ref()
                    .map_or(0, |l| l.pending.lock().unwrap().map.len())
            })
            .sum()
    }

    fn metrics(&self) -> ClusterSnapshot {
        let mut snap = ClusterSnapshot::default();
        for slot in &self.slots {
            let state = slot.state.lock().unwrap().label();
            let last = slot.last_health.lock().unwrap().clone();
            if let Some((_, h)) = &last {
                snap.absorb(h);
            }
            let deaths = slot.deaths.load(Ordering::Relaxed);
            let rejoins = slot.rejoins.load(Ordering::Relaxed);
            snap.backend_deaths += deaths;
            snap.backend_rejoins += rejoins;
            snap.backends.push(BackendSnapshot {
                index: slot.index,
                addr: slot.addr.to_string(),
                state,
                proxied: slot.proxied.load(Ordering::Relaxed),
                replies: slot.replies.load(Ordering::Relaxed),
                deaths,
                rejoins,
                queue_depth: last.map(|(_, h)| h.queue_depth).unwrap_or(0),
            });
        }
        snap.proxied = self.proxied.load(Ordering::Relaxed);
        snap.replies = self.replies.load(Ordering::Relaxed);
        snap.redispatched = self.redispatched.load(Ordering::Relaxed);
        snap.unavailable_rejected = self.unavailable.load(Ordering::Relaxed);
        snap
    }
}

// ── the backend managers ───────────────────────────────────────────────

/// Per-backend supervision thread: connect (with seeded-jitter
/// exponential backoff), stand the link up, health-poll it, and run the
/// breaker. On link death: harvest in-flight entries and re-dispatch.
fn manager_loop(core: Arc<RouterCore>, slot: Arc<BackendSlot>) {
    let cfg = &core.config;
    let mut jitter = cfg.seed ^ (0x9E37 + slot.index as u64);
    let mut attempt: u32 = 0;
    while !core.stop.load(Ordering::Relaxed) {
        let stream = match TcpStream::connect_timeout(&slot.addr, cfg.health_timeout) {
            Ok(s) => s,
            Err(_) => {
                // Exponential backoff with seeded jitter: base·2^attempt
                // capped at reconnect_max, plus up to 50% extra.
                let shift = attempt.min(8);
                let base = cfg.reconnect_base.saturating_mul(1u32 << shift).min(cfg.reconnect_max);
                let extra = splitmix64(&mut jitter) % (base.as_micros() as u64 / 2 + 1);
                let nap = (base + Duration::from_micros(extra)).min(cfg.reconnect_max);
                attempt = attempt.saturating_add(1);
                std::thread::sleep(nap);
                continue;
            }
        };
        attempt = 0;
        match run_link(&core, &slot, stream) {
            LinkEnd::Stopped => return,
            LinkEnd::Died => {} // loop back into reconnect
        }
    }
}

#[derive(Clone, Copy)]
enum LinkEnd {
    /// The router is shutting down; the link was closed cleanly.
    Stopped,
    /// The backend stopped answering (conn error or poll starvation);
    /// in-flight entries were harvested and re-dispatched.
    Died,
}

/// Drive one connected link until it dies or the router stops.
fn run_link(core: &Arc<RouterCore>, slot: &Arc<BackendSlot>, stream: TcpStream) -> LinkEnd {
    let cfg = &core.config;
    if stream.set_nodelay(true).is_err() {
        return LinkEnd::Died;
    }
    let (read_half, write_half) = match (stream.try_clone(), stream.try_clone()) {
        (Ok(r), Ok(w)) => (r, w),
        _ => return LinkEnd::Died,
    };
    let link = Arc::new(Link {
        stream,
        writer: Mutex::new(write_half),
        pending: Mutex::new(PendingMap { dead: false, map: HashMap::new() }),
        last_seq: AtomicU64::new(0),
        saw_health: AtomicBool::new(false),
        reader_done: AtomicBool::new(false),
    });
    let reader = {
        let core = core.clone();
        let slot = slot.clone();
        let link = link.clone();
        let mut read_half = read_half;
        std::thread::Builder::new()
            .name(format!("morpho-router-link-{}", slot.index))
            .spawn(move || link_reader_loop(&mut read_half, &core, &slot, &link))
    };
    let Ok(reader) = reader else {
        return LinkEnd::Died;
    };
    *slot.link.lock().unwrap() = Some(link.clone());

    // Poll / breaker loop.
    let mut seq: u64 = 0;
    let mut announced = false; // this link reached Healthy at least once
    let end = 'poll: loop {
        if core.stop.load(Ordering::Relaxed) {
            break LinkEnd::Stopped;
        }
        seq += 1;
        let poll = wire::encode_health(seq, None);
        let sent = {
            let mut w = link.writer.lock().unwrap();
            wire::write_frame(&mut *w, &poll).is_ok()
        };
        if !sent {
            break LinkEnd::Died;
        }
        // Wait for the echo (or a dead reader) up to health_timeout.
        let deadline = Instant::now() + cfg.health_timeout;
        let answered = loop {
            if link.last_seq.load(Ordering::Relaxed) >= seq {
                break true;
            }
            if link.reader_done.load(Ordering::Relaxed) || Instant::now() >= deadline {
                break false;
            }
            std::thread::sleep(Duration::from_millis(1));
        };
        if answered {
            let mut st = slot.state.lock().unwrap();
            if *st != BreakerState::Healthy {
                *st = BreakerState::Healthy;
            }
            drop(st);
            if !announced {
                announced = true;
                if slot.ever_up.swap(true, Ordering::Relaxed) {
                    slot.rejoins.fetch_add(1, Ordering::Relaxed);
                }
            }
            // Sleep out the poll interval, responsive to stop and to the
            // reader dying under us.
            let wake = Instant::now() + cfg.health_interval;
            while Instant::now() < wake {
                if core.stop.load(Ordering::Relaxed) {
                    break 'poll LinkEnd::Stopped;
                }
                if link.reader_done.load(Ordering::Relaxed) {
                    break 'poll LinkEnd::Died;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        } else if link.reader_done.load(Ordering::Relaxed) {
            // Connection error: skip the strike ladder, the link is gone.
            break LinkEnd::Died;
        } else {
            // Poll starvation: Healthy → Suspect → Dead.
            let mut st = slot.state.lock().unwrap();
            match *st {
                BreakerState::Healthy => *st = BreakerState::Suspect,
                BreakerState::Suspect | BreakerState::Dead => break LinkEnd::Died,
            }
        }
    };

    // Take the backend out of rotation and tear the link down. Joining
    // the reader BEFORE harvesting is what makes replies exactly-once:
    // after the join no reply can race the harvest.
    *slot.state.lock().unwrap() = BreakerState::Dead;
    *slot.link.lock().unwrap() = None;
    let _ = link.stream.shutdown(Shutdown::Both);
    let _ = reader.join();
    let orphans: Vec<ProxyEntry> = {
        let mut p = link.pending.lock().unwrap();
        p.dead = true;
        p.map.drain().map(|(_, e)| e).collect()
    };
    match end {
        LinkEnd::Stopped => {
            // Router shutdown: anything still in flight gets an explicit
            // ShuttingDown, never silence.
            for e in orphans {
                let rej = Rejection { id: e.client_id, reason: RejectReason::ShuttingDown };
                let _ = e.reply.send(Err(rej));
            }
        }
        LinkEnd::Died => {
            if link.saw_health.load(Ordering::Relaxed) {
                slot.deaths.fetch_add(1, Ordering::Relaxed);
            }
            for e in orphans {
                core.redispatch(e);
            }
        }
    }
    end
}

/// Backend-link reader: demux replies back to their client connections
/// (restoring client ids — the ownership-transferring pending-map remove
/// happens here) and record health reports.
fn link_reader_loop(
    stream: &mut TcpStream,
    core: &RouterCore,
    slot: &BackendSlot,
    link: &Link,
) {
    loop {
        let frame = match wire::read_frame(stream) {
            Ok(Some(payload)) => wire::decode_frame(&payload),
            Ok(None) | Err(_) => break,
        };
        match frame {
            Ok(Frame::Result(mut res)) => {
                let router_id = match &res {
                    Ok(r) => r.id,
                    Err(r) => r.id,
                };
                let entry = link.pending.lock().unwrap().map.remove(&router_id);
                if let Some(e) = entry {
                    match &mut res {
                        Ok(r) => r.id = e.client_id,
                        Err(r) => r.id = e.client_id,
                    }
                    slot.replies.fetch_add(1, Ordering::Relaxed);
                    core.replies.fetch_add(1, Ordering::Relaxed);
                    let _ = e.reply.send(res);
                }
            }
            Ok(Frame::Health { seq, stats: Some(h) }) => {
                *slot.last_health.lock().unwrap() = Some((Instant::now(), h));
                link.saw_health.store(true, Ordering::Relaxed);
                link.last_seq.store(seq, Ordering::Relaxed);
            }
            // A poll from the backend, a request, or garbage: nothing a
            // backend should send. Tear the link down; the breaker will
            // handle the fallout.
            _ => break,
        }
    }
    link.reader_done.store(true, Ordering::Relaxed);
}

// ── the client-facing surface ──────────────────────────────────────────

/// A live client connection (mirrors `WireServer`'s per-connection
/// reader/writer pair).
struct ClientConn {
    stream: TcpStream,
    reader: JoinHandle<()>,
    writer: JoinHandle<()>,
}

/// The front-end router process: `Router::bind` + client connections in,
/// [`RouterCore::dispatch`] out to the backend links. See module docs.
pub struct Router {
    local_addr: SocketAddr,
    core: Arc<RouterCore>,
    accept_stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<ClientConn>>>,
    accept: Option<JoinHandle<()>>,
    managers: Vec<JoinHandle<()>>,
    down: bool,
}

impl Router {
    /// Bind the client-facing listener and start one manager per
    /// backend. Backends need not be up yet — their breakers start Dead
    /// and join the rotation on their first health reply (see
    /// [`Router::wait_healthy`]).
    pub fn bind(addr: &str, config: RouterConfig) -> Result<Router> {
        if config.backends.is_empty() {
            return Err(anyhow::anyhow!("router needs at least one backend address"));
        }
        let slots: Vec<Arc<BackendSlot>> = config
            .backends
            .iter()
            .enumerate()
            .map(|(index, &addr)| {
                Arc::new(BackendSlot {
                    index,
                    addr,
                    state: Mutex::new(BreakerState::Dead),
                    link: Mutex::new(None),
                    last_health: Mutex::new(None),
                    proxied: AtomicU64::new(0),
                    replies: AtomicU64::new(0),
                    deaths: AtomicU64::new(0),
                    rejoins: AtomicU64::new(0),
                    ever_up: AtomicBool::new(false),
                })
            })
            .collect();
        let core = Arc::new(RouterCore {
            config,
            slots,
            stop: AtomicBool::new(false),
            next_id: AtomicU64::new(1),
            rr: AtomicU64::new(0),
            proxied: AtomicU64::new(0),
            replies: AtomicU64::new(0),
            redispatched: AtomicU64::new(0),
            unavailable: AtomicU64::new(0),
        });
        let managers = core
            .slots
            .iter()
            .map(|slot| {
                let core = core.clone();
                let slot = slot.clone();
                std::thread::Builder::new()
                    .name(format!("morpho-router-mgr-{}", slot.index))
                    .spawn(move || manager_loop(core, slot))
            })
            .collect::<io::Result<Vec<_>>>()?;

        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let accept_stop = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(Mutex::new(Vec::<ClientConn>::new()));
        let accept = {
            let stop = accept_stop.clone();
            let conns = conns.clone();
            let core = core.clone();
            std::thread::Builder::new().name("morpho-router-accept".into()).spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => match spawn_client_conn(stream, core.clone()) {
                            Ok(conn) => conns.lock().unwrap().push(conn),
                            Err(e) => eprintln!("morpho-router-accept: connection setup: {e}"),
                        },
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(1));
                        }
                        Err(e) => {
                            eprintln!("morpho-router-accept: {e}");
                            std::thread::sleep(Duration::from_millis(1));
                        }
                    }
                    reap_finished(&conns);
                }
            })?
        };
        Ok(Router {
            local_addr,
            core,
            accept_stop,
            conns,
            accept: Some(accept),
            managers,
            down: false,
        })
    }

    /// The bound client-facing address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Block until at least `n` backends are Healthy, up to `timeout`.
    /// Returns whether the quorum arrived.
    pub fn wait_healthy(&self, n: usize, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        loop {
            let healthy = self
                .core
                .slots
                .iter()
                .filter(|s| *s.state.lock().unwrap() == BreakerState::Healthy)
                .count();
            if healthy >= n {
                return true;
            }
            if Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Cluster-wide admission-queue depth: the sum of every backend's
    /// most recently reported gauge (the loadgen saturation signal).
    pub fn queue_depth(&self) -> usize {
        self.core
            .slots
            .iter()
            .map(|s| {
                s.last_health.lock().unwrap().as_ref().map_or(0, |(_, h)| h.queue_depth as usize)
            })
            .sum()
    }

    /// Per-backend breaker states, in backend-list order (test/ops
    /// introspection).
    pub fn breaker_states(&self) -> Vec<BreakerState> {
        self.core.slots.iter().map(|s| *s.state.lock().unwrap()).collect()
    }

    /// One consistent cluster snapshot: summed backend health plus the
    /// router's own proxy/failover counters.
    pub fn metrics(&self) -> ClusterSnapshot {
        self.core.metrics()
    }

    /// Graceful drain: stop accepting, let in-flight proxied requests
    /// finish (bounded), close the backend links, join everything.
    pub fn shutdown(mut self) {
        self.teardown();
    }

    fn teardown(&mut self) {
        if self.down {
            return;
        }
        self.down = true;
        // 1. Stop accepting; joining drops the listener so late connects
        //    are refused at the OS level.
        self.accept_stop.store(true, Ordering::Relaxed);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        // 2. Half-close client readers and join them: no new dispatches
        //    after this (a reader *is* the dispatcher for its
        //    connection). Writers keep flushing replies.
        let conns = std::mem::take(&mut *self.conns.lock().unwrap());
        for c in &conns {
            let _ = c.stream.shutdown(Shutdown::Read);
        }
        let mut writers = Vec::with_capacity(conns.len());
        for c in conns {
            let _ = c.reader.join();
            writers.push(c.writer);
        }
        // 3. Bounded drain: wait for the pending maps to empty (replies
        //    flow back through the link readers the whole time).
        let cap = Instant::now() + Duration::from_secs(30);
        while self.core.inflight() > 0 && Instant::now() < cap {
            std::thread::sleep(Duration::from_millis(1));
        }
        // 4. Stop the managers; each closes its link, joins its reader,
        //    and answers any straggler with ShuttingDown.
        self.core.stop.store(true, Ordering::Relaxed);
        for m in self.managers.drain(..) {
            let _ = m.join();
        }
        // 5. Reader joins (above) + the last reply-sender drops (link
        //    teardown) let the client writers flush their tails and exit.
        for w in writers {
            let _ = w.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.teardown();
    }
}

/// Join and drop client connections whose threads have both exited.
fn reap_finished(conns: &Mutex<Vec<ClientConn>>) {
    let mut guard = conns.lock().unwrap();
    let mut i = 0;
    while i < guard.len() {
        if guard[i].reader.is_finished() && guard[i].writer.is_finished() {
            let c = guard.swap_remove(i);
            let _ = c.reader.join();
            let _ = c.writer.join();
        } else {
            i += 1;
        }
    }
}

fn spawn_client_conn(stream: TcpStream, core: Arc<RouterCore>) -> io::Result<ClientConn> {
    stream.set_nonblocking(false)?;
    stream.set_nodelay(true)?;
    let mut read_half = stream.try_clone()?;
    let write_half = Arc::new(Mutex::new(stream.try_clone()?));
    let (tx, rx) = mpsc::channel::<ServeResult>();
    let writer = {
        let write_half = write_half.clone();
        std::thread::Builder::new().name("morpho-router-conn-writer".into()).spawn(move || {
            while let Ok(res) = rx.recv() {
                let bytes = wire::encode_result(&res);
                let mut w = write_half.lock().unwrap();
                if wire::write_frame(&mut *w, &bytes).is_err() {
                    break; // client gone; remaining replies undeliverable
                }
            }
        })?
    };
    let reader = std::thread::Builder::new().name("morpho-router-conn-reader".into()).spawn(
        move || {
            client_reader_loop(&mut read_half, &write_half, &core, tx);
        },
    )?;
    Ok(ClientConn { stream, reader, writer })
}

/// Client-connection request pump: requests dispatch into the cluster,
/// health polls answer with the cluster aggregate, anything else is a
/// connection-fatal protocol error — byte-compatible with talking to a
/// single [`super::WireServer`].
fn client_reader_loop(
    stream: &mut TcpStream,
    write_half: &Mutex<TcpStream>,
    core: &RouterCore,
    reply: mpsc::Sender<ServeResult>,
) {
    let fatal = |code: u8, message: &str| {
        let bytes = wire::encode_protocol_error(code, message);
        let mut w = write_half.lock().unwrap();
        let _ = wire::write_frame(&mut *w, &bytes);
        let _ = w.shutdown(Shutdown::Both);
    };
    loop {
        let payload = match wire::read_frame(stream) {
            Ok(Some(p)) => p,
            Ok(None) => return,
            Err(e) => return fatal(wire::ERR_MALFORMED, &e.to_string()),
        };
        match wire::decode_frame(&payload) {
            Ok(Frame::Request { req, fast_reject }) => {
                core.submit(req, fast_reject, reply.clone());
            }
            Ok(Frame::Health { seq, stats: None }) => {
                let report = wire::encode_health(seq, Some(&core.metrics().health));
                let mut w = write_half.lock().unwrap();
                if wire::write_frame(&mut *w, &report).is_err() {
                    return;
                }
            }
            Ok(_) => {
                return fatal(wire::ERR_UNEXPECTED_KIND, "client sent a server-only frame kind")
            }
            Err(e) => return fatal(wire::ERR_MALFORMED, &e.to_string()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::server::{
        BackendChoice, Coordinator, CoordinatorConfig, WireServer,
    };
    use super::super::BatcherConfig;
    use crate::graphics::Transform;
    use crate::loadgen::WireClient;

    fn backend() -> (Arc<Coordinator>, WireServer) {
        let c = Arc::new(
            Coordinator::start(CoordinatorConfig {
                backend: BackendChoice::Native,
                workers: 2,
                batcher: BatcherConfig {
                    max_wait: Duration::from_micros(200),
                    ..Default::default()
                },
                ..Default::default()
            })
            .unwrap(),
        );
        let s = WireServer::bind("127.0.0.1:0", c.clone()).unwrap();
        (c, s)
    }

    fn fast_config(backends: Vec<SocketAddr>) -> RouterConfig {
        let mut cfg = RouterConfig::new(backends);
        cfg.health_interval = Duration::from_millis(2);
        cfg.health_timeout = Duration::from_millis(25);
        cfg.reconnect_base = Duration::from_millis(2);
        cfg.reconnect_max = Duration::from_millis(20);
        cfg.seed = 7;
        cfg
    }

    fn serve_one(client: &WireClient, tag: f32) {
        let rx = client
            .submit(
                vec![tag, tag + 1.0],
                vec![0.0, 1.0],
                vec![Transform::Translate { tx: 1.0, ty: 2.0 }],
                false,
            )
            .expect("submit through router");
        let resp = rx.recv_timeout(Duration::from_secs(10)).expect("reply").expect("served");
        assert_eq!(resp.xs, vec![tag + 1.0, tag + 2.0]);
        assert_eq!(resp.ys, vec![2.0, 3.0]);
    }

    #[test]
    fn routes_requests_across_two_backends_and_aggregates_metrics() {
        let (c1, s1) = backend();
        let (c2, s2) = backend();
        let router =
            Router::bind("127.0.0.1:0", fast_config(vec![s1.local_addr(), s2.local_addr()]))
                .unwrap();
        assert!(router.wait_healthy(2, Duration::from_secs(10)), "both backends join");

        let client = WireClient::connect(router.local_addr(), None).unwrap();
        for i in 0..24 {
            serve_one(&client, i as f32);
        }
        drop(client);

        let m = router.metrics();
        assert_eq!(m.proxied, 24);
        assert_eq!(m.replies, 24);
        assert_eq!(m.unavailable_rejected, 0);
        assert_eq!(m.backends.len(), 2);
        // Round-robin over tied/stale depths: both backends serve.
        assert!(m.backends.iter().all(|b| b.proxied > 0), "both backends used: {m:?}");
        assert_eq!(m.backends.iter().map(|b| b.proxied).sum::<u64>(), 24);
        // The aggregate view covers both coordinators' ledgers.
        let served = c1.metrics().responses + c2.metrics().responses;
        assert_eq!(served, 24);

        router.shutdown();
        s1.shutdown();
        s2.shutdown();
        for c in [c1, c2] {
            if let Ok(c) = Arc::try_unwrap(c) {
                c.shutdown();
            }
        }
    }

    #[test]
    fn all_backends_dead_rejects_immediately_instead_of_hanging() {
        // A port with nothing behind it: bind, read the addr, drop.
        let dead_addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let router = Router::bind("127.0.0.1:0", fast_config(vec![dead_addr])).unwrap();
        let client = WireClient::connect(router.local_addr(), None).unwrap();
        let rx = client.submit(vec![1.0], vec![2.0], vec![], false).unwrap();
        let started = Instant::now();
        match rx.recv_timeout(Duration::from_secs(10)) {
            Ok(Err(rej)) => assert_eq!(rej.reason, RejectReason::Unavailable),
            other => panic!("expected immediate Unavailable, got {other:?}"),
        }
        assert!(
            started.elapsed() < Duration::from_secs(5),
            "degraded mode must answer fast, not at some timeout"
        );
        assert!(router.metrics().unavailable_rejected >= 1);
        drop(client);
        router.shutdown();
    }

    #[test]
    fn killed_backend_trips_the_breaker_and_rejoins_after_restart() {
        let (c1, s1) = backend();
        let (c2, s2) = backend();
        let addr1 = s1.local_addr();
        let router =
            Router::bind("127.0.0.1:0", fast_config(vec![addr1, s2.local_addr()])).unwrap();
        assert!(router.wait_healthy(2, Duration::from_secs(10)));
        let client = WireClient::connect(router.local_addr(), None).unwrap();
        serve_one(&client, 1.0);

        // Kill backend 1 abruptly (no drain) and drop its coordinator —
        // a process crash as far as the router can tell.
        s1.kill();
        drop(c1);
        let deadline = Instant::now() + Duration::from_secs(10);
        while router.metrics().backend_deaths == 0 {
            assert!(Instant::now() < deadline, "breaker must observe the death");
            std::thread::sleep(Duration::from_millis(1));
        }
        // Degraded but serving: backend 2 carries the traffic.
        for i in 0..8 {
            serve_one(&client, 100.0 + i as f32);
        }

        // Restart on the same address; the manager's backoff loop finds
        // it and the backend rejoins the rotation.
        let (c1b, _s1b) = {
            let c = Arc::new(
                Coordinator::start(CoordinatorConfig {
                    backend: BackendChoice::Native,
                    workers: 2,
                    batcher: BatcherConfig {
                        max_wait: Duration::from_micros(200),
                        ..Default::default()
                    },
                    ..Default::default()
                })
                .unwrap(),
            );
            let s = WireServer::bind(&addr1.to_string(), c.clone()).unwrap();
            (c, s)
        };
        let deadline = Instant::now() + Duration::from_secs(10);
        while router.metrics().backend_rejoins == 0 {
            assert!(Instant::now() < deadline, "restarted backend must rejoin");
            std::thread::sleep(Duration::from_millis(1));
        }
        serve_one(&client, 500.0);

        let m = router.metrics();
        assert!(m.backend_deaths >= 1, "{m:?}");
        assert!(m.backend_rejoins >= 1, "{m:?}");
        drop(client);
        router.shutdown();
        s2.shutdown();
        drop(c2);
        drop(c1b);
    }
}
