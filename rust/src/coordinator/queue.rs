//! A bounded MPMC queue with blocking push (backpressure), deadline-aware
//! pop, and two service lanes — the admission point of the coordinator.
//!
//! The queue carries an **express** lane and a **standard** lane under one
//! shared capacity: pops drain express first (FIFO within each lane), so
//! latency-sensitive work never waits behind a backlog of bulk work, while
//! the single capacity bound keeps backpressure semantics unchanged.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Why a push was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushError {
    Full,
    Closed,
}

/// Which service lane a push lands in. Express drains strictly before
/// standard; both lanes share one capacity bound.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    Express,
    Standard,
}

/// Outcome of a deadline-bounded pop. A dedicated enum rather than
/// `Result<Option<T>, ()>`: close-vs-timeout is a three-way decision at
/// every call site, and an opaque `Err(())` invited conflating the two.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PopResult<T> {
    /// An item was dequeued before the deadline.
    Item(T),
    /// The deadline passed with the queue still open and empty.
    TimedOut,
    /// The queue is closed and fully drained.
    Closed,
}

/// Bounded blocking queue.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct Inner<T> {
    express: VecDeque<T>,
    standard: VecDeque<T>,
    closed: bool,
}

impl<T> Inner<T> {
    fn len(&self) -> usize {
        self.express.len() + self.standard.len()
    }

    fn pop_next(&mut self) -> Option<T> {
        self.express.pop_front().or_else(|| self.standard.pop_front())
    }

    fn lane_mut(&mut self, lane: Lane) -> &mut VecDeque<T> {
        match lane {
            Lane::Express => &mut self.express,
            Lane::Standard => &mut self.standard,
        }
    }
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> BoundedQueue<T> {
        assert!(capacity > 0);
        BoundedQueue {
            inner: Mutex::new(Inner {
                express: VecDeque::new(),
                standard: VecDeque::new(),
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Blocking push into the standard lane; waits while full
    /// (backpressure). Errors if closed.
    pub fn push(&self, item: T) -> Result<(), PushError> {
        self.push_lane(item, Lane::Standard)
    }

    /// Blocking push into an explicit lane.
    pub fn push_lane(&self, item: T, lane: Lane) -> Result<(), PushError> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return Err(PushError::Closed);
            }
            if g.len() < self.capacity {
                g.lane_mut(lane).push_back(item);
                self.not_empty.notify_one();
                return Ok(());
            }
            g = self.not_full.wait(g).unwrap();
        }
    }

    /// Non-blocking push into the standard lane.
    pub fn try_push(&self, item: T) -> Result<(), (T, PushError)> {
        self.try_push_lane(item, Lane::Standard)
    }

    /// Non-blocking push into an explicit lane.
    pub fn try_push_lane(&self, item: T, lane: Lane) -> Result<(), (T, PushError)> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err((item, PushError::Closed));
        }
        if g.len() >= self.capacity {
            return Err((item, PushError::Full));
        }
        g.lane_mut(lane).push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` once closed and drained. Express lane drains
    /// first.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.pop_next() {
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Pop with a deadline; see [`PopResult`] for the three outcomes.
    /// Express lane drains first.
    pub fn pop_until(&self, deadline: Instant) -> PopResult<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.pop_next() {
                self.not_full.notify_one();
                return PopResult::Item(item);
            }
            if g.closed {
                return PopResult::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopResult::TimedOut;
            }
            let (guard, timeout) = self.not_empty.wait_timeout(g, deadline - now).unwrap();
            g = guard;
            if timeout.timed_out() && g.len() == 0 {
                if g.closed {
                    return PopResult::Closed;
                }
                return PopResult::TimedOut;
            }
        }
    }

    /// Close the queue: pushes fail, pops drain what remains.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(q.pop(), Some(i));
        }
    }

    #[test]
    fn express_lane_drains_before_standard() {
        let q = BoundedQueue::new(8);
        q.push_lane(1, Lane::Standard).unwrap();
        q.push_lane(2, Lane::Standard).unwrap();
        q.push_lane(10, Lane::Express).unwrap();
        q.push_lane(11, Lane::Express).unwrap();
        // Express first (FIFO within the lane), then standard (FIFO).
        assert_eq!(q.pop(), Some(10));
        assert_eq!(q.pop(), Some(11));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn lanes_share_one_capacity_bound() {
        let q = BoundedQueue::new(2);
        q.try_push_lane(1, Lane::Standard).unwrap();
        q.try_push_lane(2, Lane::Express).unwrap();
        match q.try_push_lane(3, Lane::Express) {
            Err((3, PushError::Full)) => {}
            other => panic!("shared capacity must bound both lanes, got {other:?}"),
        }
        assert_eq!(q.len(), 2);
        // Draining the express item frees capacity for either lane.
        assert_eq!(q.pop(), Some(2));
        q.try_push_lane(3, Lane::Standard).unwrap();
    }

    #[test]
    fn pop_until_prefers_express() {
        let q = BoundedQueue::new(4);
        q.push_lane(1, Lane::Standard).unwrap();
        q.push_lane(9, Lane::Express).unwrap();
        let d = Instant::now() + Duration::from_secs(1);
        assert_eq!(q.pop_until(d), PopResult::Item(9));
        assert_eq!(q.pop_until(d), PopResult::Item(1));
    }

    #[test]
    fn try_push_reports_full() {
        let q = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        match q.try_push(3) {
            Err((3, PushError::Full)) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn try_push_distinguishes_closed_from_full() {
        // The two rejection causes must stay distinct all the way up the
        // stack: `Full` is overload (caller may retry / shed load),
        // `Closed` is shutdown (retrying is pointless). Closed wins even
        // when the queue is also full, and the item comes back intact in
        // both cases.
        let q = BoundedQueue::new(1);
        q.try_push(10).unwrap();
        match q.try_push(11) {
            Err((11, PushError::Full)) => {}
            other => panic!("open+full must report Full, got {other:?}"),
        }
        q.close();
        match q.try_push(12) {
            Err((12, PushError::Closed)) => {}
            other => panic!("closed+full must report Closed, got {other:?}"),
        }
        // Drain below capacity: still Closed, never Full.
        assert_eq!(q.pop(), Some(10));
        match q.try_push(13) {
            Err((13, PushError::Closed)) => {}
            other => panic!("closed+empty must report Closed, got {other:?}"),
        }
    }

    #[test]
    fn close_drains_then_none() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.close();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), None);
        assert_eq!(q.push(2), Err(PushError::Closed));
    }

    #[test]
    fn blocking_push_applies_backpressure() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(0u32).unwrap();
        let q2 = q.clone();
        let h = thread::spawn(move || {
            // Blocks until the consumer pops.
            q2.push(1).unwrap();
        });
        thread::sleep(Duration::from_millis(20));
        assert_eq!(q.len(), 1, "producer must be blocked");
        assert_eq!(q.pop(), Some(0));
        h.join().unwrap();
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn pop_until_times_out() {
        let q: BoundedQueue<u32> = BoundedQueue::new(1);
        let d = Instant::now() + Duration::from_millis(25);
        assert_eq!(q.pop_until(d), PopResult::TimedOut);
        assert!(Instant::now() >= d);
    }

    #[test]
    fn pop_until_returns_item_when_available() {
        let q = BoundedQueue::new(1);
        q.push(42).unwrap();
        let d = Instant::now() + Duration::from_secs(1);
        assert_eq!(q.pop_until(d), PopResult::Item(42));
    }

    #[test]
    fn pop_until_reports_closed_not_timed_out() {
        let q: BoundedQueue<u32> = BoundedQueue::new(1);
        q.close();
        let d = Instant::now() + Duration::from_secs(1);
        assert_eq!(q.pop_until(d), PopResult::Closed);
        // Closed with items left: drain first, then report Closed.
        let q = BoundedQueue::new(2);
        q.push(7).unwrap();
        q.close();
        assert_eq!(q.pop_until(Instant::now() + Duration::from_secs(1)), PopResult::Item(7));
        assert_eq!(q.pop_until(Instant::now() + Duration::from_secs(1)), PopResult::Closed);
    }

    #[test]
    fn concurrent_producers_consumers_lose_nothing() {
        let q = Arc::new(BoundedQueue::new(4));
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                thread::spawn(move || {
                    for i in 0..100u32 {
                        q.push(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let q = q.clone();
                thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some(v) = q.pop() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u32> =
            consumers.into_iter().flat_map(|c| c.join().unwrap()).collect();
        all.sort_unstable();
        let mut expected: Vec<u32> =
            (0..4).flat_map(|p| (0..100).map(move |i| p * 1000 + i)).collect();
        expected.sort_unstable();
        assert_eq!(all, expected);
    }
}
