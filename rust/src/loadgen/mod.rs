//! # Loadgen — deterministic load generation & capacity measurement (L4)
//!
//! The serving layer ([`crate::coordinator`]) batches and executes
//! transform requests; this subsystem answers the question the paper's
//! serving scenario actually poses: *how much* concurrent client traffic
//! can a configuration sustain, at what latency, and what happens past
//! saturation? It drives a running [`crate::coordinator::Coordinator`]
//! end to end and emits a machine-readable capacity report.
//!
//! ```text
//!  Scenario (name, arrival profile, workload mix, seed, knobs)
//!      │
//!      ├── RequestFactory: request i of stream s = f(seed, s, i) — pure,
//!      │   wall-clock-free, so a fixed seed reproduces identical request
//!      │   streams (the determinism contract)
//!      │
//!      ├── runner: closed-loop N-client drivers, or an open-loop
//!      │   deterministic-arrival submitter (steady / burst / ramp) with a
//!      │   polling collector; a sampler gauges admission-queue depth
//!      │
//!      ├── transport: in-process library calls, or the wire protocol
//!      │   over a loopback `WireServer` the runner stands up — same
//!      │   seeded streams, a `transport` column in the report
//!      │
//!      └── CapacityReport → BENCH_coordinator.json (atomic temp+rename,
//!          same style as BENCH_simulator.json): throughput, p50/p95/p99
//!          latency, shed/rejected counts, queue depth, mean batch fill,
//!          simulated M1 cycles/point
//! ```
//!
//! ## Arrival disciplines
//!
//! * **Closed loop** — N clients, each submits → waits → repeats. Load is
//!   self-limiting (the classic saturation probe); with a fixed seed every
//!   client replays the identical request stream on every run.
//! * **Open loop** — requests arrive on a fixed deterministic timetable
//!   (no Poisson jitter: reproducibility beats realism here) regardless of
//!   completion. Past saturation the queue grows, so open-loop scenarios
//!   pair with admission control: `try_submit` fast-reject and/or request
//!   TTLs, exercising the coordinator's shedding paths.
//! * **Burst / ramp** — open-loop variants: periodic back-to-back bursts,
//!   and a linear rate sweep that walks the service across its knee.
//!
//! ## Determinism contract
//!
//! Request *content* is a pure function of `(seed, stream, index)` —
//! never of wall-clock time or thread interleaving. Closed-loop stream s
//! is client s; open-loop profiles use a single stream 0 in arrival
//! order. How *many* requests a run issues (and all timing numbers)
//! remain machine-dependent; what is pinned is the request sequence each
//! stream observes, which is what batching/conformance comparisons need.
//!
//! Run scenarios with `repro loadtest <name>` (see `repro loadtest list`),
//! the `loadgen` bench target, or [`run_scenario`] directly. The
//! [`saturation`] module sweeps the `ramp` scenario across a
//! workers × shards × batch-window grid (`repro sweep`) and writes the
//! measured surface to `BENCH_saturation.json`.

pub mod report;
pub mod runner;
pub mod saturation;
pub mod scenario;
pub mod transport;
pub mod workload;

pub use report::CapacityReport;
pub use runner::run_scenario;
pub use saturation::{run_sweep, SaturationCell, SweepConfig};
pub use scenario::{
    ArrivalProfile, BatchWindow, RouterScenario, Scenario, TransformKind, WorkloadMix,
};
pub use transport::{ReconnectPolicy, TransportKind, WireClient};
pub use workload::RequestFactory;
