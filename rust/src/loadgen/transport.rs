//! Client transports: how loadgen traffic reaches the coordinator.
//!
//! Every scenario runs on either transport with the same seeded request
//! streams, so the two `CapacityReport` rows are directly comparable —
//! in-process measures the library ceiling, loopback adds the wire
//! protocol, kernel sockets, and the server's per-connection threads
//! (acceptance: ROADMAP §Scale's ~15% bar at the same p99).
//!
//! * [`TransportKind::InProcess`] — `submit`/`try_submit` library calls,
//!   a per-request reply channel straight from the coordinator.
//! * [`TransportKind::Tcp`] — a [`WireClient`] per driver thread: request
//!   frames out over loopback, a background reader demuxing result
//!   frames by id into per-request channels. The driver-facing surface
//!   is the same `mpsc::Receiver<ServeResult>` either way, so the
//!   runner's collection/accounting logic is transport-blind.

use std::collections::HashMap;
use std::io;
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::faults::splitmix64;
use crate::coordinator::wire::{self, Frame};
use crate::coordinator::{Coordinator, Priority, RejectReason, ServeResult, TransformRequest};
use crate::graphics::Transform;

/// Which path a scenario's traffic takes to the coordinator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// Library calls in the loadgen process (the pre-wire baseline).
    InProcess,
    /// The wire protocol over a loopback TCP connection per driver.
    Tcp,
}

impl TransportKind {
    /// Stable label used in `CapacityReport`/`BENCH_coordinator.json`.
    pub fn label(&self) -> &'static str {
        match self {
            TransportKind::InProcess => "in-process",
            TransportKind::Tcp => "tcp",
        }
    }

    /// Parse a CLI `--transport` value.
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "in-process" | "inprocess" | "local" => Some(TransportKind::InProcess),
            "tcp" | "loopback" => Some(TransportKind::Tcp),
            _ => None,
        }
    }
}

/// How a [`WireClient`] behaves when its connection dies mid-session:
/// bounded reconnect attempts with seeded-jitter exponential backoff.
/// Requests in flight when the connection died are NOT replayed — their
/// receivers observe a disconnect (a typed error, never a hang); only
/// the submission that hit the dead socket rides the new connection.
#[derive(Debug, Clone, Copy)]
pub struct ReconnectPolicy {
    /// Reconnect attempts per failing submission before giving up.
    pub max_attempts: u32,
    /// First backoff step (doubles per attempt).
    pub base: Duration,
    /// Backoff ceiling, jitter included.
    pub max: Duration,
    /// Seed for the jitter (determinism under test).
    pub seed: u64,
}

impl Default for ReconnectPolicy {
    fn default() -> Self {
        ReconnectPolicy {
            max_attempts: 5,
            base: Duration::from_millis(10),
            max: Duration::from_millis(250),
            seed: 0,
        }
    }
}

/// One live connection: the write half plus the reply-demux reader.
struct ClientLink {
    writer: TcpStream,
    reader: Option<JoinHandle<()>>,
}

/// Open a connection and start its reply-demux reader over the shared
/// pending map.
fn open_link(
    addr: SocketAddr,
    pending: Arc<Mutex<HashMap<u64, mpsc::Sender<ServeResult>>>>,
) -> io::Result<ClientLink> {
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let writer = stream.try_clone()?;
    let mut read_half = stream;
    let reader = std::thread::Builder::new().name("wire-client-reader".into()).spawn(move || {
        loop {
            let payload = match wire::read_frame(&mut read_half) {
                Ok(Some(p)) => p,
                Ok(None) | Err(_) => break, // server closed / stream died
            };
            match wire::decode_frame(&payload) {
                Ok(Frame::Result(res)) => {
                    let id = match &res {
                        Ok(resp) => resp.id,
                        Err(rej) => rej.id,
                    };
                    if let Some(tx) = pending.lock().unwrap().remove(&id) {
                        let _ = tx.send(res);
                    }
                }
                Ok(Frame::ProtocolError { code, message }) => {
                    eprintln!("wire client: server protocol error {code}: {message}");
                    break;
                }
                // This client never polls, so a health frame here is
                // unsolicited — but it is well-formed and harmless, so
                // tolerate it rather than tearing the connection down.
                Ok(Frame::Health { .. }) => {}
                // A request frame from the server, or garbage:
                // nothing sane continues from here.
                Ok(Frame::Request { .. }) | Err(_) => break,
            }
        }
        // Orphan every outstanding request so waiting receivers
        // observe a disconnect instead of hanging.
        pending.lock().unwrap().clear();
    })?;
    Ok(ClientLink { writer, reader: Some(reader) })
}

/// A client connection speaking the [`wire`] protocol: submissions write
/// request frames (client-assigned ids), a background reader thread
/// routes each result frame to its request's channel. Dropping the
/// client closes the connection and disconnects any still-pending
/// receivers (observed as `failed` by the runner — never the case on a
/// clean server). With a [`ReconnectPolicy`], a submission that finds
/// the connection dead re-dials with backoff instead of failing
/// immediately.
pub struct WireClient {
    addr: SocketAddr,
    link: Mutex<ClientLink>,
    pending: Arc<Mutex<HashMap<u64, mpsc::Sender<ServeResult>>>>,
    next_id: AtomicU64,
    /// TTL stamped on every outgoing request (the wire carries it
    /// explicitly; `None` defers to the server's default).
    ttl: Option<Duration>,
    policy: Option<ReconnectPolicy>,
}

impl WireClient {
    /// Connect to a [`crate::coordinator::WireServer`] and start the
    /// reply-demux reader. No reconnection: a dead connection fails
    /// submissions immediately (see [`WireClient::connect_with`]).
    pub fn connect(addr: SocketAddr, ttl: Option<Duration>) -> io::Result<WireClient> {
        WireClient::dial(addr, ttl, None)
    }

    /// [`WireClient::connect`] plus mid-session resilience: submissions
    /// that hit a dead connection re-dial under `policy`.
    pub fn connect_with(
        addr: SocketAddr,
        ttl: Option<Duration>,
        policy: ReconnectPolicy,
    ) -> io::Result<WireClient> {
        WireClient::dial(addr, ttl, Some(policy))
    }

    fn dial(
        addr: SocketAddr,
        ttl: Option<Duration>,
        policy: Option<ReconnectPolicy>,
    ) -> io::Result<WireClient> {
        let pending: Arc<Mutex<HashMap<u64, mpsc::Sender<ServeResult>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let link = open_link(addr, pending.clone())?;
        Ok(WireClient {
            addr,
            link: Mutex::new(link),
            pending,
            next_id: AtomicU64::new(1),
            ttl,
            policy,
        })
    }

    /// Send one request; the reply (response or rejection) arrives on the
    /// returned channel. `fast_reject` selects the server's `try_submit`
    /// admission discipline.
    pub fn submit(
        &self,
        xs: Vec<f32>,
        ys: Vec<f32>,
        transforms: Vec<Transform>,
        fast_reject: bool,
    ) -> io::Result<mpsc::Receiver<ServeResult>> {
        self.submit_with_priority(xs, ys, transforms, fast_reject, Priority::Interactive)
    }

    /// [`WireClient::submit`] with an explicit lane — bulk requests ride
    /// the wire with flags bit 1 set and land on the server's standard
    /// admission lane.
    pub fn submit_with_priority(
        &self,
        xs: Vec<f32>,
        ys: Vec<f32>,
        transforms: Vec<Transform>,
        fast_reject: bool,
        priority: Priority,
    ) -> io::Result<mpsc::Receiver<ServeResult>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = TransformRequest::new(id, xs, ys, transforms).with_priority(priority);
        req.ttl = self.ttl;
        self.submit_request(req, fast_reject)
    }

    /// Send a pre-built request (the id must be unique per connection).
    pub fn submit_request(
        &self,
        req: TransformRequest,
        fast_reject: bool,
    ) -> io::Result<mpsc::Receiver<ServeResult>> {
        let (tx, rx) = mpsc::channel();
        let bytes = wire::encode_request(&req, fast_reject);
        self.send_registered(req.id, &tx, &bytes)?;
        Ok(rx)
    }

    /// Register the reply sender and write the frame, re-dialing under
    /// the reconnect policy (if any) when the connection is dead. The
    /// registration happens under the link lock and *before* the write —
    /// the reply can race back before the lock is even released — and is
    /// redone after every re-dial, because tearing the old link down
    /// clears the whole pending map (that disconnect is exactly how
    /// other in-flight requests learn their connection died).
    fn send_registered(
        &self,
        id: u64,
        tx: &mpsc::Sender<ServeResult>,
        bytes: &[u8],
    ) -> io::Result<()> {
        let mut link = self.link.lock().unwrap();
        self.pending.lock().unwrap().insert(id, tx.clone());
        let mut last_err = match wire::write_frame(&mut link.writer, bytes) {
            Ok(()) => return Ok(()),
            Err(e) => e,
        };
        let Some(policy) = self.policy else {
            self.pending.lock().unwrap().remove(&id);
            return Err(last_err);
        };
        let mut jitter = policy.seed;
        for attempt in 0..policy.max_attempts {
            // Tear the dead link down first: joining the old reader both
            // guarantees its pending-map clear cannot race our re-insert
            // and surfaces the disconnect to every other in-flight
            // request on this connection.
            let _ = link.writer.shutdown(Shutdown::Both);
            if let Some(r) = link.reader.take() {
                let _ = r.join();
            }
            let shift = attempt.min(8);
            let base = policy.base.saturating_mul(1u32 << shift).min(policy.max);
            let extra = splitmix64(&mut jitter) % (base.as_micros() as u64 / 2 + 1);
            std::thread::sleep((base + Duration::from_micros(extra)).min(policy.max));
            match open_link(self.addr, self.pending.clone()) {
                Ok(l) => {
                    *link = l;
                    self.pending.lock().unwrap().insert(id, tx.clone());
                    match wire::write_frame(&mut link.writer, bytes) {
                        Ok(()) => return Ok(()),
                        Err(e) => last_err = e,
                    }
                }
                Err(e) => last_err = e,
            }
        }
        self.pending.lock().unwrap().remove(&id);
        Err(last_err)
    }
}

impl Drop for WireClient {
    fn drop(&mut self) {
        // Half-close: the server reader sees EOF and stops accepting our
        // requests; in-flight replies still flush before the reader ends.
        let mut link = self.link.lock().unwrap();
        let _ = link.writer.shutdown(Shutdown::Write);
        if let Some(r) = link.reader.take() {
            let _ = r.join();
        }
    }
}

/// What a submission produced, transport-independent.
pub(crate) enum Submitted {
    /// Admitted (or at least in flight): the reply arrives here.
    Handle(mpsc::Receiver<ServeResult>),
    /// Fast-rejected before a handle existed (in-process `try_submit`) —
    /// the coordinator's metrics carry the reason.
    Rejected,
    /// The coordinator or connection is gone; stop driving.
    Down,
}

/// Factory for per-driver-thread connections: closed-loop clients each
/// get their own (realistic per-user connections over TCP; cheap Arc
/// clones in-process).
pub(crate) enum TransportCtx {
    InProcess(Arc<Coordinator>),
    Tcp { addr: SocketAddr, ttl: Option<Duration> },
}

impl TransportCtx {
    pub(crate) fn connect(&self) -> io::Result<ClientConn> {
        match self {
            TransportCtx::InProcess(c) => Ok(ClientConn::InProcess(c.clone())),
            TransportCtx::Tcp { addr, ttl } => {
                Ok(ClientConn::Tcp(WireClient::connect(*addr, *ttl)?))
            }
        }
    }
}

/// One driver thread's connection to the service.
pub(crate) enum ClientConn {
    InProcess(Arc<Coordinator>),
    Tcp(WireClient),
}

impl ClientConn {
    /// Submit generated traffic. Over TCP a rejection arrives as a
    /// result frame on the handle (the runner's collectors already treat
    /// `Ok(Err(_))` as shed/rejected); in-process fast-rejects surface
    /// as [`Submitted::Rejected`] with no handle at all — either way the
    /// coordinator's metrics count it exactly once.
    pub(crate) fn submit(
        &self,
        xs: Vec<f32>,
        ys: Vec<f32>,
        transforms: Vec<Transform>,
        fast_reject: bool,
        priority: Priority,
    ) -> Submitted {
        match self {
            ClientConn::InProcess(c) => {
                if fast_reject {
                    match c.try_submit_with_priority(xs, ys, transforms, priority) {
                        Ok(rx) => Submitted::Handle(rx),
                        Err(rej) if rej.reason == RejectReason::ShuttingDown => Submitted::Down,
                        Err(_) => Submitted::Rejected,
                    }
                } else {
                    match c.submit_with_priority(xs, ys, transforms, priority) {
                        Ok(rx) => Submitted::Handle(rx),
                        Err(_) => Submitted::Down,
                    }
                }
            }
            ClientConn::Tcp(wc) => {
                match wc.submit_with_priority(xs, ys, transforms, fast_reject, priority) {
                    Ok(rx) => Submitted::Handle(rx),
                    Err(_) => Submitted::Down,
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BackendChoice, BatcherConfig, CoordinatorConfig, WireServer};
    use std::net::TcpListener;
    use std::time::Instant;

    fn quick_coordinator() -> Arc<Coordinator> {
        Arc::new(
            Coordinator::start(CoordinatorConfig {
                backend: BackendChoice::Native,
                workers: 2,
                batcher: BatcherConfig {
                    max_wait: Duration::from_micros(200),
                    ..Default::default()
                },
                ..Default::default()
            })
            .unwrap(),
        )
    }

    #[test]
    fn server_dying_mid_stream_disconnects_in_flight_requests_not_hangs() {
        // A raw listener standing in for a server that accepts the
        // connection, takes the request, and then dies without replying.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = WireClient::connect(addr, None).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        let rx = client
            .submit(vec![1.0, 2.0], vec![3.0, 4.0], vec![], false)
            .expect("write lands in the socket buffer");
        // The "crash": both halves die with the request still in flight.
        server_side.shutdown(Shutdown::Both).unwrap();
        drop(server_side);
        // The reply channel must observe a disconnect — a typed error the
        // runner counts as failed — and must never hang.
        match rx.recv_timeout(Duration::from_secs(10)) {
            Err(mpsc::RecvTimeoutError::Disconnected) => {}
            other => panic!("expected disconnect, got {other:?}"),
        }
        // Without a reconnect policy, later submissions fail immediately
        // with an io error instead of pretending the connection is fine.
        let dead = (0..10).any(|_| client.submit(vec![1.0], vec![2.0], vec![], false).is_err());
        assert!(dead, "writes to a dead connection must surface an error");
    }

    #[test]
    fn reconnect_policy_heals_the_client_across_a_server_restart() {
        let c = quick_coordinator();
        let server = WireServer::bind("127.0.0.1:0", c.clone()).unwrap();
        let addr = server.local_addr();
        let client = WireClient::connect_with(
            addr,
            None,
            ReconnectPolicy {
                max_attempts: 8,
                base: Duration::from_millis(1),
                max: Duration::from_millis(20),
                seed: 9,
            },
        )
        .unwrap();
        let rx = client.submit(vec![1.0], vec![2.0], vec![], false).unwrap();
        assert!(rx.recv_timeout(Duration::from_secs(10)).unwrap().is_ok());

        // Crash the serving tier (abrupt: no drain) and restart it on the
        // same address, same coordinator.
        server.kill();
        let server2 = WireServer::bind(&addr.to_string(), c.clone()).unwrap();

        // The next submissions find the dead socket, re-dial under the
        // policy, and complete on the restarted server. (A write racing
        // the kill can land in the dead socket's buffer and "succeed";
        // its receiver then observes a disconnect — loop past those.)
        let deadline = Instant::now() + Duration::from_secs(10);
        let healed = loop {
            if Instant::now() >= deadline {
                break false;
            }
            match client.submit(vec![5.0], vec![6.0], vec![], false) {
                Ok(rx) => match rx.recv_timeout(Duration::from_secs(10)) {
                    Ok(Ok(resp)) => {
                        assert_eq!(resp.xs, vec![5.0]);
                        break true;
                    }
                    _ => continue,
                },
                Err(_) => continue,
            }
        };
        assert!(healed, "reconnect policy must heal across the restart");

        drop(client);
        server2.shutdown();
        if let Ok(c) = Arc::try_unwrap(c) {
            c.shutdown();
        }
    }

    #[test]
    fn transport_labels_and_parsing_roundtrip() {
        assert_eq!(TransportKind::InProcess.label(), "in-process");
        assert_eq!(TransportKind::Tcp.label(), "tcp");
        for t in [TransportKind::InProcess, TransportKind::Tcp] {
            assert_eq!(TransportKind::parse(t.label()), Some(t));
        }
        assert_eq!(TransportKind::parse("loopback"), Some(TransportKind::Tcp));
        assert_eq!(TransportKind::parse("carrier-pigeon"), None);
    }
}
