//! Client transports: how loadgen traffic reaches the coordinator.
//!
//! Every scenario runs on either transport with the same seeded request
//! streams, so the two `CapacityReport` rows are directly comparable —
//! in-process measures the library ceiling, loopback adds the wire
//! protocol, kernel sockets, and the server's per-connection threads
//! (acceptance: ROADMAP §Scale's ~15% bar at the same p99).
//!
//! * [`TransportKind::InProcess`] — `submit`/`try_submit` library calls,
//!   a per-request reply channel straight from the coordinator.
//! * [`TransportKind::Tcp`] — a [`WireClient`] per driver thread: request
//!   frames out over loopback, a background reader demuxing result
//!   frames by id into per-request channels. The driver-facing surface
//!   is the same `mpsc::Receiver<ServeResult>` either way, so the
//!   runner's collection/accounting logic is transport-blind.

use std::collections::HashMap;
use std::io;
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::wire::{self, Frame};
use crate::coordinator::{Coordinator, RejectReason, ServeResult, TransformRequest};
use crate::graphics::Transform;

/// Which path a scenario's traffic takes to the coordinator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// Library calls in the loadgen process (the pre-wire baseline).
    InProcess,
    /// The wire protocol over a loopback TCP connection per driver.
    Tcp,
}

impl TransportKind {
    /// Stable label used in `CapacityReport`/`BENCH_coordinator.json`.
    pub fn label(&self) -> &'static str {
        match self {
            TransportKind::InProcess => "in-process",
            TransportKind::Tcp => "tcp",
        }
    }

    /// Parse a CLI `--transport` value.
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "in-process" | "inprocess" | "local" => Some(TransportKind::InProcess),
            "tcp" | "loopback" => Some(TransportKind::Tcp),
            _ => None,
        }
    }
}

/// A client connection speaking the [`wire`] protocol: submissions write
/// request frames (client-assigned ids), a background reader thread
/// routes each result frame to its request's channel. Dropping the
/// client closes the connection and disconnects any still-pending
/// receivers (observed as `failed` by the runner — never the case on a
/// clean server).
pub struct WireClient {
    writer: Mutex<TcpStream>,
    pending: Arc<Mutex<HashMap<u64, mpsc::Sender<ServeResult>>>>,
    next_id: AtomicU64,
    /// TTL stamped on every outgoing request (the wire carries it
    /// explicitly; `None` defers to the server's default).
    ttl: Option<Duration>,
    reader: Option<JoinHandle<()>>,
}

impl WireClient {
    /// Connect to a [`crate::coordinator::WireServer`] and start the
    /// reply-demux reader.
    pub fn connect(addr: SocketAddr, ttl: Option<Duration>) -> io::Result<WireClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let writer = Mutex::new(stream.try_clone()?);
        let mut read_half = stream;
        let pending: Arc<Mutex<HashMap<u64, mpsc::Sender<ServeResult>>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let reader = {
            let pending = pending.clone();
            std::thread::Builder::new().name("wire-client-reader".into()).spawn(move || {
                loop {
                    let payload = match wire::read_frame(&mut read_half) {
                        Ok(Some(p)) => p,
                        Ok(None) | Err(_) => break, // server closed / stream died
                    };
                    match wire::decode_frame(&payload) {
                        Ok(Frame::Result(res)) => {
                            let id = match &res {
                                Ok(resp) => resp.id,
                                Err(rej) => rej.id,
                            };
                            if let Some(tx) = pending.lock().unwrap().remove(&id) {
                                let _ = tx.send(res);
                            }
                        }
                        Ok(Frame::ProtocolError { code, message }) => {
                            eprintln!("wire client: server protocol error {code}: {message}");
                            break;
                        }
                        // A request frame from the server, or garbage:
                        // nothing sane continues from here.
                        Ok(Frame::Request { .. }) | Err(_) => break,
                    }
                }
                // Orphan every outstanding request so waiting receivers
                // observe a disconnect instead of hanging.
                pending.lock().unwrap().clear();
            })?
        };
        Ok(WireClient { writer, pending, next_id: AtomicU64::new(1), ttl, reader: Some(reader) })
    }

    /// Send one request; the reply (response or rejection) arrives on the
    /// returned channel. `fast_reject` selects the server's `try_submit`
    /// admission discipline.
    pub fn submit(
        &self,
        xs: Vec<f32>,
        ys: Vec<f32>,
        transforms: Vec<Transform>,
        fast_reject: bool,
    ) -> io::Result<mpsc::Receiver<ServeResult>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut req = TransformRequest::new(id, xs, ys, transforms);
        req.ttl = self.ttl;
        self.submit_request(req, fast_reject)
    }

    /// Send a pre-built request (the id must be unique per connection).
    pub fn submit_request(
        &self,
        req: TransformRequest,
        fast_reject: bool,
    ) -> io::Result<mpsc::Receiver<ServeResult>> {
        let (tx, rx) = mpsc::channel();
        let bytes = wire::encode_request(&req, fast_reject);
        // Register before writing: the reply can race back before the
        // writer lock is even released.
        self.pending.lock().unwrap().insert(req.id, tx);
        let res = {
            let mut w = self.writer.lock().unwrap();
            wire::write_frame(&mut *w, &bytes)
        };
        if let Err(e) = res {
            self.pending.lock().unwrap().remove(&req.id);
            return Err(e);
        }
        Ok(rx)
    }
}

impl Drop for WireClient {
    fn drop(&mut self) {
        // Half-close: the server reader sees EOF and stops accepting our
        // requests; in-flight replies still flush before the reader ends.
        if let Ok(w) = self.writer.lock() {
            let _ = w.shutdown(Shutdown::Write);
        }
        if let Some(r) = self.reader.take() {
            let _ = r.join();
        }
    }
}

/// What a submission produced, transport-independent.
pub(crate) enum Submitted {
    /// Admitted (or at least in flight): the reply arrives here.
    Handle(mpsc::Receiver<ServeResult>),
    /// Fast-rejected before a handle existed (in-process `try_submit`) —
    /// the coordinator's metrics carry the reason.
    Rejected,
    /// The coordinator or connection is gone; stop driving.
    Down,
}

/// Factory for per-driver-thread connections: closed-loop clients each
/// get their own (realistic per-user connections over TCP; cheap Arc
/// clones in-process).
pub(crate) enum TransportCtx {
    InProcess(Arc<Coordinator>),
    Tcp { addr: SocketAddr, ttl: Option<Duration> },
}

impl TransportCtx {
    pub(crate) fn connect(&self) -> io::Result<ClientConn> {
        match self {
            TransportCtx::InProcess(c) => Ok(ClientConn::InProcess(c.clone())),
            TransportCtx::Tcp { addr, ttl } => {
                Ok(ClientConn::Tcp(WireClient::connect(*addr, *ttl)?))
            }
        }
    }
}

/// One driver thread's connection to the service.
pub(crate) enum ClientConn {
    InProcess(Arc<Coordinator>),
    Tcp(WireClient),
}

impl ClientConn {
    /// Submit generated traffic. Over TCP a rejection arrives as a
    /// result frame on the handle (the runner's collectors already treat
    /// `Ok(Err(_))` as shed/rejected); in-process fast-rejects surface
    /// as [`Submitted::Rejected`] with no handle at all — either way the
    /// coordinator's metrics count it exactly once.
    pub(crate) fn submit(
        &self,
        xs: Vec<f32>,
        ys: Vec<f32>,
        transforms: Vec<Transform>,
        fast_reject: bool,
    ) -> Submitted {
        match self {
            ClientConn::InProcess(c) => {
                if fast_reject {
                    match c.try_submit(xs, ys, transforms) {
                        Ok(rx) => Submitted::Handle(rx),
                        Err(rej) if rej.reason == RejectReason::ShuttingDown => Submitted::Down,
                        Err(_) => Submitted::Rejected,
                    }
                } else {
                    match c.submit(xs, ys, transforms) {
                        Ok(rx) => Submitted::Handle(rx),
                        Err(_) => Submitted::Down,
                    }
                }
            }
            ClientConn::Tcp(wc) => match wc.submit(xs, ys, transforms, fast_reject) {
                Ok(rx) => Submitted::Handle(rx),
                Err(_) => Submitted::Down,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_labels_and_parsing_roundtrip() {
        assert_eq!(TransportKind::InProcess.label(), "in-process");
        assert_eq!(TransportKind::Tcp.label(), "tcp");
        for t in [TransportKind::InProcess, TransportKind::Tcp] {
            assert_eq!(TransportKind::parse(t.label()), Some(t));
        }
        assert_eq!(TransportKind::parse("loopback"), Some(TransportKind::Tcp));
        assert_eq!(TransportKind::parse("carrier-pigeon"), None);
    }
}
