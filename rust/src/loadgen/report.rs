//! The capacity report: one record per scenario run, rendered for humans
//! and written to `BENCH_coordinator.json` (atomic temp-file + rename,
//! the same contract as `BENCH_simulator.json`) for the CI trajectory.

use std::time::Duration;

use crate::benchkit::write_atomic;
use crate::coordinator::BackendSnapshot;

/// Everything a scenario run measured. All rates are per wall-clock
/// second of the measured run; latency is submit → response receipt.
#[derive(Debug, Clone)]
pub struct CapacityReport {
    pub scenario: String,
    pub profile: String,
    /// Which path the traffic took: `in-process` (library calls) or
    /// `tcp` (the wire protocol over a loopback listener). ROADMAP
    /// §Scale's acceptance bar compares the two rows.
    pub transport: &'static str,
    pub backend: &'static str,
    /// Batch-window policy the run used: `default`, `fixed(<N>us)`, or
    /// `adaptive` — the A/B gate keys on this column.
    pub batch_window: String,
    pub workers: usize,
    pub shards: usize,
    pub seed: u64,
    pub duration_s: f64,
    /// Requests offered to the coordinator (including rejected ones).
    pub submitted: u64,
    /// Requests that received a successful response.
    pub completed: u64,
    /// Deadline-expired requests shed by the batcher.
    pub shed: u64,
    /// Fast-rejected at admission (`try_submit` on a full queue).
    pub rejected: u64,
    /// Served, but after their deadline.
    pub deadline_missed: u64,
    /// Fast-rejected because the coordinator was shutting down — kept
    /// distinct from `rejected` (overload) so the failure breakdown
    /// separates "retry later" from "stop retrying".
    pub closed: u64,
    /// Reply channels that died without a message — always 0 in a
    /// correct coordinator (asserted by CI's loadgen-smoke and
    /// chaos-smoke jobs, fault injection included).
    pub failed: u64,
    /// Seed of the armed fault plan, when the scenario injected faults.
    pub fault_seed: Option<u64>,
    /// Supervised tile crashes in the M1 pool (injected or real).
    pub shard_crashes: u64,
    /// Warm restarts of crashed shards from their boot snapshot.
    pub shard_restarts: u64,
    /// Tiles re-run on the recovery shard after a death / lost reply.
    pub tiles_redispatched: u64,
    /// Slowest single pool recovery pass, µs (gauge).
    pub recovery_max_us: u64,
    pub throughput_rps: f64,
    pub points_per_s: f64,
    pub latency_mean_us: f64,
    pub latency_p50_us: u64,
    pub latency_p95_us: u64,
    pub latency_p99_us: u64,
    /// Interactive-lane completions (client-observed; equals `completed`
    /// for single-lane scenarios).
    pub interactive_completed: u64,
    /// Interactive requests rejected `DeadlineExceeded` — the two-lane
    /// gate asserts 0 while bulk is being shed.
    pub interactive_deadline_missed: u64,
    /// p99 latency over interactive-lane completions only.
    pub interactive_p99_us: u64,
    /// Bulk-lane completions.
    pub bulk_completed: u64,
    /// Bulk requests rejected `DeadlineExceeded` (lane-weighted shed).
    pub bulk_shed: u64,
    pub queue_depth_mean: f64,
    pub queue_depth_max: u64,
    /// Mean points per backend job — batching efficiency.
    pub mean_batch_points: f64,
    /// Simulated M1 cycles per executed point (M1Sim backend).
    pub sim_cycles_per_point: f64,
    /// Backend coordinator count behind the front-end router (`0` = no
    /// router — the single-coordinator layout of every other scenario).
    pub router_backends: usize,
    /// Backend links the router's breaker declared dead mid-run.
    pub backend_deaths: u64,
    /// Backends that rejoined the rotation after a death (reconnect +
    /// first health reply).
    pub backend_rejoins: u64,
    /// In-flight requests harvested from dying backends and re-dispatched
    /// to a live one (each still answered exactly once).
    pub redispatched_requests: u64,
    /// Requests rejected `Unavailable`: every backend dead, or the
    /// redispatch hop budget exhausted.
    pub unavailable_rejected: u64,
    /// Per-backend rows (router runs only; empty otherwise).
    pub backends: Vec<BackendSnapshot>,
}

/// Exact percentile over pre-sorted latency samples (nearest-rank on the
/// raw samples — unlike the coordinator's log₂ histogram, loadgen keeps
/// every sample, so quantiles are not bucket-rounded).
pub fn percentile_us(sorted: &[Duration], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)].as_micros() as u64
}

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "0.000".to_string() // keep the report strictly-valid JSON
    }
}

impl CapacityReport {
    /// One JSON object (no trailing newline).
    pub fn to_json(&self) -> String {
        let rows: Vec<String> = self
            .backends
            .iter()
            .map(|b| {
                format!(
                    "{{\"index\": {}, \"addr\": \"{}\", \"state\": \"{}\", \
                     \"proxied\": {}, \"replies\": {}, \"deaths\": {}, \
                     \"rejoins\": {}, \"queue_depth\": {}}}",
                    b.index,
                    b.addr.replace('"', "'"),
                    b.state,
                    b.proxied,
                    b.replies,
                    b.deaths,
                    b.rejoins,
                    b.queue_depth,
                )
            })
            .collect();
        format!(
            "{{\"scenario\": \"{}\", \"profile\": \"{}\", \"transport\": \"{}\", \
             \"backend\": \"{}\", \"batch_window\": \"{}\", \
             \"workers\": {}, \"shards\": {}, \"seed\": {}, \"duration_s\": {}, \
             \"submitted\": {}, \"completed\": {}, \"shed\": {}, \"rejected\": {}, \
             \"deadline_missed\": {}, \"closed\": {}, \"failed\": {}, \
             \"fault_seed\": {}, \"shard_crashes\": {}, \"shard_restarts\": {}, \
             \"tiles_redispatched\": {}, \"recovery_max_us\": {}, \
             \"throughput_rps\": {}, \
             \"points_per_s\": {}, \"latency_mean_us\": {}, \"latency_p50_us\": {}, \
             \"latency_p95_us\": {}, \"latency_p99_us\": {}, \
             \"interactive_completed\": {}, \"interactive_deadline_missed\": {}, \
             \"interactive_p99_us\": {}, \"bulk_completed\": {}, \"bulk_shed\": {}, \
             \"queue_depth_mean\": {}, \
             \"queue_depth_max\": {}, \"mean_batch_points\": {}, \
             \"sim_cycles_per_point\": {}, \"router_backends\": {}, \
             \"backend_deaths\": {}, \"backend_rejoins\": {}, \
             \"redispatched_requests\": {}, \"unavailable_rejected\": {}, \
             \"backends\": [{}]}}",
            self.scenario.replace('"', "'"),
            self.profile.replace('"', "'"),
            self.transport,
            self.backend,
            self.batch_window.replace('"', "'"),
            self.workers,
            self.shards,
            self.seed,
            json_f64(self.duration_s),
            self.submitted,
            self.completed,
            self.shed,
            self.rejected,
            self.deadline_missed,
            self.closed,
            self.failed,
            self.fault_seed.map_or("null".to_string(), |s| s.to_string()),
            self.shard_crashes,
            self.shard_restarts,
            self.tiles_redispatched,
            self.recovery_max_us,
            json_f64(self.throughput_rps),
            json_f64(self.points_per_s),
            json_f64(self.latency_mean_us),
            self.latency_p50_us,
            self.latency_p95_us,
            self.latency_p99_us,
            self.interactive_completed,
            self.interactive_deadline_missed,
            self.interactive_p99_us,
            self.bulk_completed,
            self.bulk_shed,
            json_f64(self.queue_depth_mean),
            self.queue_depth_max,
            json_f64(self.mean_batch_points),
            json_f64(self.sim_cycles_per_point),
            self.router_backends,
            self.backend_deaths,
            self.backend_rejoins,
            self.redispatched_requests,
            self.unavailable_rejected,
            rows.join(", "),
        )
    }

    /// Human-readable summary block.
    pub fn render(&self) -> String {
        let mut out = format!(
            "scenario {} [{}] via {} on {} (workers={} shards={} seed={} window={}) over {:.2}s\n\
             offered={} completed={} shed={} rejected={} deadline_missed={} closed={} failed={}\n\
             throughput: {:.1} req/s, {:.2} M points/s   mean batch {:.1} pts\n\
             latency: mean={:.0}us p50={}us p95={}us p99={}us\n\
             queue depth: mean={:.1} max={}   simulated M1 cycles/point={:.2}",
            self.scenario,
            self.profile,
            self.transport,
            self.backend,
            self.workers,
            self.shards,
            self.seed,
            self.batch_window,
            self.duration_s,
            self.submitted,
            self.completed,
            self.shed,
            self.rejected,
            self.deadline_missed,
            self.closed,
            self.failed,
            self.throughput_rps,
            self.points_per_s / 1e6,
            self.mean_batch_points,
            self.latency_mean_us,
            self.latency_p50_us,
            self.latency_p95_us,
            self.latency_p99_us,
            self.queue_depth_mean,
            self.queue_depth_max,
            self.sim_cycles_per_point,
        );
        if self.bulk_completed > 0 || self.bulk_shed > 0 {
            out.push_str(&format!(
                "\nlanes: interactive completed={} deadline_missed={} p99={}us | \
                 bulk completed={} shed={}",
                self.interactive_completed,
                self.interactive_deadline_missed,
                self.interactive_p99_us,
                self.bulk_completed,
                self.bulk_shed,
            ));
        }
        if let Some(seed) = self.fault_seed {
            out.push_str(&format!(
                "\nfault injection (seed {seed}): crashes={} restarts={} \
                 redispatched={} recovery_max={}us",
                self.shard_crashes,
                self.shard_restarts,
                self.tiles_redispatched,
                self.recovery_max_us,
            ));
        }
        if self.router_backends > 0 {
            out.push_str(&format!(
                "\nrouter over {} backends: deaths={} rejoins={} \
                 redispatched={} unavailable={}",
                self.router_backends,
                self.backend_deaths,
                self.backend_rejoins,
                self.redispatched_requests,
                self.unavailable_rejected,
            ));
            for b in &self.backends {
                out.push_str(&format!(
                    "\n  backend[{}] {} ({}): proxied={} replies={} deaths={} rejoins={}",
                    b.index, b.addr, b.state, b.proxied, b.replies, b.deaths, b.rejoins,
                ));
            }
        }
        out
    }
}

/// Default report path: `BENCH_coordinator.json`, overridable with the
/// `BENCH_COORD_JSON` env var (mirrors the simulator bench's
/// `BENCH_JSON`).
pub fn default_path() -> String {
    std::env::var("BENCH_COORD_JSON").unwrap_or_else(|_| "BENCH_coordinator.json".to_string())
}

/// Write reports as a JSON array, atomically.
pub fn write_reports(reports: &[CapacityReport], path: &str) -> std::io::Result<()> {
    let mut out = String::from("[\n");
    for (i, r) in reports.iter().enumerate() {
        out.push_str("  ");
        out.push_str(&r.to_json());
        out.push_str(if i + 1 < reports.len() { ",\n" } else { "\n" });
    }
    out.push_str("]\n");
    write_atomic(path, &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CapacityReport {
        CapacityReport {
            scenario: "smoke".into(),
            profile: "closed-loop(4)".into(),
            transport: "in-process",
            backend: "m1sim",
            batch_window: "default".into(),
            workers: 1,
            shards: 2,
            seed: 42,
            duration_s: 1.0,
            submitted: 100,
            completed: 100,
            shed: 0,
            rejected: 0,
            deadline_missed: 0,
            closed: 0,
            failed: 0,
            fault_seed: None,
            shard_crashes: 0,
            shard_restarts: 0,
            tiles_redispatched: 0,
            recovery_max_us: 0,
            throughput_rps: 100.0,
            points_per_s: 6400.0,
            latency_mean_us: 900.0,
            latency_p50_us: 800,
            latency_p95_us: 1500,
            latency_p99_us: 2000,
            interactive_completed: 100,
            interactive_deadline_missed: 0,
            interactive_p99_us: 2000,
            bulk_completed: 0,
            bulk_shed: 0,
            queue_depth_mean: 1.5,
            queue_depth_max: 4,
            mean_batch_points: 128.0,
            sim_cycles_per_point: 1.62,
            router_backends: 0,
            backend_deaths: 0,
            backend_rejoins: 0,
            redispatched_requests: 0,
            unavailable_rejected: 0,
            backends: Vec::new(),
        }
    }

    fn router_sample() -> CapacityReport {
        let mut r = sample();
        r.scenario = "failover".into();
        r.transport = "tcp";
        r.router_backends = 2;
        r.backend_deaths = 1;
        r.backend_rejoins = 1;
        r.redispatched_requests = 3;
        r.backends = vec![
            BackendSnapshot {
                index: 0,
                addr: "127.0.0.1:9000".into(),
                state: "healthy",
                proxied: 60,
                replies: 60,
                deaths: 1,
                rejoins: 1,
                queue_depth: 2,
            },
            BackendSnapshot {
                index: 1,
                addr: "127.0.0.1:9001".into(),
                state: "healthy",
                proxied: 40,
                replies: 40,
                deaths: 0,
                rejoins: 0,
                queue_depth: 0,
            },
        ];
        r
    }

    #[test]
    fn json_is_structurally_sound() {
        let j = sample().to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert_eq!(j.matches('{').count(), 1);
        assert_eq!(j.matches('}').count(), 1);
        // Every key present exactly once.
        for key in [
            "scenario", "profile", "transport", "backend", "batch_window", "workers",
            "shards", "seed", "duration_s",
            "submitted", "completed", "shed", "rejected", "deadline_missed", "closed",
            "failed", "fault_seed", "shard_crashes", "shard_restarts", "tiles_redispatched",
            "recovery_max_us", "throughput_rps", "points_per_s", "latency_mean_us",
            "latency_p50_us", "latency_p95_us", "latency_p99_us",
            "interactive_completed", "interactive_deadline_missed", "interactive_p99_us",
            "bulk_completed", "bulk_shed", "queue_depth_mean",
            "queue_depth_max", "mean_batch_points", "sim_cycles_per_point",
            "router_backends", "backend_deaths", "backend_rejoins",
            "redispatched_requests", "unavailable_rejected", "backends",
        ] {
            assert_eq!(j.matches(&format!("\"{key}\":")).count(), 1, "key {key}");
        }
        // No unescaped NaN/inf can reach the file.
        assert!(!j.contains("NaN") && !j.contains("inf"));
        // Fault-free runs serialize a JSON null seed.
        assert!(j.contains("\"fault_seed\": null"));
    }

    #[test]
    fn fault_injected_report_carries_the_supervision_breakdown() {
        let mut r = sample();
        r.fault_seed = Some(0xC0FFEE);
        r.shard_crashes = 4;
        r.shard_restarts = 4;
        r.tiles_redispatched = 2;
        r.recovery_max_us = 800;
        r.closed = 1;
        let j = r.to_json();
        assert!(j.contains(&format!("\"fault_seed\": {}", 0xC0FFEE)));
        assert!(j.contains("\"shard_crashes\": 4"));
        assert!(j.contains("\"closed\": 1"));
        let text = r.render();
        assert!(text.contains("fault injection (seed 12648430)"));
        assert!(text.contains("crashes=4 restarts=4 redispatched=2 recovery_max=800us"));
        // Fault-free reports keep the human block clean.
        assert!(!sample().render().contains("fault injection"));
    }

    #[test]
    fn router_report_nests_one_object_per_backend() {
        let r = router_sample();
        let j = r.to_json();
        // Outer object plus one nested object per backend row.
        assert_eq!(j.matches('{').count(), 3);
        assert_eq!(j.matches('}').count(), 3);
        assert_eq!(j.matches("\"addr\":").count(), 2);
        assert_eq!(j.matches("\"state\": \"healthy\"").count(), 2);
        assert!(j.contains("\"router_backends\": 2"));
        assert!(j.contains("\"backend_deaths\": 1"));
        assert!(j.contains("\"redispatched_requests\": 3"));
        let text = r.render();
        assert!(text.contains("router over 2 backends: deaths=1 rejoins=1"));
        assert!(text.contains("backend[0] 127.0.0.1:9000 (healthy): proxied=60"));
        assert!(text.contains("backend[1] 127.0.0.1:9001"));
        // Non-router reports keep the human block free of router noise.
        assert!(!sample().render().contains("router over"));
    }

    #[test]
    fn two_lane_report_carries_the_lane_breakdown() {
        let mut r = sample();
        r.scenario = "lanes".into();
        r.batch_window = "adaptive".into();
        r.interactive_completed = 80;
        r.interactive_p99_us = 1500;
        r.bulk_completed = 15;
        r.bulk_shed = 5;
        let j = r.to_json();
        assert!(j.contains("\"batch_window\": \"adaptive\""));
        assert!(j.contains("\"interactive_completed\": 80"));
        assert!(j.contains("\"bulk_shed\": 5"));
        let text = r.render();
        assert!(text.contains("window=adaptive"));
        assert!(text.contains("lanes: interactive completed=80 deadline_missed=0 p99=1500us"));
        assert!(text.contains("bulk completed=15 shed=5"));
        // Single-lane reports keep the human block free of lane noise.
        assert!(!sample().render().contains("lanes:"));
    }

    #[test]
    fn nonfinite_rates_serialize_as_zero() {
        let mut r = sample();
        r.throughput_rps = f64::NAN;
        r.points_per_s = f64::INFINITY;
        let j = r.to_json();
        assert!(j.contains("\"throughput_rps\": 0.000"));
        assert!(j.contains("\"points_per_s\": 0.000"));
    }

    #[test]
    fn write_reports_emits_a_json_array() {
        let dir = std::env::temp_dir().join("morpho_loadgen_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_coordinator.json");
        let path = path.to_str().unwrap();
        write_reports(&[sample(), sample()], path).unwrap();
        let s = std::fs::read_to_string(path).unwrap();
        assert!(s.starts_with("[\n") && s.ends_with("]\n"));
        assert_eq!(s.matches("\"scenario\"").count(), 2);
        assert_eq!(s.matches("},").count(), 1, "exactly one separator for two rows");
    }

    #[test]
    fn percentiles_nearest_rank() {
        let samples: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        assert_eq!(percentile_us(&samples, 0.0), 1);
        assert_eq!(percentile_us(&samples, 0.5), 51); // nearest-rank on 0-based idx
        assert_eq!(percentile_us(&samples, 1.0), 100);
        assert_eq!(percentile_us(&[], 0.5), 0);
    }
}
