//! Deterministic request-stream generation: request `i` of stream `s` is
//! a pure function of `(seed, s, i)` — no wall clock, no shared RNG
//! state, no dependence on thread interleaving. This is what makes load
//! runs reproducible: a closed-loop client replays the identical request
//! sequence on every run with the same seed.

use crate::coordinator::Priority;
use crate::graphics::Transform;
use crate::testkit::Rng;

use super::scenario::{TransformKind, WorkloadMix};

/// One generated client request (pre-submission).
#[derive(Debug, Clone)]
pub struct GeneratedRequest {
    pub xs: Vec<f32>,
    pub ys: Vec<f32>,
    pub transforms: Vec<Transform>,
    pub priority: Priority,
}

/// Stateless request generator over a [`WorkloadMix`].
#[derive(Debug, Clone)]
pub struct RequestFactory {
    seed: u64,
    mix: WorkloadMix,
}

/// splitmix64-style finalizer over `(seed, stream, index)` — gives each
/// virtual arrival its own well-mixed RNG seed, so streams are mutually
/// independent and each is identical across runs.
fn arrival_seed(seed: u64, stream: u64, index: u64) -> u64 {
    let mut z = seed
        ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ index.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Weighted draw from a non-empty `(weight, value)` table.
fn weighted<'a, T>(rng: &mut Rng, options: &'a [(u32, T)]) -> &'a T {
    let total: u64 = options.iter().map(|(w, _)| *w as u64).sum();
    let mut r = rng.below(total.max(1));
    for (w, v) in options {
        if r < *w as u64 {
            return v;
        }
        r -= *w as u64;
    }
    &options.last().expect("weighted() requires a non-empty table").1
}

impl RequestFactory {
    pub fn new(seed: u64, mix: WorkloadMix) -> RequestFactory {
        assert!(!mix.sizes.is_empty() && !mix.transforms.is_empty(), "empty workload mix");
        RequestFactory { seed, mix }
    }

    /// The content of request `index` on stream `stream`.
    ///
    /// Transforms come from small discrete vocabularies (8 rotations, a
    /// handful of scales/translations) so concurrent requests frequently
    /// share a batch key, and every value quantizes onto the M1's Q6
    /// fixed-point datapath. Coordinates stay within ±100, far inside
    /// the backend's ±8192 i16 headroom.
    pub fn request(&self, stream: u64, index: u64) -> GeneratedRequest {
        let mut rng = Rng::new(arrival_seed(self.seed, stream, index));
        let mut n = *weighted(&mut rng, &self.mix.sizes);
        let kind = *weighted(&mut rng, &self.mix.transforms);
        // The bulk-lane draw happens only for two-lane mixes: when
        // `bulk_share == 0.0` no extra random number is consumed, so the
        // request streams of every single-lane scenario stay bit-identical
        // to what they were before lanes existed.
        let mut priority = Priority::Interactive;
        if self.mix.bulk_share > 0.0
            && (rng.below(1 << 16) as f32) < self.mix.bulk_share * (1 << 16) as f32
        {
            priority = Priority::Bulk;
            n = *weighted(&mut rng, &self.mix.bulk_sizes);
        }
        let xs: Vec<f32> = (0..n).map(|_| rng.f32_range(-100.0, 100.0)).collect();
        let ys: Vec<f32> = (0..n).map(|_| rng.f32_range(-100.0, 100.0)).collect();
        let translate = |rng: &mut Rng| Transform::Translate {
            tx: [-12.0f32, -4.0, 4.0, 12.0][rng.below(4) as usize],
            ty: [-12.0f32, -4.0, 4.0, 12.0][rng.below(4) as usize],
        };
        let scale = |rng: &mut Rng| {
            let s = [0.75f32, 1.0, 1.25, 1.5][rng.below(4) as usize];
            Transform::Scale { sx: s, sy: s }
        };
        let rotate = |rng: &mut Rng| Transform::Rotate { theta: rng.below(8) as f32 * 0.35 };
        let transforms = match kind {
            TransformKind::Translate => vec![translate(&mut rng)],
            TransformKind::Scale => vec![scale(&mut rng)],
            TransformKind::Rotate => vec![rotate(&mut rng)],
            TransformKind::Composite => {
                vec![rotate(&mut rng), scale(&mut rng), translate(&mut rng)]
            }
        };
        GeneratedRequest { xs, ys, transforms, priority }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn factory(seed: u64) -> RequestFactory {
        RequestFactory::new(seed, WorkloadMix::mixed())
    }

    #[test]
    fn same_seed_reproduces_identical_streams() {
        let (a, b) = (factory(7), factory(7));
        for stream in 0..4u64 {
            for index in 0..50u64 {
                let ra = a.request(stream, index);
                let rb = b.request(stream, index);
                assert_eq!(ra.xs, rb.xs);
                assert_eq!(ra.ys, rb.ys);
                assert_eq!(format!("{:?}", ra.transforms), format!("{:?}", rb.transforms));
            }
        }
    }

    #[test]
    fn different_seeds_or_streams_differ() {
        let a = factory(7);
        let b = factory(8);
        let diverges = (0..20u64).any(|i| a.request(0, i).xs != b.request(0, i).xs);
        assert!(diverges, "distinct seeds must give distinct streams");
        let cross = (0..20u64).any(|i| a.request(0, i).xs != a.request(1, i).xs);
        assert!(cross, "distinct streams must be independent");
    }

    #[test]
    fn generated_requests_respect_mix_and_backend_envelope() {
        let f = factory(11);
        let sizes: Vec<usize> = WorkloadMix::mixed().sizes.iter().map(|&(_, n)| n).collect();
        for i in 0..200u64 {
            let r = f.request(0, i);
            assert!(sizes.contains(&r.xs.len()));
            assert_eq!(r.xs.len(), r.ys.len());
            assert!(r.xs.iter().chain(r.ys.iter()).all(|v| v.abs() <= 100.0));
            assert!(!r.transforms.is_empty() && r.transforms.len() <= 3);
        }
    }

    #[test]
    fn single_lane_mixes_stay_interactive_and_burn_no_extra_draws() {
        // bulk_share == 0.0 must not consume RNG state: a mix with lanes
        // configured but share 0 generates the exact same coordinates as
        // the plain mix, and everything stays on the interactive lane.
        let plain = factory(19);
        let mut laned_mix = WorkloadMix::mixed();
        laned_mix.bulk_sizes = vec![(1, 4096)];
        let laned = RequestFactory::new(19, laned_mix);
        for i in 0..100u64 {
            let (a, b) = (plain.request(0, i), laned.request(0, i));
            assert_eq!(a.priority, Priority::Interactive);
            assert_eq!(a.xs, b.xs);
            assert_eq!(a.ys, b.ys);
        }
    }

    #[test]
    fn two_lane_mix_draws_both_lanes_with_bulk_sizes() {
        let f = RequestFactory::new(23, WorkloadMix::two_lane());
        let bulk_sizes: Vec<usize> =
            WorkloadMix::two_lane().bulk_sizes.iter().map(|&(_, n)| n).collect();
        let small_sizes: Vec<usize> =
            WorkloadMix::two_lane().sizes.iter().map(|&(_, n)| n).collect();
        let (mut bulk, mut interactive) = (0u32, 0u32);
        for i in 0..200u64 {
            let r = f.request(0, i);
            match r.priority {
                Priority::Bulk => {
                    bulk += 1;
                    assert!(bulk_sizes.contains(&r.xs.len()), "bulk size {}", r.xs.len());
                }
                Priority::Interactive => {
                    interactive += 1;
                    assert!(small_sizes.contains(&r.xs.len()));
                }
            }
        }
        assert!(bulk >= 40 && interactive >= 40, "lanes unbalanced: {bulk}/{interactive}");
    }

    #[test]
    fn transform_vocabulary_is_small_enough_to_batch() {
        // 200 requests of one stream must reuse transform parameters —
        // the batching-opportunity property the generator promises.
        let f = factory(3);
        let mut keys = std::collections::HashSet::new();
        for i in 0..200u64 {
            keys.insert(format!("{:?}", f.request(0, i).transforms));
        }
        assert!(
            keys.len() < 150,
            "vocabulary too large to ever merge: {} distinct in 200",
            keys.len()
        );
    }
}
