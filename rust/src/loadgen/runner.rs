//! Scenario execution: drive a live [`Coordinator`] with the scenario's
//! arrival discipline — over the scenario's transport (in-process calls,
//! or the wire protocol against a loopback [`WireServer`] stood up for
//! the run) — measure per-request latency client-side, sample
//! admission-queue depth, and fold everything (plus the coordinator's own
//! metrics) into a [`CapacityReport`].

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::{
    BackendChoice, BackendKillPlan, Coordinator, CoordinatorConfig, FaultPlan, Priority,
    RejectReason, Router, RouterConfig, ServeResult, WireServer,
};

use super::report::{percentile_us, CapacityReport};
use super::scenario::{ArrivalProfile, RouterScenario, Scenario};
use super::transport::{Submitted, TransportCtx, TransportKind};
use super::workload::RequestFactory;

/// Client-side outcome counters shared by driver/collector threads.
#[derive(Debug, Default)]
struct Tally {
    submitted: AtomicU64,
    completed: AtomicU64,
    completed_points: AtomicU64,
    /// Reply channels that disconnected without a message — a
    /// coordinator bug if ever nonzero (CI asserts 0).
    failed: AtomicU64,
    /// Per-lane accounting, all observed client-side so the columns mean
    /// the same thing on every transport.
    interactive_completed: AtomicU64,
    /// Interactive requests rejected with `DeadlineExceeded` — the
    /// two-lane gate asserts this stays 0 while bulk is being shed.
    interactive_deadline_missed: AtomicU64,
    bulk_completed: AtomicU64,
    /// Bulk requests rejected with `DeadlineExceeded` (the lane-weighted
    /// shed path).
    bulk_shed: AtomicU64,
}

impl Tally {
    /// Route one served-or-rejected outcome into the per-lane counters.
    fn record_lane_outcome(&self, priority: Priority, outcome: &ServeResult) {
        match (priority, outcome) {
            (Priority::Interactive, Ok(_)) => {
                self.interactive_completed.fetch_add(1, Ordering::Relaxed);
            }
            (Priority::Interactive, Err(rej)) => {
                if rej.reason == RejectReason::DeadlineExceeded {
                    self.interactive_deadline_missed.fetch_add(1, Ordering::Relaxed);
                }
            }
            (Priority::Bulk, Ok(_)) => {
                self.bulk_completed.fetch_add(1, Ordering::Relaxed);
            }
            (Priority::Bulk, Err(rej)) => {
                if rej.reason == RejectReason::DeadlineExceeded {
                    self.bulk_shed.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }
}

/// Client-observed latencies, whole-run and interactive-lane-only (the
/// two-lane gate reads interactive p99 against the TTL).
#[derive(Debug, Default)]
struct LatencyLog {
    all: Vec<Duration>,
    interactive: Vec<Duration>,
}

impl LatencyLog {
    fn push(&mut self, priority: Priority, latency: Duration) {
        self.all.push(latency);
        if priority == Priority::Interactive {
            self.interactive.push(latency);
        }
    }

    fn merge(&mut self, mut other: LatencyLog) {
        self.all.append(&mut other.all);
        self.interactive.append(&mut other.interactive);
    }
}

/// In-flight open-loop requests awaiting a response.
type Outstanding = Arc<Mutex<Vec<(Instant, Priority, mpsc::Receiver<ServeResult>)>>>;

fn backend_name(b: BackendChoice) -> &'static str {
    match b {
        BackendChoice::Native => "native",
        BackendChoice::Xla => "xla",
        BackendChoice::M1Sim => "m1sim",
    }
}

/// The deterministic open-loop arrival timetable: offsets from run start,
/// exhausted once past `duration`. (Closed-loop scenarios have no
/// timetable — clients self-pace.)
struct Arrivals {
    profile: ArrivalProfile,
    duration: Duration,
    index: u64,
    /// Ramp only: next arrival offset in seconds (integrated rate).
    ramp_next: f64,
}

impl Arrivals {
    fn new(profile: ArrivalProfile, duration: Duration) -> Arrivals {
        Arrivals { profile, duration, index: 0, ramp_next: 0.0 }
    }

    fn next_arrival(&mut self) -> Option<Duration> {
        let offset = match self.profile {
            ArrivalProfile::OpenLoop { rate } => {
                Duration::from_nanos(self.index.saturating_mul(1_000_000_000) / rate.max(1))
            }
            ArrivalProfile::Burst { burst, period } => {
                period * ((self.index / burst.max(1) as u64) as u32)
            }
            ArrivalProfile::Ramp { from, to } => {
                let t = self.ramp_next;
                let d = self.duration.as_secs_f64().max(1e-9);
                // Instantaneous rate at t, integrated one arrival forward.
                let r = from as f64 + (to as f64 - from as f64) * (t / d);
                self.ramp_next = t + 1.0 / r.max(1.0);
                Duration::from_secs_f64(t)
            }
            ArrivalProfile::ClosedLoop { .. } => {
                unreachable!("closed-loop scenarios have no arrival timetable")
            }
        };
        self.index += 1;
        (offset < self.duration).then_some(offset)
    }
}

/// Run one scenario to completion and report. The coordinator is started
/// fresh from the scenario's knobs (plus, on the TCP transport, a
/// loopback [`WireServer`] in front of it) and fully shut down before
/// returning.
pub fn run_scenario(sc: &Scenario) -> crate::Result<CapacityReport> {
    if let Some(rs) = sc.router {
        return run_router_scenario(sc, rs);
    }
    let c = Arc::new(Coordinator::start(CoordinatorConfig {
        backend: sc.backend,
        queue_capacity: sc.queue_capacity,
        workers: sc.workers.max(1),
        m1_shards: sc.shards.max(1),
        default_ttl: sc.ttl,
        fault_plan: sc.fault_seed.map(FaultPlan::chaos),
        batcher: sc.batch_window.batcher_config(),
        ..Default::default()
    })?);
    let (server, ctx) = match sc.transport {
        TransportKind::InProcess => (None, TransportCtx::InProcess(c.clone())),
        TransportKind::Tcp => {
            let server = WireServer::bind("127.0.0.1:0", c.clone())?;
            // Clients stamp the scenario TTL on each request frame, so
            // the wire's deadline field gets real traffic (the server
            // default would apply regardless — same effective budget).
            let ctx = TransportCtx::Tcp { addr: server.local_addr(), ttl: sc.ttl };
            (Some(server), ctx)
        }
    };
    let factory = Arc::new(RequestFactory::new(sc.seed, sc.mix.clone()));
    let tally = Arc::new(Tally::default());

    // Queue-depth sampler: 1ms gauge of the admission queue.
    let sampler_stop = Arc::new(AtomicBool::new(false));
    let sampler = {
        let c = c.clone();
        let stop = sampler_stop.clone();
        thread::spawn(move || {
            let (mut sum, mut n, mut max) = (0u64, 0u64, 0u64);
            while !stop.load(Ordering::Relaxed) {
                let d = c.queue_depth() as u64;
                sum += d;
                n += 1;
                max = max.max(d);
                thread::sleep(Duration::from_millis(1));
            }
            (sum, n, max)
        })
    };

    let t0 = Instant::now();
    let mut log = match sc.profile {
        ArrivalProfile::ClosedLoop { clients } => {
            closed_loop(&ctx, &factory, &tally, clients.max(1), t0 + sc.duration)
        }
        _ => open_loop(&ctx, &factory, &tally, sc, t0),
    };
    let elapsed = t0.elapsed();

    sampler_stop.store(true, Ordering::Relaxed);
    let (depth_sum, depth_n, depth_max) = sampler.join().expect("sampler thread");
    let m = c.metrics();
    // Graceful drain first (stops accepting, flushes admitted replies),
    // which also releases the server's coordinator handle…
    drop(ctx);
    if let Some(server) = server {
        server.shutdown();
    }
    // …then all helper clones are joined; unwrap to run the draining
    // shutdown.
    if let Ok(c) = Arc::try_unwrap(c) {
        c.shutdown();
    }

    log.all.sort_unstable();
    log.interactive.sort_unstable();
    let elapsed_s = elapsed.as_secs_f64().max(1e-9);
    let completed = tally.completed.load(Ordering::Relaxed);
    let sum_us: u128 = log.all.iter().map(|d| d.as_micros()).sum();
    Ok(CapacityReport {
        scenario: sc.name.to_string(),
        profile: sc.profile.label(),
        transport: sc.transport.label(),
        backend: backend_name(sc.backend),
        batch_window: sc.batch_window.label(),
        workers: sc.workers.max(1),
        shards: sc.shards.max(1),
        seed: sc.seed,
        duration_s: elapsed_s,
        submitted: tally.submitted.load(Ordering::Relaxed),
        completed,
        shed: m.shed,
        rejected: m.rejected,
        deadline_missed: m.deadline_missed,
        closed: m.closed,
        failed: tally.failed.load(Ordering::Relaxed),
        fault_seed: sc.fault_seed,
        shard_crashes: m.shard_crashes,
        shard_restarts: m.shard_restarts,
        tiles_redispatched: m.tiles_redispatched,
        recovery_max_us: m.recovery_max_us,
        throughput_rps: completed as f64 / elapsed_s,
        points_per_s: tally.completed_points.load(Ordering::Relaxed) as f64 / elapsed_s,
        latency_mean_us: if log.all.is_empty() {
            0.0
        } else {
            sum_us as f64 / log.all.len() as f64
        },
        latency_p50_us: percentile_us(&log.all, 0.50),
        latency_p95_us: percentile_us(&log.all, 0.95),
        latency_p99_us: percentile_us(&log.all, 0.99),
        interactive_completed: tally.interactive_completed.load(Ordering::Relaxed),
        interactive_deadline_missed: tally.interactive_deadline_missed.load(Ordering::Relaxed),
        interactive_p99_us: percentile_us(&log.interactive, 0.99),
        bulk_completed: tally.bulk_completed.load(Ordering::Relaxed),
        bulk_shed: tally.bulk_shed.load(Ordering::Relaxed),
        queue_depth_mean: if depth_n == 0 { 0.0 } else { depth_sum as f64 / depth_n as f64 },
        queue_depth_max: depth_max,
        mean_batch_points: m.mean_batch_points(),
        sim_cycles_per_point: if m.job_points > 0 {
            m.simulated_cycles as f64 / m.job_points as f64
        } else {
            0.0
        },
        router_backends: 0,
        backend_deaths: 0,
        backend_rejoins: 0,
        redispatched_requests: 0,
        unavailable_rejected: 0,
        backends: Vec::new(),
    })
}

/// One backend of the router rack: a coordinator plus its wire listener.
fn start_backend(
    config: &CoordinatorConfig,
    addr: &str,
) -> crate::Result<(Arc<Coordinator>, WireServer)> {
    let c = Arc::new(Coordinator::start(config.clone())?);
    let server = WireServer::bind(addr, c.clone())?;
    Ok((c, server))
}

/// Run a router-fronted scenario: `rs.backends` coordinators behind one
/// front-end [`Router`], all traffic over the wire through the router,
/// and — when `rs.kill_seed` is armed — a seeded [`BackendKillPlan`]
/// that kills one backend process mid-run and restarts it on the same
/// address. The failover gate reads the resulting report: `failed == 0`
/// (every admitted request answered exactly once across the death),
/// `backend_deaths ≥ 1` and `backend_rejoins ≥ 1` (the breaker fired and
/// the revived backend healed back into the rotation).
fn run_router_scenario(sc: &Scenario, rs: RouterScenario) -> crate::Result<CapacityReport> {
    let base = CoordinatorConfig {
        backend: sc.backend,
        queue_capacity: sc.queue_capacity,
        workers: sc.workers.max(1),
        m1_shards: sc.shards.max(1),
        default_ttl: sc.ttl,
        fault_plan: sc.fault_seed.map(FaultPlan::chaos),
        batcher: sc.batch_window.batcher_config(),
        ..Default::default()
    };
    let n = rs.backends.max(1);
    let mut backends: Vec<Option<(Arc<Coordinator>, WireServer)>> = Vec::with_capacity(n);
    for _ in 0..n {
        backends.push(Some(start_backend(&base, "127.0.0.1:0")?));
    }
    let addrs: Vec<_> =
        backends.iter().map(|b| b.as_ref().expect("just racked").1.local_addr()).collect();
    let mut config = RouterConfig::new(addrs.clone());
    config.seed = sc.seed;
    let router = Arc::new(Router::bind("127.0.0.1:0", config)?);
    if !router.wait_healthy(n, Duration::from_secs(10)) {
        anyhow::bail!("router: {n} backends did not report healthy in time");
    }
    let ctx = TransportCtx::Tcp { addr: router.local_addr(), ttl: sc.ttl };
    let factory = Arc::new(RequestFactory::new(sc.seed, sc.mix.clone()));
    let tally = Arc::new(Tally::default());

    // Queue-depth sampler over the cluster gauge (summed most-recent
    // health reports), same 1ms cadence as the single-coordinator path.
    let sampler_stop = Arc::new(AtomicBool::new(false));
    let sampler = {
        let router = router.clone();
        let stop = sampler_stop.clone();
        thread::spawn(move || {
            let (mut sum, mut n, mut max) = (0u64, 0u64, 0u64);
            while !stop.load(Ordering::Relaxed) {
                let d = router.queue_depth() as u64;
                sum += d;
                n += 1;
                max = max.max(d);
                thread::sleep(Duration::from_millis(1));
            }
            (sum, n, max)
        })
    };

    let t0 = Instant::now();
    // The seeded mid-run kill: pull the victim pair out of the rack and
    // let the killer thread execute the schedule — abrupt kill, pause,
    // restart on the SAME address — while clients keep hammering the
    // router.
    let killer = rs.kill_seed.map(|seed| {
        let plan = BackendKillPlan::seeded(seed, n, sc.duration);
        let e = plan.events()[0];
        let victim = backends[e.backend].take().expect("victim backend is racked");
        let addr = addrs[e.backend].to_string();
        let base = base.clone();
        thread::spawn(move || {
            let (c, server) = victim;
            if let Some(wait) = (t0 + e.at).checked_duration_since(Instant::now()) {
                thread::sleep(wait);
            }
            // Abrupt process death: listener closed, sockets severed, no
            // draining — and the coordinator handle simply dropped, as a
            // dead process flushes nothing.
            server.kill();
            drop(c);
            thread::sleep(e.restart_after);
            // Rebind the same address (bounded retry while the old
            // socket finishes dying).
            let deadline = Instant::now() + Duration::from_secs(5);
            loop {
                match start_backend(&base, &addr) {
                    Ok(pair) => return (e.backend, Some(pair)),
                    Err(_) if Instant::now() < deadline => {
                        thread::sleep(Duration::from_millis(10));
                    }
                    Err(err) => {
                        eprintln!("failover: backend {} restart failed: {err}", e.backend);
                        return (e.backend, None);
                    }
                }
            }
        })
    });

    let mut log = match sc.profile {
        ArrivalProfile::ClosedLoop { clients } => {
            closed_loop(&ctx, &factory, &tally, clients.max(1), t0 + sc.duration)
        }
        _ => open_loop(&ctx, &factory, &tally, sc, t0),
    };
    let elapsed = t0.elapsed();

    sampler_stop.store(true, Ordering::Relaxed);
    let (depth_sum, depth_n, depth_max) = sampler.join().expect("sampler thread");
    if let Some(killer) = killer {
        let (index, pair) = killer.join().expect("killer thread");
        backends[index] = pair;
    }
    // Let one more health interval elapse so the revived backend's final
    // report lands before the snapshot.
    thread::sleep(Duration::from_millis(50));
    let cluster = router.metrics();
    drop(ctx);
    // Sampler and killer are joined, so the router handle is unique
    // again; `Drop` covers the unexpected case.
    if let Ok(router) = Arc::try_unwrap(router) {
        router.shutdown();
    }
    for (c, server) in backends.into_iter().flatten() {
        server.shutdown();
        if let Ok(c) = Arc::try_unwrap(c) {
            c.shutdown();
        }
    }

    log.all.sort_unstable();
    log.interactive.sort_unstable();
    let elapsed_s = elapsed.as_secs_f64().max(1e-9);
    let completed = tally.completed.load(Ordering::Relaxed);
    let sum_us: u128 = log.all.iter().map(|d| d.as_micros()).sum();
    let h = &cluster.health;
    Ok(CapacityReport {
        scenario: sc.name.to_string(),
        profile: sc.profile.label(),
        transport: sc.transport.label(),
        backend: backend_name(sc.backend),
        batch_window: sc.batch_window.label(),
        workers: sc.workers.max(1),
        shards: sc.shards.max(1),
        seed: sc.seed,
        duration_s: elapsed_s,
        submitted: tally.submitted.load(Ordering::Relaxed),
        completed,
        shed: h.shed,
        rejected: h.rejected,
        deadline_missed: h.deadline_missed,
        closed: h.closed,
        failed: tally.failed.load(Ordering::Relaxed),
        fault_seed: sc.fault_seed,
        shard_crashes: h.shard_crashes,
        shard_restarts: h.shard_restarts,
        tiles_redispatched: h.tiles_redispatched,
        recovery_max_us: h.recovery_max_us,
        throughput_rps: completed as f64 / elapsed_s,
        points_per_s: tally.completed_points.load(Ordering::Relaxed) as f64 / elapsed_s,
        latency_mean_us: if log.all.is_empty() {
            0.0
        } else {
            sum_us as f64 / log.all.len() as f64
        },
        latency_p50_us: percentile_us(&log.all, 0.50),
        latency_p95_us: percentile_us(&log.all, 0.95),
        latency_p99_us: percentile_us(&log.all, 0.99),
        interactive_completed: tally.interactive_completed.load(Ordering::Relaxed),
        interactive_deadline_missed: tally.interactive_deadline_missed.load(Ordering::Relaxed),
        interactive_p99_us: percentile_us(&log.interactive, 0.99),
        bulk_completed: tally.bulk_completed.load(Ordering::Relaxed),
        bulk_shed: tally.bulk_shed.load(Ordering::Relaxed),
        queue_depth_mean: if depth_n == 0 { 0.0 } else { depth_sum as f64 / depth_n as f64 },
        queue_depth_max: depth_max,
        // Health frames carry admission/queue counters, not batch
        // composition — a router report leaves the batching columns zero.
        mean_batch_points: 0.0,
        sim_cycles_per_point: 0.0,
        router_backends: n,
        backend_deaths: cluster.backend_deaths,
        backend_rejoins: cluster.backend_rejoins,
        redispatched_requests: cluster.redispatched,
        unavailable_rejected: cluster.unavailable_rejected,
        backends: cluster.backends,
    })
}

/// N clients, each submit → await → repeat until `t_end`. Client `i`
/// draws stream `i`, so the per-client request sequence is seed-pinned.
/// Each client owns its own connection (an `Arc` clone in-process, a
/// dedicated loopback socket on TCP — one connection per user, as real
/// serving would see).
fn closed_loop(
    ctx: &TransportCtx,
    factory: &Arc<RequestFactory>,
    tally: &Arc<Tally>,
    clients: usize,
    t_end: Instant,
) -> LatencyLog {
    let handles: Vec<_> = (0..clients)
        .map(|client| {
            let conn = ctx.connect();
            let factory = factory.clone();
            let tally = tally.clone();
            thread::spawn(move || {
                let conn = match conn {
                    Ok(conn) => conn,
                    Err(e) => {
                        eprintln!("loadgen client {client}: connect failed: {e}");
                        tally.failed.fetch_add(1, Ordering::Relaxed);
                        return LatencyLog::default();
                    }
                };
                let mut log = LatencyLog::default();
                let mut index = 0u64;
                while Instant::now() < t_end {
                    let gr = factory.request(client as u64, index);
                    let priority = gr.priority;
                    index += 1;
                    tally.submitted.fetch_add(1, Ordering::Relaxed);
                    let t = Instant::now();
                    match conn.submit(gr.xs, gr.ys, gr.transforms, false, priority) {
                        Submitted::Handle(rx) => match rx.recv() {
                            Ok(outcome) => {
                                tally.record_lane_outcome(priority, &outcome);
                                if let Ok(resp) = outcome {
                                    log.push(priority, t.elapsed());
                                    tally.completed.fetch_add(1, Ordering::Relaxed);
                                    tally
                                        .completed_points
                                        .fetch_add(resp.xs.len() as u64, Ordering::Relaxed);
                                }
                                // Shed — the coordinator's metrics carry
                                // the reason; the client just moves on.
                            }
                            Err(_) => {
                                tally.failed.fetch_add(1, Ordering::Relaxed);
                            }
                        },
                        Submitted::Rejected => {}
                        Submitted::Down => break, // coordinator shut down
                    }
                }
                log
            })
        })
        .collect();
    let mut merged = LatencyLog::default();
    for h in handles {
        merged.merge(h.join().expect("client thread"));
    }
    merged
}

/// Deterministic-timetable submitter plus a polling collector. Latency is
/// submit → response observation (poll granularity ≈ 100µs). One
/// connection carries the whole timetable; over TCP the reply demux
/// hands back the same per-request receivers the collector already
/// polls.
fn open_loop(
    ctx: &TransportCtx,
    factory: &Arc<RequestFactory>,
    tally: &Arc<Tally>,
    sc: &Scenario,
    t0: Instant,
) -> LatencyLog {
    let conn = match ctx.connect() {
        Ok(conn) => conn,
        Err(e) => {
            eprintln!("loadgen open-loop: connect failed: {e}");
            tally.failed.fetch_add(1, Ordering::Relaxed);
            return LatencyLog::default();
        }
    };
    let outstanding: Outstanding = Arc::new(Mutex::new(Vec::new()));
    let done = Arc::new(AtomicBool::new(false));
    let collector = {
        let outstanding = outstanding.clone();
        let done = done.clone();
        let tally = tally.clone();
        thread::spawn(move || collect(&outstanding, &done, &tally))
    };

    let mut arrivals = Arrivals::new(sc.profile, sc.duration);
    let mut index = 0u64;
    while let Some(offset) = arrivals.next_arrival() {
        let due = t0 + offset;
        if let Some(wait) = due.checked_duration_since(Instant::now()) {
            if !wait.is_zero() {
                thread::sleep(wait);
            }
        }
        let gr = factory.request(0, index);
        let priority = gr.priority;
        index += 1;
        tally.submitted.fetch_add(1, Ordering::Relaxed);
        let submitted_at = Instant::now();
        // With `fast_reject`, overload is shed at the door
        // (metrics.rejected counts it — in-process as a returned
        // rejection, over the wire as a rejection frame on the handle)
        // and the timetable never blocks.
        match conn.submit(gr.xs, gr.ys, gr.transforms, sc.fast_reject, priority) {
            Submitted::Handle(rx) => {
                outstanding.lock().unwrap().push((submitted_at, priority, rx));
            }
            Submitted::Rejected | Submitted::Down => {}
        }
    }
    done.store(true, Ordering::Relaxed);
    collector.join().expect("collector thread")
}

fn collect(outstanding: &Outstanding, done: &AtomicBool, tally: &Tally) -> LatencyLog {
    let mut local: Vec<(Instant, Priority, mpsc::Receiver<ServeResult>)> = Vec::new();
    let mut log = LatencyLog::default();
    loop {
        {
            let mut g = outstanding.lock().unwrap();
            local.append(&mut g);
        }
        let mut i = 0;
        while i < local.len() {
            let (submitted_at, priority) = (local[i].0, local[i].1);
            match local[i].2.try_recv() {
                Ok(outcome) => {
                    tally.record_lane_outcome(priority, &outcome);
                    if let Ok(resp) = outcome {
                        log.push(priority, submitted_at.elapsed());
                        tally.completed.fetch_add(1, Ordering::Relaxed);
                        tally.completed_points.fetch_add(resp.xs.len() as u64, Ordering::Relaxed);
                    }
                    // Shed outcomes: server metrics count the reason.
                    local.swap_remove(i);
                }
                Err(mpsc::TryRecvError::Empty) => i += 1,
                Err(mpsc::TryRecvError::Disconnected) => {
                    tally.failed.fetch_add(1, Ordering::Relaxed);
                    local.swap_remove(i);
                }
            }
        }
        if local.is_empty() && done.load(Ordering::Relaxed) {
            let drained = outstanding.lock().unwrap().is_empty();
            if drained {
                break;
            }
        }
        thread::sleep(Duration::from_micros(100));
    }
    log
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loadgen::scenario::{BatchWindow, WorkloadMix};

    #[test]
    fn open_loop_arrivals_are_deterministic_and_monotonic() {
        let collect_offsets = |profile| {
            let mut a = Arrivals::new(profile, Duration::from_secs(1));
            let mut v = Vec::new();
            while let Some(o) = a.next_arrival() {
                v.push(o);
            }
            v
        };
        let steady = collect_offsets(ArrivalProfile::OpenLoop { rate: 100 });
        assert_eq!(steady.len(), 100);
        assert_eq!(steady[0], Duration::ZERO);
        assert_eq!(steady[1], Duration::from_millis(10));
        assert!(steady.windows(2).all(|w| w[0] <= w[1]));

        let burst = collect_offsets(ArrivalProfile::Burst {
            burst: 10,
            period: Duration::from_millis(100),
        });
        assert_eq!(burst.len(), 100, "10 bursts of 10 fit in 1s");
        assert_eq!(burst[9], Duration::ZERO, "whole burst due at once");
        assert_eq!(burst[10], Duration::from_millis(100));

        let ramp = collect_offsets(ArrivalProfile::Ramp { from: 10, to: 1000 });
        assert!(ramp.len() > 100, "mean rate ≈ 505rps over 1s, got {}", ramp.len());
        assert!(ramp.windows(2).all(|w| w[0] <= w[1]));
        // Arrivals tighten as the rate ramps.
        let head = ramp[1] - ramp[0];
        let tail = ramp[ramp.len() - 1] - ramp[ramp.len() - 2];
        assert!(tail < head, "ramp spacing must shrink: {head:?} → {tail:?}");
        // And the timetable is a pure function: a second pass agrees.
        assert_eq!(ramp, collect_offsets(ArrivalProfile::Ramp { from: 10, to: 1000 }));
    }

    #[test]
    fn tiny_closed_loop_native_run_completes_cleanly() {
        let sc = Scenario {
            name: "test-closed",
            summary: "unit",
            profile: ArrivalProfile::ClosedLoop { clients: 2 },
            duration: Duration::from_millis(200),
            mix: WorkloadMix::standard(),
            seed: 5,
            backend: BackendChoice::Native,
            workers: 1,
            shards: 1,
            queue_capacity: 64,
            ttl: None,
            fast_reject: false,
            fault_seed: None,
            batch_window: BatchWindow::Default,
            transport: TransportKind::InProcess,
            router: None,
        };
        let r = run_scenario(&sc).unwrap();
        assert!(r.completed > 0, "closed loop must complete requests");
        assert_eq!(r.failed, 0, "no reply channel may die silently");
        assert!(r.submitted >= r.completed);
        assert!(r.throughput_rps > 0.0);
        assert!(r.latency_p99_us >= r.latency_p50_us);
        assert_eq!(r.backend, "native");
        assert_eq!(r.transport, "in-process");
        assert!(r.to_json().contains("\"scenario\": \"test-closed\""));
    }

    #[test]
    fn tiny_closed_loop_run_over_loopback_tcp_completes_cleanly() {
        let sc = Scenario {
            name: "test-tcp",
            summary: "unit",
            profile: ArrivalProfile::ClosedLoop { clients: 2 },
            duration: Duration::from_millis(200),
            mix: WorkloadMix::standard(),
            seed: 5,
            backend: BackendChoice::Native,
            workers: 1,
            shards: 1,
            queue_capacity: 64,
            ttl: None,
            fast_reject: false,
            fault_seed: None,
            batch_window: BatchWindow::Default,
            transport: TransportKind::Tcp,
            router: None,
        };
        let r = run_scenario(&sc).unwrap();
        assert!(r.completed > 0, "wire clients must complete requests");
        assert_eq!(r.failed, 0, "no reply may be lost crossing the wire");
        assert_eq!(r.transport, "tcp");
        assert!(r.to_json().contains("\"transport\": \"tcp\""));
    }

    #[test]
    fn tiny_open_loop_run_with_fast_reject_stays_consistent() {
        let sc = Scenario {
            name: "test-open",
            summary: "unit",
            profile: ArrivalProfile::OpenLoop { rate: 400 },
            duration: Duration::from_millis(200),
            mix: WorkloadMix::standard(),
            seed: 9,
            backend: BackendChoice::Native,
            workers: 1,
            shards: 1,
            queue_capacity: 4,
            ttl: Some(Duration::from_millis(100)),
            fast_reject: true,
            fault_seed: None,
            batch_window: BatchWindow::Default,
            transport: TransportKind::InProcess,
            router: None,
        };
        let r = run_scenario(&sc).unwrap();
        assert_eq!(r.failed, 0);
        // Conservation: every offered request is accounted for exactly
        // once across completed / shed / rejected / still-in-flight-at-
        // shutdown (drained before join, so in-flight is zero).
        assert!(
            r.completed + r.shed + r.rejected <= r.submitted,
            "completed={} shed={} rejected={} submitted={}",
            r.completed,
            r.shed,
            r.rejected,
            r.submitted
        );
        assert!(r.completed > 0);
    }

    #[test]
    fn tiny_chaos_run_loses_no_replies_under_injected_faults() {
        let sc = Scenario {
            name: "test-chaos",
            summary: "unit",
            profile: ArrivalProfile::ClosedLoop { clients: 2 },
            duration: Duration::from_millis(300),
            mix: WorkloadMix::standard(),
            seed: 11,
            backend: BackendChoice::M1Sim,
            workers: 1,
            shards: 2,
            queue_capacity: 64,
            ttl: None,
            fast_reject: false,
            fault_seed: Some(7),
            batch_window: BatchWindow::Default,
            transport: TransportKind::InProcess,
            router: None,
        };
        let r = run_scenario(&sc).unwrap();
        // The whole point of supervision: injected crashes/deaths/dropped
        // replies must never surface as a dead reply channel.
        assert_eq!(r.failed, 0, "supervision may not lose replies");
        assert!(r.completed > 0, "degraded service still serves");
        assert_eq!(r.fault_seed, Some(7));
        let j = r.to_json();
        assert!(j.contains("\"fault_seed\": 7"));
        assert!(j.contains("\"shard_crashes\""));
    }

    #[test]
    fn tiny_router_run_without_kills_balances_two_backends() {
        let sc = Scenario {
            name: "test-router",
            summary: "unit",
            profile: ArrivalProfile::ClosedLoop { clients: 2 },
            duration: Duration::from_millis(300),
            mix: WorkloadMix::standard(),
            seed: 5,
            backend: BackendChoice::Native,
            workers: 1,
            shards: 1,
            queue_capacity: 64,
            ttl: None,
            fast_reject: false,
            fault_seed: None,
            batch_window: BatchWindow::Default,
            transport: TransportKind::Tcp,
            router: Some(RouterScenario { backends: 2, kill_seed: None }),
        };
        let r = run_scenario(&sc).unwrap();
        assert!(r.completed > 0, "routed clients must complete requests");
        assert_eq!(r.failed, 0, "no reply may be lost crossing the router");
        assert_eq!(r.router_backends, 2);
        assert_eq!(r.backends.len(), 2, "one report row per backend");
        assert_eq!((r.backend_deaths, r.backend_rejoins), (0, 0), "nobody died");
        let proxied: u64 = r.backends.iter().map(|b| b.proxied).sum();
        assert!(proxied >= r.completed, "every completed request was proxied");
        assert!(
            r.backends.iter().all(|b| b.proxied > 0),
            "least-depth/round-robin must exercise both backends: {:?}",
            r.backends
        );
        assert!(r.to_json().contains("\"router_backends\": 2"));
    }
}
