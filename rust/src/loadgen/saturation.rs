//! Saturation-surface sweep: the `ramp` scenario driven across a
//! `workers × shards × batch-window` grid, one [`CapacityReport`] per
//! cell, folded into `BENCH_saturation.json` (atomic temp+rename, the
//! same contract as the other BENCH files).
//!
//! Each cell locates the knee of its configuration: the ramp walks the
//! offered rate through saturation, so the cell's completed-request
//! throughput *is* the knee capacity, its p99 is the latency at the
//! knee, and `(shed + rejected) / submitted` is the shed fraction past
//! it. Cell *contents* are seed-pinned (the ramp's request stream is a
//! pure function of the seed); cell *execution order* is a seeded
//! Fisher–Yates shuffle of the grid, so thermal/cache drift is not
//! systematically attributed to one corner of the surface, yet the
//! order is reproducible run-to-run.

use std::time::Duration;

use crate::benchkit::write_atomic;
use crate::coordinator::faults::splitmix64;

use super::report::CapacityReport;
use super::runner::run_scenario;
use super::scenario::{by_name, BatchWindow};

/// The grid a sweep covers, plus per-cell runtime knobs.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Worker-thread counts to sweep.
    pub workers: Vec<usize>,
    /// M1 shard counts to sweep (each ≥ 2 — the scenario contract).
    pub shards: Vec<usize>,
    /// Static batch windows to sweep.
    pub windows: Vec<Duration>,
    /// Wall-clock budget per cell (the ramp is compressed into it).
    pub cell_duration: Duration,
    /// Seed for both the request streams and the cell shuffle.
    pub seed: u64,
}

impl Default for SweepConfig {
    /// The stock 2×2×2 surface: 8 cells bracketing the serving knobs,
    /// with the two windows at the adaptive controller's band edges.
    fn default() -> SweepConfig {
        SweepConfig {
            workers: vec![1, 2],
            shards: vec![2, 4],
            windows: vec![Duration::from_micros(500), Duration::from_millis(2)],
            cell_duration: Duration::from_secs(2),
            seed: 20190412,
        }
    }
}

/// One measured grid cell.
#[derive(Debug, Clone)]
pub struct SaturationCell {
    pub workers: usize,
    pub shards: usize,
    pub window: Duration,
    /// Sustained completion rate across the ramp — the knee capacity.
    pub knee_rps: f64,
    /// Client-observed p99 latency at the knee, µs.
    pub p99_at_knee_us: u64,
    /// `(shed + rejected) / submitted` — load turned away past the knee.
    pub shed_fraction: f64,
    pub submitted: u64,
    pub completed: u64,
    /// Reply channels that died silently — CI asserts 0 in every cell.
    pub failed: u64,
}

impl SaturationCell {
    fn from_report(workers: usize, shards: usize, window: Duration, r: &CapacityReport) -> Self {
        SaturationCell {
            workers,
            shards,
            window,
            knee_rps: r.throughput_rps,
            p99_at_knee_us: r.latency_p99_us,
            shed_fraction: if r.submitted == 0 {
                0.0
            } else {
                (r.shed + r.rejected) as f64 / r.submitted as f64
            },
            submitted: r.submitted,
            completed: r.completed,
            failed: r.failed,
        }
    }

    fn to_json(&self) -> String {
        format!(
            "{{\"workers\": {}, \"shards\": {}, \"window_us\": {}, \
             \"knee_rps\": {:.3}, \"p99_at_knee_us\": {}, \"shed_fraction\": {:.4}, \
             \"submitted\": {}, \"completed\": {}, \"failed\": {}}}",
            self.workers,
            self.shards,
            self.window.as_micros(),
            if self.knee_rps.is_finite() { self.knee_rps } else { 0.0 },
            self.p99_at_knee_us,
            if self.shed_fraction.is_finite() { self.shed_fraction } else { 0.0 },
            self.submitted,
            self.completed,
            self.failed,
        )
    }
}

/// The full grid in canonical (workers-major) order.
fn grid(config: &SweepConfig) -> Vec<(usize, usize, Duration)> {
    let mut cells = Vec::new();
    for &w in &config.workers {
        for &s in &config.shards {
            for &d in &config.windows {
                cells.push((w, s, d));
            }
        }
    }
    cells
}

/// Seeded Fisher–Yates: the execution order is reproducible for a fixed
/// seed yet decorrelated from the canonical grid order.
fn shuffled(config: &SweepConfig) -> Vec<(usize, usize, Duration)> {
    let mut cells = grid(config);
    let mut state = config.seed ^ 0x5A71_0C3B_9E24_D681;
    for i in (1..cells.len()).rev() {
        let j = (splitmix64(&mut state) % (i as u64 + 1)) as usize;
        cells.swap(i, j);
    }
    cells
}

/// Run the sweep: every cell is the `ramp` scenario re-knobbed to the
/// cell's corner of the grid. Cells are returned in canonical grid
/// order regardless of execution order. `progress` gets one line per
/// cell as it lands (pass `|_| {}` to silence).
pub fn run_sweep(
    config: &SweepConfig,
    mut progress: impl FnMut(&str),
) -> crate::Result<Vec<SaturationCell>> {
    anyhow::ensure!(
        !config.workers.is_empty() && !config.shards.is_empty() && !config.windows.is_empty(),
        "sweep grid must be non-empty on every axis"
    );
    let base = by_name("ramp").expect("the ramp scenario is registered");
    let order = shuffled(config);
    let total = order.len();
    let mut measured = Vec::with_capacity(total);
    for (i, &(workers, shards, window)) in order.iter().enumerate() {
        let sc = crate::loadgen::Scenario {
            workers,
            shards,
            batch_window: BatchWindow::Fixed(window),
            duration: config.cell_duration,
            seed: config.seed,
            ..base.clone()
        };
        let r = run_scenario(&sc)?;
        let cell = SaturationCell::from_report(workers, shards, window, &r);
        progress(&format!(
            "[{}/{}] workers={} shards={} window={}us: knee={:.0} req/s p99={}us shed={:.1}%",
            i + 1,
            total,
            workers,
            shards,
            window.as_micros(),
            cell.knee_rps,
            cell.p99_at_knee_us,
            cell.shed_fraction * 100.0,
        ));
        measured.push(cell);
    }
    // Canonical order back out, so the JSON diff cleanly run-to-run.
    let canonical = grid(config);
    measured.sort_by_key(|c| {
        canonical
            .iter()
            .position(|&(w, s, d)| (w, s, d) == (c.workers, c.shards, c.window))
            .unwrap_or(usize::MAX)
    });
    Ok(measured)
}

/// Default output path: `BENCH_saturation.json`, overridable with the
/// `BENCH_SATURATION_JSON` env var (mirrors `BENCH_COORD_JSON`).
pub fn default_path() -> String {
    std::env::var("BENCH_SATURATION_JSON").unwrap_or_else(|_| "BENCH_saturation.json".to_string())
}

/// Write the surface as `{"seed": …, "cell_seconds": …, "cells": […]}`,
/// atomically.
pub fn write_cells(
    config: &SweepConfig,
    cells: &[SaturationCell],
    path: &str,
) -> std::io::Result<()> {
    let mut out = format!(
        "{{\"seed\": {}, \"cell_seconds\": {:.3}, \"cells\": [\n",
        config.seed,
        config.cell_duration.as_secs_f64(),
    );
    for (i, c) in cells.iter().enumerate() {
        out.push_str("  ");
        out.push_str(&c.to_json());
        out.push_str(if i + 1 < cells.len() { ",\n" } else { "\n" });
    }
    out.push_str("]}\n");
    write_atomic(path, &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_covers_every_combination_exactly_once() {
        let config = SweepConfig::default();
        let g = grid(&config);
        assert_eq!(g.len(), 8, "stock surface is 2x2x2");
        for &w in &config.workers {
            for &s in &config.shards {
                for &d in &config.windows {
                    assert_eq!(g.iter().filter(|&&c| c == (w, s, d)).count(), 1);
                }
            }
        }
    }

    #[test]
    fn cell_order_is_seeded_shuffled_and_reproducible() {
        let config = SweepConfig::default();
        let a = shuffled(&config);
        let b = shuffled(&config);
        assert_eq!(a, b, "same seed, same execution order");
        let other = SweepConfig { seed: config.seed + 1, ..config.clone() };
        // Same cells either way…
        let mut sa = a.clone();
        let mut so = shuffled(&other);
        sa.sort();
        so.sort();
        assert_eq!(sa, so);
        // …and an 8-cell grid has 8! orders, so distinct seeds almost
        // surely disagree; these two specific seeds must (pinned).
        assert_ne!(a, shuffled(&other), "distinct seeds reorder the sweep");
    }

    #[test]
    fn cells_serialize_with_every_column_and_finite_numbers() {
        let cell = SaturationCell {
            workers: 2,
            shards: 4,
            window: Duration::from_micros(500),
            knee_rps: 1234.5,
            p99_at_knee_us: 900,
            shed_fraction: 0.25,
            submitted: 4000,
            completed: 3000,
            failed: 0,
        };
        let j = cell.to_json();
        for key in [
            "workers", "shards", "window_us", "knee_rps", "p99_at_knee_us",
            "shed_fraction", "submitted", "completed", "failed",
        ] {
            assert!(j.contains(&format!("\"{key}\":")), "missing {key}: {j}");
        }
        assert!(j.contains("\"window_us\": 500"));
        let nan = SaturationCell { knee_rps: f64::NAN, shed_fraction: f64::INFINITY, ..cell };
        let j = nan.to_json();
        assert!(!j.contains("NaN") && !j.contains("inf"));
    }

    #[test]
    fn tiny_sweep_populates_every_cell() {
        // A 1×1×1 "surface" keeps this a unit test, not a benchmark.
        let config = SweepConfig {
            workers: vec![1],
            shards: vec![2],
            windows: vec![Duration::from_millis(1)],
            cell_duration: Duration::from_millis(300),
            seed: 7,
        };
        let cells = run_sweep(&config, |_| {}).unwrap();
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert!(c.knee_rps > 0.0, "a live cell measures a knee");
        assert_eq!(c.failed, 0, "no reply may be lost in a sweep cell");
        assert!(c.submitted >= c.completed);

        let dir = std::env::temp_dir().join("morpho_saturation_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_saturation.json");
        write_cells(&config, &cells, path.to_str().unwrap()).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.starts_with("{\"seed\": 7"));
        assert_eq!(s.matches("\"knee_rps\"").count(), 1);
        assert!(s.ends_with("]}\n"));
    }
}
