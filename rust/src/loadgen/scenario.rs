//! Scenario definitions: named, self-contained descriptions of a load
//! test — arrival profile, workload mix, seed and coordinator knobs.

use std::time::Duration;

use crate::coordinator::{AdaptiveWindowConfig, BackendChoice, BatcherConfig};

use super::transport::TransportKind;

/// How requests arrive at the coordinator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProfile {
    /// N clients, each submit → wait → repeat (self-limiting load).
    ClosedLoop { clients: usize },
    /// Deterministic fixed-rate arrivals (requests/second), independent
    /// of completions.
    OpenLoop { rate: u64 },
    /// Every `period`, `burst` requests arrive back-to-back.
    Burst { burst: usize, period: Duration },
    /// Open-loop rate swept linearly from `from` to `to` req/s across the
    /// scenario duration — walks the service across its saturation knee.
    Ramp { from: u64, to: u64 },
}

impl ArrivalProfile {
    /// Human/JSON label, e.g. `closed-loop(8)` or `ramp(200..6000rps)`.
    pub fn label(&self) -> String {
        match *self {
            ArrivalProfile::ClosedLoop { clients } => format!("closed-loop({clients})"),
            ArrivalProfile::OpenLoop { rate } => format!("open-loop({rate}rps)"),
            ArrivalProfile::Burst { burst, period } => {
                format!("burst({burst}/{}ms)", period.as_millis())
            }
            ArrivalProfile::Ramp { from, to } => format!("ramp({from}..{to}rps)"),
        }
    }
}

/// The transform vocabulary of the generated workload. Values are drawn
/// from small discrete sets so the batcher has merge opportunities (many
/// clients asking for *identical* transforms, as an animation frame
/// does), and every choice stays inside the M1 backend's Q6 fixed-point
/// envelope (|matrix entry| < 2, integer translations within ±127).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransformKind {
    /// Vector-vector op (the paper's translation workload).
    Translate,
    /// Vector-scalar op (the paper's scaling workload).
    Scale,
    /// Matrix op (the paper's rotation workload).
    Rotate,
    /// rotate ∘ scale ∘ translate — the composite per-frame transform of
    /// the animation pipeline, standing in for the companion paper's
    /// mixed 2D/3D scene workloads (a projected 3-D frame reaches the
    /// coordinator as exactly this composite affine).
    Composite,
}

/// Weighted workload mix: request point counts and transform kinds, plus
/// the (optional) bulk lane blended into the stream.
#[derive(Debug, Clone)]
pub struct WorkloadMix {
    /// `(weight, points)` — the paper's tile-interesting sizes are
    /// {8, 64, 500, 2117, 4096}.
    pub sizes: Vec<(u32, usize)>,
    /// `(weight, kind)`.
    pub transforms: Vec<(u32, TransformKind)>,
    /// Fraction of requests tagged [`crate::coordinator::Priority::Bulk`]
    /// (drawn per request from the seeded stream). `0.0` — the
    /// single-lane mixes — generates *exactly* the pre-lane request
    /// streams: no extra random draw is burned, so existing scenarios
    /// stay bit-identical.
    pub bulk_share: f32,
    /// `(weight, points)` for bulk-lane requests (ignored when
    /// `bulk_share == 0.0`). Bulk traffic is the big-batch tail of the
    /// size ladder.
    pub bulk_sizes: Vec<(u32, usize)>,
}

impl WorkloadMix {
    /// Small/medium requests, all three primitive transforms.
    pub fn standard() -> WorkloadMix {
        WorkloadMix {
            sizes: vec![(3, 8), (4, 64), (2, 500)],
            transforms: vec![
                (2, TransformKind::Translate),
                (1, TransformKind::Scale),
                (1, TransformKind::Rotate),
            ],
            bulk_share: 0.0,
            bulk_sizes: vec![],
        }
    }

    /// The full size ladder plus composite transforms — the mixed
    /// "many scenes, many shapes" serving workload.
    pub fn mixed() -> WorkloadMix {
        WorkloadMix {
            sizes: vec![(2, 8), (3, 64), (2, 500), (2, 2117), (1, 4096)],
            transforms: vec![
                (2, TransformKind::Translate),
                (1, TransformKind::Scale),
                (1, TransformKind::Rotate),
                (2, TransformKind::Composite),
            ],
            bulk_share: 0.0,
            bulk_sizes: vec![],
        }
    }

    /// Two lanes in one stream: small interactive requests (which must
    /// hold their TTLs) blended half-and-half with big-batch bulk
    /// requests (which may be shed under pressure).
    pub fn two_lane() -> WorkloadMix {
        WorkloadMix {
            sizes: vec![(3, 8), (4, 64), (2, 500)],
            transforms: vec![
                (2, TransformKind::Translate),
                (1, TransformKind::Scale),
                (1, TransformKind::Rotate),
            ],
            bulk_share: 0.5,
            bulk_sizes: vec![(1, 1024), (2, 2048), (1, 4096)],
        }
    }
}

/// The batch-window policy of a scenario's coordinator — the A/B axis of
/// the adaptive-batching experiment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchWindow {
    /// The coordinator's stock static window
    /// ([`BatcherConfig::default`], 2ms).
    Default,
    /// A pinned static window.
    Fixed(Duration),
    /// The [`crate::coordinator::AdaptiveWindow`] controller with its
    /// default bounds: the window roams
    /// [`AdaptiveWindowConfig::default`]'s `[min_wait, max_wait]` band,
    /// steered by the queue-depth gauge.
    Adaptive,
}

impl BatchWindow {
    /// Human/JSON label, e.g. `fixed(100us)` or `adaptive`.
    pub fn label(&self) -> String {
        match *self {
            BatchWindow::Default => "default".to_string(),
            BatchWindow::Fixed(d) => format!("fixed({}us)", d.as_micros()),
            BatchWindow::Adaptive => "adaptive".to_string(),
        }
    }

    /// The coordinator batcher config this policy stands for.
    pub fn batcher_config(&self) -> BatcherConfig {
        match *self {
            BatchWindow::Default => BatcherConfig::default(),
            BatchWindow::Fixed(d) => BatcherConfig { max_wait: d, ..BatcherConfig::default() },
            BatchWindow::Adaptive => BatcherConfig {
                adaptive: Some(AdaptiveWindowConfig::default()),
                ..BatcherConfig::default()
            },
        }
    }
}

/// The static extremes the adaptive window is A/B'd against: exactly the
/// band the controller roams, so "adaptive ≥ both extremes" means the
/// controller finds the right operating point without being told.
pub fn window_extremes() -> (Duration, Duration) {
    let cfg = AdaptiveWindowConfig::default();
    (cfg.min_wait, cfg.max_wait)
}

/// Scale-out topology for a scenario: run `backends` independent
/// coordinators behind the front-end [`crate::coordinator::Router`]
/// instead of one coordinator, with clients dialing the router. Implies
/// the wire transport — the router *is* a wire listener.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouterScenario {
    /// Backend coordinator count (each gets the scenario's
    /// workers/shards/queue knobs).
    pub backends: usize,
    /// `Some(seed)` arms the failover harness: a seeded
    /// [`crate::coordinator::BackendKillPlan`] kills one backend mid-run
    /// (abruptly, in-flight requests and all) and restarts it on the
    /// same address — measuring degraded capacity, breaker behaviour and
    /// healing rather than the fault-free ceiling.
    pub kill_seed: Option<u64>,
}

/// A complete, reproducible load-test description.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: &'static str,
    pub summary: &'static str,
    pub profile: ArrivalProfile,
    pub duration: Duration,
    pub mix: WorkloadMix,
    /// Seeds the request factory: same seed ⇒ same per-stream request
    /// sequences (see the module docs' determinism contract).
    pub seed: u64,
    pub backend: BackendChoice,
    pub workers: usize,
    /// Tile-pool shards per M1Sim worker.
    pub shards: usize,
    pub queue_capacity: usize,
    /// Default request TTL (deadline shedding) — `None` disables.
    pub ttl: Option<Duration>,
    /// Batch-window policy of the coordinator under test (static default,
    /// pinned static, or adaptive).
    pub batch_window: BatchWindow,
    /// Open-loop admission: `try_submit` fast-reject instead of blocking
    /// the submitter on a full queue.
    pub fast_reject: bool,
    /// Deterministic fault injection: `Some(seed)` arms
    /// [`crate::coordinator::FaultPlan::chaos`] inside the coordinator's
    /// M1 tile pools, so the scenario measures *degraded* capacity
    /// (supervised crashes, shard deaths, dropped replies) rather than
    /// the fault-free ceiling. `None` for every ordinary scenario.
    pub fault_seed: Option<u64>,
    /// How traffic reaches the coordinator: library calls, or the wire
    /// protocol over a loopback listener the runner stands up. Same
    /// seeded streams either way — the report rows are comparable.
    pub transport: TransportKind,
    /// `Some` runs N coordinators behind the front-end router (scale-out
    /// topology, wire transport only); `None` is the single-coordinator
    /// layout of every pre-router scenario.
    pub router: Option<RouterScenario>,
}

impl Scenario {
    /// The same scenario driven over a different transport.
    pub fn with_transport(mut self, transport: TransportKind) -> Scenario {
        self.transport = transport;
        self
    }
}

fn base(name: &'static str, summary: &'static str, profile: ArrivalProfile) -> Scenario {
    Scenario {
        name,
        summary,
        profile,
        duration: Duration::from_secs(5),
        mix: WorkloadMix::standard(),
        seed: 42,
        backend: BackendChoice::M1Sim,
        workers: 2,
        shards: 2,
        queue_capacity: 1024,
        ttl: None,
        batch_window: BatchWindow::Default,
        fast_reject: false,
        fault_seed: None,
        transport: TransportKind::InProcess,
        router: None,
    }
}

/// The `mixed` scenario body shared by the plain row and the three
/// batch-window A/B rows (identical in everything but the window policy,
/// so the A/B comparison is apples-to-apples).
fn mixed_base(name: &'static str, summary: &'static str) -> Scenario {
    Scenario {
        duration: Duration::from_secs(4),
        mix: WorkloadMix::mixed(),
        shards: 4,
        seed: 20190412,
        ..base(name, summary, ArrivalProfile::ClosedLoop { clients: 8 })
    }
}

/// All named scenarios, in presentation order.
pub fn all() -> Vec<Scenario> {
    vec![
        Scenario {
            duration: Duration::from_secs(1),
            workers: 1,
            ..base(
                "smoke",
                "1s closed-loop sanity run (4 clients, shards=2) — the CI gate",
                ArrivalProfile::ClosedLoop { clients: 4 },
            )
        },
        Scenario {
            ttl: Some(Duration::from_millis(25)),
            fast_reject: true,
            ..base(
                "steady",
                "5s open-loop at 1500 req/s with 25ms TTLs — sustained-rate capacity",
                ArrivalProfile::OpenLoop { rate: 1500 },
            )
        },
        Scenario {
            queue_capacity: 256,
            ttl: Some(Duration::from_millis(50)),
            fast_reject: true,
            ..base(
                "burst",
                "5s of 96-request bursts every 250ms — queue absorption and shedding",
                ArrivalProfile::Burst { burst: 96, period: Duration::from_millis(250) },
            )
        },
        Scenario {
            duration: Duration::from_secs(6),
            ttl: Some(Duration::from_millis(25)),
            fast_reject: true,
            ..base(
                "ramp",
                "6s linear ramp 200→6000 req/s — locates the saturation knee",
                ArrivalProfile::Ramp { from: 200, to: 6000 },
            )
        },
        mixed_base(
            "mixed",
            "4s closed-loop (8 clients, shards=4): full size ladder + composites",
        ),
        Scenario {
            batch_window: BatchWindow::Fixed(window_extremes().0),
            ..mixed_base(
                "mixed-window-min",
                "the mixed workload pinned to the minimum static batch window (A/B floor)",
            )
        },
        Scenario {
            batch_window: BatchWindow::Fixed(window_extremes().1),
            ..mixed_base(
                "mixed-window-max",
                "the mixed workload pinned to the maximum static batch window (A/B ceiling)",
            )
        },
        Scenario {
            batch_window: BatchWindow::Adaptive,
            ..mixed_base(
                "mixed-adaptive",
                "the mixed workload under the adaptive batch window — must match or beat \
                 both static extremes",
            )
        },
        Scenario {
            duration: Duration::from_secs(4),
            mix: WorkloadMix::two_lane(),
            shards: 4,
            queue_capacity: 512,
            ttl: Some(Duration::from_millis(60)),
            fast_reject: true,
            ..base(
                "lanes",
                "4s of 64-request two-lane bursts every 100ms: bulk floods the service \
                 and is shed; interactive must hold its TTL with zero deadline misses",
                ArrivalProfile::Burst { burst: 64, period: Duration::from_millis(100) },
            )
        },
        Scenario {
            duration: Duration::from_secs(2),
            workers: 1,
            fault_seed: Some(0xC0FFEE),
            ..base(
                "chaos",
                "2s closed-loop under seeded fault injection — degraded capacity & self-healing",
                ArrivalProfile::ClosedLoop { clients: 4 },
            )
        },
        Scenario {
            duration: Duration::from_secs(2),
            workers: 1,
            transport: TransportKind::Tcp,
            router: Some(RouterScenario { backends: 2, kill_seed: Some(0xFA11) }),
            ..base(
                "failover",
                "2s closed-loop through the router over 2 backends; one is killed \
                 mid-run and restarted — failover, redispatch and healing",
                ArrivalProfile::ClosedLoop { clients: 4 },
            )
        },
    ]
}

/// Look a scenario up by name.
pub fn by_name(name: &str) -> Option<Scenario> {
    all().into_iter().find(|s| s.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_named_scenario_is_resolvable_and_m1sim_sharded() {
        let names: Vec<&str> = all().iter().map(|s| s.name).collect();
        assert!(names.contains(&"smoke"));
        for s in all() {
            let found = by_name(s.name).expect("by_name finds every listed scenario");
            assert_eq!(found.name, s.name);
            // The acceptance contract: loadtest scenarios exercise the
            // sharded simulator backend.
            assert_eq!(found.backend, BackendChoice::M1Sim);
            assert!(found.shards >= 2, "{}: shards must be ≥ 2", s.name);
            assert!(!found.mix.sizes.is_empty() && !found.mix.transforms.is_empty());
            // Transport is an orthogonal axis, not a per-scenario knob —
            // except for router topologies, where the front-end router
            // *is* a wire listener and the transport is pinned to Tcp.
            match found.router {
                None => {
                    assert_eq!(found.transport, TransportKind::InProcess);
                    assert_eq!(
                        found.with_transport(TransportKind::Tcp).transport,
                        TransportKind::Tcp
                    );
                }
                Some(r) => {
                    assert_eq!(found.transport, TransportKind::Tcp, "{}", s.name);
                    assert!(r.backends >= 2, "{}: a router over <2 backends is pointless", s.name);
                }
            }
        }
        assert!(by_name("no-such-scenario").is_none());
    }

    #[test]
    fn failover_is_the_only_router_scenario_and_arms_the_kill_plan() {
        for s in all() {
            assert_eq!(
                s.router.is_some(),
                s.name == "failover",
                "{}: router topology must stay opt-in per scenario",
                s.name
            );
        }
        let failover = by_name("failover").expect("failover scenario listed");
        let router = failover.router.unwrap();
        assert_eq!(router.backends, 2);
        assert!(router.kill_seed.is_some(), "failover must kill a backend mid-run");
        assert!(failover.fault_seed.is_none(), "backend kills, not shard faults");
        assert!(
            failover.ttl.is_none() && !failover.fast_reject,
            "every admitted request must be answerable after redispatch"
        );
    }

    #[test]
    fn chaos_is_the_only_fault_armed_scenario() {
        for s in all() {
            assert_eq!(
                s.fault_seed.is_some(),
                s.name == "chaos",
                "{}: fault injection must stay opt-in per scenario",
                s.name
            );
        }
        let chaos = by_name("chaos").expect("chaos scenario listed");
        assert_eq!(chaos.backend, BackendChoice::M1Sim, "faults live in the M1 pool");
        assert!(chaos.shards >= 2, "chaos needs shards to kill");
    }

    #[test]
    fn window_ab_rows_differ_only_in_window_policy() {
        let (lo, hi) = window_extremes();
        assert!(lo < hi);
        let base = by_name("mixed").unwrap();
        let min = by_name("mixed-window-min").unwrap();
        let max = by_name("mixed-window-max").unwrap();
        let ada = by_name("mixed-adaptive").unwrap();
        assert_eq!(base.batch_window, BatchWindow::Default);
        assert_eq!(min.batch_window, BatchWindow::Fixed(lo));
        assert_eq!(max.batch_window, BatchWindow::Fixed(hi));
        assert_eq!(ada.batch_window, BatchWindow::Adaptive);
        for s in [&min, &max, &ada] {
            // Identical in everything that shapes the offered load.
            assert_eq!(s.seed, base.seed, "{}", s.name);
            assert_eq!(s.duration, base.duration, "{}", s.name);
            assert_eq!(s.profile, base.profile, "{}", s.name);
            assert_eq!(s.workers, base.workers, "{}", s.name);
            assert_eq!(s.shards, base.shards, "{}", s.name);
            assert_eq!(s.mix.sizes, base.mix.sizes, "{}", s.name);
            assert_eq!(s.mix.transforms, base.mix.transforms, "{}", s.name);
        }
        // The adaptive policy's batcher config really arms the controller;
        // the fixed policies pin max_wait.
        assert!(ada.batch_window.batcher_config().adaptive.is_some());
        assert_eq!(min.batch_window.batcher_config().max_wait, lo);
        assert!(min.batch_window.batcher_config().adaptive.is_none());
    }

    #[test]
    fn lanes_is_the_only_two_lane_scenario_and_has_teeth() {
        for s in all() {
            assert_eq!(
                s.mix.bulk_share > 0.0,
                s.name == "lanes",
                "{}: the bulk lane must stay opt-in per scenario",
                s.name
            );
        }
        let lanes = by_name("lanes").expect("lanes scenario listed");
        assert!(lanes.ttl.is_some(), "lane guarantees are stated against a TTL");
        assert!(lanes.fast_reject, "overload must shed, not block the generator");
        assert!(!lanes.mix.bulk_sizes.is_empty());
        assert!(
            lanes.mix.bulk_sizes.iter().all(|&(_, n)| n >= 1024),
            "bulk is the big-batch lane"
        );
        assert!(lanes.fault_seed.is_none() && lanes.router.is_none());
    }

    #[test]
    fn batch_window_labels_render() {
        assert_eq!(BatchWindow::Default.label(), "default");
        assert_eq!(BatchWindow::Fixed(Duration::from_micros(100)).label(), "fixed(100us)");
        assert_eq!(BatchWindow::Adaptive.label(), "adaptive");
    }

    #[test]
    fn profile_labels_render() {
        assert_eq!(ArrivalProfile::ClosedLoop { clients: 4 }.label(), "closed-loop(4)");
        assert_eq!(ArrivalProfile::OpenLoop { rate: 100 }.label(), "open-loop(100rps)");
        assert_eq!(
            ArrivalProfile::Burst { burst: 8, period: Duration::from_millis(20) }.label(),
            "burst(8/20ms)"
        );
        assert_eq!(ArrivalProfile::Ramp { from: 1, to: 9 }.label(), "ramp(1..9rps)");
    }
}
