//! # morpho — reconfigurable-computing graphics acceleration, reproduced
//!
//! Reproduction of *"Performance Analysis of Linear Algebraic Functions
//! using Reconfigurable Computing"* (Damaj & Diab). The paper maps 2-D
//! geometrical transformations — translation (vector-vector ops), scaling
//! (vector-scalar ops) and rotation/composite (matrix multiplication) —
//! onto the MorphoSys **M1** reconfigurable system and compares cycle
//! counts against Intel 80386/80486/Pentium baselines.
//!
//! This crate provides everything the paper's evaluation needs, built from
//! scratch:
//!
//! * [`morphosys`] — a cycle-accurate simulator of the M1 chip: TinyRISC
//!   control processor, the 8×8 RC array with context-word-programmed
//!   cells, the three-level interconnect, the dual-set frame buffer,
//!   context memory and the DMA controller. This plays the role of the
//!   authors' *mULATE* emulator.
//! * [`baselines`] — an x86-subset interpreter plus per-model cycle timing
//!   tables for the 80386, 80486 and Pentium, executing the paper's exact
//!   assembly listings (Tables 3–4) and the rotation matmul routine.
//! * [`mapping`] — the paper's contribution: the algorithm-mapping
//!   compiler that emits TinyRISC programs + RC-array context words for
//!   vector-vector, vector-scalar and matrix-multiplication mappings
//!   (Tables 1–2, §5.3), with a cost model cross-checked against the
//!   simulator.
//! * [`graphics`] — the 2-D geometry/transform library the mappings
//!   accelerate (the "complete graphics acceleration library" of §7).
//! * [`runtime`] — the PJRT (XLA) runtime that loads the AOT-compiled
//!   JAX/Pallas transform pipeline (`artifacts/*.hlo.txt`) and executes it
//!   from the request path with no Python involved.
//! * [`coordinator`] — the serving layer: async request queue with
//!   admission control (blocking backpressure, `try_submit` fast-reject,
//!   TTL deadline shedding), dynamic batcher packing requests into
//!   64-element tiles (the M1's natural unit), scheduler and pluggable
//!   backends (XLA / M1 simulator / native).
//! * [`loadgen`] — deterministic load generation & capacity measurement:
//!   named scenarios (closed-loop, open-loop, burst, ramp) over seeded
//!   workload mixes drive the coordinator end to end and write
//!   `BENCH_coordinator.json` (throughput, latency quantiles, shed
//!   counts, batch fill, simulated cycles/point).
//! * [`perf`] — the reproduction harness that regenerates every table and
//!   figure of the paper's evaluation (Tables 1–5, Figures 9–16).
//! * [`replay`] — self-contained failure-repro artifacts: program +
//!   pre-state snapshot + per-step state digests, replayable to the exact
//!   first divergent instruction (`repro replay <file>`).
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

pub mod baselines;
pub mod benchkit;
pub mod coordinator;
pub mod graphics;
pub mod loadgen;
pub mod mapping;
pub mod morphosys;
pub mod perf;
pub mod replay;
pub mod runtime;
pub mod testkit;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
