//! Failure-replay artifacts (§Robustness).
//!
//! When a shard crashes (real or injected) or a conformance axis
//! diverges, the supervision layer dumps a **self-contained repro
//! artifact**: the TinyRISC program, the complete pre-execution machine
//! state (an [`M1System::snapshot`] image), the seed and fault context it
//! ran under, a per-step FNV-1a/64 digest of the full architectural state
//! after every executed instruction, and (when known) the expected result
//! elements. `repro replay <file>` re-executes the artifact step by step
//! on a fresh simulator and reports the **exact first step** at which the
//! replayed state diverges from the recorded digests — turning a flaky
//! crash under load into a deterministic single-instruction pointer.
//!
//! ## Artifact format (`.m1ra`, version 1, little-endian)
//!
//! ```text
//! magic "M1RA" | version u16
//! seed u64
//! summary: u32 len + UTF-8 bytes        (human fault context)
//! program: u32 count + tag-byte instructions (Instruction::encode_bytes)
//! pre-state: u32 len + M1System snapshot bytes ("M1SS" image)
//! result_addr u32 | expected: u32 count + i16 elements (may be empty)
//! digests: u32 count + u64 per executed step (fnv1a64 of the snapshot)
//! ```
//!
//! Dumping is **opt-in** via the `MORPHO_REPRO_DIR` environment variable
//! so ordinary test runs never write artifacts; the CI chaos-smoke job
//! sets it and uploads whatever appears.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context};

use crate::morphosys::{fnv1a64, Instruction, M1System, Program};
use crate::Result;

/// Magic prefix of a repro artifact.
pub const ARTIFACT_MAGIC: [u8; 4] = *b"M1RA";
/// Current artifact format version.
pub const ARTIFACT_VERSION: u16 = 1;

/// A self-contained failure reproduction: everything needed to re-execute
/// one tile run step by step, plus the recorded truth to diverge against.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReproArtifact {
    /// Seed of the fault plan (or conformance case) that produced this.
    pub seed: u64,
    /// Human-readable fault context ("shard crash while …").
    pub summary: String,
    /// The TinyRISC program the tile ran.
    pub program: Program,
    /// Full pre-execution machine state ([`M1System::snapshot`] image).
    pub pre_state: Vec<u8>,
    /// Main-memory element address the expected result lives at.
    pub result_addr: usize,
    /// Expected result elements; empty when the original run never
    /// finished (e.g. a crash artifact).
    pub expected_result: Vec<i16>,
    /// FNV-1a/64 digest of the full snapshot after each executed step.
    pub step_digests: Vec<u64>,
}

/// Outcome of replaying an artifact against its recorded digests.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReplayOutcome {
    /// Every step digest (and the expected result, when recorded)
    /// matched: the artifact reproduces cleanly.
    Match { steps: usize },
    /// The replayed state first diverged from the recording at `step`
    /// (0-indexed executed-instruction count).
    Diverged { step: usize, recorded: u64, replayed: u64 },
    /// Every common step matched but the executions ran for different
    /// step counts (control flow diverged at the end).
    StepCountMismatch { recorded: usize, replayed: usize },
    /// All steps matched but the result read back differs at `index`.
    ResultMismatch { index: usize, expected: i16, found: i16 },
}

impl ReplayOutcome {
    pub fn is_match(&self) -> bool {
        matches!(self, ReplayOutcome::Match { .. })
    }

    pub fn render(&self) -> String {
        match self {
            ReplayOutcome::Match { steps } => {
                format!("MATCH: all {steps} steps and the result reproduce bit-for-bit")
            }
            ReplayOutcome::Diverged { step, recorded, replayed } => format!(
                "DIVERGED at step {step}: recorded digest {recorded:#018x}, \
                 replayed {replayed:#018x}"
            ),
            ReplayOutcome::StepCountMismatch { recorded, replayed } => format!(
                "STEP-COUNT MISMATCH: recording executed {recorded} steps, replay {replayed}"
            ),
            ReplayOutcome::ResultMismatch { index, expected, found } => format!(
                "RESULT MISMATCH at element {index}: expected {expected}, found {found}"
            ),
        }
    }
}

/// Run `program` from the restored `pre_state`, collecting the per-step
/// state digests (the expensive part of capture and the whole of replay).
fn digest_run(pre_state: &[u8], program: &Program) -> Result<(M1System, Vec<u64>)> {
    let mut sys = M1System::new();
    sys.restore(pre_state).map_err(|e| anyhow::anyhow!("artifact pre-state: {e}"))?;
    let mut digests = Vec::new();
    sys.run_with(program, |_, s| digests.push(fnv1a64(&s.snapshot())));
    Ok((sys, digests))
}

impl ReproArtifact {
    /// Capture an artifact: restore `pre_state` into a fresh simulator,
    /// execute `program`, and record the digest of the full architectural
    /// state after every step. `expected_result` may be empty when the
    /// correct answer is unknown (crash artifacts).
    pub fn capture(
        seed: u64,
        summary: String,
        program: Program,
        pre_state: Vec<u8>,
        result_addr: usize,
        expected_result: Vec<i16>,
    ) -> Result<ReproArtifact> {
        let (_, step_digests) = digest_run(&pre_state, &program)?;
        Ok(ReproArtifact {
            seed,
            summary,
            program,
            pre_state,
            result_addr,
            expected_result,
            step_digests,
        })
    }

    /// Re-execute this artifact step by step and compare against the
    /// recording; see [`ReplayOutcome`].
    pub fn replay(&self) -> Result<ReplayOutcome> {
        let (sys, replayed) = digest_run(&self.pre_state, &self.program)?;
        for (step, (rec, rep)) in self.step_digests.iter().zip(&replayed).enumerate() {
            if rec != rep {
                return Ok(ReplayOutcome::Diverged { step, recorded: *rec, replayed: *rep });
            }
        }
        if replayed.len() != self.step_digests.len() {
            return Ok(ReplayOutcome::StepCountMismatch {
                recorded: self.step_digests.len(),
                replayed: replayed.len(),
            });
        }
        if !self.expected_result.is_empty() {
            let found = sys.mem.load_elements(self.result_addr, self.expected_result.len());
            for (index, (e, f)) in self.expected_result.iter().zip(&found).enumerate() {
                if e != f {
                    return Ok(ReplayOutcome::ResultMismatch {
                        index,
                        expected: *e,
                        found: *f,
                    });
                }
            }
        }
        Ok(ReplayOutcome::Match { steps: replayed.len() })
    }

    /// Serialize to the `.m1ra` wire format (see the module docs).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&ARTIFACT_MAGIC);
        out.extend_from_slice(&ARTIFACT_VERSION.to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&(self.summary.len() as u32).to_le_bytes());
        out.extend_from_slice(self.summary.as_bytes());
        out.extend_from_slice(&(self.program.instructions.len() as u32).to_le_bytes());
        for i in &self.program.instructions {
            i.encode_bytes(&mut out);
        }
        out.extend_from_slice(&(self.pre_state.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.pre_state);
        out.extend_from_slice(&(self.result_addr as u32).to_le_bytes());
        out.extend_from_slice(&(self.expected_result.len() as u32).to_le_bytes());
        for e in &self.expected_result {
            out.extend_from_slice(&e.to_le_bytes());
        }
        out.extend_from_slice(&(self.step_digests.len() as u32).to_le_bytes());
        for d in &self.step_digests {
            out.extend_from_slice(&d.to_le_bytes());
        }
        out
    }

    /// Parse the `.m1ra` wire format.
    pub fn from_bytes(bytes: &[u8]) -> Result<ReproArtifact> {
        let mut pos = 0usize;
        fn take<'a>(bytes: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8]> {
            let end = pos.checked_add(n).filter(|&e| e <= bytes.len());
            match end {
                Some(e) => {
                    let s = &bytes[*pos..e];
                    *pos = e;
                    Ok(s)
                }
                None => bail!("truncated artifact at offset {pos}"),
            }
        }
        fn u16f(bytes: &[u8], pos: &mut usize) -> Result<u16> {
            Ok(u16::from_le_bytes(take(bytes, pos, 2)?.try_into().unwrap()))
        }
        fn u32f(bytes: &[u8], pos: &mut usize) -> Result<usize> {
            Ok(u32::from_le_bytes(take(bytes, pos, 4)?.try_into().unwrap()) as usize)
        }
        fn u64f(bytes: &[u8], pos: &mut usize) -> Result<u64> {
            Ok(u64::from_le_bytes(take(bytes, pos, 8)?.try_into().unwrap()))
        }
        if take(bytes, &mut pos, 4)? != ARTIFACT_MAGIC {
            bail!("not a repro artifact (bad magic; expected \"M1RA\")");
        }
        let version = u16f(bytes, &mut pos)?;
        if version != ARTIFACT_VERSION {
            bail!("unsupported artifact version {version} (this build reads {ARTIFACT_VERSION})");
        }
        let seed = u64f(bytes, &mut pos)?;
        let summary_len = u32f(bytes, &mut pos)?;
        let summary = std::str::from_utf8(take(bytes, &mut pos, summary_len)?)
            .context("artifact summary is not UTF-8")?
            .to_string();
        let n_instr = u32f(bytes, &mut pos)?;
        let mut instructions = Vec::with_capacity(n_instr.min(1 << 20));
        for k in 0..n_instr {
            let i = Instruction::decode_bytes(bytes, &mut pos)
                .map_err(|e| anyhow::anyhow!("instruction {k}: {e}"))?;
            instructions.push(i);
        }
        let pre_len = u32f(bytes, &mut pos)?;
        let pre_state = take(bytes, &mut pos, pre_len)?.to_vec();
        let result_addr = u32f(bytes, &mut pos)?;
        let n_expected = u32f(bytes, &mut pos)?;
        let mut expected_result = Vec::with_capacity(n_expected.min(1 << 20));
        for _ in 0..n_expected {
            expected_result
                .push(i16::from_le_bytes(take(bytes, &mut pos, 2)?.try_into().unwrap()));
        }
        let n_digests = u32f(bytes, &mut pos)?;
        let mut step_digests = Vec::with_capacity(n_digests.min(1 << 20));
        for _ in 0..n_digests {
            step_digests.push(u64f(bytes, &mut pos)?);
        }
        if pos != bytes.len() {
            bail!("{} trailing bytes after artifact", bytes.len() - pos);
        }
        Ok(ReproArtifact {
            seed,
            summary,
            program: Program::new(instructions),
            pre_state,
            result_addr,
            expected_result,
            step_digests,
        })
    }

    /// Write this artifact into `dir` under a unique name; returns the
    /// path. The name carries the seed and the pre-state digest so repeat
    /// crashes of the same case overwrite rather than accumulate.
    pub fn write_into(&self, dir: &Path) -> Result<PathBuf> {
        static SERIAL: AtomicU64 = AtomicU64::new(0);
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating repro dir {}", dir.display()))?;
        let n = SERIAL.fetch_add(1, Ordering::Relaxed);
        let path = dir.join(format!(
            "repro-seed{}-{:016x}-{n}.m1ra",
            self.seed,
            fnv1a64(&self.pre_state)
        ));
        std::fs::write(&path, self.to_bytes())
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(path)
    }

    /// Read an artifact file.
    pub fn read_from(path: &Path) -> Result<ReproArtifact> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        Self::from_bytes(&bytes)
    }
}

/// The opt-in artifact directory: `MORPHO_REPRO_DIR`, or `None` (no
/// dumping) when unset or empty.
pub fn dump_dir() -> Option<PathBuf> {
    match std::env::var_os("MORPHO_REPRO_DIR") {
        Some(d) if !d.is_empty() => Some(PathBuf::from(d)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::runner::stage_routine3_on;
    use crate::mapping::{VecVecMapping, RESULT_ADDR};
    use crate::morphosys::AluOp;

    /// A staged 64-point add tile: (program, pre-state snapshot, expected).
    fn staged_case(async_dma: bool) -> (Program, Vec<u8>, Vec<i16>) {
        let routine = VecVecMapping { n: 64, op: AluOp::Add }.compile();
        let u: Vec<i16> = (0..64).collect();
        let v: Vec<i16> = (0..64).map(|i| 500 - 3 * i).collect();
        let expected: Vec<i16> = u.iter().zip(&v).map(|(a, b)| a + b).collect();
        let mut sys = M1System::with_dma_mode(async_dma);
        stage_routine3_on(&mut sys, &routine, &u, Some(&v), None);
        (routine.program.clone(), sys.snapshot(), expected)
    }

    fn artifact(async_dma: bool) -> ReproArtifact {
        let (program, pre, expected) = staged_case(async_dma);
        ReproArtifact::capture(
            17,
            "unit-test artifact".into(),
            program,
            pre,
            RESULT_ADDR,
            expected,
        )
        .unwrap()
    }

    #[test]
    fn captured_artifact_replays_to_a_match() {
        for async_dma in [false, true] {
            let art = artifact(async_dma);
            assert!(!art.step_digests.is_empty());
            let outcome = art.replay().unwrap();
            assert!(outcome.is_match(), "{async_dma}: {}", outcome.render());
        }
    }

    #[test]
    fn wire_format_roundtrips_exactly() {
        let art = artifact(false);
        let bytes = art.to_bytes();
        let back = ReproArtifact::from_bytes(&bytes).unwrap();
        assert_eq!(back, art);
        // Corruption is a typed error, never a panic.
        assert!(ReproArtifact::from_bytes(b"nope").is_err());
        assert!(ReproArtifact::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(ReproArtifact::from_bytes(&trailing).is_err());
        let mut bad_version = bytes;
        bad_version[4] = 99;
        assert!(ReproArtifact::from_bytes(&bad_version).is_err());
    }

    #[test]
    fn tampered_digest_reports_the_exact_first_divergent_step() {
        // The acceptance property: a divergence artifact replays to the
        // precise first step at which the recording and re-execution
        // disagree — for every possible position.
        let art = artifact(false);
        for k in [0usize, 1, art.step_digests.len() / 2, art.step_digests.len() - 1] {
            let mut tampered = art.clone();
            tampered.step_digests[k] ^= 1;
            match tampered.replay().unwrap() {
                ReplayOutcome::Diverged { step, recorded, replayed } => {
                    assert_eq!(step, k, "first divergence must be at the tampered step");
                    assert_eq!(recorded ^ 1, replayed);
                }
                other => panic!("expected divergence at {k}, got {}", other.render()),
            }
        }
    }

    #[test]
    fn tampered_expected_result_reports_result_mismatch() {
        let mut art = artifact(false);
        art.expected_result[5] ^= 0x40;
        match art.replay().unwrap() {
            ReplayOutcome::ResultMismatch { index: 5, .. } => {}
            other => panic!("expected result mismatch, got {}", other.render()),
        }
    }

    #[test]
    fn write_and_read_roundtrip_through_a_file() {
        let art = artifact(true);
        let dir = std::env::temp_dir().join("morpho-replay-test");
        let path = art.write_into(&dir).unwrap();
        let back = ReproArtifact::read_from(&path).unwrap();
        assert_eq!(back, art);
        assert!(back.replay().unwrap().is_match());
        let _ = std::fs::remove_file(path);
    }
}
