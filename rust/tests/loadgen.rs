//! Integration: the loadgen harness end to end against real backends,
//! and the coordinator's delivery guarantee under mid-stream shutdown —
//! every in-flight request gets a response or an explicit clean
//! rejection; reply channels never just die.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use morpho::coordinator::{
    BackendChoice, BatcherConfig, Coordinator, CoordinatorConfig, ServeResult, WireServer,
};
use morpho::graphics::Transform;
use morpho::loadgen::{
    self, ArrivalProfile, BatchWindow, RequestFactory, Scenario, TransportKind, WireClient,
    WorkloadMix,
};

/// The CI smoke scenario, shortened: must complete real requests on the
/// sharded M1 simulator with zero failed (dead-channel) requests and
/// report simulated cycles.
#[test]
fn smoke_scenario_runs_on_sharded_m1sim() {
    let mut sc = loadgen::scenario::by_name("smoke").expect("smoke scenario exists");
    sc.duration = Duration::from_millis(300);
    assert!(sc.shards >= 2);
    let r = loadgen::run_scenario(&sc).unwrap();
    assert!(r.completed > 0, "smoke must serve requests: {}", r.render());
    assert_eq!(r.failed, 0, "reply channels must never die: {}", r.render());
    assert_eq!(r.backend, "m1sim");
    assert!(r.shards >= 2);
    assert!(
        r.sim_cycles_per_point > 0.0,
        "the M1Sim backend must report simulated cycles: {}",
        r.render()
    );
    assert!(r.mean_batch_points > 0.0);
    assert!(r.latency_p99_us >= r.latency_p50_us);
}

/// The report writer produces the CI-consumed artifact shape: a JSON
/// array, one object per scenario, written atomically.
#[test]
fn loadtest_report_file_matches_ci_contract() {
    let mut sc = loadgen::scenario::by_name("smoke").unwrap();
    sc.duration = Duration::from_millis(200);
    let report = loadgen::run_scenario(&sc).unwrap();
    let dir = std::env::temp_dir().join("morpho_loadgen_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BENCH_coordinator.json");
    let path = path.to_str().unwrap();
    loadgen::report::write_reports(&[report], path).unwrap();
    let s = std::fs::read_to_string(path).unwrap();
    assert!(s.trim_start().starts_with('[') && s.trim_end().ends_with(']'));
    assert!(s.contains("\"scenario\": \"smoke\""));
    assert!(s.contains("\"failed\": 0"));
    assert!(!std::path::Path::new(&format!("{path}.tmp")).exists(), "atomic rename");
}

/// A custom (non-registry) scenario exercises the open-loop burst path
/// against the simulator with fast-reject admission.
#[test]
fn burst_profile_with_fast_reject_accounts_for_every_request() {
    let sc = Scenario {
        name: "test-burst",
        summary: "integration",
        profile: ArrivalProfile::Burst { burst: 24, period: Duration::from_millis(50) },
        duration: Duration::from_millis(300),
        mix: WorkloadMix::standard(),
        seed: 77,
        backend: BackendChoice::M1Sim,
        workers: 1,
        shards: 2,
        queue_capacity: 16,
        ttl: Some(Duration::from_millis(200)),
        fast_reject: true,
        fault_seed: None,
        batch_window: BatchWindow::Default,
        transport: TransportKind::InProcess,
        router: None,
    };
    let r = loadgen::run_scenario(&sc).unwrap();
    assert_eq!(r.failed, 0);
    assert!(r.submitted >= 24, "at least the first burst is offered");
    assert!(r.completed + r.shed + r.rejected <= r.submitted);
    assert!(r.completed > 0);
}

/// The two-lane scenario end to end, shortened: bulk bursts ride the
/// standard lane while interactive requests keep completing with zero
/// client-observed deadline rejections — the lane-isolation invariant
/// the CI lanes gate reads off the full-length run.
#[test]
fn lanes_scenario_serves_interactive_while_bulk_bears_the_pressure() {
    let mut sc = loadgen::scenario::by_name("lanes").expect("lanes scenario exists");
    sc.duration = Duration::from_millis(800);
    assert!(sc.mix.bulk_share > 0.0, "lanes must blend bulk traffic");
    assert!(sc.ttl.is_some(), "lanes runs under TTL pressure");
    let r = loadgen::run_scenario(&sc).unwrap();
    assert_eq!(r.failed, 0, "no reply channel may die: {}", r.render());
    assert!(r.interactive_completed > 0, "interactive lane must be served: {}", r.render());
    assert!(r.bulk_completed + r.bulk_shed > 0, "bulk lane must see traffic: {}", r.render());
    assert_eq!(
        r.interactive_deadline_missed, 0,
        "interactive must never be shed while bulk absorbs the pressure: {}",
        r.render()
    );
    // Lane tallies are a client-side view of the same run the aggregate
    // columns describe — they can never exceed the aggregates.
    assert!(r.interactive_completed + r.bulk_completed == r.completed);
    assert!(r.bulk_shed <= r.shed);
}

/// The adaptive batch window serves the mixed workload end to end: same
/// request streams as the static A/B rows, a live controller instead of
/// a pinned window, clean accounting either way.
#[test]
fn adaptive_window_scenario_completes_cleanly() {
    let mut sc = loadgen::scenario::by_name("mixed-adaptive").expect("adaptive A/B row exists");
    assert_eq!(sc.batch_window, BatchWindow::Adaptive);
    sc.duration = Duration::from_millis(400);
    let r = loadgen::run_scenario(&sc).unwrap();
    assert_eq!(r.failed, 0, "no reply channel may die: {}", r.render());
    assert!(r.completed > 0, "adaptive batching must serve requests: {}", r.render());
    assert_eq!(r.batch_window, "adaptive");
    assert!(r.to_json().contains("\"batch_window\": \"adaptive\""));
}

/// The transport differential (ROADMAP §Scale): the same seeded request
/// set served in-process and over the loopback wire protocol yields
/// bit-identical response payloads, and both ledgers agree — everything
/// offered is admitted, everything admitted is answered.
#[test]
fn same_seeded_requests_are_bit_identical_across_transports() {
    let factory = RequestFactory::new(4242, WorkloadMix::standard());
    let requests: Vec<_> = (0..24u64).map(|i| factory.request(i % 3, i / 3)).collect();
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    let config = || CoordinatorConfig {
        backend: BackendChoice::M1Sim,
        m1_shards: 2,
        workers: 2,
        batcher: BatcherConfig { max_wait: Duration::from_micros(500), ..Default::default() },
        ..Default::default()
    };

    // In-process: straight library calls.
    let c = Coordinator::start(config()).unwrap();
    let rxs: Vec<_> = requests
        .iter()
        .map(|g| c.submit(g.xs.clone(), g.ys.clone(), g.transforms.clone()).unwrap())
        .collect();
    let in_process: Vec<_> = rxs
        .into_iter()
        .map(|rx| {
            let r = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
            (bits(&r.xs), bits(&r.ys))
        })
        .collect();
    let m = c.metrics();
    assert_eq!(m.requests, requests.len() as u64, "in-process: all admitted");
    assert_eq!(m.responses, m.requests, "in-process: answered == admitted");
    c.shutdown();

    // Loopback: the same requests through the wire protocol.
    let c = Arc::new(Coordinator::start(config()).unwrap());
    let server = WireServer::bind("127.0.0.1:0", c.clone()).unwrap();
    let client = WireClient::connect(server.local_addr(), None).unwrap();
    let rxs: Vec<_> = requests
        .iter()
        .map(|g| client.submit(g.xs.clone(), g.ys.clone(), g.transforms.clone(), false).unwrap())
        .collect();
    let over_wire: Vec<_> = rxs
        .into_iter()
        .map(|rx| {
            let r = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
            (bits(&r.xs), bits(&r.ys))
        })
        .collect();
    let m = c.metrics();
    assert_eq!(m.requests, requests.len() as u64, "loopback: all admitted");
    assert_eq!(m.responses, m.requests, "loopback: answered == admitted");
    drop(client);
    server.shutdown();
    if let Ok(c) = Arc::try_unwrap(c) {
        c.shutdown();
    }

    assert_eq!(in_process, over_wire, "transports must serve bit-identical payloads");
}

/// The scenario axis of the same differential: `run_scenario` on each
/// transport completes cleanly, stamps the report's transport column,
/// and shows identical closed-loop accounting — without TTLs or
/// fast-reject, everything offered is answered on both paths.
#[test]
fn scenario_accounting_is_identical_across_transports() {
    for transport in [TransportKind::InProcess, TransportKind::Tcp] {
        let mut sc = loadgen::scenario::by_name("smoke").unwrap().with_transport(transport);
        sc.duration = Duration::from_millis(250);
        let r = loadgen::run_scenario(&sc).unwrap();
        assert_eq!(r.transport, transport.label());
        assert_eq!(r.failed, 0, "{}: no reply channel may die", transport.label());
        assert!(r.completed > 0, "{}: must serve requests", transport.label());
        assert_eq!(
            r.completed, r.submitted,
            "{}: closed-loop without TTLs answers everything it offers",
            transport.label()
        );
        assert_eq!(r.shed + r.rejected + r.closed, 0, "{}: nothing shed", transport.label());
        assert!(r.to_json().contains(&format!("\"transport\": \"{}\"", transport.label())));
    }
}

/// The chaos scenario end to end: seeded faults crash shards inside the
/// pool while the coordinator serves — yet no reply channel dies, the
/// run completes requests, and the degraded-capacity report carries the
/// supervision breakdown.
#[test]
fn chaos_scenario_degrades_gracefully_and_loses_nothing() {
    let mut sc = loadgen::scenario::by_name("chaos").expect("chaos scenario exists");
    sc.duration = Duration::from_millis(400);
    assert!(sc.fault_seed.is_some(), "chaos must arm fault injection");
    let r = loadgen::run_scenario(&sc).unwrap();
    assert_eq!(r.failed, 0, "supervision may not lose replies: {}", r.render());
    assert!(r.completed > 0, "degraded service still serves: {}", r.render());
    assert_eq!(r.fault_seed, sc.fault_seed);
    // Over ~400ms of 4-client closed-loop M1Sim traffic the chaos plan's
    // panic schedule (one per ~6-10 tile dispatches) always fires.
    assert!(
        r.shard_crashes > 0 && r.shard_restarts > 0,
        "chaos must actually crash shards: {}",
        r.render()
    );
    assert!(r.render().contains("fault injection (seed"));
    assert!(r.to_json().contains("\"shard_crashes\""));
}

/// Chaos determinism: the same requests served fault-free and under an
/// armed chaos plan produce bit-identical responses — supervision repairs
/// every injected failure before it can reach a client.
#[test]
fn chaos_responses_are_bit_identical_to_fault_free_serving() {
    use morpho::coordinator::FaultPlan;
    let run = |faults: Option<FaultPlan>| -> Vec<(Vec<f32>, Vec<f32>)> {
        let c = Coordinator::start(CoordinatorConfig {
            backend: BackendChoice::M1Sim,
            m1_shards: 2,
            workers: 1,
            batcher: BatcherConfig { max_wait: Duration::from_micros(500), ..Default::default() },
            fault_plan: faults,
            ..Default::default()
        })
        .unwrap();
        let receivers: Vec<_> = (0..12)
            .map(|i| {
                let n = 64 + (i * 97) % 1000;
                let xs: Vec<f32> = (0..n).map(|k| ((k + i) % 113) as f32 - 56.0).collect();
                let ys: Vec<f32> = (0..n).map(|k| ((k * 3) % 89) as f32 - 44.0).collect();
                c.submit(xs, ys, vec![Transform::Translate { tx: 5.0, ty: -7.0 }]).unwrap()
            })
            .collect();
        let out = receivers
            .into_iter()
            .map(|rx| {
                let resp = rx.recv().expect("reply channel alive").expect("no TTL, never shed");
                (resp.xs, resp.ys)
            })
            .collect();
        c.shutdown();
        out
    };
    let clean = run(None);
    let plan = FaultPlan::chaos(0xD15EA5E);
    let chaotic = run(Some(plan.clone()));
    assert!(plan.panics_fired() > 0, "the chaos plan must have injected panics");
    for (i, (c, f)) in clean.iter().zip(&chaotic).enumerate() {
        assert_eq!(c.0.len(), f.0.len(), "request {i} xs length");
        for (j, (a, b)) in c.0.iter().zip(&f.0).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "request {i} xs[{j}]");
        }
        for (j, (a, b)) in c.1.iter().zip(&f.1).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "request {i} ys[{j}]");
        }
    }
}

/// The router differential: the same seeded requests served through the
/// front-end router over two backends are bit-identical to a
/// single-coordinator wire run. Responses are pure functions of the
/// request payload, so *which* backend served each request is
/// unobservable — the property mid-run failover relies on when it
/// redispatches in-flight requests to a different backend.
#[test]
fn routed_responses_are_bit_identical_to_a_single_backend_run() {
    use morpho::coordinator::{Router, RouterConfig};
    let factory = RequestFactory::new(0xB17_F11E, WorkloadMix::standard());
    let requests: Vec<_> = (0..24u64).map(|i| factory.request(i % 3, i / 3)).collect();
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    let config = || CoordinatorConfig {
        backend: BackendChoice::M1Sim,
        m1_shards: 2,
        workers: 2,
        batcher: BatcherConfig { max_wait: Duration::from_micros(500), ..Default::default() },
        ..Default::default()
    };
    let drain = |client: &WireClient| -> Vec<(Vec<u32>, Vec<u32>)> {
        let rxs: Vec<_> = requests
            .iter()
            .map(|g| {
                client.submit(g.xs.clone(), g.ys.clone(), g.transforms.clone(), false).unwrap()
            })
            .collect();
        rxs.into_iter()
            .map(|rx| {
                let r = rx.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
                (bits(&r.xs), bits(&r.ys))
            })
            .collect()
    };

    // One coordinator, straight over the wire.
    let c = Arc::new(Coordinator::start(config()).unwrap());
    let server = WireServer::bind("127.0.0.1:0", c.clone()).unwrap();
    let client = WireClient::connect(server.local_addr(), None).unwrap();
    let single = drain(&client);
    drop(client);
    server.shutdown();
    if let Ok(c) = Arc::try_unwrap(c) {
        c.shutdown();
    }

    // Two coordinators behind the router, same wire protocol in front.
    let racks: Vec<_> = (0..2)
        .map(|_| {
            let c = Arc::new(Coordinator::start(config()).unwrap());
            let s = WireServer::bind("127.0.0.1:0", c.clone()).unwrap();
            (c, s)
        })
        .collect();
    let cfg = RouterConfig::new(racks.iter().map(|(_, s)| s.local_addr()).collect());
    let router = Router::bind("127.0.0.1:0", cfg).unwrap();
    assert!(router.wait_healthy(2, Duration::from_secs(10)), "both backends report healthy");
    let client = WireClient::connect(router.local_addr(), None).unwrap();
    let routed = drain(&client);
    let m = router.metrics();
    assert!(m.backends.iter().all(|b| b.proxied > 0), "both backends took traffic: {m:?}");
    assert_eq!(m.proxied, requests.len() as u64);
    assert_eq!(m.replies, requests.len() as u64, "exactly one reply per proxied request");
    drop(client);
    router.shutdown();
    for (c, s) in racks {
        s.shutdown();
        if let Ok(c) = Arc::try_unwrap(c) {
            c.shutdown();
        }
    }

    assert_eq!(single, routed, "the router must be payload-invisible");
}

/// The failover scenario end to end: a seeded kill plan takes one
/// backend down mid-run and restarts it on the same address. The gate:
/// the breaker fires (≥1 death), the revived backend heals back into
/// the rotation (≥1 rejoin), and no admitted request goes unanswered —
/// `failed == 0` across the whole outage.
#[test]
fn failover_scenario_heals_and_loses_nothing() {
    let mut sc = loadgen::scenario::by_name("failover").expect("failover scenario exists");
    sc.duration = Duration::from_millis(1500);
    let rs = sc.router.expect("failover runs through the router");
    assert_eq!(rs.backends, 2);
    assert!(rs.kill_seed.is_some(), "failover must arm the kill plan");
    let r = loadgen::run_scenario(&sc).unwrap();
    assert_eq!(r.failed, 0, "failover may not lose replies: {}", r.render());
    assert!(r.completed > 0, "service must keep serving through the outage: {}", r.render());
    assert!(r.backend_deaths >= 1, "the breaker must see the kill: {}", r.render());
    assert!(r.backend_rejoins >= 1, "the revived backend must rejoin: {}", r.render());
    assert_eq!(r.router_backends, 2);
    assert_eq!(r.backends.len(), 2, "one report row per backend");
    assert!(r.render().contains("router over 2 backends"));
    assert!(r.to_json().contains("\"backend_deaths\""));
}

type Receivers = Arc<Mutex<Vec<mpsc::Receiver<ServeResult>>>>;
type Storm = (Vec<std::thread::JoinHandle<()>>, Receivers, Arc<AtomicU64>);

fn submit_storm(c: &Arc<Coordinator>, threads: usize, per_thread: usize, points: usize) -> Storm {
    let receivers = Arc::new(Mutex::new(Vec::new()));
    let clean_rejects = Arc::new(AtomicU64::new(0));
    let handles = (0..threads)
        .map(|t| {
            let c = c.clone();
            let receivers = receivers.clone();
            let clean_rejects = clean_rejects.clone();
            std::thread::spawn(move || {
                for i in 0..per_thread {
                    let xs = vec![(t * per_thread + i) as f32 % 100.0; points];
                    let ys = vec![1.0f32; points];
                    match c.submit(xs, ys, vec![Transform::Translate { tx: 2.0, ty: -1.0 }]) {
                        Ok(rx) => receivers.lock().unwrap().push(rx),
                        // Clean rejection at submit: the queue closed.
                        Err(_) => {
                            clean_rejects.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            })
        })
        .collect();
    (handles, receivers, clean_rejects)
}

/// The shutdown-under-load guarantee: close the coordinator while
/// several threads are mid-stream. Every submission must either be
/// cleanly refused at the door, or — once admitted — receive exactly one
/// reply (response or rejection). No hangs, no dropped reply channels.
#[test]
fn shutdown_mid_stream_answers_or_cleanly_rejects_everything() {
    for (backend, shards) in [(BackendChoice::Native, 1), (BackendChoice::M1Sim, 2)] {
        let c = Arc::new(
            Coordinator::start(CoordinatorConfig {
                backend,
                m1_shards: shards,
                workers: 2,
                queue_capacity: 32,
                batcher: BatcherConfig {
                    max_wait: Duration::from_micros(200),
                    ..Default::default()
                },
                ..Default::default()
            })
            .unwrap(),
        );
        let (handles, receivers, clean_rejects) = submit_storm(&c, 4, 60, 64);
        // Let the storm get going, then slam the door mid-stream.
        std::thread::sleep(Duration::from_millis(5));
        c.close();
        for h in handles {
            h.join().unwrap();
        }
        let receivers = std::mem::take(&mut *receivers.lock().unwrap());
        let admitted = receivers.len() as u64;
        let mut served = 0u64;
        for rx in receivers {
            match rx.recv_timeout(Duration::from_secs(30)) {
                Ok(Ok(_)) => served += 1,
                Ok(Err(rej)) => panic!(
                    "admitted request {} rejected ({:?}) despite having no TTL",
                    rej.id, rej.reason
                ),
                Err(e) => panic!(
                    "admitted request hung or its reply channel died: {e:?} \
                     ({backend:?}, admitted={admitted})"
                ),
            }
        }
        assert_eq!(served, admitted, "every admitted request must be served");
        assert_eq!(
            admitted + clean_rejects.load(Ordering::Relaxed),
            4 * 60,
            "every submission accounted for ({backend:?})"
        );
        // Post-shutdown submissions are refused cleanly too.
        assert!(c.submit(vec![1.0], vec![1.0], vec![]).is_err());
    }
}

/// Same storm, but with TTL deadlines active: admitted requests may now
/// legitimately resolve to a shed rejection — but still exactly one
/// reply each, never a hang or dead channel.
#[test]
fn shutdown_mid_stream_with_ttls_still_replies_to_everything() {
    let c = Arc::new(
        Coordinator::start(CoordinatorConfig {
            backend: BackendChoice::M1Sim,
            m1_shards: 2,
            workers: 1,
            queue_capacity: 16,
            default_ttl: Some(Duration::from_millis(2)),
            batcher: BatcherConfig { max_wait: Duration::from_millis(5), ..Default::default() },
            ..Default::default()
        })
        .unwrap(),
    );
    let (handles, receivers, _clean_rejects) = submit_storm(&c, 4, 40, 500);
    std::thread::sleep(Duration::from_millis(5));
    c.close();
    for h in handles {
        h.join().unwrap();
    }
    let receivers = std::mem::take(&mut *receivers.lock().unwrap());
    let (mut served, mut shed) = (0u64, 0u64);
    for rx in receivers {
        match rx.recv_timeout(Duration::from_secs(30)) {
            Ok(Ok(_)) => served += 1,
            Ok(Err(_)) => shed += 1,
            Err(e) => panic!("request hung or reply channel died: {e:?}"),
        }
    }
    // With a 2ms TTL against a 5ms batch window some requests shed; both
    // outcomes are legitimate — silence is not.
    assert!(served + shed > 0);
    let m = c.metrics();
    assert_eq!(m.shed, shed, "client-observed sheds match the metrics counter");
}
